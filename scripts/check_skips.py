"""Skip-budget gate: collected-but-skipped tests vs a committed allowlist.

PRs 2-9 carried hypothesis-gated property suites that silently no-op'd
in CI for months (``pytest.importorskip`` skips are invisible in a green
run). This gate makes that impossible to repeat: the tier-1 job runs
pytest with ``--junitxml=test-report.xml``, then this script fails the
build if any skipped test is not matched by a pattern in
``tests/skip_allowlist.txt``.

Allowlist format: one ``fnmatch`` pattern per line against
``classname::testname`` (blank lines and ``#`` comments ignored). An
allowlist pattern that matches *nothing* also fails — stale entries
cannot accumulate and quietly widen the budget.

Run: ``python scripts/check_skips.py test-report.xml``
"""
from __future__ import annotations

import argparse
import fnmatch
import sys
import xml.etree.ElementTree as ET
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
ALLOWLIST = REPO / "tests" / "skip_allowlist.txt"


def load_allowlist(path: Path) -> list[str]:
    pats = []
    for line in path.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            pats.append(line)
    return pats


def skipped_tests(report: Path) -> list[str]:
    """``classname::name`` for every <testcase> with a <skipped> child."""
    root = ET.parse(report).getroot()
    out = []
    for case in root.iter("testcase"):
        if case.find("skipped") is not None:
            out.append(f"{case.get('classname', '')}::{case.get('name', '')}")
    return sorted(out)


def check(report: Path, allowlist: Path) -> int:
    skipped = skipped_tests(report)
    patterns = load_allowlist(allowlist)
    failures = []
    matched: set[str] = set()
    for test in skipped:
        hits = [p for p in patterns if fnmatch.fnmatch(test, p)]
        if hits:
            matched.update(hits)
        else:
            shown = allowlist.relative_to(REPO) \
                if allowlist.is_relative_to(REPO) else allowlist
            failures.append(
                f"skipped test not in allowlist: {test} "
                f"(add to {shown} or un-skip)")
    for pat in patterns:
        if pat not in matched:
            failures.append(
                f"stale allowlist pattern matches no skipped test: {pat!r} "
                f"— remove it so the budget stays tight")
    print(f"check_skips: {len(skipped)} skipped test(s), "
          f"{len(patterns)} allowlist pattern(s)")
    for t in skipped:
        print(f"  skipped: {t}")
    for f in failures:
        print(f"FAIL {f}")
    if not failures:
        print("check_skips OK")
    return 1 if failures else 0


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("report", type=Path, help="pytest --junitxml output")
    ap.add_argument("--allowlist", type=Path, default=ALLOWLIST)
    args = ap.parse_args(argv)
    if not args.report.exists():
        print(f"FAIL junit report not found: {args.report}")
        return 1
    return check(args.report, args.allowlist)


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
