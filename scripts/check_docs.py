"""Docs checker: executable examples + intra-repo link integrity.

Two guarantees, enforced by the CI ``docs`` job (and mirrored in tier-1
by ``tests/test_docs.py``):

  1. **Every fenced ``python`` block in ``docs/*.md`` runs.** Blocks in
     one document execute top-to-bottom as a single script (so later
     blocks may build on earlier ones), under ``PYTHONPATH=src`` from the
     repo root — exactly what the docs tell a reader to do. A fence
     tagged anything other than exactly ``python`` (``text``, ``json``,
     ``bash``, ``python-norun``...) is not executed.
  2. **Intra-repo markdown links resolve.** Every ``[text](target)`` in
     ``docs/*.md`` and ``README.md`` whose target is not an external URL
     or a pure anchor must point at an existing file or directory
     (fragments are stripped before the check).

Run: ``PYTHONPATH=src python scripts/check_docs.py [files...]``
(defaults to ``docs/*.md`` + ``README.md``). Exits non-zero with one
line per failure.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
_LINK = re.compile(r"\[[^\]]*\]\(([^()\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def extract_python_blocks(text: str) -> list[tuple[int, str]]:
    """Fenced ``python`` blocks as (start line number, source) pairs."""
    blocks, cur, start = [], None, 0
    for ln, line in enumerate(text.splitlines(), 1):
        fence = line.startswith("```")
        if cur is None and fence and line.strip() == "```python":
            cur, start = [], ln + 1
        elif cur is not None and fence:
            blocks.append((start, "\n".join(cur)))
            cur = None
        elif cur is not None:
            cur.append(line)
    return blocks


def iter_links(text: str):
    """Link targets of ``[text](target)``, fenced code excluded."""
    in_fence = False
    for line in text.splitlines():
        if line.startswith("```"):
            in_fence = not in_fence
            continue
        if not in_fence:
            yield from _LINK.findall(line)


def check_links(md: Path) -> list[str]:
    errors = []
    for target in iter_links(md.read_text()):
        if target.startswith(_EXTERNAL) or target.startswith("#"):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not (md.parent / path).exists():
            errors.append(f"{md}: broken link -> {target}")
    return errors


def run_blocks(md: Path) -> list[str]:
    blocks = extract_python_blocks(md.read_text())
    if not blocks:
        return []
    # one script per document: blocks share state top-to-bottom, with
    # line markers so a traceback names the offending block
    src = "\n\n".join(f"# --- {md.name}: block at line {ln} ---\n{code}"
                      for ln, code in blocks)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    with tempfile.NamedTemporaryFile("w", suffix=f"_{md.stem}.py",
                                     delete=False) as f:
        f.write(src)
        script = f.name
    try:
        r = subprocess.run([sys.executable, script], cwd=REPO, env=env,
                           capture_output=True, text=True, timeout=600)
    finally:
        os.unlink(script)
    if r.returncode != 0:
        return [f"{md}: python blocks failed "
                f"(exit {r.returncode}):\n{r.stdout[-1000:]}"
                f"{r.stderr[-3000:]}"]
    return []


def main(argv: list[str]) -> int:
    if argv:
        files = [Path(a) for a in argv]
    else:
        files = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]
    errors = []
    for md in files:
        if not md.exists():
            errors.append(f"{md}: missing")
            continue
        errors += check_links(md)
        if md.parent.name == "docs":        # README blocks are illustrative
            errors += run_blocks(md)
    for e in errors:
        print(f"FAIL {e}")
    if not errors:
        names = ", ".join(m.name for m in files)
        print(f"docs OK: {names} (links + executable python blocks)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
