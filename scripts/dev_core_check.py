"""Dev sanity check: SIVF core vs reference model."""
import jax.numpy as jnp
import numpy as np

from repro import core

rng = np.random.default_rng(0)
D, NL = 16, 8
cfg = core.SIVFConfig(dim=D, n_lists=NL, n_slabs=64, capacity=32,
                      n_max=4096, max_chain=16)
cents = rng.normal(size=(NL, D)).astype(np.float32)
state = core.init_state(cfg, jnp.asarray(cents))
ref = core.ReferenceIndex(cents)

# insert 200 vectors
B = 64
for step in range(4):
    ids = np.arange(step * B, (step + 1) * B, dtype=np.int32)
    vecs = rng.normal(size=(B, D)).astype(np.float32)
    state = core.insert(cfg, state, jnp.asarray(vecs), jnp.asarray(ids))
    ref.insert(vecs, ids)

print("after insert:", core.stats(cfg, state), "ref n_live:", ref.n_live)
assert int(state.n_live) == ref.n_live
assert int(state.error) == 0

# search exact (nprobe = all lists)
Q, K = 8, 5
qs = rng.normal(size=(Q, D)).astype(np.float32)
d, lab = core.search(cfg, state, jnp.asarray(qs), K, NL)
rd, rl = ref.search(qs, K, NL)
print("jax labels:", np.asarray(lab)[0], "ref labels:", rl[0])
np.testing.assert_allclose(np.asarray(d), rd, rtol=1e-4, atol=1e-4)
assert (np.asarray(lab) == rl).all(), "label mismatch"

# pointer-walk path must agree with table path
d2, l2 = core.search(cfg, state, jnp.asarray(qs), K, NL, use_tables=False)
np.testing.assert_allclose(np.asarray(d2), rd, rtol=1e-4, atol=1e-4)

# fused Pallas kernel (interpret mode) must agree with the xla dispatch
d3, l3 = core.search(cfg, state, jnp.asarray(qs), K, NL,
                     impl="pallas_interpret")
np.testing.assert_allclose(np.asarray(d3), np.asarray(d), rtol=1e-4,
                           atol=1e-4)
assert (np.asarray(l3) == np.asarray(lab)).all(), "fused kernel label mismatch"

# delete half, re-check
dels = np.arange(0, 4 * B, 2, dtype=np.int32)
state = core.delete(cfg, state, jnp.asarray(dels))
ref.delete(dels)
print("after delete:", core.stats(cfg, state), "ref n_live:", ref.n_live)
assert int(state.n_live) == ref.n_live
d, lab = core.search(cfg, state, jnp.asarray(qs), K, NL)
rd, rl = ref.search(qs, K, NL)
np.testing.assert_allclose(np.asarray(d), rd, rtol=1e-4, atol=1e-4)
assert (np.asarray(lab) == rl).all()

# overwrite semantics: re-insert id 1 with new payload
nv = rng.normal(size=(1, D)).astype(np.float32)
state = core.insert(cfg, state, jnp.asarray(nv), jnp.asarray([1], np.int32))
ref.insert(nv, [1])
assert int(state.n_live) == ref.n_live
d, lab = core.search(cfg, state, jnp.asarray(qs), K, NL)
rd, rl = ref.search(qs, K, NL)
np.testing.assert_allclose(np.asarray(d), rd, rtol=1e-4, atol=1e-4)

# nprobe < n_lists: subsets must match too
d, lab = core.search(cfg, state, jnp.asarray(qs), K, 2)
rd, rl = ref.search(qs, K, 2)
np.testing.assert_allclose(np.asarray(d), rd, rtol=1e-4, atol=1e-4)
assert (np.asarray(lab) == rl).all()

# delete everything; index must be empty, all slabs recycled
all_ids = np.arange(4 * B, dtype=np.int32)
state = core.delete(cfg, state, jnp.asarray(all_ids))
ref.delete(all_ids)
st = core.stats(cfg, state)
print("after full delete:", st)
assert st["n_live"] == 0 and st["free_slabs"] == cfg.n_slabs
assert st["error"] == 0

# pool exhaustion fail-fast
big = rng.normal(size=(cfg.n_slabs * cfg.capacity + cfg.capacity, D)).astype(np.float32)
big_ids = np.arange(big.shape[0], dtype=np.int32)
state = core.insert(cfg, state, jnp.asarray(big), jnp.asarray(big_ids))
print("exhaustion error flag:", int(state.error))
assert int(state.error) & core.ERR_POOL_EXHAUSTED

print("ALL CORE CHECKS PASSED")
