"""Bench-regression gate: fresh ``BENCH_*.json`` vs committed baselines.

The slow CI job regenerates every ``BENCH_*.json`` artifact from scratch;
this script compares each against its committed baseline under
``benchmarks/baselines/`` with *per-metric* tolerance bands and exits
non-zero on any regression, printing a comparison table either way.

Three band kinds (see ``METRICS``):

  * ``ratio_max`` — new <= baseline * tol (latency-style: lower is
    better; tolerances are generous because shared CI runners are noisy,
    and the point is catching step-function regressions, not 10% drift);
  * ``ratio_min`` — new >= baseline / tol (throughput-style: higher is
    better);
  * ``abs_min``   — new >= baseline - tol (bounded scores like recall@10,
    where "no worse" is an absolute statement);
  * ``exact_max`` — new <= baseline (counters that must never grow, like
    jit executable counts — a compile-count regression is a bug, not
    noise).

A metric path missing from the *fresh* artifact fails (a renamed field
must not silently drop out of the gate); a baseline file missing for a
known artifact fails likewise, so the gate cannot no-op. Metrics listed
as optional (path tuple ending in ``"?"``) are skipped only when absent
from the *baseline* (old baseline formats stay comparable).

Run: ``python scripts/check_bench.py [BENCH_foo.json ...]``
(defaults to every artifact named in ``METRICS``, read from the repo
root; ``--baseline-dir`` overrides the baseline location for tests).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
BASELINE_DIR = REPO / "benchmarks" / "baselines"


@dataclasses.dataclass(frozen=True)
class Band:
    """One gated metric: dotted ``path`` into the artifact JSON + band."""

    path: str                   # e.g. "deferred.p99_us.add"
    kind: str                   # ratio_max | ratio_min | abs_min | exact_max
    tol: float = 1.0
    optional: bool = False      # skip when absent from the BASELINE

    def check(self, base: float, new: float) -> bool:
        if self.kind == "ratio_max":
            return new <= base * self.tol
        if self.kind == "ratio_min":
            return new >= base / self.tol
        if self.kind == "abs_min":
            return new >= base - self.tol
        if self.kind == "exact_max":
            return new <= base
        raise ValueError(f"unknown band kind {self.kind!r}")

    def describe(self) -> str:
        return {"ratio_max": f"<= {self.tol}x",
                "ratio_min": f">= 1/{self.tol}x",
                "abs_min": f">= base-{self.tol}",
                "exact_max": "<= base"}[self.kind]


# Latency ratios are wide (shared-runner noise); structural counters are
# exact; recall/compression are near-exact. p999/p99 on sub-second phases
# routinely jitters 2-3x on CI runners — the gate is for order-of-
# magnitude regressions (a lost fused kernel, a compile storm, a stalled
# scheduler), which show up as >>4x.
METRICS: dict[str, list[Band]] = {
    "BENCH_streaming_churn.json": [
        Band("eager.p50_us.add", "ratio_max", 4.0),
        Band("eager.p50_us.search", "ratio_max", 4.0),
        Band("deferred.p50_us.add", "ratio_max", 4.0),
        Band("deferred.p99_us.add", "ratio_max", 4.0),
        Band("deferred.p99_us.flush", "ratio_max", 6.0),
        Band("eager.jit_compiles.add", "exact_max"),
        Band("eager.jit_compiles.search", "exact_max"),
        Band("deferred.jit_compiles.add", "exact_max"),
        Band("deferred.jit_compiles.search", "exact_max"),
    ],
    "BENCH_pq.json": [
        Band("recall_at_10", "abs_min", 0.02),
        Band("reduction.16", "ratio_min", 1.1),
        Band("reduction.256", "ratio_min", 1.1),
        Band("qps.pq.64", "ratio_min", 4.0),
        Band("bytes_per_vector.pq", "exact_max"),
    ],
    "BENCH_reshard.json": [
        Band("variants.raw.steps.0.seconds", "ratio_max", 4.0),
        Band("variants.pq.steps.0.seconds", "ratio_max", 4.0),
        Band("variants.raw.steps.0.bytes_moved", "exact_max"),
        Band("variants.pq.steps.0.bytes_moved", "exact_max"),
    ],
    "BENCH_filter.json": [
        # fused in-scan filtering is exact by construction: recall@10 vs
        # the within-predicate oracle must stay 1.0 at every selectivity
        Band("selectivities.sel1pct.fused.recall_at_10", "abs_min", 0.0),
        Band("selectivities.sel10pct.fused.recall_at_10", "abs_min", 0.0),
        Band("selectivities.sel50pct.fused.recall_at_10", "abs_min", 0.0),
        Band("selectivities.sel1pct.fused.qps", "ratio_min", 4.0),
        Band("selectivities.sel50pct.fused.qps", "ratio_min", 4.0),
        # one executable per filter STRUCTURE — constants must never mint
        Band("search_executables", "exact_max"),
    ],
    "BENCH_tiered.json": [
        # residency is a pure performance layer: any divergence from the
        # all-resident pool is a correctness bug, so parity is gated at
        # exactly 1.0 for every working-set ratio
        Band("ratios.r025.parity", "abs_min", 0.0),
        Band("ratios.r05.parity", "abs_min", 0.0),
        Band("ratios.r10.parity", "abs_min", 0.0),
        Band("ratios.r20.parity", "abs_min", 0.0),
        # working sets that fit the budget must serve warm from the
        # cache (uploads only on the fill, never in steady state)
        Band("ratios.r025.hit_rate", "abs_min", 0.02),
        Band("ratios.r10.hit_rate", "abs_min", 0.02),
        Band("ratios.r025.qps", "ratio_min", 4.0),
        Band("ratios.r20.qps", "ratio_min", 4.0),
    ],
    "BENCH_obs.json": [
        # the telemetry-overhead claim: pooled interleaved p99_on/p99_off.
        # The committed baseline pins this ratio at exactly 1.0 (a ratio's
        # ideal, not one run's luck), so ratio_max 1.05 here IS the
        # absolute <=5% band from ISSUE 9 — and the in-bench assert
        # (obs_bench.OVERHEAD_BOUND) already failed the run outright if
        # the pooled ratio crossed 1.05x.
        Band("overhead.p99_ratio_pooled", "ratio_max", 1.05),
        # the in-bench bound itself may never be silently loosened
        Band("overhead.bound", "exact_max"),
        Band("jit.search_executables", "exact_max"),
        # absolute latency sanity on the instrumented path (wide: runner
        # noise), catching an accidentally-hot enabled path that still
        # sneaks under the interleaved-ratio gate
        Band("on.p99_ms", "ratio_max", 4.0),
    ],
    "BENCH_drift.json": [
        # the ISSUE 10 claim: recall held under drift by online
        # maintenance (the in-bench assert already enforces the 0.95
        # floor; this band keeps the committed number honest too)...
        Band("final.maintained_recall_at_10", "abs_min", 0.05),
        # ...while the frozen-centroid baseline visibly decays. decayed
        # is a 0/1 witness and the gap must stay material.
        Band("final.decayed", "abs_min", 0.0),
        Band("final.recall_gap", "abs_min", 0.15),
        # maintenance (epoch bumps each commit) must not mint per-epoch
        # search executables
        Band("jit.search_executables", "exact_max"),
    ],
    "BENCH_serve.json": [
        Band("scale_points.0.idle.p99_ms", "ratio_max", 4.0),
        Band("scale_points.0.active.p99_ms", "ratio_max", 4.0),
        Band("scale_points.2.active.p99_ms", "ratio_max", 4.0),
        Band("scale_points.2.active.add_rows_per_s", "ratio_min", 4.0),
        Band("max_p99_active_over_idle", "ratio_max", 2.5),
        Band("jit.search_executables", "exact_max"),
        Band("jit.add", "exact_max"),
    ],
}


def lookup(doc, path: str):
    """Resolve a dotted path through dicts and lists (int segments)."""
    cur = doc
    for seg in path.split("."):
        if isinstance(cur, list):
            cur = cur[int(seg)]
        elif isinstance(cur, dict):
            if seg not in cur:
                raise KeyError(path)
            cur = cur[seg]
        else:
            raise KeyError(path)
    if not isinstance(cur, (int, float)) or isinstance(cur, bool):
        raise TypeError(f"{path} is not numeric: {cur!r}")
    return float(cur)


def compare_artifact(name: str, fresh_doc: dict, base_doc: dict,
                     bands: list[Band]) -> tuple[list[str], list[str]]:
    """-> (table rows, failure messages) for one artifact."""
    rows, failures = [], []
    for band in bands:
        try:
            base = lookup(base_doc, band.path)
        except (KeyError, IndexError, TypeError):
            if band.optional:
                rows.append(f"  {band.path:<42} (absent from baseline, "
                            f"skipped)")
                continue
            failures.append(f"{name}: baseline is missing {band.path}")
            continue
        try:
            new = lookup(fresh_doc, band.path)
        except (KeyError, IndexError, TypeError):
            failures.append(
                f"{name}: fresh artifact is missing {band.path} "
                f"(field renamed/dropped? update METRICS alongside)")
            continue
        ok = band.check(base, new)
        verdict = "ok" if ok else "REGRESSION"
        rows.append(f"  {band.path:<42} base={base:<12g} new={new:<12g} "
                    f"{band.describe():<12} {verdict}")
        if not ok:
            failures.append(
                f"{name}: {band.path} regressed — baseline {base:g}, "
                f"fresh {new:g}, band {band.describe()}")
    return rows, failures


def check(files: list[Path], baseline_dir: Path,
          metrics: dict[str, list[Band]] = METRICS) -> int:
    failures: list[str] = []
    for fresh in files:
        name = fresh.name
        bands = metrics.get(name)
        print(f"{name}:")
        if bands is None:
            failures.append(f"{name}: no metric bands registered — add it "
                            f"to METRICS in scripts/check_bench.py")
            continue
        if not fresh.exists():
            failures.append(f"{name}: fresh artifact not found at {fresh}")
            continue
        base_path = baseline_dir / name
        if not base_path.exists():
            failures.append(f"{name}: no committed baseline at {base_path} "
                            f"— commit one from a healthy run")
            continue
        rows, fails = compare_artifact(
            name, json.loads(fresh.read_text()),
            json.loads(base_path.read_text()), bands)
        for r in rows:
            print(r)
        failures += fails
    for f in failures:
        print(f"FAIL {f}")
    if not failures:
        print(f"bench OK: {len(files)} artifact(s) within tolerance bands")
    return 1 if failures else 0


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*",
                    help="fresh BENCH_*.json paths (default: every "
                         "registered artifact, from the repo root)")
    ap.add_argument("--baseline-dir", type=Path, default=BASELINE_DIR)
    args = ap.parse_args(argv)
    files = [Path(f) for f in args.files] if args.files else \
        [REPO / name for name in sorted(METRICS)]
    return check(files, args.baseline_dir)


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
