"""Render EXPERIMENTS.md tables from dry-run result JSONs.

Usage: PYTHONPATH=src python scripts/make_report.py
Prints markdown to stdout (pasted/regenerated into EXPERIMENTS.md).
"""
import json
from pathlib import Path

BASE = Path("experiments/baseline_paper_faithful.json")
OPT = Path("experiments/optimized_results.json")


def fmt_s(x):
    if x >= 1:
        return f"{x:8.2f}s"
    return f"{x * 1e3:7.2f}ms"


def table(results, mesh="single"):
    rows = []
    suffix = f"|{mesh}"
    for k in sorted(results):
        if not k.endswith(suffix):
            continue
        v = results[k]
        cell = k[: -len(suffix)]
        if v.get("status") == "skipped":
            rows.append(f"| {cell} | SKIP | — | — | — | — | — | "
                        f"{v['reason'][:60]} |")
            continue
        if v.get("status") != "ok":
            rows.append(f"| {cell} | ERROR | — | — | — | — | — | "
                        f"{v.get('error', '')[:60]} |")
            continue
        t = v["roofline"]
        uf = v.get("useful_flops_frac")
        rows.append(
            f"| {cell} | {t['dominant'].replace('_s', '')} | "
            f"{fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} | "
            f"{fmt_s(t['collective_s'])} | "
            f"{t['compute_fraction_of_bound'] * 100:5.1f}% | "
            f"{uf:5.2f} | compile {v['compile_s']}s |")
    head = ("| cell (arch \\| shape) | bound | compute | memory | "
            "collective | cf% | useful | notes |\n"
            "|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def perf_compare(base, opt, cells):
    out = ["| cell | term | baseline | optimized | gain |",
           "|---|---|---|---|---|"]
    for c in cells:
        b, o = base[c], opt[c]
        for term in ("compute_s", "memory_s", "collective_s",
                     "roofline_bound_s"):
            tb, to = b["roofline"][term], o["roofline"][term]
            gain = tb / to if to else float("inf")
            out.append(f"| {c} | {term} | {fmt_s(tb)} | {fmt_s(to)} | "
                       f"{gain:6.1f}x |")
    return "\n".join(out)


def main():
    base = json.loads(BASE.read_text())
    opt = json.loads(OPT.read_text())
    print("## Single-pod (16x16 = 256 chips) — paper-faithful baseline\n")
    print(table(base, "single"))
    print("\n## Single-pod — beyond-paper optimized\n")
    print(table(opt, "single"))
    print("\n## Multi-pod proof (2x16x16 = 512 chips) — optimized\n")
    print(table(opt, "multi"))
    print("\n## Perf iterations: baseline vs optimized (hillclimbed cells)\n")
    cells = ["moonshot-v1-16b-a3b|train_4k|single",
             "phi3-medium-14b|prefill_32k|single",
             "llama3-8b|decode_32k|single",
             "minicpm3-4b|decode_32k|single"]
    print(perf_compare(base, opt, cells))
    ok_b = sum(1 for v in base.values() if v.get("status") == "ok")
    ok_o = sum(1 for v in opt.values() if v.get("status") == "ok")
    sk = sum(1 for v in opt.values() if v.get("status") == "skipped")
    print(f"\ncells: baseline {ok_b} ok; optimized {ok_o} ok + {sk} "
          f"documented skips (of 80 arch x shape x mesh combinations)")


if __name__ == "__main__":
    main()
