"""End-to-end serving driver: batched decode over the slab-paged KV cache
with sliding-window eviction — the paper's streaming scenario (§5.5)
applied at the serving layer (DESIGN.md §3).

Run: PYTHONPATH=src python examples/sliding_window_serve.py
"""
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models import model as M
from repro.serve.paged_lm import PagedLMEngine
from repro.sharding.axes import strip
from repro.sharding.rules import unpadded_plan

cfg = ARCHS["llama3-8b"].reduced()
plan = unpadded_plan(cfg)
params = strip(M.init_params(cfg, plan, jax.random.key(0), max_seq=256))
rng = np.random.default_rng(0)

engine = PagedLMEngine(cfg, plan, params, page_size=16, n_pages=64,
                     max_seqs=4, max_pages_per_seq=16)

# admit a batch of requests (prefill writes pages; O(pages) allocation)
prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
           for n in (24, 40, 12, 32)]
for i, p in enumerate(prompts):
    assert engine.admit(i, p), "page pool exhausted"
print("admitted 4 requests;",
      f"free pages: {int(engine.pages.free_top)}/64")

# decode in lockstep; slide windows so the cache stays bounded
t0 = time.perf_counter()
n_steps = 60
for step in range(n_steps):
    toks = engine.step()
    if step % 20 == 19:
        for i in range(4):
            engine.slide(i, keep_last=32)     # O(1) page reclamation
        print(f"step {step + 1}: window slid; free pages "
              f"{int(engine.pages.free_top)}/64; last tokens {toks}")
dt = time.perf_counter() - t0
print(f"{4 * n_steps} tokens in {dt:.1f}s "
      f"({4 * n_steps / dt:.1f} tok/s on 1 CPU core)")

# eviction returns every page in O(1) — no compaction, ever
for i in range(4):
    engine.evict(i)
assert int(engine.pages.free_top) == 64
print("all sequences evicted; pool fully recycled")
