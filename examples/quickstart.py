"""Quickstart: the SIVF streaming vector index in 60 lines.

One `sivf.Index` session handle: stream ragged batches in, search, evict
in O(1), run a sliding window — the paper's core loop (§5.5) — and read
per-batch MutationReports instead of decoding sticky error bits. CI runs
this file end-to-end as a smoke test.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

import sivf

D, N_LISTS = 64, 32
rng = np.random.default_rng(0)

# 1. train the coarse quantizer and open a session handle
train = rng.normal(size=(2048, D)).astype(np.float32)
centroids = sivf.train_kmeans(jax.random.key(0), train, N_LISTS)
cfg = sivf.SIVFConfig(dim=D, n_lists=N_LISTS, n_slabs=512, capacity=64,
                      n_max=1 << 16, max_chain=128)
index = sivf.Index(cfg, centroids)

# 2. stream in 10k vectors with deliberately ragged batch sizes; the handle
#    pads to power-of-two buckets so jit compiles stay bounded
vecs = rng.normal(size=(10_000, D)).astype(np.float32)
lo = 0
while lo < 10_000:
    n = min(int(rng.integers(300, 2048)), 10_000 - lo)
    report = index.add(vecs[lo:lo + n], np.arange(lo, lo + n, dtype=np.int32))
    assert report.ok and report.accepted == n, report
    lo += n
print("after ingest:", index.stats())

# 3. search (top-10, probing 8 of 32 lists)
queries = rng.normal(size=(4, D)).astype(np.float32)
dists, labels = index.search(queries, k=10, nprobe=8)
print("top-3 neighbours of q0:", np.asarray(labels)[0, :3],
      np.asarray(dists)[0, :3].round(2))

# 4. O(1) deletion — no compaction, slabs recycle instantly
t0 = time.perf_counter()
report = index.remove(np.arange(5000, dtype=np.int32))
print(f"removed {report.accepted} in {(time.perf_counter() - t0) * 1e3:.1f} ms;",
      index.stats())
assert report.accepted == 5000

# 5. re-adding a live id overwrites it (delete-then-insert, one report)
report = index.add(vecs[:64], np.arange(5000, 5064, dtype=np.int32))
print(f"overwrite batch: accepted={report.accepted} "
      f"overwritten={report.overwritten}")

# 6. sliding window: steady-state churn with bounded memory
next_id = 10_000
for step in range(5):
    batch = rng.normal(size=(1000, D)).astype(np.float32)
    new_ids = np.arange(next_id, next_id + 1000, dtype=np.int32)
    assert index.add(batch, new_ids).ok
    index.remove(new_ids - 5000)                    # evict oldest
    next_id += 1000
print("after sliding window:", index.stats())
print("jit executables this session:", index.compile_stats())

# 7. deferred reports: submit the whole stream without a host sync, then
#    resolve every MutationReport with one flush (same executables as eager)
with sivf.Index(cfg, centroids, deferred=True) as dindex:
    futures = []
    for lo in range(0, 4096, 1024):
        futures.append(dindex.add(
            vecs[lo:lo + 1024], np.arange(lo, lo + 1024, dtype=np.int32)))
    assert not futures[0].done                      # nothing synced yet
    reports = dindex.flush()
assert all(r.ok for r in reports) and futures[-1].done
print(f"deferred: {len(reports)} reports resolved in one flush, "
      f"n_live={dindex.n_live}")
