"""Quickstart: the SIVF streaming vector index in 60 lines.

Builds an index, streams inserts, searches, deletes in O(1), and runs a
sliding window — the paper's core loop (§5.5).

Run: PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core

D, N_LISTS = 64, 32
rng = np.random.default_rng(0)

# 1. train the coarse quantizer and build an empty pool
train = rng.normal(size=(2048, D)).astype(np.float32)
centroids = core.train_kmeans(jax.random.key(0), jnp.asarray(train), N_LISTS)
cfg = core.SIVFConfig(dim=D, n_lists=N_LISTS, n_slabs=512, capacity=64,
                      n_max=1 << 16, max_chain=128)
state = core.init_state(cfg, centroids)

# 2. stream in 10k vectors
vecs = rng.normal(size=(10_000, D)).astype(np.float32)
ids = np.arange(10_000, dtype=np.int32)
for lo in range(0, 10_000, 2048):
    state = core.insert(cfg, state, jnp.asarray(vecs[lo:lo + 2048]),
                        jnp.asarray(ids[lo:lo + 2048]))
print("after ingest:", core.stats(cfg, state))

# 3. search (top-10, probing 8 of 32 lists)
queries = rng.normal(size=(4, D)).astype(np.float32)
dists, labels = core.search(cfg, state, jnp.asarray(queries), 10, 8)
print("top-3 neighbours of q0:", np.asarray(labels)[0, :3],
      np.asarray(dists)[0, :3].round(2))

# 4. O(1) deletion — no compaction, slabs recycle instantly
t0 = time.perf_counter()
state = core.delete(cfg, state, jnp.asarray(ids[:5000]))
jax.block_until_ready(state.n_live)
print(f"deleted 5k in {(time.perf_counter() - t0) * 1e3:.1f} ms;",
      core.stats(cfg, state))

# 5. sliding window: steady-state churn with bounded memory
next_id = 10_000
for step in range(5):
    batch = rng.normal(size=(1000, D)).astype(np.float32)
    new_ids = np.arange(next_id, next_id + 1000, dtype=np.int32)
    state = core.insert(cfg, state, jnp.asarray(batch),
                        jnp.asarray(new_ids))
    state = core.delete(cfg, state,
                        jnp.asarray(new_ids - 5000))   # evict oldest
    next_id += 1000
print("after sliding window:", core.stats(cfg, state))
assert int(state.error) == 0
