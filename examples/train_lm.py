"""End-to-end training driver wrapping repro.launch.train.

Defaults fit this single-core CPU container (a ~1M-param llama3-family
model, 120 steps with checkpointing). The same driver trains the ~100M+
configuration on real hardware — pass --preset 100m (documented target:
a few hundred steps on one accelerator host).

Run: PYTHONPATH=src python examples/train_lm.py [--preset 100m]
"""
import argparse
import sys

from repro.launch.train import main as train_main

PRESETS = {
    # CPU-container smoke: reduced llama3 family
    "tiny": ["--arch", "llama3-8b", "--reduced", "--steps", "120",
             "--batch", "8", "--seq", "64", "--lr", "1e-3",
             "--ckpt-every", "50"],
    # ~100M-param target for a single accelerator host (not reduced;
    # budgeted for a few hundred steps per the assignment)
    "100m": ["--arch", "llama3-8b", "--steps", "300",
             "--batch", "8", "--seq", "512", "--lr", "3e-4",
             "--ckpt-every", "100"],
}

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args, extra = ap.parse_known_args()
    argv = PRESETS[args.preset] + ["--ckpt-dir", args.ckpt_dir] + extra
    result = train_main(argv)
    if result["last_loss"] >= result["first_loss"]:
        print("WARNING: loss did not decrease", file=sys.stderr)
