"""Filtered RAG — multi-tenant retrieval with time-window predicates.

Two tenants share one GPU-resident index through the serve engine; each
is pinned to a mandatory ``Eq("tenant", ...)`` filter, so isolation is
structural, not best-effort: the engine force-stamps the tenant id onto
every ingested row (a spoofed attribute is overridden) and AND-s the
predicate into every search (a client filter can narrow, never escape).
On top of the slice, queries add a ``Range("ts", ...)`` freshness window
— the predicate evaluates *inside* the scan kernels, so recall within
the window is exact, with no post-filter widening.

Run: PYTHONPATH=src python examples/filtered_rag.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import sivf

rng = np.random.default_rng(0)
DIM, N_LISTS = 32, 8

train = rng.normal(size=(512, DIM)).astype(np.float32)
cents = sivf.train_kmeans(jax.random.key(1), jnp.asarray(train), N_LISTS)
cfg = sivf.SIVFConfig(dim=DIM, n_lists=N_LISTS, n_slabs=128, capacity=32,
                      n_max=8192, max_chain=16,
                      attributes=("tenant", "ts"))
index = sivf.Index(cfg, cents, deferred=True, min_bucket=8)

TENANTS = {"acme": 1, "globex": 2}
docs: dict[int, int] = {}            # doc id -> ingest timestamp

with sivf.ServeEngine(
        index, default_nprobe=N_LISTS,
        tenant_filters={t: sivf.Eq("tenant", v)
                        for t, v in TENANTS.items()}) as engine:
    # -- 1. two tenants stream documents in, stamped with a timestamp ------
    sessions = {t: engine.session(t) for t in TENANTS}
    doc_id = 0
    for ts in range(8):
        for tenant, sess in sessions.items():
            ids = np.arange(doc_id, doc_id + 16, dtype=np.int32)
            vecs = rng.normal(size=(16, DIM)).astype(np.float32)
            # note: no "tenant" column — the engine stamps the Eq-pinned
            # value itself; a spoofed value would be overridden the same way
            sess.add(vecs, ids, attrs={"ts": ts}).result()
            docs.update({int(i): ts for i in ids})
            doc_id += 16
    print(f"ingested {index.n_live} docs across {len(TENANTS)} tenants")

    # -- 2. tenant-sliced retrieval with a freshness window ----------------
    queries = rng.normal(size=(4, DIM)).astype(np.float32)
    window = sivf.Range("ts", 5, 8)          # only the 3 freshest steps
    for tenant, sess in sessions.items():
        res = sess.search(queries, k=8, filter=window).result()
        labels = np.asarray(res.labels)
        hits = labels[labels >= 0]
        # isolation guarantee: every hit is the tenant's own (ids were
        # interleaved per step, so parity of the 16-block identifies the
        # writer) AND inside the freshness window
        block_owner = (hits // 16) % len(TENANTS)
        want = list(TENANTS).index(tenant)
        assert (block_owner == want).all(), "cross-tenant leak!"
        assert all(5 <= docs[int(h)] < 8 for h in hits), "stale doc!"
        print(f"  {tenant}: {len(hits)} hits, all tenant-owned, "
              f"ts ∈ [5, 8) — isolation + freshness hold")

    # -- 3. the slice is inescapable ---------------------------------------
    other = sivf.Eq("tenant", TENANTS["globex"])
    escaped = sessions["acme"].search(queries, k=8, filter=other).result()
    assert (np.asarray(escaped.labels) == -1).all()
    print("  acme ∧ Eq(tenant=globex) returned nothing: slices cannot "
          "be escaped, only narrowed")

    compiles, bound = engine.assert_bounded_compiles()
    print(f"jit search executables: {compiles} <= bound {bound} "
          f"(filter constants never mint an executable)")

print("ok: multi-tenant filtered retrieval with exact in-scan predicates")
