"""Streaming RAG — the paper's motivating application (§1), end to end.

A document stream is embedded (mean-pooled LM hidden states), ingested
into a `sivf.Index` session under a sliding window, and queries retrieve
fresh context that conditions generation through the slab-paged serving
engine. Expired documents are evicted in O(1) — no index rebuilds, ever.
The retrieval loop only touches the `IndexProtocol` surface
(add/remove/search/stats), so any baseline engine drops in unchanged.

Run: PYTHONPATH=src python examples/streaming_rag.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import sivf
from repro.configs import ARCHS
from repro.models import model as M
from repro.serve.paged_lm import PagedLMEngine
from repro.sharding.axes import strip
from repro.sharding.rules import unpadded_plan

rng = np.random.default_rng(0)
cfg = ARCHS["llama3-8b"].reduced()
plan = unpadded_plan(cfg)
params = strip(M.init_params(cfg, plan, jax.random.key(0), max_seq=256))
D = cfg.d_model


def embed_doc(tokens: np.ndarray) -> np.ndarray:
    """Mean-pooled final hidden state as the document embedding."""
    batch = {"tokens": jnp.asarray(tokens[None], jnp.int32)}
    logits, _, _ = M.forward(params, cfg, plan, batch)
    # cheap proxy embedding: mean logits projected back is overkill; use
    # the embedding table lookup mean (consistent for queries and docs)
    emb = params["embed"]["table"][tokens]
    return np.asarray(jnp.mean(emb, axis=0), np.float32)


# -- 1. vector index session over the document stream ------------------------
N_LISTS = 8
train = rng.normal(size=(512, D)).astype(np.float32) * 0.02
cents = sivf.train_kmeans(jax.random.key(1), jnp.asarray(train), N_LISTS)
icfg = sivf.SIVFConfig(dim=D, n_lists=N_LISTS, n_slabs=64, capacity=32,
                       n_max=4096, max_chain=32)
index = sivf.Index(icfg, cents, strict=True, min_bucket=8)

docs: dict[int, np.ndarray] = {}
WINDOW = 24
doc_id = 0
print("streaming documents through the sliding window ...")
for step in range(6):
    batch_vecs, batch_ids = [], []
    for _ in range(8):
        toks = rng.integers(1, cfg.vocab_size, 12).astype(np.int32)
        docs[doc_id] = toks
        batch_vecs.append(embed_doc(toks))
        batch_ids.append(doc_id)
        doc_id += 1
    report = index.add(np.stack(batch_vecs), np.asarray(batch_ids, np.int32))
    expired = [i for i in list(docs) if i < doc_id - WINDOW]
    if expired:
        index.remove(np.asarray(expired, np.int32))
        for i in expired:
            docs.pop(i)
    print(f"  step {step}: live docs = {index.n_live} "
          f"(window {WINDOW}), admitted = {report.accepted}, "
          f"O(1) evictions = {len(expired)}")

# -- 2. retrieve-and-generate -------------------------------------------------
query_toks = rng.integers(1, cfg.vocab_size, 8).astype(np.int32)
q_emb = embed_doc(query_toks)[None]
_, labels = index.search(q_emb, k=2)          # nprobe=None: probe all lists
hits = [int(x) for x in np.asarray(labels)[0] if int(x) >= 0]
print("retrieved docs:", hits)
assert all(h in docs for h in hits), "retrieval returned an evicted doc!"

prompt = np.concatenate([docs[h] for h in hits] + [query_toks])
engine = PagedLMEngine(cfg, plan, params, page_size=16, n_pages=32,
                     max_seqs=1)
assert engine.admit(0, prompt)
out = [int(engine.last_tokens[0, 0])]
for _ in range(12):
    engine.step()
    out.append(int(engine.last_tokens[0, 0]))
print("generated continuation token ids:", out)
print("ok: retrieval-augmented generation over a streaming index")
