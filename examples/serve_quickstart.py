"""Serve quickstart: concurrent tenants through one ServeEngine.

Three client threads — two searchers and one ingest stream — hit a
single `sivf.Index` through `sivf.ServeEngine`: searches are coalesced
into shared kernel tiles, mutations ride the deferred pipeline with
atomic per-batch commits, and a tight tenant quota shows typed
backpressure instead of unbounded queueing. Telemetry is switched on
for the whole run and a snapshot digest prints at exit — see
docs/serving.md and docs/observability.md for the full contracts.

Run: PYTHONPATH=src python examples/serve_quickstart.py
"""
import threading

import jax
import numpy as np

import sivf

D, N_LISTS = 32, 16
rng = np.random.default_rng(7)
sivf.telemetry.enable()         # process-default Telemetry: record this run

# 1. deferred-mode handle + engine (one engine per handle)
train = rng.normal(size=(2048, D)).astype(np.float32)
cents = sivf.train_kmeans(jax.random.key(0), train, N_LISTS)
cfg = sivf.SIVFConfig(dim=D, n_lists=N_LISTS, n_slabs=512, capacity=64,
                      n_max=1 << 16)
index = sivf.Index(cfg, cents, deferred=True, min_bucket=16)
engine = sivf.ServeEngine(
    index, default_k=10, default_nprobe=8,
    quotas={"mobile": sivf.TenantQuota(max_inflight_searches=4)})

# 2. seed some data so searches have something to find
seed = engine.session("ingest")
seed.add(rng.normal(size=(4096, D)).astype(np.float32),
         np.arange(4096, dtype=np.int32)).result(timeout=120)


def searcher(tenant: str, n: int, out: list) -> None:
    sess = engine.session(tenant)
    done = shed = 0
    for i in range(n):
        q = rng.normal(size=(1 + i % 3, D)).astype(np.float32)
        try:
            r = sess.search(q).result(timeout=120)
        except sivf.Backpressure as e:     # typed: shed and move on
            shed += 1
            continue
        assert r.distances.shape == (q.shape[0], 10)
        done += 1
    out.append((tenant, done, shed))


def ingester(n_batches: int) -> None:
    sess = engine.session("ingest")
    futs = []
    for b in range(n_batches):
        ids = np.arange(4096 + b * 64, 4096 + (b + 1) * 64, dtype=np.int32)
        futs.append(sess.add(
            rng.normal(size=(64, D)).astype(np.float32), ids))
        futs.append(sess.remove(ids - 4096))     # sliding window
    assert all(f.result(timeout=120).ok for f in futs)


# 3. run all three tenants concurrently against the live index
stats: list = []
threads = [threading.Thread(target=searcher, args=("app", 40, stats)),
           threading.Thread(target=searcher, args=("mobile", 40, stats)),
           threading.Thread(target=ingester, args=(20,))]
for t in threads:
    t.start()
for t in threads:
    t.join()
engine.close()

observed, bound = engine.assert_bounded_compiles()
s = engine.stats()
for tenant, done, shed in sorted(stats):
    print(f"tenant {tenant}: {done} searches ok, {shed} shed")
print(f"epochs committed: {index.epoch}, n_live: {index.stats()['n_live']}")
print(f"coalesce mean {s['coalesce_mean']}, search executables "
      f"{observed} (bound {bound})")
assert index.stats()["n_live"] == 4096          # window slid cleanly

# 4. telemetry snapshot at exit: what the engine saw, per tenant + stage
snap = engine.telemetry()
print("-- telemetry snapshot --")
for series in snap["metrics"]["sivf_serve_requests_total"]["series"]:
    lab = series["labels"]
    print(f"requests tenant={lab['tenant']} op={lab['op']}: "
          f"{int(series['total'])}")
for series in snap["metrics"]["sivf_stage_seconds"]["series"]:
    print(f"stage {series['labels']['stage']}: n={series['count']} "
          f"p99~{series['p99_est'] * 1e3:.2f}ms")
print(f"jit compile events: {index.compile_events()}, "
      f"slow queries logged: {len(snap['slow_queries'])}")
print("serve quickstart OK")
