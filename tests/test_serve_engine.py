"""Streaming serve engine (ISSUE 6): coalescing, quotas, epoch consistency.

Covers the four serve edge cases named in the issue:

  * tenant quota exhaustion returns a *typed* rejection at submit time —
    the queue does not grow;
  * searches issued mid-ingest always observe a committed prefix of the
    mutation stream (oracle check against the stamped ``epoch``);
  * ``close()`` / drain flushes the deferred queue and resolves every
    future;
  * a threaded multi-client churn keeps jit executable counts bounded by
    the pow2-bucket x (k, nprobe) coalescing bound.

Each test builds a *fresh* ``SIVFConfig`` (distinct ``n_slabs``) so the
lru-cached backend op sets — and therefore the measured compile counts —
are isolated per test.
"""
import threading
import time

import jax
import numpy as np
import pytest

import sivf
from sivf import Backpressure, BackpressureKind, ServeEngine, TenantQuota

DIM = 16
_SLAB_SALT = iter(range(100))


def _engine(rng, *, n_lists=8, n_max=8192, min_bucket=16, telemetry=None,
            **eng_kw):
    cfg = sivf.SIVFConfig(dim=DIM, n_lists=n_lists,
                          n_slabs=256 + next(_SLAB_SALT), capacity=32,
                          n_max=n_max)
    cents = sivf.train_kmeans(
        jax.random.key(0),
        rng.normal(size=(512, DIM)).astype(np.float32), n_lists)
    idx = sivf.Index(cfg, cents, deferred=True, min_bucket=min_bucket,
                     telemetry=telemetry)
    return idx, ServeEngine(idx, **eng_kw)


def _vec_for(i: int) -> np.ndarray:
    """Deterministic per-id vector (distinct ids are well separated)."""
    return np.random.default_rng(1000 + i).normal(
        size=(DIM,)).astype(np.float32)


def _vecs_for(ids) -> np.ndarray:
    return np.stack([_vec_for(int(i)) for i in ids])


# ---------------------------------------------------------------------------
# construction contract
# ---------------------------------------------------------------------------

def test_engine_requires_deferred_nonstrict_index(rng):
    cfg = sivf.SIVFConfig(dim=DIM, n_lists=4, n_slabs=64, capacity=32,
                          n_max=1024)
    cents = rng.normal(size=(4, DIM)).astype(np.float32)
    with pytest.raises(ValueError, match="deferred=True"):
        ServeEngine(sivf.Index(cfg, cents))
    with pytest.raises(ValueError, match="strict=False"):
        ServeEngine(sivf.Index(cfg, cents, deferred=True, strict=True))
    with pytest.raises(TypeError, match="sivf.Index"):
        ServeEngine("not an index")


# ---------------------------------------------------------------------------
# basic round trip + coalescing
# ---------------------------------------------------------------------------

def test_roundtrip_and_coalesced_tiles(rng):
    idx, eng = _engine(rng, default_k=5)
    with eng:
        writer, reader = eng.session("ingest"), eng.session("app")
        ids = np.arange(64, dtype=np.int32)
        writer.add(_vecs_for(ids), ids).result(30)
        eng.pause()                      # queue searches so they coalesce
        futs = [reader.search(_vec_for(j)[None]) for j in range(8)]
        futs += [reader.search(_vec_for(j)[None], k=3, nprobe=2)
                 for j in range(4)]
        eng.resume()
        res = [f.result(30) for f in futs]
        # self-hit at distance ~0 for every query, both (k, nprobe) groups
        for j, r in enumerate(res[:8]):
            assert r.labels[0, 0] == j and r.distances[0, 0] < 1e-5
            assert r.k == 5
        # the 8 default-(k, nprobe) searches shared tiles; grouping is by
        # (k, nprobe) so the k=3 group cannot ride the k=5 tile
        assert all(r.coalesced >= 2 for r in res)
        assert {(r.k, r.nprobe) for r in res} == {(5, 8), (3, 2)}
        obs, bound = eng.assert_bounded_compiles()
        assert obs <= bound
        st = eng.stats()
        assert st["searches"] == 12 and st["search_tiles"] >= 2
    assert idx.pending_count == 0


def test_submit_validation_is_synchronous(rng):
    _, eng = _engine(rng)
    with eng:
        s = eng.session()
        with pytest.raises(ValueError, match="dim"):
            s.search(np.zeros((2, DIM + 1), np.float32))
        with pytest.raises(ValueError, match="dim"):
            s.add(np.zeros((2, DIM + 1), np.float32),
                  np.arange(2, dtype=np.int32))
        with pytest.raises(ValueError, match="mismatch"):
            s.add(np.zeros((2, DIM), np.float32),
                  np.arange(3, dtype=np.int32))


def test_mutation_errors_surface_on_result_not_raise(rng):
    """Non-strict contract: an ID_RANGE batch resolves with ok=False."""
    idx, eng = _engine(rng)
    with eng:
        s = eng.session()
        bad = np.asarray([1, idx.cfg.n_max + 7], np.int32)
        r = s.add(_vecs_for([1, 2]), bad).result(30)
        assert not r.ok
        assert r.report.errors & sivf.ErrorCode.ID_RANGE
        assert r.report.accepted == 1 and r.report.rejected == 1


# ---------------------------------------------------------------------------
# quotas: typed backpressure, no queue growth
# ---------------------------------------------------------------------------

def test_search_inflight_quota_typed_rejection(rng):
    idx, eng = _engine(
        rng, quotas={"capped": TenantQuota(max_inflight_searches=2)})
    with eng:
        s = eng.session("capped")
        eng.pause()                          # stall dispatch deterministically
        q = _vec_for(0)[None]
        f1, f2 = s.search(q), s.search(q)
        with pytest.raises(Backpressure) as ei:
            s.search(q)
        assert ei.value.kind is BackpressureKind.SEARCH_INFLIGHT
        assert ei.value.tenant == "capped"
        assert eng.stats()["queued"] == 2    # rejected submit never queued
        # other tenants are unaffected
        f3 = eng.session("other").search(q)
        eng.resume()
        for f in (f1, f2, f3):
            f.result(30)
        # resolution released the slots: the tenant can submit again
        s.search(q).result(30)
        rej = eng.stats()["rejections"]["capped"]
        assert rej == {"search_inflight": 1}


def test_queue_full_typed_rejection(rng):
    idx, eng = _engine(rng, max_queue=3)
    with eng:
        s = eng.session()
        eng.pause()
        ids = np.arange(4, dtype=np.int32)
        futs = [s.add(_vecs_for(ids + 4 * i), ids + 4 * i) for i in range(3)]
        with pytest.raises(Backpressure) as ei:
            s.remove(ids)
        assert ei.value.kind is BackpressureKind.QUEUE_FULL
        assert eng.stats()["queued"] == 3    # bounded, not growing
        eng.resume()
        assert all(f.result(30).ok for f in futs)


def test_mutation_rate_token_bucket(rng):
    now = [0.0]
    idx, eng = _engine(
        rng, clock=lambda: now[0],
        quotas={"bulk": TenantQuota(mutation_rows_per_s=100,
                                    mutation_burst_rows=50)})
    with eng:
        s = eng.session("bulk")
        ids = np.arange(50, dtype=np.int32)
        f = s.add(_vecs_for(ids), ids)       # drains the burst exactly
        with pytest.raises(Backpressure) as ei:
            s.remove(np.arange(1, dtype=np.int32))
        assert ei.value.kind is BackpressureKind.MUTATION_RATE
        now[0] += 0.5                        # refill 50 tokens
        f2 = s.remove(np.arange(40, dtype=np.int32))
        assert f.result(30).ok and f2.result(30).ok


def test_submit_after_close_rejected(rng):
    idx, eng = _engine(rng)
    eng.close()
    with pytest.raises(Backpressure) as ei:
        eng.session().search(_vec_for(0)[None])
    assert ei.value.kind is BackpressureKind.ENGINE_CLOSED


# ---------------------------------------------------------------------------
# epoch consistency: searches mid-ingest see a committed prefix
# ---------------------------------------------------------------------------

def test_search_mid_ingest_observes_committed_prefix(rng):
    """Oracle: batch b covers ids [b*B, (b+1)*B). A search stamped with
    epoch e must (a) never return an id from a batch > e, and (b) find
    the planted id at distance ~0 whenever its batch <= e. Atomic batch
    commits (PR 3) + single-thread dispatch make the prefix exact."""
    B, n_batches = 32, 12
    idx, eng = _engine(rng, default_k=4, flush_every=3)
    with eng:
        writer, reader = eng.session("ingest"), eng.session("app")
        results = []
        stop = threading.Event()

        def searcher():
            while not stop.is_set():
                target = int(rng.integers(0, B * n_batches))
                try:
                    fut = reader.search(_vec_for(target)[None], nprobe=None)
                except Backpressure:          # shed load, retry later
                    time.sleep(0.005)
                    continue
                results.append((target, fut))
                time.sleep(0.001)

        t = threading.Thread(target=searcher)
        t.start()
        mut_futs = []
        for b in range(n_batches):
            ids = np.arange(b * B, (b + 1) * B, dtype=np.int32)
            mut_futs.append(writer.add(_vecs_for(ids), ids))
            time.sleep(0.002)
        reps = [f.result(60) for f in mut_futs]
        stop.set()
        t.join()
        assert all(r.ok for r in reps)
        # batch b resolves at epoch b+1: epochs are the dispatch order
        assert [r.epoch for r in reps] == list(range(1, n_batches + 1))

        checked_absent = checked_present = 0
        for target, fut in results:
            r = fut.result(60)
            batch_of_target = target // B + 1          # 1-based epoch
            present = (r.distances[0, 0] < 1e-5
                       and r.labels[0, 0] == target)
            if batch_of_target <= r.epoch:
                # nprobe=None probes every list: a committed id is found
                assert present, (target, r.epoch, r.labels[0])
                checked_present += 1
            else:
                assert not present, (target, r.epoch, r.labels[0])
                checked_absent += 1
            # (a) no id from an uncommitted batch ever appears
            live = r.labels[0][r.labels[0] >= 0]
            assert (live < r.epoch * B).all(), (r.epoch, live)
        assert checked_present > 0           # the oracle saw both sides
    assert idx.n_live == B * n_batches


# ---------------------------------------------------------------------------
# drain / shutdown
# ---------------------------------------------------------------------------

def test_close_drains_deferred_queue(rng):
    idx, eng = _engine(rng, flush_every=10_000)   # never flush on depth
    s = eng.session()
    ids = np.arange(200, dtype=np.int32)
    futs = [s.add(_vecs_for(ids[i:i + 50]), ids[i:i + 50])
            for i in range(0, 200, 50)]
    futs.append(s.remove(ids[:10]))
    eng.close()                                   # drain=True default
    assert all(f.done for f in futs)
    reps = [f.result(0) for f in futs]
    assert all(r.ok for r in reps)
    assert idx.pending_count == 0
    assert idx.n_live == 190
    eng.close()                                   # idempotent


def test_close_without_drain_rejects_queued_requests(rng):
    idx, eng = _engine(rng)
    s = eng.session()
    eng.pause()
    ids = np.arange(8, dtype=np.int32)
    f = s.add(_vecs_for(ids), ids)
    eng.close(drain=False)
    with pytest.raises(Backpressure) as ei:
        f.result(5)
    assert ei.value.kind is BackpressureKind.ENGINE_CLOSED
    assert idx.pending_count == 0


def test_context_exit_flushes(rng):
    idx, eng = _engine(rng)
    with eng:
        ids = np.arange(32, dtype=np.int32)
        fut = eng.session().add(_vecs_for(ids), ids)
    assert fut.result(0).ok and idx.pending_count == 0


# ---------------------------------------------------------------------------
# threaded multi-client churn: bounded executables
# ---------------------------------------------------------------------------

def test_threaded_churn_bounded_executables(rng):
    idx, eng = _engine(rng, default_k=8, min_bucket=8, flush_every=4)
    n_per_client = 30
    errs: list = []
    with eng:
        def searcher(tenant, seed):
            r = np.random.default_rng(seed)
            sess = eng.session(tenant)
            for _ in range(n_per_client):
                q = r.normal(size=(int(r.integers(1, 9)), DIM)
                             ).astype(np.float32)
                try:
                    res = sess.search(q).result(60)
                    assert res.labels.shape == (q.shape[0], 8)
                except Exception as e:          # surfaced on the main thread
                    errs.append(e)

        def mutator(tenant, seed, base):
            r = np.random.default_rng(seed)
            sess = eng.session(tenant)
            nxt = base
            for i in range(n_per_client):
                n = int(r.integers(1, 33))
                ids = np.arange(nxt, nxt + n, dtype=np.int32)
                nxt += n
                try:
                    rep = sess.add(_vecs_for(ids), ids).result(60)
                    assert rep.ok, rep
                    if i % 3 == 2:
                        assert sess.remove(ids[: n // 2]).result(60).ok
                except Exception as e:
                    errs.append(e)

        threads = [
            threading.Thread(target=searcher, args=("app-a", 1)),
            threading.Thread(target=searcher, args=("app-b", 2)),
            threading.Thread(target=mutator, args=("ingest-a", 3, 0)),
            threading.Thread(target=mutator, args=("ingest-b", 4, 4000)),
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs, errs[:3]
        obs, bound = eng.assert_bounded_compiles()
        st = eng.stats()
        assert st["searches"] == 2 * n_per_client
        assert st["queued"] == 0
        assert all(v == 0 for v in st["inflight_searches"].values())
        # mutation executables ride the PR 2 bucket bound too
        comp = idx.compile_stats()
        mut_bound = len(idx.bucket_shapes(32))
        assert comp["add"] <= mut_bound and comp["remove"] <= mut_bound
    assert idx.pending_count == 0


# ---------------------------------------------------------------------------
# provenance under coalescing (ISSUE 9)
# ---------------------------------------------------------------------------

def test_tile_provenance_consistent_under_coalescing(rng):
    """Every member of one coalesced tile reports the same tile-level
    provenance: coalesced count, padded shape, epoch, and the SAME
    service window (timing is stamped once per tile, not per request)."""
    idx, eng = _engine(rng, default_k=5, max_coalesce=128)
    with eng:
        writer, reader = eng.session("ingest"), eng.session("app")
        ids = np.arange(64, dtype=np.int32)
        writer.add(_vecs_for(ids), ids).result(30)
        eng.pause()                      # queue all six into one tile
        futs = [reader.search(_vec_for(j)[None]) for j in range(6)]
        eng.resume()
        res = [f.result(30) for f in futs]
    assert {r.coalesced for r in res} == {6}
    assert len({r.padded_to for r in res}) == 1
    pad = res[0].padded_to
    assert pad >= 6 and pad & (pad - 1) == 0          # pow2 tile shape
    assert len({r.epoch for r in res}) == 1
    # shared service window: identical floats, not merely close
    assert len({r.service_s for r in res}) == 1
    for r in res:
        assert r.service_s > 0.0 and r.queue_s >= 0.0


def test_queue_wait_monotone_under_pause(rng):
    """queue_s is the request's real wait: submissions staggered while
    the engine is paused dispatch in one tile, so the earliest submit
    must report the longest wait, strictly ordered."""
    idx, eng = _engine(rng, default_k=5, max_coalesce=128)
    with eng:
        writer, reader = eng.session("ingest"), eng.session("app")
        ids = np.arange(32, dtype=np.int32)
        writer.add(_vecs_for(ids), ids).result(30)
        reader.search(_vec_for(0)[None]).result(30)   # warm the tile shape
        eng.pause()
        futs = []
        for j in range(4):
            futs.append(reader.search(_vec_for(j)[None]))
            time.sleep(0.02)
        eng.resume()
        res = [f.result(30) for f in futs]
    qs = [r.queue_s for r in res]
    assert all(a > b for a, b in zip(qs, qs[1:]))     # earlier waited longer
    assert qs[0] >= 3 * 0.02                          # held across the gaps


def test_tile_spans_agree_with_provenance(rng):
    """The serve.tile root span and the result provenance describe the
    same service window (different clocks: compared with tolerance), and
    per-request queue waits land in the serve.queue stage histogram."""
    from repro.obs import Telemetry
    tel = Telemetry(enabled=True, slow_threshold_s=0.0)
    idx, eng = _engine(rng, default_k=5, max_coalesce=128, telemetry=tel)
    with eng:
        writer, reader = eng.session("ingest"), eng.session("app")
        ids = np.arange(32, dtype=np.int32)
        writer.add(_vecs_for(ids), ids).result(30)
        eng.pause()
        futs = [reader.search(_vec_for(j)[None]) for j in range(4)]
        eng.resume()
        res = [f.result(30) for f in futs]
    tile = [e for e in tel.slow_queries()
            if e["span"] == "serve.tile" and e.get("rows") == 4][0]
    svc_ms = res[0].service_s * 1e3
    # span opens just before the tile's t0 stamp and finishes just after
    # its t1 stamp: never meaningfully shorter, close from above
    assert tile["duration_ms"] >= svc_ms - 1.0
    assert tile["duration_ms"] <= svc_ms + 250.0      # CI-noise tolerance
    assert tile["tenant"] == "app" and tile["epoch"] == res[0].epoch
    assert "index.search" in tile["stages_ms"]
    q = tel.histogram("sivf_stage_seconds", labels=("stage",))
    assert q.get(stage="serve.queue")["count"] == 4
    coal = tel.histogram("sivf_serve_coalesce_rows")
    assert coal.get()["count"] >= 1                   # the 4-row tile
