"""Tiered slab pool (ISSUE 8): host cold store + device hot cache.

The contract under test: an index whose payload planes live host-side
(``SIVFConfig(device_slabs=...)``) serves searches **bit-identical** —
ids AND distances, ``==`` not allclose — to the all-resident pool, at
every cache size that fits the probed set, across the raw / PQ / filtered
scan paths on both backends, including under insert/delete churn; warm
caches search with zero host->device transfers; and the probe-driven
prefetch dedupes slab ids shared by probed lists.
"""
import dataclasses
import json
import subprocess
import sys
import unittest.mock as mock

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import parity
from repro.core import filters as flt
from repro.core.api import Index
from repro.core.pq import PQConfig
from repro.core.state import SIVFConfig

D, NL = 16, 8


def make_cfg(device_slabs=None, **kw):
    base = dict(dim=D, n_lists=NL, n_slabs=64, capacity=32, n_max=4096)
    base.update(kw)
    return SIVFConfig(device_slabs=device_slabs, **base)


_assert_same = parity.assert_results_same


def _pair(rng, device_slabs, n=600, backend="single", **kw):
    """(tiered, all-resident) twin handles over the same data."""
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    it = Index(make_cfg(device_slabs, **kw), cents, backend=backend)
    if_ = Index(make_cfg(None, **kw), cents, backend=backend)
    vecs = rng.normal(size=(n, D)).astype(np.float32)
    ids = np.arange(n, dtype=np.int32)
    return it, if_, vecs, ids


def _churn(rng, it, if_, vecs, ids, attrs=None):
    """The shared twin mutation schedule (tests/parity.py): bulk add,
    overwrite, delete, refill — the refill recycles reclaimed slabs, so
    dirty-frame coherence on tiered pools is exercised."""
    fn = None if attrs is None else \
        (lambda n: {"tenant": np.arange(n) % 3})
    return parity.twin_churn(rng, (it, if_), vecs, ids, attrs=attrs,
                             attrs_fn=fn)


@pytest.mark.parametrize("device_slabs", [28, 40, 64])
def test_parity_raw_under_churn(rng, device_slabs):
    """Bit-identical results at several cache sizes, through overwrite,
    delete, and slab-recycling churn."""
    it, if_, vecs, ids = _pair(rng, device_slabs)
    _churn(rng, it, if_, vecs, ids)
    qs = rng.normal(size=(5, D)).astype(np.float32)
    for nprobe in (2, 4, NL):
        _assert_same(it.search(qs, k=10, nprobe=nprobe),
                     if_.search(qs, k=10, nprobe=nprobe))
    # repeat on a warm cache: residency must not change results
    _assert_same(it.search(qs, k=10, nprobe=NL),
                 if_.search(qs, k=10, nprobe=NL))


def test_parity_pq(rng):
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    vecs = rng.normal(size=(600, D)).astype(np.float32)
    ids = np.arange(600, dtype=np.int32)
    pq = PQConfig(m=4, nbits=4)
    it = Index(make_cfg(32, pq=pq), cents).train(vecs)
    if_ = Index(make_cfg(None, pq=pq), cents).train(vecs)
    _churn(rng, it, if_, vecs, ids)
    qs = rng.normal(size=(5, D)).astype(np.float32)
    _assert_same(it.search(qs, k=10, nprobe=4),
                 if_.search(qs, k=10, nprobe=4))


def test_parity_filtered(rng):
    it, if_, vecs, ids = _pair(rng, 40, attributes=("tenant",))
    _churn(rng, it, if_, vecs, ids, attrs={"tenant": ids % 3})
    qs = rng.normal(size=(5, D)).astype(np.float32)
    for pred in (flt.Eq("tenant", 1), flt.In("tenant", (0, 2))):
        _assert_same(it.search(qs, k=10, nprobe=NL, filter=pred),
                     if_.search(qs, k=10, nprobe=NL, filter=pred))


def test_parity_mesh(rng):
    mesh = jax.make_mesh((1,), ("data",))
    it, if_, vecs, ids = _pair(rng, 40, backend=mesh)
    _churn(rng, it, if_, vecs, ids)
    qs = rng.normal(size=(5, D)).astype(np.float32)
    for nprobe in (4, NL):
        _assert_same(it.search(qs, k=10, nprobe=nprobe),
                     if_.search(qs, k=10, nprobe=nprobe))
    st = it.stats()
    assert st["tiered"] and st["per_shard_resident"][0] > 0


def test_parity_rejected_rows(rng):
    """Rows the device commit rejects (out-of-range ids) must not leak
    into the host store either — the plan carries -1 for them."""
    it, if_, vecs, ids = _pair(rng, 40)
    bad = ids.copy()
    bad[::7] = 100_000                     # outside [0, n_max)
    for idx in (it, if_):
        r = idx.add(vecs, bad)
        assert r.rejected > 0
    qs = rng.normal(size=(4, D)).astype(np.float32)
    _assert_same(it.search(qs, k=10, nprobe=NL),
                 if_.search(qs, k=10, nprobe=NL))


def test_cache_too_small_raises(rng):
    it, _, vecs, ids = _pair(rng, 4)
    it.add(vecs, ids)
    qs = rng.normal(size=(8, D)).astype(np.float32)
    with pytest.raises(ValueError, match="device_slabs"):
        it.search(qs, k=5, nprobe=NL)


def test_device_slabs_validation():
    with pytest.raises(ValueError, match="device_slabs"):
        make_cfg(0)
    with pytest.raises(ValueError, match="device_slabs"):
        make_cfg(65)                       # > n_slabs


# ---------------------------------------------------------------------------
# Satellite: probe-set dedupe
# ---------------------------------------------------------------------------

def test_prefetch_dedupes_shared_slabs(rng):
    """Slab ids shared by several probed lists (and by the queries of one
    tile) are fetched once: uploads == unique ids, never raw references."""
    it, _, vecs, ids = _pair(rng, 64)
    it.add(vecs, ids)
    qs = rng.normal(size=(16, D)).astype(np.float32)
    it.search(qs, k=5, nprobe=NL)          # every query probes every list
    rt = it._tiered
    last = rt.last_prefetch
    assert last["refs"] > last["unique"]          # sharing actually occurred
    assert last["uploaded"] == last["unique"]     # cold cache: one per slab
    assert last["dedup_saved"] == last["refs"] - last["unique"]
    st = it.stats()
    assert st["dedup_saved_fetches"] == st["dedup_refs"] - \
        st["dedup_unique_refs"] > 0
    # warm repeat: same refs, zero uploads
    it.search(qs, k=5, nprobe=NL)
    assert rt.last_prefetch["uploaded"] == 0
    assert rt.last_prefetch["hits"] == last["unique"]


# ---------------------------------------------------------------------------
# Satellite: stats / memory_report split
# ---------------------------------------------------------------------------

def test_memory_report_split():
    from repro.core.state import memory_report
    ct, cf = make_cfg(16), make_cfg(None)
    mt, mf = memory_report(ct), memory_report(cf)
    assert mf["host_bytes"] == 0
    assert mf["device_cache_bytes"] == 0
    assert mf["device_bytes"] == mf["total_bytes"]
    # tiered: payloads live host-side, cache frames on device
    payload_all = mt["payload_bytes"] + mt["code_bytes"] + mt["attr_bytes"]
    assert mt["host_bytes"] == payload_all
    assert mt["device_cache_bytes"] == payload_all * 16 // ct.n_slabs
    assert mt["total_bytes"] == mt["host_bytes"] + mt["device_bytes"]
    assert mt["device_bytes"] == mt["metadata_bytes"] \
        + mt["device_cache_bytes"]


def test_stats_split_sharded(rng):
    mesh = jax.make_mesh((1,), ("data",))
    it, _, vecs, ids = _pair(rng, 40, backend=mesh)
    it.add(vecs, ids)
    it.search(rng.normal(size=(4, D)).astype(np.float32), k=5, nprobe=4)
    st = it.stats()
    for key in ("host_bytes", "device_bytes", "device_cache_bytes",
                "resident_slabs", "hit_rate", "per_shard_resident"):
        assert key in st
    assert st["resident_slabs"] == sum(st["per_shard_resident"])
    assert 0.0 <= st["hit_rate"] <= 1.0
    # untiered twin reports the all-resident view
    su = Index(make_cfg(None), rng.normal(size=(NL, D)).astype(np.float32)
               ).stats()
    assert su["tiered"] is False and su["hit_rate"] == 1.0
    assert su["host_bytes"] == 0
    assert su["resident_slabs"] == su["slabs_used"]


# ---------------------------------------------------------------------------
# Satellite: zero-copy steady state
# ---------------------------------------------------------------------------

def test_zero_copy_warm_search(rng):
    """Warm-cache repeated search does no host->device transfers at all
    (asserted under ``transfer_guard("disallow")`` with counted
    ``device_put`` calls); cache misses are the only transfer sites —
    one packed ``device_put`` per miss batch."""
    it, _, vecs, ids = _pair(rng, 64)
    it.add(vecs, ids)
    qs = jnp.asarray(rng.normal(size=(64, D)).astype(np.float32))
    puts, gets = [], []
    orig_put, orig_get = jax.device_put, jax.device_get
    with mock.patch.object(
            jax, "device_put",
            side_effect=lambda *a, **k: (puts.append(1),
                                         orig_put(*a, **k))[1]), \
         mock.patch.object(
            jax, "device_get",
            side_effect=lambda *a, **k: (gets.append(1),
                                         orig_get(*a, **k))[1]):
        cold = it.search(qs, k=10, nprobe=NL)
        # cold: one device_get drains the queued insert plan, one fetches
        # the slab table; ONE packed device_put uploads every missed slab
        assert len(puts) == 1 and len(gets) == 2
        puts.clear(), gets.clear()
        with jax.transfer_guard("disallow"):
            for _ in range(3):
                warm = it.search(qs, k=10, nprobe=NL)
        # warm: the explicit table device_get is the only transfer; the
        # cache, residency map, and payload planes are never touched
        assert len(puts) == 0
        assert len(gets) == 3
        _assert_same(cold, warm)
        # a new insert dirties its slabs -> next search re-uploads (the
        # miss/dirty path is the only transfer site)
        it.add(jnp.asarray(rng.normal(size=(64, D)).astype(np.float32)),
               jnp.arange(3000, 3064, dtype=jnp.int32))
        puts.clear(), gets.clear()
        it.search(qs, k=10, nprobe=NL)
        assert len(puts) == 1              # one packed refresh upload


# ---------------------------------------------------------------------------
# Prefetch tickets (serve-engine pipelining hook)
# ---------------------------------------------------------------------------

def test_prefetch_ticket_skips_stages(rng):
    it, _, vecs, ids = _pair(rng, 64)
    it.add(vecs, ids)
    qs = rng.normal(size=(6, D)).astype(np.float32)
    t = it.prefetch(qs, nprobe=4)
    assert t is not None and t.seq == it._tiered.seq
    seq_before = it._tiered.seq
    res = it.search(qs, k=10, nprobe=4, _prefetched=t)
    # the ticketed search ran scan-only: no new prefetch happened
    assert it._tiered.seq == seq_before
    _assert_same(res, it.search(qs, k=10, nprobe=4))
    # a mutation invalidates the ticket (epoch moved): search falls back
    t2 = it.prefetch(qs, nprobe=4)
    it.add(vecs[:8], np.arange(4000, 4008, dtype=np.int32))
    res2 = it.search(qs, k=10, nprobe=4, _prefetched=t2)
    assert it._tiered.seq == t2.seq + 1    # full path re-prefetched
    assert res2 is not None
    # untiered handles return None and ignore tickets
    if2 = Index(make_cfg(None), rng.normal(size=(NL, D)).astype(np.float32))
    assert if2.prefetch(qs) is None


# ---------------------------------------------------------------------------
# Persistence + elastic reshard (format stays 3; residency is runtime-only)
# ---------------------------------------------------------------------------

def test_save_load_roundtrips(rng, tmp_path):
    it, if_, vecs, ids = _pair(rng, 32)
    _churn(rng, it, if_, vecs, ids)
    qs = rng.normal(size=(5, D)).astype(np.float32)
    ref = if_.search(qs, k=10, nprobe=NL)
    it.save(tmp_path / "t")
    meta = json.loads((tmp_path / "t" / "index.json").read_text())
    assert meta["format"] == 3             # tiered saves keep the format
    # tiered -> tiered
    _assert_same(Index.load(tmp_path / "t").search(qs, k=10, nprobe=NL), ref)
    # tiered -> all-resident (retier on load)
    j = Index.load(tmp_path / "t", device_slabs=None)
    assert j._tiered is None
    _assert_same(j.search(qs, k=10, nprobe=NL), ref)
    # all-resident checkpoint -> tiered
    if_.save(tmp_path / "f")
    k = Index.load(tmp_path / "f", device_slabs=28)
    assert k._tiered is not None
    _assert_same(k.search(qs, k=10, nprobe=NL), ref)
    # tiered -> 1-shard mesh (elastic + tiered at once)
    mesh = jax.make_mesh((1,), ("data",))
    m = Index.load(tmp_path / "t", backend=mesh)
    assert m.backend == "mesh" and m._tiered is not None
    _assert_same(m.search(qs, k=10, nprobe=NL), ref)


def test_reshard_live_tiered(rng):
    it, if_, vecs, ids = _pair(rng, 32)
    _churn(rng, it, if_, vecs, ids)
    qs = rng.normal(size=(5, D)).astype(np.float32)
    ref = if_.search(qs, k=10, nprobe=NL)
    mesh = jax.make_mesh((1,), ("data",))
    it.reshard(mesh)
    assert it.backend == "mesh" and it._tiered is not None
    _assert_same(it.search(qs, k=10, nprobe=NL), ref)
    it.reshard("single")
    _assert_same(it.search(qs, k=10, nprobe=NL), ref)
    # the handle still mutates after two reshard round trips
    before = it.n_live
    it.add(rng.normal(size=(16, D)).astype(np.float32),
           np.arange(3500, 3516, dtype=np.int32))
    assert it.n_live == before + 16


# ---------------------------------------------------------------------------
# Serve engine: tiled prefetch pipelining
# ---------------------------------------------------------------------------

def test_serve_engine_tiered(rng):
    from repro.serve.sivf_engine import ServeEngine
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    it = Index(make_cfg(48), cents, deferred=True)
    if_ = Index(make_cfg(None), cents)
    vecs = rng.normal(size=(600, D)).astype(np.float32)
    ids = np.arange(600, dtype=np.int32)
    if_.add(vecs, ids)
    qs = rng.normal(size=(9, D)).astype(np.float32)
    with ServeEngine(it, max_coalesce=3) as eng:
        s = eng.session("t")
        s.add(vecs, ids).result()
        futs = [s.search(qs[i:i + 3], k=5, nprobe=4) for i in (0, 3, 6)]
        results = [f.result() for f in futs]
        eng.assert_bounded_compiles()
    for i, r in enumerate(results):
        ref = if_.search(qs[3 * i:3 * i + 3], k=5, nprobe=4)
        assert np.array_equal(np.asarray(r.labels), np.asarray(ref.labels))
        assert np.array_equal(np.asarray(r.distances),
                              np.asarray(ref.distances))
    assert it.stats()["cache_uploads"] > 0


# ---------------------------------------------------------------------------
# Multi-shard mesh (subprocess: fake device count must precede jax init)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np, jax, jax.numpy as jnp
from repro.core.api import Index
from repro.core.state import SIVFConfig

rng = np.random.default_rng(7)
D, NL = 16, 8
def cfg(ds):
    return SIVFConfig(dim=D, n_lists=NL, n_slabs=64, capacity=32,
                      n_max=4096, device_slabs=ds)
cents = rng.normal(size=(NL, D)).astype(np.float32)
mesh = jax.make_mesh((4,), ("data",))
it = Index(cfg(24), cents, backend=mesh)
if_ = Index(cfg(None), cents, backend=mesh)
vecs = rng.normal(size=(600, D)).astype(np.float32)
ids = np.arange(600, dtype=np.int32)
for idx in (it, if_):
    idx.add(vecs, ids)
    idx.remove(ids[100:250])
    idx.add(rng.normal(size=(80, D)).astype(np.float32) * 0 + vecs[:80],
            np.arange(2000, 2080, dtype=np.int32))
qs = rng.normal(size=(5, D)).astype(np.float32)
ok = True
for nprobe in (4, NL):
    a = it.search(qs, k=10, nprobe=nprobe)
    b = if_.search(qs, k=10, nprobe=nprobe)
    ok &= np.array_equal(np.asarray(a.labels), np.asarray(b.labels))
    ok &= np.array_equal(np.asarray(a.distances), np.asarray(b.distances))
st = it.stats()
print(json.dumps({"ok": bool(ok), "resident": st["resident_slabs"],
                  "per_shard": st["per_shard_resident"],
                  "hit_rate": st["hit_rate"]}))
"""


def test_tiered_four_shard_parity():
    r = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT], capture_output=True,
        text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"]
    assert len(out["per_shard"]) == 4
    assert out["resident"] == sum(out["per_shard"]) > 0


# ---------------------------------------------------------------------------
# hit-rate accounting: windowed vs cumulative, counters survive reshard
# (ISSUE 9 regression: stats() used to report only the cumulative rate
# unlabeled, and reshard rebuilt the runtime with zeroed counters)
# ---------------------------------------------------------------------------

def test_hit_rate_windowed_and_cumulative(rng):
    it, _, vecs, ids = _pair(rng, 32)
    it.add(vecs, ids)
    qs = rng.normal(size=(5, D)).astype(np.float32)
    it.search(qs, k=10, nprobe=NL)            # cold: misses + uploads
    st = it.stats()
    assert st["hit_rate_kind"] == "cumulative"
    assert 0.0 <= st["hit_rate"] < 1.0        # cold fill missed
    assert st["hit_rate_window"] == st["hit_rate"]   # no roll yet
    assert st["cache_hits_window"] == st["cache_hits"]
    it._tiered.roll_window()                  # new observation window
    st = it.stats()
    assert st["cache_misses_window"] == 0     # window reset...
    assert st["cache_misses"] > 0             # ...cumulative untouched
    it.search(qs, k=10, nprobe=NL)            # warm: same probe set
    st = it.stats()
    assert st["hit_rate_window"] == 1.0       # all-hit window
    assert st["hit_rate"] < 1.0               # lifetime still shows the fill


def test_hit_rate_counters_carry_across_reshard(rng):
    it, _, vecs, ids = _pair(rng, 32)
    it.add(vecs, ids)
    qs = rng.normal(size=(5, D)).astype(np.float32)
    it.search(qs, k=10, nprobe=NL)
    before = {k: it.stats()[k]
              for k in ("cache_hits", "cache_misses", "cache_uploads")}
    assert before["cache_uploads"] > 0
    it.reshard(jax.make_mesh((1,), ("data",)))
    after = it.stats()
    for k, v in before.items():               # cumulative story unbroken
        assert after[k] >= v, (k, v, after[k])
    assert after["hit_rate_kind"] == "cumulative"
