import os

# This suite is CPU-targeted (Pallas kernels run in interpret mode). On
# hosts that have libtpu installed but no TPU attached, jax's default
# platform probe can stall for minutes per process before falling back to
# CPU — pin the platform unless the caller overrides it explicitly.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
