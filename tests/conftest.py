import os
import sys
from pathlib import Path

# This suite is CPU-targeted (Pallas kernels run in interpret mode). On
# hosts that have libtpu installed but no TPU attached, jax's default
# platform probe can stall for minutes per process before falling back to
# CPU — pin the platform unless the caller overrides it explicitly.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Shared test-local modules (tests/parity.py, tests/_hypothesis_fallback.py)
# import as plain top-level names regardless of rootdir/invocation dir.
sys.path.insert(0, str(Path(__file__).resolve().parent))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
