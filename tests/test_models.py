"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward + one train step on CPU, output shapes + no
NaNs. The FULL configs are exercised only via the dry-run.

Tier-1 runs every case except the genuinely heavy jamba-v0.1-52b variants
(~25s each, measured — the hybrid mamba/attention/moe stack compiles the
most); those stay `slow`-marked so CI time doesn't regress.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS

_HEAVY = {"jamba-v0.1-52b"}       # measured ~25s/case; everything else <10s


def _arch_params(archs):
    return [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
            for a in archs]
from repro.models import model as M
from repro.sharding.axes import strip
from repro.sharding.rules import unpadded_plan
from repro.train.optimizer import OptConfig
from repro.train.train_step import (TrainConfig, init_train_state,
                                    make_train_step)


def _batch(cfg, rng, b=2, s=16):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
    }
    if cfg.frontend == "vision_stub":
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_prefix_embeds, cfg.d_model)),
            jnp.float32)
        batch["labels"] = batch["labels"].at[:, :cfg.n_prefix_embeds].set(-1)
    if cfg.enc_dec:
        batch["enc_frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.enc_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", _arch_params(sorted(ARCHS)))
def test_arch_smoke_forward_and_train_step(arch, rng):
    cfg = ARCHS[arch].reduced()
    plan = unpadded_plan(cfg)
    params = strip(M.init_params(cfg, plan, jax.random.key(0), max_seq=32))
    b, s = 2, 16
    batch = _batch(cfg, rng, b, s)

    logits, aux, _ = M.forward(params, cfg, plan, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    step = make_train_step(cfg, plan, TrainConfig(
        opt=OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)))
    state = init_train_state(params)
    state, metrics = jax.jit(step, donate_argnums=(0,))(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    assert int(state["opt"]["step"]) == 1


@pytest.mark.parametrize("arch", _arch_params(
    ["llama3-8b", "rwkv6-3b", "jamba-v0.1-52b", "whisper-base",
     "minicpm3-4b"]))
def test_decode_matches_prefill(arch, rng):
    """Token-by-token decode logits == full-sequence forward logits."""
    cfg = ARCHS[arch].reduced()
    plan = unpadded_plan(cfg)
    params = strip(M.init_params(cfg, plan, jax.random.key(1), max_seq=32))
    b, s = 2, 8
    batch = _batch(cfg, rng, b, s)
    batch.pop("labels")
    full_logits, _, _ = M.forward(params, cfg, plan, batch)

    caches = M.init_decode_cache(cfg, plan, b, 32, jnp.float32)
    if cfg.enc_dec:
        from repro.models import attention as A
        enc_out = M._encode(params, cfg, plan, batch["enc_frames"], "xla")
        new = []
        for pp, entry in enumerate(caches):
            lp = params["layers"][pp]
            ck, cv = entry[2], entry[3]
            for layer in range(entry[0].shape[0]):
                lpl = jax.tree.map(lambda x: x[layer], lp)
                k, v = A.cross_kv(lpl["xattn"], cfg, plan, enc_out)
                ck = ck.at[layer].set(k.astype(ck.dtype))
                cv = cv.at[layer].set(v.astype(cv.dtype))
            new.append((entry[0], entry[1], ck, cv))
        caches = new
    errs = []
    for t in range(s):
        emb = None
        if cfg.frontend == "vision_stub" and t < cfg.n_prefix_embeds:
            emb = batch["prefix_embeds"][:, t:t + 1]
        logits, caches = M.decode_step(
            params, cfg, plan, batch["tokens"][:, t:t + 1], caches, t,
            embeds=emb)
        errs.append(float(jnp.max(jnp.abs(logits[:, 0]
                                          - full_logits[:, t]))))
    assert max(errs) < 2e-3, errs


def test_vlm_prefix_replaces_embeddings(rng):
    cfg = ARCHS["llava-next-34b"].reduced()
    plan = unpadded_plan(cfg)
    params = strip(M.init_params(cfg, plan, jax.random.key(0), max_seq=32))
    batch = _batch(cfg, rng)
    l1, _, _ = M.forward(params, cfg, plan, batch)
    batch2 = dict(batch)
    batch2["prefix_embeds"] = batch["prefix_embeds"] + 1.0
    l2, _, _ = M.forward(params, cfg, plan, batch2)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-6   # prefix is live input


def test_loss_masking():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.array([[1, 2, -1, -1]])
    loss = M.lm_loss(logits, labels)
    np.testing.assert_allclose(float(loss), np.log(8), rtol=1e-5)


def test_param_count_plausible():
    """Analytic param counts are in the advertised ballpark."""
    approx = {
        "llama3-8b": 8.0e9, "qwen3-14b": 14.8e9, "phi3-medium-14b": 14e9,
        "minicpm3-4b": 4.2e9, "llava-next-34b": 34.8e9,
        "moonshot-v1-16b-a3b": 28e9, "jamba-v0.1-52b": 52e9,
        "rwkv6-3b": 3.1e9, "granite-moe-3b-a800m": 3.3e9,
        "whisper-base": 72e6,
    }
    for name, expect in approx.items():
        n = ARCHS[name].param_count()
        assert 0.55 * expect < n < 1.45 * expect, (name, n, expect)
