"""Deterministic stand-in for ``hypothesis`` when it is not installed.

CI installs the real library (requirements-dev.txt pins it, and
``scripts/check_skips.py`` fails the build if the property suites are
collected-but-skipped), so this shim only runs in minimal local
environments. It implements just the surface the property tests use —
``given`` / ``settings`` / ``HealthCheck`` and the ``strategies``
combinators below — by drawing a fixed number of example sets from a
PRNG seeded on the test's qualified name: the suite stays deterministic
and keeps exercising every oracle, with less search-space coverage than
the real engine.
"""
from __future__ import annotations

import enum
import functools
import inspect

import numpy as np

_FALLBACK_EXAMPLES = 5          # per test; real hypothesis drives more


class HealthCheck(enum.Enum):
    too_slow = 1
    data_too_large = 2
    filter_too_much = 3

    @classmethod
    def all(cls):
        return list(cls)


class _Strategy:
    """A strategy is just ``draw(rng) -> value`` plus combinators."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)

    def map(self, f):
        return _Strategy(lambda rng: f(self._draw(rng)))

    def filter(self, pred, _tries=64):
        def draw(rng):
            for _ in range(_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("fallback filter(): predicate too strict")
        return _Strategy(draw)


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (import as ``st``)."""

    @staticmethod
    def integers(min_value=0, max_value=1 << 30):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(items):
        seq = list(items)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    @staticmethod
    def just(value):
        return _Strategy(lambda rng: value)

    @staticmethod
    def none():
        return _Strategy(lambda rng: None)

    @staticmethod
    def one_of(*strats):
        return _Strategy(
            lambda rng: strats[int(rng.integers(0, len(strats)))]
            ._draw(rng))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements._draw(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*strats):
        return _Strategy(lambda rng: tuple(s._draw(rng) for s in strats))

    @staticmethod
    def builds(target, *args, **kwargs):
        return _Strategy(lambda rng: target(
            *(a._draw(rng) for a in args),
            **{k: v._draw(rng) for k, v in kwargs.items()}))


def settings(max_examples=None, deadline=None, suppress_health_check=(),
             **_ignored):
    """Decorator-compatible no-op that records ``max_examples``."""
    def deco(fn):
        inner = getattr(fn, "__wrapped__", fn)
        inner._fallback_max_examples = max_examples
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*arg_strats, **kw_strats):
    """Run the test body over a fixed, name-seeded example schedule."""
    if arg_strats:
        raise TypeError("fallback given() supports keyword strategies only")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            limit = (getattr(wrapper, "_fallback_max_examples", None)
                     or getattr(fn, "_fallback_max_examples", None)
                     or _FALLBACK_EXAMPLES)
            n = min(int(limit), _FALLBACK_EXAMPLES)
            seed = abs(hash(fn.__qualname__)) % (2 ** 32)
            rng = np.random.default_rng(seed)
            for _ in range(n):
                drawn = {k: s._draw(rng) for k, s in kw_strats.items()}
                fn(*args, **kwargs, **drawn)

        # pytest must not treat the strategy-supplied params as fixtures
        sig = inspect.signature(fn)
        keep = [p for name, p in sig.parameters.items()
                if name not in kw_strats]
        wrapper.__signature__ = sig.replace(parameters=keep)
        wrapper.hypothesis_fallback = True
        return wrapper
    return deco
