"""Checkpoint manager: atomicity, checksums, retention, async, elastic."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(rng):
    return {"a": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
            "b": {"c": jnp.asarray(rng.integers(0, 9, (3,)), jnp.int32),
                  "d": jnp.asarray(1.5, jnp.float32)}}


def test_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(tmp_path)
    t = _tree(rng)
    mgr.save(7, t)
    out = mgr.restore(7, t)
    for a, b in zip(np.asarray(t["a"]), np.asarray(out["a"])):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(np.asarray(t["b"]["c"]),
                                  np.asarray(out["b"]["c"]))
    assert mgr.latest_step() == 7


def test_async_save(tmp_path, rng):
    mgr = CheckpointManager(tmp_path)
    t = _tree(rng)
    mgr.save(1, t, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1
    out = mgr.restore(1, t)
    np.testing.assert_array_equal(np.asarray(t["a"]), np.asarray(out["a"]))


def test_retention_prunes_old(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    t = _tree(rng)
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert sorted(mgr.all_steps()) == [3, 4]


def test_corruption_detected(tmp_path, rng):
    mgr = CheckpointManager(tmp_path)
    t = _tree(rng)
    mgr.save(3, t)
    # flip a byte in one array
    d = tmp_path / "step_00000003"
    path = d / "arr_00000.npy"
    raw = bytearray(path.read_bytes())
    raw[-1] ^= 0xFF
    path.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="checksum"):
        mgr.restore(3, t)


def test_structure_mismatch_rejected(tmp_path, rng):
    mgr = CheckpointManager(tmp_path)
    t = _tree(rng)
    mgr.save(1, t)
    with pytest.raises(ValueError, match="structure mismatch"):
        mgr.restore(1, {"only": t["a"]})


def test_tmp_dir_never_published(tmp_path, rng):
    """A leftover .tmp dir (simulated crash) is invisible to discovery."""
    mgr = CheckpointManager(tmp_path)
    t = _tree(rng)
    mgr.save(5, t)
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert mgr.latest_step() == 5
