"""HLO analyzer: trip-count-aware accounting verified against known
workloads (this is the §Roofline measurement instrument)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analyzer import analyze, parse_module, _trip_count


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_flops_scale_with_trip_count():
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)

    def f_scan(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return jnp.sum(y)

    def f_unrolled(w, x):
        for i in range(8):
            x = jnp.tanh(x @ w[i])
        return jnp.sum(x)

    r_scan = analyze(_compile(f_scan, w, x).as_text())
    r_unroll = analyze(_compile(f_unrolled, w, x).as_text())
    expected = 8 * 2 * 64 * 128 * 128
    assert r_scan["flops"] == pytest.approx(expected, rel=0.01)
    assert r_unroll["flops"] == pytest.approx(expected, rel=0.01)


def test_single_dot_flops_exact():
    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 16), jnp.float32)
    r = analyze(_compile(lambda a, b: a @ b, a, b).as_text())
    assert r["flops"] == 2 * 32 * 64 * 16


def test_nested_scan_multiplicity():
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def f(x, w):
        def outer(x, _):
            def inner(x, _):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(inner, x, None, length=5)
            return x, None
        x, _ = jax.lax.scan(outer, x, None, length=3)
        return jnp.sum(x)

    r = analyze(_compile(f, x, w).as_text())
    assert r["flops"] == pytest.approx(15 * 2 * 16 * 16 * 16, rel=0.01)


def test_trip_count_parse():
    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)

    def f(x):
        return jax.lax.fori_loop(0, 37, lambda i, x: x * 1.5, x)

    text = _compile(f, x).as_text()
    comps = parse_module(text)
    trips = [_trip_count(comps, cond)
             for c in comps.values() if c.name != "__entry__"
             for _, cond, _ in c.while_ops]
    assert 37 in trips


def test_memory_counts_payload():
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    r = analyze(_compile(lambda a: a + 1.0, a).as_text())
    # read + write of 4MB each (fusion operand + result)
    assert 8e6 < r["memory_bytes"] < 2e7
