"""Shared kernel-parity scaffolding (build → churn → compare impls).

Four suites (test_fused_search / test_pq / test_filters / test_tiered)
grew copy-pasted variants of the same skeleton: build an index, churn
it, then assert that two execution paths return the same ids AND the
same distances. This module is the single copy. The comparison contract
everywhere:

  * labels compare ``==`` exactly — never allclose;
  * distances compare ``==`` (bit-exact) on paths that share the
    summation structure (PQ/ADC: one materialized table feeds both
    impls; tiered: a pure residency layer over identical planes), and
    ``allclose(rtol=atol=1e-5)`` only where fp accumulation order
    legitimately differs (raw-payload XLA vs Pallas fold).

``assert_search_parity`` is the end-to-end form (``core.search`` with
``impl="xla"`` vs ``impl="pallas_interpret"``, optional compiled
filter); the kernel-level single-impl asserts stay in their own suites.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.core import filters as flt


def make_state(rng, dim=16, n_lists=4, n_slabs=24, capacity=32, n_max=2048,
               max_chain=8, metric="l2", pq=None, attributes=None,
               train=None):
    """(cfg, fresh state) with random centroids; trains PQ if configured."""
    cfg = core.SIVFConfig(dim=dim, n_lists=n_lists, n_slabs=n_slabs,
                          capacity=capacity, n_max=n_max, metric=metric,
                          max_chain=max_chain, pq=pq,
                          attributes=attributes or ())
    cents = rng.normal(size=(n_lists, dim)).astype(np.float32)
    cb = None
    if pq is not None:
        from repro.core import pq as pq_mod
        data = train if train is not None else \
            rng.normal(size=(512, dim)).astype(np.float32)
        cb = pq_mod.train_pq(jax.random.key(0), jnp.asarray(data),
                             pq.m, pq.nbits, iters=8)
    return cfg, core.init_state(cfg, jnp.asarray(cents), cb)


def random_attrs(cfg, rng, n, n_tenants=5):
    """Attribute rows: first column tenant-like, the rest wide ints."""
    cols = [rng.integers(0, n_tenants, n)]
    cols += [rng.integers(0, 100, n) for _ in range(cfg.n_attrs - 1)]
    return np.stack(cols, axis=1).astype(np.int32)


def load_rows(cfg, state, rng, n, start=0, vecs=None, lists=None,
              n_tenants=5):
    """Insert ``n`` rows with ids ``start..start+n``; returns attrs too."""
    if vecs is None:
        vecs = rng.normal(size=(n, cfg.dim)).astype(np.float32)
    attrs = random_attrs(cfg, rng, n, n_tenants) if cfg.n_attrs else None
    state = core.insert(
        cfg, state, jnp.asarray(vecs),
        jnp.asarray(np.arange(start, start + n), np.int32),
        None if lists is None else jnp.asarray(lists, jnp.int32),
        attrs=None if attrs is None else jnp.asarray(attrs))
    return state, vecs, attrs


def churn(cfg, state, rng, steps=4, id_space=512, rows=None):
    """Randomized insert/delete churn; mirrors membership in ``rows``.

    ``rows`` (dict id -> vec) doubles as the oracle the property suites
    diff against; pass an existing dict to continue a schedule.
    """
    rows = {} if rows is None else rows
    nxt = max(rows) + 1 if rows else 0
    for _ in range(steps):
        n_ins = int(rng.integers(8, 40))
        ids = (np.arange(nxt, nxt + n_ins) % id_space).astype(np.int32)
        nxt += n_ins
        vecs = rng.normal(size=(n_ins, cfg.dim)).astype(np.float32)
        state = core.insert(cfg, state, jnp.asarray(vecs),
                            jnp.asarray(ids))
        for i, v in zip(ids.tolist(), vecs):
            rows[i] = v
        if len(rows) > 20:
            dels = rng.choice(sorted(rows), size=8, replace=False)
            state = core.delete(cfg, state, jnp.asarray(dels, np.int32))
            for i in dels.tolist():
                rows.pop(i, None)
        assert int(np.asarray(state.error).max()) == 0
    return state, rows


def assert_search_parity(cfg, state, rng, k, nprobe, q=5, use_tables=True,
                         block_q=8, pred=None, exact_dist=None,
                         queries=None):
    """``core.search`` xla vs pallas_interpret on identical state.

    Labels must be identical; distances bit-exact on the ADC path (the
    default when PQ is configured), allclose on the raw-payload path.
    Returns the (xla) distances and labels for follow-on asserts.
    """
    if exact_dist is None:
        exact_dist = cfg.pq is not None
    if queries is None:
        queries = rng.normal(size=(q, cfg.dim)).astype(np.float32)
    qs = jnp.asarray(queries)
    kw = {}
    if pred is not None:
        cf = flt.compile_filter(pred, cfg.attributes)
        kw = {"fstruct": cf.structure,
              "fconsts": jnp.asarray(cf.consts, jnp.int32)}
    dx, lx = core.search(cfg, state, qs, k, nprobe, use_tables=use_tables,
                         impl="xla", block_q=block_q, **kw)
    dp, lp = core.search(cfg, state, qs, k, nprobe, use_tables=use_tables,
                         impl="pallas_interpret", block_q=block_q, **kw)
    if exact_dist:
        assert (np.asarray(dp) == np.asarray(dx)).all()
    else:
        np.testing.assert_allclose(np.asarray(dp), np.asarray(dx),
                                   rtol=1e-5, atol=1e-5)
    assert (np.asarray(lp) == np.asarray(lx)).all()
    return np.asarray(dx), np.asarray(lx)


# ---------------------------------------------------------------------------
# Index-handle twins (the tiered-vs-resident form of the same skeleton)
# ---------------------------------------------------------------------------

def assert_results_same(res_a, res_b):
    """Two ``SearchResult``s: ids AND distances ``==`` exactly."""
    assert np.array_equal(np.asarray(res_a.labels),
                          np.asarray(res_b.labels))
    assert np.array_equal(np.asarray(res_a.distances),
                          np.asarray(res_b.distances))


def twin_churn(rng, twins, vecs, ids, attrs=None, attrs_fn=None):
    """The shared mutation schedule over N twin handles: bulk add,
    overwrite, delete, refill (the refill recycles reclaimed slabs —
    dirty-frame coherence on tiered pools)."""
    dim = vecs.shape[1]
    for idx in twins:
        idx.add(vecs, ids, attrs=attrs)
    over = rng.normal(size=(100, dim)).astype(np.float32)
    oa = None if attrs_fn is None else attrs_fn(100)
    for idx in twins:
        idx.add(over, ids[:100], attrs=oa)
        idx.remove(ids[150:300])
    refill = rng.normal(size=(120, dim)).astype(np.float32)
    rid = np.arange(2000, 2120, dtype=np.int32)
    ra = None if attrs_fn is None else attrs_fn(120)
    for idx in twins:
        idx.add(refill, rid, attrs=ra)
    return twins
