"""Index maintenance under drift (ISSUE 10): split / merge / recluster.

Layers under test, matching the subsystem's structure:

  * op construction + the occupancy-driven ``plan_ops`` policy;
  * the functional core (``core.maintain``): every op commits atomically
    through the staged-insert path, never changes the live id set, and
    keeps full-probe search results layout-invariant;
  * kernel parity (shared scaffolding in tests/parity.py): search stays
    bit-identical across ``xla`` / ``pallas_interpret`` before AND after
    a maintenance pass, raw and PQ paths;
  * **atomicity acceptance**: an aborted op leaves every previously-live
    id searchable with its old payload, on the single backend, the
    1-shard mesh, and a true 2-shard mesh (subprocess) — and strict mode
    surfaces the abort as :class:`sivf.MaintenanceAborted` only after
    every op has resolved;
  * the session surface: ``stats()`` per-list occupancy/skew counters vs
    an independent host recount after overwrite-heavy churn (the
    regression satellite), tiered-store coherence, deferred handles, and
    mesh-vs-single report/search parity.
"""
import json
import subprocess
import sys

import jax
import numpy as np
import pytest

import parity
import sivf
from repro import core
from repro.core import maintenance as mt
from repro.core import quantizer

D, NL = 16, 4


# ---------------------------------------------------------------------------
# Op construction + policy
# ---------------------------------------------------------------------------

def test_maintop_validation():
    assert mt.split(0, 1).lists == (0, 1)
    assert mt.recluster(3).lists == (3,)
    with pytest.raises(ValueError, match="unknown maintenance kind"):
        mt.MaintOp("defrag", (0,))
    with pytest.raises(ValueError, match="takes 1 list"):
        mt.MaintOp("recluster", (0, 1))
    with pytest.raises(ValueError, match="takes 2 list"):
        mt.MaintOp("split", (0,))
    with pytest.raises(ValueError, match="distinct"):
        mt.merge(2, 2)


def test_plan_ops_split_on_skew():
    """Hot list > skew_hi*mean with a near-empty victim -> split first."""
    ops, _ = mt.plan_ops([300, 2, 2, 40], max_ops=2)
    assert ops[0] == mt.split(0, 1)


def test_plan_ops_merge_underfull():
    """Two under-full lists and no split candidate -> merge them."""
    ops, _ = mt.plan_ops([40, 2, 2, 40], max_ops=1)
    assert ops == [mt.merge(1, 2)]


def test_plan_ops_recluster_round_robin():
    """Balanced occupancy: the cursor walks every non-empty list across
    sweeps, so sustained drift recenters the whole index."""
    ops, cur = mt.plan_ops([5, 5, 5, 5], cursor=1, max_ops=2)
    assert ops == [mt.recluster(1), mt.recluster(2)] and cur == 3
    ops, cur = mt.plan_ops([5, 5, 5, 5], cursor=cur, max_ops=2)
    assert ops == [mt.recluster(3), mt.recluster(0)] and cur == 1


def test_plan_ops_empty_index_plans_nothing():
    ops, cur = mt.plan_ops([0, 0, 0, 0], cursor=2)
    assert ops == [] and cur == 2


# ---------------------------------------------------------------------------
# Functional core: live set preserved, layout-invariant full-probe search
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", [
    mt.recluster(0), mt.split(2, 1), mt.merge(0, 3),
], ids=["recluster", "split", "merge"])
def test_functional_op_preserves_live_set(rng, op):
    cfg, state = parity.make_state(rng)
    state, vecs, _ = parity.load_rows(cfg, state, rng, 200)
    before = int(state.n_live)
    state, rep = core.maintain(cfg, state, op)
    assert rep.committed and rep.errors == 0
    assert int(state.n_live) == before == rep.n_live
    # every id self-queries back at distance 0 (full probe)
    d, lab = core.search(cfg, state, vecs, 1, NL)
    assert (np.asarray(lab)[:, 0] == np.arange(200)).all()
    np.testing.assert_allclose(np.asarray(d)[:, 0], 0, atol=1e-4)


def test_merge_empties_the_source_list(rng):
    cfg, state = parity.make_state(rng)
    state, _, _ = parity.load_rows(cfg, state, rng, 200)
    occ0 = np.asarray(core.stats(cfg, state)["list_occupancy"])
    a, b = 0, 1
    state, rep = core.maintain(cfg, state, mt.merge(a, b))
    assert rep.committed
    occ1 = np.asarray(core.stats(cfg, state)["list_occupancy"])
    tgt = min(a, b)
    assert occ1[max(a, b)] == 0
    assert occ1[tgt] == occ0[a] + occ0[b]
    assert occ1.sum() == occ0.sum()


def test_split_rebalances_between_two_lists(rng):
    cfg, state = parity.make_state(rng)
    # pile everything into list 0 so the split has real skew to fix
    state, _, _ = parity.load_rows(cfg, state, rng, 150,
                                   lists=np.zeros((150,), np.int32))
    state, rep = core.maintain(cfg, state, mt.split(0, 1))
    assert rep.committed and rep.rows == 150
    occ = np.asarray(core.stats(cfg, state)["list_occupancy"])
    assert occ[0] > 0 and occ[1] > 0          # both halves populated
    assert occ[0] + occ[1] == 150


def test_maintenance_no_op_on_empty_lists(rng):
    """Ops over empty lists are host no-ops: committed, nothing moved,
    no device commit attempted."""
    cfg, state = parity.make_state(rng)
    state, _, _ = parity.load_rows(cfg, state, rng, 50,
                                   lists=np.zeros((50,), np.int32))
    state, rep = core.maintain(cfg, state, mt.merge(2, 3))
    assert rep.committed and rep.rows == 0 and rep.n_live == 50


# ---------------------------------------------------------------------------
# Kernel parity before vs after a maintenance pass (shared tests/parity.py)
# ---------------------------------------------------------------------------

@pytest.mark.pallas
def test_search_parity_before_and_after_maintenance(rng):
    """xla == pallas_interpret before AND after a maintenance pass, and
    the full-probe result set is identical across the pass (maintenance
    moves rows between lists; it must never change what a search
    returns)."""
    cfg, state = parity.make_state(rng)
    state, _, _ = parity.load_rows(cfg, state, rng, 200)
    qs = rng.normal(size=(5, D)).astype(np.float32)
    d0, l0 = parity.assert_search_parity(cfg, state, rng, k=8, nprobe=NL,
                                         queries=qs)
    for op in (mt.recluster(0), mt.split(1, 2), mt.merge(0, 3)):
        state, rep = core.maintain(cfg, state, op)
        assert rep.committed
    d1, l1 = parity.assert_search_parity(cfg, state, rng, k=8, nprobe=NL,
                                         queries=qs)
    assert (l0 == l1).all()
    np.testing.assert_allclose(d0, d1, rtol=1e-5, atol=1e-5)


@pytest.mark.pallas
def test_search_parity_after_maintenance_pq_bit_exact(rng):
    """Same pass on the compressed pool: moved rows' codes ride the
    re-insert verbatim, so ADC results are bit-exact across the pass AND
    across impls."""
    cfg, state = parity.make_state(rng, pq=core.PQConfig(m=4, nbits=4))
    state, _, _ = parity.load_rows(cfg, state, rng, 200)
    qs = rng.normal(size=(5, D)).astype(np.float32)
    d0, l0 = parity.assert_search_parity(cfg, state, rng, k=8, nprobe=NL,
                                         queries=qs)
    for op in (mt.split(0, 3), mt.recluster(1)):
        state, rep = core.maintain(cfg, state, op)
        assert rep.committed
    d1, l1 = parity.assert_search_parity(cfg, state, rng, k=8, nprobe=NL,
                                         queries=qs)
    assert (l0 == l1).all() and (d0 == d1).all()


@pytest.mark.pallas
def test_search_parity_after_maintenance_filtered(rng):
    """Attribute stamps ride the re-insert verbatim: filtered parity and
    the filtered result set survive a maintenance pass."""
    from repro.core import filters as flt
    cfg, state = parity.make_state(rng, attributes=("tenant", "ts"))
    state, _, _ = parity.load_rows(cfg, state, rng, 200)
    pred = flt.And(flt.Eq("tenant", 1), flt.Range("ts", 0, 60))
    qs = rng.normal(size=(4, D)).astype(np.float32)
    d0, l0 = parity.assert_search_parity(cfg, state, rng, k=7, nprobe=NL,
                                         queries=qs, pred=pred)
    state, rep = core.maintain(cfg, state, mt.split(0, 1))
    assert rep.committed
    d1, l1 = parity.assert_search_parity(cfg, state, rng, k=7, nprobe=NL,
                                         queries=qs, pred=pred)
    assert (l0 == l1).all()
    np.testing.assert_allclose(d0, d1, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Atomicity acceptance: aborted ops leave every live id searchable
# ---------------------------------------------------------------------------

_TIGHT = dict(dim=D, n_lists=NL, n_slabs=12, capacity=32, n_max=2048,
              max_chain=2)


def _tight_index(rng, backend="single"):
    """A pool whose 2-slab chain bound makes merge(0, 1) of exactly 100
    rows provably overflow: the commit must abort and revert atomically.
    Rows are drawn tightly around well-separated centroids so routing
    (and therefore the 50-rows-per-list setup) is deterministic."""
    cfg = sivf.SIVFConfig(**_TIGHT)
    cents = (rng.normal(size=(NL, D)) * 4.0).astype(np.float32)
    idx = sivf.Index(cfg, cents, backend=backend, min_bucket=8)
    vecs = (cents[np.arange(200) % NL] +
            0.1 * rng.normal(size=(200, D))).astype(np.float32)
    assert idx.add(vecs, np.arange(200, dtype=np.int32)).ok
    return idx, vecs


def _assert_all_live_searchable(idx, vecs):
    d, lab = idx.search(vecs, 1, NL)
    assert (np.asarray(lab)[:, 0] == np.arange(len(vecs))).all()
    np.testing.assert_allclose(np.asarray(d)[:, 0], 0, atol=1e-4)


@pytest.mark.parametrize("backend_name", ["single", "mesh"])
def test_aborted_op_atomic(rng, backend_name):
    """ISSUE 10 acceptance: after an aborted maintenance op every
    previously-live id is still searchable with its old payload, the
    centroids are byte-identical, and no epoch was consumed."""
    backend = "single" if backend_name == "single" \
        else jax.make_mesh((1,), ("data",))
    idx, vecs = _tight_index(rng, backend)
    cents_before = np.asarray(idx.state.centroids).copy()
    epoch_before = idx.epoch
    rep = idx.maintain(ops=[mt.merge(0, 1)], strict=False)[0]
    assert not rep.committed
    assert rep.errors & mt.ABORT_BITS
    assert (np.asarray(idx.state.centroids) == cents_before).all()
    assert idx.epoch == epoch_before
    assert idx.n_live == 200
    _assert_all_live_searchable(idx, vecs)
    # the pool still ingests after the abort (free stack fully restored)
    more = np.random.default_rng(3).normal(size=(8, D)).astype(np.float32)
    assert idx.add(more, np.arange(300, 308, dtype=np.int32)).ok


def test_strict_mode_raises_after_all_ops_resolve(rng):
    idx, vecs = _tight_index(rng)
    with pytest.raises(sivf.MaintenanceAborted) as ei:
        # the committed recluster AFTER the aborted merge must still run
        idx.maintain(ops=[mt.merge(0, 1), mt.recluster(2)], strict=True)
    assert ei.value.report.kind == "merge"
    assert not ei.value.report.committed
    _assert_all_live_searchable(idx, vecs)


_MESH2_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import numpy as np, jax
import sivf
from repro.core import maintenance as mt

rng = np.random.default_rng(7)
D, NL = 16, 4
cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=12, capacity=32,
                      n_max=2048, max_chain=2)
cents = (rng.normal(size=(NL, D)) * 4.0).astype(np.float32)
mesh = jax.make_mesh((2,), ("data",))
idx = sivf.Index(cfg, cents, backend=mesh, min_bucket=8)
# lists 0/1 get 80 rows each (~40 per shard): merge(0, 1) must overflow
# the 64-row per-shard chain bound on BOTH shards however rows shard
pattern = rng.permuted(np.repeat([0, 1, 2, 3], [80, 80, 20, 20]))
vecs = (cents[pattern] + 0.1 * rng.normal(size=(200, D))).astype(np.float32)
assert idx.add(vecs, np.arange(200, dtype=np.int32)).ok
rep = idx.maintain(ops=[mt.merge(0, 1)], strict=False)[0]
d, lab = idx.search(vecs, 1, NL)
ok_ids = bool((np.asarray(lab)[:, 0] == np.arange(200)).all())
ok_d = bool(np.allclose(np.asarray(d)[:, 0], 0, atol=1e-4))
cents2 = np.asarray(idx.state.centroids)          # [2, NL, D] stacked
rep2 = idx.maintain(ops=[mt.recluster(2)], strict=False)[0]
d2, lab2 = idx.search(vecs, 1, NL)
print(json.dumps({
    "aborted": not rep.committed, "errors": rep.errors,
    "ok_ids": ok_ids, "ok_d": ok_d,
    "cents_replicated": bool((cents2[0] == cents2[1]).all()),
    "recluster_committed": rep2.committed,
    "ok_after": bool((np.asarray(lab2)[:, 0] == np.arange(200)).all()),
}))
"""


def test_aborted_op_atomic_two_shard_mesh():
    """All shards vote: one shard's overflow reverts BOTH shards (the
    pmax abort ballot), and the next committed op replicates the refined
    centroids to every shard."""
    r = subprocess.run(
        [sys.executable, "-c", _MESH2_SCRIPT], capture_output=True,
        text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["aborted"] and out["errors"] & mt.ABORT_BITS
    assert out["ok_ids"] and out["ok_d"]
    assert out["cents_replicated"]
    assert out["recluster_committed"] and out["ok_after"]


# ---------------------------------------------------------------------------
# stats() occupancy counters vs independent recount (regression satellite)
# ---------------------------------------------------------------------------

def test_stats_occupancy_matches_recount_after_overwrite_churn(rng):
    """Per-list occupancy in ``stats()`` must agree with an independent
    recount after overwrite-heavy churn. The recount routes every live
    id's LATEST vector through the quantizer — the same truth the scan
    path uses — so stale incremental counters cannot hide."""
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=48, capacity=32,
                          n_max=2048, max_chain=12)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    idx = sivf.Index(cfg, cents, min_bucket=8)
    latest: dict[int, np.ndarray] = {}
    ids = np.arange(200, dtype=np.int32)
    for round_ in range(4):                      # each round re-routes ids
        vecs = rng.normal(size=(len(ids), D)).astype(np.float32)
        assert idx.add(vecs, ids).ok
        for i, v in zip(ids.tolist(), vecs):
            latest[i] = v
        drop = ids[rng.integers(0, len(ids), size=30)]
        idx.remove(drop)
        for i in set(drop.tolist()):
            latest.pop(i, None)
        ids = np.asarray(sorted(set(ids.tolist()) | set(
            range(500 + 50 * round_, 530 + 50 * round_))), np.int32)
    s = idx.stats()
    occ = np.asarray(s["list_occupancy"])
    assert occ.sum() == idx.n_live == len(latest)
    live_ids = sorted(latest)
    assigned = np.asarray(quantizer.assign(
        idx.state.centroids, np.stack([latest[i] for i in live_ids]),
        cfg.metric))
    recount = np.bincount(assigned, minlength=NL)
    assert (occ == recount).all(), (occ, recount)
    assert s["list_skew"] == pytest.approx(float(occ.max() / occ.mean()))


def test_stats_occupancy_tracks_maintenance(rng):
    """After a committed merge, the counters reflect the new layout (and
    keep summing to n_live)."""
    cfg, state = parity.make_state(rng)
    state, _, _ = parity.load_rows(cfg, state, rng, 160)
    idx = sivf.Index(cfg, np.asarray(state.centroids),
                     _state=jax.tree.map(np.asarray, state), min_bucket=8)
    occ0 = np.asarray(idx.stats()["list_occupancy"])
    rep = idx.maintain(ops=[mt.merge(1, 2)], strict=True)[0]
    assert rep.committed
    occ1 = np.asarray(idx.stats()["list_occupancy"])
    assert occ1[2] == 0 and occ1[1] == occ0[1] + occ0[2]
    assert occ1.sum() == occ0.sum() == idx.n_live


# ---------------------------------------------------------------------------
# Session surface: policy wiring, epochs, mesh parity, tiered, deferred
# ---------------------------------------------------------------------------

def _handle(rng, backend="single", **kw):
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=48, capacity=32,
                          n_max=2048, max_chain=12, **kw)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    return sivf.Index(cfg, cents, backend=backend, min_bucket=8)


def test_policy_planned_maintain_bumps_epoch_per_commit(rng):
    idx = _handle(rng)
    vecs = rng.normal(size=(300, D)).astype(np.float32)
    idx.add(vecs, np.arange(300, dtype=np.int32))
    e0 = idx.epoch
    reps = idx.maintain(max_ops=2)               # drift policy plans
    assert reps
    moved = sum(1 for r in reps if r.committed and r.rows > 0)
    assert idx.epoch == e0 + moved
    _assert_all_live_searchable(idx, vecs)


def test_mesh_maintain_matches_single(rng):
    mesh = jax.make_mesh((1,), ("data",))
    a = _handle(np.random.default_rng(0))
    b = _handle(np.random.default_rng(0), backend=mesh)
    vecs = np.random.default_rng(5).normal(size=(250, D)).astype(np.float32)
    qs = np.random.default_rng(6).normal(size=(6, D)).astype(np.float32)
    ops = [mt.split(0, 1), mt.merge(2, 3), mt.recluster(0)]
    for idx in (a, b):
        idx.add(vecs, np.arange(250, dtype=np.int32))
    ra = a.maintain(ops=ops, strict=True)
    rb = b.maintain(ops=ops, strict=True)
    assert [(r.kind, r.committed, r.rows) for r in ra] \
        == [(r.kind, r.committed, r.rows) for r in rb]
    parity.assert_results_same(a.search(qs, 8, NL), b.search(qs, 8, NL))


def test_tiered_maintenance_coherent(rng):
    """Tiered twin stays bit-identical to the all-resident twin through a
    maintenance pass: moved rows' payloads/attrs ride the commit plan
    into the host store, and centroid updates reach future prefetches."""
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    cfg = dict(dim=D, n_lists=NL, n_slabs=48, capacity=32, n_max=4096,
               max_chain=12, attributes=("tenant",))
    it = sivf.Index(sivf.SIVFConfig(device_slabs=40, **cfg), cents)
    if_ = sivf.Index(sivf.SIVFConfig(**cfg), cents)
    vecs = rng.normal(size=(500, D)).astype(np.float32)
    ids = np.arange(500, dtype=np.int32)
    parity.twin_churn(rng, (it, if_), vecs, ids,
                      attrs={"tenant": ids % 3},
                      attrs_fn=lambda n: {"tenant": np.arange(n) % 3})
    ops = [mt.recluster(0), mt.merge(1, 2)]
    for idx in (it, if_):
        reps = idx.maintain(ops=ops, strict=True)
        assert all(r.committed for r in reps)
    qs = rng.normal(size=(5, D)).astype(np.float32)
    from repro.core import filters as flt
    for kw in ({}, {"filter": flt.Eq("tenant", 1)}):
        parity.assert_results_same(it.search(qs, 10, NL, **kw),
                                   if_.search(qs, 10, NL, **kw))
    # and the tiered pool keeps ingesting post-maintenance
    more = rng.normal(size=(16, D)).astype(np.float32)
    for idx in (it, if_):
        idx.add(more, np.arange(3000, 3016, dtype=np.int32),
                attrs={"tenant": 1})
    parity.assert_results_same(it.search(qs, 10, NL), if_.search(qs, 10, NL))


def test_deferred_handle_maintains_between_pending(rng):
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=48, capacity=32,
                          n_max=2048, max_chain=12)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    deferred = sivf.Index(cfg, cents, min_bucket=8, deferred=True)
    vecs = rng.normal(size=(120, D)).astype(np.float32)
    fut = deferred.add(vecs, np.arange(120, dtype=np.int32))
    reps = deferred.maintain(ops=[mt.recluster(0)], strict=False)
    assert all(isinstance(r, mt.MaintenanceReport) for r in reps)
    assert not fut.done
    deferred.flush()
    assert fut.result().ok and deferred.n_live == 120
    _assert_all_live_searchable(deferred, vecs)


def test_serve_engine_maintenance(rng):
    from repro.serve.sivf_engine import ServeEngine
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=48, capacity=32,
                          n_max=2048, max_chain=12)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    serve_idx = sivf.Index(cfg, cents, min_bucket=8, deferred=True)
    plain = sivf.Index(cfg, cents, min_bucket=8)
    vecs = rng.normal(size=(300, D)).astype(np.float32)
    ids = np.arange(300, dtype=np.int32)
    plain.add(vecs, ids)
    qs = rng.normal(size=(6, D)).astype(np.float32)
    with ServeEngine(serve_idx, default_nprobe=NL) as eng:
        s = eng.session("t")
        s.add(vecs, ids).result()
        res = s.maintain(max_ops=2).result()
        assert res.ok and res.epoch >= 1
        assert isinstance(res.queue_s, float)
        after = s.search(qs, k=10).result()
        assert eng.stats()["maintenance_passes"] == 1
    # maintenance must not change what the serve path returns (full probe)
    want = plain.search(qs, 10, NL)
    assert (np.asarray(after.labels) == np.asarray(want.labels)).all()
    np.testing.assert_allclose(np.asarray(after.distances),
                               np.asarray(want.distances),
                               rtol=1e-5, atol=1e-5)


def test_maintain_requires_trained(rng):
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=8, capacity=32,
                          pq=sivf.PQConfig(m=4, nbits=4))
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    idx = sivf.Index(cfg, cents)
    with pytest.raises(RuntimeError, match="untrained"):
        idx.maintain(ops=[mt.recluster(0)])
