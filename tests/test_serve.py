"""Slab-paged serving engine: parity with the dense path + O(1) lifecycle.

Tier-1 runs the page-pool lifecycle tests plus one representative decode
arch (llama3-8b reduced); the remaining compile-heavy archs carry the
``slow`` marker and run in the main-branch CI job.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import model as M
from repro.serve import kv_cache as kvc
from repro.serve.paged_lm import PagedLMEngine
from repro.sharding.axes import strip
from repro.sharding.rules import unpadded_plan

# MoE archs get a loose tolerance: top-k routing is discontinuous, so
# attention-order numerics can flip near-tied experts.
CASES = [
    ("llama3-8b", 5e-3),
    pytest.param("minicpm3-4b", 5e-3, marks=pytest.mark.slow),
    pytest.param("rwkv6-3b", 5e-3, marks=pytest.mark.slow),
    pytest.param("jamba-v0.1-52b", 5e-2, marks=pytest.mark.slow),
    pytest.param("moonshot-v1-16b-a3b", 2e-1, marks=pytest.mark.slow),
]


@pytest.mark.parametrize("arch,tol", CASES)
def test_paged_engine_matches_dense_decode(arch, tol, rng):
    cfg = ARCHS[arch].reduced()
    plan = unpadded_plan(cfg)
    params = strip(M.init_params(cfg, plan, jax.random.key(1), max_seq=64))
    prompt = rng.integers(1, cfg.vocab_size, 10).astype(np.int32)
    feed = rng.integers(1, cfg.vocab_size, 5).astype(np.int32)

    caches = M.init_decode_cache(cfg, plan, 1, 64, jnp.float32)
    for t in range(len(prompt)):
        logits, caches = M.decode_step(
            params, cfg, plan, jnp.asarray([[prompt[t]]], jnp.int32),
            caches, t)

    eng = PagedLMEngine(cfg, plan, params, page_size=8, n_pages=32, max_seqs=2)
    assert eng.admit(0, prompt)
    errs = []
    for i, tok in enumerate(feed):
        eng.last_tokens = eng.last_tokens.at[0, 0].set(int(tok))
        logits, caches = M.decode_step(
            params, cfg, plan, jnp.asarray([[tok]], jnp.int32), caches,
            len(prompt) + i)
        lg, _, _ = eng._decode(params, eng.pools, eng.last_tokens,
                               eng.pages.tables, eng.pages.lengths,
                               eng.pages.starts, eng.pages.offsets,
                               eng.pages.active)
        eng.step()
        errs.append(float(jnp.max(jnp.abs(lg[0, 0] - logits[0, 0]))))
    assert max(errs) < tol, errs

    # O(1) eviction returns every page
    eng.evict(0)
    assert int(eng.pages.free_top) == 32
    assert not bool(eng.pages.active[0])


def test_page_pool_lifecycle():
    cfg = kvc.PagedKVConfig(n_pages=16, page_size=4, max_pages_per_seq=8,
                            max_seqs=3)
    st = kvc.init_page_state(cfg)
    st, ok = kvc.allocate(cfg, st, jnp.int32(0), 3)
    assert bool(ok) and int(st.free_top) == 13
    st, ok = kvc.allocate(cfg, st, jnp.int32(1), 2)
    assert bool(ok) and int(st.free_top) == 11
    # no page is handed out twice
    used = np.asarray(st.tables)
    used = used[used >= 0]
    assert len(set(used.tolist())) == len(used) == 5
    st = kvc.evict_seq(cfg, st, jnp.int32(0))
    assert int(st.free_top) == 14
    # exhaustion fail-fast
    st, ok = kvc.allocate(cfg, st, jnp.int32(2), 15)
    assert not bool(ok)
    assert int(st.free_top) == 14                  # unchanged


def test_sliding_window_frees_whole_pages():
    cfg = kvc.PagedKVConfig(n_pages=16, page_size=4, max_pages_per_seq=8,
                            max_seqs=2)
    st = kvc.init_page_state(cfg)
    st, ok = kvc.allocate(cfg, st, jnp.int32(0), 6)   # 24 slots
    st = kvc.PageState(tables=st.tables, lengths=st.lengths.at[0].set(22),
                       starts=st.starts, offsets=st.offsets,
                       active=st.active, free_stack=st.free_stack,
                       free_top=st.free_top)
    st = kvc.slide_window(cfg, st, jnp.int32(0), jnp.int32(10))
    # pages 0,1 (slots 0-7) freed; table compacted; coords shifted by 8
    assert int(st.free_top) == 12
    assert int(st.lengths[0]) == 14
    assert int(st.starts[0]) == 2
    assert int(st.offsets[0]) == 8
    row = np.asarray(st.tables[0])
    assert (row[:4] >= 0).all() and (row[4:] == -1).all()


def test_engine_sliding_window_decode(rng):
    """Decode continues correctly after window slides (positions stay
    absolute via offsets)."""
    cfg = ARCHS["llama3-8b"].reduced()
    plan = unpadded_plan(cfg)
    params = strip(M.init_params(cfg, plan, jax.random.key(2), max_seq=64))
    prompt = rng.integers(1, cfg.vocab_size, 12).astype(np.int32)
    eng = PagedLMEngine(cfg, plan, params, page_size=4, n_pages=32, max_seqs=1)
    assert eng.admit(0, prompt)
    for _ in range(4):
        eng.step()
    free_before = int(eng.pages.free_top)
    eng.slide(0, keep_last=8)
    assert int(eng.pages.free_top) > free_before   # pages reclaimed
    out = eng.step()                                # still decodes fine
    assert 0 <= out[0] < cfg.vocab_size
