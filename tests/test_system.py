"""End-to-end behaviour tests for the paper's system.

The streaming/recall cases are cheap enough for tier-1; only the train
launcher restart (three full train-step compiles) stays ``slow``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.data.pipeline import VectorStream, VectorStreamConfig


def test_streaming_sliding_window_end_to_end(rng):
    """Paper §5.5 scenario: maintain a fixed window W under churn —
    ingest B new / evict B oldest per step; search stays correct, memory
    stays bounded, no compaction ever runs."""
    D, NL, W, B = 16, 16, 512, 64
    cfg = core.SIVFConfig(dim=D, n_lists=NL, n_slabs=128, capacity=32,
                          n_max=1 << 14, max_chain=32)
    stream = VectorStream(VectorStreamConfig(dim=D, n_clusters=NL))
    train = stream.batch(0, 512)
    cents = core.train_kmeans(jax.random.key(0), jnp.asarray(train), NL)
    state = core.init_state(cfg, cents)
    ref = core.ReferenceIndex(np.asarray(cents))

    next_id = 0
    peak_slabs = 0
    for step in range(1, 14):
        vecs = stream.batch(step, B)
        ids = np.arange(next_id, next_id + B, dtype=np.int32)
        next_id += B
        state = core.insert(cfg, state, jnp.asarray(vecs), jnp.asarray(ids))
        ref.insert(vecs, ids)
        if next_id > W:
            evict = np.arange(next_id - W - B, next_id - W, dtype=np.int32)
            state = core.delete(cfg, state, jnp.asarray(evict))
            ref.delete(evict)
        assert int(state.error) == 0
        assert int(state.n_live) == ref.n_live <= W
        peak_slabs = max(peak_slabs, int(cfg.n_slabs - state.free_top))

    # bounded footprint: never needed more slabs than window + batch slack
    assert peak_slabs * cfg.capacity <= (W + B) * 2.5
    # search over the final window matches brute force
    qs = stream.batch(99, 8)
    d, lab = core.search(cfg, state, jnp.asarray(qs), 10, NL)
    rd, rl = ref.search(qs, 10, NL)
    np.testing.assert_allclose(np.asarray(d), rd, rtol=1e-4, atol=1e-4)
    assert (np.asarray(lab) == rl).all()


def test_recall_parity_with_exact_at_full_probe(rng):
    """Paper Fig. 9: 'strict recall parity' — at nprobe=n_lists SIVF's
    candidate set equals brute force, so Recall@10 == 1.0 vs exact."""
    D, NL = 32, 8
    cfg = core.SIVFConfig(dim=D, n_lists=NL, n_slabs=64, capacity=64,
                          n_max=4096, max_chain=16)
    vecs = rng.normal(size=(800, D)).astype(np.float32)
    cents = core.train_kmeans(jax.random.key(1), jnp.asarray(vecs[:256]), NL)
    state = core.init_state(cfg, cents)
    state = core.insert(cfg, state, jnp.asarray(vecs),
                        jnp.asarray(np.arange(800), np.int32))
    qs = rng.normal(size=(16, D)).astype(np.float32)
    d, lab = core.search(cfg, state, jnp.asarray(qs), 10, NL)
    # exact brute force
    from repro.utils import l2_sq
    full = np.asarray(l2_sq(jnp.asarray(qs), jnp.asarray(vecs)))
    exact = np.argsort(full, axis=1, kind="stable")[:, :10]
    recall = np.mean([len(set(np.asarray(lab)[i].tolist())
                          & set(exact[i].tolist())) / 10
                      for i in range(16)])
    assert recall == 1.0


@pytest.mark.slow
def test_train_launcher_checkpoint_restart(tmp_path):
    """Elastic restart: kill after N steps, resume, final state identical
    to an uninterrupted run (deterministic data + restored step)."""
    from repro.launch.train import main as train_main
    args = ["--arch", "llama3-8b", "--reduced", "--batch", "2",
            "--seq", "16", "--log-every", "100"]

    r1 = train_main(args + ["--steps", "6",
                            "--ckpt-dir", str(tmp_path / "a"),
                            "--ckpt-every", "3"])
    assert r1["steps_run"] == 6

    # interrupted run (simulated preemption at step 3), then resume to 6;
    # --steps stays 6 so the LR schedule is identical across runs
    r2a = train_main(args + ["--steps", "6", "--stop-after", "3",
                             "--ckpt-dir", str(tmp_path / "b"),
                             "--ckpt-every", "3"])
    r2b = train_main(args + ["--steps", "6",
                             "--ckpt-dir", str(tmp_path / "b"),
                             "--ckpt-every", "3"])
    assert r2a["steps_run"] == 3
    assert r2b["steps_run"] == 3          # resumed from step 3
    assert abs(r2b["last_loss"] - r1["last_loss"]) < 1e-4
