"""Hypothesis churn tests for the `sivf.Index` session handle.

Randomized interleaved add / remove / search (ragged batch sizes, id
overwrites, pool exhaustion) against the brute-force dict oracle, on both
the single-device and the shard-mapped mesh backend. The linearizability
argument is the same as ``test_core_property``, lifted to the handle: any
op sequence observed through ``search`` must match the dict model, and
every ``MutationReport`` must account for its batch exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                                   # dev extra, pinned in CI; the local
    from hypothesis import given, settings, strategies as st
except ImportError:                    # fallback keeps tier-1 executing
    from _hypothesis_fallback import given, settings, strategies as st

import sivf
from repro import core
from repro.core import filters as flt

D, NL = 8, 4
CFG = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=48, capacity=32,
                      n_max=256, max_chain=12)
# tiny pool: 3 slabs over 4 lists, chain bound 2 — batches routinely hit
# POOL_EXHAUSTED / CHAIN_OVERFLOW so the failure semantics get exercised
CFG_TINY = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=3, capacity=32,
                           n_max=256, max_chain=2)
_CENTS = np.random.default_rng(42).normal(size=(NL, D)).astype(np.float32)

_MESH = None


def _backend(name):
    global _MESH
    if name == "single":
        return "single"
    if _MESH is None:
        _MESH = jax.make_mesh((1,), ("data",))
    return _MESH


_ABORT = sivf.ErrorCode.POOL_EXHAUSTED | sivf.ErrorCode.CHAIN_OVERFLOW


def _oracle_add(ref, vecs, ids, rep, cfg):
    """Dict-model update for *atomic* insert semantics: a shard rejected by
    POOL_EXHAUSTED / CHAIN_OVERFLOW changes nothing — its previously-live
    ids keep their old payloads (neither dropped nor overwritten). Uses
    ``rep.shard_errors`` so the model stays exact per shard if the mesh
    fixture ever grows beyond one shard."""
    se = rep.shard_errors
    for v, i in zip(vecs, ids):
        i = int(i)
        if not (0 <= i < cfg.n_max):
            continue
        bits = rep.errors if se is None else se[i % len(se)]
        if bits & _ABORT:
            continue                     # owning shard aborted atomically
        ref.store[i] = v.copy()


def _check_search(idx, ref, rng, q=3, k=4):
    qs = rng.normal(size=(q, D)).astype(np.float32)
    d, lab = idx.search(qs, k, NL)
    rd, rl = ref.search(qs, k, NL)
    np.testing.assert_allclose(np.asarray(d), rd, rtol=1e-4, atol=1e-4)
    assert (np.asarray(lab) == rl).all()


# maintenance kinds ride the same alphabet (ISSUE 10): the dict oracle
# must be bit-for-bit unaffected by any split / merge / recluster
_MAINT_KINDS = ("maintain", "split", "merge", "recluster")

ops_strategy = st.lists(
    st.tuples(
        st.sampled_from(["add", "remove", "search", *_MAINT_KINDS]),
        st.lists(st.integers(0, 63), min_size=1, max_size=14),
    ),
    min_size=1, max_size=10,
)


def _maint_ops(kind, ids):
    """Deterministic MaintOp list from the drawn id payload (None asks the
    drift policy to plan from live occupancy counters instead)."""
    if kind == "maintain":
        return None
    a = int(ids[0]) % NL
    b = (a + 1 + int(ids[-1]) % (NL - 1)) % NL
    if kind == "split":
        return [core.split(a, b)]
    if kind == "merge":
        return [core.merge(a, b)]
    return [core.recluster(a)]


def _run_maint(idx, kind, ids):
    reps = idx.maintain(ops=_maint_ops(kind, ids), max_ops=1, strict=False)
    for r in reps:
        assert r.kind in ("split", "merge", "recluster")
        assert isinstance(r.committed, bool)
    return reps


def _assert_failed_batch_atomic(idx, before):
    """Exhaustion-atomicity oracle: after a POOL_EXHAUSTED / CHAIN_OVERFLOW
    batch, every previously-live id is still returned by ``search`` with
    its *old* vector (self-query -> distance 0)."""
    assert idx.n_live == len(before)
    if not before:
        return
    pids = np.fromiter(before.keys(), np.int32)
    qs = np.stack([before[int(i)] for i in pids])
    d, lab = idx.search(qs, 1, NL)
    assert (np.asarray(lab)[:, 0] == pids).all()
    np.testing.assert_allclose(np.asarray(d)[:, 0], 0, atol=1e-4)


def _drive(idx, ref, cfg, ops, seed):
    rng = np.random.default_rng(seed)
    for kind, ids in ops:
        ids = np.asarray(ids, np.int32)
        if kind == "add":
            vecs = rng.normal(size=(len(ids), D)).astype(np.float32)
            before = {i: v.copy() for i, v in ref.store.items()}
            rep = idx.add(vecs, ids)
            _oracle_add(ref, vecs, ids, rep, cfg)
            # the disjoint counts always account for the whole batch
            assert rep.accepted + rep.overwritten + rep.rejected \
                == rep.requested == len(ids)
            if rep.errors & _ABORT and (
                    rep.shard_errors is None
                    or all(e & _ABORT for e in rep.shard_errors)):
                # every shard aborted -> the whole batch was a no-op
                assert rep.accepted == 0 and rep.overwritten == 0
                _assert_failed_batch_atomic(idx, before)
        elif kind == "remove":
            before = len(set(ids.tolist()) & set(ref.store))
            rep = idx.remove(ids)
            ref.delete(ids)
            assert rep.accepted == before
        elif kind in _MAINT_KINDS:
            # maintenance may reshape the layout but never the live set:
            # the dict oracle is untouched and full-probe search (below
            # and at sequence end) must still match it exactly
            _run_maint(idx, kind, ids)
        else:
            _check_search(idx, ref, rng, q=1 + len(ids) % 5)
        assert idx.n_live == ref.n_live
    _check_search(idx, ref, rng)


@pytest.mark.parametrize("backend_name", ["single", "mesh"])
@settings(max_examples=25, deadline=None)
@given(ops=ops_strategy, seed=st.integers(0, 2 ** 16))
def test_handle_churn_matches_reference(backend_name, ops, seed):
    idx = sivf.Index(CFG, _CENTS, backend=_backend(backend_name),
                     min_bucket=8)
    ref = core.ReferenceIndex(_CENTS)
    _drive(idx, ref, CFG, ops, seed)
    # structural invariants still hold under the handle
    state = idx.state
    free_top = np.asarray(state.free_top).reshape(-1)
    owner = np.asarray(state.owner).reshape(-1, CFG.n_slabs)
    assert int(free_top.sum()) + int((owner >= 0).sum()) \
        == CFG.n_slabs * len(free_top)


@pytest.mark.parametrize("backend_name", ["single", "mesh"])
@settings(max_examples=20, deadline=None)
@given(ops=ops_strategy, seed=st.integers(0, 2 ** 16))
def test_handle_churn_under_pool_exhaustion(backend_name, ops, seed):
    """Same sequences on a pool small enough that batches routinely fail:
    reports must stay truthful and every failed batch must be atomic —
    previously-live ids stay searchable with their old payloads (checked
    by the self-query oracle in ``_drive``)."""
    idx = sivf.Index(CFG_TINY, _CENTS, backend=_backend(backend_name),
                     min_bucket=8)
    ref = core.ReferenceIndex(_CENTS)
    _drive(idx, ref, CFG_TINY, ops, seed)


# ---------------------------------------------------------------------------
# PQ-compressed churn (ISSUE 4): codes must track ids exactly
# ---------------------------------------------------------------------------

CFG_PQ = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=48, capacity=32,
                         n_max=256, max_chain=12,
                         pq=sivf.PQConfig(m=4, nbits=4))
# tiny PQ pool: batches routinely abort, exercising code-plane atomicity
CFG_PQ_TINY = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=3, capacity=32,
                              n_max=256, max_chain=2,
                              pq=sivf.PQConfig(m=4, nbits=4))
_PQ_CB = sivf.train_pq(
    jax.random.key(11),
    jnp.asarray(np.random.default_rng(7).normal(size=(512, D)),
                jnp.float32), 4, 4, iters=8)


def _assert_codes_consistent(idx, store):
    """Every live id's stored code row equals encode(codebooks, its
    current vector) — the PQ analogue of the payload oracle. Covers
    inserts, overwrites, and failed batches (whose old codes must
    survive untouched)."""
    from repro.core import pq
    st = idx.state
    assert idx.n_live == len(store)
    if not store:
        return
    ids = np.fromiter(store.keys(), np.int32)
    vecs = np.stack([store[int(i)] for i in ids])
    att_slab = np.asarray(st.att_slab)
    att_slot = np.asarray(st.att_slot)
    codes = np.asarray(st.codes)
    cb = np.asarray(st.pq_codebooks)
    if att_slab.ndim == 2:                    # stacked sharded state
        n_sh = att_slab.shape[0]
        sh = ids % n_sh
        slab, slot = att_slab[sh, ids], att_slot[sh, ids]
        assert (slab >= 0).all()
        got = codes[sh, slab, slot]
        cb = cb[0]                            # replicated per shard
    else:
        slab, slot = att_slab[ids], att_slot[ids]
        assert (slab >= 0).all()
        got = codes[slab, slot]
    want = np.asarray(pq.encode(jnp.asarray(cb), jnp.asarray(vecs)))
    assert (got == want).all()


def _assert_live_set_searchable(idx, store):
    """Full-probe search with k >= n_live returns exactly the live ids
    (ADC distances are approximate; the *set* of reachable ids is not)."""
    if not store:
        return
    k = max(len(store), 1)
    qs = np.stack([v for v in store.values()][:2])
    _, labels = idx.search(qs, k, NL)
    got = set(np.asarray(labels).ravel().tolist()) - {-1}
    assert got == set(int(i) for i in store)


@pytest.mark.parametrize("backend_name", ["single", "mesh"])
@pytest.mark.parametrize("cfg", [CFG_PQ, CFG_PQ_TINY],
                         ids=["pq", "pq_tiny"])
@settings(max_examples=15, deadline=None)
@given(ops=ops_strategy, seed=st.integers(0, 2 ** 16))
def test_pq_churn_codes_consistent(backend_name, cfg, ops, seed):
    """Hypothesis churn with PQ enabled on both backends: insert / delete /
    overwrite keep the uint8 code plane consistent with the id set, failed
    batches leave the old codes searchable, and reports stay disjoint."""
    idx = sivf.Index(cfg, _CENTS, backend=_backend(backend_name),
                     min_bucket=8, pq_codebooks=_PQ_CB)
    rng = np.random.default_rng(seed)
    store: dict[int, np.ndarray] = {}
    for kind, ids in ops:
        ids = np.asarray(ids, np.int32)
        if kind == "add":
            vecs = rng.normal(size=(len(ids), D)).astype(np.float32)
            rep = idx.add(vecs, ids)
            assert rep.accepted + rep.overwritten + rep.rejected \
                == rep.requested == len(ids)
            se = rep.shard_errors
            last = {int(i): v for i, v in zip(ids, vecs)}   # batch: last wins
            for i, v in last.items():
                bits = rep.errors if se is None else se[i % len(se)]
                if not bits & _ABORT:
                    store[i] = v.copy()
        elif kind == "remove":
            rep = idx.remove(ids)
            for i in set(ids.tolist()):
                store.pop(int(i), None)
        elif kind in _MAINT_KINDS:
            # moved rows' codes ride the re-insert verbatim: the stored
            # code plane must still equal encode(current vector) per id
            _run_maint(idx, kind, ids)
        else:
            _assert_live_set_searchable(idx, store)
        _assert_codes_consistent(idx, store)
    _assert_live_set_searchable(idx, store)


@pytest.mark.parametrize("backend_name", ["single", "mesh"])
@settings(max_examples=10, deadline=None)
@given(ops=ops_strategy, seed=st.integers(0, 2 ** 16))
def test_deferred_churn_matches_eager_reports(backend_name, ops, seed):
    """Deferred mode must emit byte-identical reports to eager mode for the
    same op sequence (including failed batches on the tiny pool), with the
    state evolving identically."""
    eager = sivf.Index(CFG_TINY, _CENTS, backend=_backend(backend_name),
                       min_bucket=8)
    deferred = sivf.Index(CFG_TINY, _CENTS, backend=_backend(backend_name),
                          min_bucket=8, deferred=True)
    rng = np.random.default_rng(seed)
    eager_reps, futs = [], []
    for kind, ids in ops:
        ids = np.asarray(ids, np.int32)
        if kind != "add" and kind != "remove":
            continue
        if kind == "add":
            vecs = rng.normal(size=(len(ids), D)).astype(np.float32)
            eager_reps.append(eager.add(vecs, ids))
            futs.append(deferred.add(vecs, ids))
        else:
            eager_reps.append(eager.remove(ids))
            futs.append(deferred.remove(ids))
        assert not futs[-1].done
    deferred_reps = deferred.flush()
    assert deferred_reps == [f.result() for f in futs]
    for er, dr in zip(eager_reps, deferred_reps):
        assert er == dr, (er, dr)
    assert eager.n_live == deferred.n_live


# ---------------------------------------------------------------------------
# Filtered churn (ISSUE 7): predicate masks must track the live set
# ---------------------------------------------------------------------------

CFG_ATTR = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=48, capacity=32,
                           n_max=256, max_chain=12,
                           attributes=("tenant", "ts"))

# random predicates over small attribute domains so selectivity spans
# empty -> everything (Range bounds may invert: empty matches are legal)
pred_strategy = st.one_of(
    st.builds(sivf.Eq, st.just("tenant"), st.integers(0, 3)),
    st.builds(sivf.In, st.just("tenant"),
              st.lists(st.integers(0, 3), min_size=1, max_size=3)
              .map(tuple)),
    st.builds(sivf.Range, st.just("ts"), st.integers(0, 8),
              st.integers(0, 8)),
    st.builds(lambda a, b: sivf.And(a, b),
              st.builds(sivf.Eq, st.just("tenant"), st.integers(0, 3)),
              st.builds(sivf.Range, st.just("ts"), st.integers(0, 8),
                        st.integers(0, 8))),
)


def _check_filtered_live_set(idx, store, pred, rng, q=2):
    """Full-probe filtered search with k >= n_matching returns exactly the
    ids whose CURRENT attribute row satisfies the predicate (the dict
    oracle) — overwritten rows count under their latest stamps, removed
    rows never."""
    matching = {i for i, (_, a) in store.items()
                if flt.host_matches(pred, CFG_ATTR.attributes, a)}
    k = max(len(matching), 1)
    qs = rng.normal(size=(q, D)).astype(np.float32)
    _, lab = idx.search(qs, k, NL, filter=pred)
    for row in np.asarray(lab):
        assert set(row[row >= 0].tolist()) == matching


@pytest.mark.parametrize("backend_name", ["single", "mesh"])
@settings(max_examples=15, deadline=None)
@given(ops=ops_strategy, pred=pred_strategy, seed=st.integers(0, 2 ** 16))
def test_filtered_churn_matches_oracle(backend_name, ops, pred, seed):
    """Hypothesis churn with random attribute stamps and a random
    predicate, on both backends: at every search point the filtered
    reachable set equals the dict oracle's within-predicate live set."""
    idx = sivf.Index(CFG_ATTR, _CENTS, backend=_backend(backend_name),
                     min_bucket=8)
    rng = np.random.default_rng(seed)
    store: dict[int, tuple[np.ndarray, np.ndarray]] = {}
    for kind, ids in ops:
        ids = np.asarray(ids, np.int32)
        if kind == "add":
            vecs = rng.normal(size=(len(ids), D)).astype(np.float32)
            attrs = np.stack([rng.integers(0, 4, len(ids)),
                              rng.integers(0, 9, len(ids))],
                             axis=1).astype(np.int32)
            rep = idx.add(vecs, ids, attrs=attrs)
            assert rep.accepted + rep.overwritten + rep.rejected \
                == rep.requested == len(ids)
            se = rep.shard_errors
            last = {int(i): (v, a) for i, v, a in zip(ids, vecs, attrs)}
            for i, va in last.items():               # batch: last wins
                bits = rep.errors if se is None else se[i % len(se)]
                if not bits & _ABORT:
                    store[i] = va
        elif kind == "remove":
            idx.remove(ids)
            for i in set(ids.tolist()):
                store.pop(int(i), None)
        elif kind in _MAINT_KINDS:
            # attribute planes ride the re-insert verbatim: filtered
            # reachability is layout-invariant under maintenance
            _run_maint(idx, kind, ids)
        else:
            _check_filtered_live_set(idx, store, pred, rng)
        assert idx.n_live == len(store)
    _check_filtered_live_set(idx, store, pred, rng)
