"""Hypothesis churn tests for the `sivf.Index` session handle.

Randomized interleaved add / remove / search (ragged batch sizes, id
overwrites, pool exhaustion) against the brute-force dict oracle, on both
the single-device and the shard-mapped mesh backend. The linearizability
argument is the same as ``test_core_property``, lifted to the handle: any
op sequence observed through ``search`` must match the dict model, and
every ``MutationReport`` must account for its batch exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev extra; tier-1 stays green without it
from hypothesis import given, settings, strategies as st

import sivf
from repro import core

D, NL = 8, 4
CFG = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=48, capacity=32,
                      n_max=256, max_chain=12)
# tiny pool: 3 slabs over 4 lists, chain bound 2 — batches routinely hit
# POOL_EXHAUSTED / CHAIN_OVERFLOW so the failure semantics get exercised
CFG_TINY = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=3, capacity=32,
                           n_max=256, max_chain=2)
_CENTS = np.random.default_rng(42).normal(size=(NL, D)).astype(np.float32)

_MESH = None


def _backend(name):
    global _MESH
    if name == "single":
        return "single"
    if _MESH is None:
        _MESH = jax.make_mesh((1,), ("data",))
    return _MESH


def _oracle_add(ref, vecs, ids, rep, cfg):
    """Dict-model update honouring the documented failure semantics: a
    batch rejected by POOL_EXHAUSTED / CHAIN_OVERFLOW inserts nothing, but
    ids it was overwriting lose their old payload (delete-then-insert)."""
    if rep.errors & (sivf.ErrorCode.POOL_EXHAUSTED
                     | sivf.ErrorCode.CHAIN_OVERFLOW):
        for i in ids:
            ref.store.pop(int(i), None)
    else:
        for v, i in zip(vecs, ids):
            if 0 <= int(i) < cfg.n_max:
                ref.store[int(i)] = v.copy()


def _check_search(idx, ref, rng, q=3, k=4):
    qs = rng.normal(size=(q, D)).astype(np.float32)
    d, l = idx.search(qs, k, NL)
    rd, rl = ref.search(qs, k, NL)
    np.testing.assert_allclose(np.asarray(d), rd, rtol=1e-4, atol=1e-4)
    assert (np.asarray(l) == rl).all()


ops_strategy = st.lists(
    st.tuples(
        st.sampled_from(["add", "remove", "search"]),
        st.lists(st.integers(0, 63), min_size=1, max_size=14),
    ),
    min_size=1, max_size=10,
)


def _drive(idx, ref, cfg, ops, seed):
    rng = np.random.default_rng(seed)
    for kind, ids in ops:
        ids = np.asarray(ids, np.int32)
        if kind == "add":
            vecs = rng.normal(size=(len(ids), D)).astype(np.float32)
            rep = idx.add(vecs, ids)
            _oracle_add(ref, vecs, ids, rep, cfg)
            # the disjoint counts always account for the whole batch
            assert rep.accepted + rep.overwritten + rep.rejected \
                == rep.requested == len(ids)
        elif kind == "remove":
            before = len(set(ids.tolist()) & set(ref.store))
            rep = idx.remove(ids)
            ref.delete(ids)
            assert rep.accepted == before
        else:
            _check_search(idx, ref, rng, q=1 + len(ids) % 5)
        assert idx.n_live == ref.n_live
    _check_search(idx, ref, rng)


@pytest.mark.parametrize("backend_name", ["single", "mesh"])
@settings(max_examples=25, deadline=None)
@given(ops=ops_strategy, seed=st.integers(0, 2 ** 16))
def test_handle_churn_matches_reference(backend_name, ops, seed):
    idx = sivf.Index(CFG, _CENTS, backend=_backend(backend_name),
                     min_bucket=8)
    ref = core.ReferenceIndex(_CENTS)
    _drive(idx, ref, CFG, ops, seed)
    # structural invariants still hold under the handle
    state = idx.state
    free_top = np.asarray(state.free_top).reshape(-1)
    owner = np.asarray(state.owner).reshape(-1, CFG.n_slabs)
    assert int(free_top.sum()) + int((owner >= 0).sum()) \
        == CFG.n_slabs * len(free_top)


@pytest.mark.parametrize("backend_name", ["single", "mesh"])
@settings(max_examples=20, deadline=None)
@given(ops=ops_strategy, seed=st.integers(0, 2 ** 16))
def test_handle_churn_under_pool_exhaustion(backend_name, ops, seed):
    """Same sequences on a pool small enough that batches routinely fail:
    reports must stay truthful and the oracle must track the documented
    reject-atomically-but-drop-overwrites semantics."""
    idx = sivf.Index(CFG_TINY, _CENTS, backend=_backend(backend_name),
                     min_bucket=8)
    ref = core.ReferenceIndex(_CENTS)
    _drive(idx, ref, CFG_TINY, ops, seed)
