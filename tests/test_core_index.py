"""SIVF core behaviour vs the reference model (paper §3 semantics)."""
import jax.numpy as jnp
import numpy as np

from repro import core

D, NL = 16, 8


def make(rng, capacity=32, n_slabs=64, metric="l2", max_chain=16):
    cfg = core.SIVFConfig(dim=D, n_lists=NL, n_slabs=n_slabs,
                          capacity=capacity, n_max=4096, metric=metric,
                          max_chain=max_chain)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    return cfg, core.init_state(cfg, jnp.asarray(cents)), \
        core.ReferenceIndex(cents, metric)


def insert(cfg, state, ref, rng, ids):
    vecs = rng.normal(size=(len(ids), D)).astype(np.float32)
    state = core.insert(cfg, state, jnp.asarray(vecs),
                        jnp.asarray(ids, np.int32))
    ref.insert(vecs, ids)
    return state


def check_search(cfg, state, ref, rng, k=5, nprobe=NL, q=6):
    qs = rng.normal(size=(q, D)).astype(np.float32)
    d, lab = core.search(cfg, state, jnp.asarray(qs), k, nprobe)
    rd, rl = ref.search(qs, k, nprobe)
    np.testing.assert_allclose(np.asarray(d), rd, rtol=1e-4, atol=1e-4)
    assert (np.asarray(lab) == rl).all()


def test_insert_search_exact(rng):
    cfg, state, ref = make(rng)
    state = insert(cfg, state, ref, rng, np.arange(200))
    assert int(state.n_live) == ref.n_live == 200
    assert int(state.error) == 0
    check_search(cfg, state, ref, rng)


def test_delete_matches_reference(rng):
    cfg, state, ref = make(rng)
    state = insert(cfg, state, ref, rng, np.arange(200))
    dels = np.arange(0, 200, 3)
    state = core.delete(cfg, state, jnp.asarray(dels, np.int32))
    ref.delete(dels)
    assert int(state.n_live) == ref.n_live
    check_search(cfg, state, ref, rng)


def test_delete_idempotent(rng):
    cfg, state, ref = make(rng)
    state = insert(cfg, state, ref, rng, np.arange(50))
    ids = np.array([1, 1, 2, 2, 2, 999], np.int32)   # dupes + absent
    state = core.delete(cfg, state, jnp.asarray(ids))
    state = core.delete(cfg, state, jnp.asarray(ids))  # repeat: no-op
    ref.delete(ids)
    assert int(state.n_live) == ref.n_live == 48


def test_overwrite_delete_then_insert(rng):
    """Paper Data Model: re-inserting an id replaces its payload."""
    cfg, state, ref = make(rng)
    state = insert(cfg, state, ref, rng, np.arange(64))
    state = insert(cfg, state, ref, rng, np.arange(10))   # overwrite 0..9
    assert int(state.n_live) == ref.n_live == 64
    check_search(cfg, state, ref, rng)


def test_within_batch_duplicates_keep_last(rng):
    cfg, state, ref = make(rng)
    vecs = rng.normal(size=(4, D)).astype(np.float32)
    ids = np.array([7, 7, 7, 8], np.int32)
    state = core.insert(cfg, state, jnp.asarray(vecs), jnp.asarray(ids))
    ref.insert(vecs, ids)   # dict semantics: last wins
    assert int(state.n_live) == ref.n_live == 2
    check_search(cfg, state, ref, rng, k=2)


def test_full_delete_recycles_all_slabs(rng):
    cfg, state, ref = make(rng)
    state = insert(cfg, state, ref, rng, np.arange(300))
    state = core.delete(cfg, state, jnp.asarray(np.arange(300), np.int32))
    st = core.stats(cfg, state)
    assert st["n_live"] == 0
    assert st["free_slabs"] == cfg.n_slabs      # instant reclamation
    assert st["error"] == 0
    # pool reusable after full churn
    ref.delete(np.arange(300))
    state = insert(cfg, state, ref, rng, np.arange(300))
    assert int(state.error) == 0
    check_search(cfg, state, ref, rng)


def test_pool_exhaustion_fails_fast(rng):
    cfg, state, ref = make(rng, n_slabs=8, max_chain=8)
    n = cfg.n_slabs * cfg.capacity + 1
    vecs = rng.normal(size=(n, D)).astype(np.float32)
    state = core.insert(cfg, state, jnp.asarray(vecs),
                        jnp.asarray(np.arange(n), np.int32))
    assert int(state.error) & core.ERR_POOL_EXHAUSTED
    assert int(state.n_live) == 0               # batch rejected atomically


def test_id_out_of_range_flagged(rng):
    cfg, state, ref = make(rng)
    vecs = rng.normal(size=(2, D)).astype(np.float32)
    state = core.insert(cfg, state, jnp.asarray(vecs),
                        jnp.asarray([1, cfg.n_max + 5], np.int32))
    assert int(state.error) & core.ERR_ID_RANGE
    assert int(state.n_live) == 1


def test_pointer_walk_equals_table_path(rng):
    cfg, state, ref = make(rng)
    state = insert(cfg, state, ref, rng, np.arange(150))
    state = core.delete(cfg, state, jnp.asarray(np.arange(0, 150, 2),
                                                np.int32))
    qs = rng.normal(size=(4, D)).astype(np.float32)
    d1, l1 = core.search(cfg, state, jnp.asarray(qs), 5, NL, use_tables=True)
    d2, l2 = core.search(cfg, state, jnp.asarray(qs), 5, NL,
                         use_tables=False)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)
    assert (np.asarray(l1) == np.asarray(l2)).all()


def test_nprobe_subset(rng):
    cfg, state, ref = make(rng)
    state = insert(cfg, state, ref, rng, np.arange(256))
    for nprobe in (1, 2, 4):
        qs = rng.normal(size=(5, D)).astype(np.float32)
        d, lab = core.search(cfg, state, jnp.asarray(qs), 4, nprobe)
        rd, rl = ref.search(qs, 4, nprobe)
        np.testing.assert_allclose(np.asarray(d), rd, rtol=1e-4, atol=1e-4)
        assert (np.asarray(lab) == rl).all()


def test_ip_metric(rng):
    cfg, state, ref = make(rng, metric="ip")
    state = insert(cfg, state, ref, rng, np.arange(100))
    check_search(cfg, state, ref, rng)


def test_capacity_128_lane_width(rng):
    """TPU-default slab capacity (C = lane width)."""
    cfg, state, ref = make(rng, capacity=128, n_slabs=16)
    state = insert(cfg, state, ref, rng, np.arange(300))
    check_search(cfg, state, ref, rng)


def test_bitmap_live_invariant(rng):
    """live counters == popcount(bitmap) for every slab."""
    from repro.core import bitmap as bm
    cfg, state, ref = make(rng)
    state = insert(cfg, state, ref, rng, np.arange(200))
    state = core.delete(cfg, state,
                        jnp.asarray(np.arange(0, 200, 5), np.int32))
    pop = bm.popcount_rows(state.bitmap)
    assert (np.asarray(pop) == np.asarray(state.live)).all()
    assert int(jnp.sum(pop)) == int(state.n_live)


def test_memory_overhead_below_one_percent():
    """Paper §5.6.2: metadata overhead < 1% for SIFT-like payloads."""
    cfg = core.SIVFConfig(dim=128, n_lists=1024, n_slabs=8192, capacity=128,
                          n_max=1 << 20)
    rep = core.memory_report(cfg)
    assert rep["overhead_frac_vs_payload"] < 0.08
    # GIST-like high dim: well under 1%
    cfg = core.SIVFConfig(dim=960, n_lists=1024, n_slabs=8192, capacity=128,
                          n_max=1 << 20)
    rep = core.memory_report(cfg)
    assert rep["overhead_frac_vs_payload"] < 0.01
