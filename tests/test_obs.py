"""Observability layer (ISSUE 9): metrics, spans, exporters, wiring.

Three layers under test:

  * the zero-dependency metric primitives (``repro.obs.metrics``) —
    counter/gauge/histogram semantics, windowed reads, label handling;
  * span tracing (``repro.obs.trace``) — nesting, stage attribution to
    the innermost root, the slow-query log, the disabled fast path
    (all with an injected fake clock, so durations are exact);
  * the instrumented product paths — a tiered ``Index`` and a
    ``ServeEngine`` run a real mixed workload and the resulting snapshot
    must agree with the ground-truth counters the code already exposes
    (``stats()``, ``compile_events()``), and the Prometheus text render
    must round-trip the same values as the JSON snapshot.
"""
import json
import math

import numpy as np
import pytest

import sivf
from repro.obs import (BUCKETS_S, MetricsRegistry, Telemetry,
                       WindowedCounter, latency_summary_ms,
                       parse_prometheus, percentiles, render_prometheus,
                       snapshot_json)
from repro.obs.trace import _NOOP

# ---------------------------------------------------------------------------
# metric primitives
# ---------------------------------------------------------------------------


def test_counter_cumulative_and_window():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests", ("tenant",))
    c.inc(tenant="a")
    c.inc(4, tenant="a")
    c.inc(2, tenant="b")
    assert c.get(tenant="a") == 5 and c.get(tenant="b") == 2
    assert c.get_window(tenant="a") == 5
    reg.roll_window()
    assert c.get_window(tenant="a") == 0      # window reset...
    assert c.get(tenant="a") == 5             # ...cumulative untouched
    c.inc(3, tenant="a")
    assert c.get_window(tenant="a") == 3 and c.get(tenant="a") == 8


def test_counter_rejects_negative():
    c = MetricsRegistry().counter("n")
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1)


def test_label_validation():
    c = MetricsRegistry().counter("n", labels=("tenant",))
    with pytest.raises(ValueError, match="labels"):
        c.inc(shard="0")                      # wrong label name
    with pytest.raises(ValueError, match="labels"):
        c.inc()                               # missing label


def test_reregistration_idempotent_and_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("n", "h", ("x",))
    assert reg.counter("n", "h", ("x",)) is a     # same declaration: reuse
    with pytest.raises(ValueError, match="re-registered"):
        reg.counter("n", "h", ("y",))             # label mismatch
    with pytest.raises(ValueError, match="re-registered"):
        reg.gauge("n")                            # kind mismatch


def test_gauge_last_write_wins():
    g = MetricsRegistry().gauge("depth")
    g.set(3)
    g.set(7)
    assert g.get() == 7.0


def test_histogram_buckets_and_percentile_estimate():
    reg = MetricsRegistry()
    h = reg.histogram("lat", labels=("stage",))
    assert h.buckets == BUCKETS_S
    # bucket bounds are inclusive upper bounds (bisect_left: first >= v)
    h.observe(1e-6, stage="s")                # lands in bucket 0
    h.observe(3e-6, stage="s")                # first bound >= 3us is 4us
    h.observe(1e9, stage="s")                 # beyond the last bound: +inf
    d = h.get(stage="s")
    assert d["count"] == 3 and d["counts"][0] == 1 and d["counts"][-1] == 1
    assert d["counts"][2] == 1                # 1,2,4us -> index 2
    assert h.percentile(50.0, stage="s") == BUCKETS_S[2]
    assert h.percentile(99.0, stage="s") == math.inf
    assert h.percentile(50.0, stage="empty") == 0.0


def test_windowed_counter_and_carry():
    a = WindowedCounter()
    a.add(5)
    a.mark()
    a.add(2)
    assert a.total == 7 and a.window == 2
    b = WindowedCounter().carry(a)            # reshard-style adoption
    assert b.total == 7 and b.window == 2
    b.add(1)
    assert b.total == 8 and b.window == 3 and a.total == 7


def test_percentiles_and_latency_summary():
    assert percentiles([], (50.0, 99.0)) == {50.0: 0.0, 99.0: 0.0}
    p = percentiles(range(1, 101), (50.0, 99.0))
    assert p[50.0] == pytest.approx(50.5) and p[99.0] == pytest.approx(99.01)
    s = latency_summary_ms([0.001] * 10)
    assert s == {"p50_ms": 1.0, "p99_ms": 1.0, "p999_ms": 1.0}
    # the helper IS np.percentile (shared definition with the benchmarks)
    a = np.random.default_rng(0).uniform(size=97)
    assert percentiles(a, (99.0,))[99.0] == float(np.percentile(a, 99.0))


# ---------------------------------------------------------------------------
# span tracing (fake clock: exact durations)
# ---------------------------------------------------------------------------


def _fake_clock():
    t = [0.0]

    def clock():
        return t[0]
    return t, clock


def test_span_nesting_attributes_stages_to_root():
    t, clock = _fake_clock()
    tel = Telemetry(enabled=True, slow_threshold_s=0.0, clock=clock)
    with tel.span("serve.tile", root=True, tenant="a", epoch=3):
        t[0] += 0.010                         # un-attributed root time
        with tel.span("plan"):
            t[0] += 0.002
        with tel.span("scan"):
            t[0] += 0.005
    (entry,) = tel.slow_queries()             # threshold 0: every root logs
    assert entry["span"] == "serve.tile"
    assert entry["duration_ms"] == pytest.approx(17.0)
    assert entry["stages_ms"] == {"plan": 2.0, "scan": 5.0}
    assert entry["tenant"] == "a" and entry["epoch"] == 3
    hist = tel.histogram("sivf_stage_seconds", labels=("stage",))
    assert hist.get(stage="plan")["sum"] == pytest.approx(0.002)
    assert hist.get(stage="serve.tile")["count"] == 1
    assert tel.counter("sivf_slow_queries_total").get() == 1


def test_root_auto_depends_on_enclosing_root():
    t, clock = _fake_clock()
    tel = Telemetry(enabled=True, slow_threshold_s=0.0, clock=clock)
    with tel.span("index.search", root="auto"):   # no enclosing root
        t[0] += 0.001
    assert tel.slow_queries()[0]["span"] == "index.search"
    tel.clear_slow_log()
    with tel.span("serve.tile", root=True):
        with tel.span("index.search", root="auto"):   # under a tile: stage
            t[0] += 0.001
        t[0] += 0.001
    (entry,) = tel.slow_queries()
    assert entry["span"] == "serve.tile"
    assert "index.search" in entry["stages_ms"]


def test_open_span_exit_scope_finish_lifecycle():
    t, clock = _fake_clock()
    tel = Telemetry(enabled=True, slow_threshold_s=0.0, clock=clock)
    sp = tel.open_span("serve.tile", root=True, rows=4)
    with tel.span("plan"):
        t[0] += 0.002
    tel.exit_scope(sp)                        # dispatch done; tile still runs
    with tel.span("prefetch"):                # next tile's work: NOT attributed
        t[0] += 0.004
    t[0] += 0.001
    tel.finish_span(sp)                       # result resolved
    (entry,) = [e for e in tel.slow_queries() if e["span"] == "serve.tile"]
    assert entry["duration_ms"] == pytest.approx(7.0)
    assert entry["stages_ms"] == {"plan": 2.0}    # prefetch was out of scope


def test_disabled_fast_path_records_nothing():
    tel = Telemetry(enabled=False)
    assert tel.span("x", root=True) is _NOOP      # shared no-op instance
    assert tel.open_span("x") is None
    tel.exit_scope(None)
    tel.finish_span(None)                         # all None-safe
    tel.record_duration("x", 1.0)
    with tel.span("x", root=True):
        pass
    assert tel.slow_queries() == []
    assert tel.histogram("sivf_stage_seconds",
                         labels=("stage",)).items() == []


def test_slow_log_keeps_n_slowest():
    t, clock = _fake_clock()
    tel = Telemetry(enabled=True, slow_threshold_s=0.0, slow_log_size=2,
                    clock=clock)
    for ms in (5, 1, 9, 3):
        with tel.span("op", root=True):
            t[0] += ms / 1e3
    got = [e["duration_ms"] for e in tel.slow_queries()]
    assert got == [9.0, 5.0]
    tel.clear_slow_log()
    assert tel.slow_queries() == []


def test_record_duration_and_traced_decorator():
    t, clock = _fake_clock()
    tel = Telemetry(enabled=True, slow_threshold_s=0.0, clock=clock)

    @tel.traced("queue_drain", root=True)
    def work():
        t[0] += 0.004
        tel.record_duration("serve.queue", 0.003)

    work()
    (entry,) = tel.slow_queries()
    assert entry["stages_ms"] == {"serve.queue": 3.0}
    h = tel.histogram("sivf_stage_seconds", labels=("stage",))
    assert h.get(stage="serve.queue")["sum"] == pytest.approx(0.003)


# ---------------------------------------------------------------------------
# exporters: Prometheus <-> JSON round trip
# ---------------------------------------------------------------------------


def test_prometheus_round_trips_snapshot_values():
    tel = Telemetry(enabled=True)
    c = tel.counter("sivf_serve_requests_total", "reqs", ("tenant", "op"))
    c.inc(6, tenant="appA", op="search")
    c.inc(2, tenant="ingest", op="add")
    tel.roll_window()
    c.inc(1, tenant="appA", op="search")
    tel.gauge("sivf_serve_queue_depth", "depth").set(4)
    h = tel.histogram("sivf_stage_seconds", "stage secs", ("stage",))
    h.observe(3e-6, stage="plan")
    h.observe(5e-3, stage="plan")

    series = parse_prometheus(render_prometheus(tel))
    assert series['sivf_serve_requests_total{tenant="appA",op="search"}'] == 7
    assert series['sivf_serve_requests_total_window'
                  '{tenant="appA",op="search"}'] == 1
    assert series["sivf_serve_queue_depth"] == 4
    assert series['sivf_stage_seconds_count{stage="plan"}'] == 2
    assert series['sivf_stage_seconds_bucket{stage="plan",le="+Inf"}'] == 2
    # cumulative le buckets: monotone, ending at count
    le_keys = [k for k in series
               if k.startswith('sivf_stage_seconds_bucket{stage="plan"')]
    vals = [series[k] for k in le_keys]
    assert vals == sorted(vals)

    snap = json.loads(snapshot_json(tel))
    req = snap["metrics"]["sivf_serve_requests_total"]["series"]
    by_tenant = {(s["labels"]["tenant"], s["labels"]["op"]): s for s in req}
    assert by_tenant[("appA", "search")]["total"] == 7
    assert by_tenant[("appA", "search")]["window"] == 1
    plan = [s for s in snap["metrics"]["sivf_stage_seconds"]["series"]
            if s["labels"]["stage"] == "plan"][0]
    assert plan["count"] == 2
    assert plan["sum"] == pytest.approx(5e-3 + 3e-6)
    # every snapshot value appears identically in the text exposition
    assert series['sivf_stage_seconds_sum{stage="plan"}'] == \
        pytest.approx(plan["sum"])


# ---------------------------------------------------------------------------
# instrumented product paths (real Index / ServeEngine workloads)
# ---------------------------------------------------------------------------

D, NL = 16, 8


def _tiered_index(rng, tel, n_slabs, device_slabs=24, **kw):
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=n_slabs, capacity=32,
                          n_max=4096, device_slabs=device_slabs)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    return sivf.Index(cfg, cents, telemetry=tel, **kw)


def test_index_spans_cache_events_and_compile_counter(rng):
    tel = Telemetry(enabled=True, slow_threshold_s=0.0)
    idx = _tiered_index(rng, tel, n_slabs=93)
    vecs = rng.normal(size=(400, D)).astype(np.float32)
    idx.add(vecs, np.arange(400, dtype=np.int32))
    qs = rng.normal(size=(4, D)).astype(np.float32)
    idx.search(qs, k=5, nprobe=4)
    idx.search(qs, k=5, nprobe=4)             # second pass: warm hits

    snap = idx.telemetry()
    stages = {s["labels"]["stage"]
              for s in snap["metrics"]["sivf_stage_seconds"]["series"]}
    assert {"plan", "prefetch", "scan", "index.search",
            "mutation.dispatch"} <= stages

    # cache-event counters must equal the stats() ground truth
    st = idx.stats()
    ev = tel.counter("sivf_tiered_cache_events_total", labels=("event",))
    assert ev.get(event="hit") == st["cache_hits"] > 0
    assert ev.get(event="miss") == st["cache_misses"] > 0
    assert ev.get(event="upload") == st["cache_uploads"] > 0
    tb = tel.counter("sivf_transfer_bytes_total",
                     labels=("direction", "stage"))
    assert tb.get(direction="h2d", stage="prefetch") > 0

    # compile-event counter == the handle's observed executable delta
    assert idx.compile_events() > 0
    assert tel.counter("sivf_jit_compile_events_total").get() == \
        idx.compile_events()
    assert tel.counter("sivf_index_mutation_rows_total",
                       labels=("op",)).get(op="add") == 400

    # a root span (the direct index.search) landed in the slow log with
    # its stage breakdown
    entries = [e for e in tel.slow_queries() if e["span"] == "index.search"]
    assert entries and {"plan", "prefetch", "scan"} <= \
        set(entries[0]["stages_ms"])


def test_serve_engine_mixed_workload_snapshot(rng):
    from sivf import Backpressure, ServeEngine, TenantQuota
    tel = Telemetry(enabled=True, slow_threshold_s=0.0)
    idx = _tiered_index(rng, tel, n_slabs=95, deferred=True, min_bucket=16)
    eng = ServeEngine(idx, default_k=5, default_nprobe=4,
                      quotas={"appA": TenantQuota(max_inflight_searches=2),
                              "ingest": TenantQuota()})
    with eng:
        writer, reader = eng.session("ingest"), eng.session("appA")
        ids = np.arange(128, dtype=np.int32)
        writer.add(rng.normal(size=(128, D)).astype(np.float32),
                   ids).result(60)
        # sequential: the appA quota caps *concurrent* searches at 2
        for _ in range(3):
            reader.search(
                rng.normal(size=(2, D)).astype(np.float32)).result(60)
        # provoke a typed rejection so the backpressure counter moves
        eng.pause()
        held = [reader.search(rng.normal(size=(1, D)).astype(np.float32))
                for _ in range(2)]
        with pytest.raises(Backpressure):
            reader.search(rng.normal(size=(1, D)).astype(np.float32))
        eng.resume()
        for f in held:
            f.result(60)
        snap = eng.telemetry()
        prom = eng.render_prometheus()

    req = tel.counter("sivf_serve_requests_total", labels=("tenant", "op"))
    assert req.get(tenant="appA", op="search") == 5
    assert req.get(tenant="ingest", op="add") == 1
    rows = tel.counter("sivf_serve_rows_total", labels=("tenant", "op"))
    assert rows.get(tenant="ingest", op="add") == 128
    assert rows.get(tenant="appA", op="search") == 3 * 2 + 2
    bp = tel.counter("sivf_serve_backpressure_total",
                     labels=("tenant", "kind"))
    assert bp.get(tenant="appA", kind="search_inflight") == 1

    stages = {s["labels"]["stage"]
              for s in snap["metrics"]["sivf_stage_seconds"]["series"]}
    assert {"serve.tile", "serve.queue", "serve.mutation_queue",
            "index.search", "plan", "prefetch", "scan"} <= stages
    tiles = [e for e in tel.slow_queries() if e["span"] == "serve.tile"]
    assert tiles and "index.search" in tiles[0]["stages_ms"]
    assert "tenant" in tiles[0] and "epoch" in tiles[0]

    # Prometheus text agrees with the JSON snapshot series-by-series
    series = parse_prometheus(prom)
    assert series['sivf_serve_requests_total{tenant="appA",op="search"}'] \
        == 5
    assert series["sivf_serve_epoch"] == \
        snap["metrics"]["sivf_serve_epoch"]["series"][0]["value"]
    # compile-event counter equals the engine's observed executable delta
    assert tel.counter("sivf_jit_compile_events_total").get() == \
        idx.compile_events() > 0


def test_telemetry_disabled_by_default_and_module_facade(rng):
    import repro.obs as obs
    from sivf import telemetry as sivf_tel
    assert obs.default().enabled is False     # process default: off
    # an Index built without explicit telemetry records nothing
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=91, capacity=32,
                          n_max=4096)
    idx = sivf.Index(cfg, rng.normal(size=(NL, D)).astype(np.float32))
    idx.add(rng.normal(size=(64, D)).astype(np.float32),
            np.arange(64, dtype=np.int32))
    idx.search(rng.normal(size=(2, D)).astype(np.float32), k=5, nprobe=2)
    snap = sivf_tel.snapshot()
    hist = snap["metrics"].get("sivf_stage_seconds")
    assert hist is None or hist["series"] == []
    # the facade exports the same default instance
    assert sivf_tel.get() is obs.default()
