"""Hypothesis property tests: arbitrary op sequences vs the dict model.

The linearizability theorems (paper §3.5) reduce, under JAX value
semantics, to: any interleaving of batched insert / overwrite / delete
observed through search is equivalent to the same sequence applied to a
python dict — searches never surface dead or stale vectors and never miss
live ones.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:                                   # dev extra, pinned in CI; the local
    from hypothesis import given, settings, strategies as st
except ImportError:                    # fallback keeps tier-1 executing
    from _hypothesis_fallback import given, settings, strategies as st

from repro import core

D, NL = 8, 4
CFG = core.SIVFConfig(dim=D, n_lists=NL, n_slabs=48, capacity=32,
                      n_max=256, max_chain=12)
_CENTS = np.random.default_rng(42).normal(size=(NL, D)).astype(np.float32)


def _vec(rng, i):
    return rng.normal(size=(D,)).astype(np.float32)


ops_strategy = st.lists(
    st.tuples(
        st.sampled_from(["insert", "delete",
                         "split", "merge", "recluster"]),
        st.lists(st.integers(0, 63), min_size=1, max_size=12),
    ),
    min_size=1, max_size=10,
)


def _maint_op(kind, ids):
    """Deterministic maintenance op from the drawn id payload."""
    a = int(ids[0]) % NL
    b = (a + 1 + int(ids[-1]) % (NL - 1)) % NL
    if kind == "split":
        return core.split(a, b)
    if kind == "merge":
        return core.merge(a, b)
    return core.recluster(a)


@settings(max_examples=40, deadline=None)
@given(ops=ops_strategy, seed=st.integers(0, 2 ** 16))
def test_op_sequences_match_reference(ops, seed):
    rng = np.random.default_rng(seed)
    state = core.init_state(CFG, jnp.asarray(_CENTS))
    ref = core.ReferenceIndex(_CENTS)
    for kind, ids in ops:
        ids = np.asarray(ids, np.int32)
        if kind == "insert":
            vecs = rng.normal(size=(len(ids), D)).astype(np.float32)
            state = core.insert(CFG, state, jnp.asarray(vecs),
                                jnp.asarray(ids))
            # dict semantics: later batch rows win
            for v, i in zip(vecs, ids):
                ref.store[int(i)] = v
        elif kind == "delete":
            state = core.delete(CFG, state, jnp.asarray(ids))
            ref.delete(ids)
        else:
            # maintenance reshapes the layout but never the live set: the
            # dict oracle is untouched and the final full-probe search
            # plus the structural invariants below must still hold
            state, rep = core.maintain(CFG, state, _maint_op(kind, ids))
            assert rep.committed, rep
            assert rep.n_live == ref.n_live
        assert int(state.error) == 0
        assert int(state.n_live) == ref.n_live

    # full-probe search must agree exactly (ties are measure-zero)
    qs = rng.normal(size=(3, D)).astype(np.float32)
    k = 4
    d, lab = core.search(CFG, state, jnp.asarray(qs), k, NL)
    rd, rl = ref.search(qs, k, NL)
    np.testing.assert_allclose(np.asarray(d), rd, rtol=1e-4, atol=1e-4)
    assert (np.asarray(lab) == rl).all()

    # structural invariants
    from repro.core import bitmap as bm
    pop = np.asarray(bm.popcount_rows(state.bitmap))
    assert (pop == np.asarray(state.live)).all()
    # free stack entries + used slabs account for the whole pool
    used = int(CFG.n_slabs - state.free_top)
    assert used == int(np.sum(np.asarray(state.owner) >= 0))
    # no slab id appears twice in (free stack tail + owned set)
    free = set(np.asarray(state.free_stack)[: int(state.free_top)].tolist())
    owned = set(np.nonzero(np.asarray(state.owner) >= 0)[0].tolist())
    assert not (free & owned)
    assert len(free) == int(state.free_top)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       window=st.integers(8, 32), batch=st.integers(4, 16))
def test_sliding_window_churn(seed, window, batch):
    """Paper §5.5 sliding-window: net live count stays == window size."""
    rng = np.random.default_rng(seed)
    state = core.init_state(CFG, jnp.asarray(_CENTS))
    ref = core.ReferenceIndex(_CENTS)
    next_id = 0
    for step in range(6):
        ids = (np.arange(batch) + next_id) % CFG.n_max
        next_id += batch
        vecs = rng.normal(size=(batch, D)).astype(np.float32)
        state = core.insert(CFG, state, jnp.asarray(vecs),
                            jnp.asarray(ids, np.int32))
        ref.insert(vecs, ids)
        if next_id > window:
            evict = np.arange(next_id - window - batch,
                              next_id - window) % CFG.n_max
            evict = evict[evict < next_id]
            state = core.delete(CFG, state,
                                jnp.asarray(evict, np.int32))
            ref.delete(evict)
        assert int(state.n_live) == ref.n_live
        assert int(state.error) == 0
