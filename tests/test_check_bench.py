"""The bench-regression gate cannot silently no-op (ISSUE 6 satellite).

Same pattern as ``tests/test_docs.py``: the CI slow job *runs*
``scripts/check_bench.py``; tier-1 pins the checker's own behavior —
path lookup, every tolerance-band kind, the injected-regression failure
path, and the "missing field/baseline fails loudly" contract.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "scripts"))
from check_bench import (  # noqa: E402
    BASELINE_DIR,
    METRICS,
    Band,
    check,
    compare_artifact,
    lookup,
)


def test_lookup_traverses_dicts_and_lists():
    doc = {"a": {"b": [{"c": 3.5}, {"c": 4.5}]}, "n": 2}
    assert lookup(doc, "a.b.0.c") == 3.5
    assert lookup(doc, "a.b.1.c") == 4.5
    assert lookup(doc, "n") == 2.0
    with pytest.raises(KeyError):
        lookup(doc, "a.missing")
    with pytest.raises(IndexError):
        lookup(doc, "a.b.9.c")
    with pytest.raises(TypeError):
        lookup(doc, "a")            # non-numeric leaf
    with pytest.raises(TypeError):
        lookup({"x": True}, "x")    # bools are not metrics


def test_band_kinds():
    assert Band("p", "ratio_max", 1.5).check(100, 149)
    assert not Band("p", "ratio_max", 1.5).check(100, 151)
    assert Band("p", "ratio_min", 2.0).check(100, 51)
    assert not Band("p", "ratio_min", 2.0).check(100, 49)
    assert Band("p", "abs_min", 0.02).check(1.0, 0.985)
    assert not Band("p", "abs_min", 0.02).check(1.0, 0.97)
    assert Band("p", "exact_max").check(5, 5)
    assert not Band("p", "exact_max").check(5, 6)
    with pytest.raises(ValueError):
        Band("p", "nope").check(1, 1)


def test_injected_regression_fails_and_prints_table():
    base = {"p99": {"search": 100.0}, "jit": {"search": 3}}
    good = {"p99": {"search": 120.0}, "jit": {"search": 3}}
    bad = {"p99": {"search": 100.0}, "jit": {"search": 4}}
    bands = [Band("p99.search", "ratio_max", 1.5),
             Band("jit.search", "exact_max")]
    rows, fails = compare_artifact("X.json", good, base, bands)
    assert not fails and len(rows) == 2
    assert all("ok" in r for r in rows)
    rows, fails = compare_artifact("X.json", bad, base, bands)
    assert len(fails) == 1 and "jit.search" in fails[0]
    assert any("REGRESSION" in r for r in rows)


def test_fresh_artifact_missing_metric_fails():
    """A renamed/dropped field must fail the gate, not skip it."""
    base = {"p99": 10.0}
    fresh = {"p99_renamed": 10.0}
    _, fails = compare_artifact("X.json", fresh, base,
                                [Band("p99", "ratio_max", 2.0)])
    assert len(fails) == 1 and "missing p99" in fails[0]


def test_optional_band_skips_only_on_missing_baseline():
    bands = [Band("new_metric", "ratio_max", 2.0, optional=True)]
    rows, fails = compare_artifact("X.json", {"new_metric": 5}, {}, bands)
    assert not fails and "skipped" in rows[0]
    # present in baseline but absent from fresh: still a failure
    _, fails = compare_artifact("X.json", {}, {"new_metric": 5}, bands)
    assert len(fails) == 1


def test_check_end_to_end_with_temp_baselines(tmp_path, capsys):
    baselines = tmp_path / "baselines"
    baselines.mkdir()
    name = "BENCH_streaming_churn.json"
    doc = {"eager": {"p50_us": {"add": 100.0, "search": 50.0},
                     "jit_compiles": {"add": 5, "search": 1}},
           "deferred": {"p50_us": {"add": 10.0},
                        "p99_us": {"add": 20.0, "flush": 2.0},
                        "jit_compiles": {"add": 5, "search": 1}}}
    (baselines / name).write_text(json.dumps(doc))
    fresh = tmp_path / name
    fresh.write_text(json.dumps(doc))
    assert check([fresh], baselines) == 0
    assert "bench OK" in capsys.readouterr().out
    # inject a 10x p99 regression
    doc["deferred"]["p99_us"]["add"] = 200.0
    fresh.write_text(json.dumps(doc))
    assert check([fresh], baselines) == 1
    out = capsys.readouterr().out
    assert "REGRESSION" in out and "deferred.p99_us.add" in out


def test_missing_baseline_and_unregistered_artifact_fail(tmp_path, capsys):
    fresh = tmp_path / "BENCH_pq.json"
    fresh.write_text("{}")
    assert check([fresh], tmp_path / "nowhere") == 1
    assert "no committed baseline" in capsys.readouterr().out
    rogue = tmp_path / "BENCH_rogue.json"
    rogue.write_text("{}")
    assert check([rogue], tmp_path) == 1
    assert "no metric bands registered" in capsys.readouterr().out


def test_committed_baselines_cover_every_registered_artifact():
    """The real gate has a baseline for all four artifacts, and every
    non-optional band resolves against it — so the CI invocation can
    never silently check nothing."""
    for name, bands in METRICS.items():
        path = BASELINE_DIR / name
        assert path.exists(), f"missing committed baseline {path}"
        doc = json.loads(path.read_text())
        for band in bands:
            if band.optional:
                continue
            lookup(doc, band.path)      # raises if the baseline drifted


def test_cli_exit_codes(tmp_path):
    """The script entrypoint (what CI runs) propagates failures."""
    name = "BENCH_pq.json"
    baselines = tmp_path / "b"
    baselines.mkdir()
    doc = {"recall_at_10": 1.0, "reduction": {"16": 5.3, "256": 5.3},
           "qps": {"pq": {"64": 500.0}}, "bytes_per_vector": {"pq": 8}}
    (baselines / name).write_text(json.dumps(doc))
    fresh = tmp_path / name
    fresh.write_text(json.dumps(doc))
    cmd = [sys.executable, str(REPO / "scripts" / "check_bench.py"),
           str(fresh), "--baseline-dir", str(baselines)]
    assert subprocess.run(cmd, capture_output=True).returncode == 0
    doc["recall_at_10"] = 0.5           # injected recall regression
    fresh.write_text(json.dumps(doc))
    r = subprocess.run(cmd, capture_output=True, text=True)
    assert r.returncode == 1 and "recall_at_10" in r.stdout
