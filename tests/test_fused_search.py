"""Fused scan->top-k kernel vs the XLA streaming reference.

The fused Pallas kernel (kernels/sivf_scan/fused.py) must match
``core.index.scan_slabs_topk`` — the jnp register-top-k analogue — on
distances AND labels, including deleted-slot masking, empty chains,
``k > n_live`` padding, and ragged query counts (block_q padding path).
"""
import jax.numpy as jnp
import numpy as np
import pytest

import parity
from repro import core
from repro.kernels.sivf_scan import ops as scan_ops

pytestmark = pytest.mark.pallas

D, NL = 16, 4


def make(rng, capacity=32, metric="l2", n_slabs=24, max_chain=8):
    """Build/churn scaffolding lives in tests/parity.py (shared by the
    pq / filters / tiered suites)."""
    return parity.make_state(rng, dim=D, n_lists=NL, n_slabs=n_slabs,
                             capacity=capacity, metric=metric,
                             max_chain=max_chain)


def load(cfg, state, rng, n, lists=None):
    state, _, _ = parity.load_rows(cfg, state, rng, n, lists=lists)
    return state


def assert_fused_matches_ref(cfg, state, rng, k, nprobe, q=5, block_q=8,
                             use_tables=True):
    qs = jnp.asarray(rng.normal(size=(q, D)).astype(np.float32))
    lists = core.probe(state.centroids, qs, nprobe, cfg.metric)
    table = (core.gather_tables if use_tables else core.walk_chains)(
        cfg, state, lists)
    dr, lr = core.scan_slabs_topk(cfg, state, qs, table, k)
    df, lf = scan_ops.sivf_fused_search(
        qs, table, state.data, state.ids, state.norms, state.bitmap, k,
        metric=cfg.metric, block_q=block_q, interpret=True)
    np.testing.assert_allclose(np.asarray(df), np.asarray(dr), rtol=1e-5,
                               atol=1e-5)
    assert (np.asarray(lf) == np.asarray(lr)).all()
    return np.asarray(df), np.asarray(lf)


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("capacity", [32, 64])
def test_fused_parity_metrics(rng, metric, capacity):
    cfg, state = make(rng, capacity=capacity, metric=metric)
    state = load(cfg, state, rng, 200)
    assert_fused_matches_ref(cfg, state, rng, k=7, nprobe=2)


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_fused_deleted_slot_masking(rng, metric):
    """Deleted ids must never surface: bitmap masking inside the kernel."""
    cfg, state = make(rng, metric=metric)
    state = load(cfg, state, rng, 200)
    dels = np.arange(0, 200, 3, dtype=np.int32)
    state = core.delete(cfg, state, jnp.asarray(dels))
    _, lf = assert_fused_matches_ref(cfg, state, rng, k=9, nprobe=NL)
    live = lf[lf >= 0]
    assert not np.isin(live, dels).any()


def test_fused_empty_chains(rng):
    """Probing empty lists yields -1 slab rows -> +inf / -1 results."""
    cfg, state = make(rng)
    # route everything into a single list so the other probed chains are empty
    state = load(cfg, state, rng, 40, lists=np.zeros((40,), np.int32))
    assert_fused_matches_ref(cfg, state, rng, k=5, nprobe=NL)


def test_fused_fully_empty_index(rng):
    cfg, state = make(rng)
    df, lf = assert_fused_matches_ref(cfg, state, rng, k=4, nprobe=NL)
    assert np.isinf(df).all() and (lf == -1).all()


def test_fused_k_exceeds_n_live(rng):
    """k > live candidates: the tail must pad with +inf / -1."""
    cfg, state = make(rng)
    state = load(cfg, state, rng, 6)
    df, lf = assert_fused_matches_ref(cfg, state, rng, k=16, nprobe=NL)
    assert np.isinf(df[:, -1]).all()            # not enough live vectors
    assert (np.sort(lf, axis=1) != -1).sum(axis=1).max() <= 6


@pytest.mark.parametrize("q,block_q", [(1, 8), (5, 4), (8, 8), (13, 8)])
def test_fused_ragged_query_blocking(rng, q, block_q):
    """Q not divisible by block_q exercises the padding path."""
    cfg, state = make(rng)
    state = load(cfg, state, rng, 150)
    assert_fused_matches_ref(cfg, state, rng, k=5, nprobe=2, q=q,
                             block_q=block_q)


def test_fused_pointer_walk_table(rng):
    """The paper-faithful walk_chains table feeds the same fused kernel."""
    cfg, state = make(rng)
    state = load(cfg, state, rng, 150)
    state = core.delete(cfg, state,
                        jnp.asarray(np.arange(0, 150, 2), np.int32))
    assert_fused_matches_ref(cfg, state, rng, k=5, nprobe=NL,
                             use_tables=False)


def test_fused_randomized_churn_workload(rng):
    """Acceptance: randomized insert/delete workloads, fused == reference."""
    cfg, state = make(rng, n_slabs=48, max_chain=12)
    rows: dict = {}
    for step in range(6):
        state, rows = parity.churn(cfg, state, rng, steps=1, rows=rows)
        assert_fused_matches_ref(cfg, state, rng, k=8,
                                 nprobe=int(rng.integers(1, NL + 1)),
                                 q=int(rng.integers(1, 7)))


def test_search_impl_dispatch_parity(rng):
    """core.search impl="pallas_interpret" == impl="xla" end to end."""
    cfg, state = make(rng)
    state = load(cfg, state, rng, 180)
    state = core.delete(cfg, state,
                        jnp.asarray(np.arange(0, 180, 4), np.int32))
    parity.assert_search_parity(cfg, state, rng, k=5, nprobe=3, q=6)


def test_search_impl_rejects_unknown(rng):
    cfg, state = make(rng)
    state = load(cfg, state, rng, 30)
    qs = jnp.asarray(rng.normal(size=(2, D)).astype(np.float32))
    with pytest.raises(ValueError, match="unknown impl"):
        core.search(cfg, state, qs, 3, 1, impl="cuda")
