"""Hypothesis property tests for the slab-paged KV pool (serve/kv_cache).

Same invariants the paper's SlabManager guarantees (§3.1/§3.4), applied to
the serving pool: no page handed out twice, conservation of the pool,
eviction returns exactly the owned pages, sliding windows keep
cache-coordinate/absolute-position bookkeeping consistent.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:                                   # dev extra, pinned in CI; the local
    from hypothesis import given, settings, strategies as st
except ImportError:                    # fallback keeps tier-1 executing
    from _hypothesis_fallback import given, settings, strategies as st

from repro.serve import kv_cache as kvc

CFG = kvc.PagedKVConfig(n_pages=32, page_size=4, max_pages_per_seq=8,
                        max_seqs=4)

ops = st.lists(
    st.one_of(
        st.tuples(st.just("alloc"), st.integers(0, 3), st.integers(1, 3)),
        st.tuples(st.just("evict"), st.integers(0, 3), st.just(0)),
        st.tuples(st.just("grow"), st.integers(0, 3), st.integers(1, 6)),
        st.tuples(st.just("slide"), st.integers(0, 3), st.integers(0, 10)),
    ),
    min_size=1, max_size=24,
)


@settings(max_examples=60, deadline=None)
@given(ops=ops)
def test_page_pool_invariants(ops):
    st_ = kvc.init_page_state(CFG)
    model = {i: 0 for i in range(CFG.max_seqs)}   # seq -> token length

    for kind, seq, arg in ops:
        if kind == "alloc":
            st_, ok = kvc.allocate(CFG, st_, jnp.int32(seq), arg)
        elif kind == "evict":
            st_ = kvc.evict_seq(CFG, st_, jnp.int32(seq))
            model[seq] = 0
        elif kind == "grow":
            # append `arg` tokens if pages allow
            need = int(kvc.pages_needed(st_.lengths[seq], arg,
                                        CFG.page_size))
            if need:
                st_, ok = kvc.allocate(CFG, st_, jnp.int32(seq), need)
                if not bool(ok):
                    continue
            have = int(np.sum(np.asarray(st_.tables[seq]) >= 0))
            if (model[seq] + arg) <= have * CFG.page_size:
                st_ = kvc.PageState(
                    tables=st_.tables,
                    lengths=st_.lengths.at[seq].add(arg),
                    starts=st_.starts, offsets=st_.offsets,
                    active=st_.active.at[seq].set(True),
                    free_stack=st_.free_stack, free_top=st_.free_top)
                model[seq] += arg
        elif kind == "slide":
            new_start = min(arg, int(st_.lengths[seq]))
            st_ = kvc.slide_window(CFG, st_, jnp.int32(seq),
                                   jnp.int32(new_start))
            model[seq] = int(st_.lengths[seq])

        # -- invariants after every op --------------------------------------
        tables = np.asarray(st_.tables)
        used = tables[tables >= 0]
        free_top = int(st_.free_top)
        free = np.asarray(st_.free_stack)[:free_top]
        # conservation: used + free == pool, no duplicates anywhere
        assert len(used) + free_top == CFG.n_pages
        assert len(set(used.tolist())) == len(used)
        assert len(set(free.tolist())) == free_top
        assert not (set(used.tolist()) & set(free.tolist()))
        # per-seq bookkeeping stays in range
        for i in range(CFG.max_seqs):
            length = int(st_.lengths[i])
            start = int(st_.starts[i])
            n_pages_i = int(np.sum(tables[i] >= 0))
            assert 0 <= start <= max(length, 0) + CFG.page_size
            assert length <= n_pages_i * CFG.page_size
            assert int(st_.offsets[i]) >= 0
