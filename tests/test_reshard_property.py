"""Hypothesis churn property for elastic resharding (ISSUE 5).

Any randomly churned index (ragged adds with overwrites, deletes of
present and absent ids), pushed through a save-shaped reshard chain
4 -> 2 -> 3 -> single, must match the brute-force dict oracle at *every*
step: same ids, same distances, same live count. The chain exercises
grow, shrink, an odd (non-divisor) shard count, and the mesh -> single
collapse in one property.
"""
import jax
import numpy as np
import pytest

try:                                   # dev extra, pinned in CI; the local
    from hypothesis import given, settings, strategies as st
except ImportError:                    # fallback keeps tier-1 executing
    from _hypothesis_fallback import given, settings, strategies as st

import sivf
from repro import core
from repro.core import distributed as dist

D, NL = 16, 8


def search_any(cfg, state, qs, k, nprobe=NL):
    """Search a single OR stacked host state (``dist.search_stacked`` is
    the shared mesh-free merge; its rule mirrors ``sharded_search``)."""
    return dist.search_stacked(cfg, state, qs, k, nprobe)
_CFG = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=48, capacity=32,
                       n_max=256, max_chain=12)
_CENTS = np.random.default_rng(42).normal(size=(NL, D)).astype(np.float32)

churn_ops = st.lists(
    st.tuples(st.sampled_from(["add", "remove"]),
              st.lists(st.integers(0, 63), min_size=1, max_size=12)),
    min_size=1, max_size=8)


@given(ops=churn_ops)
@settings(max_examples=15, deadline=None)
def test_churn_then_reshard_chain_matches_oracle(ops):
    rng = np.random.default_rng(7)
    idx = sivf.Index(_CFG, _CENTS, min_bucket=8)
    ref = core.ReferenceIndex(_CENTS)
    for op, ids in ops:
        ids = np.asarray(ids, np.int32)
        if op == "add":
            vecs = rng.normal(size=(len(ids), D)).astype(np.float32)
            idx.add(vecs, ids)
            ref.insert(vecs, ids)
        else:
            idx.remove(ids)
            ref.delete(np.unique(ids))
    qs = rng.normal(size=(3, D)).astype(np.float32)
    rd, rl = ref.search(qs, 4, NL)

    state = idx.state
    for n_from, n_to in [(1, 4), (4, 2), (2, 3), (3, 1)]:
        state = dist.reshard_state(_CFG, state, n_from, n_to)
        d, lab = search_any(_CFG, state, qs, 4)
        np.testing.assert_allclose(d, rd, rtol=1e-4, atol=1e-4)
        assert (lab == rl).all(), (n_from, n_to)
        assert int(np.asarray(state.n_live).sum()) == ref.n_live
    # the collapsed state still routes: a fresh handle keeps streaming
    end = sivf.Index(_CFG, _CENTS, _state=jax.tree.map(
        lambda x: np.asarray(x), state), min_bucket=8)
    assert end.n_live == ref.n_live
