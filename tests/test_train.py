"""Training substrate: loss goes down, accumulation equivalence,
optimizer math, grad compression, data determinism.

Tier-1 since ISSUE 3: every case here is cheap on CPU (the whole module
measures ~10s; the reduced llama config compiles fast), so the old
module-wide `slow` mark only hid coverage.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data.pipeline import DataConfig, TokenStream
from repro.models import model as M
from repro.sharding.axes import strip
from repro.sharding.rules import unpadded_plan
from repro.train.grad_compress import (compress_tree, dequantize_int8,
                                       quantize_int8)
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state, \
    schedule
from repro.train.train_step import (TrainConfig, init_train_state,
                                    make_train_step)


def test_loss_decreases_small_lm(rng):
    cfg = ARCHS["llama3-8b"].reduced()
    plan = unpadded_plan(cfg)
    params = strip(M.init_params(cfg, plan, jax.random.key(0), max_seq=32))
    state = init_train_state(params)
    tcfg = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=2,
                                     total_steps=30))
    step = jax.jit(make_train_step(cfg, plan, tcfg), donate_argnums=(0,))
    data = TokenStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=4))
    losses = []
    for i in range(25):
        batch = jax.tree.map(jnp.asarray, data.batch(0))   # same batch
        state, met = step(state, batch)
        losses.append(float(met["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::6]


def test_microbatch_accumulation_equivalence(rng):
    """K microbatches of B/K == one batch of B (same gradient step)."""
    cfg = ARCHS["llama3-8b"].reduced()
    plan = unpadded_plan(cfg)
    params = strip(M.init_params(cfg, plan, jax.random.key(0), max_seq=16))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
    labs = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)
    opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)

    s1 = init_train_state(params)
    step1 = jax.jit(make_train_step(cfg, plan, TrainConfig(opt=opt)))
    s1, _ = step1(s1, {"tokens": toks, "labels": labs})

    s2 = init_train_state(params)
    step2 = jax.jit(make_train_step(
        cfg, plan, TrainConfig(opt=opt, microbatches=2)))
    mb = {"tokens": toks.reshape(2, 2, 16), "labels": labs.reshape(2, 2, 16)}
    s2, _ = step2(s2, mb)

    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     s1["params"], s2["params"])
    assert max(jax.tree.leaves(d)) < 1e-5


def test_adamw_matches_reference_step():
    """One AdamW step vs a hand-computed update."""
    p = {"w": jnp.asarray([[1.0, -2.0]])}
    g = {"w": jnp.asarray([[0.5, 0.5]])}
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=1, clip_norm=1e9,
                    weight_decay=0.0, b1=0.9, b2=0.999, eps=1e-8)
    st = init_opt_state(p)
    newp, st2, met = adamw_update(cfg, p, g, st)
    # bias-corrected first step: update = lr * g/|g| elementwise = lr*sign(g)
    np.testing.assert_allclose(np.asarray(newp["w"]),
                               np.asarray(p["w"]) - 0.1 * np.sign(0.5),
                               rtol=1e-4)
    assert int(st2["step"]) == 1


def test_schedule_warmup_and_decay():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                    min_lr_frac=0.1)
    assert float(schedule(cfg, 0)) == pytest.approx(0.1)
    assert float(schedule(cfg, 9)) == pytest.approx(1.0)
    assert float(schedule(cfg, 110)) == pytest.approx(0.1, abs=1e-3)


def test_grad_clip_applied():
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    cfg = OptConfig(lr=0.0, clip_norm=1.0)
    _, _, met = adamw_update(cfg, p, g, init_opt_state(p))
    assert float(met["grad_norm"]) == pytest.approx(200.0)


def test_int8_error_feedback_converges(rng):
    """Error feedback: accumulated quantized stream ~= true stream."""
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32) * 1e-3
    res = {"g": jnp.zeros_like(g)}
    total = jnp.zeros_like(g)
    for _ in range(50):
        q, s, new_res = compress_tree({"g": g}, res)
        total = total + dequantize_int8(q["g"], s["g"])
        res = new_res
    np.testing.assert_allclose(np.asarray(total), np.asarray(g) * 50,
                               rtol=0.02, atol=1e-5)


def test_quantize_roundtrip_error_bounded(rng):
    g = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    q, s = quantize_int8(g)
    err = float(jnp.max(jnp.abs(dequantize_int8(q, s) - g)))
    assert err <= float(s) * 0.5 + 1e-9


def test_data_pipeline_deterministic_and_sharded():
    base = DataConfig(seed=3, vocab_size=100, seq_len=8, global_batch=8,
                      n_hosts=2, host_id=0)
    a = TokenStream(base).batch(5)
    b = TokenStream(base).batch(5)      # re-created stream: identical
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    import dataclasses
    other = TokenStream(dataclasses.replace(base, host_id=1)).batch(5)
    assert not np.array_equal(a["tokens"], other["tokens"])
    # labels are next-token shifted
    full = TokenStream(dataclasses.replace(base, n_hosts=1)).batch(5)
    np.testing.assert_array_equal(full["tokens"][:, 1:],
                                  full["labels"][:, :-1])
