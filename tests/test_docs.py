"""The docs/ subsystem stays true (ISSUE 5 satellite).

``scripts/check_docs.py`` is the enforcement point: every fenced
``python`` block in ``docs/*.md`` must execute, and every intra-repo
markdown link in ``docs/*.md`` + ``README.md`` must resolve. Tier-1 runs
it so a doc-breaking code change fails locally, not just in the CI
``docs`` job; the unit tests below pin the checker's own behavior (a
checker that silently checks nothing would pass forever).
"""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "scripts"))
from check_docs import check_links, extract_python_blocks, iter_links  # noqa: E402


def test_docs_links_and_examples():
    r = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "check_docs.py")],
        capture_output=True, text=True, cwd=REPO, timeout=590)
    assert r.returncode == 0, f"\n{r.stdout}\n{r.stderr[-2000:]}"
    assert "docs OK" in r.stdout


def test_docs_exist_and_are_nonempty():
    for name in ("architecture.md", "checkpoint-format.md", "api.md"):
        p = REPO / "docs" / name
        assert p.exists(), name
        assert len(p.read_text()) > 1000, name


def test_extractor_finds_blocks_and_ignores_other_fences():
    text = "\n".join([
        "```python", "x = 1", "```",
        "```text", "not code", "```",
        "```python-norun", "y = 2", "```",
        "```python", "z = 3", "w = 4", "```",
    ])
    blocks = extract_python_blocks(text)
    assert [code for _, code in blocks] == ["x = 1", "z = 3\nw = 4"]


def test_link_scanner_skips_fences_and_external(tmp_path):
    md = tmp_path / "docs.md"
    md.write_text("\n".join([
        "[ok](real.md) [web](https://x.example) [anchor](#frag)",
        "```text", "[not a link](nope.md)", "```",
        "[broken](gone.md#sec)",
    ]))
    (tmp_path / "real.md").write_text("hi")
    assert list(iter_links(md.read_text())) == [
        "real.md", "https://x.example", "#frag", "gone.md#sec"]
    errs = check_links(md)
    assert len(errs) == 1 and "gone.md" in errs[0]


def test_docs_examples_are_real():
    """Every shipped doc carries at least one executed python block — the
    'examples are tested' promise in each document header."""
    for name in ("architecture.md", "checkpoint-format.md", "api.md"):
        text = (REPO / "docs" / name).read_text()
        assert extract_python_blocks(text), f"{name} has no python blocks"
