"""Elastic resharding: load any checkpoint onto any mesh (ISSUE 5).

Two layers of coverage:

  * **Pure function** (in-process, one device): ``reshard_state`` is
    host-driven and topology-agnostic — a stacked per-shard state is just
    a pytree with a leading axis — so grow / shrink / collapse chains run
    and verify without any fake-device subprocess. Search parity on a
    stacked state uses the same merge rule as ``sharded_search`` (per-
    shard top-k, global re-sort).
  * **Acceptance** (subprocess, 4 forced host devices): a checkpoint saved
    on a real 4-shard mesh loads onto 2-shard, 3-shard, and single
    backends with bit-identical search results (ids AND distances), PQ on
    and off; a live handle reshards in place and keeps streaming; post-
    reshard inserts land on the owning shard.

Everything asserts exact equality (``==``), not allclose: resharding
re-routes stored bytes, it never recomputes distances differently.
"""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import sivf
from repro import core
from repro.core import distributed as dist

D, NL = 16, 8


def make_cfg(pq=None, **kw):
    base = dict(dim=D, n_lists=NL, n_slabs=64, capacity=32, n_max=4096,
                max_chain=16, pq=pq)
    base.update(kw)
    return sivf.SIVFConfig(**base)


def make_index(rng, cfg, **kw):
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    return sivf.Index(cfg, cents, min_bucket=16, **kw), cents


def search_any(cfg, state, qs, k, nprobe=NL):
    """Search a single OR stacked host state (``dist.search_stacked`` is
    the shared mesh-free merge; its rule mirrors ``sharded_search``)."""
    return dist.search_stacked(cfg, state, qs, k, nprobe)


PQ_CASES = [None, sivf.PQConfig(m=4, nbits=6),
            sivf.PQConfig(m=4, nbits=6, store_raw=True)]


@pytest.mark.parametrize("pq", PQ_CASES,
                         ids=["raw", "pq", "pq_store_raw"])
def test_reshard_chain_is_search_identical(rng, pq):
    """Grow -> shrink -> odd -> collapse (1->4->2->3->1): every step keeps
    the canonical live-row table AND the search results bit-identical."""
    cfg = make_cfg(pq)
    idx, _ = make_index(rng, cfg)
    vecs = rng.normal(size=(300, D)).astype(np.float32)
    if pq is not None:
        idx.train(vecs, key=jax.random.key(1))
    idx.add(vecs, np.arange(300))
    idx.remove(np.arange(0, 300, 7))
    idx.add(vecs[:10], np.arange(10))              # overwrites
    qs = rng.normal(size=(6, D)).astype(np.float32)
    d0, l0 = idx.search(qs, 5, NL)
    d0, l0 = np.asarray(d0), np.asarray(l0)
    rows0 = dist.flatten_live_rows(cfg, idx.state)

    st = idx.state
    for n_from, n_to in [(1, 4), (4, 2), (2, 3), (3, 1)]:
        st = dist.reshard_state(cfg, st, n_from, n_to)
        rows = dist.flatten_live_rows(cfg, st)
        assert np.array_equal(rows["ids"], rows0["ids"])
        assert np.array_equal(rows["lists"], rows0["lists"])
        assert np.array_equal(rows["data"], rows0["data"])      # payloads
        assert np.array_equal(rows["codes"], rows0["codes"])    # PQ codes
        d, lab = search_any(cfg, st, qs, 5)
        assert np.array_equal(d, d0) and np.array_equal(lab, l0), (n_from, n_to)
        # routing invariant: every id lives on the shard id % n_to picks
        if n_to > 1:
            for s in range(n_to):
                sub = jax.tree.map(lambda x: np.asarray(x)[s], st)
                srows = dist.flatten_live_rows(cfg, sub)
                assert (srows["ids"] % n_to == s).all()

    # the collapsed state is a drop-in handle state that keeps streaming
    idx2 = sivf.Index(cfg, rows0["centroids"], _state=st, min_bucket=16,
                      _pq_trained=True)
    assert idx2.n_live == idx.n_live
    nv = rng.normal(size=(3, D)).astype(np.float32)
    assert idx2.add(nv, np.arange(2000, 2003)).ok


def test_reshard_empty_index(rng):
    cfg = make_cfg()
    idx, _ = make_index(rng, cfg)
    st = dist.reshard_state(cfg, idx.state, 1, 3)
    assert int(np.asarray(st.n_live).sum()) == 0
    assert np.asarray(st.ids).shape[0] == 3
    d, lab = search_any(cfg, st, rng.normal(size=(2, D)).astype(np.float32), 4)
    assert (lab == -1).all() and np.isinf(d).all()
    st = dist.reshard_state(cfg, st, 3, 1)
    assert int(np.asarray(st.n_live)) == 0


def test_shrink_leaves_a_shard_empty(rng):
    """All ids even -> on a 2-shard target, shard 1 owns zero live rows;
    the empty shard must still be a well-formed, searchable, growable
    state."""
    cfg = make_cfg()
    idx, _ = make_index(rng, cfg)
    vecs = rng.normal(size=(60, D)).astype(np.float32)
    idx.add(vecs, np.arange(0, 240, 4))            # ids ≡ 0 (mod 4)
    qs = rng.normal(size=(4, D)).astype(np.float32)
    d0, l0 = idx.search(qs, 5, NL)
    st4 = dist.reshard_state(cfg, idx.state, 1, 4)  # shards 1-3 empty
    per_shard = np.asarray(st4.n_live)
    assert per_shard[0] == 60 and (per_shard[1:] == 0).all()
    st2 = dist.reshard_state(cfg, st4, 4, 2)
    per_shard = np.asarray(st2.n_live)
    assert per_shard[0] == 60 and per_shard[1] == 0
    d, lab = search_any(cfg, st2, qs, 5)
    assert np.array_equal(d, np.asarray(d0))
    assert np.array_equal(lab, np.asarray(l0))
    # the empty shard accepts its first insert (id 1 routes to shard 1)
    one = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)[1]), st2)
    one = core.insert(cfg, one, jnp.asarray(vecs[:1]),
                      jnp.asarray([1], jnp.int32))
    assert int(one.n_live) == 1 and int(one.error) == 0


def test_reshard_rejects_wrong_n_from(rng):
    cfg = make_cfg()
    idx, _ = make_index(rng, cfg)
    with pytest.raises(ValueError, match="n_from"):
        dist.reshard_state(cfg, idx.state, 2, 4)


def _stack_shards(states):
    return jax.tree.map(lambda *xs: np.stack([np.asarray(x) for x in xs]),
                        *states)


def test_reshard_capacity_overflow_raises(rng):
    """Shrinking concentrates rows: 4 shards' pools can together hold more
    than one shard's static ``n_slabs`` pool fits — the collapse must fail
    up front with an error naming the limit, before any rebuild work.
    (The 4-shard state is assembled by stacking independently-filled
    single states, since no single pool could ever have held it.)"""
    cfg = make_cfg(n_slabs=16, max_chain=16)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    states = []
    for s in range(4):                 # 200 rows/shard, ids ≡ s (mod 4)
        idx = sivf.Index(cfg, cents, min_bucket=16)
        ids = np.arange(s, s + 4 * 200, 4, dtype=np.int32)
        rep = idx.add(rng.normal(size=(200, D)).astype(np.float32), ids)
        assert rep.ok
        states.append(idx.state)
    st4 = _stack_shards(states)
    # 800 rows need >= ceil(800/32) = 25 slabs on the collapsed shard > 16
    with pytest.raises(ValueError, match="n_slabs"):
        dist.reshard_state(cfg, st4, 4, 1)


def test_reshard_chain_overflow_raises(rng):
    """Per-list chain bound: merging shards whose rows share one IVF list
    exceeds ``max_chain`` even though the pool itself would fit."""
    cfg = make_cfg(n_slabs=64, max_chain=1)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    one = rng.normal(size=(1, D)).astype(np.float32)
    states = []
    for s in range(4):                 # 20 rows/shard, all in one list
        idx = sivf.Index(cfg, cents, min_bucket=16)
        ids = np.arange(s, s + 4 * 20, 4, dtype=np.int32)
        rep = idx.add(np.repeat(one, 20, axis=0), ids)
        assert rep.ok
        states.append(idx.state)
    # 80 rows in a single list: ceil(80/32) = 3 chained slabs > max_chain=1
    with pytest.raises(ValueError, match="max_chain"):
        dist.reshard_state(cfg, _stack_shards(states), 4, 1)


def test_load_wrong_axis_mesh_raises(tmp_path, rng):
    """Strict-mode load onto a mesh without the checkpoint's data axis must
    raise up front, not fail inside shard_map."""
    cfg = make_cfg()
    idx, _ = make_index(rng, cfg, strict=True)
    idx.add(rng.normal(size=(20, D)).astype(np.float32), np.arange(20))
    idx.save(tmp_path / "ckpt")
    wrong = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="axis"):
        sivf.Index.load(tmp_path / "ckpt", backend=wrong, strict=True)
    with pytest.raises(TypeError, match="backend"):
        sivf.Index.load(tmp_path / "ckpt", backend=3)


def test_load_unknown_routing_rule_raises(tmp_path, rng):
    from repro.checkpoint.manager import CheckpointManager
    cfg = make_cfg()
    idx, _ = make_index(rng, cfg)
    idx.add(rng.normal(size=(8, D)).astype(np.float32), np.arange(8))
    idx.save(tmp_path / "ckpt")
    mgr = CheckpointManager(tmp_path / "ckpt", keep_last=1)
    meta = mgr.load_metadata("index")
    assert meta["routing"] == {"rule": "mod", "n_shards": 1, "axis": "data"}
    meta["routing"]["rule"] = "rendezvous"
    mgr.save_metadata("index", meta)
    with pytest.raises(ValueError, match="routing"):
        sivf.Index.load(tmp_path / "ckpt")


def test_load_single_checkpoint_onto_one_shard_mesh(tmp_path, rng):
    """Kind change without count change (single -> 1-shard mesh and back)
    goes through the reshard path and stays bit-identical."""
    cfg = make_cfg()
    idx, _ = make_index(rng, cfg)
    vecs = rng.normal(size=(100, D)).astype(np.float32)
    idx.add(vecs, np.arange(100))
    idx.remove(np.arange(0, 100, 3))
    idx.save(tmp_path / "ckpt")
    qs = rng.normal(size=(5, D)).astype(np.float32)
    d0, l0 = idx.search(qs, 5, NL)
    mesh1 = jax.make_mesh((1,), ("data",))
    m = sivf.Index.load(tmp_path / "ckpt", backend=mesh1)
    assert m.backend == "mesh" and m.n_shards == 1
    d1, l1 = m.search(qs, 5, NL)
    assert np.array_equal(np.asarray(d0), np.asarray(d1))
    assert np.array_equal(np.asarray(l0), np.asarray(l1))
    assert m.add(vecs[:2], np.arange(500, 502)).ok
    # and back down: mesh checkpoint -> "single" collapse
    m.remove(np.arange(500, 502))
    m.save(tmp_path / "ckpt2")
    s = sivf.Index.load(tmp_path / "ckpt2", backend="single")
    assert s.backend == "single"
    d2, l2 = s.search(qs, 5, NL)
    assert np.array_equal(np.asarray(d0), np.asarray(d2))
    assert np.array_equal(np.asarray(l0), np.asarray(l2))


def test_live_reshard_flushes_deferred_queue(rng):
    cfg = make_cfg()
    idx, _ = make_index(rng, cfg, deferred=True)
    vecs = rng.normal(size=(30, D)).astype(np.float32)
    fut = idx.add(vecs, np.arange(30))
    assert not fut.done
    idx.reshard(jax.make_mesh((1,), ("data",)))
    assert fut.done and fut.result().accepted == 30   # resolved pre-reshard
    assert idx.backend == "mesh" and idx.n_live == 30
    fut2 = idx.add(vecs, np.arange(100, 130))
    assert idx.flush() == [fut2.result()]


# ---------------------------------------------------------------------------
# Acceptance: real 4-shard mesh checkpoint onto 2 / 3 / single (subprocess)
# ---------------------------------------------------------------------------

_MESH_RESHARD_SCRIPT = r"""
import os, json, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax
import sivf

rng = np.random.default_rng(11)
D, NL = 16, 8
out = {}
for tag, pq in (("raw", None), ("pq", sivf.PQConfig(m=4, nbits=6))):
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=32, capacity=32,
                          n_max=4096, max_chain=16, pq=pq)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    mesh4 = jax.make_mesh((4,), ("data",))
    idx = sivf.Index(cfg, cents, backend=mesh4, min_bucket=16)
    vecs = rng.normal(size=(300, D)).astype(np.float32)
    if pq is not None:
        idx.train(vecs, key=jax.random.key(3))
    idx.add(vecs, np.arange(300))
    idx.remove(np.arange(0, 300, 7))
    idx.add(vecs[:10], np.arange(10))            # overwrites survive reshard
    qs = rng.normal(size=(6, D)).astype(np.float32)
    d0, l0 = idx.search(qs, 5, NL)
    d0, l0 = np.asarray(d0), np.asarray(l0)
    nv = rng.normal(size=(4, D)).astype(np.float32) * 3.0 + 10.0

    with tempfile.TemporaryDirectory() as td:
        idx.save(td)
        for tgt, n in ((jax.make_mesh((2,), ("data",)), 2),
                       (jax.make_mesh((3,), ("data",)), 3),
                       ("single", 1)):
            m = sivf.Index.load(td, backend=tgt)
            assert m.n_shards == n and m.n_live == idx.n_live
            d, l = m.search(qs, 5, NL)
            # acceptance: bit-identical ids AND distances, PQ on and off
            assert np.array_equal(np.asarray(d), d0), (tag, n)
            assert np.array_equal(np.asarray(l), l0), (tag, n)
            # post-reshard inserts land on the owning shard and are found
            rep = m.add(nv, np.arange(2000, 2004))
            assert rep.ok and rep.accepted == 4, (tag, n, rep)
            if n > 1:
                per = np.asarray(m.state.n_live)
                live = sorted((set(range(300)) - set(range(0, 300, 7)))
                              | set(range(10)) | {2000, 2001, 2002, 2003})
                want = np.bincount(np.asarray(live) % n, minlength=n)
                assert (per == want).all(), (tag, n, per, want)
            dd, ll = m.search(nv, 1, NL)
            if pq is None:                       # exact payloads: d == 0
                assert (np.asarray(ll)[:, 0] ==
                        np.arange(2000, 2004)).all(), (tag, n)

    # live handle reshard: 4 -> 2 -> single, streaming throughout
    idx.reshard(jax.make_mesh((2,), ("data",)))
    d, l = idx.search(qs, 5, NL)
    assert np.array_equal(np.asarray(d), d0) and np.array_equal(
        np.asarray(l), l0), (tag, "live-2")
    assert idx.add(nv, np.arange(3000, 3004)).ok
    assert idx.remove(np.arange(3000, 3004)).accepted == 4
    idx.reshard("single")
    d, l = idx.search(qs, 5, NL)
    assert np.array_equal(np.asarray(d), d0) and np.array_equal(
        np.asarray(l), l0), (tag, "live-single")
    out[tag] = {"live": idx.n_live, "backend": idx.backend}

print(json.dumps({"ok": True, **out}))
"""


def _run(script):
    return subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")


def test_mesh_checkpoint_loads_onto_any_backend():
    """ISSUE-5 acceptance: a 4-shard checkpoint loads onto 2-shard,
    3-shard, and single backends bit-identically (PQ on and off), and a
    live handle reshards in place."""
    r = _run(_MESH_RESHARD_SCRIPT)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["raw"]["backend"] == "single"
