"""Unit tests for the skip-budget gate (scripts/check_skips.py).

The gate exists because hypothesis-gated property suites silently
no-op'd in CI for several PRs; these tests pin its three behaviors:
allowlisted skips pass, unallowlisted skips fail, and stale allowlist
patterns fail (the budget can only shrink).
"""
import importlib.util
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "check_skips", REPO / "scripts" / "check_skips.py")
check_skips = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_skips)


_REPORT = """<?xml version="1.0" encoding="utf-8"?>
<testsuites>
  <testsuite name="pytest" tests="3" skipped="{n_skip}">
    <testcase classname="tests.test_a" name="test_runs"/>
    {cases}
  </testsuite>
</testsuites>
"""

_SKIP_CASE = ('<testcase classname="tests.test_{m}" name="test_{t}">'
              '<skipped message="why"/></testcase>')


def _write(tmp_path, skips, patterns):
    cases = "\n    ".join(_SKIP_CASE.format(m=m, t=t) for m, t in skips)
    report = tmp_path / "report.xml"
    report.write_text(_REPORT.format(n_skip=len(skips), cases=cases))
    allow = tmp_path / "allow.txt"
    allow.write_text("# comment line\n\n" + "\n".join(patterns) + "\n")
    return report, allow


def test_skipped_tests_parses_junitxml(tmp_path):
    report, _ = _write(tmp_path, [("b", "x"), ("c", "y")], [])
    assert check_skips.skipped_tests(report) == [
        "tests.test_b::test_x", "tests.test_c::test_y"]


def test_allowlisted_skip_passes(tmp_path):
    report, allow = _write(tmp_path, [("gpu", "needs_tpu")],
                           ["tests.test_gpu::*"])
    assert check_skips.check(report, allow) == 0


def test_unallowlisted_skip_fails(tmp_path):
    report, allow = _write(tmp_path, [("gpu", "needs_tpu"),
                                      ("rogue", "surprise")],
                           ["tests.test_gpu::*"])
    assert check_skips.check(report, allow) == 1


def test_stale_allowlist_pattern_fails(tmp_path):
    """A pattern matching nothing fails too: the budget stays tight."""
    report, allow = _write(tmp_path, [], ["tests.test_gone::*"])
    assert check_skips.check(report, allow) == 1


def test_no_skips_empty_allowlist_passes(tmp_path):
    report, allow = _write(tmp_path, [], [])
    assert check_skips.check(report, allow) == 0


def test_main_missing_report_fails(tmp_path):
    assert check_skips.main([str(tmp_path / "nope.xml")]) == 1


def test_repo_allowlist_is_loadable():
    """The committed allowlist parses (comments/blanks only today —
    every property suite must actually execute)."""
    pats = check_skips.load_allowlist(check_skips.ALLOWLIST)
    assert pats == []
