"""Filtered search (ISSUE 7): predicate algebra, fused masks, isolation.

Four layers, matching the subsystem's structure:

  * the predicate algebra / ``compile_filter`` split (structure vs
    constants) and the ``normalize_attrs`` ingest contract;
  * kernel parity — the Pallas fused scan with an in-scan predicate mask
    must match the XLA reference label-exact on raw AND PQ paths,
    including deleted slots, empty-after-filter, ``k > n_passing`` and
    the pointer-walk table;
  * the ``sivf.Index`` handle — filtered recall@10 == 1.0 against the
    brute-force-within-predicate oracle, compile counts bounded by
    filter *structures* (constants never mint an executable), and
    checkpoint format 3 (attrs plane roundtrip + format-2 migration);
  * ``ServeEngine`` mandatory tenant filters — read- and write-path
    isolation (spoofed attributes are force-stamped, user filters can
    narrow but never escape).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import parity
import sivf
from repro import core
from repro.core import filters as flt

D, NL = 16, 4
ATTRS = ("tenant", "ts")
# distinct n_slabs per compile-counting test: backend op sets are
# lru-cached per cfg, so a unique shape isolates the measured counters
_SLAB_SALT = iter(range(200, 300))


# ---------------------------------------------------------------------------
# Predicate algebra + compilation
# ---------------------------------------------------------------------------

def test_compile_structure_and_const_layout():
    pred = flt.And(flt.Eq("tenant", 7),
                   flt.In("ts", (3, 1, 2)),
                   flt.Range("ts", 10, 20))
    cf = flt.compile_filter(pred, ATTRS)
    assert cf.structure == ("and", ("eq", 0), ("in", 1, 3), ("range", 1))
    assert cf.consts == (7, 3, 1, 2, 10, 20)


def test_same_structure_different_consts_share_key():
    a = flt.compile_filter(flt.Eq("tenant", 3), ATTRS)
    b = flt.compile_filter(flt.Eq("tenant", 9), ATTRS)
    assert a.structure == b.structure and hash(a.structure) == hash(b.structure)
    assert a.consts != b.consts
    assert a != b and hash(a) != hash(b)          # CompiledFilter is hashable


def test_compile_none_passthrough_and_errors():
    assert flt.compile_filter(None, ATTRS) is None
    with pytest.raises(KeyError, match="unknown attribute 'nope'"):
        flt.compile_filter(flt.Eq("nope", 1), ATTRS)
    with pytest.raises(KeyError, match="SIVFConfig"):
        flt.compile_filter(flt.Eq("tenant", 1), ())   # filtering not enabled
    with pytest.raises(ValueError, match="at least one value"):
        flt.In("tenant", ())
    with pytest.raises(ValueError, match="at least one predicate"):
        flt.And()
    with pytest.raises(TypeError, match="not a predicate"):
        flt.compile_filter("tenant == 1", ATTRS)


def test_host_matches_oracle():
    attrs = np.array([[1, 5], [2, 15], [1, 15], [3, 25]], np.int32)
    assert (flt.host_matches(flt.Eq("tenant", 1), ATTRS, attrs)
            == [True, False, True, False]).all()
    assert (flt.host_matches(flt.In("tenant", (2, 3)), ATTRS, attrs)
            == [False, True, False, True]).all()
    # Range is half-open: hi excluded, empty range matches nothing
    assert (flt.host_matches(flt.Range("ts", 5, 15), ATTRS, attrs)
            == [True, False, False, False]).all()
    assert not flt.host_matches(flt.Range("ts", 7, 7), ATTRS, attrs).any()
    both = flt.And(flt.Eq("tenant", 1), flt.Range("ts", 10, 30))
    assert (flt.host_matches(both, ATTRS, attrs)
            == [False, False, True, False]).all()


def test_eq_bindings_recurse_through_and():
    pred = flt.And(flt.Eq("tenant", 4),
                   flt.And(flt.Eq("ts", 9), flt.Range("ts", 0, 10)))
    assert flt.eq_bindings(pred) == {"tenant": 4, "ts": 9}
    assert flt.eq_bindings(flt.Range("ts", 0, 1)) == {}
    assert flt.eq_bindings(None) == {}


def test_normalize_attrs_contract():
    got = flt.normalize_attrs(ATTRS, {"tenant": 3, "ts": [1, 2]}, 2)
    assert got.dtype == np.int32 and (got == [[3, 1], [3, 2]]).all()
    # [n, A] arrays pass through; wrong shapes are rejected
    arr = np.array([[1, 2]], np.int64)
    assert (flt.normalize_attrs(ATTRS, arr, 1) == arr).all()
    with pytest.raises(ValueError, match="shape"):
        flt.normalize_attrs(ATTRS, arr, 2)
    # every configured attribute must be covered — no silent zero-default
    with pytest.raises(ValueError, match="missing attributes \\['ts'\\]"):
        flt.normalize_attrs(ATTRS, {"tenant": 1}, 2)
    with pytest.raises(KeyError, match="unknown attributes \\['shard'\\]"):
        flt.normalize_attrs(ATTRS, {"tenant": 1, "ts": 0, "shard": 2}, 2)
    # overrides (ServeEngine stamping) win over client columns AND cover
    # omitted ones — a spoofed tenant column cannot survive
    got = flt.normalize_attrs(ATTRS, {"tenant": 99, "ts": 5}, 2,
                              overrides={"tenant": 1})
    assert (got[:, 0] == 1).all() and (got[:, 1] == 5).all()
    got = flt.normalize_attrs(ATTRS, {"ts": 5}, 2, overrides={"tenant": 1})
    assert (got == [[1, 5], [1, 5]]).all()


# ---------------------------------------------------------------------------
# Kernel parity: in-scan predicate mask, XLA vs Pallas (interpret)
# ---------------------------------------------------------------------------

pallas = pytest.mark.pallas


def make(rng, n_slabs=24, capacity=32, max_chain=8, pq=None):
    """Build/load scaffolding lives in tests/parity.py."""
    return parity.make_state(rng, dim=D, n_lists=NL, n_slabs=n_slabs,
                             capacity=capacity, max_chain=max_chain,
                             attributes=ATTRS, pq=pq)


def load(cfg, state, rng, n, n_tenants=5):
    return parity.load_rows(cfg, state, rng, n, n_tenants=n_tenants)


def assert_filtered_parity(cfg, state, rng, pred, k, nprobe, q=5,
                           use_tables=True, exact_dist=False):
    """impl="xla" vs "pallas_interpret" with the same compiled filter:
    labels must match exactly; distances bit-exact on the PQ/ADC path,
    allclose on the raw path (fp accumulation order differs). Thin alias
    over the shared helper, keeping this suite's raw-path default."""
    return parity.assert_search_parity(cfg, state, rng, k, nprobe, q=q,
                                       use_tables=use_tables, pred=pred,
                                       exact_dist=exact_dist)


@pallas
@pytest.mark.parametrize("pred", [
    flt.Eq("tenant", 2),
    flt.In("tenant", (0, 3)),
    flt.Range("ts", 20, 70),
    flt.And(flt.Eq("tenant", 1), flt.Range("ts", 0, 50)),
], ids=["eq", "in", "range", "and"])
def test_filtered_parity_all_node_types(rng, pred):
    cfg, state = make(rng)
    state, _, attrs = load(cfg, state, rng, 200)
    _, lab = assert_filtered_parity(cfg, state, rng, pred, k=7, nprobe=NL)
    live = lab[lab >= 0]
    # every returned id satisfies the predicate (mask ran BEFORE top-k)
    assert flt.host_matches(pred, ATTRS, attrs[live]).all()


@pallas
def test_filtered_parity_deleted_slots(rng):
    """Bitmap mask and predicate mask compose: deleted ids never surface
    even when they match the predicate."""
    cfg, state = make(rng)
    state, _, attrs = load(cfg, state, rng, 200)
    dels = np.arange(0, 200, 3, dtype=np.int32)
    state = core.delete(cfg, state, jnp.asarray(dels))
    pred = flt.Range("ts", 0, 100)                 # matches everything live
    _, lab = assert_filtered_parity(cfg, state, rng, pred, k=9, nprobe=NL)
    live = lab[lab >= 0]
    assert not np.isin(live, dels).any()


@pallas
def test_filtered_empty_after_filter(rng):
    """A predicate nothing satisfies yields all +inf / -1, both impls."""
    cfg, state = make(rng)
    state, _, _ = load(cfg, state, rng, 150)
    d, lab = assert_filtered_parity(cfg, state, rng, flt.Eq("tenant", 999),
                                    k=5, nprobe=NL)
    assert np.isinf(d).all() and (lab == -1).all()


@pallas
def test_filtered_k_exceeds_n_passing(rng):
    """k > passing rows: the tail pads with +inf / -1, never with rows
    that fail the predicate."""
    cfg, state = make(rng)
    state, _, attrs = load(cfg, state, rng, 120)
    pred = flt.Eq("tenant", 2)
    n_pass = int(flt.host_matches(pred, ATTRS, attrs).sum())
    k = n_pass + 8
    d, lab = assert_filtered_parity(cfg, state, rng, pred, k=k, nprobe=NL)
    assert ((lab >= 0).sum(axis=1) == n_pass).all()
    assert np.isinf(d[:, n_pass:]).all()
    live = lab[lab >= 0]
    assert flt.host_matches(pred, ATTRS, attrs[live]).all()


@pallas
def test_filtered_pointer_walk_table(rng):
    """The paper-faithful walk_chains table feeds the same masked kernel."""
    cfg, state = make(rng)
    state, _, _ = load(cfg, state, rng, 150)
    state = core.delete(cfg, state,
                        jnp.asarray(np.arange(0, 150, 2), np.int32))
    assert_filtered_parity(cfg, state, rng, flt.In("tenant", (1, 2)),
                           k=6, nprobe=NL, use_tables=False)


@pallas
def test_filtered_pq_adc_parity_bit_exact(rng):
    """Filtered ADC scan over compressed slabs: labels AND distances must
    be bit-exact between XLA and the Pallas kernel (both read the same
    f32 tables, so there is no accumulation-order slack)."""
    cfg, state = make(rng, pq=core.PQConfig(m=4, nbits=4))
    state, _, attrs = load(cfg, state, rng, 200)
    state = core.delete(cfg, state,
                        jnp.asarray(np.arange(0, 200, 5), np.int32))
    pred = flt.And(flt.In("tenant", (0, 1, 2)), flt.Range("ts", 10, 90))
    _, lab = assert_filtered_parity(cfg, state, rng, pred, k=8, nprobe=NL,
                                    exact_dist=True)
    live = lab[lab >= 0]
    assert flt.host_matches(pred, ATTRS, attrs[live]).all()


@pallas
def test_filtered_ragged_query_blocking(rng):
    """Q not divisible by block_q exercises the padded-row mask path."""
    cfg, state = make(rng)
    state, _, _ = load(cfg, state, rng, 150)
    cf = flt.compile_filter(flt.Eq("tenant", 1), cfg.attributes)
    fconsts = jnp.asarray(cf.consts, jnp.int32)
    qs = jnp.asarray(rng.normal(size=(5, D)).astype(np.float32))
    dx, lx = core.search(cfg, state, qs, 4, NL, impl="xla",
                         fstruct=cf.structure, fconsts=fconsts)
    dp, lp = core.search(cfg, state, qs, 4, NL, impl="pallas_interpret",
                         block_q=4, fstruct=cf.structure, fconsts=fconsts)
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dx),
                               rtol=1e-5, atol=1e-5)
    assert (np.asarray(lp) == np.asarray(lx)).all()


# ---------------------------------------------------------------------------
# Index handle: oracle recall, API contract, compile bound
# ---------------------------------------------------------------------------

def _index(rng, n_slabs, attributes=ATTRS, **kw):
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=n_slabs, capacity=32,
                          n_max=2048, attributes=attributes)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    return sivf.Index(cfg, jnp.asarray(cents), min_bucket=8, **kw)


def test_index_filtered_recall_is_exact(rng):
    """Acceptance: filtered recall@10 == 1.0 vs the brute-force-within-
    predicate oracle at full probe (in-scan masking is exact, not a
    heuristic)."""
    idx = _index(rng, n_slabs=next(_SLAB_SALT))
    n = 300
    vecs = rng.normal(size=(n, D)).astype(np.float32)
    tenant = rng.integers(0, 10, n).astype(np.int32)
    ts = rng.integers(0, 100, n).astype(np.int32)
    idx.add(vecs, np.arange(n, dtype=np.int32),
            attrs={"tenant": tenant, "ts": ts})
    attrs = np.stack([tenant, ts], axis=1)
    qs = rng.normal(size=(8, D)).astype(np.float32)
    k = 10
    for pred in (flt.Eq("tenant", 3),
                 flt.In("tenant", (0, 1, 2)),
                 flt.Range("ts", 25, 75),
                 flt.And(flt.Eq("tenant", 4), flt.Range("ts", 0, 80))):
        mask = flt.host_matches(pred, ATTRS, attrs)
        dmat = ((qs[:, None, :] - vecs[None, :, :]) ** 2).sum(-1)
        dmat = np.where(mask[None, :], dmat, np.inf)
        want = np.argsort(dmat, axis=1, kind="stable")[:, :k]
        _, lab = idx.search(qs, k, NL, filter=pred)
        lab = np.asarray(lab)
        for qi in range(len(qs)):
            n_pass = min(int(mask.sum()), k)
            got = set(lab[qi][lab[qi] >= 0].tolist())
            exp = set(want[qi, :n_pass].tolist())
            assert got == exp, f"pred {pred}: {got ^ exp}"


def test_index_attrs_api_contract(rng):
    idx = _index(rng, n_slabs=next(_SLAB_SALT))
    vecs = rng.normal(size=(4, D)).astype(np.float32)
    ids = np.arange(4, dtype=np.int32)
    with pytest.raises(ValueError, match="requires attrs="):
        idx.add(vecs, ids)
    with pytest.raises(ValueError, match="missing attributes"):
        idx.add(vecs, ids, attrs={"tenant": 1})
    idx.add(vecs, ids, attrs={"tenant": 1, "ts": [0, 1, 2, 3]})
    assert idx.n_live == 4
    # filters on an attribute-less index are a config error
    plain = _index(rng, n_slabs=next(_SLAB_SALT), attributes=())
    plain.add(vecs, ids)
    with pytest.raises(ValueError, match="attributes"):
        plain.search(vecs[:1], 2, filter=flt.Eq("tenant", 1))
    with pytest.raises(ValueError, match="attrs= given"):
        plain.add(vecs, ids, attrs={"tenant": 1})


def test_index_filter_structures_bound_compiles(rng):
    """One executable per filter STRUCTURE x query bucket: new constants
    must reuse the compiled kernel."""
    idx = _index(rng, n_slabs=next(_SLAB_SALT))
    n = 64
    vecs = rng.normal(size=(n, D)).astype(np.float32)
    idx.add(vecs, np.arange(n, dtype=np.int32),
            attrs={"tenant": np.arange(n, dtype=np.int32) % 8, "ts": 0})
    qs = rng.normal(size=(3, D)).astype(np.float32)
    idx.search(qs, 5)                                   # unfiltered
    base = idx.compile_stats()["search"]
    idx.search(qs, 5, filter=flt.Eq("tenant", 0))
    assert idx.compile_stats()["search"] == base + 1
    for v in range(1, 6):                               # constants only
        idx.search(qs, 5, filter=flt.Eq("tenant", v))
    assert idx.compile_stats()["search"] == base + 1
    idx.search(qs, 5, filter=flt.Range("ts", 0, 10))    # new structure
    idx.search(qs, 5, filter=flt.Range("ts", 5, 99))
    assert idx.compile_stats()["search"] == base + 2
    # a pre-compiled filter passes straight through (ServeEngine path)
    cf = flt.compile_filter(flt.Eq("tenant", 7), idx.cfg.attributes)
    idx.search(qs, 5, filter=cf)
    assert idx.compile_stats()["search"] == base + 2


def test_stats_report_attr_plane_bytes(rng):
    idx = _index(rng, n_slabs=next(_SLAB_SALT))
    from repro.core.state import memory_report
    s = idx.stats()
    want = idx.cfg.n_slabs * idx.cfg.capacity * len(ATTRS) * 4
    assert s["attr_bytes"] == want
    mr = memory_report(idx.cfg)
    assert mr["attr_bytes"] == want
    assert mr["total_bytes"] >= mr["payload_bytes"] + want
    # the attrs plane sits on BOTH sides of the compression ratio, so
    # enabling filtering never inflates the apparent compression
    assert mr["compression_ratio"] == pytest.approx(1.0)
    plain = _index(rng, n_slabs=next(_SLAB_SALT), attributes=())
    assert plain.stats()["attr_bytes"] == 0


# ---------------------------------------------------------------------------
# Checkpoint: format 3 roundtrip + format-2 migration + elastic reshard
# ---------------------------------------------------------------------------

def _filtered_results(idx, qs, pred):
    d, lab = idx.search(qs, 6, NL, filter=pred)
    return np.asarray(d), np.asarray(lab)


def test_checkpoint_attrs_roundtrip(tmp_path, rng):
    idx = _index(rng, n_slabs=next(_SLAB_SALT))
    n = 120
    vecs = rng.normal(size=(n, D)).astype(np.float32)
    attrs = np.stack([rng.integers(0, 4, n), rng.integers(0, 50, n)],
                     axis=1).astype(np.int32)
    idx.add(vecs, np.arange(n, dtype=np.int32), attrs=attrs)
    qs = rng.normal(size=(4, D)).astype(np.float32)
    pred = flt.And(flt.Eq("tenant", 1), flt.Range("ts", 0, 40))
    want_d, want_l = _filtered_results(idx, qs, pred)
    idx.save(tmp_path / "ckpt")

    from repro.checkpoint.manager import CheckpointManager
    assert CheckpointManager(tmp_path / "ckpt").load_metadata(
        "index")["format"] == 3
    back = sivf.Index.load(tmp_path / "ckpt")
    assert (np.asarray(back.state.attrs) == np.asarray(idx.state.attrs)).all()
    got_d, got_l = _filtered_results(back, qs, pred)
    assert (got_l == want_l).all()
    np.testing.assert_allclose(got_d, want_d, rtol=1e-6)


def test_checkpoint_format2_migration_zero_fills_attrs(tmp_path, rng):
    """A format-2 checkpoint predates the attrs plane: its manifest stores
    one fewer leaf. Loading must zero-fill the trailing plane, not crash.
    The fixture forges a true format-2 save (truncated leaf list + patched
    sidecar) from an attribute-less index, exactly what the old writer
    produced."""
    from repro.checkpoint.manager import CheckpointManager
    idx = _index(rng, n_slabs=next(_SLAB_SALT), attributes=())
    n = 60
    vecs = rng.normal(size=(n, D)).astype(np.float32)
    idx.add(vecs, np.arange(n, dtype=np.int32))
    qs = rng.normal(size=(3, D)).astype(np.float32)
    want_d, want_l = idx.search(qs, 5, NL)
    idx.save(tmp_path / "ckpt")

    mgr = CheckpointManager(tmp_path / "ckpt", keep_last=1)
    leaves = jax.tree.leaves(idx.state)
    mgr.save(1, leaves[:-1])                    # attrs leaf absent on disk
    meta = mgr.load_metadata("index")
    meta["format"] = 2
    del meta["cfg"]["attributes"]               # old cfg had no such field
    mgr.save_metadata("index", meta)

    back = sivf.Index.load(tmp_path / "ckpt")
    assert back.cfg.attributes == ()
    a = np.asarray(back.state.attrs)
    assert a.shape == (idx.cfg.n_slabs, idx.cfg.capacity, 0)
    got_d, got_l = back.search(qs, 5, NL)
    assert (np.asarray(got_l) == np.asarray(want_l)).all()
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-6)
    assert back.n_live == n


def test_reshard_preserves_attrs_and_filters(tmp_path, rng):
    """Elastic load single -> mesh re-routes rows with their attribute
    stamps: filtered searches return identical labels on the new
    topology."""
    idx = _index(rng, n_slabs=next(_SLAB_SALT))
    n = 100
    vecs = rng.normal(size=(n, D)).astype(np.float32)
    attrs = np.stack([rng.integers(0, 3, n), rng.integers(0, 30, n)],
                     axis=1).astype(np.int32)
    idx.add(vecs, np.arange(n, dtype=np.int32), attrs=attrs)
    qs = rng.normal(size=(4, D)).astype(np.float32)
    pred = flt.In("tenant", (0, 2))
    want_d, want_l = _filtered_results(idx, qs, pred)
    idx.save(tmp_path / "ckpt")

    mesh = jax.make_mesh((1,), ("data",))
    m = sivf.Index.load(tmp_path / "ckpt", backend=mesh)
    assert m.n_shards == 1 and m._backend_kind == "mesh"
    got_d, got_l = _filtered_results(m, qs, pred)
    assert (got_l == want_l).all()
    np.testing.assert_allclose(got_d, want_d, rtol=1e-5, atol=1e-5)
    # and the mesh backend keeps accepting stamped inserts
    m.add(vecs[:4] + 10, np.arange(500, 504, dtype=np.int32),
          attrs={"tenant": 2, "ts": 7})
    assert m.n_live == n + 4


# ---------------------------------------------------------------------------
# ServeEngine: mandatory tenant filters (read- AND write-path isolation)
# ---------------------------------------------------------------------------

def _serve_pair(rng):
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=next(_SLAB_SALT),
                          capacity=32, n_max=2048, attributes=ATTRS)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    idx = sivf.Index(cfg, jnp.asarray(cents), deferred=True, min_bucket=8)
    eng = sivf.ServeEngine(
        idx, default_nprobe=NL,
        tenant_filters={"acme": flt.Eq("tenant", 1),
                        "globex": flt.Eq("tenant", 2)})
    return idx, eng


def test_serve_engine_tenant_isolation(rng):
    idx, eng = _serve_pair(rng)
    with eng:
        acme, globex = eng.session("acme"), eng.session("globex")
        va = rng.normal(size=(40, D)).astype(np.float32)
        vg = rng.normal(size=(40, D)).astype(np.float32)
        # acme SPOOFS tenant=2; the engine force-stamps the Eq binding
        acme.add(va, np.arange(40, dtype=np.int32),
                 attrs={"tenant": 2, "ts": np.arange(40)}).result()
        # Eq-pinned attributes may simply be omitted
        globex.add(vg, np.arange(100, 140, dtype=np.int32),
                   attrs={"ts": np.arange(40)}).result()
        qs = rng.normal(size=(6, D)).astype(np.float32)
        la = np.asarray(acme.search(qs, k=20).result().labels)
        lg = np.asarray(globex.search(qs, k=20).result().labels)
        assert ((la == -1) | (la < 100)).all()       # acme sees only acme
        assert (lg[lg >= 0] >= 100).all()            # globex only globex
        # a user filter narrows within the slice...
        lr = np.asarray(acme.search(
            qs, k=20, filter=flt.Range("ts", 0, 10)).result().labels)
        assert ((lr == -1) | (lr < 10)).all()
        # ...but cannot escape it: AND with a contradictory Eq is empty
        esc = acme.search(qs, k=20, filter=flt.Eq("tenant", 2)).result()
        assert (np.asarray(esc.labels) == -1).all()
        compiles, bound = eng.assert_bounded_compiles()
        assert compiles <= bound
    # write path really stored the stamped values, not the spoofed ones
    attrs = np.asarray(idx.state.attrs)
    ids = np.asarray(idx.state.ids)
    assert (attrs[..., 0][ids == 5] == 1).all()      # acme row: tenant=1
    assert (attrs[..., 0][ids == 105] == 2).all()    # globex row: tenant=2


def test_serve_engine_rejects_bad_tenant_filters(rng):
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=next(_SLAB_SALT),
                          capacity=32, n_max=512, attributes=ATTRS)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    idx = sivf.Index(cfg, jnp.asarray(cents), deferred=True, min_bucket=8)
    with pytest.raises(KeyError, match="unknown attribute"):
        sivf.ServeEngine(idx, tenant_filters={"t": flt.Eq("shard", 1)})
    plain_cfg = dataclasses.replace(cfg, n_slabs=next(_SLAB_SALT),
                                    attributes=())
    plain = sivf.Index(plain_cfg, jnp.asarray(cents), deferred=True,
                       min_bucket=8)
    with pytest.raises(KeyError, match="SIVFConfig"):
        sivf.ServeEngine(plain, tenant_filters={"t": flt.Eq("tenant", 1)})
