"""Deferred MutationReports + device-side padding (ISSUE 3).

The acceptance criterion lives here: over the same 58-size ragged churn
stream, deferred mode must report *identical* accepted / overwritten /
rejected counts to eager mode while adding **zero** jit executables beyond
the power-of-two bucket bound (deferral reuses the eager executables —
the aux counts already lived on device; eager mode merely synced them
per batch).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import sivf

D, NL = 16, 8


def make(rng, *, n_slabs=96, capacity=32, n_max=8192, max_chain=24,
         min_bucket=8, **kw):
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=n_slabs,
                          capacity=capacity, n_max=n_max, max_chain=max_chain)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    return cfg, cents, sivf.Index(cfg, cents, min_bucket=min_bucket, **kw)


# ---------------------------------------------------------------------------
# PendingReport futures + flush
# ---------------------------------------------------------------------------

def test_deferred_returns_pending_and_flush_resolves(rng):
    _, _, idx = make(rng, deferred=True)
    vecs = rng.normal(size=(20, D)).astype(np.float32)
    f1 = idx.add(vecs, np.arange(20))
    f2 = idx.add(vecs[:6], np.arange(15, 21))       # 5 overwrites + 1 new
    f3 = idx.remove(np.arange(0, 10))
    assert isinstance(f1, sivf.PendingReport)
    assert not (f1.done or f2.done or f3.done)
    reports = idx.flush()
    assert [f1.done, f2.done, f3.done] == [True] * 3
    assert reports == [f1.result(), f2.result(), f3.result()]
    assert f1.result().accepted == 20 and f1.result().ok
    assert (f2.result().accepted, f2.result().overwritten) == (1, 5)
    assert f3.result().accepted == 10
    assert idx.flush() == []                         # empty queue is a no-op


def test_future_attribute_access_forces_flush(rng):
    _, _, idx = make(rng, deferred=True)
    vecs = rng.normal(size=(8, D)).astype(np.float32)
    fut = idx.add(vecs, np.arange(8))
    assert fut.accepted == 8                         # proxies through result()
    assert fut.done and not idx._pending


def test_context_exit_flushes(rng):
    cfg, cents, _ = make(rng)
    with sivf.Index(cfg, cents, min_bucket=8, deferred=True) as idx:
        fut = idx.add(rng.normal(size=(5, D)).astype(np.float32),
                      np.arange(5))
        assert not fut.done
    assert fut.done and fut.result().accepted == 5


def test_strict_deferred_raises_at_flush_queue_still_resolves(rng):
    cfg, cents, _ = make(rng)
    idx = sivf.Index(cfg, cents, min_bucket=8, deferred=True, strict=True)
    vecs = rng.normal(size=(4, D)).astype(np.float32)
    bad = idx.add(vecs[:2], np.asarray([1, cfg.n_max + 7], np.int32))
    good = idx.add(vecs[:3], np.arange(10, 13))
    with pytest.raises(sivf.MutationRejected) as ei:
        idx.flush()
    assert ei.value.report.errors & sivf.ErrorCode.ID_RANGE
    # the whole queue resolved before the raise — no dangling futures
    assert bad.done and good.done and good.result().ok
    assert not idx._pending


def test_deferred_failed_batch_is_atomic(rng):
    """Exhaustion under deferral: the future's report shows the atomic
    reject and the old payloads stay searchable."""
    cfg, cents, idx = make(rng, n_slabs=10, max_chain=4, deferred=True)
    base = rng.normal(size=(30, D)).astype(np.float32)
    ok = idx.add(base, np.arange(30))
    n = 10 * 32 + 40
    ids = np.concatenate([np.arange(10),
                          np.arange(100, 100 + n - 10)]).astype(np.int32)
    failed = idx.add(rng.normal(size=(n, D)).astype(np.float32), ids)
    idx.flush()
    assert ok.result().ok and ok.result().accepted == 30
    rep = failed.result()
    assert rep.errors & sivf.ErrorCode.POOL_EXHAUSTED
    assert (rep.accepted, rep.overwritten, rep.rejected) == (0, 0, n)
    assert idx.n_live == 30
    res = idx.search(base[:10], 1)
    assert (np.asarray(res.labels)[:, 0] == np.arange(10)).all()
    np.testing.assert_allclose(np.asarray(res.distances)[:, 0], 0, atol=1e-4)


def test_flush_is_one_device_transfer(rng, monkeypatch):
    """ISSUE 4 satellite: ``flush()`` packs the whole queue's aux scalars
    into ONE stacked device->host transfer — a single explicit
    ``jax.device_get`` on one concatenated int32 array, with zero implicit
    transfers (enforced by the transfer guard: "disallow" rejects any
    implicit device->host sync while letting the one device_get through).
    """
    _, _, idx = make(rng, deferred=True)
    futs = []
    for step in range(7):
        vecs = rng.normal(size=(6, D)).astype(np.float32)
        futs.append(idx.add(vecs, np.arange(step * 6, step * 6 + 6)))
        if step % 3 == 2:
            futs.append(idx.remove(np.arange(step, step + 2)))
    jax.block_until_ready(idx.state.n_live)      # settle queued computation
    calls = []
    real = jax.device_get
    monkeypatch.setattr(
        jax, "device_get", lambda x: (calls.append(1), real(x))[1])
    with jax.transfer_guard("disallow"):
        reports = idx.flush()
    assert len(calls) == 1, f"flush used {len(calls)} transfers"
    assert len(reports) == len(futs) and all(f.done for f in futs)
    assert sum(r.accepted for r in reports if r.op == "add") == 42


def test_eager_report_is_one_device_transfer(rng, monkeypatch):
    """Eager mode rides the same path with a one-element queue."""
    _, _, idx = make(rng)
    vecs = rng.normal(size=(10, D)).astype(np.float32)
    idx.add(vecs, np.arange(10))                 # warm executables
    calls = []
    real = jax.device_get
    monkeypatch.setattr(
        jax, "device_get", lambda x: (calls.append(1), real(x))[1])
    rep = idx.add(vecs, np.arange(10, 20))
    assert rep.accepted == 10
    assert len(calls) == 1


# ---------------------------------------------------------------------------
# Acceptance criterion: 58 ragged sizes, identical counts, bounded compiles
# ---------------------------------------------------------------------------

def test_deferred_matches_eager_over_58_ragged_sizes(rng):
    # fresh cfg so this test owns the (shared-by-config) jit counters
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=257, capacity=32,
                          n_max=1 << 14, max_chain=65)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    eager = sivf.Index(cfg, cents, min_bucket=8)
    deferred = sivf.Index(cfg, cents, min_bucket=8, deferred=True)

    sizes = list(range(1, 59))                       # 58 distinct ragged sizes
    rng.shuffle(sizes)
    buckets = {eager._bucket(s) for s in sizes}
    next_id, step = 0, 0
    eager_reps, futs = [], []
    for s in sizes:
        vecs = rng.normal(size=(s, D)).astype(np.float32)
        if step % 3 == 2 and next_id > s:            # overwrite slice
            ids = np.arange(next_id - s, next_id, dtype=np.int32)
        else:
            ids = np.arange(next_id, next_id + s, dtype=np.int32)
            next_id += s
        eager_reps.append(eager.add(vecs, ids))
        futs.append(deferred.add(vecs, ids))
        if step % 4 == 3:                            # interleaved eviction
            evict = np.arange(step, next_id, 7, dtype=np.int32)[:s]
            eager_reps.append(eager.remove(evict))
            futs.append(deferred.remove(evict))
        step += 1
    deferred_reps = deferred.flush()

    assert deferred_reps == [f.result() for f in futs]
    for er, dr in zip(eager_reps, deferred_reps):
        assert (er.accepted, er.overwritten, er.rejected, er.errors) \
            == (dr.accepted, dr.overwritten, dr.rejected, dr.errors), (er, dr)
    assert eager.n_live == deferred.n_live

    # both handles share one op set: deferral added zero executables, and
    # the total stays within the bucket bound for 58 distinct sizes
    compiles = eager.compile_stats()
    assert compiles == deferred.compile_stats()
    assert 1 <= compiles["add"] <= len(buckets), (compiles, buckets)
    assert 1 <= compiles["remove"] <= len(buckets), (compiles, buckets)


# ---------------------------------------------------------------------------
# Device-side padding
# ---------------------------------------------------------------------------

def test_device_inputs_pad_device_side_and_match_host_path(rng):
    _, _, idx = make(rng)
    vecs = rng.normal(size=(12, D)).astype(np.float32)
    ids = np.arange(12, dtype=np.int32)
    dv, di = jnp.asarray(vecs), jnp.asarray(ids)
    # the padding helpers must not round-trip jax inputs through numpy
    padded = idx._pad_rows(dv, 16)
    assert isinstance(padded, jax.Array) and padded.shape == (16, D)
    assert float(jnp.sum(jnp.abs(padded[12:]))) == 0.0
    pids = idx._pad_ids(di, 16)
    assert isinstance(pids, jax.Array)
    assert (np.asarray(pids[12:]) == -1).all()

    rep = idx.add(dv, di)
    assert rep.ok and rep.accepted == 12
    res = idx.search(dv, 1)                          # device queries too
    assert (np.asarray(res.labels)[:, 0] == ids).all()

    _, _, host_idx = make(rng)
    rep_h = host_idx.add(vecs, ids)
    assert (rep_h.accepted, rep_h.overwritten, rep_h.rejected) \
        == (rep.accepted, rep.overwritten, rep.rejected)
    qs = rng.normal(size=(5, D)).astype(np.float32)
    d_dev, l_dev = idx.search(jnp.asarray(qs), 4)
    d_host, l_host = host_idx.search(qs, 4)
    np.testing.assert_allclose(np.asarray(d_dev), np.asarray(d_host),
                               rtol=1e-6)
    assert (np.asarray(l_dev) == np.asarray(l_host)).all()


def test_device_inputs_in_deferred_mode(rng):
    _, _, idx = make(rng, deferred=True)
    dv = jnp.asarray(rng.normal(size=(9, D)).astype(np.float32))
    fut = idx.add(dv, jnp.arange(9, dtype=jnp.int32))
    fut2 = idx.remove(jnp.arange(3, dtype=jnp.int32))
    reports = idx.flush()
    assert reports[0].accepted == 9 and reports[1].accepted == 3
    assert fut.done and fut2.done


# ---------------------------------------------------------------------------
# Mesh backend deferral (single-shard in-process; 4-shard partial-failure
# case rides in test_api.py's subprocess script)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("data",))


def test_mesh_deferred_matches_eager(rng, mesh1):
    cfg, cents, _ = make(rng)
    eager = sivf.Index(cfg, cents, backend=mesh1, min_bucket=8)
    deferred = sivf.Index(cfg, cents, backend=mesh1, min_bucket=8,
                          deferred=True)
    vecs = rng.normal(size=(40, D)).astype(np.float32)
    er1 = eager.add(vecs, np.arange(40))
    er2 = eager.add(vecs[:10], np.arange(35, 45))
    er3 = eager.remove(np.arange(0, 20))
    f1 = deferred.add(vecs, np.arange(40))
    f2 = deferred.add(vecs[:10], np.arange(35, 45))
    f3 = deferred.remove(np.arange(0, 20))
    deferred.flush()
    for er, fut in [(er1, f1), (er2, f2), (er3, f3)]:
        dr = fut.result()
        assert (er.accepted, er.overwritten, er.rejected) \
            == (dr.accepted, dr.overwritten, dr.rejected)
    # mesh reports carry per-shard error bits
    assert er1.shard_errors == (sivf.ErrorCode.NONE,)
    assert f1.result().shard_errors == (sivf.ErrorCode.NONE,)
    assert eager.n_live == deferred.n_live
