"""Distributed behaviour on fake devices (subprocess: device count must be
set before jax initializes, so these run isolated)."""
import json
import subprocess
import sys

_DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np, jax, jax.numpy as jnp
from repro import core
from repro.core import distributed as dist

rng = np.random.default_rng(1)
D, NL = 16, 8
cfg = core.SIVFConfig(dim=D, n_lists=NL, n_slabs=32, capacity=32,
                      n_max=4096, max_chain=8)
cents = rng.normal(size=(NL, D)).astype(np.float32)
mesh = jax.make_mesh((4,), ("data",))
state = dist.init_sharded_state(cfg, jnp.asarray(cents), mesh)
ref = core.ReferenceIndex(cents)

B = 64
vecs = rng.normal(size=(B, D)).astype(np.float32)
ids = np.arange(B, dtype=np.int32)
state = dist.dist_insert(cfg, mesh, state, jnp.asarray(vecs), jnp.asarray(ids))
ref.insert(vecs, ids)
assert dist.total_live(state) == ref.n_live

qs = rng.normal(size=(4, D)).astype(np.float32)
d, l = dist.dist_search(cfg, mesh, state, jnp.asarray(qs), 5, NL)
rd, rl = ref.search(qs, 5, NL)
np.testing.assert_allclose(np.asarray(d), rd, rtol=1e-4, atol=1e-4)
assert (np.asarray(l) == rl).all()

state = dist.dist_delete(cfg, mesh, state, jnp.asarray(ids[::2]))
ref.delete(ids[::2])
assert dist.total_live(state) == ref.n_live
d, l = dist.dist_search(cfg, mesh, state, jnp.asarray(qs), 5, NL)
rd, rl = ref.search(qs, 5, NL)
np.testing.assert_allclose(np.asarray(d), rd, rtol=1e-4, atol=1e-4)
print(json.dumps({"ok": True, "live": dist.total_live(state)}))
"""

_DRYRUN_SCRIPT = r"""
import os, sys
os.environ["REPRO_DRYRUN_DEVICES"] = "8"
os.environ["REPRO_DRYRUN_MESH"] = "2,2"
sys.argv = ["dryrun", "--arch", "llama3-8b", "--shape", "decode_32k",
            "--mesh", "both", "--out", sys.argv[1]]
from repro.launch import dryrun
dryrun.main()
"""


def _run(script, *args):
    return subprocess.run(
        [sys.executable, "-c", script, *args], capture_output=True,
        text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # fake-device CPU tests; avoid the TPU-probe stall on hosts
             # with libtpu installed (see conftest.py)
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")


def test_sharded_sivf_scatter_gather():
    """Paper §4.2: data-sharded insert, scatter-gather search, broadcast
    delete across 4 shards match the reference model exactly."""
    r = _run(_DIST_SCRIPT)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["live"] == 32


def test_dryrun_cell_compiles(tmp_path):
    """dryrun.py lowers+compiles a (arch x shape) cell on a reduced mesh on
    both single- and multi-pod layouts (smoke for the real 512-dev sweep)."""
    out = tmp_path / "res.json"
    r = _run(_DRYRUN_SCRIPT, str(out))
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-1500:])
    res = json.loads(out.read_text())
    assert res["llama3-8b|decode_32k|single"]["status"] == "ok"
    assert res["llama3-8b|decode_32k|multi"]["status"] == "ok"
    cell = res["llama3-8b|decode_32k|single"]
    assert cell["hlo_flops"] > 0
    assert cell["roofline"]["dominant"] in ("compute_s", "memory_s",
                                            "collective_s")
