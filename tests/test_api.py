"""`sivf.Index` session handle: reports, bucketing, backends, persistence.

The ISSUE-2 acceptance criteria live here:
  * one handle passes the same churn test on single-device and 2+-shard
    mesh backends (the mesh case runs on 4 fake devices in a subprocess,
    because the device count must be fixed before jax initializes);
  * a stream over 8+ distinct ragged batch sizes compiles at most
    (number of bucket shapes) add/remove/search executables — asserted
    via the handle's measured jit-cache counters, not assumed.
"""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import sivf
from repro import core
from repro.core import distributed as dist

D, NL = 16, 8


def make(rng, *, n_slabs=64, capacity=32, n_max=4096, max_chain=16,
         min_bucket=16, **kw):
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=n_slabs,
                          capacity=capacity, n_max=n_max, max_chain=max_chain)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    idx = sivf.Index(cfg, cents, min_bucket=min_bucket, **kw)
    return idx, core.ReferenceIndex(cents)


def check_search(idx, ref, rng, k=5, nprobe=NL, q=6):
    qs = rng.normal(size=(q, D)).astype(np.float32)
    d, lab = idx.search(qs, k, nprobe)
    rd, rl = ref.search(qs, k, nprobe)
    np.testing.assert_allclose(np.asarray(d), rd, rtol=1e-4, atol=1e-4)
    assert (np.asarray(lab) == rl).all()


# ---------------------------------------------------------------------------
# Reports
# ---------------------------------------------------------------------------

def test_add_remove_search_matches_reference(rng):
    idx, ref = make(rng)
    vecs = rng.normal(size=(200, D)).astype(np.float32)
    rep = idx.add(vecs, np.arange(200))
    ref.insert(vecs, np.arange(200))
    assert (rep.requested, rep.accepted, rep.overwritten, rep.rejected) \
        == (200, 200, 0, 0)
    assert rep.ok and rep.n_live == idx.n_live == ref.n_live == 200
    check_search(idx, ref, rng)

    rep = idx.remove(np.arange(0, 200, 3))
    ref.delete(np.arange(0, 200, 3))
    assert rep.accepted == 67 and rep.rejected == 0
    assert idx.n_live == ref.n_live
    check_search(idx, ref, rng)


def test_report_overwrite_is_disjoint_from_accepted(rng):
    idx, ref = make(rng)
    vecs = rng.normal(size=(64, D)).astype(np.float32)
    idx.add(vecs, np.arange(64))
    ref.insert(vecs, np.arange(64))
    # 10 overwrites + 6 new in one batch
    more = rng.normal(size=(16, D)).astype(np.float32)
    ids = np.arange(54, 70, dtype=np.int32)
    rep = idx.add(more, ids)
    ref.insert(more, ids)
    assert (rep.accepted, rep.overwritten, rep.rejected) == (6, 10, 0)
    assert rep.n_live == ref.n_live == 70
    check_search(idx, ref, rng)


def test_report_within_batch_duplicates_rejected(rng):
    idx, ref = make(rng)
    vecs = rng.normal(size=(4, D)).astype(np.float32)
    ids = np.array([7, 7, 7, 8], np.int32)
    rep = idx.add(vecs, ids)
    ref.insert(vecs, ids)               # dict semantics: last row wins
    assert (rep.requested, rep.accepted, rep.rejected) == (4, 2, 2)
    assert idx.n_live == ref.n_live == 2
    check_search(idx, ref, rng, k=2)


def test_report_id_range_error_and_bit_clearing(rng):
    idx, ref = make(rng)
    vecs = rng.normal(size=(2, D)).astype(np.float32)
    rep = idx.add(vecs, np.asarray([1, idx.cfg.n_max + 5], np.int32))
    assert rep.errors == sivf.ErrorCode.ID_RANGE
    assert rep.accepted == 1 and rep.rejected == 1
    # handled bits are cleared: state is clean and the next report is too
    assert int(jnp.sum(idx.state.error)) == 0
    rep2 = idx.add(vecs, np.asarray([2, 3], np.int32))
    assert rep2.ok


def test_report_pool_exhaustion_and_strict_raise(rng):
    idx, _ = make(rng, n_slabs=8, max_chain=8)
    n = 8 * 32 + 1
    vecs = rng.normal(size=(n, D)).astype(np.float32)
    rep = idx.add(vecs, np.arange(n))
    assert rep.errors & sivf.ErrorCode.POOL_EXHAUSTED
    assert rep.accepted == 0 and rep.rejected == n
    assert idx.n_live == 0              # batch rejected atomically

    strict_idx, _ = make(rng, n_slabs=8, max_chain=8, strict=True)
    with pytest.raises(sivf.MutationRejected) as ei:
        strict_idx.add(vecs, np.arange(n))
    assert ei.value.report.errors & sivf.ErrorCode.POOL_EXHAUSTED
    # per-call override beats the handle default
    rep = strict_idx.add(vecs, np.arange(n), strict=False)
    assert not rep.ok


def test_failed_overwrite_batch_is_atomic(rng):
    """ISSUE-3 tentpole: a POOL_EXHAUSTED batch that was overwriting live
    ids must leave them searchable with their *old* payloads (the seed
    behavior dropped them)."""
    idx, _ = make(rng, n_slabs=10, max_chain=4)
    base = rng.normal(size=(30, D)).astype(np.float32)
    assert idx.add(base, np.arange(30)).ok
    n = 10 * 32 + 40
    ids = np.concatenate([np.arange(10),
                          np.arange(100, 100 + n - 10)]).astype(np.int32)
    rep = idx.add(rng.normal(size=(n, D)).astype(np.float32), ids)
    assert rep.errors & sivf.ErrorCode.POOL_EXHAUSTED
    # nothing accepted, nothing overwritten: the would-be overwrites kept
    # their old payloads, so they land in `rejected` with the rest
    assert (rep.accepted, rep.overwritten, rep.rejected) == (0, 0, n)
    assert idx.n_live == 30
    res = idx.search(base[:10], 1)
    assert (np.asarray(res.labels)[:, 0] == np.arange(10)).all()
    np.testing.assert_allclose(np.asarray(res.distances)[:, 0], 0, atol=1e-4)
    # the handle keeps streaming normally after the atomic reject
    assert idx.add(base[:5], np.arange(200, 205)).ok


def test_count_unique_counts_int32_max_id():
    """Regression (ISSUE 3): the old sentinel encoding collapsed a genuine
    id equal to INT32_MAX into the masked-out run and undercounted."""
    from repro.core.api import _count_unique
    m = np.iinfo(np.int32).max
    ids = jnp.asarray([m, 3, m, 3, -1], jnp.int32)
    mask = jnp.asarray([True, True, True, True, False])
    assert int(_count_unique(ids, mask)) == 2
    # masked-out duplicates of a live id don't double count; masked-out
    # ids alone don't count at all
    assert int(_count_unique(jnp.asarray([5, 5, 9], jnp.int32),
                             jnp.asarray([True, False, False]))) == 1
    assert int(_count_unique(jnp.asarray([m], jnp.int32),
                             jnp.asarray([False]))) == 0


def test_out_of_range_id_not_misreported_as_overwrite(rng):
    """Regression (ISSUE 3): clipping made an ID_RANGE-rejected id read
    slot n_max-1's occupancy, so it could be reported as `overwritten`
    when that boundary slot happened to be live."""
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=64, capacity=32,
                          n_max=64, max_chain=16)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    idx = sivf.Index(cfg, cents, min_bucket=8)
    vecs = rng.normal(size=(4, D)).astype(np.float32)
    assert idx.add(vecs[:1], np.asarray([63], np.int32)).ok  # n_max-1 live
    rep = idx.add(vecs[1:2], np.asarray([64], np.int32))     # out of range
    assert rep.errors == sivf.ErrorCode.ID_RANGE
    assert (rep.accepted, rep.overwritten, rep.rejected) == (0, 0, 1)
    # mixed batch: the real boundary id overwrites, the phantom rejects
    rep = idx.add(vecs[2:4], np.asarray([63, 64], np.int32))
    assert (rep.accepted, rep.overwritten, rep.rejected) == (0, 1, 1)
    assert idx.n_live == 1


def test_remove_missing_ids_counted_rejected(rng):
    idx, _ = make(rng)
    vecs = rng.normal(size=(10, D)).astype(np.float32)
    idx.add(vecs, np.arange(10))
    rep = idx.remove(np.asarray([0, 1, 999, 1000], np.int32))
    assert rep.accepted == 2 and rep.rejected == 2
    rep = idx.remove(np.asarray([0, 1], np.int32))   # already gone
    assert rep.accepted == 0 and rep.rejected == 2


# ---------------------------------------------------------------------------
# Bounded compilation under ragged streaming (acceptance criterion)
# ---------------------------------------------------------------------------

def test_ragged_batches_bounded_compiles(rng):
    # unique cfg so this test owns its jit-cache counters (they are shared
    # between handles with equal configs by design)
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=63, capacity=32,
                          n_max=4096, max_chain=17)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    idx = sivf.Index(cfg, cents, min_bucket=8)
    ref = core.ReferenceIndex(cents)

    sizes = [1, 3, 5, 9, 13, 17, 29, 33, 47, 63]      # 10 distinct raggeds
    buckets = {idx._bucket(s) for s in sizes}
    assert buckets == {8, 16, 32, 64}
    assert idx.bucket_shapes(63) == [8, 16, 32, 64]

    next_id = 0
    for s in sizes:
        vecs = rng.normal(size=(s, D)).astype(np.float32)
        ids = np.arange(next_id, next_id + s, dtype=np.int32)
        assert idx.add(vecs, ids).ok
        ref.insert(vecs, ids)
        next_id += s
    for s in sizes:
        d, lab = idx.search(rng.normal(size=(s, D)).astype(np.float32), 4, NL)
        assert d.shape == (s, 4)
    for s in (2, 6, 11, 18, 27, 34, 50, 62):
        ids = rng.integers(0, next_id, s).astype(np.int32)
        idx.remove(ids)
        ref.delete(np.unique(ids))

    compiles = idx.compile_stats()
    # >= 8 distinct ragged sizes ran; executables bounded by bucket count.
    # The lower bound of 1 guards against a broken/unavailable counter
    # (compile_stats returns -1 then) passing the bound vacuously.
    assert 1 <= compiles["add"] <= len(buckets), compiles
    assert 1 <= compiles["remove"] <= len(buckets), compiles
    assert 1 <= compiles["search"] <= len(buckets), compiles
    assert idx.n_live == ref.n_live
    check_search(idx, ref, rng)


def test_caller_centroids_buffer_survives_donation(rng):
    """Mutation kernels donate the state; the caller's centroids array must
    never be aliased into it (init_state copies), or the first add() would
    delete the caller's buffer."""
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=64, capacity=32,
                          n_max=4096, max_chain=16)
    cents = jnp.asarray(rng.normal(size=(NL, D)).astype(np.float32))
    idx1 = sivf.Index(cfg, cents, min_bucket=8)
    vecs = rng.normal(size=(10, D)).astype(np.float32)
    assert idx1.add(vecs, np.arange(10)).ok
    # same device array builds a second session and stays readable
    idx2 = sivf.Index(cfg, cents, min_bucket=8)
    assert idx2.add(vecs, np.arange(10)).ok
    assert np.asarray(cents).shape == (NL, D)


# ---------------------------------------------------------------------------
# Mesh backend (in-process single-shard; 4-shard case in subprocess below)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh1():
    return jax.make_mesh((1,), ("data",))


def test_mesh_backend_matches_reference(rng, mesh1):
    idx, ref = make(rng, backend=mesh1)
    vecs = rng.normal(size=(150, D)).astype(np.float32)
    rep = idx.add(vecs, np.arange(150))
    ref.insert(vecs, np.arange(150))
    assert rep.accepted == 150 and rep.ok
    rep = idx.add(vecs[:9], np.arange(9))
    assert rep.overwritten == 9 and rep.accepted == 0
    ref.insert(vecs[:9], np.arange(9))
    idx.remove(np.arange(0, 150, 2))
    ref.delete(np.arange(0, 150, 2))
    assert idx.n_live == ref.n_live
    check_search(idx, ref, rng)
    st = idx.stats()
    assert st["backend"] == "mesh" and st["n_shards"] == 1
    assert st["per_shard_live"] == [idx.n_live]


def test_stats_aggregates_stacked_sharded_state(rng, mesh1):
    """core.index.stats on the stacked per-shard state (used to crash)."""
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=32, capacity=32,
                          n_max=4096, max_chain=8)
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    state = dist.init_sharded_state(cfg, jnp.asarray(cents), mesh1)
    state = dist.dist_insert(cfg, mesh1, state,
                             jnp.asarray(rng.normal(size=(40, D)), jnp.float32),
                             jnp.arange(40, dtype=jnp.int32))
    st = core.stats(cfg, state)
    assert st["n_live"] == dist.total_live(state) == 40
    assert st["n_shards"] == 1
    assert st["slabs_used"] == sum(st["per_shard_slabs_used"])
    assert st["error"] == 0


# ---------------------------------------------------------------------------
# Persistence
# ---------------------------------------------------------------------------

def test_save_load_roundtrip(tmp_path, rng):
    idx, _ = make(rng)
    vecs = rng.normal(size=(120, D)).astype(np.float32)
    idx.add(vecs, np.arange(120))
    idx.remove(np.arange(0, 120, 4))
    idx.save(tmp_path / "ckpt")

    loaded = sivf.Index.load(tmp_path / "ckpt")
    assert loaded.cfg == idx.cfg
    assert loaded.n_live == idx.n_live
    qs = rng.normal(size=(5, D)).astype(np.float32)
    d0, l0 = idx.search(qs, 5, NL)
    d1, l1 = loaded.search(qs, 5, NL)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1))
    assert (np.asarray(l0) == np.asarray(l1)).all()
    # the restored handle keeps streaming
    assert loaded.add(vecs[:4], np.arange(200, 204)).ok


def test_load_format1_checkpoint(tmp_path, rng):
    """Pre-PQ (format-1) checkpoints lack the ``codes`` / ``pq_codebooks``
    / ``attrs`` leaves; ``Index.load`` must restore them into the leaf
    prefix and fill the (zero-width, since format 1 implies ``pq=None``
    and no attributes) planes fresh."""
    from repro.checkpoint.manager import CheckpointManager
    idx, _ = make(rng)
    vecs = rng.normal(size=(60, D)).astype(np.float32)
    idx.add(vecs, np.arange(60))
    idx.save(tmp_path / "ckpt")
    # rewrite the checkpoint as a format-1 save: drop the three trailing
    # plane leaves (last registered data fields) and the newer metadata
    mgr = CheckpointManager(tmp_path / "ckpt", keep_last=1)
    meta = mgr.load_metadata("index")
    meta["format"] = 1
    meta.pop("pq_trained")
    meta["cfg"].pop("pq")
    meta["cfg"].pop("attributes")
    mgr.save_metadata("index", meta)
    leaves, _ = jax.tree.flatten(idx.state)
    mgr.save(0, leaves[:-3])
    loaded = sivf.Index.load(tmp_path / "ckpt")
    assert loaded.n_live == 60
    qs = rng.normal(size=(4, D)).astype(np.float32)
    d0, l0 = idx.search(qs, 5, NL)
    d1, l1 = loaded.search(qs, 5, NL)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1))
    assert (np.asarray(l0) == np.asarray(l1)).all()
    assert loaded.add(vecs[:4], np.arange(200, 204)).ok


def test_save_load_mesh_roundtrip(tmp_path, rng, mesh1):
    idx, _ = make(rng, backend=mesh1)
    idx.add(rng.normal(size=(50, D)).astype(np.float32), np.arange(50))
    idx.save(tmp_path / "ckpt")
    with pytest.raises(ValueError, match="mesh"):
        sivf.Index.load(tmp_path / "ckpt")           # mesh required
    loaded = sivf.Index.load(tmp_path / "ckpt", backend=mesh1)
    assert loaded.n_live == 50 and loaded.n_shards == 1


# ---------------------------------------------------------------------------
# Protocol conformance
# ---------------------------------------------------------------------------

def test_index_and_baselines_satisfy_protocol(rng):
    from repro.baselines import ContiguousIVF, FlatIndex, HNSWLite, LSHIndex
    idx, _ = make(rng)
    cents = rng.normal(size=(4, D)).astype(np.float32)
    engines = [idx, FlatIndex(D, 64), ContiguousIVF(cents, list_cap=32),
               LSHIndex(jax.random.key(0), D, bucket_cap=64), HNSWLite(D)]
    vecs = rng.normal(size=(20, D)).astype(np.float32)
    for eng in engines:
        assert isinstance(eng, sivf.IndexProtocol), type(eng)
        rep = eng.add(vecs, np.arange(20))
        assert rep.accepted == 20, type(eng)
        res = eng.search(vecs[:3], 4)
        d, lab = res                                   # tuple-compat unpack
        assert np.asarray(d).shape == (3, 4)
        assert eng.remove(np.arange(10)).accepted == 10
        assert eng.stats()["n_live"] == eng.n_live == 10


# ---------------------------------------------------------------------------
# 4-shard mesh churn (subprocess: device count fixed before jax init)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np, jax, jax.numpy as jnp
import sivf
from repro import core

rng = np.random.default_rng(3)
D, NL = 16, 8
cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=32, capacity=32,
                      n_max=4096, max_chain=8)
cents = rng.normal(size=(NL, D)).astype(np.float32)
mesh = jax.make_mesh((4,), ("data",))
idx = sivf.Index(cfg, cents, backend=mesh, min_bucket=8)
ref = core.ReferenceIndex(cents)
assert idx.n_shards == 4

# ragged churn with overwrites and eviction against the oracle
next_id = 0
sizes = [5, 17, 9, 30, 3, 21, 14, 8]
for step, s in enumerate(sizes):
    vecs = rng.normal(size=(s, D)).astype(np.float32)
    ids = np.arange(next_id, next_id + s, dtype=np.int32)
    rep = idx.add(vecs, ids)
    assert rep.ok and rep.accepted == s, rep
    ref.insert(vecs, ids)
    next_id += s
    if step % 2:
        over = np.arange(0, next_id, 7, dtype=np.int32)[:6]
        ov = rng.normal(size=(len(over), D)).astype(np.float32)
        present = len(set(over.tolist()) & set(ref.store))
        rep = idx.add(ov, over)
        ref.insert(ov, over)
        assert rep.overwritten == present, (rep, present)
    if next_id > 60:
        evict = np.arange(next_id - 60 - s, next_id - 60, dtype=np.int32)
        idx.remove(evict)
        ref.delete(evict)
    assert idx.n_live == ref.n_live, (idx.n_live, ref.n_live)
    qs = rng.normal(size=(4, D)).astype(np.float32)
    d, l = idx.search(qs, 5, NL)
    rd, rl = ref.search(qs, 5, NL)
    np.testing.assert_allclose(np.asarray(d), rd, rtol=1e-4, atol=1e-4)
    assert (np.asarray(l) == rl).all()

# sharded-aware stats aggregation
st = idx.stats()
assert st["n_shards"] == 4
assert st["n_live"] == ref.n_live
assert sum(st["per_shard_live"]) == st["n_live"]
assert st["error"] == 0

# bounded compiles across the ragged stream (buckets of min_bucket=8);
# lower bound 1 keeps the assertion non-vacuous if the counter breaks
buckets = set(idx._bucket(s) for s in sizes + [6])
comp = idx.compile_stats()
assert 1 <= comp["add"] <= len(buckets), (comp, buckets)
assert 1 <= comp["remove"] <= len(buckets), (comp, buckets)

# ---- partial per-shard failure stays truthful under deferral (ISSUE 3) ----
# shard 0 gets overloaded past its own pool; shards 1-3 commit. The report
# must count shard-0 rows rejected (its overwrites kept old payloads) and
# the other shards' rows accepted, with per-shard bits naming the culprit.
tiny = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=4, capacity=32,
                      n_max=4096, max_chain=2)
tidx = sivf.Index(tiny, cents, backend=mesh, min_bucket=8, deferred=True)
base_ids = np.asarray([0, 4, 8, 1, 2, 3], np.int32)      # 3 on shard 0
base = rng.normal(size=(len(base_ids), D)).astype(np.float32)
f0 = tidx.add(base, base_ids)
over = np.arange(0, 4 * 4 * 32 + 4, 4, dtype=np.int32)   # all shard 0, > pool
n0 = len(over)
others = np.asarray([5, 6, 7], np.int32)                 # shards 1-3 commit
batch_ids = np.concatenate([over, others])
bv = rng.normal(size=(len(batch_ids), D)).astype(np.float32)
f1 = tidx.add(bv, batch_ids)
reps = tidx.flush()
assert reps == [f0.result(), f1.result()]
assert f0.result().ok and f0.result().accepted == len(base_ids)
rep = f1.result()
POOL = sivf.ErrorCode.POOL_EXHAUSTED
assert rep.errors & POOL, rep
assert rep.shard_errors is not None and (rep.shard_errors[0] & POOL)
assert not any(e & POOL for e in rep.shard_errors[1:]), rep.shard_errors
assert rep.accepted == len(others), rep
assert rep.overwritten == 0, rep                          # shard 0 aborted
assert rep.rejected == n0, rep
assert tidx.n_live == len(base_ids) + len(others)
# shard 0's previously-live ids keep their *old* payloads
sq = np.stack([base[0], base[1], base[2]])                # ids 0, 4, 8
d, l = tidx.search(sq, 1, NL)
assert (np.asarray(l)[:, 0] == np.asarray([0, 4, 8])).all(), np.asarray(l)
np.testing.assert_allclose(np.asarray(d)[:, 0], 0, atol=1e-4)

print(json.dumps({"ok": True, "live": idx.n_live,
                  "per_shard": st["per_shard_live"], "compiles": comp,
                  "partial_shard_errors": [int(e) for e in rep.shard_errors]}))
"""


def _run(script, *args):
    return subprocess.run(
        [sys.executable, "-c", script, *args], capture_output=True,
        text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")


def test_sharded_index_handle_churn():
    """ISSUE-2 acceptance: the same handle semantics on a 4-shard mesh,
    plus the ISSUE-3 partial per-shard failure truthfulness under
    deferral (shard 0 aborts atomically, shards 1-3 commit)."""
    r = _run(_MESH_SCRIPT)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"]
    assert sum(out["per_shard"]) == out["live"]
    assert out["partial_shard_errors"][0] & int(sivf.ErrorCode.POOL_EXHAUSTED)
