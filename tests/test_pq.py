"""Product-quantization subsystem (ISSUE 4).

Four layers under test:
  * the codec (``core/pq.py``): train/encode/decode/ADC-table math;
  * state + ingest: uint8 code planes replace fp32 payloads, codes stay
    consistent with ids under churn, failed batches stay atomic;
  * the fused ADC kernel (``kernels/sivf_scan/pq_fused.py``): **bit-exact**
    against the XLA reference ``core.scan_slabs_topk_pq`` — distances AND
    labels — including deleted-slot masking, empty chains, ``k > n_live``
    and ragged query blocking;
  * the session surface: recall oracle on clustered data, stats/memory
    accounting, save/load round-trips on single and sharded backends.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import parity
import sivf
from repro import core
from repro.core import pq

D, NL = 16, 4


def clustered(rng, n, dim=D, n_clusters=8, spread=0.25):
    """Gaussian-mixture vectors (PQ-friendly: codebooks have structure)."""
    centers = rng.normal(size=(n_clusters, dim)).astype(np.float32) * 2.0
    which = rng.integers(0, n_clusters, size=n)
    return (centers[which]
            + spread * rng.normal(size=(n, dim)).astype(np.float32)
            ).astype(np.float32)


def make(rng, m=4, nbits=4, capacity=32, metric="l2", n_slabs=24,
         max_chain=8, store_raw=False, n_train=512):
    """Build scaffolding lives in tests/parity.py; only the clustered
    training distribution is suite-specific."""
    return parity.make_state(
        rng, dim=D, n_lists=NL, n_slabs=n_slabs, capacity=capacity,
        metric=metric, max_chain=max_chain,
        pq=core.PQConfig(m=m, nbits=nbits, store_raw=store_raw),
        train=clustered(rng, n_train))


def load(cfg, state, rng, n, start=0):
    state, vecs, _ = parity.load_rows(cfg, state, rng, n, start=start,
                                      vecs=clustered(rng, n))
    return state, vecs


# ---------------------------------------------------------------------------
# Codec
# ---------------------------------------------------------------------------

def test_pqconfig_validation():
    with pytest.raises(ValueError, match="nbits"):
        core.PQConfig(m=4, nbits=9)
    with pytest.raises(ValueError, match="divisible"):
        core.SIVFConfig(dim=D, n_lists=NL, n_slabs=8,
                        pq=core.PQConfig(m=5))
    assert core.PQConfig(m=8).ksub == 256
    assert core.PQConfig(m=8).code_bytes() == 8


def test_encode_decode_roundtrip(rng):
    xs = clustered(rng, 400)
    cb = pq.train_pq(jax.random.key(1), jnp.asarray(xs), 4, 6, iters=10)
    assert cb.shape == (4, 64, D // 4)
    codes = pq.encode(cb, jnp.asarray(xs))
    assert codes.shape == (400, 4) and codes.dtype == jnp.uint8
    rec = pq.decode(cb, codes)
    mse = float(jnp.mean((rec - xs) ** 2))
    base = float(jnp.mean(jnp.var(jnp.asarray(xs), axis=0)))
    assert mse < 0.5 * base     # trained codebooks beat the data variance
    # encoding is the per-subspace argmin: re-encoding the decode is stable
    assert (np.asarray(pq.encode(cb, rec)) == np.asarray(codes)).all()


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_adc_tables_match_decoded_distance(rng, metric):
    xs = clustered(rng, 256)
    qs = clustered(rng, 9)
    cb = pq.train_pq(jax.random.key(2), jnp.asarray(xs), 4, 4, iters=8)
    codes = pq.encode(cb, jnp.asarray(xs[:32]))
    rec = np.asarray(pq.decode(cb, codes))
    adc = np.asarray(pq.adc_tables(cb, jnp.asarray(qs), metric))  # [Q, m, K]
    got = adc[:, np.arange(4)[None, :], np.asarray(codes, np.int32)]
    got = got.sum(-1)                                             # [Q, 32]
    if metric == "l2":
        want = ((qs[:, None] - rec[None]) ** 2).sum(-1)
    else:
        want = -(qs[:, None] * rec[None]).sum(-1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# State + ingest
# ---------------------------------------------------------------------------

def test_pq_state_replaces_payload_plane(rng):
    cfg, state = make(rng)
    assert state.data.shape == (cfg.n_slabs, cfg.capacity, 0)
    assert state.codes.shape == (cfg.n_slabs, cfg.capacity, 4)
    assert state.codes.dtype == jnp.uint8
    cfg_raw, state_raw = make(rng, store_raw=True)
    assert state_raw.data.shape == (cfg_raw.n_slabs, cfg_raw.capacity, D)


def test_insert_encodes_codes_consistent_with_ids(rng):
    cfg, state = make(rng)
    (state, vecs) = load(cfg, state, rng, 150)
    att_slab = np.asarray(state.att_slab)[:150]
    att_slot = np.asarray(state.att_slot)[:150]
    assert (att_slab >= 0).all()
    got = np.asarray(state.codes)[att_slab, att_slot]
    want = np.asarray(pq.encode(state.pq_codebooks, jnp.asarray(vecs)))
    assert (got == want).all()
    # overwrite re-encodes: new payloads land under the same ids
    new = clustered(rng, 30)
    state = core.insert(cfg, state, jnp.asarray(new),
                        jnp.asarray(np.arange(30), np.int32))
    att_slab = np.asarray(state.att_slab)[:30]
    att_slot = np.asarray(state.att_slot)[:30]
    got = np.asarray(state.codes)[att_slab, att_slot]
    want = np.asarray(pq.encode(state.pq_codebooks, jnp.asarray(new)))
    assert (got == want).all()


def test_failed_batch_leaves_old_codes_searchable(rng):
    """Atomicity extends to the code plane: a POOL_EXHAUSTED batch changes
    neither the ATT nor any stored code, and a full-probe search still
    returns exactly the previously-live id set."""
    cfg, state = make(rng, n_slabs=4, max_chain=2)
    (state, vecs) = load(cfg, state, rng, 40)
    codes_before = np.asarray(state.codes).copy()
    att_before = np.asarray(state.att_slab).copy()
    n = 4 * 32 + 50                              # provably > free capacity
    state = core.insert(
        cfg, state, jnp.asarray(clustered(rng, n)),
        jnp.asarray(np.arange(100, 100 + n), np.int32))
    assert int(state.error) & core.ERR_POOL_EXHAUSTED
    assert (np.asarray(state.codes) == codes_before).all()
    assert (np.asarray(state.att_slab) == att_before).all()
    qs = jnp.asarray(clustered(rng, 3))
    _, labels = core.search(cfg, state, qs, 40, NL)
    got = set(np.asarray(labels).ravel().tolist()) - {-1}
    assert got == set(range(40))


# ---------------------------------------------------------------------------
# Fused ADC kernel: bit-exact parity vs the XLA reference
# ---------------------------------------------------------------------------

pq_kernel = pytest.mark.pallas


def assert_pq_fused_matches_ref(cfg, state, rng, k, nprobe, q=5, block_q=8,
                                use_tables=True):
    from repro.kernels.sivf_scan.pq_fused import sivf_pq_fused_search_pallas
    qs = jnp.asarray(clustered(rng, q))
    lists = core.probe(state.centroids, qs, nprobe, cfg.metric)
    table = (core.gather_tables if use_tables else core.walk_chains)(
        cfg, state, lists)
    # one materialized ADC table feeds both backends — exactly what
    # core._scan_dispatch does — so parity is structural, not rounding luck
    adc = pq.adc_tables(state.pq_codebooks, qs, cfg.metric)
    dr, lr = core.scan_slabs_topk_pq(cfg, state, qs, table, k, adc=adc)
    df, lf = sivf_pq_fused_search_pallas(
        adc, table, state.codes, state.ids, state.bitmap, k,
        block_q=block_q, interpret=True)
    # acceptance: BIT-exact — same tables, same summation order, same fold;
    # not merely allclose
    assert (np.asarray(df) == np.asarray(dr)).all(), (df, dr)
    assert (np.asarray(lf) == np.asarray(lr)).all()
    return np.asarray(df), np.asarray(lf)


@pq_kernel
@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("m,nbits", [(4, 4), (8, 5)])
def test_pq_fused_parity(rng, metric, m, nbits):
    cfg, state = make(rng, m=m, nbits=nbits, metric=metric)
    state, _ = load(cfg, state, rng, 200)
    assert_pq_fused_matches_ref(cfg, state, rng, k=7, nprobe=2)


@pq_kernel
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_pq_fused_deleted_slot_masking(rng, metric):
    cfg, state = make(rng, metric=metric)
    state, _ = load(cfg, state, rng, 200)
    dels = np.arange(0, 200, 3, dtype=np.int32)
    state = core.delete(cfg, state, jnp.asarray(dels))
    _, lf = assert_pq_fused_matches_ref(cfg, state, rng, k=9, nprobe=NL)
    live = lf[lf >= 0]
    assert not np.isin(live, dels).any()


@pq_kernel
def test_pq_fused_empty_chains(rng):
    cfg, state = make(rng)
    vecs = clustered(rng, 40)
    state = core.insert(cfg, state, jnp.asarray(vecs),
                        jnp.asarray(np.arange(40), np.int32),
                        jnp.zeros((40,), jnp.int32))   # single list only
    assert_pq_fused_matches_ref(cfg, state, rng, k=5, nprobe=NL)


@pq_kernel
def test_pq_fused_fully_empty_index(rng):
    cfg, state = make(rng)
    df, lf = assert_pq_fused_matches_ref(cfg, state, rng, k=4, nprobe=NL)
    assert np.isinf(df).all() and (lf == -1).all()


@pq_kernel
def test_pq_fused_k_exceeds_n_live(rng):
    cfg, state = make(rng)
    state, _ = load(cfg, state, rng, 6)
    df, lf = assert_pq_fused_matches_ref(cfg, state, rng, k=16, nprobe=NL)
    assert np.isinf(df[:, -1]).all()
    assert (np.sort(lf, axis=1) != -1).sum(axis=1).max() <= 6


@pq_kernel
@pytest.mark.parametrize("q,block_q", [(1, 8), (5, 4), (8, 8), (13, 8)])
def test_pq_fused_ragged_query_blocking(rng, q, block_q):
    cfg, state = make(rng)
    state, _ = load(cfg, state, rng, 150)
    assert_pq_fused_matches_ref(cfg, state, rng, k=5, nprobe=2, q=q,
                                block_q=block_q)


@pq_kernel
def test_pq_fused_pointer_walk_table(rng):
    cfg, state = make(rng)
    state, _ = load(cfg, state, rng, 150)
    state = core.delete(cfg, state,
                        jnp.asarray(np.arange(0, 150, 2), np.int32))
    assert_pq_fused_matches_ref(cfg, state, rng, k=5, nprobe=NL,
                                use_tables=False)


@pq_kernel
def test_pq_fused_randomized_churn(rng):
    cfg, state = make(rng, n_slabs=48, max_chain=12)
    rows: dict = {}
    for step in range(5):
        state, rows = parity.churn(cfg, state, rng, steps=1, rows=rows)
        assert_pq_fused_matches_ref(cfg, state, rng, k=8,
                                    nprobe=int(rng.integers(1, NL + 1)),
                                    q=int(rng.integers(1, 7)))


@pq_kernel
def test_pq_search_dispatch_parity(rng):
    """core.search impl="pallas_interpret" == impl="xla", bit-for-bit
    (exact_dist comes from cfg.pq in the shared helper)."""
    cfg, state = make(rng)
    state, _ = load(cfg, state, rng, 180)
    state = core.delete(cfg, state,
                        jnp.asarray(np.arange(0, 180, 4), np.int32))
    parity.assert_search_parity(cfg, state, rng, k=5, nprobe=3,
                                queries=clustered(rng, 6))


# ---------------------------------------------------------------------------
# Recall oracle
# ---------------------------------------------------------------------------

def test_pq_recall_oracle(rng):
    """ADC recall@10 vs exact fp32 search >= 0.8 on clustered data.

    300 planted clusters of 10 near-neighbors each (the query's true top-10
    is its cluster; spread 0.4 vs inter-cluster distances ~sqrt(2*dim)*2,
    so the ranking is non-trivial but resolvable). Full probe, so coarse
    quantization contributes no loss — the gap under test is purely the PQ
    approximation (m=8 subspaces of 4 dims, 6 bits = 8 B/vector vs 128 B
    fp32). Measured headroom: recall ~1.0 at these settings; the 0.8 floor
    is the ISSUE acceptance bar and catches codec/ADC regressions.
    """
    dim, k, ngroups, per = 32, 10, 300, 10
    gcent = rng.normal(size=(ngroups, dim)).astype(np.float32) * 2.0
    xs = (np.repeat(gcent, per, axis=0)
          + 0.4 * rng.normal(size=(ngroups * per, dim))).astype(np.float32)
    n = len(xs)
    cfg = core.SIVFConfig(dim=dim, n_lists=8, n_slabs=160, capacity=32,
                          n_max=4096, max_chain=64,
                          pq=core.PQConfig(m=8, nbits=6))
    cents = core.train_kmeans(jax.random.key(3), jnp.asarray(xs), 8)
    idx = sivf.Index(cfg, cents, min_bucket=64).train(xs[:2000], iters=25)
    assert idx.add(xs, np.arange(n)).ok
    qs = (gcent[rng.integers(0, ngroups, size=64)]
          + 0.4 * rng.normal(size=(64, dim))).astype(np.float32)
    res = idx.search(qs, k)                        # nprobe=None: full probe
    d = ((qs[:, None] - xs[None]) ** 2).sum(-1)
    true = np.argsort(d, axis=1, kind="stable")[:, :k]
    pred = np.asarray(res.labels)
    hits = [len(set(pred[i].tolist()) & set(true[i].tolist()))
            for i in range(len(qs))]
    recall = float(np.mean(hits)) / k
    assert recall >= 0.8, f"PQ recall@10 {recall:.3f} < 0.8"


# ---------------------------------------------------------------------------
# Session surface: stats, save/load (single + sharded), mesh parity
# ---------------------------------------------------------------------------

def _session(rng, backend="single", **kw):
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=48, capacity=32,
                          n_max=2048, max_chain=12,
                          pq=sivf.PQConfig(m=4, nbits=4))
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    idx = sivf.Index(cfg, cents, backend=backend, min_bucket=8, **kw)
    idx.train(clustered(rng, 512), key=jax.random.key(7))
    return cfg, cents, idx


def test_stats_reports_compression(rng):
    cfg, _, idx = _session(rng)
    idx.add(clustered(rng, 100), np.arange(100))
    s = idx.stats()
    assert s["payload_bytes"] == 0
    assert s["code_bytes"] == cfg.n_slabs * cfg.capacity * 4
    assert s["compression_ratio"] == pytest.approx(D * 4 / 4)
    # store_raw keeps the fp32 plane: ratio < 1 (codes are pure overhead)
    mr = sivf.memory_report(dataclasses.replace(
        cfg, pq=sivf.PQConfig(m=4, nbits=4, store_raw=True)))
    assert mr["payload_bytes"] > 0 and mr["compression_ratio"] < 1.0
    # non-PQ configs don't advertise a ratio through stats
    plain = sivf.Index(dataclasses.replace(cfg, pq=None),
                       rng.normal(size=(NL, D)).astype(np.float32))
    assert "compression_ratio" not in plain.stats()
    assert plain.stats()["code_bytes"] == 0


def test_stats_sharded_aggregates(rng):
    mesh = jax.make_mesh((1,), ("data",))
    cfg, _, idx = _session(rng, backend=mesh)
    idx.add(clustered(rng, 60), np.arange(60))
    s = idx.stats()
    assert s["n_shards"] == 1
    assert s["code_bytes"] == cfg.n_slabs * cfg.capacity * 4
    assert s["compression_ratio"] == pytest.approx(16.0)


def test_pq_save_load_single(rng, tmp_path):
    _, _, idx = _session(rng)
    vecs = clustered(rng, 120)
    idx.add(vecs, np.arange(120))
    idx.remove(np.arange(0, 120, 7))
    idx.save(tmp_path)
    back = sivf.Index.load(tmp_path)
    assert back.cfg.pq == idx.cfg.pq
    assert (np.asarray(back.state.codes) == np.asarray(idx.state.codes)).all()
    qs = clustered(rng, 6)
    a, b = idx.search(qs, 5), back.search(qs, 5)
    assert (np.asarray(a.distances) == np.asarray(b.distances)).all()
    assert (np.asarray(a.labels) == np.asarray(b.labels)).all()
    # trainedness survives the round trip: ingest keeps working
    assert back.add(clustered(rng, 8), np.arange(500, 508)).ok


def test_pq_save_load_sharded(rng, tmp_path):
    mesh = jax.make_mesh((1,), ("data",))
    _, _, idx = _session(rng, backend=mesh)
    vecs = clustered(rng, 120)
    idx.add(vecs, np.arange(120))
    idx.save(tmp_path)
    back = sivf.Index.load(tmp_path, backend=mesh)
    assert back.backend == "mesh" and back.cfg.pq == idx.cfg.pq
    qs = clustered(rng, 6)
    a, b = idx.search(qs, 5), back.search(qs, 5)
    assert (np.asarray(a.distances) == np.asarray(b.distances)).all()
    assert (np.asarray(a.labels) == np.asarray(b.labels)).all()
    assert back.add(clustered(rng, 8), np.arange(500, 508)).ok


def test_pq_mesh_matches_single(rng):
    mesh = jax.make_mesh((1,), ("data",))
    _, _, single = _session(rng)
    rng2 = np.random.default_rng(0)
    _, _, sharded = _session(rng2, backend=mesh)
    vecs = clustered(np.random.default_rng(5), 200)
    for idx in (single, sharded):
        idx.add(vecs, np.arange(200))
        idx.remove(np.arange(0, 200, 3))
    qs = clustered(np.random.default_rng(6), 7)
    a, b = single.search(qs, 6), sharded.search(qs, 6)
    assert (np.asarray(a.labels) == np.asarray(b.labels)).all()
    np.testing.assert_allclose(np.asarray(a.distances),
                               np.asarray(b.distances), rtol=1e-6)


def test_train_guards(rng):
    cfg = sivf.SIVFConfig(dim=D, n_lists=NL, n_slabs=8, capacity=32,
                          pq=sivf.PQConfig(m=4, nbits=4))
    cents = rng.normal(size=(NL, D)).astype(np.float32)
    idx = sivf.Index(cfg, cents)
    with pytest.raises(RuntimeError, match="untrained"):
        idx.add(clustered(rng, 4), np.arange(4))
    idx.train(clustered(rng, 256))
    idx.add(clustered(rng, 4), np.arange(4))
    with pytest.raises(RuntimeError, match="non-empty"):
        idx.train(clustered(rng, 256))
    plain = sivf.Index(dataclasses.replace(cfg, pq=None), cents)
    with pytest.raises(RuntimeError, match="pq"):
        plain.train(clustered(rng, 256))
    with pytest.raises(ValueError, match="pq_codebooks"):
        sivf.Index(dataclasses.replace(cfg, pq=None), cents,
                   pq_codebooks=np.zeros((4, 16, 4), np.float32))
