"""Sharding plans: padding math, rule resolution, padded-head inertness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import model as M
from repro.sharding.axes import logical_axes, spec_for, strip
from repro.sharding.rules import make_plan, unpadded_plan

MESH = {"data": 16, "model": 16}


@pytest.mark.parametrize("arch,hq,hkv,kv_sharded", [
    ("llama3-8b", 32, 8, False),          # divisible q, replicated kv
    ("qwen3-14b", 48, 8, False),          # remap padding 40->48
    ("phi3-medium-14b", 48, 12, False),   # ratio-preserving pad (g=4)
    ("llava-next-34b", 64, 8, False),     # remap 56->64
    ("granite-moe-3b-a800m", 32, 8, False),
    ("moonshot-v1-16b-a3b", 16, 16, True),
    ("minicpm3-4b", 48, 48, True),        # MLA: heads pad together
    ("rwkv6-3b", 48, 48, True),
    ("jamba-v0.1-52b", 32, 8, False),
    ("whisper-base", 16, 16, True),
])
def test_head_padding_policy(arch, hq, hkv, kv_sharded):
    cfg = ARCHS[arch]
    plan = make_plan(cfg, MESH, "train", 256)
    assert plan.n_heads_padded == hq, plan
    assert plan.n_kv_heads_padded == hkv
    assert plan.kv_sharded == kv_sharded
    # invariants: padded counts shard / group cleanly
    assert plan.n_heads_padded % MESH["model"] == 0 or not plan.kv_sharded
    assert plan.n_heads_padded % plan.n_kv_heads_padded == 0
    assert plan.n_heads_padded >= cfg.n_heads
    assert plan.vocab_padded % (16 * 128) == 0
    assert plan.vocab_padded >= cfg.vocab_size
    if cfg.moe:
        assert plan.n_experts_padded % 16 == 0
        assert plan.n_experts_padded >= cfg.n_experts


def test_decode_cache_exactly_one_model_axis():
    """The decode cache maps the model axis to exactly one of
    (kv-head axis, head_dim axis) — never both, never neither."""
    for name, cfg in ARCHS.items():
        if cfg.attention == "none" and cfg.block == "rwkv":
            continue                    # no attention cache
        plan = make_plan(cfg, MESH, "decode", 128)
        r = plan.rules_dict
        head_rule = r["heads" if cfg.attention == "mla" else "kv_heads"]
        dh_rule = r["kv_dh"]
        on_model = [x for x in (head_rule, dh_rule) if x == "model"]
        assert len(on_model) == 1, (name, head_rule, dh_rule)
        assert r["kv_seq"] is None      # seq sharding refuted (§Perf it.3)


def test_long_context_batch1_plan():
    cfg = ARCHS["jamba-v0.1-52b"]
    plan = make_plan(cfg, {"pod": 2, "data": 16, "model": 16}, "decode", 1)
    r = plan.rules_dict
    assert r["batch"] is None
    assert r["kv_dh"] == "model"        # kv=8 replicated -> dh shards


def test_spec_resolution():
    plan = make_plan(ARCHS["llama3-8b"], MESH, "train", 256)
    from jax.sharding import PartitionSpec as P
    r = plan.rules_dict
    assert spec_for(("embed", "mlp"), r) == P(None, "model")
    assert spec_for(("batch", "seq_sp", None), r) == P("data", "model", None)
    assert spec_for((None, None), r) == P(None, None)


def test_padded_heads_are_inert(rng):
    """Perturbing padding-head weights must not change the output."""
    cfg = ARCHS["llama3-8b"].reduced()   # 4 heads / 2 kv
    plan = unpadded_plan(cfg)
    plan = dataclasses.replace(plan, n_heads_padded=6)   # 2 pad heads, g=3
    params = strip(M.init_params(cfg, plan, jax.random.key(0), max_seq=16))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)),
                                   jnp.int32)}
    l1, _, _ = M.forward(params, cfg, plan, batch)

    def poison(path_params):
        lp = path_params["layers"][0]
        dh = cfg.head_dim
        # q rows of padded heads + their out-proj rows
        wq = lp["attn"]["wq"]
        wq = wq.at[:, cfg.n_heads * dh:].set(99.0)
        wo = lp["attn"]["wo"].at[cfg.n_heads * dh:, :].set(99.0)
        lp = dict(lp, attn=dict(lp["attn"], wq=wq, wo=wo))
        out = dict(path_params)
        out["layers"] = [lp] + list(path_params["layers"][1:])
        return out

    l2, _, _ = M.forward(poison(params), cfg, plan, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6,
                               atol=1e-6)


def test_annotations_cover_all_params():
    """Every param leaf is annotated with axes matching its rank."""
    for name in ("llama3-8b", "jamba-v0.1-52b", "whisper-base",
                 "minicpm3-4b", "rwkv6-3b"):
        cfg = ARCHS[name].reduced()
        plan = unpadded_plan(cfg)
        tree = M.init_params(cfg, plan, jax.random.key(0), max_seq=16)
        vals = strip(tree)
        axs = logical_axes(tree)
        for v, a in zip(jax.tree.leaves(vals),
                        jax.tree.leaves(axs, is_leaf=lambda x:
                                        isinstance(x, tuple))):
            assert v.ndim == len(a), (name, v.shape, a)


def test_abstract_params_match_concrete():
    """eval_shape param tree == shapes of the real init (dry-run soundness)."""
    from repro.launch.specs import abstract_params
    cfg = ARCHS["llama3-8b"].reduced()
    plan = unpadded_plan(cfg)
    abst = strip(abstract_params(cfg, plan, max_seq=16))
    conc = strip(M.init_params(cfg, plan, jax.random.key(0), max_seq=16))
    ja, jc = jax.tree.leaves(abst), jax.tree.leaves(conc)
    assert len(ja) == len(jc)
    for a, c in zip(ja, jc):
        assert a.shape == c.shape and a.dtype == c.dtype
