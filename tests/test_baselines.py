"""Baselines used in the paper's comparisons (§5.7 Table 4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import ContiguousIVF, FlatIndex, HNSWLite, LSHIndex
from repro.core import ReferenceIndex, train_kmeans

D = 24


@pytest.fixture
def data(rng):
    vecs = rng.normal(size=(250, D)).astype(np.float32)
    ids = np.arange(250, dtype=np.int32)
    qs = rng.normal(size=(5, D)).astype(np.float32)
    ref = ReferenceIndex(np.zeros((1, D), np.float32))
    ref.insert(vecs, ids)
    ref.delete(ids[::2])
    return vecs, ids, qs, ref


def test_flat_exact(data):
    vecs, ids, qs, ref = data
    ix = FlatIndex(D, 512)
    ix.insert(vecs, ids)
    ix.delete(ids[::2])
    d, lab = ix.search(qs, 5)
    rd, rl = ref.search(qs, 5, 1)
    np.testing.assert_allclose(np.asarray(d), rd, rtol=1e-4, atol=1e-4)
    assert (np.asarray(lab) == rl).all()
    assert ix.n_live == ref.n_live


def test_contiguous_ivf_exact_full_probe(data, rng):
    vecs, ids, qs, ref = data
    cents = np.asarray(train_kmeans(jax.random.key(0), jnp.asarray(vecs), 8))
    ix = ContiguousIVF(cents, list_cap=8)
    ix.insert(vecs, ids)
    assert ix.n_relayouts > 0          # 2x growth exercised
    ix.delete(ids[::2])
    d, lab = ix.search(qs, 5, 8)
    rd, rl = ref.search(qs, 5, 1)
    np.testing.assert_allclose(np.asarray(d), rd, rtol=1e-4, atol=1e-4)
    assert (np.asarray(lab) == rl).all()


def test_lsh_recall_reasonable(data):
    vecs, ids, qs, ref = data
    ix = LSHIndex(jax.random.key(1), D, n_tables=6, bits=4, bucket_cap=128)
    ix.insert(vecs, ids)
    ix.delete(ids[::2])
    d, lab = ix.search(qs, 5)
    rd, rl = ref.search(qs, 5, 1)
    rec = np.mean([len(set(np.asarray(lab)[i].tolist())
                       & set(rl[i].tolist())) / 5 for i in range(len(qs))])
    assert rec > 0.3


def test_hnsw_lite_recall_and_rebuild(data):
    vecs, ids, qs, ref = data
    ix = HNSWLite(D, m=8, ef=48)
    ix.insert(vecs, ids)
    ix.delete(ids[::2])                # forces full rebuild
    assert ix.n_live == ref.n_live
    d, lab = ix.search(qs, 5)
    rd, rl = ref.search(qs, 5, 1)
    rec = np.mean([len(set(np.asarray(lab)[i].tolist())
                       & set(rl[i].tolist())) / 5 for i in range(len(qs))])
    assert rec > 0.7
