"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core


# -- sivf_scan ----------------------------------------------------------------

@pytest.mark.parametrize("capacity,d,metric", [
    (32, 16, "l2"), (64, 32, "l2"), (128, 128, "l2"), (32, 16, "ip"),
])
def test_sivf_scan_sweep(rng, capacity, d, metric):
    from repro.kernels.sivf_scan import ops as scan_ops
    from repro.kernels.sivf_scan.ref import sivf_scan_ref
    nl = 4
    cfg = core.SIVFConfig(dim=d, n_lists=nl, n_slabs=16, capacity=capacity,
                          n_max=2048, metric=metric, max_chain=8)
    cents = rng.normal(size=(nl, d)).astype(np.float32)
    state = core.init_state(cfg, jnp.asarray(cents))
    n = 200
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    state = core.insert(cfg, state, jnp.asarray(vecs),
                        jnp.asarray(np.arange(n), np.int32))
    state = core.delete(cfg, state, jnp.asarray(np.arange(0, n, 3),
                                                np.int32))
    qs = rng.normal(size=(4, d)).astype(np.float32)
    lists = core.probe(state.centroids, jnp.asarray(qs), 2)
    table = core.gather_tables(cfg, state, lists)
    args = (jnp.asarray(qs), table, state.data, state.ids, state.norms,
            state.bitmap)
    dr, lr = sivf_scan_ref(*args, metric=metric)
    dp, lp = scan_ops.sivf_scan(*args, metric=metric, interpret=True)
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dr), rtol=1e-5,
                               atol=1e-5)
    assert (np.asarray(lp) == np.asarray(lr)).all()


# -- topk ----------------------------------------------------------------------

@pytest.mark.parametrize("q,nl,k", [(8, 64, 5), (16, 256, 17), (3, 128, 1)])
def test_topk_sweep(rng, q, nl, k):
    from repro.kernels.topk import ops as topk_ops
    from repro.kernels.topk.ref import topk_ref
    d = rng.normal(size=(q, nl)).astype(np.float32)
    d[rng.random(size=(q, nl)) < 0.2] = np.inf      # dead slots
    lab = rng.integers(0, 1000, (q, nl)).astype(np.int32)
    td, tl = topk_ops.topk(jnp.asarray(d), jnp.asarray(lab), k,
                           interpret=True)
    rd, rl = topk_ref(jnp.asarray(d), jnp.asarray(lab), k)
    np.testing.assert_allclose(np.asarray(td), np.asarray(rd), rtol=1e-6)
    # labels may differ only where distances tie / are inf
    mism = np.asarray(tl) != np.asarray(rl)
    assert not (mism & np.isfinite(np.asarray(rd))).any()


# -- flash attention ------------------------------------------------------------

@pytest.mark.parametrize("sq,sk,hq,hkv,dh,causal,dtype", [
    (64, 64, 4, 2, 32, True, jnp.float32),
    (64, 64, 4, 4, 16, False, jnp.float32),
    (32, 64, 2, 1, 64, True, jnp.float32),   # chunked decode window
    (64, 64, 4, 2, 32, True, jnp.bfloat16),
])
def test_flash_attention_sweep(rng, sq, sk, hq, hkv, dh, causal, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import mha_ref
    b = 2
    q = jnp.asarray(rng.normal(size=(b, hq, sq, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, sk, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, sk, dh)), dtype)
    o1 = flash_attention(q, k, v, causal=causal, interpret=True,
                         block_q=32, block_k=32)
    o2 = mha_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), rtol=tol,
                               atol=tol)


# -- paged attention -------------------------------------------------------------

@pytest.mark.parametrize("page,maxp,hq,hkv,dh", [
    (16, 4, 4, 2, 32), (32, 3, 2, 2, 64), (8, 6, 8, 2, 16),
])
def test_paged_attention_sweep(rng, page, maxp, hq, hkv, dh):
    from repro.kernels.paged_attention.ops import paged_attention
    from repro.kernels.paged_attention.ref import paged_attention_ref
    b, n_pages = 3, 24
    q = rng.normal(size=(b, hq, dh)).astype(np.float32)
    kp = rng.normal(size=(n_pages, page, hkv, dh)).astype(np.float32)
    vp = rng.normal(size=(n_pages, page, hkv, dh)).astype(np.float32)
    tables = np.full((b, maxp), -1, np.int32)
    lengths = np.zeros((b,), np.int32)
    starts = np.zeros((b,), np.int32)
    perm = rng.permutation(n_pages)
    c = 0
    for i in range(b):
        n = int(rng.integers(1, maxp + 1))
        tables[i, :n] = perm[c: c + n]
        c += n
        lengths[i] = int(rng.integers(1, n * page + 1))
        starts[i] = int(rng.integers(0, lengths[i]))
    args = (jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(tables), jnp.asarray(lengths))
    o1 = paged_attention(*args, starts=jnp.asarray(starts), interpret=True)
    o2 = paged_attention_ref(*args, starts=jnp.asarray(starts))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4,
                               atol=2e-4)


# -- wkv6 -------------------------------------------------------------------------

@pytest.mark.parametrize("t,h,dk,dv", [(8, 2, 8, 8), (16, 3, 16, 16)])
def test_wkv6_sweep(rng, t, h, dk, dv):
    from repro.kernels.wkv6.ops import wkv6
    from repro.kernels.wkv6.ref import wkv6_ref
    b = 2
    r = rng.normal(size=(b, t, h, dk)).astype(np.float32)
    k = rng.normal(size=(b, t, h, dk)).astype(np.float32)
    v = rng.normal(size=(b, t, h, dv)).astype(np.float32)
    w = rng.uniform(0.2, 0.99, size=(b, t, h, dk)).astype(np.float32)
    u = rng.normal(size=(h, dk)).astype(np.float32)
    o1 = wkv6(r, k, v, w, u, interpret=True)
    o2 = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4,
                               atol=2e-4)


def test_wkv6_matches_model_sequential_path(rng):
    """The model's scan-of-checkpointed-scans == the kernel == the ref."""
    from repro.kernels.wkv6.ref import wkv6_ref
    from repro.models.rwkv import _wkv_sequential
    b, t, h, dk = 2, 16, 2, 8
    r = rng.normal(size=(b, t, h, dk)).astype(np.float32)
    k = rng.normal(size=(b, t, h, dk)).astype(np.float32)
    v = rng.normal(size=(b, t, h, dk)).astype(np.float32)
    w = rng.uniform(0.2, 0.99, size=(b, t, h, dk)).astype(np.float32)
    u = rng.normal(size=(h, dk)).astype(np.float32)
    s0 = np.zeros((b, h, dk, dk), np.float32)
    y, _ = _wkv_sequential(jnp.asarray(r), jnp.asarray(k), jnp.asarray(v),
                           jnp.asarray(w), jnp.asarray(u), jnp.asarray(s0),
                           chunk=4)
    ref = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


# -- mamba scan -------------------------------------------------------------------

@pytest.mark.parametrize("t,di,n,bd", [(8, 16, 4, 8), (12, 32, 8, 16)])
def test_mamba_scan_sweep(rng, t, di, n, bd):
    from repro.kernels.mamba_scan.ops import mamba_scan
    from repro.kernels.mamba_scan.ref import mamba_scan_ref
    b = 2
    u = rng.normal(size=(b, t, di)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, size=(b, t, di)).astype(np.float32)
    a = -rng.uniform(0.5, 2, size=(di, n)).astype(np.float32)
    bb = rng.normal(size=(b, t, n)).astype(np.float32)
    cc = rng.normal(size=(b, t, n)).astype(np.float32)
    dd = rng.normal(size=(di,)).astype(np.float32)
    o1 = mamba_scan(u, dt, a, bb, cc, dd, interpret=True, block_d=bd)
    o2 = mamba_scan_ref(u, dt, a, bb, cc, dd)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4,
                               atol=2e-4)


def test_mamba_matches_model_sequential_path(rng):
    from repro.kernels.mamba_scan.ref import mamba_scan_ref
    from repro.models.mamba import _ssm_sequential
    b, t, di, n = 2, 16, 8, 4
    u = rng.normal(size=(b, t, di)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, size=(b, t, di)).astype(np.float32)
    a = -rng.uniform(0.5, 2, size=(di, n)).astype(np.float32)
    bb = rng.normal(size=(b, t, n)).astype(np.float32)
    cc = rng.normal(size=(b, t, n)).astype(np.float32)
    dd = rng.normal(size=(di,)).astype(np.float32)
    h0 = jnp.zeros((b, di, n), jnp.float32)
    y, _ = _ssm_sequential(jnp.asarray(u), jnp.asarray(dt), jnp.asarray(a),
                           jnp.asarray(bb), jnp.asarray(cc),
                           jnp.asarray(dd), h0, chunk=4)
    ref = mamba_scan_ref(u, dt, a, bb, cc, dd)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


# -- chunked attention (xla fast path) ----------------------------------------------

def test_chunked_sdpa_matches_direct(rng):
    from repro.models.attention import _sdpa_chunked, _sdpa_grouped
    b, s, hq, hkv, dh = 2, 4096, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, hq, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, dh)), jnp.float32)
    pos = jnp.arange(s)
    for causal in (True, False):
        a = _sdpa_grouped(q, k, v, pos, pos, causal, dh ** -0.5)
        c = _sdpa_chunked(q, k, v, pos, pos, causal, dh ** -0.5)
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-5,
                                   atol=1e-5)
