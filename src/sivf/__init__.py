"""``sivf`` — the client-facing namespace of the SIVF reproduction.

One import gives the whole streaming-session surface:

    import sivf

    cfg = sivf.SIVFConfig(dim=64, n_lists=32, n_slabs=512)
    centroids = sivf.train_kmeans(key, train_vecs, cfg.n_lists)
    index = sivf.Index(cfg, centroids)          # or backend=<jax Mesh>
    report = index.add(vecs, ids)               # -> MutationReport
    dists, labels = index.search(queries, k=10, nprobe=8)

Everything re-exported here lives in ``repro.core`` (the functional API
remains importable from there); this package is the stable alias clients
should depend on.
"""
from repro.core.api import (  # noqa: F401
    ErrorCode,
    Index,
    IndexProtocol,
    MaintenanceAborted,
    MutationRejected,
    MutationReport,
    PendingReport,
    SearchResult,
)
from repro.core.distributed import (  # noqa: F401
    flatten_live_rows,
    reshard_state,
    search_stacked,
)
from repro.core.filters import (  # noqa: F401
    And,
    CompiledFilter,
    Eq,
    In,
    Range,
    compile_filter,
)
from repro.core.maintenance import (  # noqa: F401
    MaintenanceReport,
    MaintOp,
    merge,
    recluster,
    split,
)
from repro.core.pq import PQConfig, train_pq  # noqa: F401
from repro.core.quantizer import train_kmeans  # noqa: F401
from repro.core.state import SIVFConfig, init_state, memory_report  # noqa: F401
from repro.serve.quota import (  # noqa: F401
    Backpressure,
    BackpressureKind,
    TenantQuota,
)
from repro.serve.session import (  # noqa: F401
    ClientSession,
    ServeMaintenanceResult,
    ServeMutationResult,
    ServeSearchResult,
)
from repro.serve.sivf_engine import ServeEngine  # noqa: F401

from sivf import telemetry  # noqa: F401  (import after repro: avoids cycles)

__all__ = [
    "And", "Backpressure", "BackpressureKind", "ClientSession",
    "CompiledFilter", "Eq", "ErrorCode", "In", "Index", "IndexProtocol",
    "MaintOp", "MaintenanceAborted", "MaintenanceReport",
    "MutationRejected", "MutationReport", "PendingReport", "PQConfig",
    "Range", "SearchResult", "ServeEngine", "ServeMaintenanceResult",
    "ServeMutationResult", "ServeSearchResult", "SIVFConfig", "TenantQuota",
    "compile_filter", "flatten_live_rows", "init_state", "memory_report",
    "merge", "recluster", "reshard_state", "search_stacked", "split",
    "telemetry", "train_kmeans", "train_pq",
]
