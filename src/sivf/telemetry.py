"""sivf.telemetry — public facade over the process-default Telemetry.

Quickstart::

    import sivf.telemetry as telemetry

    telemetry.enable(slow_threshold_s=0.025)
    ...serve traffic...
    snap = telemetry.snapshot()          # JSON-able dict
    text = telemetry.render_prometheus() # text exposition for a scrape

Handles constructed with an explicit ``telemetry=`` record into their
own instance instead; ``engine.telemetry()`` / ``index.telemetry()``
snapshot whichever instance the handle uses.
"""
from __future__ import annotations

from repro import obs as _obs
from repro.obs import Telemetry, disable, enable

__all__ = ["Telemetry", "enable", "disable", "get", "snapshot",
           "snapshot_json", "render_prometheus", "slow_queries",
           "roll_window"]


def get() -> Telemetry:
    """The process-default :class:`Telemetry` instance."""
    return _obs.default()


def snapshot() -> dict:
    """JSON-able snapshot (metrics + slow-query log) of the default
    Telemetry."""
    return _obs.default().snapshot()


def snapshot_json(indent: int | None = None) -> str:
    return _obs.snapshot_json(_obs.default(), indent=indent)


def render_prometheus() -> str:
    """Prometheus text exposition of the default Telemetry."""
    return _obs.default().render_prometheus()


def slow_queries() -> list[dict]:
    """Current slow-query log entries, slowest first."""
    return _obs.default().slow_queries()


def roll_window() -> None:
    """Start a new window for every counter's windowed reads."""
    return _obs.default().roll_window()
