"""Moonlight-16B-A3B (kimi/moonshot) — MoE decoder, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf] 48L d_model=2048 16H (kv=16)
expert d_ff=1408 vocab=163840, 64e top-6 + 2 shared experts.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,            # dense fallback dim (unused: all layers MoE)
    vocab_size=163840,
    head_dim=128,
    moe=True,
    n_experts=64,
    moe_top_k=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    rope_theta=50000.0,
)
