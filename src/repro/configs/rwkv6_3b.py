"""RWKV6-3B (Finch) — attention-free RNN with data-dependent decay.

[arXiv:2404.05892; hf] 32L d_model=2560 d_ff=8960 vocab=65536,
head_size 64 (40 wkv heads). O(1)-state decode => long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    attention="none",
    block="rwkv",
    rwkv_head_size=64,
    norm="layernorm",
    subquadratic=True,
)
