"""Granite-MoE-3B-A800M — MoE decoder, 40 experts top-8.

[hf:ibm-granite family; hf] 32L d_model=1536 24H (GQA kv=8) expert
d_ff=512 vocab=49155, 40e top-8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    moe=True,
    n_experts=40,
    moe_top_k=8,
    moe_d_ff=512,
    rope_theta=10000.0,
)
