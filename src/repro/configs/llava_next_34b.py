"""LLaVA-NeXT-34B — VLM: LM backbone + anyres vision stub.

[hf:llava-hf family; unverified] 60L d_model=7168 56H (GQA kv=8)
d_ff=20480 vocab=64000. The modality frontend is a STUB per the
assignment: input_specs() provides precomputed patch embeddings that
replace the first ``n_prefix_embeds`` positions of the sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    rope_theta=5000000.0,
    frontend="vision_stub",
    n_prefix_embeds=576,   # one anyres tile of 24x24 patches
)
