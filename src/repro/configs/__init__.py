"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.configs import (
    minicpm3_4b, qwen3_14b, phi3_medium_14b, llama3_8b, llava_next_34b,
    moonshot_v1_16b_a3b, granite_moe_3b_a800m, rwkv6_3b, jamba_v0_1_52b,
    whisper_base,
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        minicpm3_4b.CONFIG,
        qwen3_14b.CONFIG,
        phi3_medium_14b.CONFIG,
        llama3_8b.CONFIG,
        llava_next_34b.CONFIG,
        moonshot_v1_16b_a3b.CONFIG,
        granite_moe_3b_a800m.CONFIG,
        rwkv6_3b.CONFIG,
        jamba_v0_1_52b.CONFIG,
        whisper_base.CONFIG,
    ]
}


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """Assigned input shapes (same four for every LM arch)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cell_runnable(arch: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is this (arch x shape) dry-run cell runnable? (else documented skip).

    ``long_500k`` requires sub-quadratic attention: run for SSM/hybrid,
    skip for pure full-attention archs (DESIGN.md §5).
    """
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "long_500k skipped: pure full-attention arch " \
                      "(O(S^2) attention; see DESIGN.md §5)"
    return True, ""
