"""MiniCPM3-4B — dense decoder with Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B; hf] 62L d_model=2560 40H (kv=40) d_ff=6400
vocab=73448. MLA ranks follow the published config (q_lora 768, kv_lora
256, qk nope/rope 64/32, v_head 64).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    head_dim=96,          # qk head dim (nope+rope)
    rope_theta=10000.0,
)
