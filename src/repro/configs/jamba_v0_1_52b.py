"""Jamba-v0.1-52B — hybrid Mamba+attention (1:7) with MoE 16e top-2.

[arXiv:2403.19887; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536; attention at layer period 8 offset 4; MoE period 2 offset 1.
Sub-quadratic (28/32 layers are Mamba) => long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    block="hybrid",
    attn_every=8,
    attn_offset=4,
    moe=True,
    n_experts=16,
    moe_top_k=2,
    moe_every=2,
    moe_offset=1,
    moe_d_ff=14336,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    subquadratic=True,
)
