"""Whisper-base — audio encoder-decoder backbone (conv frontend STUB).

[arXiv:2212.04356; unverified] 6L(enc)+6L(dec) d_model=512 8H d_ff=2048
vocab=51865. input_specs() provides precomputed frame embeddings (the
conv1d frontend is a stub per the assignment). Decode shapes exercise the
decoder's self+cross KV caches; positional tables are sized to the
assigned shapes (documented stretch beyond the real 448-token decoder).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,            # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    enc_dec=True,
    n_enc_layers=6,
    enc_seq=1500,
    frontend="audio_stub",
    mlp_act="gelu",
    norm="layernorm",
)
