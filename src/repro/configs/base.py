"""Model configuration system.

One frozen dataclass covers all ten assigned architecture families (dense /
MoE / SSM / hybrid / VLM / audio enc-dec). Each ``src/repro/configs/<id>.py``
instantiates the exact published hyperparameters; ``reduced()`` derives the
CPU smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses

from repro.utils import ceil_div


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # attention flavour
    attention: str = "gqa"           # gqa | mla | none
    qk_norm: bool = False            # qwen3
    rope_theta: float = 10000.0

    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    moe: bool = False
    n_experts: int = 0
    moe_top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    moe_every: int = 1               # MoE layer period (jamba: 2)
    moe_offset: int = 0              # MoE layer offset within period
    capacity_factor: float = 1.25

    # layer pattern (hybrid)
    block: str = "attn"              # attn | rwkv | hybrid (mamba+attn)
    attn_every: int = 1              # jamba: 8
    attn_offset: int = 0             # jamba: 4

    # mamba (jamba)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0           # 0 -> ceil(d_model / 16)

    # rwkv6
    rwkv_head_size: int = 64

    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500

    # modality frontend stubs (assignment: input_specs provides embeddings)
    frontend: str = "none"           # none | vision_stub | audio_stub
    n_prefix_embeds: int = 0         # vlm: image-patch positions in the seq

    # mlp / norm
    mlp_act: str = "swiglu"          # swiglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = False

    dtype: str = "bfloat16"
    remat: bool = True
    # which attention positions can run sub-quadratic / O(1)-state decode
    subquadratic: bool = False       # ssm/hybrid: long_500k runnable

    # -- derived ------------------------------------------------------------
    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or ceil_div(self.d_model, 16)

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    @property
    def qk_head_dim(self) -> int:
        if self.attention == "mla":
            return self.qk_nope_dim + self.qk_rope_dim
        return self.head_dim

    @property
    def layer_period(self) -> int:
        """Smallest repeating layer pattern (for scan-over-layers)."""
        import math
        p = 1
        if self.block == "hybrid":
            p = math.lcm(p, self.attn_every)
        if self.moe:
            p = math.lcm(p, self.moe_every)
        return p

    def is_attn_layer(self, i: int) -> bool:
        if self.attention == "none":
            return False
        if self.block == "hybrid":
            return i % self.attn_every == self.attn_offset
        return self.block == "attn"

    def is_moe_layer(self, i: int) -> bool:
        return self.moe and (i % self.moe_every == self.moe_offset)

    def param_count(self) -> int:
        """Analytic parameter count of the *specified* model (no padding)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                      # embed
        if not self.tie_embeddings:
            total += v * d                 # lm head
        for i in range(self.n_layers):
            total += d                     # pre-norm scale
            if self.is_attn_layer(i):
                if self.attention == "mla":
                    qd = self.n_heads * self.qk_head_dim
                    total += d * self.q_lora_rank + self.q_lora_rank * qd
                    total += d * (self.kv_lora_rank + self.qk_rope_dim)
                    total += self.kv_lora_rank * self.n_heads * (
                        self.qk_nope_dim + self.v_head_dim)
                    total += self.n_heads * self.v_head_dim * d
                    total += self.q_lora_rank + self.kv_lora_rank  # norms
                else:
                    total += d * self.n_heads * self.head_dim
                    total += 2 * d * self.n_kv_heads * self.head_dim
                    total += self.n_heads * self.head_dim * d
                    if self.qk_norm:
                        total += 2 * self.head_dim
            elif self.block == "rwkv":
                total += 4 * d * d + d * d      # r,k,v,w(lora approximated),o
                total += 2 * d * self.d_ff + d  # channel mix
            elif self.block == "hybrid":        # mamba layer
                di, n, dr = self.mamba_d_inner, self.mamba_d_state, self.dt_rank
                total += d * 2 * di + di * self.mamba_d_conv
                total += di * (dr + 2 * n) + dr * di + di * n + 2 * di
                total += di * d
            total += d                          # post/ffn norm
            if self.is_moe_layer(i):
                e, h = self.n_experts, self.moe_d_ff
                total += d * e                  # router
                total += e * 3 * d * h
                total += self.n_shared_experts * 3 * d * h
            elif self.block != "rwkv":
                mult = 3 if self.mlp_act == "swiglu" else 2
                total += mult * d * self.d_ff
        if self.enc_dec:
            for _ in range(self.n_enc_layers):
                total += 4 * d * self.head_dim * self.n_heads + \
                    2 * d * self.d_ff + 2 * d
            # decoder cross-attention
            total += self.n_layers * (4 * d * self.head_dim * self.n_heads + d)
        total += d                              # final norm
        return int(total)

    def param_count_active(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if not self.moe:
            return self.param_count()
        total = self.param_count()
        # subtract inactive expert weights
        for i in range(self.n_layers):
            if self.is_moe_layer(i):
                inactive = self.n_experts - self.moe_top_k
                total -= inactive * 3 * self.d_model * self.moe_d_ff
        return int(total)

    def reduced(self) -> "ModelConfig":
        """Same family, tiny dims — the CPU smoke-test variant."""
        changes = dict(
            n_layers=max(2, self.layer_period),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads
            else self.n_kv_heads,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            dtype="float32",
            remat=False,
        )
        if self.family in ("moe",) or self.moe:
            changes.update(n_experts=4, moe_top_k=2, moe_d_ff=32)
        if self.attention == "mla":
            changes.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                           qk_rope_dim=8, v_head_dim=16)
        if self.block == "rwkv":
            changes.update(rwkv_head_size=16)
        if self.block == "hybrid":
            changes.update(mamba_d_state=4, mamba_d_conv=4, mamba_dt_rank=8,
                           n_layers=self.layer_period)
        if self.enc_dec:
            changes.update(n_enc_layers=2, enc_seq=16)
        if self.frontend == "vision_stub":
            changes.update(n_prefix_embeds=4)
        # MLA keeps kv = q heads
        if self.attention == "mla":
            changes["n_kv_heads"] = changes["n_heads"]
        return dataclasses.replace(self, **changes)
