"""Streaming serve engine: search-during-ingest front door for ``sivf.Index``.

The paper's headline claim is that SIVF keeps serving millisecond searches
*while* mutations stream in. Until now every consumer drove the index
synchronously from one thread; this engine is the concurrent front door:

    index = sivf.Index(cfg, centroids, deferred=True)
    with ServeEngine(index) as eng:
        writer = eng.session("ingest")
        reader = eng.session("app")
        writer.add(vecs, ids)                       # non-blocking submit
        res = reader.search(qs, k=10).result()      # ServeSearchResult

Architecture (cribbed from the seed LLM engine's admit/step split — one
scheduler owns the device, clients only touch queues and futures):

  * **One dispatch thread.** Client threads validate + enqueue under the
    engine lock; a single scheduler thread drains the queue and is the
    only thread that touches the index. JAX device work executes in
    dispatch order, so the scheduler's ordering decisions *are* the
    consistency story.
  * **Coalesced query batching.** Queued searches sharing
    ``(k, nprobe, filter)`` concatenate into one tile (capped at
    ``max_coalesce`` rows) and ride one fused-kernel call;
    ``Index.search`` pads the tile to the PR 2 power-of-two query
    buckets, so executable counts stay bounded by ``#buckets x
    #(k, nprobe, filter-structure) groups`` — filter constants never
    mint an executable — and :meth:`assert_bounded_compiles` checks the
    observed jit cache against that bound.
  * **Mandatory tenant filters.** ``tenant_filters={tenant: predicate}``
    AND-s the predicate into every search the tenant submits and
    force-stamps its ``Eq``-pinned attributes onto the tenant's ingested
    rows — isolation holds on the read *and* write paths (see
    docs/filtering.md).
  * **Epoch-consistent mutation interleaving.** Mutations are admitted
    through the ``deferred=True`` pipeline (fire-and-forget submits, one
    packed sync per flush). Each dispatched batch bumps ``Index.epoch``;
    a search dispatched at epoch ``e`` observes exactly the first ``e``
    batches — never a half-applied one, because each batch commits
    atomically on device (PR 3) and the scheduler serializes dispatch.
    Searches dispatch *before* the mutations drained in the same cycle,
    so queries never stall behind ingest.
  * **Typed backpressure.** Per-tenant quotas (in-flight search cap,
    mutation-rate token bucket) and the global queue bound reject at
    submit time with :class:`repro.serve.quota.Backpressure` — the queue
    cannot grow without bound.

``close()`` (or context exit) drains: queued requests are processed, the
deferred queue is flushed, every future resolves. See docs/serving.md.
"""
from __future__ import annotations

import threading
import time
from collections import deque

import jax
import numpy as np

from repro.core import filters as flt
from repro.core.api import Index
from repro.serve.quota import (
    Backpressure,
    BackpressureKind,
    TenantQuota,
    TenantState,
)
from repro.serve.session import (
    ClientSession,
    MaintenanceRequest,
    MutationRequest,
    SearchRequest,
    ServeFuture,
    ServeMaintenanceResult,
    ServeMutationResult,
    ServeSearchResult,
)


class ServeEngine:
    """Concurrent serve front door over a ``deferred=True`` ``sivf.Index``.

    Parameters
    ----------
    index:        the :class:`sivf.Index` to serve. Must be constructed
                  with ``deferred=True`` (the engine sequences flushes)
                  and ``strict=False`` (admission errors surface on the
                  per-request :class:`ServeMutationResult`, never as a
                  mid-flush raise).
    default_k:    ``k`` used when a search request does not name one.
    default_nprobe: likewise for ``nprobe`` (``None`` probes every list).
    quota:        engine-wide default :class:`TenantQuota`.
    quotas:       per-tenant overrides, ``{tenant: TenantQuota}``.
    max_queue:    global bound on queued requests; beyond it submits are
                  rejected with ``QUEUE_FULL``.
    max_coalesce: cap on live query rows coalesced into one search tile
                  (the tile then pads to the next pow2 bucket).
    flush_every:  flush the deferred mutation queue once this many
                  batches are pending (the queue also flushes whenever
                  the engine goes idle, and at drain).
    tenant_filters: ``{tenant: predicate}`` *mandatory* filters
                  (``repro.core.filters``). Every search from a listed
                  tenant is AND-ed with its predicate — a client filter
                  can narrow but never escape it — and every attribute
                  the predicate pins with ``Eq`` (e.g. a tenant id) is
                  force-stamped onto that tenant's ingested rows, so a
                  listed tenant can neither read nor write outside its
                  slice. (``remove`` stays id-addressed; partition the id
                  space per tenant if eviction isolation matters too.)
                  Requires ``SIVFConfig(attributes=...)``.
    telemetry:    a ``repro.obs.Telemetry`` to record into. Defaults to
                  the served index's instance so engine tile spans and
                  the index's plan/prefetch/scan stage spans land in one
                  registry (see docs/observability.md).
    clock:        injectable monotonic clock (tests drive quota refill
                  deterministically).
    """

    def __init__(self, index: Index, *, default_k: int = 10,
                 default_nprobe: int | None = None,
                 quota: TenantQuota | None = None,
                 quotas: "dict[str, TenantQuota] | None" = None,
                 max_queue: int = 1024, max_coalesce: int = 256,
                 flush_every: int = 8,
                 tenant_filters: "dict | None" = None,
                 telemetry=None, clock=time.monotonic):
        if not isinstance(index, Index):
            raise TypeError(f"index must be a sivf.Index, got {index!r}")
        if not index.deferred:
            raise ValueError(
                "ServeEngine requires Index(deferred=True): the engine "
                "sequences flushes, eager per-batch syncs would stall the "
                "dispatch thread")
        if index.strict:
            raise ValueError(
                "ServeEngine requires strict=False: admission errors are "
                "reported on each ServeMutationResult, a strict flush "
                "raise would tear down the whole queue")
        if max_coalesce < 1:
            raise ValueError("max_coalesce must be >= 1")
        self._index = index
        self._default_k = int(default_k)
        self._default_nprobe = default_nprobe
        self._default_quota = quota or TenantQuota()
        self._quota_overrides = dict(quotas or {})
        self._max_queue = int(max_queue)
        self._max_coalesce = int(max_coalesce)
        self._flush_every = int(flush_every)
        self._clock = clock
        # mandatory per-tenant filters: compile eagerly so a bad predicate
        # (unknown attribute, no attributes configured) fails construction,
        # not some later search; Eq-pinned values become ingest overrides
        self._tenant_filters = dict(tenant_filters or {})
        self._tenant_stamps: dict[str, dict[str, int]] = {}
        for tenant, pred in self._tenant_filters.items():
            flt.compile_filter(pred, index.cfg.attributes)
            self._tenant_stamps[tenant] = flt.eq_bindings(pred)

        self._cv = threading.Condition()
        self._queue: deque = deque()
        self._tenants: dict[str, TenantState] = {}
        self._closing = False
        self._closed = False
        self._gate = threading.Event()        # cleared = scheduler paused
        self._gate.set()
        # scheduler-thread-only state
        self._mut_inflight: deque = deque()   # (req, PendingReport, epoch)
        self._kn_groups: set = set()
        self._max_tile = 0
        self._max_mut_rows = 0
        self._n_searches = 0
        self._n_tiles = 0
        self._n_mutations = 0
        self._n_maintenance = 0
        self._coalesce_sizes: list[int] = []
        # telemetry: default to the index's instance so one registry holds
        # the whole request path (tile roots + plan/prefetch/scan stages)
        self._tel = telemetry if telemetry is not None \
            else index._telemetry
        t = self._tel
        self._m_requests = t.counter(
            "sivf_serve_requests_total",
            "admitted serve requests by tenant and op", ("tenant", "op"))
        self._m_rows = t.counter(
            "sivf_serve_rows_total",
            "query/mutation rows admitted by tenant and op",
            ("tenant", "op"))
        self._m_backpressure = t.counter(
            "sivf_serve_backpressure_total",
            "submits rejected by tenant and backpressure kind",
            ("tenant", "kind"))
        self._m_queue_depth = t.gauge(
            "sivf_serve_queue_depth", "requests waiting in the engine queue")
        self._m_epoch = t.gauge(
            "sivf_serve_epoch", "committed mutation-batch prefix length")
        self._m_coalesce = t.histogram(
            "sivf_serve_coalesce_rows",
            "query rows coalesced into one kernel tile",
            buckets=tuple(float(2 ** i) for i in range(13)))
        if index.pending_count:               # engine owns the queue from here
            index.flush()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="sivf-serve-engine")
        self._thread.start()

    # -- client surface ------------------------------------------------------

    def session(self, tenant: str = "default") -> ClientSession:
        """A tenant-scoped submit handle (cheap; any number per tenant)."""
        return ClientSession(self, tenant)

    @property
    def index(self) -> Index:
        return self._index

    @property
    def epoch(self) -> int:
        """Committed mutation-batch prefix length (``Index.epoch``)."""
        return self._index.epoch

    def _tenant_state(self, tenant: str) -> TenantState:
        st = self._tenants.get(tenant)
        if st is None:
            st = TenantState(
                self._quota_overrides.get(tenant, self._default_quota),
                clock=self._clock)
            self._tenants[tenant] = st
        return st

    def _check_open_and_capacity(self, st: TenantState, tenant: str) -> None:
        if self._closing:
            raise Backpressure(BackpressureKind.ENGINE_CLOSED, tenant,
                               "engine is closed")
        if len(self._queue) >= self._max_queue:
            st.reject(BackpressureKind.QUEUE_FULL, tenant,
                      f"engine queue at max_queue={self._max_queue}")

    def _effective_filter(self, tenant: str, filter):
        """AND the tenant's mandatory predicate (if any) with the request's
        own, compiled once at submit so bad filters raise in the client
        thread and equal filters coalesce by value downstream."""
        mandatory = self._tenant_filters.get(tenant)
        if mandatory is None:
            pred = filter
        elif filter is None:
            pred = mandatory
        else:
            pred = flt.And(mandatory, filter)
        return flt.compile_filter(pred, self._index.cfg.attributes)

    def submit_search(self, tenant: str, queries, *, k: int | None = None,
                      nprobe: int | None = None, filter=None) -> ServeFuture:
        """Validate + enqueue a search; returns a future, never blocks."""
        q = np.asarray(queries, np.float32)
        if q.ndim == 1:
            q = q[None]
        if q.ndim != 2 or q.shape[1] != self._index.cfg.dim:
            raise ValueError(
                f"queries {q.shape} != [q, dim={self._index.cfg.dim}]")
        k = self._default_k if k is None else int(k)
        nprobe = self._default_nprobe if nprobe is None else nprobe
        n_lists = self._index.cfg.n_lists
        nprobe = n_lists if nprobe is None else min(int(nprobe), n_lists)
        cfilter = self._effective_filter(tenant, filter)
        try:
            with self._cv:
                st = self._tenant_state(tenant)
                self._check_open_and_capacity(st, tenant)
                st.admit_search(tenant)
                fut = ServeFuture(on_done=lambda _f, s=st: self._release(s))
                self._queue.append(SearchRequest(
                    tenant=tenant, queries=q, k=k, nprobe=nprobe,
                    future=fut, t_submit=self._clock(), cfilter=cfilter))
                depth = len(self._queue)
                self._cv.notify()
        except Backpressure as e:
            self._note_backpressure(tenant, e)
            raise
        if self._tel.enabled:
            self._m_requests.inc(tenant=tenant, op="search")
            self._m_rows.inc(int(q.shape[0]), tenant=tenant, op="search")
            self._m_queue_depth.set(depth)
        return fut

    def _release(self, st: TenantState) -> None:
        with self._cv:
            st.release_search()

    def _submit_mutation(self, tenant: str, op: str, vecs, ids,
                         attrs=None) -> ServeFuture:
        ids_a = np.asarray(ids, np.int32).reshape(-1)
        vecs_a = attrs_a = None
        if op == "add":
            vecs_a = np.asarray(vecs, np.float32)
            if vecs_a.ndim != 2 or vecs_a.shape[1] != self._index.cfg.dim:
                raise ValueError(
                    f"vecs {vecs_a.shape} != [B, dim={self._index.cfg.dim}]")
            if vecs_a.shape[0] != ids_a.shape[0]:
                raise ValueError(
                    f"vecs {vecs_a.shape} / ids {ids_a.shape} mismatch")
            if self._index.cfg.n_attrs:
                # normalize in the client thread (errors raise at submit);
                # Eq-pinned tenant attributes override whatever the client
                # sent — a row can never escape its mandatory filter
                attrs_a = flt.normalize_attrs(
                    self._index.cfg.attributes, attrs,
                    int(ids_a.shape[0]),
                    overrides=self._tenant_stamps.get(tenant))
            elif attrs is not None:
                raise ValueError(
                    "attrs= given but the served index has no "
                    "SIVFConfig(attributes=...)")
        try:
            with self._cv:
                st = self._tenant_state(tenant)
                self._check_open_and_capacity(st, tenant)
                st.admit_mutation(tenant, int(ids_a.shape[0]))
                fut = ServeFuture()
                self._queue.append(MutationRequest(
                    tenant=tenant, op=op, vecs=vecs_a, ids=ids_a,
                    future=fut, t_submit=self._clock(), attrs=attrs_a))
                depth = len(self._queue)
                self._cv.notify()
        except Backpressure as e:
            self._note_backpressure(tenant, e)
            raise
        if self._tel.enabled:
            self._m_requests.inc(tenant=tenant, op=op)
            self._m_rows.inc(int(ids_a.shape[0]), tenant=tenant, op=op)
            self._m_queue_depth.set(depth)
        return fut

    def _note_backpressure(self, tenant: str, e: Backpressure) -> None:
        if self._tel.enabled:
            self._m_backpressure.inc(tenant=tenant, kind=e.kind.value)

    def submit_add(self, tenant: str, vecs, ids, attrs=None) -> ServeFuture:
        """Enqueue an ingest batch through the deferred pipeline."""
        return self._submit_mutation(tenant, "add", vecs, ids, attrs=attrs)

    def submit_remove(self, tenant: str, ids) -> ServeFuture:
        """Enqueue an eviction batch through the deferred pipeline."""
        return self._submit_mutation(tenant, "remove", None, ids)

    def submit_maintenance(self, tenant: str, ops=None,
                           max_ops: int = 2) -> ServeFuture:
        """Enqueue a maintenance pass (``core/maintenance.py``).

        Operator-plane: exempt from per-tenant mutation quotas (it moves
        no client rows) but still bounded by the global queue. The
        scheduler interleaves it epoch-consistently — searches drained in
        the same cycle dispatch first, against the pre-maintenance
        prefix; each committed op then bumps the epoch like any other
        atomic batch, so later searches observe the whole new layout.
        """
        if ops is not None:
            from repro.core.maintenance import MaintOp
            ops = list(ops)
            for op in ops:
                if not isinstance(op, MaintOp):
                    raise TypeError(f"ops must be MaintOp, got {op!r}")
        try:
            with self._cv:
                st = self._tenant_state(tenant)
                self._check_open_and_capacity(st, tenant)
                fut = ServeFuture()
                self._queue.append(MaintenanceRequest(
                    tenant=tenant, ops=ops, max_ops=int(max_ops),
                    future=fut, t_submit=self._clock()))
                depth = len(self._queue)
                self._cv.notify()
        except Backpressure as e:
            self._note_backpressure(tenant, e)
            raise
        if self._tel.enabled:
            self._m_requests.inc(tenant=tenant, op="maintain")
            self._m_queue_depth.set(depth)
        return fut

    # -- scheduler -----------------------------------------------------------

    def _loop(self) -> None:
        while True:
            with self._cv:
                while True:
                    if self._closing and not self._queue \
                            and not self._mut_inflight:
                        return
                    if self._gate.is_set() and (
                            self._queue or self._closing
                            or self._mut_inflight):
                        break
                    self._cv.wait(timeout=0.1)
                batch = list(self._queue)
                self._queue.clear()
            searches = [r for r in batch if isinstance(r, SearchRequest)]
            muts = [r for r in batch if isinstance(r, MutationRequest)]
            maint = [r for r in batch if isinstance(r, MaintenanceRequest)]
            dispatched = self._dispatch_searches(searches)
            self._dispatch_mutations(muts)
            self._dispatch_maintenance(maint)
            self._maybe_flush()
            self._resolve_searches(dispatched)

    def _dispatch_searches(self, searches: list) -> list:
        """Coalesce by (k, nprobe, compiled filter), dispatch each tile
        async, at the *current* committed epoch — before this cycle's
        mutations. Equal filters (same structure AND constants) share a
        tile; the jit cache additionally collapses same-structure tiles
        onto one executable.

        On a tiered index (``SIVFConfig(device_slabs=...)``) the tiles are
        software-pipelined: after dispatching tile ``i``'s scan (async),
        the scheduler immediately prefetches tile ``i+1``'s probed slabs —
        the host->device uploads overlap the in-flight kernel, and tile
        ``i+1``'s search then skips its plan/prefetch stages via the
        returned ticket. Dispatch-order device execution makes this safe:
        tile ``i``'s scan is ordered before tile ``i+1``'s cache scatter,
        so eviction can never clobber a frame a running scan still reads.
        """
        groups: dict = {}
        for r in searches:
            groups.setdefault((r.k, r.nprobe, r.cfilter), []).append(r)
        tiles: list = []
        for (k, nprobe, cfilter), reqs in sorted(groups.items(), key=repr):
            chunk: list = []
            rows = 0
            for r in reqs + [None]:                # None terminates
                nq = 0 if r is None else r.queries.shape[0]
                if chunk and (r is None or rows + nq > self._max_coalesce):
                    qmat = chunk[0].queries if len(chunk) == 1 else \
                        np.concatenate([c.queries for c in chunk])
                    tiles.append((chunk, qmat, k, nprobe, cfilter))
                    chunk, rows = [], 0
                if r is not None:
                    chunk.append(r)
                    rows += nq
        dispatched: list = []
        epoch = self._index.epoch
        ticket = self._prefetch_tile(tiles[0]) if tiles else None
        for i, tile in enumerate(tiles):
            self._dispatch_tile(tile, epoch, dispatched, ticket)
            ticket = self._prefetch_tile(tiles[i + 1]) \
                if i + 1 < len(tiles) else None
        return dispatched

    def _prefetch_tile(self, tile):
        """Stage a tile's probed slabs ahead of its dispatch (tiered only;
        ``Index.prefetch`` is a no-op ``None`` on an all-resident index).
        Prefetch errors are swallowed — the tile's own search will hit the
        same condition and report it on the right futures."""
        _, qmat, _, nprobe, _ = tile
        try:
            return self._index.prefetch(qmat, nprobe)
        except Exception:
            return None

    def _dispatch_tile(self, tile, epoch: int, dispatched: list,
                       ticket=None) -> None:
        chunk, qmat, k, nprobe, cfilter = tile
        # the tile root span lives from dispatch to result readiness (set
        # at _resolve_searches); its scope exits right after dispatch so
        # the NEXT tile's pipelined prefetch doesn't nest into it
        span = self._tel.open_span(
            "serve.tile", root=True, epoch=epoch,
            tenant=",".join(sorted({r.tenant for r in chunk})),
            filter=None if cfilter is None else str(cfilter.structure),
            rows=int(qmat.shape[0]))
        t0 = self._clock()
        try:
            res = self._index.search(qmat, k, nprobe, filter=cfilter,
                                     _prefetched=ticket)  # async dispatch
        except Exception as e:
            self._tel.exit_scope(span)
            self._tel.finish_span(span)
            for r in chunk:
                r.future.set_exception(e)
            return
        self._tel.exit_scope(span)
        self._n_tiles += 1
        self._n_searches += len(chunk)
        self._coalesce_sizes.append(int(qmat.shape[0]))
        self._max_tile = max(self._max_tile, res.padded_to)
        if self._tel.enabled:
            self._m_coalesce.observe(int(qmat.shape[0]))
        # executables are per filter STRUCTURE, not per constant set
        self._kn_groups.add((k, res.nprobe,
                             None if cfilter is None else cfilter.structure))
        dispatched.append((chunk, res, epoch, t0, span))

    def _dispatch_mutations(self, muts: list) -> None:
        for r in muts:
            try:
                if r.op == "add":
                    pending = self._index.add(r.vecs, r.ids, attrs=r.attrs)
                else:
                    pending = self._index.remove(r.ids)
            except Exception as e:
                r.future.set_exception(e)
                continue
            self._n_mutations += 1
            self._max_mut_rows = max(self._max_mut_rows,
                                     int(r.ids.shape[0]))
            self._mut_inflight.append((r, pending, self._index.epoch))

    def _dispatch_maintenance(self, maint: list) -> None:
        """Run queued maintenance passes, after this cycle's searches
        dispatched (they observe the pre-maintenance prefix) and after
        its mutations (the pass sees their committed device state).
        ``Index.maintain`` syncs per op — acceptable for a background
        operator action; client searches already left the queue."""
        for r in maint:
            try:
                reports = self._index.maintain(ops=r.ops,
                                               max_ops=r.max_ops,
                                               strict=False)
            except Exception as e:
                r.future.set_exception(e)
                continue
            self._n_maintenance += 1
            if self._tel.enabled:
                self._m_epoch.set(self._index.epoch)
            r.future.set_result(ServeMaintenanceResult(
                reports=tuple(reports), epoch=self._index.epoch,
                queue_s=self._clock() - r.t_submit))

    def _maybe_flush(self) -> None:
        """Flush when the deferred queue is deep, the engine is idle, or
        a drain is in progress — one packed sync resolves every batch."""
        if not self._mut_inflight:
            return
        if self._index.pending_count < self._flush_every \
                and not self._closing:
            with self._cv:
                if self._queue:        # more work queued: keep deferring
                    return
        try:
            self._index.flush()
        except Exception as e:
            while self._mut_inflight:
                req, _, _ = self._mut_inflight.popleft()
                req.future.set_exception(e)
            return
        now = self._clock()
        if self._tel.enabled:
            self._m_epoch.set(self._index.epoch)
        while self._mut_inflight:
            req, pending, epoch = self._mut_inflight.popleft()
            if self._tel.enabled:
                self._tel.record_duration(
                    "serve.mutation_queue", now - req.t_submit,
                    attach=False)
            req.future.set_result(ServeMutationResult(
                report=pending.result(), epoch=epoch,
                queue_s=now - req.t_submit))

    def _resolve_searches(self, dispatched: list) -> None:
        for chunk, res, epoch, t0, span in dispatched:
            try:
                jax.block_until_ready(res.distances)
                d = np.asarray(res.distances)
                labels = np.asarray(res.labels)
            except Exception as e:
                self._tel.finish_span(span)
                for r in chunk:
                    r.future.set_exception(e)
                continue
            t1 = self._clock()
            self._tel.finish_span(span)  # tile wall time ~= service_s
            total = sum(r.queries.shape[0] for r in chunk)
            off = 0
            for r in chunk:
                nq = r.queries.shape[0]
                if self._tel.enabled:
                    self._tel.record_duration(
                        "serve.queue", t0 - r.t_submit, attach=False)
                r.future.set_result(ServeSearchResult(
                    distances=d[off:off + nq], labels=labels[off:off + nq],
                    k=res.k, nprobe=res.nprobe, epoch=epoch,
                    coalesced=total, padded_to=res.padded_to,
                    queue_s=t0 - r.t_submit, service_s=t1 - t0))
                off += nq

    # -- lifecycle -----------------------------------------------------------

    def pause(self) -> None:
        """Hold the scheduler after its current cycle: submits keep
        queueing (and hitting quota/queue bounds) but nothing dispatches
        until :meth:`resume`. Admission-control behavior under a stalled
        device becomes deterministic — that is what the backpressure
        tests (and a maintenance window) need."""
        self._gate.clear()

    def resume(self) -> None:
        with self._cv:
            self._gate.set()
            self._cv.notify_all()

    def close(self, drain: bool = True) -> None:
        """Stop the engine. ``drain=True`` (default) processes every queued
        request and flushes the deferred queue before returning — no
        future is left unresolved. ``drain=False`` fails queued requests
        with ``ENGINE_CLOSED`` (already-dispatched work still resolves)."""
        with self._cv:
            if self._closed:
                return
            self._closing = True
            dropped = []
            if not drain:
                dropped = list(self._queue)
                self._queue.clear()
            self._gate.set()                  # a paused engine still drains
            self._cv.notify_all()
        for r in dropped:
            r.future.set_exception(Backpressure(
                BackpressureKind.ENGINE_CLOSED, r.tenant,
                "engine closed before dispatch"))
        self._thread.join(timeout=120)
        if self._thread.is_alive():            # pragma: no cover - defensive
            raise RuntimeError("serve scheduler failed to drain")
        if self._index.pending_count:          # pragma: no cover - defensive
            self._index.flush()
        self._closed = True

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close(drain=exc_type is None)
        return False

    # -- introspection -------------------------------------------------------

    def compile_bound(self) -> int:
        """Upper bound on search executables for the traffic served so far:
        ``#pow2 query buckets up to the largest tile x #(k, nprobe,
        filter-structure)`` groups — filter *constants* never mint an
        executable, only distinct predicate shapes do."""
        max_tile = max(self._max_tile, self._index.min_bucket)
        buckets = len(self._index.bucket_shapes(max_tile))
        return buckets * max(1, len(self._kn_groups))

    def assert_bounded_compiles(self) -> tuple[int, int]:
        """Assert observed search executables <= :meth:`compile_bound`;
        returns ``(observed, bound)``. Shared jit caches mean handles with
        an equal (cfg, backend, impl, ...) tuple pool executables — use a
        fresh ``SIVFConfig`` to measure an engine in isolation."""
        observed = self._index.compile_stats()["search"]
        bound = self.compile_bound()
        if observed > bound:
            raise AssertionError(
                f"search executables {observed} exceed the coalescing bound "
                f"{bound} ({len(self._kn_groups)} (k, nprobe, filter) groups, max "
                f"tile {self._max_tile})")
        return observed, bound

    def telemetry(self) -> dict:
        """JSON-able telemetry snapshot (metrics + slow-query log) of the
        registry this engine records into — by default the served index's,
        so one snapshot covers tile roots, plan/prefetch/scan stages,
        cache/transfer counters and compile events."""
        self._index._note_compiles()
        return self._tel.snapshot()

    def render_prometheus(self) -> str:
        """Prometheus text exposition of the same registry."""
        self._index._note_compiles()
        return self._tel.render_prometheus()

    def stats(self) -> dict:
        """Serve-side counters + the index's own compile stats."""
        with self._cv:
            rejections = {
                tenant: {kind.value: n for kind, n in st.rejections.items()
                         if n}
                for tenant, st in self._tenants.items()}
            inflight = {tenant: st.inflight_searches
                        for tenant, st in self._tenants.items()}
            queued = len(self._queue)
        sizes = self._coalesce_sizes
        return {
            "epoch": self.epoch,
            "queued": queued,
            "searches": self._n_searches,
            "search_tiles": self._n_tiles,
            "coalesce_mean": round(float(np.mean(sizes)), 2) if sizes else 0,
            "coalesce_max": max(sizes, default=0),
            "mutations": self._n_mutations,
            "maintenance_passes": self._n_maintenance,
            "pending_mutations": self._index.pending_count,
            "inflight_searches": inflight,
            "rejections": rejections,
            "kn_groups": sorted(self._kn_groups, key=repr),
            "compiles": self._index.compile_stats(),
            "compile_bound": self.compile_bound(),
        }
