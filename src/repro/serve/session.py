"""Client-facing sessions and the typed request/future plumbing.

A :class:`ClientSession` is a tenant-scoped handle onto a running
``ServeEngine``: every call is a *non-blocking submit* that either
enqueues a typed request and returns a :class:`ServeFuture`, or raises
:class:`repro.serve.quota.Backpressure` immediately. Results carry the
*epoch* (number of mutation batches the device had committed when the
request was dispatched), which is what makes search-during-ingest
results explainable: a search with ``epoch == e`` observed exactly the
first ``e`` mutation batches — never a half-applied one (the PR 3
atomic commit makes each batch all-or-nothing; the engine's single
dispatch thread makes the prefix exact).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable

import numpy as np

from repro.core.api import MutationReport
from repro.core.filters import CompiledFilter


class ServeFuture:
    """Engine-resolved future for one submitted request.

    ``result()`` blocks until the scheduler resolves the request (or
    raises the stored exception); ``done`` never blocks. ``on_done``
    runs exactly once, after the value/error is stored but before
    waiters wake — the engine uses it to release the tenant's in-flight
    quota slot.
    """

    __slots__ = ("_event", "_value", "_error", "_on_done")

    def __init__(self, on_done: "Callable[[ServeFuture], None] | None" = None):
        self._event = threading.Event()
        self._value = None
        self._error: BaseException | None = None
        self._on_done = on_done

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def _fire(self) -> None:
        cb, self._on_done = self._on_done, None
        if cb is not None:
            cb(self)
        self._event.set()

    def set_result(self, value) -> None:
        self._value = value
        self._fire()

    def set_exception(self, err: BaseException) -> None:
        self._error = err
        self._fire()

    def result(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"request unresolved after {timeout}s")
        if self._error is not None:
            raise self._error
        return self._value


@dataclasses.dataclass
class SearchRequest:
    tenant: str
    queries: np.ndarray        # [q, dim] float32 (host)
    k: int
    nprobe: int
    future: ServeFuture
    t_submit: float
    # effective compiled predicate (tenant-mandatory AND user filter);
    # requests coalesce only within an identical (k, nprobe, cfilter)
    cfilter: CompiledFilter | None = None


@dataclasses.dataclass
class MutationRequest:
    tenant: str
    op: str                    # "add" | "remove"
    vecs: np.ndarray | None    # [B, dim] float32 for add, None for remove
    ids: np.ndarray            # [B] int32
    future: ServeFuture
    t_submit: float
    # dense [B, n_attrs] int32, already normalized + tenant-stamped at
    # submit time (None when the index has no attributes / on remove)
    attrs: np.ndarray | None = None


@dataclasses.dataclass
class MaintenanceRequest:
    """Operator-plane request: run index maintenance between batches.

    ``ops=None`` lets the index's drift policy plan from its occupancy
    counters at dispatch time (the stats snapshot is taken by the
    scheduler thread, so the plan always reflects the committed prefix
    the ops will run against).
    """

    tenant: str
    ops: "list | None"         # explicit core.maintenance.MaintOp list
    max_ops: int
    future: ServeFuture
    t_submit: float


@dataclasses.dataclass(frozen=True)
class ServeSearchResult:
    """Per-request slice of a coalesced search tile."""

    distances: np.ndarray      # [q, k] f32 (inf pads)
    labels: np.ndarray         # [q, k] int32 external ids (-1 pads)
    k: int
    nprobe: int
    epoch: int                 # committed mutation-batch prefix observed
    coalesced: int             # live queries in the shared tile
    padded_to: int             # pow2 block_q bucket the tile padded to
    queue_s: float             # submit -> dispatch
    service_s: float           # dispatch -> device completion

    def __iter__(self):
        return iter((self.distances, self.labels))


@dataclasses.dataclass(frozen=True)
class ServeMutationResult:
    """Resolved deferred mutation: the index report plus its epoch."""

    report: MutationReport
    epoch: int                 # prefix length including this batch
    queue_s: float             # submit -> flush resolution

    @property
    def ok(self) -> bool:
        return self.report.ok


@dataclasses.dataclass(frozen=True)
class ServeMaintenanceResult:
    """Resolved maintenance request: one report per op, in run order.

    An aborted op is atomic (old layout stays fully searchable), so
    ``ok=False`` here is advisory — retry after evictions, or ignore.
    """

    reports: tuple             # core.maintenance.MaintenanceReport per op
    epoch: int                 # prefix length after the committed ops
    queue_s: float             # submit -> completion

    @property
    def ok(self) -> bool:
        return all(r.committed for r in self.reports)


class ClientSession:
    """Tenant-scoped submit surface over a running engine.

    Obtained from ``ServeEngine.session(tenant)``; safe to share across
    client threads (all state lives in the engine, guarded by its lock).
    """

    def __init__(self, engine, tenant: str):
        self._engine = engine
        self.tenant = tenant

    def search(self, queries, k: int | None = None,
               nprobe: int | None = None, filter=None) -> ServeFuture:
        """Submit a search; resolves to :class:`ServeSearchResult`.

        ``filter`` is a ``repro.core.filters`` predicate; if the engine
        pins a mandatory filter for this tenant the two are AND-ed — the
        tenant's filter can be narrowed, never escaped.
        """
        return self._engine.submit_search(self.tenant, queries, k=k,
                                          nprobe=nprobe, filter=filter)

    def add(self, vecs, ids, attrs=None) -> ServeFuture:
        """Submit an ingest batch; resolves to :class:`ServeMutationResult`.

        With configured attributes, ``attrs`` follows ``Index.add`` (dict
        or ``[B, n_attrs]`` array); attributes the tenant's mandatory
        filter pins with ``Eq`` are force-stamped by the engine and may be
        omitted here.
        """
        return self._engine.submit_add(self.tenant, vecs, ids, attrs=attrs)

    def remove(self, ids) -> ServeFuture:
        """Submit an eviction batch; resolves to
        :class:`ServeMutationResult`."""
        return self._engine.submit_remove(self.tenant, ids)

    def maintain(self, ops=None, max_ops: int = 2) -> ServeFuture:
        """Submit a maintenance pass (split/merge/recluster); resolves to
        :class:`ServeMaintenanceResult`. With ``ops=None`` the index's
        drift policy plans from its occupancy counters at dispatch time.
        The scheduler runs it between batches, so searches in the same
        cycle observe the pre-maintenance prefix and later searches the
        whole new layout — never a hybrid."""
        return self._engine.submit_maintenance(self.tenant, ops=ops,
                                               max_ops=max_ops)

    def __repr__(self) -> str:
        return f"ClientSession(tenant={self.tenant!r})"
