"""Batched LM serving engine over the slab-paged KV cache.

Decoder-only archs (all assigned archs except whisper-base, whose cross
cache lives in the dense path). Requests are admitted via prefill, decoded
in lockstep batches, and evicted / window-slid in O(1) — the paper's
streaming lifecycle (ingest / search / evict) at the KV-cache level.

Formerly ``repro.serve.engine.ServeEngine``; renamed to
:class:`PagedLMEngine` when ``sivf_engine.ServeEngine`` (the vector-search
serve front door, the surface ``sivf.ServeEngine`` exports) took the
name. This module is the *token-decode* side of the streaming story and
is independent of the SIVF index path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import rwkv as rwkv_mod
from repro.models.common import apply_norm, embed_lookup, lm_head
from repro.serve import kv_cache as kvc
from repro.sharding.rules import ShardPlan
from repro.utils import ceil_div


class PagedLMEngine:
    def __init__(self, cfg: ModelConfig, plan: ShardPlan, params,
                 page_size: int = 16, n_pages: int = 128,
                 max_seqs: int = 4, max_pages_per_seq: int = 32,
                 attn_impl: str = "ref"):
        assert not cfg.enc_dec, "paged engine covers decoder-only archs"
        self.cfg, self.plan, self.params = cfg, plan, params
        self.attn_impl = attn_impl
        self.kv_cfg = kvc.PagedKVConfig(
            n_pages=n_pages, page_size=page_size,
            max_pages_per_seq=max_pages_per_seq, max_seqs=max_seqs)
        self.pages = kvc.init_page_state(self.kv_cfg)
        dt = jnp.dtype(cfg.dtype)
        hkv = plan.n_kv_heads_padded
        dh = cfg.head_dim
        dv = cfg.head_dim
        if cfg.attention == "mla":
            # absorbed-form latent pages: one shared "kv head" of
            # (latent + rope) keys and latent values (§Perf iteration 5)
            hkv = 1
            dh = cfg.kv_lora_rank + cfg.qk_rope_dim
            dv = cfg.kv_lora_rank
        period = cfg.layer_period
        n_per = cfg.n_layers // period
        self.pools = []
        for pos in range(period):
            if cfg.is_attn_layer(pos):
                self.pools.append((
                    jnp.zeros((n_per, n_pages, page_size, hkv, dh), dt),
                    jnp.zeros((n_per, n_pages, page_size, hkv, dv), dt),
                ))
            elif cfg.block == "rwkv":
                self.pools.append((
                    jnp.zeros((n_per, max_seqs, 1, cfg.d_model), dt),
                    jnp.zeros((n_per, max_seqs, plan.n_heads_padded,
                               cfg.rwkv_head_size, cfg.rwkv_head_size),
                              jnp.float32),
                    jnp.zeros((n_per, max_seqs, 1, cfg.d_model), dt),
                ))
            elif cfg.block == "hybrid":
                self.pools.append((
                    jnp.zeros((n_per, max_seqs, cfg.mamba_d_conv - 1,
                               cfg.mamba_d_inner), dt),
                    jnp.zeros((n_per, max_seqs, cfg.mamba_d_inner,
                               cfg.mamba_d_state), jnp.float32),
                ))
            else:
                self.pools.append((jnp.zeros((n_per, max_seqs, 1), dt),))
        self.last_tokens = jnp.zeros((max_seqs, 1), jnp.int32)
        self._decode = self._build_decode()

    # -- request lifecycle ---------------------------------------------------

    def admit(self, seq_id: int, tokens, prefix_embeds=None) -> bool:
        """Prefill ``tokens`` into sequence slot ``seq_id``."""
        from repro.models import model as M
        cfg, plan = self.cfg, self.plan
        toks = jnp.asarray(tokens, jnp.int32)[None, :]
        s = toks.shape[1]
        page = self.kv_cfg.page_size
        n_pages = ceil_div(s + 1, page)   # +1: room for the next token
        self.pages, ok = kvc.allocate(
            self.kv_cfg, self.pages, jnp.int32(seq_id), int(n_pages))
        if not bool(ok):
            return False
        batch = {"tokens": toks}
        if prefix_embeds is not None:
            batch["prefix_embeds"] = jnp.asarray(prefix_embeds)[None]
        logits, _, caches = M.forward(self.params, cfg, plan, batch,
                                      collect_cache=True)
        row = self.pages.tables[seq_id]
        pad = n_pages * page - s
        for pos, cache in enumerate(caches):
            if cfg.is_attn_layer(pos) and cfg.attention == "mla":
                lat, rope = cache               # [n_per, 1, S, lat/rope]
                k = jnp.concatenate([lat, rope], axis=-1)[:, :, :, None, :]
                v = lat[:, :, :, None, :]
                cache = (k, v)
            if cfg.is_attn_layer(pos):
                k, v = cache                    # [n_per, 1, S, hkv, dh]
                kp, vp = self.pools[pos]
                for arr, pool in ((k, 0), (v, 1)):
                    a = jnp.pad(arr[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
                    a = a.reshape(a.shape[0], n_pages, page, *a.shape[2:])
                    new = (kp if pool == 0 else vp).at[
                        :, row[:n_pages]].set(a.astype(kp.dtype))
                    if pool == 0:
                        kp = new
                    else:
                        vp = new
                self.pools[pos] = (kp, vp)
            elif cfg.block == "rwkv":
                xp, st, xc = cache
                a, b, c = self.pools[pos]
                self.pools[pos] = (
                    a.at[:, seq_id].set(xp[:, 0].astype(a.dtype)),
                    b.at[:, seq_id].set(st[:, 0]),
                    c.at[:, seq_id].set(xc[:, 0].astype(c.dtype)))
            elif cfg.block == "hybrid":
                conv, h = cache
                a, b = self.pools[pos]
                self.pools[pos] = (
                    a.at[:, seq_id].set(conv[:, 0].astype(a.dtype)),
                    b.at[:, seq_id].set(h[:, 0]))
        self.pages = kvc.PageState(
            tables=self.pages.tables,
            lengths=self.pages.lengths.at[seq_id].set(s),
            starts=self.pages.starts,
            offsets=self.pages.offsets,
            active=self.pages.active,
            free_stack=self.pages.free_stack,
            free_top=self.pages.free_top)
        nxt = jnp.argmax(logits[0, -1]).astype(jnp.int32)
        self.last_tokens = self.last_tokens.at[seq_id, 0].set(nxt)
        return True

    def evict(self, seq_id: int) -> None:
        """O(1) eviction — pages return to the free stack, no copies."""
        self.pages = kvc.evict_seq(self.kv_cfg, self.pages,
                                   jnp.int32(seq_id))

    def slide(self, seq_id: int, keep_last: int) -> None:
        """Sliding window: drop pages before (length - keep_last)."""
        new_start = jnp.maximum(
            self.pages.lengths[seq_id] - keep_last, 0)
        self.pages = kvc.slide_window(self.kv_cfg, self.pages,
                                      jnp.int32(seq_id), new_start)

    # -- decode ---------------------------------------------------------------

    def _build_decode(self):
        cfg, plan = self.cfg, self.plan
        period = cfg.layer_period
        impl = self.attn_impl

        def decode(params, pools, tokens, tables, lengths, starts, offsets,
                   active):
            dtype = jnp.dtype(cfg.dtype)
            x = embed_lookup(params["embed"], tokens, dtype)
            positions = offsets + lengths
            new_pools = []
            for pp in range(period):
                lp_stack = params["layers"][pp]
                pool = pools[pp]

                def body(x, xs, pp=pp):
                    lp, ch = xs
                    h = apply_norm(lp["ln1"], x)
                    if cfg.is_attn_layer(pp):
                        o, kp, vp = attn.gqa_decode_paged(
                            lp["attn"], cfg, plan, h, ch[0], ch[1],
                            tables, lengths, starts, positions, impl=impl) \
                            if cfg.attention != "mla" else \
                            _mla_paged(lp["attn"], cfg, plan, h, ch, tables,
                                       lengths, starts, positions, impl)
                        x = x + o
                        ch_new = (kp, vp)
                    elif cfg.block == "rwkv":
                        o, st = rwkv_mod.time_mix(
                            lp["tm"], cfg, plan, h, (ch[0], ch[1]),
                            impl="xla")
                        x = x + o
                        ch_new = st
                    elif cfg.block == "hybrid":
                        o, st = mamba_mod.mamba_block(
                            lp["mamba"], cfg, plan, h, (ch[0], ch[1]),
                            impl="xla", chunk=1)
                        x = x + o
                        ch_new = st
                    else:
                        ch_new = ch
                    h = apply_norm(lp["ln2"], x)
                    if cfg.is_moe_layer(pp):
                        o, _ = mlp_mod.moe(lp["moe"], cfg, plan, h)
                        x = x + o
                    elif cfg.block == "rwkv":
                        o, cm = rwkv_mod.channel_mix(lp["cm"], cfg, h, ch[2])
                        x = x + o
                        ch_new = ch_new + (cm,)
                    else:
                        x = x + mlp_mod.apply_mlp(lp["mlp"], h, cfg.mlp_act)
                    return x, ch_new

                x, np_ = jax.lax.scan(body, x, (lp_stack, pool))
                new_pools.append(np_)
            x = apply_norm(params["final_norm"], x)
            logits = lm_head(params.get("head", params["embed"]), x,
                             cfg.vocab_size)
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            nxt = jnp.where(active, nxt, 0)
            return logits, nxt[:, None], new_pools

        return jax.jit(decode)

    def step(self) -> np.ndarray:
        """Decode one token for every active sequence."""
        page = self.kv_cfg.page_size
        # page-boundary allocation (paper Alg. 2 new-slab path)
        for seq in range(self.kv_cfg.max_seqs):
            if bool(self.pages.active[seq]):
                need = int(kvc.pages_needed(
                    self.pages.lengths[seq], 1, page))
                if need > 0:
                    self.pages, ok = kvc.allocate(
                        self.kv_cfg, self.pages, jnp.int32(seq), need)
                    if not bool(ok):
                        raise RuntimeError("page pool exhausted (fail-fast)")
        logits, nxt, self.pools = self._decode(
            self.params, self.pools, self.last_tokens, self.pages.tables,
            self.pages.lengths, self.pages.starts, self.pages.offsets,
            self.pages.active)
        act = self.pages.active
        self.pages = kvc.PageState(
            tables=self.pages.tables,
            lengths=self.pages.lengths + act.astype(jnp.int32),
            starts=self.pages.starts,
            offsets=self.pages.offsets,
            active=act,
            free_stack=self.pages.free_stack,
            free_top=self.pages.free_top)
        self.last_tokens = jnp.where(act[:, None], nxt, self.last_tokens)
        return np.asarray(nxt[:, 0])


def _mla_paged(p, cfg, plan, h, ch, tables, lengths, starts, positions,
               impl):
    """MLA decode over latent pages (absorbed form, §Perf iteration 5).

    Pages hold one shared "kv head": keys = latent (+) rope (320 dims for
    minicpm3), values = latent (288). The existing paged_attention kernel
    runs unchanged with Hkv=1, g=Hq."""
    import jax.numpy as jnp
    from repro.models import attention as attn
    b = h.shape[0]
    page = ch[0].shape[1]
    q_comb, lat_new, rope_new = attn.mla_absorbed_parts(
        p, cfg, plan, h, positions[:, None])
    k_new = jnp.concatenate([lat_new, rope_new], axis=-1)[:, 0, None, :]
    v_new = lat_new[:, 0, None, :]
    pslot = lengths // page
    pidx = tables[jnp.arange(b), jnp.clip(pslot, 0, tables.shape[1] - 1)]
    tgt = jnp.where(pidx >= 0, pidx, ch[0].shape[0])
    kp = ch[0].at[tgt, lengths % page].set(
        k_new.astype(ch[0].dtype), mode="drop")
    vp = ch[1].at[tgt, lengths % page].set(
        v_new.astype(ch[1].dtype), mode="drop")
    from repro.kernels.paged_attention.ops import paged_attention
    ctx = paged_attention(q_comb[:, 0], kp, vp, tables, lengths + 1,
                          starts=starts, scale=cfg.qk_head_dim ** -0.5,
                          impl="ref" if impl == "ref" else "pallas",
                          interpret=(impl == "pallas_interpret"))
    o = attn.mla_absorbed_out(p, cfg, ctx[:, None])          # [B,1,H,vh]
    o = o * attn._head_mask(plan, cfg.n_heads)[None, None, :, None].astype(
        o.dtype)
    from repro.models.common import dense
    out = dense(p["wo"], o.reshape(b, 1, -1))
    return out, kp, vp
