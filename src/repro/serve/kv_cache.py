"""Slab-paged KV cache: the paper's SDMA applied to serving (DESIGN.md §3).

Mapping from SIVF (paper §3) to the KV cache:

  =====================  =====================================
  SIVF                   paged KV cache
  =====================  =====================================
  slab pool              page pool  [n_pages, page, Hkv, dh]
  global free stack      page free stack + top
  address table (ATT)    per-sequence block table [B, max_pages]
  validity bitmap        (start, length) live window per sequence
  lazy eviction (Alg.4)  O(1) sequence eviction / sliding-window
                         page drop: pages pushed back to the stack,
                         no data movement
  =====================  =====================================

All state is a functional pytree; mutation ops are jitted with donation.
The same physical page ids index every layer's pool (vLLM-style shared
block tables), so allocation cost is O(new_pages), independent of model
depth and sequence count — the paper's O(1) claim carried over.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PagedKVConfig:
    n_pages: int
    page_size: int
    max_pages_per_seq: int
    max_seqs: int


@partial(jax.tree_util.register_dataclass,
         data_fields=["tables", "lengths", "starts", "offsets", "active",
                      "free_stack", "free_top"],
         meta_fields=[])
@dataclasses.dataclass
class PageState:
    tables: jax.Array      # [max_seqs, max_pages] int32 page ids (-1)
    lengths: jax.Array     # [max_seqs] int32 tokens written (cache coords)
    starts: jax.Array      # [max_seqs] int32 window start (cache coords)
    offsets: jax.Array     # [max_seqs] int32 absolute-position offset
                           #   (tokens dropped by sliding windows so far)
    active: jax.Array      # [max_seqs] bool
    free_stack: jax.Array  # [n_pages] int32
    free_top: jax.Array    # [] int32


def init_page_state(cfg: PagedKVConfig) -> PageState:
    return PageState(
        tables=jnp.full((cfg.max_seqs, cfg.max_pages_per_seq), -1, jnp.int32),
        lengths=jnp.zeros((cfg.max_seqs,), jnp.int32),
        starts=jnp.zeros((cfg.max_seqs,), jnp.int32),
        offsets=jnp.zeros((cfg.max_seqs,), jnp.int32),
        active=jnp.zeros((cfg.max_seqs,), bool),
        free_stack=jnp.arange(cfg.n_pages, dtype=jnp.int32),
        free_top=jnp.array(cfg.n_pages, jnp.int32),
    )


@partial(jax.jit, static_argnames=("cfg", "n_new"), donate_argnums=(1,))
def allocate(cfg: PagedKVConfig, st: PageState, seq: jax.Array,
             n_new: int) -> tuple[PageState, jax.Array]:
    """Pop ``n_new`` pages for ``seq`` (paper Alg. 1 Allocate). Returns
    (state, ok)."""
    have = jnp.sum(st.tables[seq] >= 0)
    ok = (st.free_top >= n_new) & (have + n_new <= cfg.max_pages_per_seq)
    idx = jnp.arange(n_new)
    pages = st.free_stack[jnp.clip(st.free_top - 1 - idx, 0)]
    tgt = jnp.where(ok, seq, cfg.max_seqs)
    tables = st.tables.at[tgt, have + idx].set(pages, mode="drop")
    return PageState(
        tables=tables, lengths=st.lengths, starts=st.starts,
        offsets=st.offsets,
        active=st.active.at[tgt].set(True, mode="drop"),
        free_stack=st.free_stack,
        free_top=st.free_top - jnp.where(ok, n_new, 0)), ok


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1,))
def evict_seq(cfg: PagedKVConfig, st: PageState, seq: jax.Array
              ) -> PageState:
    """O(1) sequence eviction (paper Alg. 4): push the sequence's pages
    back onto the free stack; no data movement."""
    row = st.tables[seq]                                   # [max_pages]
    used = row >= 0
    n = jnp.sum(used)
    dst = jnp.cumsum(used) - 1
    stack = st.free_stack.at[
        jnp.where(used, st.free_top + dst, cfg.n_pages)].set(
        row, mode="drop")
    return PageState(
        tables=st.tables.at[seq].set(-1),
        lengths=st.lengths.at[seq].set(0),
        starts=st.starts.at[seq].set(0),
        offsets=st.offsets.at[seq].set(0),
        active=st.active.at[seq].set(False),
        free_stack=stack,
        free_top=st.free_top + n)


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1,))
def slide_window(cfg: PagedKVConfig, st: PageState, seq: jax.Array,
                 new_start: jax.Array) -> PageState:
    """Sliding-window eviction: free whole pages that fall before
    ``new_start`` (the paper's streaming-window eviction, §5.5)."""
    row = st.tables[seq]
    first_live_page = new_start // cfg.page_size
    pidx = jnp.arange(cfg.max_pages_per_seq)
    drop = (pidx < first_live_page) & (row >= 0)
    n = jnp.sum(drop)
    dst = jnp.cumsum(drop) - 1
    stack = st.free_stack.at[
        jnp.where(drop, st.free_top + dst, cfg.n_pages)].set(
        row, mode="drop")
    # compact the table: shift remaining pages down, adjust start offset
    keep = ~drop & (row >= 0)
    kdst = jnp.cumsum(keep) - 1
    new_row = jnp.full_like(row, -1).at[
        jnp.where(keep, kdst, cfg.max_pages_per_seq)].set(row, mode="drop")
    return PageState(
        tables=st.tables.at[seq].set(new_row),
        lengths=st.lengths.at[seq].add(-n * cfg.page_size),
        starts=st.starts.at[seq].set(new_start - n * cfg.page_size),
        offsets=st.offsets.at[seq].add(n * cfg.page_size),
        active=st.active,
        free_stack=stack,
        free_top=st.free_top + n)


def pages_needed(length: jax.Array, add: int, page: int) -> jax.Array:
    """Pages to allocate so ``length + add`` tokens fit."""
    return (length + add + page - 1) // page - (length + page - 1) // page
