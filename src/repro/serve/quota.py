"""Per-tenant admission control for the SIVF serve engine.

The engine never queues unboundedly: every submit is checked against the
tenant's :class:`TenantQuota` (and the engine's global queue bound) and
either admitted or rejected *immediately* with a typed
:class:`Backpressure` error naming the reason. Clients therefore learn
about overload at the submit call, not via a timeout three layers later —
the "typed backpressure, not unbounded queueing" contract of ISSUE 6.

Two quota dimensions:

  * ``max_inflight_searches`` — searches queued or executing for the
    tenant. Admission increments the counter; resolving the request's
    future (success *or* failure) releases it.
  * ``mutation_rows_per_s`` / ``mutation_burst_rows`` — a token bucket
    over mutation *rows* (vectors added or ids removed), so one tenant
    streaming bulk ingest cannot starve the device of search time.
    ``float("inf")`` (the default) disables rate limiting.

All state mutations happen under the engine's lock; the bucket takes an
injectable ``clock`` so tests can drive refill deterministically.
"""
from __future__ import annotations

import dataclasses
import enum
import math
import time


class BackpressureKind(enum.Enum):
    """Why a submit was rejected (carried on :class:`Backpressure`)."""

    SEARCH_INFLIGHT = "search_inflight"   # tenant's in-flight search cap
    MUTATION_RATE = "mutation_rate"       # tenant's mutation token bucket
    QUEUE_FULL = "queue_full"             # engine-wide request queue bound
    ENGINE_CLOSED = "engine_closed"       # submit after close()


class Backpressure(RuntimeError):
    """Typed submit-time rejection; never raised mid-flight.

    Carries ``kind`` (:class:`BackpressureKind`), ``tenant`` and a human
    ``detail`` string, so callers can switch on the reason (shed load,
    retry with backoff, surface a 429) instead of parsing messages.
    """

    def __init__(self, kind: BackpressureKind, tenant: str,
                 detail: str = ""):
        super().__init__(f"[{kind.value}] tenant={tenant!r}: {detail}")
        self.kind = kind
        self.tenant = tenant
        self.detail = detail


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Static per-tenant limits (engine-wide default or per tenant)."""

    max_inflight_searches: int = 64
    mutation_rows_per_s: float = math.inf
    mutation_burst_rows: int = 8192


class _TokenBucket:
    """Classic token bucket over mutation rows; ``inf`` rate = unlimited."""

    def __init__(self, rate: float, burst: float, clock):
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._tokens = float(burst)
        self._last = clock()

    def try_take(self, n: int) -> bool:
        if math.isinf(self.rate):
            return True
        now = self._clock()
        self._tokens = min(self.burst,
                           self._tokens + (now - self._last) * self.rate)
        self._last = now
        if n > self._tokens:
            return False
        self._tokens -= n
        return True


class TenantState:
    """Mutable per-tenant admission state; guarded by the engine lock."""

    def __init__(self, quota: TenantQuota, clock=time.monotonic):
        self.quota = quota
        self.inflight_searches = 0
        self.bucket = _TokenBucket(quota.mutation_rows_per_s,
                                   quota.mutation_burst_rows, clock)
        self.rejections = {kind: 0 for kind in BackpressureKind}

    def reject(self, kind: BackpressureKind, tenant: str, detail: str):
        self.rejections[kind] += 1
        raise Backpressure(kind, tenant, detail)

    def admit_search(self, tenant: str) -> None:
        cap = self.quota.max_inflight_searches
        if self.inflight_searches >= cap:
            self.reject(BackpressureKind.SEARCH_INFLIGHT, tenant,
                        f"{self.inflight_searches} searches in flight >= "
                        f"max_inflight_searches={cap}")
        self.inflight_searches += 1

    def release_search(self) -> None:
        self.inflight_searches = max(self.inflight_searches - 1, 0)

    def admit_mutation(self, tenant: str, rows: int) -> None:
        if not self.bucket.try_take(rows):
            self.reject(BackpressureKind.MUTATION_RATE, tenant,
                        f"{rows} mutation rows exceed the token bucket "
                        f"(rate={self.quota.mutation_rows_per_s}/s, "
                        f"burst={self.quota.mutation_burst_rows})")
