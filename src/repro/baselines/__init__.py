"""Comparison baselines from the paper's evaluation (§5).

  * ``FlatIndex``       — GPU Flat analogue: brute force, O(N) compaction
                          on delete (paper Table 4).
  * ``ContiguousIVF``   — the primary baseline (Faiss GPU IVFFlat
                          analogue): contiguous per-list buffers with 2x
                          growth and full re-layout on overflow/delete.
  * ``LSHIndex``        — hash-bucket baseline (paper Table 4).
  * ``HNSWLite``        — small graph baseline; deletion requires rebuild,
                          reproducing the paper's graph-index pathology.

Every baseline implements :class:`repro.core.api.IndexProtocol`
(``add`` / ``remove`` / ``search`` / ``stats`` / ``n_live``) via
:class:`ProtocolEngine`, so ``benchmarks/`` and the examples drive SIVF and
all baselines through one interface. The legacy ``insert``/``delete``
method names stay as the underlying implementations.
"""
import numpy as np


class ProtocolEngine:
    """Mixin mapping ``insert``/``delete`` engines onto ``IndexProtocol``.

    Reports are measured from live-count deltas: rows the engine silently
    dropped (bucket/list overflow) surface as ``rejected``. Baselines do
    not track overwrite semantics, so ``overwritten`` is always 0.
    """

    def add(self, vecs, ids):
        from repro.core.api import report_from_counts
        ids_np = np.asarray(ids).reshape(-1)
        requested = int((ids_np >= 0).sum())
        n0 = self.n_live
        self.insert(vecs, ids)
        n1 = self.n_live
        return report_from_counts("add", requested, n1 - n0, 0, n1,
                                  len(ids_np))

    def remove(self, ids):
        from repro.core.api import report_from_counts
        ids_np = np.asarray(ids).reshape(-1)
        requested = int((ids_np >= 0).sum())
        n0 = self.n_live
        self.delete(ids)
        n1 = self.n_live
        return report_from_counts("remove", requested, n0 - n1, 0, n1,
                                  len(ids_np))

    def stats(self) -> dict:
        return {"engine": type(self).__name__, "n_live": self.n_live}


from repro.baselines.flat import FlatIndex  # noqa: F401,E402
from repro.baselines.contiguous_ivf import ContiguousIVF  # noqa: F401,E402
from repro.baselines.lsh import LSHIndex  # noqa: F401,E402
from repro.baselines.hnsw_lite import HNSWLite  # noqa: F401,E402
