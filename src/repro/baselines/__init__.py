"""Comparison baselines from the paper's evaluation (§5).

  * ``FlatIndex``       — GPU Flat analogue: brute force, O(N) compaction
                          on delete (paper Table 4).
  * ``ContiguousIVF``   — the primary baseline (Faiss GPU IVFFlat
                          analogue): contiguous per-list buffers with 2x
                          growth and full re-layout on overflow/delete.
  * ``LSHIndex``        — hash-bucket baseline (paper Table 4).
  * ``HNSWLite``        — small graph baseline; deletion requires rebuild,
                          reproducing the paper's graph-index pathology.
"""
from repro.baselines.flat import FlatIndex  # noqa: F401
from repro.baselines.contiguous_ivf import ContiguousIVF  # noqa: F401
from repro.baselines.lsh import LSHIndex  # noqa: F401
from repro.baselines.hnsw_lite import HNSWLite  # noqa: F401
