"""HNSW-lite graph baseline (paper Table 4, HNSW/NSG rows).

A compact single-layer NSW graph (numpy; graph indices are host structures
in Faiss too). It reproduces the streaming pathology the paper measures:
no native delete — eviction forces a full graph REBUILD over the surviving
vectors, which is why graph indices post 10^2-10^5 ms deletion latencies in
Table 4.
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.baselines import ProtocolEngine


class HNSWLite(ProtocolEngine):
    def __init__(self, dim: int, m: int = 8, ef: int = 32,
                 metric: str = "l2"):
        self.dim, self.m, self.ef, self.metric = dim, m, ef, metric
        self.vecs: dict[int, np.ndarray] = {}
        self.links: dict[int, list[int]] = {}
        self.entry: int | None = None

    def _d(self, a: np.ndarray, b: np.ndarray) -> float:
        if self.metric == "ip":
            return -float(a @ b)
        diff = a - b
        return float(diff @ diff)

    def _greedy(self, q: np.ndarray, ef: int) -> list[tuple[float, int]]:
        if self.entry is None:
            return []
        visited = {self.entry}
        d0 = self._d(q, self.vecs[self.entry])
        cand = [(d0, self.entry)]
        best = [(-d0, self.entry)]
        while cand:
            d, u = heapq.heappop(cand)
            if d > -best[0][0] and len(best) >= ef:
                break
            for v in self.links[u]:
                if v in visited:
                    continue
                visited.add(v)
                dv = self._d(q, self.vecs[v])
                if len(best) < ef or dv < -best[0][0]:
                    heapq.heappush(cand, (dv, v))
                    heapq.heappush(best, (-dv, v))
                    if len(best) > ef:
                        heapq.heappop(best)
        return sorted((-nd, u) for nd, u in best)

    def _insert_one(self, i: int, v: np.ndarray) -> None:
        self.vecs[i] = v
        near = self._greedy(v, self.ef)[: self.m]
        self.links[i] = [u for _, u in near]
        for _, u in near:
            self.links[u].append(i)
            if len(self.links[u]) > 2 * self.m:   # prune to closest
                self.links[u].sort(
                    key=lambda w: self._d(self.vecs[u], self.vecs[w]))
                self.links[u] = self.links[u][: 2 * self.m]
        if self.entry is None:
            self.entry = i

    def insert(self, vecs, ids) -> None:
        for v, i in zip(np.asarray(vecs, np.float32), ids):
            self._insert_one(int(i), v)

    def delete(self, ids) -> None:
        """Full rebuild over survivors (graph topology must be repaired)."""
        drop = set(int(i) for i in ids)
        survivors = [(i, v) for i, v in self.vecs.items() if i not in drop]
        self.vecs, self.links, self.entry = {}, {}, None
        for i, v in survivors:
            self._insert_one(i, v)

    def search(self, qs, k, nprobe=None):
        """Graph search; ``nprobe`` accepted for IndexProtocol, unused."""
        from repro.core.api import SearchResult
        qs = np.asarray(qs, np.float32)
        out_d = np.full((len(qs), k), np.inf, np.float32)
        out_l = np.full((len(qs), k), -1, np.int64)
        for qi, q in enumerate(qs):
            res = self._greedy(q, max(self.ef, k))[:k]
            for j, (d, u) in enumerate(res):
                out_d[qi, j] = d
                out_l[qi, j] = u
        return SearchResult(distances=out_d, labels=out_l, k=k, nprobe=0,
                            padded_to=len(qs))

    @property
    def n_live(self) -> int:
        return len(self.vecs)
