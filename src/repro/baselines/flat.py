"""Flat (brute-force) baseline — the paper's "GPU Flat".

Storage is one contiguous [cap, D] buffer. Insert appends at a cursor;
delete performs the O(N) physical compaction that contiguous layouts force
(paper Fig. 1a / Table 4): every live row is gathered into a fresh dense
prefix. Search is an exact matmul + top-k.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.baselines import ProtocolEngine
from repro.core.api import SearchResult
from repro.utils import l2_sq


@partial(jax.jit, donate_argnums=(0, 1, 2))
def _append(buf, ids, cursor, vecs, new_ids):
    b = vecs.shape[0]
    pos = cursor + jnp.arange(b)
    ok = pos < buf.shape[0]
    tgt = jnp.where(ok, pos, buf.shape[0])
    buf = buf.at[tgt].set(vecs, mode="drop")
    ids = ids.at[tgt].set(new_ids, mode="drop")
    return buf, ids, cursor + jnp.sum(ok)


@partial(jax.jit, donate_argnums=(0, 1))
def _compact(buf, ids, cursor, del_ids):
    """O(N) compaction: drop deleted rows, shift live rows down."""
    n = buf.shape[0]
    dead = jnp.isin(ids, del_ids) & (jnp.arange(n) < cursor)
    alive = (~dead) & (jnp.arange(n) < cursor)
    # stable partition: order of live rows preserved (memmove semantics)
    dst = jnp.cumsum(alive) - 1
    tgt = jnp.where(alive, dst, n)
    buf = jnp.zeros_like(buf).at[tgt].set(buf, mode="drop")
    ids = jnp.full_like(ids, -1).at[tgt].set(ids, mode="drop")
    return buf, ids, jnp.sum(alive)


@partial(jax.jit, static_argnames=("k", "metric"))
def _search(buf, ids, cursor, qs, k, metric):
    if metric == "ip":
        d = -(qs @ buf.T)
    else:
        d = l2_sq(qs, buf)
    live = (jnp.arange(buf.shape[0]) < cursor) & (ids >= 0)
    d = jnp.where(live[None, :], d, jnp.inf)
    nd, idx = jax.lax.top_k(-d, k)
    return -nd, ids[idx]


class FlatIndex(ProtocolEngine):
    def __init__(self, dim: int, capacity: int, metric: str = "l2"):
        self.metric = metric
        self.buf = jnp.zeros((capacity, dim), jnp.float32)
        self.ids = jnp.full((capacity,), -1, jnp.int32)
        self.cursor = jnp.array(0, jnp.int32)

    def insert(self, vecs, ids):
        self.buf, self.ids, self.cursor = _append(
            self.buf, self.ids, self.cursor, jnp.asarray(vecs, jnp.float32),
            jnp.asarray(ids, jnp.int32))

    def delete(self, ids):
        self.buf, self.ids, self.cursor = _compact(
            self.buf, self.ids, self.cursor, jnp.asarray(ids, jnp.int32))

    def search(self, qs, k, nprobe=None):
        """Exact search; ``nprobe`` accepted for IndexProtocol, unused."""
        qs = jnp.asarray(qs, jnp.float32)
        d, lab = _search(self.buf, self.ids, self.cursor, qs, k, self.metric)
        return SearchResult(distances=d, labels=lab, k=k, nprobe=0,
                            padded_to=qs.shape[0])

    @property
    def n_live(self) -> int:
        return int(self.cursor)
