"""LSH baseline (paper Table 4): sign-random-projection hash tables.

L tables x 2^bits buckets with fixed bucket capacity; insert appends to the
matching bucket in every table; delete tombstones by id (the legacy-LSH
behaviour the paper contrasts with: cheap deletes, weak recall).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.baselines import ProtocolEngine
from repro.core.api import SearchResult


def _codes(planes, vecs):
    """planes [L, bits, D]; vecs [B, D] -> bucket ids [B, L]."""
    s = jnp.einsum("lbd,nd->nlb", planes, vecs) > 0
    w = (2 ** jnp.arange(planes.shape[1])).astype(jnp.int32)
    return jnp.sum(s.astype(jnp.int32) * w[None, None, :], axis=-1)


@partial(jax.jit, donate_argnums=(0, 1, 2))
def _insert(bucket_vecs, bucket_ids, cursors, planes, vecs, ids):
    nl, nb, cap, d = bucket_vecs.shape
    codes = _codes(planes, vecs)                            # [B, L]
    for li in range(nl):                                     # L is small
        c = codes[:, li]
        order = jnp.argsort(c, stable=True)
        cs = c[order]
        start = jnp.searchsorted(cs, cs, side="left")
        rank = jnp.arange(cs.shape[0]) - start
        pos = cursors[li, cs] + rank
        ok = (ids[order] >= 0) & (pos < cap)
        tgt = jnp.where(ok, cs, nb)
        bucket_vecs = bucket_vecs.at[li, tgt, pos].set(vecs[order], mode="drop")
        bucket_ids = bucket_ids.at[li, tgt, pos].set(ids[order], mode="drop")
        add = jnp.bincount(jnp.where(ok, cs, nb), length=nb + 1)[:-1]
        cursors = cursors.at[li].add(add.astype(cursors.dtype))
    return bucket_vecs, bucket_ids, cursors


@partial(jax.jit, donate_argnums=(0,))
def _tombstone(bucket_ids, del_ids):
    dead = jnp.isin(bucket_ids, del_ids)
    return jnp.where(dead, -1, bucket_ids)


@partial(jax.jit, static_argnames=("k", "metric"))
def _search(bucket_vecs, bucket_ids, planes, qs, k, metric):
    nl, nb, cap, d = bucket_vecs.shape
    codes = _codes(planes, qs)                              # [Q, L]
    xs = bucket_vecs[jnp.arange(nl)[None, :], codes]         # [Q, L, cap, D]
    xi = bucket_ids[jnp.arange(nl)[None, :], codes]          # [Q, L, cap]
    if metric == "ip":
        dist = -jnp.einsum("qd,qlcd->qlc", qs, xs)
    else:
        qq = jnp.sum(qs * qs, -1)[:, None, None]
        dist = qq - 2 * jnp.einsum("qd,qlcd->qlc", qs, xs) \
            + jnp.sum(xs * xs, -1)
    dist = jnp.where(xi >= 0, dist, jnp.inf)
    qn = qs.shape[0]
    dist = dist.reshape(qn, -1)
    xi = xi.reshape(qn, -1)
    # dedupe across tables: keep first occurrence of each id by masking
    # later duplicates (sort-by-id trick)
    order = jnp.argsort(xi, axis=1, stable=True)
    xis = jnp.take_along_axis(xi, order, 1)
    ds = jnp.take_along_axis(dist, order, 1)
    dup = jnp.concatenate(
        [jnp.zeros((qn, 1), bool), xis[:, 1:] == xis[:, :-1]], axis=1)
    ds = jnp.where(dup, jnp.inf, ds)
    nd, idx = jax.lax.top_k(-ds, k)
    return -nd, jnp.take_along_axis(xis, idx, axis=1)


class LSHIndex(ProtocolEngine):
    def __init__(self, key, dim: int, n_tables: int = 4, bits: int = 8,
                 bucket_cap: int = 64, metric: str = "l2"):
        self.metric = metric
        self.planes = jax.random.normal(key, (n_tables, bits, dim))
        nb = 2 ** bits
        self.bucket_vecs = jnp.zeros((n_tables, nb, bucket_cap, dim),
                                     jnp.float32)
        self.bucket_ids = jnp.full((n_tables, nb, bucket_cap), -1, jnp.int32)
        self.cursors = jnp.zeros((n_tables, nb), jnp.int32)

    def insert(self, vecs, ids):
        self.bucket_vecs, self.bucket_ids, self.cursors = _insert(
            self.bucket_vecs, self.bucket_ids, self.cursors, self.planes,
            jnp.asarray(vecs, jnp.float32), jnp.asarray(ids, jnp.int32))

    def delete(self, ids):
        self.bucket_ids = _tombstone(self.bucket_ids,
                                     jnp.asarray(ids, jnp.int32))

    def search(self, qs, k, nprobe=None):
        """Hash-bucket search; ``nprobe`` accepted for IndexProtocol, unused."""
        qs = jnp.asarray(qs, jnp.float32)
        d, lab = _search(self.bucket_vecs, self.bucket_ids, self.planes,
                       qs, k, self.metric)
        return SearchResult(distances=d, labels=lab, k=k, nprobe=0,
                            padded_to=qs.shape[0])

    @property
    def n_live(self) -> int:
        """Live entries in table 0 (approximate under bucket overflow)."""
        return int(jnp.sum(self.bucket_ids[0] >= 0))
