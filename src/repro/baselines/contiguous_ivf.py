"""Contiguous-layout IVF — the paper's primary baseline (Faiss GPU IVFFlat).

Inverted lists are stored in per-list contiguous buffers [n_lists, cap, D].
This reproduces the two pathologies the paper measures:

  * **Insert** — when any list outgrows its capacity the whole structure is
    re-laid-out with 2x capacity growth ("dynamic arrays reserve up to 2x
    capacity to amortize resizing", paper §3.5.3) — the analogue of the
    cudaMalloc/copy churn in Table 3.
  * **Delete** — contiguous layouts require O(N) data shifting (paper
    Fig. 1a): every probed list is compacted with a stable partition, i.e.
    the memmove the Faiss CPU fallback performs after the PCIe roundtrip.

Search scans probed lists from the padded dense layout (fully coalesced —
this is why static GPU IVF is fast until you mutate it).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.baselines import ProtocolEngine
from repro.core import quantizer
from repro.core.api import SearchResult


@partial(jax.jit, donate_argnums=(0, 1, 2))
def _scatter_insert(buf, ids, counts, vecs, new_ids, lists):
    """Append within per-list capacity; returns overflow flag."""
    cap = buf.shape[1]
    order = jnp.argsort(lists, stable=True)
    sl = lists[order]
    sv = vecs[order]
    sid = new_ids[order]
    start = jnp.searchsorted(sl, sl, side="left")
    rank = jnp.arange(sl.shape[0]) - start
    pos = counts[sl] + rank
    ok = (sid >= 0) & (pos < cap)
    overflow = jnp.any((sid >= 0) & (pos >= cap))
    li = jnp.where(ok, sl, buf.shape[0])
    buf = buf.at[li, pos].set(sv, mode="drop")
    ids = ids.at[li, pos].set(sid, mode="drop")
    add = jnp.bincount(jnp.where(ok, sl, buf.shape[0]),
                       length=buf.shape[0] + 1)[:-1]
    return buf, ids, counts + add.astype(counts.dtype), overflow


@partial(jax.jit, donate_argnums=(0, 1, 2))
def _compact_lists(buf, ids, counts, del_ids):
    """O(N) per-list stable compaction (the memmove)."""
    nl, cap, _ = buf.shape
    slot = jnp.arange(cap)[None, :]
    live = (slot < counts[:, None]) & ~jnp.isin(ids, del_ids)
    dst = jnp.cumsum(live, axis=1) - 1
    tgt = jnp.where(live, dst, cap)
    li = jnp.broadcast_to(jnp.arange(nl)[:, None], (nl, cap))
    buf = jnp.zeros_like(buf).at[li, tgt].set(buf, mode="drop")
    ids = jnp.full_like(ids, -1).at[li, tgt].set(ids, mode="drop")
    return buf, ids, jnp.sum(live, axis=1).astype(counts.dtype)


@partial(jax.jit, static_argnames=("k", "nprobe", "metric"))
def _search(centroids, buf, ids, counts, qs, k, nprobe, metric):
    probes = quantizer.probe(centroids, qs, nprobe, metric)   # [Q, P]
    x = buf[probes]                                           # [Q, P, cap, D]
    xi = ids[probes]
    cnt = counts[probes]
    if metric == "ip":
        d = -jnp.einsum("qd,qpcd->qpc", qs, x)
    else:
        qq = jnp.sum(qs * qs, -1)[:, None, None]
        xx = jnp.sum(x * x, -1)
        d = qq - 2.0 * jnp.einsum("qd,qpcd->qpc", qs, x) + xx
    slot = jnp.arange(buf.shape[1])[None, None, :]
    okm = (slot < cnt[..., None]) & (xi >= 0)
    d = jnp.where(okm, d, jnp.inf)
    qn = qs.shape[0]
    d = d.reshape(qn, -1)
    xi = xi.reshape(qn, -1)
    nd, idx = jax.lax.top_k(-d, k)
    return -nd, jnp.take_along_axis(xi, idx, axis=1)


class ContiguousIVF(ProtocolEngine):
    def __init__(self, centroids, list_cap: int = 64, metric: str = "l2"):
        self.centroids = jnp.asarray(centroids, jnp.float32)
        self.metric = metric
        nl, d = self.centroids.shape
        self.buf = jnp.zeros((nl, list_cap, d), jnp.float32)
        self.ids = jnp.full((nl, list_cap), -1, jnp.int32)
        self.counts = jnp.zeros((nl,), jnp.int32)
        self.n_relayouts = 0

    def _grow(self):
        """2x capacity re-layout: allocate + full copy (the paper's resizing
        overhead; counted so benchmarks can report it)."""
        nl, cap, d = self.buf.shape
        buf = jnp.zeros((nl, cap * 2, d), jnp.float32).at[:, :cap].set(self.buf)
        ids = jnp.full((nl, cap * 2), -1, jnp.int32).at[:, :cap].set(self.ids)
        self.buf, self.ids = buf, ids
        self.n_relayouts += 1

    def insert(self, vecs, ids):
        vecs = jnp.asarray(vecs, jnp.float32)
        ids = jnp.asarray(ids, jnp.int32)
        lists = quantizer.assign(self.centroids, vecs, self.metric)
        while True:
            buf, idb, counts, overflow = _scatter_insert(
                self.buf, self.ids, self.counts, vecs, ids, lists)
            if not bool(overflow):
                self.buf, self.ids, self.counts = buf, idb, counts
                return
            # overflow: keep old state (donated buffers were replaced), grow
            self.buf, self.ids, self.counts = buf, idb, counts
            self.delete(ids)            # undo partial insert
            self._grow()

    def delete(self, ids):
        self.buf, self.ids, self.counts = _compact_lists(
            self.buf, self.ids, self.counts, jnp.asarray(ids, jnp.int32))

    def search(self, qs, k, nprobe=None):
        """IVF search; ``nprobe=None`` probes every list."""
        nprobe = self.centroids.shape[0] if nprobe is None \
            else min(int(nprobe), self.centroids.shape[0])
        qs = jnp.asarray(qs, jnp.float32)
        d, lab = _search(self.centroids, self.buf, self.ids, self.counts,
                       qs, k, nprobe, self.metric)
        return SearchResult(distances=d, labels=lab, k=k, nprobe=nprobe,
                            padded_to=qs.shape[0])

    def stats(self) -> dict:
        return {"engine": type(self).__name__, "n_live": self.n_live,
                "list_cap": int(self.buf.shape[1]),
                "n_relayouts": self.n_relayouts}

    @property
    def n_live(self) -> int:
        return int(jnp.sum(self.counts))
