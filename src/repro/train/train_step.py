"""Training step: loss -> grads -> AdamW, with microbatch gradient
accumulation (lax.scan) and buffer donation.

The step function is built once per (cfg, plan, opt_cfg) and jitted with
in/out shardings derived from the logical-axes tree, so the same code path
serves the CPU smoke tests and the 512-chip dry-run.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.sharding.rules import ShardPlan
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1          # gradient accumulation steps
    aux_coef: float = 0.01         # MoE load-balance coefficient


def loss_fn(params, cfg: ModelConfig, plan: ShardPlan, batch: dict,
            aux_coef: float, impl: str = "xla"):
    logits, aux, _ = M.forward(params, cfg, plan, batch, impl=impl)
    loss = M.lm_loss(logits, batch["labels"], aux, aux_coef)
    return loss, {"loss": loss, "aux": aux}


def make_train_step(cfg: ModelConfig, plan: ShardPlan, tcfg: TrainConfig,
                    impl: str = "xla"):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt"}; batch = {"tokens","labels",(extras)} with a
    leading microbatch dim when tcfg.microbatches > 1.
    """

    def grads_of(params, batch):
        (loss, met), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, plan, batch, tcfg.aux_coef, impl)
        return grads, met

    def train_step(state, batch):
        params = state["params"]
        if tcfg.microbatches > 1:
            def mb(carry, mbatch):
                acc = carry
                g, met = grads_of(params, mbatch)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, met

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, mets = jax.lax.scan(mb, zero, batch)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
            metrics = jax.tree.map(lambda m: jnp.mean(m), mets)
        else:
            grads, metrics = grads_of(params, batch)

        new_params, new_opt, opt_met = adamw_update(
            tcfg.opt, params, grads, state["opt"])
        metrics = {**metrics, **opt_met}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(params) -> dict:
    return {"params": params, "opt": init_opt_state(params)}


def state_specs(param_specs, params_abs=None, batch_axes: tuple = ("data",),
                mesh_axes: dict | None = None, zero1: bool = False) -> dict:
    """PartitionSpec tree for the train state.

    Default: moments shard exactly like params. ``zero1=True`` additionally
    shards each moment's first *unsharded* dim over the data axes when
    divisible (ZeRO-1): optimizer memory drops ~dp-fold; GSPMD inserts the
    gather at update time (the reduce-scatter/all-gather pair of ZeRO).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    import jax

    def moment_spec(spec, leaf):
        if not zero1 or leaf is None or mesh_axes is None:
            return spec
        dp = 1
        for a in batch_axes:
            dp *= mesh_axes[a]
        base = spec.spec if isinstance(spec, NamedSharding) else spec
        entries = list(base) + [None] * (leaf.ndim - len(base))
        for d in range(leaf.ndim):
            if entries[d] is None and leaf.shape[d] % dp == 0 \
                    and leaf.shape[d] >= dp:
                entries[d] = batch_axes if len(batch_axes) > 1 \
                    else batch_axes[0]
                new = P(*entries)
                if isinstance(spec, NamedSharding):
                    return NamedSharding(spec.mesh, new)
                return new
        return spec

    if zero1 and params_abs is not None:
        moments = jax.tree.map(moment_spec, param_specs, params_abs)
    else:
        moments = param_specs
    return {
        "params": param_specs,
        "opt": {
            "mu": moments,
            "nu": moments,
            "step": P(),
        },
    }
