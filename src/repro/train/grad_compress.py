"""Error-feedback int8 gradient compression for cross-pod reduction.

Distributed-optimization trick for the thin cross-pod links: gradients are
quantized to int8 with a per-tensor scale before the cross-pod all-reduce,
and the quantization residual is fed back into the next step's gradients
(error feedback keeps SGD/Adam convergence; 1-bit-Adam-style). Intra-pod
reduction stays full precision — only the "pod" axis pays the compression.

Used by train_step when ``compress_pod_grads`` is on: grads are computed
with per-pod psum only (shard_map over "pod"), compressed, all-reduced over
"pod", decompressed, and residual carried in the train state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization."""
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, residual):
    """Quantize grads+residual; return (int8 tree, scales, new residual)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return q, s, gf - deq

    out = jax.tree.map(one, grads, residual)
    istuple = lambda x: isinstance(x, tuple)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=istuple)
    s = jax.tree.map(lambda t: t[1], out, is_leaf=istuple)
    res = jax.tree.map(lambda t: t[2], out, is_leaf=istuple)
    return q, s, res


def psum_compressed(q, s, axis: str):
    """All-reduce compressed grads over ``axis``.

    int8 payloads are summed in int32 (values bounded by 127 * pod_count)
    and rescaled by the mean scale — a mean-of-quantized estimator.
    """
    n = jax.lax.psum(1, axis)
    qs = jax.tree.map(
        lambda x: jax.lax.psum(x.astype(jnp.int32), axis), q)
    ss = jax.tree.map(lambda x: jax.lax.psum(x, axis) / n, s)
    return jax.tree.map(
        lambda qi, si: qi.astype(jnp.float32) * si / n, qs, ss)


def init_residual(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
