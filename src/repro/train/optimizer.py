"""AdamW + cosine schedule + global-norm clipping, built from scratch.

Optimizer state shards like the params (specs derive from the same
logical-axes tree). ZeRO-1 variant: moments additionally shard their
largest dim over the data axes when divisible (cuts optimizer memory
dp-fold; gathered transparently by GSPMD at update time).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step):
    """Linear warmup + cosine decay to min_lr_frac."""
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: OptConfig, params, grads, opt_state
                 ) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"]
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** (step.astype(jnp.float32) + 1)
    c2 = 1.0 - b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / c1
        nhat = nu / c2
        p32 = p.astype(jnp.float32)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p32 - lr * (mhat / (jnp.sqrt(nhat) + cfg.eps) + wd * p32)
        return newp.astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, opt_state["mu"], opt_state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step + 1}, \
        {"grad_norm": gn, "lr": lr}
