"""Deterministic synthetic data pipelines.

Requirements at scale: (1) deterministic per (seed, step, host) so an
elastic restart resumes the exact stream without coordination; (2) O(1)
skip-ahead (counter-based RNG, no sequential state); (3) per-host sharding
by host id so each host materializes only its slice of the global batch.

Token streams are Zipf-distributed over the vocab (natural-ish unigram
statistics); vector streams are Gaussian-mixture draws matching the SIVF
benchmark datasets (SIFT/GIST-like dims).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    n_hosts: int = 1
    host_id: int = 0
    zipf_a: float = 1.2


class TokenStream:
    """Counter-based deterministic token batches."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.host_batch = cfg.global_batch // cfg.n_hosts

    def batch(self, step: int) -> dict:
        """Batch for ``step`` (O(1) — safe to skip-ahead after restart)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
        # Zipf over vocab, clipped; labels are next-token shifted
        toks = rng.zipf(cfg.zipf_a, size=(self.host_batch, cfg.seq_len + 1))
        toks = (toks - 1) % cfg.vocab_size
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


@dataclasses.dataclass(frozen=True)
class VectorStreamConfig:
    seed: int = 0
    dim: int = 128
    n_clusters: int = 64
    cluster_std: float = 0.3
    zipf_a: float = 0.0        # 0 = uniform cluster popularity, else skewed


class VectorStream:
    """Gaussian-mixture vector batches for SIVF benchmarks (SIFT-like)."""

    def __init__(self, cfg: VectorStreamConfig):
        self.cfg = cfg
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 777]))
        self.centers = rng.normal(size=(cfg.n_clusters, cfg.dim)
                                  ).astype(np.float32)

    def batch(self, step: int, n: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        if cfg.zipf_a > 0:
            ranks = (rng.zipf(cfg.zipf_a, size=n) - 1) % cfg.n_clusters
        else:
            ranks = rng.integers(0, cfg.n_clusters, size=n)
        x = self.centers[ranks] + rng.normal(
            size=(n, cfg.dim)).astype(np.float32) * cfg.cluster_std
        return x.astype(np.float32)
