"""Checkpoint manager: atomic, checksummed, async-capable, elastic.

Design for 1000+ nodes (DESIGN.md §6):
  * **atomicity** — writes go to ``step_XXXX.tmp`` and are renamed only
    after the manifest (with per-array SHA-256) is fsynced; a crashed save
    never corrupts the latest-good checkpoint.
  * **async** — ``save(..., blocking=False)`` snapshots to host memory and
    writes on a background thread so the train loop overlaps I/O.
  * **elastic restart** — arrays are stored unsharded (np.save per leaf);
    ``restore(..., sharding_tree=...)`` re-places them onto *any* mesh, so
    a job can resume on a different topology after node loss, and
    ``restore_arrays(step)`` loads a step's raw leaves straight from its
    manifest with no example tree at all — the self-describing path
    elastic *resharding* uses, where the caller re-routes rows across a
    different shard count instead of merely re-placing leaves
    (``core.distributed.reshard_state``; docs/checkpoint-format.md). (At
    real scale the np.save backend swaps for a per-host sharded writer;
    the manager API is the contract.)
  * **retention** — keep_last prunes old steps; a ``latest`` symlink gives
    O(1) discovery on restart.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, blocking: bool = True) -> None:
        """Snapshot ``tree`` at ``step``. Non-blocking saves copy to host
        first, then write on a daemon thread."""
        leaves, treedef = _flatten(tree)
        host = [np.asarray(x) for x in leaves]    # device -> host snapshot
        self.wait()                                # one in-flight save max
        if blocking:
            self._write(step, host, treedef)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, treedef), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves, treedef) -> None:
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "arrays": []}
        for i, arr in enumerate(host_leaves):
            path = tmp / f"arr_{i:05d}.npy"
            np.save(path, arr)
            manifest["arrays"].append({
                "file": path.name,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
            })
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                     # atomic publish
        latest = self.dir / "latest"
        if latest.is_symlink() or latest.exists():
            latest.unlink()
        os.symlink(final.name, latest)
        self._prune()

    def _prune(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- metadata sidecars ----------------------------------------------------

    def save_metadata(self, name: str, obj: dict) -> None:
        """Atomically publish a JSON sidecar (e.g. index config/topology)."""
        tmp = self.dir / f"{name}.json.tmp"
        with open(tmp, "w") as f:
            json.dump(obj, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.dir / f"{name}.json")

    def load_metadata(self, name: str) -> dict:
        with open(self.dir / f"{name}.json") as f:
            return json.load(f)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                if p.is_dir() and not p.name.endswith(".tmp")]

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore_arrays(self, step: int, verify: bool = True
                       ) -> list[np.ndarray]:
        """Load a step's raw leaves straight from its manifest.

        Self-describing restore: shapes/dtypes come from the manifest, so
        no example tree is needed. This is the entry point for elastic
        resharding (``core.distributed.reshard_state``), which re-routes
        the restored rows across a *different* shard count — an example
        tree shaped like the target topology would be a lie there.
        """
        d = self.dir / f"step_{step:08d}"
        with open(d / "manifest.json") as f:
            manifest = json.load(f)
        out = []
        for meta in manifest["arrays"]:
            arr = np.load(d / meta["file"])
            if verify:
                digest = hashlib.sha256(arr.tobytes()).hexdigest()
                if digest != meta["sha256"]:
                    raise IOError(f"checksum mismatch in {meta['file']}")
            out.append(arr)
        return out

    def restore(self, step: int, example_tree, sharding_tree=None,
                verify: bool = True):
        """Load ``step`` into the structure of ``example_tree``; optionally
        re-place each leaf with the given shardings (elastic re-mesh)."""
        leaves, treedef = _flatten(example_tree)
        # count check against the manifest alone (one small JSON read)
        # before touching any array file: a structure mismatch on a huge
        # checkpoint must not cost a full load-and-hash pass first
        with open(self.dir / f"step_{step:08d}" / "manifest.json") as f:
            stored = len(json.load(f)["arrays"])
        if len(leaves) != stored:
            raise ValueError(
                f"checkpoint/model structure mismatch: example tree has "
                f"{len(leaves)} leaves, step {step} stored {stored}")
        out = self.restore_arrays(step, verify=verify)
        tree = jax.tree.unflatten(treedef, out)
        if sharding_tree is not None:
            tree = jax.tree.map(jax.device_put, tree, sharding_tree)
        return tree
