"""Exporters: Prometheus text exposition + JSON snapshot (+ parser).

Both exporters read the same :class:`~repro.obs.metrics.MetricsRegistry`
under its lock, so a scrape taken mid-workload is internally consistent.
``parse_prometheus`` exists for the round-trip acceptance test (ISSUE 9:
"Prometheus and JSON exports round-tripping the same values") and for
operators who want to spot-check a scrape without a Prometheus server.

Prometheus conventions honoured:

  * ``# HELP`` / ``# TYPE`` headers per family.
  * Histograms expose cumulative ``_bucket{le=...}`` series ending in
    ``le="+Inf"``, plus ``_sum`` and ``_count``.
  * Counters expose both the cumulative total and a companion
    ``<name>_window`` gauge (delta since the last
    :meth:`~repro.obs.metrics.MetricsRegistry.roll_window`) — the
    windowed twin is this repo's addition, labeled as such in HELP.
"""
from __future__ import annotations

import json
import math
import time

from repro.obs.metrics import Counter, Gauge, Histogram


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _labels_str(names, values, extra=()) -> str:
    pairs = [f'{n}="{v}"' for n, v in zip(names, values)]
    pairs += [f'{n}="{v}"' for n, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


def render_prometheus(tel) -> str:
    """Render a Telemetry (or bare registry) in text exposition format."""
    reg = getattr(tel, "registry", tel)
    out: list[str] = []
    with reg._lock:
        for name in sorted(reg._families):
            fam = reg._families[name]
            if fam.help:
                out.append(f"# HELP {fam.name} {fam.help}")
            out.append(f"# TYPE {fam.name} {fam.kind}")
            if isinstance(fam, Histogram):
                for lv, c in sorted(fam._children.items()):
                    acc = 0
                    for bound, n in zip(fam.buckets, c.counts):
                        acc += n
                        le = _labels_str(fam.label_names, lv,
                                         (("le", _fmt(bound)),))
                        out.append(f"{fam.name}_bucket{le} {acc}")
                    acc += c.counts[-1]
                    le = _labels_str(fam.label_names, lv, (("le", "+Inf"),))
                    out.append(f"{fam.name}_bucket{le} {acc}")
                    ls = _labels_str(fam.label_names, lv)
                    out.append(f"{fam.name}_sum{ls} {repr(float(c.sum))}")
                    out.append(f"{fam.name}_count{ls} {c.count}")
            elif isinstance(fam, Counter):
                for lv, c in sorted(fam._children.items()):
                    ls = _labels_str(fam.label_names, lv)
                    out.append(f"{fam.name}{ls} {_fmt(c.total)}")
                win = [(lv, c.total - c.mark)
                       for lv, c in sorted(fam._children.items())]
                if any(w for _, w in win) or win:
                    out.append(f"# HELP {fam.name}_window delta of "
                               f"{fam.name} since last roll_window")
                    out.append(f"# TYPE {fam.name}_window gauge")
                    for lv, w in win:
                        ls = _labels_str(fam.label_names, lv)
                        out.append(f"{fam.name}_window{ls} {_fmt(w)}")
            elif isinstance(fam, Gauge):
                for lv, c in sorted(fam._children.items()):
                    ls = _labels_str(fam.label_names, lv)
                    out.append(f"{fam.name}{ls} {_fmt(c.value)}")
    return "\n".join(out) + "\n"


def parse_prometheus(text: str) -> dict[str, float]:
    """Parse exposition text -> {"name{label=\"v\"}" : value}. Series
    names keep their label string verbatim so snapshots and scrapes can
    be diffed key-by-key (round-trip test uses this)."""
    series: dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        key, _, val = line.rpartition(" ")
        series[key] = math.inf if val == "+Inf" else float(val)
    return series


def snapshot(tel) -> dict:
    """JSON-able snapshot of a Telemetry: metrics + slow-query log.

    Counters carry ``total`` and ``window``; histograms carry bucket
    counts plus bucket-estimated p50/p99 (``*_est`` to flag estimator
    resolution vs the exact benchmark percentiles).
    """
    reg = tel.registry
    metrics: dict[str, dict] = {}
    with reg._lock:
        for name in sorted(reg._families):
            fam = reg._families[name]
            entry: dict = {"kind": fam.kind, "help": fam.help,
                           "labels": list(fam.label_names), "series": []}
            if isinstance(fam, Histogram):
                entry["buckets"] = list(fam.buckets)
                for lv, c in sorted(fam._children.items()):
                    entry["series"].append({
                        "labels": dict(zip(fam.label_names, lv)),
                        "count": c.count, "sum": c.sum,
                        "counts": list(c.counts),
                        "p50_est": _bucket_pct(fam.buckets, c, 50.0),
                        "p99_est": _bucket_pct(fam.buckets, c, 99.0),
                    })
            elif isinstance(fam, Counter):
                for lv, c in sorted(fam._children.items()):
                    entry["series"].append({
                        "labels": dict(zip(fam.label_names, lv)),
                        "total": c.total, "window": c.total - c.mark})
            elif isinstance(fam, Gauge):
                for lv, c in sorted(fam._children.items()):
                    entry["series"].append({
                        "labels": dict(zip(fam.label_names, lv)),
                        "value": c.value})
            metrics[name] = entry
    return {
        "t_wall": time.time(),
        "metrics": metrics,
        "slow_queries": tel.slow_queries(),
        "slow_threshold_ms": tel.slow_threshold_s * 1e3,
    }


def _bucket_pct(buckets, child, q: float) -> float:
    if child.count == 0:
        return 0.0
    rank = math.ceil(q / 100.0 * child.count)
    acc = 0
    for i, n in enumerate(child.counts):
        acc += n
        if acc >= rank:
            return buckets[i] if i < len(buckets) else math.inf
    return math.inf  # pragma: no cover


def snapshot_json(tel, indent: int | None = None) -> str:
    return json.dumps(snapshot(tel), indent=indent, sort_keys=True)
