"""Span-based tracing + the :class:`Telemetry` facade (ISSUE 9).

A *span* is one timed region of the request path. Spans nest through a
per-thread stack: while a **root** span (a serve tile, a flush, a
reshard) is open, every nested stage span that finishes on the same
thread both records its duration into the shared
``sivf_stage_seconds{stage=...}`` histogram *and* contributes to the
root's per-stage breakdown — which is what makes a slow-query-log entry
say "23 ms total: 1 ms plan, 19 ms prefetch, 3 ms scan" instead of just
"23 ms".

:class:`Telemetry` bundles the three observability pieces one handle
needs: a :class:`~repro.obs.metrics.MetricsRegistry`, the span tracer,
and the rolling slow-query log (top-N root spans over a configurable
threshold, with stage breakdown and tenant/filter/epoch provenance).
It is **always-on-cheap**: with ``enabled=False`` (the process default)
``span()`` returns a shared no-op context manager and every recording
method returns after a single attribute check — instrumented code paths
never pay for telemetry they did not ask for. The serve-churn overhead
benchmark (``benchmarks/obs_bench.py``) gates the *enabled* cost too:
p99 with telemetry on must stay within 5% of off.

Usage::

    tel = Telemetry(enabled=True, slow_threshold_s=0.010)
    with tel.span("serve.search", root=True, tenant="app", epoch=3):
        with tel.span("plan"):
            ...
        with tel.span("scan"):
            ...
    tel.snapshot()            # JSON-able dict (metrics + slow queries)
    tel.render_prometheus()   # Prometheus text exposition
"""
from __future__ import annotations

import functools
import threading
import time

from repro.obs.metrics import MetricsRegistry

STAGE_HISTOGRAM = "sivf_stage_seconds"


class Span:
    """One timed region; produced by :meth:`Telemetry.span` /
    :meth:`Telemetry.open_span`. ``stages`` accumulates nested spans'
    durations (root spans only, by stage name)."""

    __slots__ = ("name", "root", "attrs", "t0", "t1", "stages", "_tel")

    def __init__(self, tel: "Telemetry", name: str, root: bool,
                 attrs: dict, t0: float):
        self._tel = tel
        self.name = name
        self.root = root
        self.attrs = attrs
        self.t0 = t0
        self.t1: float | None = None
        self.stages: dict[str, float] = {}

    @property
    def duration_s(self) -> float:
        return (self.t1 if self.t1 is not None
                else self._tel._clock()) - self.t0

    def add_stage(self, stage: str, seconds: float) -> None:
        self.stages[stage] = self.stages.get(stage, 0.0) + seconds

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, root={self.root}, "
                f"dur={self.duration_s * 1e3:.3f}ms, stages="
                f"{sorted(self.stages)})")


class _NoopSpan:
    """Shared do-nothing context manager for the disabled fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def add_stage(self, stage, seconds):
        pass


_NOOP = _NoopSpan()


class _SpanCtx:
    """Context manager binding one live span to the thread's stack."""

    __slots__ = ("_tel", "_span")

    def __init__(self, tel: "Telemetry", span: Span):
        self._tel = tel
        self._span = span

    def __enter__(self) -> Span:
        self._tel._push(self._span)
        return self._span

    def __exit__(self, *exc) -> bool:
        self._tel._pop(self._span)
        self._tel.finish_span(self._span)
        return False


class Telemetry:
    """Per-process (or per-handle) observability hub.

    Parameters
    ----------
    enabled:          master switch. Disabled, every entry point is a
                      single-attribute-check no-op; flip
                      :attr:`enabled` at runtime to start/stop recording
                      (the overhead benchmark toggles it mid-run).
    slow_threshold_s: root spans at least this long enter the slow-query
                      log (0 logs every root span — tests use that).
    slow_log_size:    the log keeps the N slowest qualifying spans seen
                      since the last :meth:`clear_slow_log`.
    clock:            injectable monotonic clock for deterministic tests.
    """

    def __init__(self, enabled: bool = True,
                 slow_threshold_s: float = 0.050,
                 slow_log_size: int = 32, clock=time.perf_counter):
        self.enabled = bool(enabled)
        self.slow_threshold_s = float(slow_threshold_s)
        self.slow_log_size = int(slow_log_size)
        self._clock = clock
        self.registry = MetricsRegistry()
        self._stage_hist = self.registry.histogram(
            STAGE_HISTOGRAM, "wall seconds per pipeline stage", ("stage",))
        self._slow_counter = self.registry.counter(
            "sivf_slow_queries_total",
            "root spans over the slow-query threshold")
        self._local = threading.local()
        self._slow_lock = threading.Lock()
        self._slow: list[dict] = []

    # -- span API ------------------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _push(self, span: Span) -> None:
        self._stack().append(span)

    def _pop(self, span: Span) -> None:
        st = self._stack()
        if st and st[-1] is span:
            st.pop()

    def span(self, name: str, root: bool | str = False, **attrs):
        """Context manager timing one region. Non-root spans feed the
        innermost enclosing root span's stage breakdown; root spans are
        slow-query-log candidates. ``root="auto"`` makes the span a root
        only when no root is already open on this thread (a directly-used
        Index.search is a root; the same call under a serve tile is a
        stage). No-op when disabled."""
        if not self.enabled:
            return _NOOP
        if root == "auto":
            root = self._enclosing_root() is None
        return _SpanCtx(self, Span(self, name, bool(root), attrs,
                                   self._clock()))

    def open_span(self, name: str, root: bool = True, **attrs
                  ) -> "Span | None":
        """Begin a span whose end is *not* lexically scoped (e.g. a serve
        tile: dispatched now, completed at result resolution). Pushes it
        on this thread's stack; call :meth:`exit_scope` when the region
        that spawns nested stages ends, then :meth:`finish_span` when the
        span's real end time arrives. Returns ``None`` when disabled."""
        if not self.enabled:
            return None
        sp = Span(self, name, root, attrs, self._clock())
        self._push(sp)
        return sp

    def exit_scope(self, span: "Span | None") -> None:
        """Remove an :meth:`open_span` from the nesting stack without
        recording it (its duration keeps running)."""
        if span is not None:
            self._pop(span)

    def finish_span(self, span: "Span | None", t1: float | None = None
                    ) -> None:
        """Record a span: stage histogram + root bookkeeping (slow log)."""
        if span is None or not self.enabled:
            return
        span.t1 = self._clock() if t1 is None else t1
        dur = span.t1 - span.t0
        self._stage_hist.observe(dur, stage=span.name)
        root = self._enclosing_root()
        if root is not None and root is not span:
            root.add_stage(span.name, dur)
        if span.root and dur >= self.slow_threshold_s:
            self._log_slow(span, dur)

    def _enclosing_root(self) -> "Span | None":
        for sp in reversed(self._stack()):
            if sp.root:
                return sp
        return None

    def record_duration(self, stage: str, seconds: float,
                        attach: bool = True) -> None:
        """Record a pre-measured duration as if a span ran (queue waits
        are measured from request timestamps, not a context manager)."""
        if not self.enabled:
            return
        self._stage_hist.observe(seconds, stage=stage)
        if attach:
            root = self._enclosing_root()
            if root is not None:
                root.add_stage(stage, seconds)

    def traced(self, name: str, root: bool = False):
        """Decorator form of :meth:`span`."""
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*a, **kw):
                with self.span(name, root=root):
                    return fn(*a, **kw)
            return wrapper
        return deco

    # -- slow-query log ------------------------------------------------------

    def _log_slow(self, span: Span, dur: float) -> None:
        self._slow_counter.inc()
        entry = {
            "span": span.name,
            "duration_ms": round(dur * 1e3, 3),
            "stages_ms": {k: round(v * 1e3, 3)
                          for k, v in sorted(span.stages.items())},
            "t_wall": time.time(),
        }
        entry.update({k: v for k, v in span.attrs.items()
                      if v is not None})
        with self._slow_lock:
            self._slow.append(entry)
            if len(self._slow) > self.slow_log_size:
                self._slow.sort(key=lambda e: -e["duration_ms"])
                del self._slow[self.slow_log_size:]

    def slow_queries(self) -> list[dict]:
        """The current slow-query log, slowest first."""
        with self._slow_lock:
            return sorted(self._slow, key=lambda e: -e["duration_ms"])

    def clear_slow_log(self) -> None:
        with self._slow_lock:
            self._slow.clear()

    # -- metric passthrough --------------------------------------------------

    def counter(self, name, help="", labels=()):
        return self.registry.counter(name, help, labels)

    def gauge(self, name, help="", labels=()):
        return self.registry.gauge(name, help, labels)

    def histogram(self, name, help="", labels=(), **kw):
        return self.registry.histogram(name, help, labels, **kw)

    def roll_window(self) -> None:
        self.registry.roll_window()

    # -- exporters -----------------------------------------------------------

    def snapshot(self) -> dict:
        from repro.obs.export import snapshot
        return snapshot(self)

    def render_prometheus(self) -> str:
        from repro.obs.export import render_prometheus
        return render_prometheus(self)
