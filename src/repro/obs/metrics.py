"""Zero-dependency metrics registry: counters, gauges, log-bucket histograms.

The runtime half of the observability layer (ISSUE 9). Three metric
kinds, all label-aware and thread-safe under one registry lock:

  * :class:`Counter` — monotone totals with a *windowed* twin: every
    child keeps its cumulative total **and** the delta since the last
    :meth:`MetricsRegistry.roll_window`, so operators can read both
    "since process start" and "since the last scrape" without the
    cumulative-only trap the tiered cache's ``hit_rate`` used to have.
  * :class:`Gauge` — last-write-wins point-in-time values (queue depth,
    executable counts, epoch).
  * :class:`Histogram` — fixed log2 latency buckets (1 µs .. ~67 s,
    :data:`BUCKETS_S`), identical for every histogram in the process so
    percentiles from different stages are comparable and the Prometheus
    ``le`` label set never varies. Row-count histograms (coalesce sizes)
    pass their own pow2 bucket bounds.

Percentile math lives here too (:func:`percentiles`,
:func:`latency_summary_ms`): the benchmarks (``serve_bench``,
``paper.streaming_churn``, ``tiered_bench``) consume these helpers
instead of hand-rolling ``np.percentile`` calls, so the p50/p99
definitions in benchmark artifacts and runtime snapshots share one
source of truth. :class:`WindowedCounter` is the scalar (label-free)
building block the tiered runtime uses for its cache counters — same
cumulative+window semantics, carryable across a reshard.

Everything here is plain Python + numpy; no external metrics client.
"""
from __future__ import annotations

import bisect
import math
import threading

import numpy as np

# One fixed log2 bucket scheme for every latency histogram: 1 µs doubling
# up to ~67 s, then +inf. 27 buckets keeps a histogram child at ~28 ints.
BUCKET_FLOOR_S = 1e-6
N_BUCKETS = 27
BUCKETS_S: tuple[float, ...] = tuple(
    BUCKET_FLOOR_S * (2.0 ** i) for i in range(N_BUCKETS))


def percentiles(samples, qs=(50.0, 99.0)) -> dict[float, float]:
    """Exact percentiles of raw samples: ``{q: value}``.

    The single definition of "p50/p99" shared by the benchmarks and the
    tests (linear interpolation, numpy's default). Empty input -> 0.0s.
    """
    a = np.asarray(list(samples), np.float64)
    if a.size == 0:
        return {float(q): 0.0 for q in qs}
    vals = np.percentile(a, list(qs))
    return {float(q): float(v) for q, v in zip(qs, vals)}


def latency_summary_ms(samples_s, round_to: int = 3) -> dict[str, float]:
    """p50/p99/p999 of latencies in *seconds* -> the benchmark-artifact
    ``{"p50_ms", "p99_ms", "p999_ms"}`` dict (one source of truth for the
    serve/tiered/churn artifacts' percentile fields)."""
    p = percentiles(samples_s, (50.0, 99.0, 99.9))
    return {"p50_ms": round(p[50.0] * 1e3, round_to),
            "p99_ms": round(p[99.0] * 1e3, round_to),
            "p999_ms": round(p[99.9] * 1e3, round_to)}


class WindowedCounter:
    """Label-free cumulative + windowed counter (no lock; callers that
    share one across threads synchronize externally).

    ``total`` accumulates forever; ``window`` is the delta since the last
    :meth:`mark`. :meth:`carry` adopts another instance's state — the
    tiered runtime uses it so a reshard (which rebuilds the runtime)
    *carries* cumulative cache counters instead of silently zeroing them.
    """

    __slots__ = ("total", "_mark")

    def __init__(self, total: int = 0, mark: int = 0):
        self.total = total
        self._mark = mark

    def add(self, n: int = 1) -> None:
        self.total += n

    @property
    def window(self) -> int:
        return self.total - self._mark

    def mark(self) -> None:
        self._mark = self.total

    def carry(self, other: "WindowedCounter") -> "WindowedCounter":
        self.total, self._mark = other.total, other._mark
        return self


class _Family:
    """Shared label plumbing: one named metric family -> per-label children.

    Children are keyed by the tuple of label *values* in the family's
    declared label-name order; a label-free family has the single child
    key ``()``.
    """

    kind = "untyped"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str,
                 label_names: tuple[str, ...]):
        self._registry = registry
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._children: dict[tuple, object] = {}

    def _key(self, labels: dict) -> tuple:
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.label_names)}")
        return tuple(str(labels[k]) for k in self.label_names)

    def _child(self, labels: dict):
        key = self._key(labels)
        child = self._children.get(key)
        if child is None:
            child = self._new_child()
            self._children[key] = child
        return child

    def _new_child(self):  # pragma: no cover - overridden
        raise NotImplementedError

    def items(self):
        """[(label_values_tuple, child)] snapshot-ordered for export."""
        return sorted(self._children.items())


class _CounterChild:
    __slots__ = ("total", "mark")

    def __init__(self):
        self.total = 0.0
        self.mark = 0.0


class Counter(_Family):
    """Monotone counter family with cumulative + windowed reads."""

    kind = "counter"

    def _new_child(self):
        return _CounterChild()

    def inc(self, n: float = 1, **labels) -> None:
        if n < 0:
            raise ValueError(f"{self.name}: counters only go up (n={n})")
        with self._registry._lock:
            self._child(labels).total += n

    def get(self, **labels) -> float:
        with self._registry._lock:
            return self._child(labels).total

    def get_window(self, **labels) -> float:
        """Delta since the registry's last :meth:`~MetricsRegistry.roll_window`."""
        with self._registry._lock:
            c = self._child(labels)
            return c.total - c.mark


class _GaugeChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0


class Gauge(_Family):
    kind = "gauge"

    def _new_child(self):
        return _GaugeChild()

    def set(self, v: float, **labels) -> None:
        with self._registry._lock:
            self._child(labels).value = float(v)

    def get(self, **labels) -> float:
        with self._registry._lock:
            return self._child(labels).value


class _HistogramChild:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)      # +1 = the +inf bucket
        self.sum = 0.0
        self.count = 0


class Histogram(_Family):
    """Fixed-bucket histogram; default buckets are :data:`BUCKETS_S`."""

    kind = "histogram"

    def __init__(self, registry, name, help, label_names,
                 buckets: tuple[float, ...] = BUCKETS_S):
        super().__init__(registry, name, help, label_names)
        self.buckets = tuple(float(b) for b in buckets)

    def _new_child(self):
        return _HistogramChild(len(self.buckets))

    def observe(self, v: float, **labels) -> None:
        v = float(v)
        i = bisect.bisect_left(self.buckets, v)   # first bound >= v
        with self._registry._lock:
            c = self._child(labels)
            c.counts[i] += 1
            c.sum += v
            c.count += 1

    def get(self, **labels) -> dict:
        with self._registry._lock:
            c = self._child(labels)
            return {"count": c.count, "sum": c.sum,
                    "counts": list(c.counts)}

    def percentile(self, q: float, **labels) -> float:
        """Bucket-resolved percentile estimate (upper bound of the bucket
        holding the q-th sample; exact math for benchmarks lives in
        :func:`percentiles` — this is the runtime-snapshot estimator)."""
        with self._registry._lock:
            c = self._child(labels)
            if c.count == 0:
                return 0.0
            rank = math.ceil(q / 100.0 * c.count)
            acc = 0
            for i, n in enumerate(c.counts):
                acc += n
                if acc >= rank:
                    return self.buckets[i] if i < len(self.buckets) \
                        else math.inf
        return math.inf                          # pragma: no cover


class MetricsRegistry:
    """Named metric families behind one lock; the exporter's data source."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}

    def _register(self, cls, name, help, labels, **kw) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if not isinstance(fam, cls) or \
                        fam.label_names != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} re-registered as {cls.kind} with "
                        f"labels {tuple(labels)} (was {fam.kind} "
                        f"{fam.label_names})")
                return fam
            fam = cls(self, name, help, tuple(labels), **kw)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labels: tuple[str, ...] = ()) -> Counter:
        return self._register(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: tuple[str, ...] = ()) -> Gauge:
        return self._register(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: tuple[str, ...] = (),
                  buckets: tuple[float, ...] = BUCKETS_S) -> Histogram:
        return self._register(Histogram, name, help, labels,
                              buckets=buckets)

    def roll_window(self) -> None:
        """Start a new window: every counter's windowed read resets to 0
        (cumulative totals are untouched)."""
        with self._lock:
            for fam in self._families.values():
                if isinstance(fam, Counter):
                    for c in fam._children.values():
                        c.mark = c.total

    def families(self) -> list[_Family]:
        with self._lock:
            return [self._families[k] for k in sorted(self._families)]
