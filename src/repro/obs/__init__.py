"""repro.obs — zero-dependency telemetry for the SIVF runtime (ISSUE 9).

Three pieces:

  * :mod:`repro.obs.metrics` — Counter / Gauge / Histogram registry with
    label sets, fixed log2 latency buckets, windowed+cumulative counter
    reads, and the shared benchmark percentile helpers.
  * :mod:`repro.obs.trace`   — span-based tracing (`Telemetry.span()`),
    per-stage histograms, and the rolling slow-query log.
  * :mod:`repro.obs.export`  — Prometheus text renderer + JSON snapshot.

A process-wide default :class:`Telemetry` (disabled — the no-op fast
path — until :func:`enable` is called) backs `sivf.telemetry`; handles
(`Index`, `ServeEngine`) use it unless given their own instance.
"""
from __future__ import annotations

from repro.obs.export import (parse_prometheus, render_prometheus, snapshot,
                              snapshot_json)
from repro.obs.metrics import (BUCKETS_S, Counter, Gauge, Histogram,
                               MetricsRegistry, WindowedCounter,
                               latency_summary_ms, percentiles)
from repro.obs.trace import Span, Telemetry

_default = Telemetry(enabled=False)


def default() -> Telemetry:
    """The process-wide default Telemetry (shared by every handle that
    wasn't constructed with an explicit ``telemetry=``)."""
    return _default


def enable(slow_threshold_s: float | None = None,
           slow_log_size: int | None = None) -> Telemetry:
    """Switch the default Telemetry on (optionally retuning the
    slow-query log) and return it."""
    if slow_threshold_s is not None:
        _default.slow_threshold_s = float(slow_threshold_s)
    if slow_log_size is not None:
        _default.slow_log_size = int(slow_log_size)
    _default.enabled = True
    return _default


def disable() -> Telemetry:
    """Switch the default Telemetry off (recorded data is kept)."""
    _default.enabled = False
    return _default


__all__ = [
    "BUCKETS_S", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Span", "Telemetry", "WindowedCounter", "default", "disable",
    "enable", "latency_summary_ms", "parse_prometheus", "percentiles",
    "render_prometheus", "snapshot", "snapshot_json",
]
