"""Tiered slab pool: host-resident cold store + on-device hot slab cache.

The single-tier pool (``core/state.py``) caps index capacity at accelerator
memory. This module splits the storage layer in two, the SVFusion/Fantasy
co-processing layout:

  * **Host store** (:class:`HostStore`) — the *canonical* payload planes
    (``data`` / ``codes`` / ``attrs``) as numpy arrays sized by the full
    ``cfg.n_slabs`` pool, bounded by host RAM. All slab *metadata* (ids,
    norms, bitmaps, chains, ATT, tables) stays device-resident: at
    dim=128 the metadata is ~64x smaller than the payloads, and keeping
    it on device means deletes, occupancy and chain bookkeeping never
    need host mirroring or cache invalidation.
  * **Device cache** (:class:`SlabCacheDev`) — ``cfg.device_slabs`` cache
    *frames* of the same per-slab payload width, plus the residency map
    ``frame_of`` (pool slab id -> frame, -1 = not resident) and its
    inverse ``slab_of_frame``. Host-side twins of both (plus per-frame
    LRU ticks and a dirty set) drive the replacement policy without any
    device round trip.

**Search** becomes a three-stage pipeline (:class:`TieredRuntime`):

  1. *plan* (jitted) — coarse probe + slab-table gather, exactly the
     prefix of the all-resident search, producing the pool-slab-id table
     ``[Q, T]``;
  2. *prefetch* (host) — one explicit ``device_get`` of the table, a
     ``np.unique`` dedupe (slab ids shared by several probed lists are
     fetched once — the ROADMAP's query-tile DMA dedupe), LRU eviction of
     victim frames, and one packed ``device_put`` + donated scatter that
     uploads only the *missing* (or dirty-resident) slabs' payload rows
     into their frames. A warm cache uploads nothing and touches the
     device zero times;
  3. *scan* (jitted) — rewrite the table into frame coordinates
     (``kernels.sivf_scan.ops.translate_table``), gather fresh per-frame
     metadata views from the full device metadata planes, and feed the
     *unmodified* fused/PQ/filtered scan->top-k dispatch. The kernels see
     a smaller pool and a translated table; their math is untouched, so
     results are bit-identical (ids AND distances) to the all-resident
     pool whenever the probed set fits the cache.

**Inserts** stay atomic across both tiers: the device commit
(``core.index._insert_impl(want_plan=True)``) emits a *plan* — per input
row the (slab, slot) the commit wrote, -1 everywhere the commit did not
(including the whole batch on an atomic abort), plus the device-encoded
PQ codes. The host store replays exactly those writes (deferred-friendly:
plans queue as device arrays and drain in one ``device_get`` at the next
prefetch/save/reshard), and every touched slab is marked *dirty* so a
resident frame re-uploads before the next scan reads it. **Deletes** are
metadata-only (bitmap punch) and need no host action at all — the scan's
per-frame metadata gather observes them immediately, which is how a
delete "punches both tiers" for free. Recycled slabs are covered by the
insert plan of the batch that reuses them.

Residency is **runtime-only** state: checkpoints always store the
assembled full-pool planes (:func:`assemble_full`), so the on-disk format
is unchanged (format 3) and any checkpoint loads tiered or untiered.

See docs/architecture.md (tiered memory section) for the dataflow
diagram.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as ix
from repro.core import quantizer
from repro.core.state import SIVFConfig, SlabPoolState
from repro.kernels.sivf_scan.ops import translate_table
from repro.obs.metrics import WindowedCounter


# ---------------------------------------------------------------------------
# Tier state containers
# ---------------------------------------------------------------------------

@partial(
    jax.tree_util.register_dataclass,
    data_fields=["data", "codes", "attrs", "frame_of", "slab_of_frame"],
    meta_fields=[],
)
@dataclasses.dataclass
class SlabCacheDev:
    """Device-resident hot-cache planes + residency map.

    Single backend shapes below; the mesh backend stacks a leading shard
    axis on every leaf (one independent cache per shard).
    """

    data: jax.Array           # [F, C, payload_dim] cached fp payload rows
    codes: jax.Array          # [F, C, code_m] uint8 cached PQ codes
    attrs: jax.Array          # [F, C, n_attrs] int32 cached attribute stamps
    frame_of: jax.Array       # [n_slabs] int32 slab -> frame (-1 = cold)
    slab_of_frame: jax.Array  # [F] int32 frame -> slab (-1 = empty frame)


def init_cache(cfg: SIVFConfig) -> SlabCacheDev:
    """Empty cache: every frame free, every slab cold."""
    f, c = cfg.device_slabs, cfg.capacity
    return SlabCacheDev(
        data=jnp.zeros((f, c, cfg.payload_dim), cfg.dtype),
        codes=jnp.zeros((f, c, cfg.code_m), jnp.uint8),
        attrs=jnp.zeros((f, c, cfg.n_attrs), jnp.int32),
        frame_of=jnp.full((cfg.n_slabs,), -1, jnp.int32),
        slab_of_frame=jnp.full((f,), -1, jnp.int32))


class HostStore:
    """One shard's canonical host-side payload planes (numpy)."""

    __slots__ = ("data", "codes", "attrs")

    def __init__(self, data: np.ndarray, codes: np.ndarray,
                 attrs: np.ndarray):
        self.data = data        # [n_slabs, C, payload_dim]
        self.codes = codes      # [n_slabs, C, code_m] uint8
        self.attrs = attrs      # [n_slabs, C, n_attrs] int32

    @classmethod
    def empty(cls, cfg: SIVFConfig) -> "HostStore":
        ns, c = cfg.n_slabs, cfg.capacity
        return cls(np.zeros((ns, c, cfg.payload_dim), np.dtype(cfg.dtype)),
                   np.zeros((ns, c, cfg.code_m), np.uint8),
                   np.zeros((ns, c, cfg.n_attrs), np.int32))

    def rows(self, slabs: np.ndarray):
        """Gather upload rows for a (padded) slab-id vector."""
        s = np.clip(slabs, 0, self.data.shape[0] - 1)
        return self.data[s], self.codes[s], self.attrs[s]


class _Residency:
    """One shard's host-side residency bookkeeping (LRU + dirty set)."""

    def __init__(self, cfg: SIVFConfig):
        self.frame_of = np.full((cfg.n_slabs,), -1, np.int32)
        self.slab_of_frame = np.full((cfg.device_slabs,), -1, np.int32)
        self.tick = np.zeros((cfg.device_slabs,), np.int64)
        self.clock = 0
        self.dirty: set[int] = set()

    @property
    def resident_slabs(self) -> int:
        return int((self.slab_of_frame >= 0).sum())


@dataclasses.dataclass(frozen=True)
class PrefetchTicket:
    """Proof that a query batch's probed slabs are resident.

    Returned by :meth:`TieredRuntime.prefetch`; pass it back to the scan
    stage to skip re-planning/re-prefetching. Valid only while nothing
    else has prefetched (``seq``) or mutated the index (``epoch``) since —
    the serve engine uses this to overlap the *next* tile's prefetch with
    the *current* tile's kernel execution, and a stale ticket silently
    falls back to the full three-stage path.
    """

    table: jax.Array          # [Q, T] (mesh: [S, Q, T]) pool-slab-id table
    nprobe: int
    padded_q: int             # query bucket the table was planned for
    seq: int                  # runtime prefetch sequence number at issue
    epoch: int                # Index.epoch at issue


# ---------------------------------------------------------------------------
# Jitted stage factories (lru_cached so equal configs share executables,
# mirroring core/api.py's _single_ops/_mesh_ops)
# ---------------------------------------------------------------------------

def cache_view(cfg: SIVFConfig, state: SlabPoolState, cache: SlabCacheDev
               ) -> SlabPoolState:
    """Frame-indexed view of the pool for the unmodified scan dispatch.

    Payload planes come from the cache frames; per-frame metadata (ids,
    norms, validity bitmaps) is gathered *fresh* from the full device
    metadata planes via ``slab_of_frame`` — so deletes and overwrites are
    visible to the very next scan with zero invalidation tracking. Empty
    frames mask to dead (bitmap 0 / ids -1); they are never referenced by
    a translated table anyway.
    """
    sof = jnp.clip(cache.slab_of_frame, 0)
    has = cache.slab_of_frame >= 0
    return dataclasses.replace(
        state,
        data=cache.data, codes=cache.codes, attrs=cache.attrs,
        ids=jnp.where(has[:, None], state.ids[sof], -1),
        norms=state.norms[sof],
        bitmap=jnp.where(has[:, None], state.bitmap[sof], jnp.uint32(0)))


@lru_cache(maxsize=None)
def _plan_ops(cfg: SIVFConfig, use_tables: bool | None):
    """Stage 1: probe + slab-table gather — the all-resident search prefix."""
    ut = cfg.track_tables if use_tables is None else use_tables

    @partial(jax.jit, static_argnames=("nprobe",))
    def plan(state, queries, nprobe):
        lists = quantizer.probe(state.centroids, queries.astype(cfg.dtype),
                                nprobe, cfg.metric)
        return (ix.gather_tables if ut else ix.walk_chains)(cfg, state, lists)

    return plan


@lru_cache(maxsize=None)
def _scan_ops(cfg: SIVFConfig, impl: str, block_q: int):
    """Stage 3: frame-translate the table and run the unmodified dispatch."""

    @partial(jax.jit, static_argnames=("k", "fstruct"))
    def scan(state, cache, queries, table, k, fstruct, fconsts):
        ftable = translate_table(table, cache.frame_of)
        view = cache_view(cfg, state, cache)
        return ix._scan_dispatch(cfg, view, queries, ftable, k, impl,
                                 block_q, fstruct=fstruct, fconsts=fconsts)

    return scan


@lru_cache(maxsize=None)
def _upload_ops(cfg: SIVFConfig):
    """Stage 2 device half: donated scatter of upload rows into frames.

    ``frames`` rows of -1 are padding (scatter drops them). Updates the
    device residency map for the uploaded slabs only — entries of evicted
    slabs go stale on device but are never read before a prefetch
    re-uploads them (a slab enters a table only via prefetch).
    """
    f_oob, ns = cfg.device_slabs, cfg.n_slabs

    @partial(jax.jit, donate_argnums=(0,))
    def upload(cache, frames, slabs, drows, crows, arows):
        f = jnp.where(frames >= 0, frames, f_oob)
        data = cache.data.at[f].set(drows, mode="drop")
        codes = cache.codes.at[f].set(crows, mode="drop")
        attrs = cache.attrs.at[f].set(arows, mode="drop")
        sof = cache.slab_of_frame.at[f].set(slabs, mode="drop")
        fof = cache.frame_of.at[jnp.where(frames >= 0, slabs, ns)].set(
            frames, mode="drop")
        return SlabCacheDev(data, codes, attrs, fof, sof)

    return upload


@lru_cache(maxsize=None)
def _upload_ops_mesh(cfg: SIVFConfig, n_shards: int):
    """Per-shard stacked variant of :func:`_upload_ops`."""
    f_oob, ns = cfg.device_slabs, cfg.n_slabs
    s_ix = np.arange(n_shards)[:, None]

    @partial(jax.jit, donate_argnums=(0,))
    def upload(cache, frames, slabs, drows, crows, arows):   # frames [S, U]
        f = jnp.where(frames >= 0, frames, f_oob)
        data = cache.data.at[s_ix, f].set(drows, mode="drop")
        codes = cache.codes.at[s_ix, f].set(crows, mode="drop")
        attrs = cache.attrs.at[s_ix, f].set(arows, mode="drop")
        sof = cache.slab_of_frame.at[s_ix, f].set(slabs, mode="drop")
        fof = cache.frame_of.at[
            s_ix, jnp.where(frames >= 0, slabs, ns)].set(frames, mode="drop")
        return SlabCacheDev(data, codes, attrs, fof, sof)

    return upload


# ---------------------------------------------------------------------------
# Full-state split / assemble (checkpoint + reshard interop)
# ---------------------------------------------------------------------------

def split_full(cfg: SIVFConfig, full: SlabPoolState
               ) -> tuple[SlabPoolState, list[HostStore]]:
    """Full-pool state (any backend, any leaf placement) -> (meta state
    with zero-width device payload planes, per-shard host stores)."""
    data = np.asarray(full.data)
    stacked = data.ndim == 4
    n_sh = data.shape[0] if stacked else 1
    codes = np.asarray(full.codes)
    attrs = np.asarray(full.attrs)
    stores = []
    for s in range(n_sh):
        stores.append(HostStore(
            np.ascontiguousarray(data[s] if stacked else data),
            np.ascontiguousarray(codes[s] if stacked else codes),
            np.ascontiguousarray(attrs[s] if stacked else attrs)))
    c = cfg.capacity
    shp = ((n_sh, 0) if stacked else (0,))
    meta = dataclasses.replace(
        full,
        data=np.zeros(shp + (c, cfg.payload_dim), data.dtype),
        codes=np.zeros(shp + (c, cfg.code_m), np.uint8),
        attrs=np.zeros(shp + (c, cfg.n_attrs), np.int32))
    return meta, stores


def assemble_full(cfg: SIVFConfig, meta: SlabPoolState,
                  stores: list[HostStore]) -> SlabPoolState:
    """(meta state, host stores) -> full-pool *host* state whose payload
    planes are the canonical host bytes — the value checkpoints store and
    ``flatten_live_rows`` / ``reshard_state`` consume. Byte-identical to
    what an all-resident pool would hold."""
    host = jax.tree.map(np.asarray, meta)
    stacked = host.ids.ndim == 3
    if stacked:
        return dataclasses.replace(
            host,
            data=np.stack([st.data for st in stores]),
            codes=np.stack([st.codes for st in stores]),
            attrs=np.stack([st.attrs for st in stores]))
    return dataclasses.replace(host, data=stores[0].data,
                               codes=stores[0].codes, attrs=stores[0].attrs)


def is_full_state(cfg: SIVFConfig, state: SlabPoolState) -> bool:
    """True when ``state`` carries full-width payload planes (vs the
    zero-width planes of a tiered meta state)."""
    return state.data.shape[-3] == cfg.n_slabs


def _pow2(n: int, floor: int = 8) -> int:
    b = floor
    while b < n:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------

class TieredRuntime:
    """Per-handle orchestration of the host store + device cache.

    Owned by ``sivf.Index`` when ``cfg.device_slabs`` is set; runtime-only
    (never checkpointed). One instance covers both backends: the mesh
    backend keeps one :class:`HostStore` + residency per shard and stacked
    cache planes sharded with the state.
    """

    _COUNTERS = ("hits", "misses", "refs", "unique_refs", "uploads",
                 "evictions")

    def __init__(self, cfg: SIVFConfig, backend_kind: str, mesh=None,
                 axis: str = "data", impl: str = "xla", block_q: int = 8,
                 use_tables: bool | None = None, n_shards: int = 1,
                 stores: list[HostStore] | None = None, telemetry=None):
        if not cfg.tiered:
            raise ValueError("TieredRuntime needs SIVFConfig(device_slabs=)")
        self.cfg = cfg
        self.backend_kind = backend_kind
        self.mesh = mesh
        self.axis = axis
        self.impl = impl
        self.block_q = block_q
        self.use_tables = use_tables
        self.n_shards = n_shards
        if stores is not None and len(stores) != n_shards:
            raise ValueError(
                f"{len(stores)} host stores for {n_shards} shards")
        self.stores = stores or [HostStore.empty(cfg)
                                 for _ in range(n_shards)]
        self.res = [_Residency(cfg) for _ in range(n_shards)]
        self.cache = self._init_cache_dev()
        self._plans: list[dict] = []     # queued insert plans (device refs)
        self.seq = 0                     # prefetch sequence number
        # counters (aggregated over shards; Index.stats surfaces them) —
        # WindowedCounters: cumulative totals + a delta window so stats()
        # can report both; roll_window()/carry_from() manage the lifecycle
        self.hits = WindowedCounter()        # resident probed slabs
        self.misses = WindowedCounter()      # uploaded-on-demand probed slabs
        self.refs = WindowedCounter()        # raw table refs (pre-dedupe)
        self.unique_refs = WindowedCounter() # post-dedupe references
        self.uploads = WindowedCounter()     # slabs uploaded (miss + dirty)
        self.evictions = WindowedCounter()   # occupied frames recycled
        self.last_prefetch: dict = {}
        if telemetry is None:
            from repro import obs
            telemetry = obs.default()
        self.tel = telemetry
        t = telemetry
        self._m_cache = t.counter(
            "sivf_tiered_cache_events_total",
            "tiered-cache events: hit/miss/eviction/upload/dirty_refresh/"
            "dedup_saved (probed-slab granularity)", ("event",))
        self._m_bytes = t.counter(
            "sivf_transfer_bytes_total",
            "explicit host<->device transfer bytes by direction and stage",
            ("direction", "stage"))

    # -- construction helpers ----------------------------------------------

    def _init_cache_dev(self) -> SlabCacheDev:
        one = init_cache(self.cfg)
        if self.backend_kind != "mesh":
            return one
        from jax.sharding import NamedSharding, PartitionSpec as P
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.n_shards,) + x.shape), one)
        sharding = NamedSharding(self.mesh, P(self.axis))
        return jax.tree.map(lambda x: jax.device_put(x, sharding), stacked)

    # -- insert-plan pipeline ----------------------------------------------

    def queue_plan(self, plan: dict, vecs, attrs) -> None:
        """Queue one committed batch's host-store writes.

        ``plan`` is the device dict from ``_insert_impl(want_plan=True)``
        (mesh: stacked [S, B] leaves). ``vecs`` / ``attrs`` are the batch
        rows in the same input order — numpy when the caller had host
        data (no transfer needed at drain), device arrays otherwise.
        Deferred-friendly: nothing syncs here.
        """
        self._plans.append({
            "slab": plan["slab"], "slot": plan["slot"],
            "codes": plan["codes"],
            "vecs": None if self.cfg.payload_dim == 0 else vecs,
            "attrs": attrs if self.cfg.n_attrs else None})

    def drain_plans(self) -> None:
        """Apply every queued plan to the host store (one ``device_get``)."""
        if not self._plans:
            return
        plans, self._plans = self._plans, []
        dev_leaves = [[p[k] for k in ("slab", "slot", "codes", "vecs",
                                      "attrs")
                       if isinstance(p[k], jax.Array)] for p in plans]
        host_flat = jax.device_get([x for sub in dev_leaves for x in sub])
        it = iter(host_flat)
        for p in plans:
            vals = {k: (next(it) if isinstance(p[k], jax.Array) else p[k])
                    for k in ("slab", "slot", "codes", "vecs", "attrs")}
            self._apply_plan(vals)

    def _apply_plan(self, p: dict) -> None:
        slab = np.asarray(p["slab"])
        slot = np.asarray(p["slot"])
        codes = np.asarray(p["codes"])
        stacked = slab.ndim == 2
        for s in range(self.n_shards):
            ps = slab[s] if stacked else slab
            po = slot[s] if stacked else slot
            rows = np.flatnonzero(ps >= 0)
            if rows.size == 0:
                continue
            tgt_s, tgt_o = ps[rows], po[rows]
            store = self.stores[s]
            if self.cfg.payload_dim:
                v = np.asarray(p["vecs"])
                store.data[tgt_s, tgt_o] = v[rows, :self.cfg.payload_dim
                                             ].astype(store.data.dtype)
            if self.cfg.code_m:
                pc = codes[s] if stacked else codes
                store.codes[tgt_s, tgt_o] = pc[rows]
            if self.cfg.n_attrs:
                a = np.asarray(p["attrs"])
                store.attrs[tgt_s, tgt_o] = a[rows]
            self.res[s].dirty.update(int(x) for x in np.unique(tgt_s))

    # -- the three search stages -------------------------------------------

    def plan(self, state: SlabPoolState, queries: jax.Array, nprobe: int
             ) -> jax.Array:
        """Stage 1 (jitted): probe lists -> pool slab-id table."""
        if self.backend_kind == "mesh":
            fn = _plan_ops_mesh(self.cfg, self.mesh, self.axis,
                                self.use_tables)
        else:
            fn = _plan_ops(self.cfg, self.use_tables)
        with self.tel.span("plan"):      # dispatch time; sync lands in
            return fn(state, queries, nprobe=nprobe)   # prefetch's get

    def prefetch(self, table: jax.Array, nprobe: int, epoch: int
                 ) -> PrefetchTicket:
        """Stage 2 (host): make every probed slab resident.

        One explicit ``device_get`` of the table; dedupe, evict, and — only
        when there are misses or dirty residents — one packed explicit
        ``device_put`` plus one donated scatter call. A fully warm cache
        performs **zero** transfers and zero device work here.
        """
        with self.tel.span("prefetch"):
            self.drain_plans()
            tbl = np.asarray(jax.device_get(table))
            per_shard = tbl if tbl.ndim == 3 else tbl[None]
            up_frames, up_slabs, total_up = [], [], 0
            stats = {"refs": 0, "unique": 0, "hits": 0, "misses": 0,
                     "dirty_refresh": 0, "uploaded": 0, "evicted": 0}
            for s in range(self.n_shards):
                f_s, s_s = self._prefetch_shard(s, per_shard[s], stats)
                up_frames.append(f_s)
                up_slabs.append(s_s)
                total_up += len(f_s)
            stats["dedup_saved"] = stats["refs"] - stats["unique"]
            self.last_prefetch = stats
            self.seq += 1
            if total_up:
                self._upload(up_frames, up_slabs)
            if self.tel.enabled:
                m = self._m_cache
                m.inc(stats["hits"], event="hit")
                m.inc(stats["misses"], event="miss")
                m.inc(stats["evicted"], event="eviction")
                m.inc(stats["uploaded"], event="upload")
                m.inc(stats["dirty_refresh"], event="dirty_refresh")
                m.inc(stats["dedup_saved"], event="dedup_saved")
                self._m_bytes.inc(tbl.nbytes, direction="d2h",
                                  stage="prefetch")
            return PrefetchTicket(table=table, nprobe=nprobe,
                                  padded_q=int(per_shard.shape[-2]),
                                  seq=self.seq, epoch=epoch)

    def _prefetch_shard(self, s: int, tbl: np.ndarray, stats: dict
                        ) -> tuple[list[int], list[int]]:
        """LRU bookkeeping for one shard -> (upload frames, upload slabs)."""
        res = self.res[s]
        flat = tbl[tbl >= 0]
        uniq = np.unique(flat)
        stats["refs"] += int(flat.size)
        stats["unique"] += int(uniq.size)
        self.refs.add(int(flat.size))
        self.unique_refs.add(int(uniq.size))
        f_cap = self.cfg.device_slabs
        if uniq.size > f_cap:
            raise ValueError(
                f"query batch probes {uniq.size} distinct slabs on shard "
                f"{s} but device_slabs={f_cap}: the hot cache cannot hold "
                f"one batch's working set — raise device_slabs, lower "
                f"nprobe, or shrink the query batch")
        frame = res.frame_of[uniq]
        hit_slabs = uniq[frame >= 0]
        miss_slabs = uniq[frame < 0]
        dirty_hits = np.array(
            [sl for sl in hit_slabs if int(sl) in res.dirty], np.int32)
        stats["hits"] += int(hit_slabs.size)
        stats["misses"] += int(miss_slabs.size)
        stats["dirty_refresh"] += int(dirty_hits.size)
        self.hits.add(int(hit_slabs.size))
        self.misses.add(int(miss_slabs.size))
        res.clock += 1
        res.tick[res.frame_of[hit_slabs]] = res.clock
        up_frames: list[int] = []
        up_slabs: list[int] = []
        if miss_slabs.size:
            needed = np.zeros((self.cfg.n_slabs,), bool)
            needed[uniq] = True
            free = np.flatnonzero(res.slab_of_frame < 0)
            occ = np.flatnonzero(res.slab_of_frame >= 0)
            evictable = occ[~needed[res.slab_of_frame[occ]]]
            evictable = evictable[np.argsort(res.tick[evictable],
                                             kind="stable")]
            victims = np.concatenate([free, evictable])[:miss_slabs.size]
            for fr, sl in zip(victims, miss_slabs):
                old = int(res.slab_of_frame[fr])
                if old >= 0:
                    res.frame_of[old] = -1
                    res.dirty.discard(old)
                    self.evictions.add(1)
                    stats["evicted"] += 1
                res.slab_of_frame[fr] = sl
                res.frame_of[sl] = fr
                res.tick[fr] = res.clock
                res.dirty.discard(int(sl))
                up_frames.append(int(fr))
                up_slabs.append(int(sl))
        for sl in dirty_hits:                  # refresh in place, same frame
            res.dirty.discard(int(sl))
            up_frames.append(int(res.frame_of[sl]))
            up_slabs.append(int(sl))
        self.uploads.add(len(up_frames))
        stats["uploaded"] += len(up_frames)
        return up_frames, up_slabs

    def _upload(self, up_frames: list[list[int]], up_slabs: list[list[int]]
                ) -> None:
        """Pack per-shard upload sets and run the donated cache scatter."""
        u = _pow2(max(max((len(f) for f in up_frames), default=0), 1))
        n = self.n_shards
        frames = np.full((n, u), -1, np.int32)
        slabs = np.zeros((n, u), np.int32)
        drows = np.zeros((n, u) + self.stores[0].data.shape[1:],
                         self.stores[0].data.dtype)
        crows = np.zeros((n, u) + self.stores[0].codes.shape[1:], np.uint8)
        arows = np.zeros((n, u) + self.stores[0].attrs.shape[1:], np.int32)
        for s in range(n):
            m = len(up_frames[s])
            if not m:
                continue
            frames[s, :m] = up_frames[s]
            slabs[s, :m] = up_slabs[s]
            d, c, a = self.stores[s].rows(slabs[s, :m])
            drows[s, :m], crows[s, :m], arows[s, :m] = d, c, a
        if self.backend_kind == "mesh":
            args = jax.device_put((frames, slabs, drows, crows, arows))
            self.cache = _upload_ops_mesh(self.cfg, n)(self.cache, *args)
            up_bytes = sum(a.nbytes for a in
                           (frames, slabs, drows, crows, arows))
        else:
            # ONE explicit host->device transfer per prefetch-with-misses:
            # the packed tuple is the only transfer site in steady state
            args = jax.device_put((frames[0], slabs[0], drows[0], crows[0],
                                   arows[0]))
            self.cache = _upload_ops(self.cfg)(self.cache, *args)
            up_bytes = sum(a.nbytes for a in args)
        if self.tel.enabled:
            self._m_bytes.inc(up_bytes, direction="h2d", stage="prefetch")

    def scan(self, state: SlabPoolState, queries: jax.Array,
             table: jax.Array, k: int, fstruct, fconsts
             ) -> tuple[jax.Array, jax.Array]:
        """Stage 3 (jitted): frame-translated scan -> top-k."""
        if self.backend_kind == "mesh":
            fn = _scan_ops_mesh(self.cfg, self.mesh, self.axis, self.impl,
                                self.block_q)
        else:
            fn = _scan_ops(self.cfg, self.impl, self.block_q)
        with self.tel.span("scan"):      # dispatch time; the caller's
            return fn(state, self.cache, queries, table, k=k,   # sync point
                      fstruct=fstruct, fconsts=fconsts)  # absorbs exec time

    def search(self, state: SlabPoolState, queries: jax.Array, k: int,
               nprobe: int, fstruct=None, fconsts=None, epoch: int = 0,
               ticket: PrefetchTicket | None = None
               ) -> tuple[jax.Array, jax.Array]:
        """The full three-stage tiered search.

        A valid ``ticket`` (same runtime ``seq`` — nothing prefetched
        since — same ``epoch``, ``nprobe`` and query bucket) skips stages
        1-2; anything stale falls back to the full path.
        """
        if not (ticket is not None and ticket.seq == self.seq
                and ticket.epoch == epoch and ticket.nprobe == nprobe
                and ticket.padded_q == int(queries.shape[0])):
            table = self.plan(state, queries, nprobe)
            ticket = self.prefetch(table, nprobe, epoch)
        return self.scan(state, queries, ticket.table, k, fstruct, fconsts)

    # -- introspection ------------------------------------------------------

    def compile_stats(self) -> dict:
        def size(f):
            try:
                return int(f._cache_size())
            except Exception:               # pragma: no cover - private API
                return -1
        if self.backend_kind == "mesh":
            plan = _plan_ops_mesh(self.cfg, self.mesh, self.axis,
                                  self.use_tables)
            scan = _scan_ops_mesh(self.cfg, self.mesh, self.axis, self.impl,
                                  self.block_q)
        else:
            plan = _plan_ops(self.cfg, self.use_tables)
            scan = _scan_ops(self.cfg, self.impl, self.block_q)
        return {"tiered_plan": size(plan), "tiered_scan": size(scan)}

    def roll_window(self) -> None:
        """Start a new stats window: the ``*_window`` reads in
        :meth:`stats` reset to 0 (cumulative totals are untouched)."""
        for name in self._COUNTERS:
            getattr(self, name).mark()

    def carry_from(self, other: "TieredRuntime") -> "TieredRuntime":
        """Adopt another runtime's cumulative counters (and their window
        marks). ``Index.reshard`` rebuilds the runtime and calls this so a
        reshard no longer silently zeroes the cache statistics."""
        for name in self._COUNTERS:
            getattr(self, name).carry(getattr(other, name))
        return self

    def stats(self) -> dict:
        probed = self.hits.total + self.misses.total
        probed_w = self.hits.window + self.misses.window
        return {
            "tiered": True,
            "device_slabs": self.cfg.device_slabs,
            "resident_slabs": sum(r.resident_slabs for r in self.res),
            "per_shard_resident": [r.resident_slabs for r in self.res],
            # labeled explicitly: hit_rate is CUMULATIVE (handle lifetime,
            # carried across reshard); hit_rate_window covers only the
            # probes since the last roll_window()
            "hit_rate": (self.hits.total / probed) if probed else 1.0,
            "hit_rate_kind": "cumulative",
            "hit_rate_window": (self.hits.window / probed_w)
            if probed_w else 1.0,
            "cache_hits": self.hits.total,
            "cache_misses": self.misses.total,
            "cache_uploads": self.uploads.total,
            "cache_evictions": self.evictions.total,
            "cache_hits_window": self.hits.window,
            "cache_misses_window": self.misses.window,
            "dedup_refs": self.refs.total,
            "dedup_unique_refs": self.unique_refs.total,
            "dedup_saved_fetches": self.refs.total - self.unique_refs.total,
            "dirty_slabs": sum(len(r.dirty) for r in self.res),
            "pending_plans": len(self._plans),
        }


# ---------------------------------------------------------------------------
# Mesh stage factories (shard_map bodies mirroring core/distributed.py)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _plan_ops_mesh(cfg: SIVFConfig, mesh, axis: str,
                   use_tables: bool | None):
    from jax.sharding import PartitionSpec as P

    from repro.utils import shard_map_compat
    ut = cfg.track_tables if use_tables is None else use_tables

    @partial(jax.jit, static_argnames=("nprobe",))
    def plan(state, queries, nprobe):
        def local(st, q):
            st = jax.tree.map(lambda x: x[0], st)
            lists = quantizer.probe(st.centroids, q.astype(cfg.dtype),
                                    nprobe, cfg.metric)
            tab = (ix.gather_tables if ut else ix.walk_chains)(cfg, st,
                                                               lists)
            return tab[None]

        f = shard_map_compat(
            local, mesh=mesh, check_vma=False,
            in_specs=(jax.tree.map(lambda _: P(axis), state), P()),
            out_specs=P(axis))
        return f(state, queries)                       # [S, Q, T]

    return plan


@lru_cache(maxsize=None)
def _scan_ops_mesh(cfg: SIVFConfig, mesh, axis: str, impl: str,
                   block_q: int):
    from jax.sharding import PartitionSpec as P

    from repro.utils import shard_map_compat

    @partial(jax.jit, static_argnames=("k", "fstruct"))
    def scan(state, cache, queries, table, k, fstruct, fconsts):
        def local(st, ca, q, tab, *fc):
            st = jax.tree.map(lambda x: x[0], st)
            ca = jax.tree.map(lambda x: x[0], ca)
            ftable = translate_table(tab[0], ca.frame_of)
            view = cache_view(cfg, st, ca)
            d, lab = ix._scan_dispatch(
                cfg, view, q, ftable, k, impl, block_q, fstruct=fstruct,
                fconsts=fc[0] if fc else None)
            # identical scatter-gather merge to distributed.sharded_search
            dg = jax.lax.all_gather(d, axis)           # [S, Q, k]
            lg = jax.lax.all_gather(lab, axis)
            s, qn, _ = dg.shape
            dg = jnp.moveaxis(dg, 0, 1).reshape(qn, s * k)
            lg = jnp.moveaxis(lg, 0, 1).reshape(qn, s * k)
            nd, idx = jax.lax.top_k(-dg, k)
            return -nd, jnp.take_along_axis(lg, idx, axis=1)

        extra = () if fconsts is None else (fconsts,)
        f = shard_map_compat(
            local, mesh=mesh, check_vma=False,
            in_specs=(jax.tree.map(lambda _: P(axis), state),
                      jax.tree.map(lambda _: P(axis), cache), P(), P(axis))
            + tuple(P() for _ in extra),
            out_specs=(P(), P()))
        return f(state, cache, queries, table, *extra)

    return scan
