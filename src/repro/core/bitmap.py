"""Packed validity bitmaps (paper §3.1).

Each slab carries a C-bit validity bitmap stored as ``C // 32`` uint32
words. The bitmap is the *single source of truth* for logical membership
(Theorems 3.1-3.3): a slot (slab, o) holds a live vector iff bit ``o`` is
set. The paper uses C = 32 (one warp); on TPU we default to C = 128 (one
lane row), i.e. four words per slab.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WORD_BITS = 32


def n_words(capacity: int) -> int:
    if capacity % WORD_BITS != 0:
        raise ValueError(f"slab capacity {capacity} must be a multiple of {WORD_BITS}")
    return capacity // WORD_BITS


def slot_word_bit(slot: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Decompose slot index -> (word index, bit mask)."""
    word = slot // WORD_BITS
    bit = jnp.left_shift(jnp.uint32(1), (slot % WORD_BITS).astype(jnp.uint32))
    return word, bit


def get_bits(bitmap: jax.Array, slab: jax.Array, slot: jax.Array) -> jax.Array:
    """Read validity bits for coordinates. bitmap [n_slabs, W]; returns bool."""
    word, bit = slot_word_bit(slot)
    w = bitmap[slab, word]
    return (w & bit) != 0


def unpack(bitmap_row: jax.Array, capacity: int) -> jax.Array:
    """Unpack one slab's words -> [capacity] bool mask (slot-ordered)."""
    w = n_words(capacity)
    words = bitmap_row.reshape(w, 1)                                  # [W,1]
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)[None, :]          # [1,32]
    bits = (jnp.right_shift(words, shifts) & jnp.uint32(1)) != 0      # [W,32]
    return bits.reshape(capacity)


def unpack_batch(bitmap_rows: jax.Array, capacity: int) -> jax.Array:
    """Unpack [..., W] words -> [..., capacity] bool mask."""
    w = n_words(capacity)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (jnp.right_shift(bitmap_rows[..., None], shifts) & jnp.uint32(1)) != 0
    return bits.reshape(*bitmap_rows.shape[:-1], w * WORD_BITS)


def popcount_rows(bitmap: jax.Array) -> jax.Array:
    """Per-slab population count. bitmap [n_slabs, W] -> [n_slabs] int32."""
    x = bitmap
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    x = (x * jnp.uint32(0x01010101)) >> 24
    return jnp.sum(x.astype(jnp.int32), axis=-1)
