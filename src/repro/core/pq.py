"""Product-quantization codec: trainable per-subspace codebooks + ADC.

SIVF's fused search is bandwidth-bound on raw fp32 slab DMA; PQ cuts the
bytes a slab scan moves by ~8-16x by storing each vector as ``m`` one-byte
codewords (one per ``dim/m``-dimensional subspace) instead of ``dim`` fp32
components. Search never decompresses: per query, an *asymmetric distance
computation* (ADC) table ``T[s, j] = d(q_s, codebook[s, j])`` is built
once, and a candidate's distance is the sum of ``m`` table lookups — the
quantity the fused kernel (``kernels/sivf_scan/pq_fused.py``) and the XLA
reference (``core.index.scan_slabs_topk_pq``) both compute, bit-for-bit
identically.

This module is deliberately state-free: codebooks are plain arrays that
live inside ``SlabPoolState.pq_codebooks`` (so they checkpoint and shard
with the rest of the index) and every function here is jit-safe.

Conventions:
  * ``codebooks``: ``[m, ksub, dsub]`` f32 with ``ksub = 2**nbits`` and
    ``dsub = dim // m``;
  * ``codes``: ``[..., m]`` uint8 (one byte per subspace even for
    ``nbits < 8`` — sub-byte packing is a recorded follow-up);
  * codeword assignment is always the L2-nearest centroid per subspace
    (standard PQ, metric-independent); the *metric* only changes the ADC
    table contents (squared-L2 partials vs negated inner products).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import quantizer


@dataclasses.dataclass(frozen=True)
class PQConfig:
    """Static PQ configuration (hashable; nests inside ``SIVFConfig.pq``).

    ``m``        — number of subspaces (must divide ``dim``); stored bytes
                   per vector = ``m`` (one uint8 codeword per subspace).
    ``nbits``    — bits per codeword; codebook size ``ksub = 2**nbits``.
    ``store_raw``— keep the fp32 payload plane next to the codes (for
                   reranking / debugging). Default False: codes *replace*
                   the payload, which is where the memory win comes from.
    """

    m: int
    nbits: int = 8
    store_raw: bool = False

    def __post_init__(self):
        if self.m < 1:
            raise ValueError(f"pq.m must be >= 1, got {self.m}")
        if not 1 <= self.nbits <= 8:
            raise ValueError(f"pq.nbits must be in [1, 8], got {self.nbits}")

    @property
    def ksub(self) -> int:
        return 1 << self.nbits

    def code_bytes(self) -> int:
        """Stored bytes per vector (one uint8 per subspace)."""
        return self.m


def subspaces(xs: jax.Array, m: int) -> jax.Array:
    """``[..., dim]`` -> ``[..., m, dim//m]`` subspace view."""
    return xs.reshape(*xs.shape[:-1], m, xs.shape[-1] // m)


@partial(jax.jit, static_argnames=("m", "nbits", "iters"))
def train_pq(key: jax.Array, xs: jax.Array, m: int, nbits: int = 8,
             iters: int = 16) -> jax.Array:
    """K-means per subspace. ``xs [N, dim]`` -> codebooks ``[m, ksub, dsub]``.

    Each subspace trains independently (vmapped Lloyd's iterations over the
    same sample), mirroring Faiss ``ProductQuantizer::train``.
    """
    if xs.shape[-1] % m:
        raise ValueError(f"dim {xs.shape[-1]} not divisible by m={m}")
    sub = jnp.moveaxis(subspaces(xs.astype(jnp.float32), m), -2, 0)  # [m,N,ds]
    keys = jax.random.split(key, m)
    train = lambda k, x: quantizer.train_kmeans(k, x, 1 << nbits, iters=iters)
    return jax.vmap(train)(keys, sub)


def encode(codebooks: jax.Array, xs: jax.Array) -> jax.Array:
    """Nearest codeword per subspace. ``xs [B, dim]`` -> ``[B, m]`` uint8."""
    m, _, dsub = codebooks.shape
    sub = subspaces(xs.astype(jnp.float32), m)                    # [B, m, ds]
    d = (jnp.sum(sub * sub, axis=-1, keepdims=True)
         - 2.0 * jnp.einsum("bmd,mkd->bmk", sub, codebooks)
         + jnp.sum(codebooks * codebooks, axis=-1)[None])         # [B, m, K]
    return jnp.argmin(d, axis=-1).astype(jnp.uint8)


def decode(codebooks: jax.Array, codes: jax.Array) -> jax.Array:
    """Reconstruct. ``codes [B, m]`` uint8 -> ``[B, dim]`` f32."""
    m = codebooks.shape[0]
    sel = codebooks[jnp.arange(m)[None, :], codes.astype(jnp.int32)]
    return sel.reshape(codes.shape[0], -1)


def adc_tables(codebooks: jax.Array, queries: jax.Array,
               metric: str = "l2") -> jax.Array:
    """Per-query ADC lookup tables. ``queries [Q, dim]`` -> ``[Q, m, ksub]``.

    ``l2``: ``T[q, s, j] = ||q_s - codebook[s, j]||^2`` so a candidate's
    ADC distance is ``sum_s T[q, s, code_s]`` (the squared-L2 surrogate the
    rest of the search stack already ranks by). ``ip``: negated partial
    inner products, summing to ``-<q, decode(code)>``.

    Both the fused kernel and the XLA reference consume *this* table, so
    scoring parity only depends on matching the m-wise summation order —
    which both sides fix to ascending ``s``.
    """
    m = codebooks.shape[0]
    q = subspaces(queries.astype(jnp.float32), m)                 # [Q, m, ds]
    dot = jnp.einsum("qmd,mkd->qmk", q, codebooks)
    if metric == "ip":
        return -dot
    return (jnp.sum(q * q, axis=-1, keepdims=True) - 2.0 * dot
            + jnp.sum(codebooks * codebooks, axis=-1)[None])
