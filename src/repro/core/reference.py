"""Pure-python reference model of SIVF semantics.

Used as the oracle for unit and hypothesis property tests: a dict of live
vectors plus the same coarse assignment rule. Any observable behaviour of
the JAX index (search results, live counts, overwrite semantics) must match
this model exactly (up to distance ties).
"""
from __future__ import annotations

import numpy as np


class ReferenceIndex:
    def __init__(self, centroids: np.ndarray, metric: str = "l2"):
        self.centroids = np.asarray(centroids, np.float32)
        self.metric = metric
        self.store: dict[int, np.ndarray] = {}

    # -- routing (must match quantizer.assign / probe tie-breaking) --------
    def _dists(self, xs: np.ndarray, ys: np.ndarray) -> np.ndarray:
        if self.metric == "ip":
            return -(xs @ ys.T)
        aa = np.sum(xs * xs, axis=-1, keepdims=True)
        bb = np.sum(ys * ys, axis=-1, keepdims=True).T
        return aa - 2.0 * (xs @ ys.T) + bb

    def assign(self, xs: np.ndarray) -> np.ndarray:
        return np.argmin(self._dists(np.asarray(xs, np.float32),
                                     self.centroids), axis=1)

    def probe(self, qs: np.ndarray, nprobe: int) -> np.ndarray:
        d = self._dists(np.asarray(qs, np.float32), self.centroids)
        return np.argsort(d, axis=1, kind="stable")[:, :nprobe]

    # -- mutation -----------------------------------------------------------
    def insert(self, vecs: np.ndarray, ids) -> None:
        for v, i in zip(np.asarray(vecs, np.float32), ids):
            i = int(i)
            if i < 0:
                continue
            self.store[i] = v.copy()     # delete-then-insert == overwrite

    def delete(self, ids) -> None:
        for i in ids:
            self.store.pop(int(i), None)  # idempotent

    @property
    def n_live(self) -> int:
        return len(self.store)

    # -- search -------------------------------------------------------------
    def search(self, qs: np.ndarray, k: int, nprobe: int
               ) -> tuple[np.ndarray, np.ndarray]:
        """Brute force over live vectors restricted to probed lists."""
        qs = np.asarray(qs, np.float32)
        nq = qs.shape[0]
        out_d = np.full((nq, k), np.inf, np.float32)
        out_l = np.full((nq, k), -1, np.int64)
        if not self.store:
            return out_d, out_l
        ids = np.fromiter(self.store.keys(), np.int64)
        vecs = np.stack([self.store[int(i)] for i in ids])
        lists = self.assign(vecs)
        probes = self.probe(qs, nprobe)
        d_all = self._dists(qs, vecs)                       # [Q, N]
        for q in range(nq):
            mask = np.isin(lists, probes[q])
            if not mask.any():
                continue
            cand = np.nonzero(mask)[0]
            dq = d_all[q, cand]
            order = np.argsort(dq, kind="stable")[:k]
            out_d[q, :len(order)] = dq[order]
            out_l[q, :len(order)] = ids[cand[order]]
        return out_d, out_l
