"""Coarse quantizer: k-means over the training sample (Faiss-IVF style).

The quantizer is *static* in SIVF (as in the paper: lists are fixed after
training; only their contents stream). ``assign`` routes vectors to lists,
``probe`` returns the top-nprobe lists for queries.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.utils import l2_sq


@partial(jax.jit, static_argnames=("n_lists", "iters"))
def train_kmeans(key: jax.Array, xs: jax.Array, n_lists: int, iters: int = 10
                 ) -> jax.Array:
    """Lloyd's k-means. xs [N, D] -> centroids [n_lists, D]."""
    n = xs.shape[0]
    idx = jax.random.choice(key, n, (n_lists,), replace=n < n_lists)
    cents = xs[idx]

    def step(cents, _):
        assign = jnp.argmin(l2_sq(xs, cents), axis=1)              # [N]
        onehot = jax.nn.one_hot(assign, n_lists, dtype=xs.dtype)   # [N, L]
        sums = onehot.T @ xs                                        # [L, D]
        counts = jnp.sum(onehot, axis=0)[:, None]                   # [L, 1]
        new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1), cents)
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    return cents


def assign(centroids: jax.Array, xs: jax.Array, metric: str = "l2") -> jax.Array:
    """Route vectors to their IVF list. xs [B, D] -> [B] int32."""
    if metric == "ip":
        scores = xs @ centroids.T
        return jnp.argmax(scores, axis=1).astype(jnp.int32)
    return jnp.argmin(l2_sq(xs, centroids), axis=1).astype(jnp.int32)


def probe(centroids: jax.Array, qs: jax.Array, nprobe: int, metric: str = "l2"
          ) -> jax.Array:
    """Top-nprobe coarse lists per query. qs [Q, D] -> [Q, nprobe] int32."""
    if metric == "ip":
        scores = qs @ centroids.T
    else:
        scores = -l2_sq(qs, centroids)
    _, lists = jax.lax.top_k(scores, nprobe)
    return lists.astype(jnp.int32)
