"""SIVF index operations: batched insert / delete / search (paper §3).

CUDA -> TPU adaptation (DESIGN.md §2): the paper's per-thread lock-free
protocols (Algorithms 1, 2, 4) become *bulk-synchronous batched plans*:

  insert  — sort-by-list + segmented prefix sums produce a conflict-free
            (slab, slot) coordinate for every element of the batch, then
            scatters apply payloads, bitmap bits, ATT entries and chain
            links in one shot. O(B log B) per batch of B, independent of
            index size N (the paper's O(1)-per-element claim). The batch is
            *all-or-nothing*: overwrite-deletes are staged and commit only
            after the allocation plan succeeds, so a POOL_EXHAUSTED /
            CHAIN_OVERFLOW batch leaves the index byte-identical (error
            bits aside) — previously-live ids keep their old payloads.
  delete  — ATT lookup + vectorized bitmap clear (the paper's atomicAnd
            linearization point becomes the functional state swap), then a
            bounded sequential pass reclaims slabs that dropped to zero
            occupancy (unlink + push to free stack; Alg. 4 lines 15-19).
  search  — coarse probe + slab-chain traversal + fused validity-masked
            distance scan + streaming top-k (Alg. 3). Two table sources
            (the paper-faithful pointer walk over ``nxt`` and the
            beyond-paper dense list->slab gather) feed one scan->top-k
            dispatch; no backend materializes the [Q, T*C] candidates.
            With ``cfg.pq`` set, every backend scores PQ-compressed slabs
            by ADC instead (``scan_slabs_topk_pq`` /
            kernels/sivf_scan/pq_fused.py): one per-query-batch table of
            per-subspace partial distances feeds table-lookup sums over
            the uint8 code plane, bit-exact between the XLA reference and
            the fused Pallas kernel.

All ops are jit-compiled with state donation: the returned state reuses the
input buffers (XLA in-place), mirroring "in-place mutation in VRAM".

This module is the *functional* surface (explicit cfg/state threading). The
preferred client entry point is the stateful session handle
``sivf.Index`` (``core/api.py``), which owns the state, buckets ragged
batches, turns the sticky ``state.error`` bits into per-batch
``MutationReport``s (eager, or deferred futures resolved in one packed
transfer at ``Index.flush``), persists/reshards the state across device
topologies, and delegates to the same kernels here. Design notes with the
memory-layout and commit-pipeline diagrams: docs/architecture.md.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core import filters as flt
from repro.core import pq as pqmod
from repro.core import quantizer
from repro.core.state import (
    ERR_CHAIN_OVERFLOW,
    ERR_ID_RANGE,
    ERR_POOL_EXHAUSTED,
    SIVFConfig,
    SlabPoolState,
)
from repro.utils import ceil_div, exclusive_cumsum

_I32_MAX = jnp.iinfo(jnp.int32).max


# ---------------------------------------------------------------------------
# Insert (paper Alg. 1 Insert / Alg. 2)
# ---------------------------------------------------------------------------

def _dedupe_keep_last(ext_ids: jax.Array, valid: jax.Array) -> jax.Array:
    """Within-batch duplicate ids: keep only the last occurrence.

    Implements the paper's delete-then-insert overwrite semantics at batch
    granularity (the batch is one linearization epoch; last write wins).
    """
    b = ext_ids.shape[0]
    key = jnp.where(valid, ext_ids, _I32_MAX)
    order = jnp.argsort(key, stable=True)        # same ids: ascending position
    ks = key[order]
    keep_sorted = jnp.concatenate(
        [ks[:-1] != ks[1:], jnp.array([True])])   # last of each run
    keep = jnp.zeros((b,), bool).at[order].set(keep_sorted)
    return valid & keep


def _insert_impl(cfg: SIVFConfig, state: SlabPoolState, vecs: jax.Array,
                 ext_ids: jax.Array, lists: jax.Array,
                 codes: jax.Array | None = None,
                 attrs: jax.Array | None = None,
                 want_plan: bool = False):
    """All-or-nothing batched insert.

    With ``want_plan=True`` (the tiered host store, ``core/tiered.py``)
    the return value is ``(state, plan)`` where ``plan`` maps every *input*
    row to the coordinates the commit gave it: ``plan["slab"]`` /
    ``plan["slot"]`` ``[B]`` int32 (-1 for padding rows, out-of-range ids,
    rows superseded by a later in-batch duplicate, and — because the batch
    is atomic — *every* row of an aborted batch), plus ``plan["codes"]``
    ``[B, code_m]`` uint8, the device-encoded PQ codewords in input order
    (zero-width without PQ). The host store replays exactly the payload
    writes the device committed, so the two tiers stay bit-identical
    without ever transferring the payload planes themselves.

    With ``cfg.pq`` set, ``codes`` ``[B, m]`` may carry pre-encoded
    codewords (elastic resharding re-routes *stored* codes, so the code
    planes survive byte-for-byte by construction instead of round-tripping
    through decode/encode); omitted, the batch encodes on ingest.

    With ``cfg.attributes`` set, ``attrs`` ``[B, n_attrs]`` int32 stamps
    each row's filter attributes (core/filters.py); omitted at this
    functional layer the batch stamps zeros — the session handle
    (``Index.add``) is the strict surface that *requires* attributes, so
    tenant rows can never default their way out of a mandatory filter.

    Overwrites keep the paper's delete-then-insert linearization, but the
    whole batch is *staged*: the overwrite-deletes run on a functional copy
    (``staged``) of the pre-batch state while the pristine input value stays
    live, and the allocation plan — computed exactly, on the post-delete
    pool — picks which value survives the single ``lax.cond`` commit point.
    A batch that hits ``POOL_EXHAUSTED`` / ``CHAIN_OVERFLOW`` therefore
    returns the input state untouched except for its error bits: every
    previously-live id stays searchable with its old payload. The payload
    planes (``data`` / ``ids`` / ``norms``) pass through the staged delete
    unmodified, so keeping both values alive until the commit point costs
    one transient copy of the small metadata arrays only, never of the
    vector pool itself.
    """
    b = vecs.shape[0]
    c = cfg.capacity
    ns, nl, nm = cfg.n_slabs, cfg.n_lists, cfg.n_max

    # -- sanitize ids ------------------------------------------------------
    in_range = (ext_ids >= 0) & (ext_ids < nm)
    err_range = jnp.any((~in_range) & (ext_ids != -1))
    valid0 = in_range
    valid0 = _dedupe_keep_last(ext_ids, valid0)

    # -- stage delete-then-insert for already-present ids (§3 Data Model) --
    eid0 = jnp.where(valid0, ext_ids, 0)
    present = valid0 & (state.att_slab[eid0] >= 0)
    staged = _delete_impl(cfg, state, jnp.where(present, ext_ids, -1))

    # -- sort batch by target list; rank within list -----------------------
    lists_key = jnp.where(valid0, lists.astype(jnp.int32), nl)
    order = jnp.argsort(lists_key, stable=True)
    sl = lists_key[order]                                     # [B] sorted
    sv = vecs[order]
    sids = ext_ids[order]
    svalid = sl < nl
    first_ix = jnp.searchsorted(sl, sl, side="left")
    rank = (jnp.arange(b) - first_ix).astype(jnp.int32)
    counts = jnp.bincount(lists_key, length=nl + 1)[:nl].astype(jnp.int32)

    # -- per-list capacity plan (segmented prefix sums) --------------------
    # Exact: planned on the staged post-delete pool, so slabs drained by
    # this batch's own overwrites are already back on the free stack (a
    # full-pool overwrite of a full index still commits).
    heads = staged.heads
    cur_l = jnp.where(heads >= 0, staged.cursor[jnp.clip(heads, 0)], c)
    space_l = (c - cur_l).astype(jnp.int32)                   # head free slots
    overflow_l = jnp.maximum(counts - space_l, 0)
    n_new_l = ceil_div(overflow_l, c).astype(jnp.int32)       # new slabs/list
    offs_l = exclusive_cumsum(n_new_l).astype(jnp.int32)
    total_new = jnp.sum(n_new_l)

    pool_ok = total_new <= staged.free_top                    # fail-fast (§3.2)
    chain_ok = jnp.all(staged.table_len + n_new_l <= cfg.max_chain)
    ok = pool_ok & chain_ok

    # -- per-item coordinates ----------------------------------------------
    sl_c = jnp.clip(sl, 0, nl - 1)
    h_item = jnp.where(svalid, heads[sl_c], -1)
    space_item = space_l[sl_c]
    in_head = svalid & (rank < space_item) & (h_item >= 0)
    over = rank - space_item
    new_ord = jnp.where(svalid & ~in_head, over // c, 0)
    new_slot = jnp.where(svalid & ~in_head, over % c, 0)
    alloc_idx = offs_l[sl_c] + new_ord                        # global new-slab ordinal
    stack_pos = staged.free_top - 1 - alloc_idx
    new_slab_for_item = staged.free_stack[jnp.clip(stack_pos, 0, ns - 1)]
    item_slab = jnp.where(in_head, h_item, new_slab_for_item)
    item_slot = jnp.where(in_head, c - space_item + rank, new_slot)

    # -- per-new-slab metadata (g = global allocation ordinal) -------------
    g = jnp.arange(b, dtype=jnp.int32)
    gmask = g < total_new
    slab_of_g = staged.free_stack[jnp.clip(staged.free_top - 1 - g, 0, ns - 1)]
    slab_prev_g = staged.free_stack[jnp.clip(staged.free_top - g, 0, ns - 1)]
    slab_next_g = staged.free_stack[jnp.clip(staged.free_top - 2 - g, 0,
                                             ns - 1)]
    # ordinal/list of each new slab, scattered from the slot-0 item
    first_of_slab = svalid & (~in_head) & (new_slot == 0)
    g_tgt = jnp.where(first_of_slab, alloc_idx, b)
    list_of_g = jnp.full((b,), 0, jnp.int32).at[g_tgt].set(sl, mode="drop")
    ord_of_g = jnp.zeros((b,), jnp.int32).at[g_tgt].set(new_ord, mode="drop")
    # chain links: new slab j links next -> (j==0 ? old head : slab j-1);
    # the *last* new slab of each list becomes the new head (Alg. 2).
    nxt_of_g = jnp.where(ord_of_g == 0, heads[jnp.clip(list_of_g, 0, nl - 1)],
                         slab_prev_g)
    is_last_of_list = ord_of_g == (n_new_l[jnp.clip(list_of_g, 0, nl - 1)] - 1)
    prv_of_g = jnp.where(is_last_of_list, -1, slab_next_g)

    # PQ ingest path: encode once per batch (the codebooks are identical in
    # the staged and pristine values; an aborted batch discards the codes
    # with the rest of the staged scatter, so atomicity is untouched)
    if cfg.pq is not None:
        if codes is None:
            new_codes = pqmod.encode(state.pq_codebooks,
                                     sv.astype(jnp.float32))
        else:
            new_codes = codes[order].astype(jnp.uint8)   # same batch sort
    # attribute stamps ride the same sort and the same staged commit
    if cfg.n_attrs:
        if attrs is None:
            sattrs = jnp.zeros((b, cfg.n_attrs), jnp.int32)
        else:
            sattrs = attrs[order].astype(jnp.int32)

    def apply(operand) -> SlabPoolState:
        staged, _ = operand                          # commit the staged batch
        drop_g = jnp.where(gmask, slab_of_g, ns)
        nxt = staged.nxt.at[drop_g].set(nxt_of_g, mode="drop")
        prv = staged.prv.at[drop_g].set(prv_of_g, mode="drop")
        owner = staged.owner.at[drop_g].set(list_of_g, mode="drop")
        cursor = staged.cursor.at[drop_g].set(0, mode="drop")
        live = staged.live.at[drop_g].set(0, mode="drop")
        bitmap = staged.bitmap.at[drop_g].set(jnp.uint32(0), mode="drop")
        # per-list head relink
        has_new = n_new_l > 0
        first_new_l = slab_of_g[jnp.clip(offs_l, 0, b - 1)]
        last_new_l = slab_of_g[jnp.clip(offs_l + n_new_l - 1, 0, b - 1)]
        old_head_tgt = jnp.where(has_new & (heads >= 0), heads, ns)
        prv = prv.at[old_head_tgt].set(first_new_l, mode="drop")
        new_heads = jnp.where(has_new, last_new_l, heads)
        # dense chain tables (beyond-paper; maintained incrementally)
        tl_g = staged.table_len[jnp.clip(list_of_g, 0, nl - 1)]
        tab_l = jnp.where(gmask, list_of_g, nl)
        tables = staged.tables.at[tab_l, jnp.clip(tl_g + ord_of_g, 0,
                                                  cfg.max_chain - 1)
                                  ].set(slab_of_g, mode="drop")
        table_pos = staged.table_pos.at[drop_g].set(tl_g + ord_of_g,
                                                    mode="drop")
        table_len = staged.table_len + n_new_l
        # payload writes + publication (bitmap bits are distinct per word, so
        # a scatter-add is an OR; see DESIGN.md §2 on the fence analogue)
        drop_i = jnp.where(svalid, item_slab, ns)
        data = staged.data.at[drop_i, item_slot].set(
            sv[:, :cfg.payload_dim].astype(cfg.dtype), mode="drop")
        if cfg.pq is not None:
            codes = staged.codes.at[drop_i, item_slot].set(
                new_codes, mode="drop")
        else:
            codes = staged.codes
        if cfg.n_attrs:
            attrs_plane = staged.attrs.at[drop_i, item_slot].set(
                sattrs, mode="drop")
        else:
            attrs_plane = staged.attrs
        ids = staged.ids.at[drop_i, item_slot].set(sids, mode="drop")
        norms = staged.norms.at[drop_i, item_slot].set(
            jnp.sum(sv.astype(jnp.float32) ** 2, axis=-1), mode="drop")
        word, bit = bm.slot_word_bit(item_slot)
        bitmap = bitmap.at[drop_i, word].add(bit, mode="drop")
        cursor = cursor.at[drop_i].add(1, mode="drop")
        live = live.at[drop_i].add(1, mode="drop")
        att_tgt = jnp.where(svalid, sids, nm)
        att_slab = staged.att_slab.at[att_tgt].set(item_slab, mode="drop")
        att_slot = staged.att_slot.at[att_tgt].set(item_slot, mode="drop")
        return SlabPoolState(
            data=data, ids=ids, norms=norms, bitmap=bitmap, nxt=nxt, prv=prv,
            owner=owner, cursor=cursor, live=live, heads=new_heads,
            free_stack=staged.free_stack, free_top=staged.free_top - total_new,
            att_slab=att_slab, att_slot=att_slot,
            n_live=staged.n_live + jnp.sum(svalid),
            error=staged.error | jnp.where(err_range, ERR_ID_RANGE, 0),
            centroids=staged.centroids, tables=tables, table_len=table_len,
            table_pos=table_pos, codes=codes,
            pq_codebooks=staged.pq_codebooks, attrs=attrs_plane)

    def fail(operand) -> SlabPoolState:
        _, pristine = operand                 # drop the staged deletes whole
        err = jnp.where(~pool_ok, ERR_POOL_EXHAUSTED, 0) \
            | jnp.where(~chain_ok, ERR_CHAIN_OVERFLOW, 0) \
            | jnp.where(err_range, ERR_ID_RANGE, 0)
        return SlabPoolState(
            **{f.name: getattr(pristine, f.name)
               for f in pristine.__dataclass_fields__.values()
               if f.name != "error"},
            error=pristine.error | err)

    out = jax.lax.cond(ok, apply, fail, (staged, state))
    if not want_plan:
        return out
    # commit plan in *input* order: scatter the batch-sorted coordinates
    # back through `order`; -1 marks rows the commit never wrote (padding /
    # out-of-range / superseded duplicates / the whole batch on abort)
    inv_slab = jnp.full((b,), -1, jnp.int32).at[order].set(
        jnp.where(svalid, item_slab, -1))
    inv_slot = jnp.zeros((b,), jnp.int32).at[order].set(item_slot)
    plan_slab = jnp.where(ok, inv_slab, -1)
    if cfg.pq is not None:
        plan_codes = jnp.zeros((b, cfg.code_m), jnp.uint8
                               ).at[order].set(new_codes)
    else:
        plan_codes = jnp.zeros((b, 0), jnp.uint8)
    return out, {"slab": plan_slab, "slot": inv_slot, "codes": plan_codes}


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1,))
def insert(cfg: SIVFConfig, state: SlabPoolState, vecs: jax.Array,
           ext_ids: jax.Array, lists: jax.Array | None = None,
           codes: jax.Array | None = None,
           attrs: jax.Array | None = None) -> SlabPoolState:
    """Batched ingest. ``vecs`` [B, D], ``ext_ids`` [B] (-1 rows = padding).

    ``lists`` may pre-route vectors (distributed ingestion reuses the
    router's assignment); otherwise the coarse quantizer assigns. With
    ``cfg.pq``, ``codes`` may carry pre-encoded codewords (resharding);
    otherwise the batch encodes on ingest. With ``cfg.attributes``,
    ``attrs`` [B, n_attrs] stamps filter attributes (zeros when omitted).
    """
    if lists is None:
        lists = quantizer.assign(state.centroids, vecs.astype(cfg.dtype),
                                 cfg.metric)
    return _insert_impl(cfg, state, vecs, ext_ids, lists, codes, attrs)


# ---------------------------------------------------------------------------
# Delete (paper Alg. 1 Delete / Alg. 4)
# ---------------------------------------------------------------------------

def _delete_impl(cfg: SIVFConfig, state: SlabPoolState, ext_ids: jax.Array
                 ) -> SlabPoolState:
    b = ext_ids.shape[0]
    ns, nl, nm = cfg.n_slabs, cfg.n_lists, cfg.n_max

    valid = (ext_ids >= 0) & (ext_ids < nm)
    # dedupe (paper: repeated deletes are idempotent, Thm 3.3)
    key = jnp.where(valid, ext_ids, _I32_MAX)
    order = jnp.argsort(key, stable=True)
    ke = key[order]
    first = jnp.concatenate([jnp.array([True]), ke[1:] != ke[:-1]])
    act0 = first & (ke != _I32_MAX)
    ke_c = jnp.where(act0, ke, 0)
    s = state.att_slab[ke_c]                                  # [B]
    o = state.att_slot[ke_c]
    act = act0 & (s >= 0)                                     # live entries only

    # -- logical deletion: clear validity bits (linearization point) -------
    word, bit = bm.slot_word_bit(o)
    drop_s = jnp.where(act, s, ns)
    clear = jnp.zeros_like(state.bitmap).at[drop_s, word].add(bit, mode="drop")
    bitmap = state.bitmap & ~clear
    live = state.live.at[drop_s].add(-1, mode="drop")
    att_slab = state.att_slab.at[jnp.where(act, ke_c, nm)].set(-1, mode="drop")
    n_live = state.n_live - jnp.sum(act)

    # -- slab-wise reclamation (Alg. 4 lines 15-19) -------------------------
    # Bounded sequential pass: only slabs that dropped to zero occupancy are
    # unlinked (doubly-linked chains; DESIGN.md §2) and pushed to the stack.
    def body(i, carry):
        (nxt, prv, owner, heads, free_stack, free_top, cursor, live2,
         tables, table_len, table_pos) = carry
        si = jnp.clip(s[i], 0)
        do = act[i] & (live2[si] == 0) & (owner[si] >= 0)
        li = jnp.clip(owner[si], 0)
        p, n = prv[si], nxt[si]
        # unlink
        heads = heads.at[jnp.where(do & (p < 0), li, nl)].set(n, mode="drop")
        nxt = nxt.at[jnp.where(do & (p >= 0), jnp.clip(p, 0), ns)].set(
            n, mode="drop")
        prv = prv.at[jnp.where(do & (n >= 0), jnp.clip(n, 0), ns)].set(
            p, mode="drop")
        # dense-table removal: swap-with-last
        pos = jnp.clip(table_pos[si], 0)
        last = jnp.clip(table_len[li] - 1, 0)
        moved = tables[li, last]
        li_d = jnp.where(do, li, nl)
        tables = tables.at[li_d, pos].set(moved, mode="drop")
        tables = tables.at[li_d, last].set(-1, mode="drop")
        table_pos = table_pos.at[
            jnp.where(do & (moved >= 0), jnp.clip(moved, 0), ns)].set(
            pos, mode="drop")
        table_pos = table_pos.at[jnp.where(do, si, ns)].set(-1, mode="drop")
        table_len = table_len.at[li_d].add(-1, mode="drop")
        # recycle (instant reuse; paper §3.1 "immediate reclamation")
        free_stack = free_stack.at[jnp.where(do, free_top, ns)].set(
            si, mode="drop")
        free_top = free_top + do.astype(jnp.int32)
        owner = owner.at[jnp.where(do, si, ns)].set(-1, mode="drop")
        cursor = cursor.at[jnp.where(do, si, ns)].set(0, mode="drop")
        nxt = nxt.at[jnp.where(do, si, ns)].set(-1, mode="drop")
        prv = prv.at[jnp.where(do, si, ns)].set(-1, mode="drop")
        return (nxt, prv, owner, heads, free_stack, free_top, cursor, live2,
                tables, table_len, table_pos)

    carry = (state.nxt, state.prv, state.owner, state.heads,
             state.free_stack, state.free_top, state.cursor, live,
             state.tables, state.table_len, state.table_pos)
    (nxt, prv, owner, heads, free_stack, free_top, cursor, live, tables,
     table_len, table_pos) = jax.lax.fori_loop(0, b, body, carry)

    return SlabPoolState(
        data=state.data, ids=state.ids, norms=state.norms, bitmap=bitmap,
        nxt=nxt, prv=prv, owner=owner, cursor=cursor, live=live, heads=heads,
        free_stack=free_stack, free_top=free_top, att_slab=att_slab,
        att_slot=state.att_slot, n_live=n_live, error=state.error,
        centroids=state.centroids, tables=tables, table_len=table_len,
        table_pos=table_pos, codes=state.codes,
        pq_codebooks=state.pq_codebooks, attrs=state.attrs)


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1,))
def delete(cfg: SIVFConfig, state: SlabPoolState, ext_ids: jax.Array
           ) -> SlabPoolState:
    """Batched lazy eviction. ``ext_ids`` [B]; -1 entries are no-ops."""
    return _delete_impl(cfg, state, ext_ids)


# ---------------------------------------------------------------------------
# Search (paper Alg. 3)
# ---------------------------------------------------------------------------

def walk_chains(cfg: SIVFConfig, state: SlabPoolState, lists: jax.Array
                ) -> jax.Array:
    """Paper-faithful pointer walk: lists [Q, P] -> slab table [Q, P*T].

    Sequential gathers over ``nxt`` with the Alg. 3 traversal bound and
    self-loop guard. -1 pads exhausted chains.
    """
    s = jnp.where(lists >= 0, state.heads[jnp.clip(lists, 0)], -1)

    def step(s, _):
        n = jnp.where(s >= 0, state.nxt[jnp.clip(s, 0)], -1)
        n = jnp.where(n == s, -1, n)        # self-loop guard
        return n, s

    _, seq = jax.lax.scan(step, s, None, length=cfg.max_chain)  # [T, Q, P]
    q = lists.shape[0]
    return jnp.moveaxis(seq, 0, -1).reshape(q, -1)


def gather_tables(cfg: SIVFConfig, state: SlabPoolState, lists: jax.Array
                  ) -> jax.Array:
    """Beyond-paper dense-table path: one gather, no pointer chasing."""
    q = lists.shape[0]
    t = jnp.where(lists[..., None] >= 0,
                  state.tables[jnp.clip(lists, 0)], -1)       # [Q, P, T]
    return t.reshape(q, -1)


def _filter_mask(cfg: SIVFConfig, state: SlabPoolState, sc: jax.Array,
                 fstruct: tuple | None, fconsts: jax.Array | None
                 ) -> jax.Array | None:
    """Per-slot predicate mask for one gathered slab column (XLA paths).

    ``sc`` [Q] clipped slab ids -> bool [Q, C] (or None when unfiltered).
    Same ``filters.eval_structure`` recursion the Pallas kernels run; the
    structure is static (jit key), the constants are traced.
    """
    if fstruct is None:
        return None
    at = state.attrs[sc]                                      # [Q, C, A]
    return flt.eval_structure(
        fstruct, lambda j: at[..., j], lambda i: fconsts[i])


def scan_slabs_topk(cfg: SIVFConfig, state: SlabPoolState, queries: jax.Array,
                    table: jax.Array, k: int,
                    fstruct: tuple | None = None,
                    fconsts: jax.Array | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Validity-masked distance scan + streaming top-k (XLA path).

    Memory-bounded: scans the slab table column-by-column keeping a running
    [Q, k] result, the jnp analogue of Alg. 3's per-lane register top-k.
    The fused Pallas kernel (kernels/sivf_scan/fused.py) is the TPU
    analogue and matches this reference bit-for-bit, ties included.
    ``fstruct``/``fconsts`` (core/filters.py) AND a per-slot predicate mask
    into the validity mask *before* the fold — filtered-out candidates
    score +inf / label -1, exactly like deleted slots, so they can never
    displace passing rows from the top-k.
    """
    qn = queries.shape[0]
    qf = queries.astype(jnp.float32)
    qq = jnp.sum(qf * qf, axis=-1)                            # [Q]

    def step(carry, slab_col):                                # slab_col [Q]
        bd, bl = carry
        sc = jnp.clip(slab_col, 0)
        x = state.data[sc].astype(jnp.float32)                # [Q, C, D]
        vb = bm.unpack_batch(state.bitmap[sc], cfg.capacity)  # [Q, C]
        ok = vb & (slab_col >= 0)[:, None]
        pm = _filter_mask(cfg, state, sc, fstruct, fconsts)
        if pm is not None:
            ok = ok & pm
        dot = jnp.einsum("qd,qcd->qc", qf, x)
        if cfg.metric == "l2":
            d = qq[:, None] - 2.0 * dot + state.norms[sc]
        else:
            d = -dot
        d = jnp.where(ok, d, jnp.inf)
        lab = jnp.where(ok, state.ids[sc], -1)
        alld = jnp.concatenate([bd, d], axis=1)               # [Q, k+C]
        alll = jnp.concatenate([bl, lab], axis=1)
        nd, idx = jax.lax.top_k(-alld, k)
        nl = jnp.take_along_axis(alll, idx, axis=1)
        return (-nd, nl), None

    init = (jnp.full((qn, k), jnp.inf, jnp.float32),
            jnp.full((qn, k), -1, jnp.int32))
    (d, lab), _ = jax.lax.scan(step, init, table.T)
    return d, lab


def scan_slabs_topk_pq(cfg: SIVFConfig, state: SlabPoolState,
                       queries: jax.Array, table: jax.Array, k: int,
                       adc: jax.Array | None = None,
                       fstruct: tuple | None = None,
                       fconsts: jax.Array | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """ADC scan + streaming top-k over PQ-compressed slabs (XLA path).

    Mirrors :func:`scan_slabs_topk` column-by-column, but scores candidates
    by summing per-subspace ADC table lookups instead of touching fp32
    payloads — only the uint8 code plane is gathered per slab. The ``m``
    partial distances accumulate in ascending-subspace order; the fused
    Pallas kernel (kernels/sivf_scan/pq_fused.py) uses the same summation
    order and — fed the *same materialized* ``adc`` array, as
    ``_scan_dispatch`` does (the table is built once per query batch and
    shared across backends; compiler fusion of the table build itself may
    differ at the ULP level otherwise) — matches this reference
    bit-for-bit, ties included.
    """
    qn = queries.shape[0]
    m = cfg.pq.m
    if adc is None:
        adc = pqmod.adc_tables(state.pq_codebooks,
                               queries.astype(jnp.float32),
                               cfg.metric)                    # [Q, m, K]

    def step(carry, slab_col):                                # slab_col [Q]
        bd, bl = carry
        sc = jnp.clip(slab_col, 0)
        codes = state.codes[sc]                               # [Q, C, m] u8
        # per-subspace table gathers, accumulated left-to-right: the peak
        # live set stays O(Q*C) per column (vs O(Q*C*m) for a fused
        # [..., m] gather) and the fixed add order is what the Pallas
        # kernel reproduces for bit-exact parity
        d = None
        for s in range(m):
            t_s = jnp.take_along_axis(
                adc[:, s, :], codes[..., s].astype(jnp.int32), axis=1)
            d = t_s if d is None else d + t_s                 # [Q, C]
        vb = bm.unpack_batch(state.bitmap[sc], cfg.capacity)  # [Q, C]
        ok = vb & (slab_col >= 0)[:, None]
        pm = _filter_mask(cfg, state, sc, fstruct, fconsts)
        if pm is not None:
            ok = ok & pm
        d = jnp.where(ok, d, jnp.inf)
        lab = jnp.where(ok, state.ids[sc], -1)
        alld = jnp.concatenate([bd, d], axis=1)               # [Q, k+C]
        alll = jnp.concatenate([bl, lab], axis=1)
        nd, idx = jax.lax.top_k(-alld, k)
        nl = jnp.take_along_axis(alll, idx, axis=1)
        return (-nd, nl), None

    init = (jnp.full((qn, k), jnp.inf, jnp.float32),
            jnp.full((qn, k), -1, jnp.int32))
    (d, lab), _ = jax.lax.scan(step, init, table.T)
    return d, lab


SEARCH_IMPLS = ("xla", "pallas", "pallas_interpret")


def _scan_dispatch(cfg: SIVFConfig, state: SlabPoolState, queries: jax.Array,
                   table: jax.Array, k: int, impl: str, block_q: int,
                   fstruct: tuple | None = None,
                   fconsts: jax.Array | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Route a gathered slab table through one scan->top-k backend.

    Every backend streams: none materializes the [Q, T*C] candidate matrix.
      "xla"              — jnp column scan (CPU, dry-run, shard_map bodies);
      "pallas"           — the fused TPU kernel (kernels/sivf_scan/fused.py);
      "pallas_interpret" — same kernel, Pallas interpreter (CPU emulation).

    With ``cfg.pq`` set every backend scores compressed slabs by ADC
    (``scan_slabs_topk_pq`` / kernels/sivf_scan/pq_fused.py): the uint8
    code plane replaces the fp32 payload DMA and distances are table-lookup
    sums against per-query ADC tables held in VMEM.

    ``fstruct``/``fconsts`` (a compiled predicate, core/filters.py) thread
    the same per-slot mask into every backend: the XLA references AND it
    into their validity mask, the Pallas kernels read the constants from a
    second scalar-prefetch operand in SMEM and mask before the top-k fold.
    """
    if fstruct is not None and cfg.n_attrs == 0:
        raise ValueError("filtered search needs SIVFConfig(attributes=...)")
    if cfg.pq is not None and impl in SEARCH_IMPLS:
        # one ADC table build serves whichever backend scores with it
        adc = pqmod.adc_tables(state.pq_codebooks,
                               queries.astype(jnp.float32), cfg.metric)
        if impl == "xla":
            return scan_slabs_topk_pq(cfg, state, queries, table, k, adc=adc,
                                      fstruct=fstruct, fconsts=fconsts)
        from repro.kernels.sivf_scan.pq_fused import (
            sivf_pq_fused_search_pallas,
        )
        return sivf_pq_fused_search_pallas(
            adc, table, state.codes, state.ids, state.bitmap, k,
            block_q=block_q, interpret=impl == "pallas_interpret",
            attrs=state.attrs if fstruct is not None else None,
            fstruct=fstruct, fconsts=fconsts)
    if impl == "xla":
        return scan_slabs_topk(cfg, state, queries, table, k,
                               fstruct=fstruct, fconsts=fconsts)
    if impl in ("pallas", "pallas_interpret"):
        if fstruct is not None:
            from repro.kernels.sivf_scan.fused import sivf_fused_search_pallas
            return sivf_fused_search_pallas(
                queries.astype(jnp.float32), table, state.data, state.ids,
                state.norms, state.bitmap, k, metric=cfg.metric,
                block_q=block_q, interpret=impl == "pallas_interpret",
                attrs=state.attrs, fstruct=fstruct, fconsts=fconsts)
        from repro.kernels.sivf_scan import ops as scan_ops
        return scan_ops.sivf_fused_search(
            queries.astype(jnp.float32), table, state.data, state.ids,
            state.norms, state.bitmap, k, metric=cfg.metric,
            block_q=block_q, interpret=impl == "pallas_interpret")
    raise ValueError(f"unknown impl {impl!r}; expected one of {SEARCH_IMPLS}")


def _search_impl(cfg: SIVFConfig, state: SlabPoolState, queries: jax.Array,
                 k: int, nprobe: int, use_tables: bool | None, impl: str,
                 block_q: int, fstruct: tuple | None = None,
                 fconsts: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Un-jitted search body, shared by `search` and distributed shards."""
    ut = cfg.track_tables if use_tables is None else use_tables
    lists = quantizer.probe(state.centroids, queries.astype(cfg.dtype),
                            nprobe, cfg.metric)
    table = (gather_tables if ut else walk_chains)(cfg, state, lists)
    return _scan_dispatch(cfg, state, queries, table, k, impl, block_q,
                          fstruct=fstruct, fconsts=fconsts)


@partial(jax.jit, static_argnames=("cfg", "k", "nprobe", "use_tables",
                                   "impl", "block_q", "fstruct"))
def search(cfg: SIVFConfig, state: SlabPoolState, queries: jax.Array,
           k: int, nprobe: int, use_tables: bool | None = None,
           impl: str = "xla", block_q: int = 8,
           fstruct: tuple | None = None,
           fconsts: jax.Array | None = None
           ) -> tuple[jax.Array, jax.Array]:
    """Top-k search. queries [Q, D] -> (distances [Q, k], labels [Q, k]).

    ``use_tables`` selects the beyond-paper dense-table slab lookup (default
    from config); both the dense-table and pointer-walk tables feed the same
    fused scan->top-k dispatch. ``impl``: "xla" (jnp math, used for CPU +
    dry-run), "pallas" (fused TPU kernel), or "pallas_interpret" (the fused
    kernel under the Pallas interpreter). ``block_q`` is the fused kernel's
    query-tile height.

    ``fstruct``/``fconsts`` come from ``filters.compile_filter``: the
    structure is a *static* argument (one executable per filter shape), the
    constants are traced (changing ``Eq("tenant", 3)`` to ``..., 7`` hits
    the same executable).
    """
    return _search_impl(cfg, state, queries, k, nprobe, use_tables, impl,
                        block_q, fstruct=fstruct, fconsts=fconsts)


# ---------------------------------------------------------------------------
# Introspection
# ---------------------------------------------------------------------------

def _memory_stats(cfg: SIVFConfig, n_shards: int = 1) -> dict:
    """Pool memory footprint, aggregated across shards like ``total_live``.

    Delegates the byte math to ``state.memory_report`` (one source of
    truth) and scales the per-pool planes by the shard count;
    ``compression_ratio`` (shard-count invariant) surfaces only when PQ is
    enabled.
    """
    from repro.core.state import memory_report
    mr = memory_report(cfg)
    out = {"payload_bytes": mr["payload_bytes"] * n_shards,
           "code_bytes": mr["code_bytes"] * n_shards,
           "attr_bytes": mr["attr_bytes"] * n_shards,
           # tiered host/device split (one source of truth: memory_report)
           "host_bytes": mr["host_bytes"] * n_shards,
           "device_bytes": mr["device_bytes"] * n_shards,
           "device_cache_bytes": mr["device_cache_bytes"] * n_shards}
    if cfg.pq is not None:
        out["compression_ratio"] = mr["compression_ratio"]
    return out


def stats(cfg: SIVFConfig, state: SlabPoolState) -> dict:
    """Occupancy / fragmentation report (paper §5.6.2).

    Handles both a single-device ``SlabPoolState`` and the stacked
    per-shard state produced by ``distributed.init_sharded_state`` (leaves
    carry a leading shard axis): shard occupancy is aggregated, the live
    count folds ``distributed.total_live``, and error bits are OR-reduced.
    Includes the pool memory footprint (``_memory_stats``) so sessions can
    observe the PQ compression ratio.
    """
    import numpy as np
    free_top = np.asarray(state.free_top)
    occ = _list_occupancy(cfg, state)
    skew = {"list_occupancy": occ.tolist(),
            "list_skew": float(occ.max() / occ.mean()) if occ.any() else 0.0}
    if free_top.ndim:                      # stacked per-shard state
        from repro.core.distributed import total_live
        used_per = (cfg.n_slabs - free_top).astype(int)
        used = int(used_per.sum())
        live = total_live(state)
        alloc_slots = used * cfg.capacity
        table_len = np.asarray(state.table_len)          # [S, n_lists]
        err = int(np.bitwise_or.reduce(np.asarray(state.error).ravel()))
        return {
            "n_live": live,
            "slabs_used": used,
            "free_slabs": int(free_top.sum()),
            "alloc_slots": alloc_slots,
            "fill_frac": live / max(alloc_slots, 1),
            "error": err,
            "max_chain_len": int(table_len.max()),
            "mean_chain_len": float(table_len.mean()),
            "n_shards": int(free_top.shape[0]),
            "per_shard_live": np.asarray(state.n_live).astype(int).tolist(),
            "per_shard_slabs_used": used_per.tolist(),
            **skew,
            **_memory_stats(cfg, int(free_top.shape[0])),
        }
    used = int(cfg.n_slabs - state.free_top)
    live = int(state.n_live)
    alloc_slots = used * cfg.capacity
    return {
        "n_live": live,
        "slabs_used": used,
        "free_slabs": int(state.free_top),
        "alloc_slots": alloc_slots,
        "fill_frac": live / max(alloc_slots, 1),
        "error": int(state.error),
        "max_chain_len": int(jnp.max(state.table_len)),
        "mean_chain_len": float(jnp.mean(state.table_len)),
        **skew,
        **_memory_stats(cfg),
    }


def _list_occupancy(cfg: SIVFConfig, state: SlabPoolState) -> "np.ndarray":
    """Exact per-list live-row counts (drift-policy input).

    Recounted from the validity bitmaps and slab ownership rather than
    the incremental ``live`` counters: the bitmap is the plane searches
    mask by, so this tally is correct by construction under any
    overwrite/delete interleaving, single or stacked state.
    """
    import numpy as np

    from repro.core.state import host_live_mask
    owner = np.asarray(state.owner)
    per_slab = host_live_mask(cfg, np.asarray(state.bitmap)).sum(-1)
    owner, per_slab = owner.reshape(-1), per_slab.reshape(-1)
    occ = np.zeros((cfg.n_lists,), np.int64)
    sel = owner >= 0
    np.add.at(occ, owner[sel], per_slab[sel])
    return occ
