"""SIVF core: the paper's contribution as a composable JAX module."""
from repro.core.state import (  # noqa: F401
    ERR_CHAIN_OVERFLOW,
    ERR_ID_RANGE,
    ERR_POOL_EXHAUSTED,
    SIVFConfig,
    SlabPoolState,
    init_state,
    memory_report,
)
from repro.core.index import (  # noqa: F401
    delete,
    gather_tables,
    insert,
    scan_slabs_topk,
    scan_slabs_topk_pq,
    search,
    stats,
    walk_chains,
)
from repro.core.pq import (  # noqa: F401
    PQConfig,
    adc_tables,
    decode as pq_decode,
    encode as pq_encode,
    train_pq,
)
from repro.core.quantizer import assign, probe, train_kmeans  # noqa: F401
from repro.core.reference import ReferenceIndex  # noqa: F401
from repro.core.maintenance import (  # noqa: F401
    MaintenanceReport,
    MaintOp,
    maintain,
    merge,
    plan_ops,
    recluster,
    split,
)
from repro.core.api import (  # noqa: F401
    ErrorCode,
    Index,
    IndexProtocol,
    MaintenanceAborted,
    MutationRejected,
    MutationReport,
    PendingReport,
    SearchResult,
)
