"""SIVF slab-pool state (paper §3.1, SDMA).

The CUDA design keeps all of this in VRAM behind a ``SlabManager``; the JAX
port keeps it as one pytree of preallocated dense arrays. Mutation kernels
(`index.py`) are jitted with buffer donation so updates are in-place at the
XLA level, and the *state swap* is the linearization point (DESIGN.md §2).

Divergences from the paper (deliberate, documented in DESIGN.md §2):
  * doubly-linked chains (``nxt`` + ``prv``) so batched reclamation unlinks
    slabs exactly instead of leaving freed slabs spliced into old chains;
  * separate ``cursor`` (allocation watermark) and ``live`` (occupancy)
    counters, fixing the reuse-overwrites-live-slot hazard of using
    ``valid_count`` for both;
  * the 64-bit packed ATT entry ``(slab << 32) | slot`` is stored as two
    int32 planes (same 8 B/entry the paper reports in §3.5.3).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core.pq import PQConfig


@dataclasses.dataclass(frozen=True)
class SIVFConfig:
    """Static configuration (hashable; safe to close over in jit)."""

    dim: int                       # vector dimensionality D
    n_lists: int                   # number of IVF lists (coarse centroids)
    n_slabs: int                   # slab pool size (pre-allocated)
    capacity: int = 128            # C: slots per slab (TPU lane width; paper uses 32)
    n_max: int = 1 << 20           # dense external-id space [0, n_max)
    metric: str = "l2"             # "l2" or "ip"
    max_chain: int = 64            # slabs walked per list (Alg. 3 bound)
    track_tables: bool = True      # dense list->slab tables (DESIGN.md §2)
    dtype: jnp.dtype = jnp.float32
    pq: PQConfig | None = None     # product-quantized slab payloads (core/pq.py)
    attributes: tuple[str, ...] = ()  # named int32 filter attributes
    #                                   (core/filters.py; order = plane column)
    device_slabs: int | None = None  # tiered mode: on-device hot-cache frame
    #                                  budget; payload planes (data / codes /
    #                                  attrs) then live host-side and searches
    #                                  prefetch probed slabs (core/tiered.py)

    def __post_init__(self):
        bm.n_words(self.capacity)  # validates capacity
        if self.metric not in ("l2", "ip"):
            raise ValueError(f"unknown metric {self.metric}")
        if self.device_slabs is not None and not (
                1 <= self.device_slabs <= self.n_slabs):
            raise ValueError(
                f"device_slabs must be in [1, n_slabs={self.n_slabs}], got "
                f"{self.device_slabs}")
        if self.pq is not None and self.dim % self.pq.m:
            raise ValueError(
                f"dim {self.dim} not divisible by pq.m {self.pq.m}")
        attrs = tuple(self.attributes)
        if len(set(attrs)) != len(attrs) or any(
                not (a and isinstance(a, str)) for a in attrs):
            raise ValueError(
                f"attributes must be unique non-empty names, got {attrs}")
        object.__setattr__(self, "attributes", attrs)

    @property
    def words(self) -> int:
        return bm.n_words(self.capacity)

    @property
    def pool_vectors(self) -> int:
        return self.n_slabs * self.capacity

    @property
    def payload_dim(self) -> int:
        """Width of the fp32 ``data`` plane: 0 when PQ codes replace it."""
        return 0 if (self.pq is not None and not self.pq.store_raw) \
            else self.dim

    @property
    def code_m(self) -> int:
        """Width of the uint8 ``codes`` plane (0 when PQ is disabled)."""
        return self.pq.m if self.pq is not None else 0

    @property
    def n_attrs(self) -> int:
        """Width of the int32 ``attrs`` plane (0 when filtering is off)."""
        return len(self.attributes)

    @property
    def tiered(self) -> bool:
        """True when the payload planes are host-resident (device_slabs)."""
        return self.device_slabs is not None

    @property
    def payload_slabs(self) -> int:
        """Leading dim of the *device* payload planes: 0 in tiered mode (the
        canonical planes live host-side; the on-device copies are the
        ``device_slabs`` cache frames of ``core/tiered.py``)."""
        return 0 if self.tiered else self.n_slabs


@partial(
    jax.tree_util.register_dataclass,
    data_fields=[
        "data", "ids", "norms", "bitmap", "nxt", "prv", "owner", "cursor",
        "live", "heads", "free_stack", "free_top", "att_slab", "att_slot",
        "n_live", "error", "centroids", "tables", "table_len", "table_pos",
        "codes", "pq_codebooks", "attrs",
    ],
    meta_fields=[],
)
@dataclasses.dataclass
class SlabPoolState:
    """Device-resident SIVF index state. All shapes static."""

    # slab payloads + per-slot metadata
    data: jax.Array        # [n_slabs, C, payload_dim] fp payloads (width 0
    #                        when PQ codes replace them; cfg.payload_dim)
    ids: jax.Array         # [n_slabs, C] int32 external ids
    norms: jax.Array       # [n_slabs, C] f32 cached ||x||^2 (beyond-paper)
    # slab headers M = <next, b_valid, cnt> (paper §3.1) + divergence fields
    bitmap: jax.Array      # [n_slabs, W] uint32 validity bitmaps
    nxt: jax.Array         # [n_slabs] int32 next-slab pointer (-1 = end)
    prv: jax.Array         # [n_slabs] int32 prev-slab pointer (-1 = head)
    owner: jax.Array       # [n_slabs] int32 owning list id (-1 = free)
    cursor: jax.Array      # [n_slabs] int32 allocation watermark in [0, C]
    live: jax.Array        # [n_slabs] int32 live-slot count
    # per-list heads H[l] (paper §3.1)
    heads: jax.Array       # [n_lists] int32 head slab id (-1 = empty list)
    # global free stack P_top (paper Alg. 1)
    free_stack: jax.Array  # [n_slabs] int32
    free_top: jax.Array    # [] int32: number of free slabs
    # address translation table T (paper §3.4), two int32 planes
    att_slab: jax.Array    # [n_max] int32 (-1 = INVALID)
    att_slot: jax.Array    # [n_max] int32
    # counters / error flags
    n_live: jax.Array      # [] int32 total live vectors
    error: jax.Array       # [] int32 sticky error bits (1 = pool exhausted)
    # coarse quantizer centroids
    centroids: jax.Array   # [n_lists, D]
    # beyond-paper dense chain tables (track_tables):
    tables: jax.Array      # [n_lists, max_chain] int32 slab ids (-1 pad)
    table_len: jax.Array   # [n_lists] int32 chain length
    table_pos: jax.Array   # [n_slabs] int32 position of slab in its table
    # product-quantization planes (core/pq.py; zero-width when cfg.pq=None)
    codes: jax.Array       # [n_slabs, C, code_m] uint8 PQ codewords
    pq_codebooks: jax.Array  # [m, ksub, dim//m] f32 trained codebooks
    # filter-attribute plane (core/filters.py; zero-width when no attributes).
    # NOTE: keep this the LAST registered data field — checkpoint-format
    # migration (core/api.py Index.load) maps older formats by how many
    # trailing leaves they lack (format 1: codes/pq_codebooks/attrs,
    # format 2: attrs).
    attrs: jax.Array       # [n_slabs, C, n_attrs] int32 attribute stamps


ERR_POOL_EXHAUSTED = 1
ERR_ID_RANGE = 2
ERR_CHAIN_OVERFLOW = 4


def clear_error(state: SlabPoolState) -> SlabPoolState:
    """Return ``state`` with the sticky error bits zeroed.

    The error word is cumulative by design (fail-fast kernels OR bits in);
    the session facade (``core/api.py``) snapshots the bits into a typed
    per-batch ``MutationReport`` and clears them with this helper so each
    report describes exactly one batch.
    """
    return dataclasses.replace(state, error=jnp.zeros_like(state.error))


def init_state(cfg: SIVFConfig, centroids: jax.Array,
               pq_codebooks: jax.Array | None = None) -> SlabPoolState:
    """Fresh empty pool. ``centroids`` [n_lists, D] from the coarse quantizer.

    With ``cfg.pq`` set, ``pq_codebooks`` ``[m, ksub, dim//m]`` carries the
    trained subspace codebooks (``core.pq.train_pq``); omitted, the plane
    initializes to zeros and must be trained before ingest
    (``Index.train``) — every vector would otherwise encode to codeword 0.
    """
    if centroids.shape != (cfg.n_lists, cfg.dim):
        raise ValueError(
            f"centroids shape {centroids.shape} != {(cfg.n_lists, cfg.dim)}")
    ns, c, w = cfg.n_slabs, cfg.capacity, cfg.words
    if cfg.pq is not None:
        cb_shape = (cfg.pq.m, cfg.pq.ksub, cfg.dim // cfg.pq.m)
    else:
        cb_shape = (0, 0, 0)
    if pq_codebooks is None:
        cb = jnp.zeros(cb_shape, jnp.float32)
    else:
        if pq_codebooks.shape != cb_shape:
            raise ValueError(
                f"pq_codebooks shape {pq_codebooks.shape} != {cb_shape}")
        cb = jnp.array(pq_codebooks, dtype=jnp.float32)   # copy (donation)
    ps = cfg.payload_slabs          # 0 in tiered mode: payload planes are
    #                                 host-resident (core/tiered.py) and the
    #                                 device keeps only metadata + the cache
    return SlabPoolState(
        data=jnp.zeros((ps, c, cfg.payload_dim), cfg.dtype),
        ids=jnp.full((ns, c), -1, jnp.int32),
        norms=jnp.zeros((ns, c), jnp.float32),
        bitmap=jnp.zeros((ns, w), jnp.uint32),
        nxt=jnp.full((ns,), -1, jnp.int32),
        prv=jnp.full((ns,), -1, jnp.int32),
        owner=jnp.full((ns,), -1, jnp.int32),
        cursor=jnp.zeros((ns,), jnp.int32),
        live=jnp.zeros((ns,), jnp.int32),
        heads=jnp.full((cfg.n_lists,), -1, jnp.int32),
        free_stack=jnp.arange(ns, dtype=jnp.int32),
        free_top=jnp.array(ns, jnp.int32),
        att_slab=jnp.full((cfg.n_max,), -1, jnp.int32),
        att_slot=jnp.zeros((cfg.n_max,), jnp.int32),
        n_live=jnp.array(0, jnp.int32),
        error=jnp.array(0, jnp.int32),
        # copy, never alias: mutation kernels donate the whole state, and a
        # donated alias would delete the caller's centroids buffer
        centroids=jnp.array(centroids, dtype=cfg.dtype),
        tables=jnp.full((cfg.n_lists, cfg.max_chain), -1, jnp.int32),
        table_len=jnp.zeros((cfg.n_lists,), jnp.int32),
        table_pos=jnp.full((ns,), -1, jnp.int32),
        codes=jnp.zeros((ps, c, cfg.code_m), jnp.uint8),
        pq_codebooks=cb,
        attrs=jnp.zeros((ps, c, cfg.n_attrs), jnp.int32),
    )


def host_live_mask(cfg: SIVFConfig, bitmap) -> np.ndarray:
    """Unpack validity bitmaps to a host-side bool mask, slot-ordered.

    Accepts any ``[..., words]`` bitmap plane (single or stacked per-shard)
    and returns ``[..., capacity]`` bool. This is the numpy analogue of
    ``bitmap.unpack_batch`` for host-side state surgery — checkpoint
    inspection and elastic resharding (``distributed.flatten_live_rows``)
    walk the pool without touching a device.
    """
    words = np.asarray(bitmap).astype(np.uint32)
    shifts = np.arange(bm.WORD_BITS, dtype=np.uint32)
    bits = ((words[..., None] >> shifts) & np.uint32(1)) != 0
    return bits.reshape(*words.shape[:-1], cfg.capacity)


def memory_report(cfg: SIVFConfig) -> dict:
    """Structural-overhead accounting mirroring paper §5.6.2 / Fig. 12.

    With ``cfg.pq`` set, the per-vector payload is the uint8 code plane
    (plus the raw plane only when ``store_raw``); ``compression_ratio``
    reports pool payload bytes at fp32 over the stored payload+code bytes.
    Filter attributes (``cfg.attributes``) are stored raw on both sides of
    that ratio — they appear in the raw-equivalent row exactly as in the
    stored row, so enabling filtering never inflates the apparent
    compression.

    This is also the single source of truth for the tiered host/device
    split (``cfg.device_slabs``, core/tiered.py): ``host_bytes`` is the
    canonical payload store (data + codes + attrs planes — zero when the
    whole pool is device-resident), ``device_bytes`` is everything the
    accelerator holds (metadata, codebooks, and in tiered mode the
    ``device_slabs`` cache frames, reported separately as
    ``device_cache_bytes``). ``total_bytes`` always equals
    ``host_bytes + device_bytes``.
    """
    slots = cfg.n_slabs * cfg.capacity
    payload = slots * cfg.payload_dim * jnp.dtype(cfg.dtype).itemsize
    codes = slots * cfg.code_m
    attrs = slots * cfg.n_attrs * 4
    raw_equiv = slots * cfg.dim * jnp.dtype(cfg.dtype).itemsize + attrs
    codebooks = 0
    if cfg.pq is not None:
        codebooks = cfg.pq.m * cfg.pq.ksub * (cfg.dim // cfg.pq.m) * 4
    ids = slots * 4
    norms = slots * 4
    headers = cfg.n_slabs * (cfg.words * 4 + 4 * 6)  # bitmap + 6 int32 fields
    att = cfg.n_max * 8
    heads = cfg.n_lists * 4
    stack = cfg.n_slabs * 4
    tables = (cfg.n_lists * cfg.max_chain + cfg.n_lists + cfg.n_slabs) * 4 \
        if cfg.track_tables else 0
    stored = payload + codes + attrs
    metadata = codebooks + ids + norms + headers + att + heads + stack + tables
    # tiered split: the canonical payload planes live host-side and the
    # device adds `device_slabs` cache frames of the same per-slab width
    per_slab_payload = cfg.capacity * (
        cfg.payload_dim * jnp.dtype(cfg.dtype).itemsize
        + cfg.code_m + cfg.n_attrs * 4)
    cache = (cfg.device_slabs * per_slab_payload) if cfg.tiered else 0
    host = stored if cfg.tiered else 0
    device = metadata + cache + (0 if cfg.tiered else stored)
    total = host + device
    return {
        "payload_bytes": int(payload),
        "code_bytes": int(codes),
        "attr_bytes": int(attrs),
        "codebook_bytes": int(codebooks),
        "compression_ratio": float(raw_equiv / stored) if stored else 1.0,
        "metadata_bytes": int(metadata),
        "host_bytes": int(host),
        "device_bytes": int(device),
        "device_cache_bytes": int(cache),
        "total_bytes": int(total),
        "overhead_frac_vs_payload": float((total - stored) / max(stored, 1)),
    }
