"""Composable predicate algebra over named int attributes (filtered search).

Real streaming workloads search *within* a predicate — tenant id,
timestamp window, tag set (VecFlow, PAPERS.md). Post-filtering the
``[Q, k]`` result is recall-lossy (filtered-out rows displace passing
ones before the cut); SIVF instead stamps every stored vector with
``cfg.n_attrs`` int32 attributes (``SlabPoolState.attrs``) and pushes the
predicate mask *into* the scan, ahead of the top-k fold.

The algebra is deliberately small and closed over int attributes:

  ``Eq(attr, v)``          attribute == v
  ``In(attr, (v0, ...))``  attribute ∈ {v0, ...}
  ``Range(attr, lo, hi)``  lo <= attribute < hi   (half-open)
  ``And(p0, p1, ...)``     conjunction

``compile_filter`` splits a predicate into a hashable *structure* (which
attributes are tested, how, and how many constants each node consumes)
and a flat tuple of int32 *constants*. The structure is a static jit key;
the constants are traced operands. Two filters with the same shape —
``Eq("tenant", 3)`` vs ``Eq("tenant", 7)`` — therefore share one compiled
executable: compile counts are bounded by filter *structures* × bucket
shapes, never by the constants a session happens to query.

``eval_structure`` is the one evaluator for every backend. It is
parameterized by two accessors — ``get_attr(j) -> array`` (the j-th
attribute column of the candidate set, any shape) and
``get_const(i) -> scalar`` — so the same recursion produces the XLA
reference mask (jnp), the Pallas kernel mask (``[1, C]`` rows against
SMEM scalars), and the host-side numpy oracle (``host_matches``).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Eq:
    """attribute == value."""

    attr: str
    value: int


@dataclasses.dataclass(frozen=True)
class In:
    """attribute ∈ values (non-empty)."""

    attr: str
    values: tuple[int, ...]

    def __post_init__(self):
        vals = tuple(int(v) for v in self.values)
        if not vals:
            raise ValueError("In() needs at least one value")
        object.__setattr__(self, "values", vals)


@dataclasses.dataclass(frozen=True)
class Range:
    """lo <= attribute < hi (half-open; empty ranges match nothing)."""

    attr: str
    lo: int
    hi: int


@dataclasses.dataclass(frozen=True, init=False)
class And:
    """Conjunction of sub-predicates."""

    preds: tuple

    def __init__(self, *preds):
        if not preds:
            raise ValueError("And() needs at least one predicate")
        object.__setattr__(self, "preds", tuple(preds))


Predicate = Eq | In | Range | And


@dataclasses.dataclass(frozen=True)
class CompiledFilter:
    """Hashable (structure, constants) split of a predicate.

    ``structure`` keys the jit cache; ``consts`` ride as a traced int32
    vector whose length is a function of the structure alone.
    """

    structure: tuple
    consts: tuple[int, ...]


def _attr_index(attr: str, attributes: tuple[str, ...]) -> int:
    if attr not in attributes:
        raise KeyError(
            f"unknown attribute {attr!r}; configured: {list(attributes)} "
            f"(set SIVFConfig(attributes=...))")
    return attributes.index(attr)


def _compile(pred, attributes: tuple[str, ...], consts: list) -> tuple:
    if isinstance(pred, Eq):
        consts.append(int(pred.value))
        return ("eq", _attr_index(pred.attr, attributes))
    if isinstance(pred, In):
        consts.extend(pred.values)
        return ("in", _attr_index(pred.attr, attributes), len(pred.values))
    if isinstance(pred, Range):
        consts.extend((int(pred.lo), int(pred.hi)))
        return ("range", _attr_index(pred.attr, attributes))
    if isinstance(pred, And):
        return ("and",
                *(_compile(p, attributes, consts) for p in pred.preds))
    raise TypeError(f"not a predicate: {pred!r}")


def compile_filter(pred: Predicate | None, attributes: tuple[str, ...]
                   ) -> CompiledFilter | None:
    """Predicate -> (structure, consts); None passes through."""
    if pred is None:
        return None
    consts: list[int] = []
    structure = _compile(pred, tuple(attributes), consts)
    return CompiledFilter(structure=structure, consts=tuple(consts))


def _eval(node: tuple, get_attr, get_const, base: int):
    tag = node[0]
    if tag == "eq":
        return get_attr(node[1]) == get_const(base), base + 1
    if tag == "range":
        a = get_attr(node[1])
        return (a >= get_const(base)) & (a < get_const(base + 1)), base + 2
    if tag == "in":
        a = get_attr(node[1])
        m = None
        for i in range(node[2]):
            e = a == get_const(base + i)
            m = e if m is None else (m | e)
        return m, base + node[2]
    if tag == "and":
        m = None
        for sub in node[1:]:
            sm, base = _eval(sub, get_attr, get_const, base)
            m = sm if m is None else (m & sm)
        return m, base
    raise ValueError(f"bad filter structure node {node!r}")


def eval_structure(structure: tuple, get_attr, get_const):
    """Evaluate a compiled structure to a boolean match mask.

    ``get_attr(j)`` returns the j-th attribute column over the candidate
    set (any array shape/backend); ``get_const(i)`` returns the i-th
    constant as a scalar of the same backend. The returned mask has the
    shape ``get_attr`` produces.
    """
    m, _ = _eval(structure, get_attr, get_const, 0)
    return m


def host_matches(pred: Predicate, attributes: tuple[str, ...],
                 attrs) -> np.ndarray:
    """Numpy oracle: attrs [..., A] int -> bool mask [...].

    The brute-force-within-predicate reference used by tests and the
    ``filtered_sweep`` benchmark; same evaluator as the device masks.
    """
    cf = compile_filter(pred, tuple(attributes))
    a = np.asarray(attrs)
    return np.asarray(eval_structure(
        cf.structure,
        lambda j: a[..., j],
        lambda i: np.int32(cf.consts[i])))


def eq_bindings(pred: Predicate | None) -> dict[str, int]:
    """The attribute values a predicate pins exactly (Eq nodes, recursively
    through And). ServeEngine uses this to force-stamp tenant attributes on
    ingest so a row can never escape its tenant's mandatory filter."""
    out: dict[str, int] = {}
    if isinstance(pred, Eq):
        out[pred.attr] = int(pred.value)
    elif isinstance(pred, And):
        for p in pred.preds:
            out.update(eq_bindings(p))
    return out


def normalize_attrs(attributes: tuple[str, ...], attrs, n: int,
                    overrides: dict[str, int] | None = None) -> np.ndarray:
    """Client attrs (dict of scalars/[n]-columns, or an [n, A] array) ->
    dense ``[n, A]`` int32, column order = ``attributes``.

    Every configured attribute must be covered (by ``attrs`` or
    ``overrides``) — silent zero-defaults would let rows slip out of a
    tenant's mandatory filter. ``overrides`` (ServeEngine stamping) win
    over client-provided columns.
    """
    a = len(attributes)
    overrides = overrides or {}
    if attrs is None:
        attrs = {}
    if isinstance(attrs, dict):
        unknown = set(attrs) - set(attributes)
        if unknown:
            raise KeyError(f"unknown attributes {sorted(unknown)}; "
                           f"configured: {list(attributes)}")
        missing = [name for name in attributes
                   if name not in attrs and name not in overrides]
        if missing:
            raise ValueError(f"missing attributes {missing}: every "
                             "configured attribute must be stamped on add")
        out = np.zeros((n, a), np.int32)
        for j, name in enumerate(attributes):
            if name in overrides:
                out[:, j] = np.int32(overrides[name])
            else:
                out[:, j] = np.asarray(attrs[name], np.int32)
        return out
    arr = np.asarray(attrs, np.int32)
    if arr.shape != (n, a):
        raise ValueError(f"attrs shape {arr.shape} != {(n, a)} "
                         f"(attributes {list(attributes)})")
    if overrides:
        arr = arr.copy()
        for j, name in enumerate(attributes):
            if name in overrides:
                arr[:, j] = np.int32(overrides[name])
    return arr
