"""``sivf.Index`` — the unified streaming-session facade over SIVF backends.

The paper ships SIVF behind one mutable Faiss-style handle; this module is
that handle for the JAX reproduction. It folds the three parallel surfaces
(``core.index`` free functions, ``core.distributed.dist_*``, and the
baselines' ad-hoc signatures) into a single stateful session object:

    cfg = SIVFConfig(dim=64, n_lists=32, n_slabs=512)
    index = Index(cfg, centroids)                  # or backend=mesh
    report = index.add(vecs, ids)                  # -> MutationReport
    result = index.search(queries, k=10, nprobe=8) # -> SearchResult
    report = index.remove(ids)
    index.save(path); index = Index.load(path)

    with Index(cfg, centroids, deferred=True) as index:
        futs = [index.add(v, i) for v, i in stream]    # -> PendingReport
        reports = index.flush()                        # one sync, N reports

Design points (ISSUE 2, atomicity + deferral reworked in ISSUE 3):

  * **One code path over backends.** ``backend="single"`` wraps the
    batched kernels of ``core.index``; ``backend=<jax Mesh>`` wraps the
    shard-mapped builders of ``core.distributed``. The handle logic —
    batch bucketing, error decoding, report accounting — is identical for
    both; only the raw jitted op differs.
  * **Structured error reporting.** The core kernels accumulate sticky
    int error bits in ``state.error``; the handle converts them into a
    per-batch :class:`MutationReport` with a typed :class:`ErrorCode` and
    disjoint ``accepted`` / ``overwritten`` / ``rejected`` counts, then
    clears the handled bits so each report describes exactly one batch.
    ``strict=True`` (per handle or per call) raises
    :class:`MutationRejected` instead. Failed insert batches are
    *atomic*: ``POOL_EXHAUSTED`` / ``CHAIN_OVERFLOW`` leaves every
    previously-live id searchable with its old payload (the mesh backend
    applies this per shard, and the counts stay truthful under partial
    per-shard failure).
  * **Deferred reports.** ``Index(..., deferred=True)`` turns ``add`` /
    ``remove`` into fire-and-forget submits returning
    :class:`PendingReport` futures backed by on-device aux scalars; no
    host sync happens until :meth:`Index.flush` (or context-manager
    exit, or touching a future), so the device queue stays full between
    syncs. Resolution is one *packed* transfer per queue — every batch's
    scalars (and per-shard error vectors) concatenate into a single
    int32 array crossing in one ``jax.device_get`` — never one sync per
    future. Eager and deferred modes run the *same* jitted executables —
    deferral adds zero compilations.
  * **Serve-engine hooks** (ISSUE 6). :attr:`Index.epoch` counts
    mutation batches *dispatched* (each is an atomic on-device commit,
    so it is also the committed prefix a later search observes) and
    :attr:`Index.pending_count` exposes the deferred-queue depth;
    together with :meth:`flush` resolving futures oldest-first they are
    the contract ``repro.serve.sivf_engine.ServeEngine`` builds its
    coalescing scheduler and epoch-consistency guarantee on.
  * **Device-side padding.** Batches that arrive as ``jax.Array``s are
    padded to their bucket with ``jnp`` ops on the device; only host
    (numpy / list) inputs take the numpy padding path. Device-resident
    streams therefore never pay a device->host->device round trip per op.
  * **Bounded jit compilations under ragged streaming.** Live clients send
    arbitrary batch sizes; every batch is padded to the next power-of-two
    bucket (floor ``min_bucket``), so a stream whose batches span sizes
    ``[1, S]`` compiles at most ``log2(S / min_bucket) + 1`` add / remove /
    search executables. This is *measured*, not assumed:
    :meth:`Index.compile_stats` exposes the jit cache sizes and the tests
    assert the bound over 8+ distinct ragged sizes.
  * **Persistence** goes through ``checkpoint/manager.py`` (atomic,
    checksummed) plus a JSON sidecar holding the config, backend
    topology, and shard-routing rule, so :meth:`Index.load` can rebuild
    the handle.
  * **Elastic resharding** (ISSUE 5). A checkpoint saved on S shards
    loads onto *any* backend — S' shards or ``"single"`` — via
    ``core.distributed.reshard_state`` (rows re-route by
    ``id % n_shards'``; search results stay bit-identical), and
    :meth:`Index.reshard` does the same to a live handle in place. See
    docs/architecture.md and docs/checkpoint-format.md.
  * :class:`IndexProtocol` is the structural interface the baselines
    (``baselines/contiguous_ivf.py``, ``baselines/lsh.py``, ...) also
    implement, so benchmarks and examples drive every engine identically.

The old functional API (``core.insert/delete/search``, ``dist_*``) remains
importable and delegates to the same kernels; see README for the migration
map.
"""
from __future__ import annotations

import dataclasses
import enum
from functools import lru_cache, partial
from types import SimpleNamespace
from typing import Iterator, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import filters as flt
from repro.core import index as ix
from repro.core import pq as pqmod
from repro.core import quantizer
from repro.core.pq import PQConfig
from repro.core.state import (
    ERR_CHAIN_OVERFLOW,
    ERR_ID_RANGE,
    ERR_POOL_EXHAUSTED,
    SIVFConfig,
    SlabPoolState,
    clear_error as _clear_error,
    init_state,
)

_I32_MAX = jnp.iinfo(jnp.int32).max


# ---------------------------------------------------------------------------
# Report types
# ---------------------------------------------------------------------------

class ErrorCode(enum.IntFlag):
    """Typed view of the core kernels' sticky ``state.error`` bits."""

    NONE = 0
    POOL_EXHAUSTED = ERR_POOL_EXHAUSTED
    ID_RANGE = ERR_ID_RANGE
    CHAIN_OVERFLOW = ERR_CHAIN_OVERFLOW


@dataclasses.dataclass(frozen=True)
class MutationReport:
    """Per-batch admission report for :meth:`Index.add` / :meth:`Index.remove`.

    The three counts are disjoint and sum to ``requested``:

      * ``accepted``    — distinct new ids now live in the index;
      * ``overwritten`` — distinct ids that existed before the batch and
        whose payload was actually replaced (delete-then-insert
        semantics). Ids whose shard aborted are *not* counted here: a
        pool-exhausted / chain-overflow batch is atomic, so their old
        payload survives untouched;
      * ``rejected``    — everything else: rows superseded by a later
        duplicate in the same batch, ids outside ``[0, n_max)``, and all
        rows of an aborted (pool-exhausted / chain-overflow) batch —
        including ids that *would have been* overwritten, since the
        atomic abort left their old payloads live.

    All counts are measured from device state (live totals and address-
    table presence before/after), not inferred, so they stay truthful under
    partial per-shard failures on the mesh backend; ``shard_errors`` then
    carries each shard's own bits (``None`` on the single-device backend).
    """

    op: str                 # "add" | "remove"
    requested: int          # non-padding rows in the caller's batch
    accepted: int
    overwritten: int
    rejected: int
    errors: ErrorCode       # this batch's error bits (already cleared)
    n_live: int             # total live vectors after the batch
    padded_to: int          # bucket shape the batch was padded to
    shard_errors: tuple[ErrorCode, ...] | None = None  # mesh: per-shard bits

    @property
    def ok(self) -> bool:
        return self.errors == ErrorCode.NONE


class MutationRejected(RuntimeError):
    """Raised in strict mode when a batch reports any error bit.

    In deferred mode the raise happens at :meth:`Index.flush` (or context
    exit) — the whole pending queue still resolves first, so every
    :class:`PendingReport` is usable afterwards.
    """

    def __init__(self, report: MutationReport):
        super().__init__(
            f"{report.op} batch rejected: errors={report.errors!r} "
            f"accepted={report.accepted} overwritten={report.overwritten} "
            f"rejected={report.rejected} of requested={report.requested}")
        self.report = report


class MaintenanceAborted(RuntimeError):
    """Raised in strict mode when a maintenance op aborts atomically.

    The abort is clean by construction — every previously-live id stays
    searchable under the old list layout (old centroids included) — so
    catching this and retrying after evictions is always safe. Raised
    after every requested op has resolved, like :meth:`Index.flush`.
    """

    def __init__(self, report):
        super().__init__(
            f"maintenance {report.kind} on lists {report.lists} aborted: "
            f"error bits {report.errors:#x} ({report.rows} rows kept "
            f"under the old layout)")
        self.report = report


class PendingReport:
    """Future for a deferred :class:`MutationReport`.

    Returned by ``add`` / ``remove`` on a handle constructed with
    ``deferred=True``. The batch's counts live in on-device aux scalars
    until the owning :class:`Index` flushes; submitting costs no host
    sync. ``result()`` — or reading any :class:`MutationReport` attribute
    straight off the future — forces a flush of the *whole* pending queue
    (one sync resolves every outstanding future, oldest first).
    """

    __slots__ = ("_index", "_resolved")

    def __init__(self, index: "Index"):
        self._index = index
        self._resolved: MutationReport | None = None

    @property
    def done(self) -> bool:
        """True once the owning handle has flushed past this batch."""
        return self._resolved is not None

    def result(self) -> MutationReport:
        if self._resolved is None:
            self._index.flush()
        if self._resolved is None:      # pragma: no cover - defensive
            raise RuntimeError(
                "PendingReport still unresolved after flush() — its batch "
                "is no longer in the owning Index's pending queue")
        return self._resolved

    def __getattr__(self, name: str):
        # proxy MutationReport attributes (forces resolution)
        if name.startswith("_"):
            raise AttributeError(name)
        return getattr(self.result(), name)

    def __repr__(self) -> str:
        return (f"PendingReport({self._resolved!r})" if self.done
                else "PendingReport(<unresolved>)")


@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Top-k result. Iterable as ``(distances, labels)`` for tuple-compat."""

    distances: jax.Array    # [Q, k] f32 (inf pads empty slots)
    labels: jax.Array       # [Q, k] int32 external ids (-1 pads)
    k: int
    nprobe: int
    padded_to: int          # query bucket the batch was padded to

    def __iter__(self) -> Iterator:
        return iter((self.distances, self.labels))


@runtime_checkable
class IndexProtocol(Protocol):
    """Structural interface every engine (SIVF + baselines) implements.

    ``benchmarks/`` and ``examples/streaming_rag.py`` drive all engines
    through this surface; engines without IVF probing accept and ignore
    ``nprobe``.
    """

    def add(self, vecs, ids) -> MutationReport: ...

    def remove(self, ids) -> MutationReport: ...

    def search(self, queries, k: int, nprobe: int | None = None
               ) -> SearchResult: ...

    def stats(self) -> dict: ...

    @property
    def n_live(self) -> int: ...


def report_from_counts(op: str, requested: int, accepted: int,
                       overwritten: int, n_live: int, padded_to: int,
                       errors: ErrorCode = ErrorCode.NONE) -> MutationReport:
    """Build a consistent report from host-side counts (baseline engines)."""
    accepted = max(int(accepted), 0)
    overwritten = max(int(overwritten), 0)
    return MutationReport(
        op=op, requested=int(requested), accepted=accepted,
        overwritten=overwritten,
        rejected=max(int(requested) - accepted - overwritten, 0),
        errors=errors, n_live=int(n_live), padded_to=int(padded_to))


# ---------------------------------------------------------------------------
# Traced accounting helpers (run inside the jitted mutation wrappers)
# ---------------------------------------------------------------------------

_ABORT_BITS = ERR_POOL_EXHAUSTED | ERR_CHAIN_OVERFLOW   # batch-atomic aborts


def _count_unique(ids: jax.Array, mask: jax.Array) -> jax.Array:
    """Number of distinct ids where ``mask`` holds (traced).

    Sorts on ``(~mask, id)`` — the mask is a second sort key, not a magic
    value — so a genuine id equal to ``INT32_MAX`` is still counted (the
    old sentinel encoding silently collapsed it into the masked-out run).
    """
    order = jnp.lexsort((ids, ~mask))       # masked-in rows first, id-sorted
    sm = mask[order]
    si = ids[order]
    first = jnp.concatenate([jnp.ones((1,), bool), si[1:] != si[:-1]])
    return jnp.sum((first & sm).astype(jnp.int32))


def _or_bits(err: jax.Array) -> jax.Array:
    """Bitwise-OR reduce error bits over any shape (per-shard arrays)."""
    acc = jnp.zeros((), jnp.int32)
    for bit in (ERR_POOL_EXHAUSTED, ERR_ID_RANGE, ERR_CHAIN_OVERFLOW):
        acc = acc | jnp.where(jnp.any((err & bit) != 0), bit, 0)
    return acc


_AUX_SCALARS = ("n_requested", "n_live_before", "errors", "n_live_after",
                "n_overwritten")


def _resolve_aux(auxes: list[dict]) -> list[dict]:
    """Sync a queue of device aux dicts in ONE device->host transfer.

    Every aux value is int32 (five scalars per batch, plus the mesh
    backend's per-shard error vector), so the whole queue packs into one
    flat device array: a single concatenate + a single explicit
    ``jax.device_get``, however long the queue. ``Index.flush`` resolving
    N deferred reports therefore costs one transfer, not 5N — and eager
    mode reuses the same path with a one-element queue.
    """
    if not auxes:
        return []
    chunks, spans, off = [], [], 0
    for a in auxes:
        se = a.get("shard_errors")
        n_se = 0 if se is None else int(se.shape[0])
        chunks.append(jnp.stack([a[k] for k in _AUX_SCALARS]))
        if se is not None:
            chunks.append(se.astype(jnp.int32).reshape(-1))
        spans.append((off, n_se))
        off += len(_AUX_SCALARS) + n_se
    flat = chunks[0] if len(chunks) == 1 else jnp.concatenate(chunks)
    host = np.asarray(jax.device_get(flat))
    out = []
    for off, n_se in spans:
        vals = host[off:off + len(_AUX_SCALARS) + n_se]
        d = dict(zip(_AUX_SCALARS, vals[:len(_AUX_SCALARS)].tolist()))
        if n_se:
            d["shard_errors"] = vals[len(_AUX_SCALARS):]
        out.append(d)
    return out


# ---------------------------------------------------------------------------
# Backend op factories (cached so handles with equal configs share jit
# caches — this is what keeps compile counts bounded across sessions)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _single_ops(cfg: SIVFConfig, impl: str, block_q: int,
                use_tables: bool | None) -> SimpleNamespace:
    """Jitted single-device insert/delete/search with report accounting.

    The aux dict returned next to the new state holds *device* scalars
    only — nothing syncs until the handle resolves a report (immediately
    in eager mode, at ``flush()`` in deferred mode).
    """

    def _presence(state, ids, valid):
        # mask before indexing: an out-of-range id must never read another
        # slot's occupancy (clipping used to alias it onto slot n_max-1,
        # misreporting it as an overwrite instead of a rejection)
        safe = jnp.where(valid, ids, 0)
        return valid & (state.att_slab[safe] >= 0)

    def _pre(state, ids):
        valid = (ids >= 0) & (ids < cfg.n_max)
        pb = _presence(state, ids, valid)
        aux = {"n_requested": jnp.sum((ids >= 0).astype(jnp.int32)),
               "n_live_before": state.n_live}
        return valid, pb, aux

    @partial(jax.jit, donate_argnums=(0,))
    def insert_fn(state, vecs, ids, attrs):
        valid, pb, aux = _pre(state, ids)
        lists = quantizer.assign(state.centroids, vecs.astype(cfg.dtype),
                                 cfg.metric)
        out = ix._insert_impl(cfg, _clear_error(state), vecs, ids, lists,
                              attrs=attrs, want_plan=cfg.tiered)
        st, plan = out if cfg.tiered else (out, None)
        aux["errors"] = _or_bits(st.error)
        aux["n_live_after"] = st.n_live
        # overwritten == present-before AND the batch committed; on an
        # atomic abort the old payload survives, so nothing is overwritten
        failed = (st.error & _ABORT_BITS) != 0
        aux["n_overwritten"] = _count_unique(ids, pb & ~failed)
        if cfg.tiered:     # commit plan rides along for the host-store replay
            return _clear_error(st), aux, plan
        return _clear_error(st), aux

    @partial(jax.jit, donate_argnums=(0,))
    def delete_fn(state, ids):
        _, _, aux = _pre(state, ids)
        st = ix._delete_impl(cfg, _clear_error(state), ids)
        aux["errors"] = _or_bits(st.error)
        aux["n_live_after"] = st.n_live
        aux["n_overwritten"] = jnp.zeros((), jnp.int32)
        return _clear_error(st), aux

    @partial(jax.jit, static_argnums=(2, 3, 4))
    def search_fn(state, queries, k, nprobe, fstruct, fconsts):
        return ix._search_impl(cfg, state, queries, k, nprobe, use_tables,
                               impl, block_q, fstruct=fstruct,
                               fconsts=fconsts)

    return SimpleNamespace(insert=insert_fn, delete=delete_fn,
                           search=search_fn, n_shards=1)


@lru_cache(maxsize=None)
def _mesh_ops(cfg: SIVFConfig, mesh: Mesh, axis: str, impl: str,
              block_q: int, use_tables: bool | None) -> SimpleNamespace:
    """Jitted shard_map insert/delete/search over a stacked sharded state.

    Same aux contract as :func:`_single_ops` (device scalars, deferred-
    friendly) plus ``shard_errors``: the per-shard error vector, so a
    report can say *which* shard aborted. Inserts are atomic per shard —
    ids owned by an aborting shard keep their old payloads and are counted
    rejected, ids on committing shards proceed normally.
    """
    from repro.core import distributed as dist
    n = mesh.shape[axis]
    raw_insert = dist.sharded_insert(cfg, mesh, axis, want_plan=cfg.tiered)
    raw_delete = dist.sharded_delete(cfg, mesh, axis)
    raw_search = dist.sharded_search(cfg, mesh, axis, impl, block_q,
                                     use_tables)

    def _presence(state, ids, valid):
        # an id lives only on its owner shard: gather that shard's ATT row
        # (mask before indexing — see the single-backend note)
        safe = jnp.where(valid, ids, 0)
        owner = jnp.where(valid, ids % n, 0)
        return valid & (state.att_slab[owner, safe] >= 0)

    def _pre(state, ids):
        valid = (ids >= 0) & (ids < cfg.n_max)
        pb = _presence(state, ids, valid)
        aux = {"n_requested": jnp.sum((ids >= 0).astype(jnp.int32)),
               "n_live_before": jnp.sum(state.n_live)}
        return valid, pb, aux

    @partial(jax.jit, donate_argnums=(0,))
    def insert_fn(state, vecs, ids, attrs):
        valid, pb, aux = _pre(state, ids)
        out = raw_insert(_clear_error(state), vecs, ids, attrs)
        st, plan = out if cfg.tiered else (out, None)
        aux["errors"] = _or_bits(st.error)
        aux["shard_errors"] = st.error                       # [S] bits
        aux["n_live_after"] = jnp.sum(st.n_live)
        # partial per-shard failure: only ids on committing shards count
        # as overwritten — an aborting shard restored its old payloads
        shard_failed = (st.error & _ABORT_BITS) != 0         # [S]
        failed = shard_failed[jnp.where(valid, ids % n, 0)]
        aux["n_overwritten"] = _count_unique(ids, pb & ~failed)
        if cfg.tiered:     # stacked [S, B] plan for the per-shard replay
            return _clear_error(st), aux, plan
        return _clear_error(st), aux

    @partial(jax.jit, donate_argnums=(0,))
    def delete_fn(state, ids):
        _, _, aux = _pre(state, ids)
        st = raw_delete(_clear_error(state), ids)
        aux["errors"] = _or_bits(st.error)
        aux["shard_errors"] = st.error
        aux["n_live_after"] = jnp.sum(st.n_live)
        aux["n_overwritten"] = jnp.zeros((), jnp.int32)
        return _clear_error(st), aux

    @partial(jax.jit, static_argnums=(2, 3, 4))
    def search_fn(state, queries, k, nprobe, fstruct, fconsts):
        return raw_search(state, queries, k, nprobe, fstruct=fstruct,
                          fconsts=fconsts)

    return SimpleNamespace(insert=insert_fn, delete=delete_fn,
                           search=search_fn, n_shards=n)


# ---------------------------------------------------------------------------
# The handle
# ---------------------------------------------------------------------------

def _resolve_backend(backend, axis: str) -> tuple[str, int]:
    """Validate a backend spec -> (``"single"`` | ``"mesh"``, shard count).

    The single point of truth for what a backend argument may be
    (:class:`Index` construction, :meth:`Index.load`,
    :meth:`Index.reshard` all accept the same forms) — a mesh must carry
    the index's data axis, anything else must be the literal ``"single"``.
    """
    if isinstance(backend, Mesh):
        if axis not in backend.shape:
            raise ValueError(
                f"target mesh has no {axis!r} axis (axes: "
                f"{tuple(backend.shape)}); pass axis= or a mesh with the "
                f"index's data axis")
        return "mesh", int(backend.shape[axis])
    if isinstance(backend, str) and backend == "single":
        return "single", 1
    raise TypeError(
        f"backend must be 'single' or a jax Mesh, got {backend!r}")

class Index:
    """Stateful SIVF session handle; see module docstring for the contract.

    Parameters
    ----------
    cfg:        static :class:`SIVFConfig` (hashable; keys the jit caches).
    centroids:  ``[n_lists, dim]`` coarse-quantizer centroids.
    backend:    ``"single"`` (default) or a ``jax.sharding.Mesh`` whose
                ``axis`` dimension data-shards the index (paper §4.2).
    impl:       scan->top-k backend: "xla" | "pallas" | "pallas_interpret".
    block_q:    fused kernel query-tile height.
    use_tables: dense-table vs pointer-walk slab lookup (None = cfg default).
    strict:     raise :class:`MutationRejected` on any per-batch error bit
                (in deferred mode the raise happens at :meth:`flush`).
    min_bucket: smallest padded batch shape; batches are padded to
                ``max(min_bucket, next_pow2(B))`` so ragged streams trigger
                a bounded number of jit compilations.
    deferred:   make ``add`` / ``remove`` return :class:`PendingReport`
                futures instead of syncing per batch; resolve them all with
                :meth:`flush` (the handle is a context manager that flushes
                on clean exit). Uses the same jitted executables as eager
                mode — deferral never adds compilations.
    pq_codebooks: pre-trained ``[m, ksub, dim//m]`` PQ codebooks (only with
                ``cfg.pq``); otherwise call :meth:`train` before the first
                ``add``. With PQ enabled, ingest encodes batches to uint8
                codes and search runs ADC over the compressed slabs.
    """

    def __init__(self, cfg: SIVFConfig, centroids, backend="single", *,
                 axis: str = "data", impl: str = "xla", block_q: int = 8,
                 use_tables: bool | None = None, strict: bool = False,
                 min_bucket: int = 64, deferred: bool = False,
                 pq_codebooks=None, telemetry=None,
                 _state: SlabPoolState | None = None,
                 _pq_trained: bool | None = None):
        if min_bucket < 1:
            raise ValueError("min_bucket must be >= 1")
        if telemetry is None:
            from repro import obs
            telemetry = obs.default()
        self._telemetry = telemetry
        if pq_codebooks is not None and cfg.pq is None:
            raise ValueError("pq_codebooks given but cfg.pq is None")
        self.cfg = cfg
        self.strict = bool(strict)
        self.min_bucket = int(min_bucket)
        self.deferred = bool(deferred)
        self._pending: list[tuple[PendingReport, str, dict, int,
                                  bool | None]] = []
        self._epoch = 0
        self._axis = axis
        self._impl = impl
        self._block_q = int(block_q)
        self._use_tables = use_tables
        if pq_codebooks is not None:
            pq_codebooks = jnp.asarray(pq_codebooks, jnp.float32)
        self._backend_kind, _ = _resolve_backend(backend, axis)
        if self._backend_kind == "single":
            self._mesh = None
            self._ops = _single_ops(cfg, impl, self._block_q, use_tables)
            if _state is None:
                _state = init_state(cfg, jnp.asarray(centroids),
                                    pq_codebooks)
        else:
            from repro.core import distributed as dist
            self._mesh = backend
            self._ops = _mesh_ops(cfg, backend, axis, impl, self._block_q,
                                  use_tables)
            if _state is None:
                _state = dist.init_sharded_state(
                    cfg, jnp.asarray(centroids), backend, axis,
                    pq_codebooks)
        self._tiered = None
        if cfg.tiered:
            from repro.core import tiered as trt
            stores = None
            if trt.is_full_state(cfg, _state):
                # incoming full-pool state (load / reshard): split into the
                # host canonical store + a zero-width-payload device state
                meta, stores = trt.split_full(cfg, _state)
                if self._backend_kind == "mesh":
                    from repro.core import distributed as dist
                    _state = dist.place_sharded(meta, self._mesh, axis)
                else:
                    _state = jax.tree.map(jnp.asarray, meta)
            self._tiered = trt.TieredRuntime(
                cfg, self._backend_kind, mesh=self._mesh, axis=axis,
                impl=impl, block_q=self._block_q, use_tables=use_tables,
                n_shards=self._ops.n_shards, stores=stores,
                telemetry=self._telemetry)
        self._state = _state
        if _pq_trained is None:
            _pq_trained = cfg.pq is None or pq_codebooks is not None
        self._pq_trained = bool(_pq_trained)
        # jit-compile observability: executables existing at construction
        # (lru_cached op sets are shared between same-keyed handles) are
        # the baseline; _note_compiles() turns later growth into counter
        # events so a compile storm is visible in a scrape, not just tests
        self._m_compiles = self._telemetry.counter(
            "sivf_jit_compile_events_total",
            "new jit executables observed since handle construction")
        self._m_executables = self._telemetry.gauge(
            "sivf_jit_executables",
            "current executable count across this handle's op set")
        self._m_mutations = self._telemetry.counter(
            "sivf_index_mutation_rows_total",
            "mutation rows dispatched through this handle", ("op",))
        self._m_maint = self._telemetry.counter(
            "sivf_maintenance_ops_total",
            "maintenance ops dispatched", ("kind", "outcome"))
        self._m_maint_rows = self._telemetry.counter(
            "sivf_maintenance_rows_total",
            "live rows moved by committed maintenance ops")
        self._maint_cursor = 0      # round-robin recluster position
        self._compiles_seen = self._total_compiles()
        self._compile_base = self._compiles_seen

    # -- introspection ------------------------------------------------------

    @property
    def backend(self) -> str:
        return self._backend_kind

    @property
    def n_shards(self) -> int:
        return self._ops.n_shards

    @property
    def state(self) -> SlabPoolState:
        """The underlying pytree (functional-API interop; treat read-only)."""
        return self._state

    @property
    def n_live(self) -> int:
        return int(jnp.sum(self._state.n_live))

    @property
    def epoch(self) -> int:
        """Mutation batches dispatched over this handle's lifetime.

        Bumps on every ``add`` / ``remove`` *dispatch* (eager or
        deferred) — device work executes in dispatch order and each
        batch commits atomically, so a search dispatched at epoch ``e``
        observes exactly the first ``e`` batches. The serve engine
        (``repro.serve.sivf_engine``) stamps results with this value to
        make search-during-ingest consistency checkable.
        """
        return self._epoch

    @property
    def pending_count(self) -> int:
        """Deferred mutation batches awaiting :meth:`flush` (0 if eager)."""
        return len(self._pending)

    def __len__(self) -> int:
        return self.n_live

    def stats(self) -> dict:
        """Occupancy/fragmentation report + handle/backend metadata."""
        s = ix.stats(self.cfg, self._state)
        s["backend"] = self._backend_kind
        s["n_shards"] = self.n_shards
        s["compiles"] = self.compile_stats()
        if self._tiered is not None:
            s.update(self._tiered.stats())
        else:
            # all-resident pool: every used slab is trivially "resident"
            s["tiered"] = False
            s["resident_slabs"] = s["slabs_used"]
            s["hit_rate"] = 1.0
            s["hit_rate_kind"] = "cumulative"
        return s

    def compile_stats(self) -> dict:
        """Observed jit-executable counts for this handle's op set.

        Counters are shared between handles constructed with an identical
        (cfg, backend, impl, block_q, use_tables) tuple — that sharing is
        deliberate (sessions over the same index config reuse executables).
        Use a fresh ``SIVFConfig`` to measure in isolation.
        """
        def size(f):
            try:
                return int(f._cache_size())
            except Exception:               # pragma: no cover - private API
                return -1
        out = {"add": size(self._ops.insert),
               "remove": size(self._ops.delete),
               "search": size(self._ops.search)}
        if self._tiered is not None:
            # tiered searches run the plan + scan executables instead of
            # self._ops.search (whose count stays 0 on a tiered handle)
            out.update(self._tiered.compile_stats())
        return out

    def _total_compiles(self) -> int:
        return sum(v for v in self.compile_stats().values() if v > 0)

    def _note_compiles(self) -> None:
        """Fold executable-count growth into the telemetry registry
        (``sivf_jit_compile_events_total`` counts *new* executables since
        construction — the compile-storm alert signal)."""
        if not self._telemetry.enabled:
            return
        now = self._total_compiles()
        if now > self._compiles_seen:
            self._m_compiles.inc(now - self._compiles_seen)
        self._compiles_seen = max(self._compiles_seen, now)
        self._m_executables.set(now)

    def compile_events(self) -> int:
        """New jit executables observed since this handle was built (the
        value ``sivf_jit_compile_events_total`` accumulates)."""
        return max(self._total_compiles(), self._compiles_seen) \
            - self._compile_base

    def telemetry(self) -> dict:
        """JSON-able snapshot of this handle's telemetry (metrics +
        slow-query log). The handle records into the process default
        unless constructed with an explicit ``telemetry=``."""
        self._note_compiles()
        return self._telemetry.snapshot()

    # -- batch bucketing ----------------------------------------------------

    def _bucket(self, n: int) -> int:
        b = self.min_bucket
        while b < n:
            b *= 2
        return b

    def bucket_shapes(self, max_size: int) -> list[int]:
        """The bounded set of padded shapes for batches up to ``max_size``."""
        out = [self.min_bucket]
        while out[-1] < max_size:
            out.append(out[-1] * 2)
        return out

    def _pad_ids(self, ids, bucket: int) -> jax.Array:
        if isinstance(ids, jax.Array):       # device fast path: jnp pad, no
            if ids.shape[0] == bucket and ids.dtype == jnp.int32:
                return ids                   # bucket-aligned: zero device ops
            return jnp.pad(ids.astype(jnp.int32),    # host round trip
                           (0, bucket - ids.shape[0]), constant_values=-1)
        out = np.full((bucket,), -1, np.int32)
        out[: len(ids)] = ids
        return jnp.asarray(out)

    def _pad_rows(self, rows, bucket: int) -> jax.Array:
        if isinstance(rows, jax.Array):
            if rows.shape[0] == bucket and rows.dtype == jnp.float32:
                return rows                  # bucket-aligned: zero device ops
            return jnp.pad(rows.astype(jnp.float32),
                           ((0, bucket - rows.shape[0]), (0, 0)))
        out = np.zeros((bucket, self.cfg.dim), np.float32)
        out[: len(rows)] = rows
        return jnp.asarray(out)

    def _pad_attrs(self, attrs: np.ndarray, bucket: int) -> jax.Array:
        # padding rows carry zeros; their ids are -1 so they never commit
        out = np.zeros((bucket, self.cfg.n_attrs), np.int32)
        out[: len(attrs)] = attrs
        return jnp.asarray(out)

    @staticmethod
    def _as_batch(x, np_dtype, flat: bool = False):
        """Host inputs -> numpy; ``jax.Array`` inputs stay on device."""
        if isinstance(x, jax.Array):
            return x.reshape(-1) if flat else x
        x = np.asarray(x, np_dtype)
        return x.reshape(-1) if flat else x

    # -- PQ training --------------------------------------------------------

    def train(self, xs, *, key=None, iters: int = 16) -> "Index":
        """Train the PQ codebooks from a sample (``cfg.pq`` required).

        Runs per-subspace k-means (``core.pq.train_pq``) and installs the
        codebooks into the device state (replicated to every shard on the
        mesh backend). Must happen on an *empty* index — stored codes
        would go stale under new codebooks — and before the first ``add``;
        alternatively pass pre-trained ``pq_codebooks=`` at construction.
        Returns ``self`` for chaining.
        """
        if self.cfg.pq is None:
            raise RuntimeError("train() needs SIVFConfig(pq=PQConfig(...))")
        if self.n_live:
            raise RuntimeError(
                "train() on a non-empty index: stored codes would go stale "
                "under new codebooks — train before the first add()")
        key = jax.random.key(0) if key is None else key
        cb = pqmod.train_pq(key, jnp.asarray(xs, jnp.float32),
                            self.cfg.pq.m, self.cfg.pq.nbits, iters=iters)
        if self._backend_kind == "mesh":
            from jax.sharding import NamedSharding, PartitionSpec as P
            stacked = jnp.broadcast_to(cb, (self.n_shards,) + cb.shape)
            cb = jax.device_put(
                stacked, NamedSharding(self._mesh, P(self._axis)))
        self._state = dataclasses.replace(self._state, pq_codebooks=cb)
        self._pq_trained = True
        return self

    # -- mutation -----------------------------------------------------------

    def _require_trained(self) -> None:
        if not self._pq_trained:
            raise RuntimeError(
                "PQ codebooks are untrained: call Index.train(sample) or "
                "construct with pq_codebooks= before adding vectors")

    def add(self, vecs, ids, *, attrs=None, strict: bool | None = None
            ) -> "MutationReport | PendingReport":
        """Ingest a batch. ``vecs [B, D]``, ``ids [B]`` (-1 rows skipped).

        Re-adding a live id overwrites its payload (paper delete-then-insert
        semantics); within-batch duplicate ids keep the last row. A batch
        that hits ``POOL_EXHAUSTED`` / ``CHAIN_OVERFLOW`` is atomic: it
        inserts nothing and every previously-live id keeps its old payload
        (per shard on the mesh backend). Inputs that are already
        ``jax.Array``s are padded device-side. In deferred mode this
        returns a :class:`PendingReport` without any host sync.

        With ``SIVFConfig(attributes=...)`` configured, ``attrs`` is
        **required** — either a ``{name: value_or_column}`` dict or a
        ``[B, n_attrs]`` int array in config order. Every configured
        attribute must be supplied (missing names raise): silently
        defaulting an attribute like ``tenant`` to 0 would leak rows into
        tenant 0's filtered results. Without configured attributes,
        passing ``attrs`` raises.
        """
        self._require_trained()
        vecs = self._as_batch(vecs, np.float32)
        ids_a = self._as_batch(ids, np.int32, flat=True)
        if vecs.ndim != 2 or vecs.shape[0] != ids_a.shape[0]:
            raise ValueError(
                f"vecs {vecs.shape} / ids {ids_a.shape} mismatch")
        if vecs.shape[1] != self.cfg.dim:
            raise ValueError(f"dim {vecs.shape[1]} != cfg.dim {self.cfg.dim}")
        if self.cfg.n_attrs:
            if attrs is None:
                raise ValueError(
                    f"index has attributes {self.cfg.attributes}: add() "
                    f"requires attrs= for every row (dict of per-attribute "
                    f"values or a [B, {self.cfg.n_attrs}] int array)")
            attrs_np = flt.normalize_attrs(self.cfg.attributes, attrs,
                                           int(ids_a.shape[0]))
        elif attrs is not None:
            raise ValueError(
                "attrs= given but SIVFConfig(attributes=...) is empty")
        bucket = self._bucket(ids_a.shape[0])
        with self._telemetry.span("mutation.dispatch", root="auto",
                                  op="add", epoch=self._epoch + 1):
            pv = self._pad_rows(vecs, bucket)
            pa = self._pad_attrs(attrs_np, bucket) if self.cfg.n_attrs \
                else None
            if self._tiered is not None:
                self._state, aux, plan = self._ops.insert(
                    self._state, pv, self._pad_ids(ids_a, bucket), pa)
                # queue the commit plan for the host-store replay; host
                # inputs ride along as-is (no transfer at drain), device
                # inputs as the padded device rows (fetched with the plan
                # in one device_get)
                self._tiered.queue_plan(
                    plan, vecs if isinstance(vecs, np.ndarray) else pv,
                    attrs_np if self.cfg.n_attrs else None)
            else:
                self._state, aux = self._ops.insert(
                    self._state, pv, self._pad_ids(ids_a, bucket), pa)
        if self._telemetry.enabled:
            self._m_mutations.inc(int(ids_a.shape[0]), op="add")
        return self._emit("add", aux, bucket, strict)

    def remove(self, ids, *, strict: bool | None = None
               ) -> "MutationReport | PendingReport":
        """Evict a batch of ids in O(1); absent ids count as ``rejected``."""
        ids_a = self._as_batch(ids, np.int32, flat=True)
        bucket = self._bucket(ids_a.shape[0])
        with self._telemetry.span("mutation.dispatch", root="auto",
                                  op="remove", epoch=self._epoch + 1):
            self._state, aux = self._ops.delete(
                self._state, self._pad_ids(ids_a, bucket))
        if self._telemetry.enabled:
            self._m_mutations.inc(int(ids_a.shape[0]), op="remove")
        return self._emit("remove", aux, bucket, strict)

    def _emit(self, op: str, aux: dict, bucket: int, strict: bool | None):
        self._epoch += 1          # batch dispatched: the committed prefix
        if self.deferred:         # a later search observes grows by one
            fut = PendingReport(self)
            self._pending.append((fut, op, aux, bucket, strict))
            return fut
        return self._finalize(op, _resolve_aux([aux])[0], bucket,
                              self.strict if strict is None else strict)

    def _finalize(self, op: str, aux: dict, bucket: int, strict: bool
                  ) -> MutationReport:
        """Build a report from an already-host-synced aux dict
        (``_resolve_aux`` is the only sync point)."""
        requested = int(aux["n_requested"])
        n0 = int(aux["n_live_before"])
        n1 = int(aux["n_live_after"])
        errors = ErrorCode(int(aux["errors"]))
        if op == "add":
            # overwrites are live-count-neutral and aborted shards restore
            # their state, so the net live delta is exactly the new ids
            overwritten = int(aux["n_overwritten"])
            accepted = max(n1 - n0, 0)
        else:
            overwritten = 0
            accepted = max(n0 - n1, 0)
        se = aux.get("shard_errors")
        report = MutationReport(
            op=op, requested=requested, accepted=accepted,
            overwritten=overwritten,
            rejected=max(requested - accepted - overwritten, 0),
            errors=errors, n_live=n1, padded_to=bucket,
            shard_errors=None if se is None else tuple(
                ErrorCode(int(e)) for e in np.asarray(se)))
        if strict and not report.ok:
            raise MutationRejected(report)
        return report

    def flush(self) -> list[MutationReport]:
        """Resolve every outstanding :class:`PendingReport`, oldest first.

        One host sync for the whole queue: every batch's aux scalars (and
        the mesh backend's per-shard error vectors) stack into a single
        flat int32 array and cross device->host in one ``jax.device_get``
        (``_resolve_aux``), however long the queue. In strict mode the
        first failed report raises :class:`MutationRejected` — after the
        entire queue has resolved, so no future is left dangling. No-op
        (``[]``) when nothing is pending.
        """
        pending, self._pending = self._pending, []
        with self._telemetry.span("mutation.flush", root="auto",
                                  batches=len(pending), epoch=self._epoch):
            if self._tiered is not None:  # host store catches up at the
                self._tiered.drain_plans()  # sync point reports resolve at
            reports: list[MutationReport] = []
            first_err: MutationRejected | None = None
            k = 0
            try:
                host_auxes = _resolve_aux([a for _, _, a, _, _ in pending])
                for k, (fut, op, _, bucket, strict) in enumerate(pending):
                    strict = self.strict if strict is None else strict
                    try:
                        rep = self._finalize(op, host_auxes[k], bucket,
                                             strict)
                    except MutationRejected as e:
                        rep = e.report
                        if first_err is None:
                            first_err = e
                    fut._resolved = rep
                    reports.append(rep)
            except BaseException:
                # an unexpected error (device failure, interrupt) mid-queue:
                # re-queue the unresolved tail so no future is orphaned
                self._pending = pending[k:] + self._pending
                raise
        self._note_compiles()
        if first_err is not None:
            raise first_err
        return reports

    def maintain(self, ops=None, *, max_ops: int = 2,
                 strict: bool | None = None) -> list:
        """Run background maintenance ops (``core/maintenance.py``).

        ``ops`` is a list of :class:`~repro.core.maintenance.MaintOp`
        (``split`` / ``merge`` / ``recluster``); omitted, the drift
        policy plans up to ``max_ops`` ops from the per-list occupancy
        counters in :meth:`stats`, round-robining re-clustering across
        sweeps. Each op commits atomically through the staged-insert
        path — on the mesh backend all shards revert together if any
        aborts — so a failed op leaves every live id searchable under
        the old layout and bumps no epoch. Committed ops bump
        :attr:`epoch` exactly like a mutation batch: a search dispatched
        afterwards observes the whole new layout, never a hybrid.

        Returns the per-op :class:`MaintenanceReport` list. In strict
        mode (``strict=True`` or the handle default) an aborted op
        raises :class:`MaintenanceAborted` after every op has resolved.
        """
        from repro.core import maintenance as mt
        self._require_trained()
        if self._tiered is not None:
            self._tiered.drain_plans()      # host store current pre-gather
        if ops is None:
            occ = self.stats()["list_occupancy"]
            ops, self._maint_cursor = mt.plan_ops(
                occ, self._maint_cursor, max_ops=max_ops)
        strict = self.strict if strict is None else strict
        stores = None if self._tiered is None else self._tiered.stores
        want_plan = self._tiered is not None
        reports: list[mt.MaintenanceReport] = []
        first_abort: mt.MaintenanceReport | None = None
        for op in ops:
            with self._telemetry.span("maintenance.op", root="auto",
                                      kind=op.kind, lists=list(op.lists),
                                      epoch=self._epoch + 1):
                views = mt.shard_views(self.cfg, self._state, stores)
                gathered = mt.gather_live(self.cfg, self._state, views,
                                          op.lists)
                cents = np.asarray(self._state.centroids, np.float32)
                if cents.ndim == 3:         # stacked per-shard replicas
                    cents = cents[0]
                plan = mt.plan_op(self.cfg, op, gathered, cents)
                if plan is None:            # nothing to move: host no-op
                    reports.append(mt.MaintenanceReport(
                        op.kind, op.lists, len(gathered["ids"]), True, 0,
                        self.n_live))
                    continue
                new_cents, lists = plan
                batch = mt.pad_batch(
                    self.cfg, gathered, lists,
                    mt.maint_batch_size(self.cfg, self.n_shards))
                if self._backend_kind == "mesh":
                    run = mt._commit_op_mesh(self.cfg, self._mesh,
                                             self._axis, want_plan)
                else:
                    run = mt._commit_op(self.cfg, want_plan)
                args = (self._state, jnp.asarray(new_cents),
                        jnp.asarray(batch["vecs"]),
                        jnp.asarray(batch["ids"]),
                        jnp.asarray(batch["lists"]),
                        None if batch["codes"] is None
                        else jnp.asarray(batch["codes"]),
                        None if batch["attrs"] is None
                        else jnp.asarray(batch["attrs"]))
                if want_plan:
                    self._state, aux, dev_plan = run(*args)
                else:
                    self._state, aux = run(*args)
                aux = {k: v for k, v in aux.items() if k != "shard_errors"}
                aux = jax.device_get(aux)
                committed = bool(int(aux["committed"]))
                if want_plan:
                    if committed:
                        self._tiered.queue_plan(
                            dev_plan, batch["vecs"],
                            batch["attrs"] if self.cfg.n_attrs else None)
                        self._tiered.drain_plans()
                    # centroid updates replicate into future prefetch
                    # plans automatically (they read self._state)
                rep = mt.MaintenanceReport(
                    op.kind, op.lists, batch["rows"], committed,
                    int(aux["errors"]), int(aux["n_live"]))
            if committed:
                self._epoch += 1            # a new committed prefix entry
                if self._telemetry.enabled:
                    self._m_maint_rows.inc(rep.rows)
            elif first_abort is None:
                first_abort = rep
            if self._telemetry.enabled:
                self._m_maint.inc(1, kind=op.kind,
                                  outcome="committed" if committed
                                  else "aborted")
            reports.append(rep)
        self._note_compiles()
        if strict and first_abort is not None:
            raise MaintenanceAborted(first_abort)
        return reports

    def __enter__(self) -> "Index":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is None:
            self.flush()
        return False

    # -- search -------------------------------------------------------------

    def search(self, queries, k: int, nprobe: int | None = None, *,
               filter=None, _prefetched=None) -> SearchResult:
        """Top-k search; ``nprobe=None`` probes every list (exact recall).

        ``jax.Array`` queries are padded device-side (no host round trip).

        ``filter`` is a :mod:`repro.core.filters` predicate (``Eq`` /
        ``In`` / ``Range`` / ``And``) over the configured attributes — or
        an already-:func:`~repro.core.filters.compile_filter`-ed
        ``CompiledFilter`` (the serve engine pre-compiles to coalesce).
        Only rows matching it can appear in the result (non-matching slots
        mask to ``inf`` / ``-1`` *inside* the scan, before top-k, so they
        never displace passing candidates). The predicate *structure* is a
        static jit key while its constants are traced operands — searching
        ``Eq("tenant", 3)`` then ``Eq("tenant", 7)`` compiles once.
        """
        queries = self._as_batch(queries, np.float32)
        if queries.ndim == 1:
            queries = queries[None]
        if queries.shape[1] != self.cfg.dim:
            raise ValueError(
                f"dim {queries.shape[1]} != cfg.dim {self.cfg.dim}")
        fstruct = fconsts = None
        if filter is not None:
            if not self.cfg.n_attrs:
                raise ValueError(
                    "filtered search needs SIVFConfig(attributes=...)")
            cf = filter if isinstance(filter, flt.CompiledFilter) \
                else flt.compile_filter(filter, self.cfg.attributes)
            fstruct = cf.structure
            fconsts = jnp.asarray(cf.consts, jnp.int32)
        nprobe = self.cfg.n_lists if nprobe is None \
            else min(int(nprobe), self.cfg.n_lists)
        q = queries.shape[0]
        bucket = self._bucket(q)
        padded = self._pad_rows(queries, bucket)
        with self._telemetry.span("index.search", root="auto",
                                  epoch=self._epoch,
                                  filter=None if fstruct is None
                                  else str(fstruct)):
            if self._tiered is not None:
                # three-stage tiered path: plan (probe->slab table),
                # prefetch (make probed slabs cache-resident), frame-
                # translated scan. A valid ``_prefetched`` ticket
                # (Index.prefetch) skips the first two stages; a stale one
                # falls back transparently.
                d, lab = self._tiered.search(
                    self._state, padded, int(k), nprobe, fstruct, fconsts,
                    epoch=self._epoch, ticket=_prefetched)
            else:
                d, lab = self._ops.search(self._state, padded, int(k),
                                          nprobe, fstruct, fconsts)
        self._note_compiles()
        return SearchResult(distances=d[:q], labels=lab[:q], k=int(k),
                            nprobe=nprobe, padded_to=bucket)

    def prefetch(self, queries, nprobe: int | None = None):
        """Stage the slabs a coming query batch will probe (tiered only).

        Runs the plan + prefetch stages of the tiered search and returns
        an opaque ticket for ``search(..., _prefetched=ticket)``, letting
        a scheduler overlap the next tile's host->device uploads with the
        current tile's kernel execution (the serve engine does exactly
        this). The ticket is valid until the next prefetch or mutation;
        passing a stale ticket — or calling with the same queries and no
        ticket at all — is always safe, merely un-overlapped. Returns
        ``None`` on an untiered handle.
        """
        if self._tiered is None:
            return None
        queries = self._as_batch(queries, np.float32)
        if queries.ndim == 1:
            queries = queries[None]
        nprobe = self.cfg.n_lists if nprobe is None \
            else min(int(nprobe), self.cfg.n_lists)
        padded = self._pad_rows(queries, self._bucket(queries.shape[0]))
        table = self._tiered.plan(self._state, padded, nprobe)
        return self._tiered.prefetch(table, nprobe, self._epoch)

    # -- persistence --------------------------------------------------------

    _META = "index"

    def save(self, path) -> None:
        """Persist the index (atomic + checksummed via CheckpointManager)."""
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(path, keep_last=1)
        cfg = dataclasses.asdict(self.cfg)   # nested PQConfig -> plain dict
        cfg["dtype"] = np.dtype(self.cfg.dtype).name
        mgr.save_metadata(self._META, {
            "format": 3,
            "pq_trained": self._pq_trained,
            "backend": self._backend_kind,
            "n_shards": self.n_shards,
            # self-describing shard routing: any loader (this class, or a
            # future external tool) can re-route rows onto a different
            # shard count knowing only the sidecar
            "routing": {"rule": "mod", "n_shards": self.n_shards,
                        "axis": self._axis},
            "axis": self._axis,
            "impl": self._impl,
            "block_q": self._block_q,
            "use_tables": self._use_tables,
            "strict": self.strict,
            "min_bucket": self.min_bucket,
            "deferred": self.deferred,
            "cfg": cfg,
        })
        state = self._state
        if self._tiered is not None:
            # residency is runtime-only: checkpoints always store the
            # assembled full-pool planes, so the on-disk format (3) is
            # identical to an untiered save and loads onto either mode
            from repro.core import tiered as trt
            self._tiered.drain_plans()
            state = trt.assemble_full(self.cfg, self._state,
                                      self._tiered.stores)
        mgr.save(0, state)

    @classmethod
    def load(cls, path, backend=None, **overrides) -> "Index":
        """Rebuild a handle from :meth:`save` output — onto *any* backend.

        Loading is **elastic**: a checkpoint saved on S shards loads onto
        S' shards (grow, shrink, mesh->single, single->mesh). When the
        target topology matches the checkpoint, leaves restore directly
        onto their devices; otherwise the slab pools are flattened to the
        canonical live-row table and re-routed by ``id % n_shards'``
        (``core.distributed.reshard_state``) — searches return identical
        ids and distances either way, and later inserts land on the owning
        shard.

        ``backend`` is a ``jax.sharding.Mesh`` or ``"single"``. Defaults:
        a single-device checkpoint loads as ``"single"``; a sharded
        checkpoint requires an explicit target (pass ``"single"`` to
        collapse the shards onto one device). Keyword ``overrides``
        replace any saved handle option (impl, strict, ...).
        """
        from repro.checkpoint.manager import CheckpointManager
        from repro.core import distributed as dist
        mgr = CheckpointManager(path)
        meta = mgr.load_metadata(cls._META)
        cfg_d = dict(meta["cfg"])
        cfg_d["dtype"] = jnp.dtype(cfg_d["dtype"])
        if cfg_d.get("pq") is not None:
            cfg_d["pq"] = PQConfig(**cfg_d["pq"])
        cfg = SIVFConfig(**cfg_d)
        if "device_slabs" in overrides:
            # retier on load: any checkpoint loads tiered (or back to
            # all-resident with device_slabs=None) — the stored planes are
            # the same full pool either way
            cfg = dataclasses.replace(
                cfg, device_slabs=overrides.pop("device_slabs"))
        kw = {"axis": meta["axis"], "impl": meta["impl"],
              "block_q": meta["block_q"], "use_tables": meta["use_tables"],
              "strict": meta["strict"], "min_bucket": meta["min_bucket"],
              "deferred": meta.get("deferred", False)}
        kw.update(overrides)
        src_kind = meta["backend"]
        src_shards = int(meta["n_shards"])
        # pre-routing checkpoints (PR 2-4) used the same implicit mod rule
        rule = meta.get("routing", {}).get("rule", "mod")
        if rule != "mod":
            raise ValueError(
                f"checkpoint uses unknown shard-routing rule {rule!r}; "
                f"this build can only re-route 'mod' checkpoints")
        if backend is None:
            if src_kind == "mesh":
                raise ValueError(
                    "sharded checkpoint: pass backend= — the target mesh, "
                    "or 'single' to collapse the shards onto one device")
            backend = "single"
        tgt_kind, n_to = _resolve_backend(backend, kw["axis"])
        step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint steps under {path}")
        if cfg.tiered:
            # tiered target: the payload planes must never be device_put
            # whole, so always take the host restore path (an untiered
            # example tree — checkpoints store the full pool) and hand the
            # full host state to __init__, which splits it into the host
            # store + meta device state
            cfg_full = dataclasses.replace(cfg, device_slabs=None)
            example = jax.eval_shape(lambda: init_state(
                cfg_full, jnp.zeros((cfg.n_lists, cfg.dim), cfg.dtype)))
            if src_kind == "mesh":
                example = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct((src_shards,) + x.shape,
                                                   x.dtype), example)
            leaves, treedef = jax.tree.flatten(example)
            n_miss = {1: 3, 2: 1}.get(int(meta.get("format", 1)), 0)
            out = mgr.restore_arrays(step)
            if n_miss:
                out = out + [np.zeros(x.shape, x.dtype)
                             for x in leaves[-n_miss:]]
            if len(out) != len(leaves):
                raise ValueError(
                    f"checkpoint stored {len(out)} leaves but the "
                    f"{src_shards}-shard state needs {len(leaves)}")
            host_state = jax.tree.unflatten(treedef, out)
            if not (tgt_kind == src_kind and n_to == src_shards):
                host_state = dist.reshard_state(cfg_full, host_state,
                                                src_shards, n_to,
                                                stack=tgt_kind == "mesh")
            return cls(cfg, None, backend=backend, _state=host_state,
                       _pq_trained=meta.get("pq_trained", True), **kw)
        # abstract example tree: restore needs only structure/shapes, so no
        # throwaway zero pool is ever allocated next to the restored one
        example = jax.eval_shape(lambda: init_state(
            cfg, jnp.zeros((cfg.n_lists, cfg.dim), cfg.dtype)))
        if src_kind == "mesh":
            example = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct((src_shards,) + x.shape,
                                               x.dtype), example)
        leaves, treedef = jax.tree.flatten(example)
        # older checkpoints predate trailing slab planes, which are by
        # design the LAST registered data fields so a legacy manifest
        # restores into the leaf prefix and the missing planes fill fresh:
        # format 1 lacks ``codes`` / ``pq_codebooks`` / ``attrs`` (all
        # zero-width: format 1 implies cfg.pq=None and no attributes),
        # format 2 lacks only ``attrs``
        n_miss = {1: 3, 2: 1}.get(int(meta.get("format", 1)), 0)
        if tgt_kind == src_kind and n_to == src_shards:
            # topology match: restore leaves straight onto their devices
            shard = None
            if tgt_kind == "mesh":
                from jax.sharding import NamedSharding, PartitionSpec as P
                shard = NamedSharding(backend, P(kw["axis"]))
            want = leaves[:-n_miss] if n_miss else leaves
            out = list(mgr.restore(
                step, want,
                sharding_tree=None if shard is None else [shard] * len(want)))
            if n_miss:
                fill = [jnp.zeros(x.shape, x.dtype) for x in leaves[-n_miss:]]
                if shard is not None:
                    fill = [jax.device_put(f, shard) for f in fill]
                out += fill
            state = jax.tree.unflatten(treedef, out)
        else:
            # elastic reshard: manifest-described host restore, pure
            # re-route, then placement onto the target backend
            out = mgr.restore_arrays(step)
            if n_miss:
                out = out + [np.zeros(x.shape, x.dtype)
                             for x in leaves[-n_miss:]]
            if len(out) != len(leaves):
                raise ValueError(
                    f"checkpoint stored {len(out)} leaves but the "
                    f"{src_shards}-shard state needs {len(leaves)}")
            host_state = jax.tree.unflatten(treedef, out)
            state = dist.reshard_state(cfg, host_state, src_shards, n_to,
                                       stack=tgt_kind == "mesh")
            if tgt_kind == "mesh":
                state = dist.place_sharded(state, backend, kw["axis"])
        return cls(cfg, None, backend=backend, _state=state,
                   _pq_trained=meta.get("pq_trained", True), **kw)

    def reshard(self, backend="single", *, axis: str | None = None
                ) -> "Index":
        """Elastically remap this *live* handle onto a new backend in place.

        ``backend`` is a ``jax.sharding.Mesh`` (any shard count) or
        ``"single"``. Pending deferred reports are flushed first (their
        counts reference the pre-reshard shard topology), then the slab
        pools flatten to the canonical live-row table, re-route by
        ``id % n_shards'`` and rebuild on the target — the same pure
        ``core.distributed.reshard_state`` path :meth:`load` uses, so
        search results are identical before and after and subsequent
        mutations land on the owning shard. Returns ``self``.
        """
        with self._telemetry.span("reshard", root="auto",
                                  n_from=self.n_shards):
            return self._reshard_impl(backend, axis)

    def _reshard_impl(self, backend, axis):
        from repro.core import distributed as dist
        self.flush()
        axis = self._axis if axis is None else axis
        tgt_kind, n_to = _resolve_backend(backend, axis)
        if self._tiered is not None:
            # assemble the canonical full pool (host planes + device
            # metadata) and reshard under the untiered twin config — the
            # reshard machinery only ever sees full-width states
            from repro.core import tiered as trt
            cfg_r = dataclasses.replace(self.cfg, device_slabs=None)
            host = trt.assemble_full(self.cfg, self._state,
                                     self._tiered.stores)
        else:
            cfg_r = self.cfg
            host = jax.tree.map(np.asarray, self._state)   # device -> host
        state = dist.reshard_state(cfg_r, host, self.n_shards, n_to,
                                   stack=tgt_kind == "mesh")
        stores = None
        if self._tiered is not None:
            meta, stores = trt.split_full(self.cfg, state)
            state = meta if tgt_kind == "mesh" \
                else jax.tree.map(jnp.asarray, meta)
        if tgt_kind == "mesh":
            state = dist.place_sharded(state, backend, axis)
            self._ops = _mesh_ops(self.cfg, backend, axis, self._impl,
                                  self._block_q, self._use_tables)
            self._mesh = backend
        else:
            self._ops = _single_ops(self.cfg, self._impl, self._block_q,
                                    self._use_tables)
            self._mesh = None
        self._backend_kind = tgt_kind
        self._axis = axis
        self._state = state
        if self._tiered is not None:
            from repro.core import tiered as trt
            # rebuild the runtime for the new topology but CARRY the
            # cumulative cache counters (and their window marks): before
            # ISSUE 9 a reshard silently zeroed hit_rate's history
            self._tiered = trt.TieredRuntime(
                self.cfg, tgt_kind, mesh=self._mesh, axis=axis,
                impl=self._impl, block_q=self._block_q,
                use_tables=self._use_tables, n_shards=n_to, stores=stores,
                telemetry=self._telemetry).carry_from(self._tiered)
        return self
