"""Online index maintenance under drift: split / merge / re-cluster.

Streaming ingest drifts away from the trained coarse quantizer: chains
skew, recall decays (PAPERS.md, "Incremental IVF Index Maintenance for
Streaming Vector Search"). This module is the in-place twin of the
reshard machinery — instead of flattening the whole pool through the
host, each maintenance op touches only the affected lists:

  * **split**  — a skewed list's live rows are re-partitioned by a local
    deterministic 2-means *trained on the skewed list's rows alone* (a
    far-off victim cluster must not capture one of the two sides); the
    refined centroids land on the skewed list and a near-empty victim
    list, and the union of both lists' rows re-routes to the nearer of
    the pair (``n_lists`` is a static shape, so a split recycles an
    existing slot instead of growing the plane);
  * **merge**  — two under-full lists collapse onto ``min(a, b)``; both
    centroid rows become the occupancy-weighted mean, so the coarse
    quantizer's stable argmin routes all future traffic to the target
    while the source drains to empty;
  * **recluster** — a drifted list's centroid is recentered on the mean
    of its live rows and the rows are re-inserted (which also compacts
    the chain).

Every op is the same three-phase pipeline: a host-side gather of the
affected lists' live rows (payloads from the device planes, or from the
tiered host store), host-side centroid refinement in numpy, then ONE
atomic device batch through ``index._insert_impl`` — staged state with
the *new* centroids plus a single ``lax.cond`` commit. A failed op
(pool exhausted / chain overflow) therefore leaves every live id
searchable under the *old* centroids: searches observe the old or the
new list layout, never a hybrid. Stored PQ codes ride the re-insert
verbatim (byte-for-byte, exactly like elastic resharding).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as ix
from repro.core.state import (
    ERR_CHAIN_OVERFLOW,
    ERR_POOL_EXHAUSTED,
    SIVFConfig,
    SlabPoolState,
    clear_error,
    host_live_mask,
)

ABORT_BITS = ERR_POOL_EXHAUSTED | ERR_CHAIN_OVERFLOW

KINDS = ("split", "merge", "recluster")


@dataclasses.dataclass(frozen=True)
class MaintOp:
    """One maintenance operation over one or two lists."""

    kind: str                    # split | merge | recluster
    lists: tuple[int, ...]       # split/merge: (a, b); recluster: (a,)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown maintenance kind {self.kind!r}")
        want = 1 if self.kind == "recluster" else 2
        if len(self.lists) != want:
            raise ValueError(
                f"{self.kind} takes {want} list(s), got {self.lists}")
        if len(set(self.lists)) != len(self.lists):
            raise ValueError(f"{self.kind} lists must be distinct")


def split(a: int, victim: int) -> MaintOp:
    return MaintOp("split", (int(a), int(victim)))


def merge(a: int, b: int) -> MaintOp:
    return MaintOp("merge", (int(a), int(b)))


def recluster(a: int) -> MaintOp:
    return MaintOp("recluster", (int(a),))


@dataclasses.dataclass(frozen=True)
class MaintenanceReport:
    """Outcome of one committed-or-aborted maintenance op."""

    kind: str
    lists: tuple[int, ...]
    rows: int                    # live rows gathered / re-inserted
    committed: bool              # False: state unchanged (atomic abort)
    errors: int                  # raw error bits from the commit attempt
    n_live: int                  # pool live count after the op


# ---------------------------------------------------------------------------
# Host-side gather
# ---------------------------------------------------------------------------

def shard_views(cfg: SIVFConfig, state: SlabPoolState, stores=None) -> list:
    """Per-shard host views of the planes the gather needs.

    ``state`` may be a single-device pool or the stacked per-shard state;
    ``stores`` (tiered) supplies the payload planes when the device ones
    are zero-width. Returns one dict per shard of numpy arrays.
    """
    owner = np.asarray(state.owner)
    stacked = owner.ndim == 2
    n_shards = owner.shape[0] if stacked else 1
    if cfg.tiered and stores is None:
        raise ValueError("tiered config: maintenance gather needs the "
                         "host stores (pass stores=runtime.stores)")
    views = []
    for s in range(n_shards):
        pick = (lambda x: np.asarray(x)[s]) if stacked else \
            (lambda x: np.asarray(x))
        v = {"owner": pick(state.owner), "bitmap": pick(state.bitmap),
             "ids": pick(state.ids)}
        if cfg.tiered:
            st = stores[s]
            v["data"], v["codes"], v["attrs"] = st.data, st.codes, st.attrs
        else:
            v["data"] = pick(state.data)
            v["codes"] = pick(state.codes)
            v["attrs"] = pick(state.attrs)
        views.append(v)
    return views


# ---------------------------------------------------------------------------
# Centroid refinement (host numpy; deterministic)
# ---------------------------------------------------------------------------

def _kmeans2(x: np.ndarray, iters: int = 8) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic local 2-means: farthest-point init + Lloyd."""
    mean = x.mean(axis=0)
    c0 = x[int(np.argmax(((x - mean) ** 2).sum(-1)))]
    c1 = x[int(np.argmax(((x - c0) ** 2).sum(-1)))]
    cents = np.stack([c0, c1])
    for _ in range(iters):
        d = ((x[:, None] - cents[None]) ** 2).sum(-1)    # [N, 2]
        assign = d.argmin(axis=1)
        for j in (0, 1):
            sel = x[assign == j]
            if len(sel):
                cents[j] = sel.mean(axis=0)
    return cents.astype(np.float32), assign


def _route2(vecs: np.ndarray, cents2: np.ndarray, metric: str) -> np.ndarray:
    """Index (0/1) of the nearer of two centroids under the index metric."""
    if metric == "ip":
        scores = vecs @ cents2.T                         # higher = nearer
        return scores.argmax(axis=1)
    d = ((vecs[:, None] - cents2[None]) ** 2).sum(-1)
    return d.argmin(axis=1)


def plan_op(cfg: SIVFConfig, op: MaintOp, gathered: dict,
            centroids: np.ndarray) -> tuple[np.ndarray, np.ndarray] | None:
    """Host planning: -> (new centroids [n_lists, D], per-row routing [N]).

    ``None`` means the op is a no-op on the current state (nothing to
    move, no centroid change) and no device commit should run.
    """
    vecs, n = gathered["vecs"], len(gathered["ids"])
    new_cents = np.array(centroids, np.float32, copy=True)
    if op.kind == "recluster":
        (a,) = op.lists
        if n == 0:
            return None
        new_cents[a] = vecs.mean(axis=0)
        return new_cents, np.full((n,), a, np.int32)
    a, b = op.lists
    if op.kind == "merge":
        tgt = min(a, b)
        if n == 0:
            return None
        # both rows become the merged mean: the quantizer's stable argmin
        # ties toward min(a, b), so future inserts route to the target
        # while the source stays empty
        new_cents[a] = new_cents[b] = vecs.mean(axis=0)
        return new_cents, np.full((n,), tgt, np.int32)
    # split: the 2-means is trained on the skewed list's own rows (if the
    # victim holds rows of some distant cluster, a union fit would park
    # one centroid on the victim and leave the glued pair glued); the
    # union of both lists' rows then re-routes to the nearer of the pair
    if n < 2:
        return None
    hot = vecs[gathered["lists"] == a]
    cents2, _ = _kmeans2(hot if len(hot) >= 2 else vecs)
    new_cents[a], new_cents[b] = cents2[0], cents2[1]
    route = _route2(vecs, cents2, cfg.metric)
    return new_cents, np.where(route == 0, a, b).astype(np.int32)


# ---------------------------------------------------------------------------
# Batch padding: one executable per config
# ---------------------------------------------------------------------------

def maint_batch_size(cfg: SIVFConfig, n_shards: int = 1) -> int:
    """Fixed pad width for maintenance batches (one jit executable).

    An op touches at most two lists; each list owns at most ``max_chain``
    slabs of ``capacity`` rows per shard — that product is the hard upper
    bound on gathered rows, clamped to the id space.
    """
    hard = 2 * cfg.max_chain * cfg.capacity * n_shards
    b = min(hard, cfg.n_max)
    p = 1
    while p < b:
        p <<= 1
    return p


def pad_batch(cfg: SIVFConfig, gathered: dict, lists: np.ndarray,
              width: int) -> dict:
    """-1-padded fixed-width arrays (padding rows set no error bits)."""
    n = len(gathered["ids"])
    if n > width:
        raise AssertionError(
            f"maintenance gather ({n} rows) exceeds the chain-bound batch "
            f"width ({width}) — max_chain accounting is broken")
    ids = np.full((width,), -1, np.int32)
    ids[:n] = gathered["ids"]
    vecs = np.zeros((width, cfg.dim), np.float32)
    vecs[:n] = gathered["vecs"]
    lst = np.zeros((width,), np.int32)
    lst[:n] = lists
    out = {"ids": ids, "vecs": vecs, "lists": lst, "codes": None,
           "attrs": None, "rows": n}
    if cfg.code_m:
        codes = np.zeros((width, cfg.code_m), np.uint8)
        codes[:n] = gathered["codes"]
        out["codes"] = codes
    if cfg.n_attrs:
        attrs = np.zeros((width, cfg.n_attrs), np.int32)
        attrs[:n] = gathered["attrs"]
        out["attrs"] = attrs
    return out


# ---------------------------------------------------------------------------
# Atomic device commit (single-device; the mesh twin lives in distributed)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _commit_op(cfg: SIVFConfig, want_plan: bool):
    """jit'd: staged re-insert under the NEW centroids, single commit point.

    ``_insert_impl``'s fail branch returns its *input* — here the staged
    state that already carries the new centroids — so the outer ``where``
    restores the old centroid plane on abort: an aborted op changes
    nothing observable.
    """
    use_codes = cfg.pq is not None
    use_attrs = cfg.n_attrs > 0

    @partial(jax.jit, donate_argnums=(0,))
    def run(state, new_cents, vecs, ids, lists, codes, attrs):
        st0 = clear_error(state)
        staged = dataclasses.replace(st0, centroids=new_cents)
        out = ix._insert_impl(cfg, staged, vecs, ids, lists,
                              codes=codes if use_codes else None,
                              attrs=attrs if use_attrs else None,
                              want_plan=want_plan)
        st, plan = out if want_plan else (out, None)
        aborted = (st.error & ABORT_BITS) != 0
        st = dataclasses.replace(
            st, centroids=jnp.where(aborted, st0.centroids, new_cents))
        aux = {"errors": st.error,
               "committed": (~aborted).astype(jnp.int32),
               "n_live": st.n_live}
        st = clear_error(st)
        return (st, aux, plan) if want_plan else (st, aux)

    return run


@lru_cache(maxsize=None)
def _commit_op_mesh(cfg: SIVFConfig, mesh, axis: str, want_plan: bool):
    """jit'd mesh twin of ``_commit_op`` (``distributed.sharded_maintain``).

    The shards vote on the outcome inside the mapped body (any abort
    reverts every shard), so the stacked result is already consistent;
    this wrapper just folds the per-shard error vector into the same aux
    shape the single-device path emits.
    """
    from repro.core import distributed as dist
    inner = dist.sharded_maintain(cfg, mesh, axis, want_plan)
    use_codes = cfg.pq is not None
    use_attrs = cfg.n_attrs > 0

    @partial(jax.jit, donate_argnums=(0,))
    def run(state, new_cents, vecs, ids, lists, codes, attrs):
        out = inner(state, new_cents, vecs, ids, lists,
                    codes if use_codes else None,
                    attrs if use_attrs else None)
        if want_plan:
            st, errs, plan = out
        else:
            (st, errs), plan = out, None
        aborted = jnp.any((errs & ABORT_BITS) != 0)
        bits = jnp.zeros((), errs.dtype)
        for s in range(errs.shape[0]):
            bits = bits | errs[s]
        aux = {"errors": bits,
               "committed": (~aborted).astype(jnp.int32),
               "n_live": jnp.sum(st.n_live),
               "shard_errors": errs}
        return (st, aux, plan) if want_plan else (st, aux)

    return run


def maintain(cfg: SIVFConfig, state: SlabPoolState, op: MaintOp,
             stores=None) -> tuple[SlabPoolState, MaintenanceReport]:
    """Functional single-device maintenance: run one op atomically.

    The session layer (``Index.maintain``) wraps this with sharding,
    tiered-store replay and telemetry; this entry point is the property-
    testable core. Returns the (possibly unchanged) state + a report.
    """
    views = shard_views(cfg, state, stores)
    gathered = gather_live(cfg, state, views, op.lists)
    plan = plan_op(cfg, op, gathered, np.asarray(state.centroids))
    if plan is None:
        return state, MaintenanceReport(op.kind, op.lists,
                                        len(gathered["ids"]), True, 0,
                                        int(state.n_live))
    new_cents, lists = plan
    batch = pad_batch(cfg, gathered, lists, maint_batch_size(cfg))
    run = _commit_op(cfg, want_plan=bool(cfg.tiered))
    args = (state, jnp.asarray(new_cents), jnp.asarray(batch["vecs"]),
            jnp.asarray(batch["ids"]), jnp.asarray(batch["lists"]),
            None if batch["codes"] is None else jnp.asarray(batch["codes"]),
            None if batch["attrs"] is None else jnp.asarray(batch["attrs"]))
    if cfg.tiered:
        st, aux, dev_plan = run(*args)
        replay_plan_to_store(cfg, stores[0], dev_plan, batch["vecs"],
                             batch["attrs"])
    else:
        st, aux = run(*args)
    rep = MaintenanceReport(op.kind, op.lists, batch["rows"],
                            bool(int(aux["committed"])), int(aux["errors"]),
                            int(aux["n_live"]))
    return st, rep


def replay_plan_to_store(cfg: SIVFConfig, store, plan, vecs, attrs) -> None:
    """Mirror a commit plan into one shard's host store (tiered pools).

    The device plan names exactly the payload writes the commit applied
    (-1 rows — padding, unowned, or a whole aborted batch — write
    nothing), so the two tiers stay bit-identical without transferring
    the payload planes. The session layer routes through
    ``TieredRuntime.queue_plan`` instead (same replay + dirty tracking).
    """
    slab = np.asarray(plan["slab"])
    rows = np.flatnonzero(slab >= 0)
    if not len(rows):
        return
    slot = np.asarray(plan["slot"])
    if cfg.payload_dim:
        store.data[slab[rows], slot[rows]] = \
            np.asarray(vecs)[rows, :cfg.payload_dim]
    if cfg.code_m:
        store.codes[slab[rows], slot[rows]] = np.asarray(plan["codes"])[rows]
    if cfg.n_attrs:
        store.attrs[slab[rows], slot[rows]] = np.asarray(attrs)[rows]


def gather_live(cfg: SIVFConfig, state: SlabPoolState, views: list,
                target_lists) -> dict:
    """``gather_rows`` + PQ-decode fallback for raw-payload-free configs."""
    tl = np.asarray(sorted(target_lists), np.int32)
    ids_parts, vec_parts, code_parts, attr_parts = [], [], [], []
    list_parts = []
    for v in views:
        mask_slab = np.isin(v["owner"], tl)
        live = host_live_mask(cfg, v["bitmap"])
        si, so = np.nonzero(live & mask_slab[:, None])
        ids_parts.append(v["ids"][si, so].astype(np.int32))
        list_parts.append(v["owner"][si].astype(np.int32))
        if cfg.payload_dim:
            vec_parts.append(np.asarray(v["data"][si, so]))
        if cfg.code_m:
            code_parts.append(np.asarray(v["codes"][si, so]))
        if cfg.n_attrs:
            attr_parts.append(np.asarray(v["attrs"][si, so]))
    ids = (np.concatenate(ids_parts) if ids_parts
           else np.zeros((0,), np.int32)).astype(np.int32)
    order = np.argsort(ids, kind="stable")
    ids = ids[order]
    src_lists = (np.concatenate(list_parts)[order].astype(np.int32)
                 if list_parts else np.zeros((0,), np.int32))
    codes = (np.concatenate(code_parts)[order].astype(np.uint8)
             if cfg.code_m and code_parts else
             (np.zeros((0, cfg.code_m), np.uint8) if cfg.code_m else None))
    attrs = (np.concatenate(attr_parts)[order].astype(np.int32)
             if cfg.n_attrs and attr_parts else
             (np.zeros((0, cfg.n_attrs), np.int32) if cfg.n_attrs else None))
    if cfg.payload_dim:
        vecs = (np.concatenate(vec_parts)[order]
                if vec_parts else np.zeros((0, cfg.dim), np.float32))
        vecs = np.asarray(vecs, np.float32)[:, :cfg.dim]
    else:
        # PQ without store_raw: reconstruct stand-in vectors from the
        # stored codes. Search is pure-ADC over the codes (which ride the
        # re-insert verbatim); the stand-ins only feed the unused norm
        # plane and the centroid means.
        cb = np.asarray(state.pq_codebooks, np.float32)  # [m, K, dsub]
        if cb.ndim == 4:                # stacked per-shard replicas
            cb = cb[0]
        m = cb.shape[0]
        if len(ids):
            c = codes.astype(np.int64)                   # [N, m]
            vecs = cb[np.arange(m)[None, :], c].reshape(len(ids), cfg.dim)
            vecs = vecs.astype(np.float32)
        else:
            vecs = np.zeros((0, cfg.dim), np.float32)
    return {"ids": ids, "vecs": vecs, "codes": codes, "attrs": attrs,
            "lists": src_lists}


# ---------------------------------------------------------------------------
# Drift-triggered policy
# ---------------------------------------------------------------------------

def plan_ops(list_occupancy, cursor: int = 0, max_ops: int = 2,
             skew_hi: float = 2.0, skew_lo: float = 0.25
             ) -> tuple[list[MaintOp], int]:
    """Occupancy-driven maintenance schedule (reads ``stats()`` counters).

    Priority: (1) split the most-skewed list into a near-empty victim,
    (2) merge the two most under-full lists, then (3) round-robin
    recluster from ``cursor`` — so sustained drift recenters every list
    over successive sweeps. Returns (ops, advanced cursor).
    """
    occ = np.asarray(list_occupancy, np.int64)
    nl = len(occ)
    ops: list[MaintOp] = []
    mean = float(occ.mean()) if nl else 0.0
    used = set()
    if nl >= 2 and mean > 0:
        hot = int(occ.argmax())
        cold = int(occ.argmin())
        if (occ[hot] > skew_hi * mean and occ[cold] < skew_lo * mean
                and hot != cold and len(ops) < max_ops):
            ops.append(split(hot, cold))
            used.update((hot, cold))
        small = [i for i in np.argsort(occ, kind="stable")
                 if i not in used and occ[i] > 0]
        if (len(small) >= 2 and occ[small[0]] < skew_lo * mean
                and occ[small[1]] < skew_lo * mean and len(ops) < max_ops):
            ops.append(merge(int(small[0]), int(small[1])))
            used.update((int(small[0]), int(small[1])))
    for _ in range(nl):
        if len(ops) >= max_ops:
            break
        cand = cursor % max(nl, 1)
        cursor += 1
        if cand not in used and occ[cand] > 0:
            ops.append(recluster(cand))
            used.add(cand)
    return ops, cursor % max(nl, 1)
