"""Distributed SIVF: shared-nothing data sharding + scatter-gather (paper §4.2).

The paper's 12-GPU MPI architecture maps 1:1 onto ``jax.shard_map`` over a
mesh axis:

  * **Data sharding** — each shard owns a disjoint id range via deterministic
    ``id % n_shards`` routing (the paper's round-robin/hash routing). Every
    shard keeps its *own* SlabPoolState; the global state is the stack of
    per-shard states along a leading axis sharded on ``axis_name``.
  * **Ingestion** — the batch is broadcast; each shard masks to its owned
    ids and ingests locally (no cross-shard sync, hence the paper's linear
    ingestion scaling).
  * **Search (scatter-gather)** — queries are broadcast; each shard searches
    its local shard; partial top-k are all-gathered and merged (the paper's
    MPI_Gather / tree reduction).
  * **Deletion** — broadcast; ids live on exactly one shard, others no-op
    (paper: "the target ID exists on at most one worker").
  * **Per-shard atomicity** — each shard runs the all-or-nothing insert of
    ``core.index``: a shard that hits POOL_EXHAUSTED / CHAIN_OVERFLOW
    keeps its previously-live ids (old payloads included) and raises only
    its own ``error`` bits, while sibling shards commit normally. The
    stacked ``state.error`` vector is therefore the per-shard truth that
    ``sivf.Index`` surfaces as ``MutationReport.shard_errors`` — eagerly
    or deferred, the accounting never has to guess which rows survived.

  * **Elastic resharding** — :func:`reshard_state` remaps an index saved
    on S shards onto S' shards (grow, shrink, mesh<->single) *without a
    rebuild from raw data*: the per-shard slab pools flatten to one
    canonical id-sorted table of live rows, rows re-route by the same
    ``id % n_shards'`` rule ``sharded_insert`` uses (so post-reshard
    inserts land on the owning shard), and each target shard's chains /
    bitmaps / ATT / centroid replicas are rebuilt through the existing
    ``init_state`` + insert path. Searches before vs. after resharding
    return identical ids and distances (docs/architecture.md §Resharding).

The ``sharded_*`` builders return the raw shard-mapped callables; they are
the single code path behind both the legacy ``dist_*`` free functions and
the ``sivf.Index`` mesh backend (``core/api.py``), which wraps them in jit
with buffer donation, shape-bucketed batches, and (in deferred mode)
device-resident report aux that only syncs at ``Index.flush()``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import index as ix
from repro.core import pq as pqmod
from repro.core.state import (
    SIVFConfig,
    SlabPoolState,
    host_live_mask,
    init_state,
)
from repro.utils import shard_map_compat


def shard_of(ids: jax.Array, n_shards: int) -> jax.Array:
    """Deterministic owner shard for each external id."""
    return jnp.where(ids >= 0, ids % n_shards, -1)


def init_sharded_state(cfg: SIVFConfig, centroids: jax.Array, mesh: Mesh,
                       axis: str = "data",
                       pq_codebooks: jax.Array | None = None
                       ) -> SlabPoolState:
    """Per-shard empty states stacked on a leading sharded axis.

    ``pq_codebooks`` (when ``cfg.pq`` is set) replicates to every shard,
    like the coarse centroids — shards encode and ADC-score locally.
    """
    n = mesh.shape[axis]
    one = init_state(cfg, centroids, pq_codebooks)
    stacked = jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), one)
    sharding = NamedSharding(mesh, P(axis))
    return jax.tree.map(lambda x: jax.device_put(x, sharding), stacked)


def _spec_tree(state: SlabPoolState, axis: str):
    return jax.tree.map(lambda _: P(axis), state)


# ---------------------------------------------------------------------------
# Shard-mapped op builders (one code path for dist_* and sivf.Index)
# ---------------------------------------------------------------------------

def sharded_insert(cfg: SIVFConfig, mesh: Mesh, axis: str = "data",
                   want_plan: bool = False):
    """Broadcast-ingest op: each shard ingests the ids it owns.

    Returns ``run(state, vecs, ext_ids) -> state``. Building the shard_map
    wrapper happens at trace time, so callers that jit ``run`` pay it once
    per shape bucket. Failure is per-shard atomic: an exhausted shard's
    slice of the stacked output equals its input (plus error bits), so a
    partially-failing batch never drops payloads anywhere.

    ``want_plan=True`` (the tiered slab pool, ``core/tiered.py``) makes
    ``run`` return ``(state, plan)`` where ``plan`` is the stacked [S, B]
    commit plan of ``ix._insert_impl(want_plan=True)`` — rows a shard did
    not own (or an aborted shard's whole batch) are -1, so the host-store
    replay applies exactly the device commits, per shard.
    """
    n = mesh.shape[axis]

    def run(state: SlabPoolState, vecs: jax.Array, ext_ids: jax.Array,
            attrs: jax.Array | None = None):
        def local(st, v, i, *a):
            st = jax.tree.map(lambda x: x[0], st)
            me = jax.lax.axis_index(axis)
            mine = shard_of(i, n) == me
            from repro.core.quantizer import assign
            lists = assign(st.centroids, v.astype(cfg.dtype), cfg.metric)
            out = ix._insert_impl(cfg, st, v, jnp.where(mine, i, -1), lists,
                                  attrs=a[0] if a else None,
                                  want_plan=want_plan)
            if want_plan:
                st, plan = out
                return (jax.tree.map(lambda x: x[None], st),
                        jax.tree.map(lambda x: x[None], plan))
            return jax.tree.map(lambda x: x[None], out)

        extra = () if attrs is None else (attrs,)
        state_spec = _spec_tree(state, axis)
        out_specs = state_spec if not want_plan else (
            state_spec, {"slab": P(axis), "slot": P(axis),
                         "codes": P(axis)})
        f = shard_map_compat(
            local, mesh=mesh, check_vma=False,
            in_specs=(state_spec, P(), P())
            + tuple(P() for _ in extra),
            out_specs=out_specs)
        return f(state, vecs, ext_ids, *extra)

    return run


def sharded_delete(cfg: SIVFConfig, mesh: Mesh, axis: str = "data"):
    """Broadcast-delete op: non-owners see ATT misses and no-op.

    Returns ``run(state, ext_ids) -> state``.
    """

    def run(state: SlabPoolState, ext_ids: jax.Array) -> SlabPoolState:
        def local(st, i):
            st = jax.tree.map(lambda x: x[0], st)
            st = ix._delete_impl(cfg, st, i)
            return jax.tree.map(lambda x: x[None], st)

        f = shard_map_compat(
            local, mesh=mesh, check_vma=False,
            in_specs=(_spec_tree(state, axis), P()),
            out_specs=_spec_tree(state, axis))
        return f(state, ext_ids)

    return run


def sharded_maintain(cfg: SIVFConfig, mesh: Mesh, axis: str = "data",
                     want_plan: bool = False):
    """Atomic maintenance commit across shards (``core/maintenance.py``).

    The host-planned batch (new centroid plane + the affected lists' live
    rows, id-sorted and -1-padded) is broadcast exactly like
    ``sharded_insert``: each shard stages the new centroids, re-inserts
    only the rows it owns, and the shards then *agree* on the outcome —
    if any shard aborts (pool exhausted / chain overflow), every shard
    reverts to its pre-op state via a ``pmax`` vote, so a search never
    observes shard A under the new layout and shard B under the old one.

    Returns ``run(state, new_cents, vecs, ext_ids, lists, codes?, attrs?)
    -> (state, errors [S])`` (plus the stacked ``[S, B]`` commit plan with
    ``want_plan=True`` — voided to -1 everywhere on an aborted vote, so
    the tiered host-store replay applies exactly what the devices kept).
    """
    import dataclasses as dc

    from repro.core.maintenance import ABORT_BITS
    from repro.core.state import clear_error
    n = mesh.shape[axis]

    def run(state: SlabPoolState, new_cents: jax.Array, vecs: jax.Array,
            ext_ids: jax.Array, lists: jax.Array,
            codes: jax.Array | None = None, attrs: jax.Array | None = None):
        def local(st, nc, v, i, li, *rest):
            st = jax.tree.map(lambda x: x[0], st)
            me = jax.lax.axis_index(axis)
            mine = shard_of(i, n) == me
            st0 = clear_error(st)
            staged = dc.replace(st0, centroids=nc)
            k = 0
            kw = {}
            if cfg.pq is not None:
                kw["codes"] = rest[k]
                k += 1
            if cfg.n_attrs:
                kw["attrs"] = rest[k]
            out = ix._insert_impl(cfg, staged, v, jnp.where(mine, i, -1),
                                  li, want_plan=want_plan, **kw)
            st1, plan = out if want_plan else (out, None)
            errs = st1.error
            any_ab = jax.lax.pmax(
                ((errs & ABORT_BITS) != 0).astype(jnp.int32), axis) > 0
            st1 = jax.tree.map(
                lambda old, new: jnp.where(any_ab, old, new), st0, st1)
            st1 = clear_error(st1)
            outs = (jax.tree.map(lambda x: x[None], st1), errs[None])
            if want_plan:
                plan = {"slab": jnp.where(any_ab, -1, plan["slab"]),
                        "slot": plan["slot"], "codes": plan["codes"]}
                outs += (jax.tree.map(lambda x: x[None], plan),)
            return outs

        extra = tuple(x for x in (codes, attrs) if x is not None)
        state_spec = _spec_tree(state, axis)
        out_specs = (state_spec, P(axis))
        if want_plan:
            out_specs += ({"slab": P(axis), "slot": P(axis),
                           "codes": P(axis)},)
        f = shard_map_compat(
            local, mesh=mesh, check_vma=False,
            in_specs=(state_spec, P(), P(), P(), P())
            + tuple(P() for _ in extra),
            out_specs=out_specs)
        return f(state, new_cents, vecs, ext_ids, lists, *extra)

    return run


def sharded_search(cfg: SIVFConfig, mesh: Mesh, axis: str = "data",
                   impl: str = "xla", block_q: int = 8,
                   use_tables: bool | None = None):
    """Scatter-gather search op: fused local top-k, all-gather, global merge.

    Returns ``run(state, queries, k, nprobe) -> (dists, labels)`` where
    ``k``/``nprobe`` must be trace-time constants. Each shard runs the same
    unified scan->top-k dispatch as ``core.search`` (``impl`` selects
    xla / pallas / pallas_interpret), so only the fused [Q, k] partials ever
    cross the interconnect — never per-slab candidates.
    """

    def run(state: SlabPoolState, queries: jax.Array, k: int, nprobe: int,
            fstruct: tuple | None = None, fconsts: jax.Array | None = None
            ) -> tuple[jax.Array, jax.Array]:
        def local(st, q, *fc):
            st = jax.tree.map(lambda x: x[0], st)
            d, lab = ix._search_impl(cfg, st, q, k, nprobe, use_tables, impl,
                                     block_q, fstruct=fstruct,
                                     fconsts=fc[0] if fc else None)
            # gather fused [Q, k] partials from all shards (paper MPI_Gather)
            dg = jax.lax.all_gather(d, axis)                   # [S, Q, k]
            lg = jax.lax.all_gather(lab, axis)
            s, qn, _ = dg.shape
            dg = jnp.moveaxis(dg, 0, 1).reshape(qn, s * k)
            lg = jnp.moveaxis(lg, 0, 1).reshape(qn, s * k)
            nd, idx = jax.lax.top_k(-dg, k)                    # global merge
            return -nd, jnp.take_along_axis(lg, idx, axis=1)

        extra = () if fconsts is None else (fconsts,)
        f = shard_map_compat(
            local, mesh=mesh, check_vma=False,
            in_specs=(_spec_tree(state, axis), P())
            + tuple(P() for _ in extra),
            out_specs=(P(), P()))
        return f(state, queries, *extra)

    return run


# ---------------------------------------------------------------------------
# Elastic resharding (pure host-side; Index.load / Index.reshard wrap this)
# ---------------------------------------------------------------------------

def _leading_shards(state: SlabPoolState) -> int:
    """Shard count of a state value: leading-axis length when stacked, 1
    for a plain single-device state (``ids`` is [n_slabs, C] vs [S, n_slabs, C])."""
    ids = np.asarray(state.ids)
    return int(ids.shape[0]) if ids.ndim == 3 else 1


def flatten_live_rows(cfg: SIVFConfig, state: SlabPoolState) -> dict:
    """Flatten slab pools to the canonical host-side table of live rows.

    Works on a single-device state or the stacked per-shard state (leaves
    may be device arrays or the numpy leaves of a host-restored
    checkpoint). Rows are **id-sorted**, which makes the table canonical:
    two states hold the same logical index iff their tables are equal,
    regardless of shard count, slab layout, or deletion history. This is
    the exchange format of :func:`reshard_state` and the byte-accounting
    basis of the ``reshard_sweep`` benchmark.

    Returns a dict of numpy arrays over the N live rows:
      ``ids``     [N] int32 external ids (ascending, globally unique);
      ``lists``   [N] int32 owning IVF list (from the slab's ``owner``);
      ``data``    [N, payload_dim] stored fp payloads (width 0 when PQ
                  codes replace them);
      ``codes``   [N, code_m] uint8 PQ codewords (width 0 without PQ);
      ``attrs``   [N, n_attrs] int32 filter attributes (width 0 without
                  ``cfg.attributes``);
    plus the replicated leaves ``centroids`` [n_lists, D] and
    ``pq_codebooks`` (shard 0's copy when stacked).
    """
    c = cfg.capacity
    ids = np.asarray(state.ids).reshape(-1, c)                # [S*ns, C]
    bitmap = np.asarray(state.bitmap).reshape(-1, cfg.words)
    owner = np.asarray(state.owner).reshape(-1)               # [S*ns]
    mask = host_live_mask(cfg, bitmap).reshape(-1)            # [S*ns*C]
    idx = np.flatnonzero(mask)
    slots = mask.shape[0]            # explicit row count: the payload /
    #                                  code planes may be zero-width, where
    #                                  a -1 reshape is ambiguous
    live_ids = ids.reshape(-1)[idx]
    live_lists = np.broadcast_to(owner[:, None], (owner.shape[0], c)
                                 ).reshape(-1)[idx]
    data = np.asarray(state.data).reshape(slots, cfg.payload_dim)[idx]
    codes = np.asarray(state.codes).reshape(slots, cfg.code_m)[idx]
    attrs = np.asarray(state.attrs).reshape(slots, cfg.n_attrs)[idx]
    n_live = int(np.asarray(state.n_live).sum())
    if len(live_ids) != n_live:
        raise ValueError(
            f"corrupt state: bitmap says {len(live_ids)} live rows but "
            f"n_live says {n_live}")
    order = np.argsort(live_ids, kind="stable")               # canonical
    cents = np.asarray(state.centroids)
    cb = np.asarray(state.pq_codebooks)
    stacked = np.asarray(state.ids).ndim == 3
    return {
        "ids": live_ids[order].astype(np.int32),
        "lists": live_lists[order].astype(np.int32),
        "data": data[order],
        "codes": codes[order],
        "attrs": attrs[order].astype(np.int32),
        "centroids": cents[0] if stacked else cents,
        "pq_codebooks": cb[0] if stacked else cb,
    }


def _check_reshard_fit(cfg: SIVFConfig, ids: np.ndarray, lists: np.ndarray,
                       n_to: int) -> None:
    """Host-side feasibility: every target shard's rows must fit its pool.

    Shrinking concentrates rows, so a state that fit S shards can overflow
    the (per-shard, static) ``n_slabs`` pool or a list's ``max_chain``
    bound on S' < S shards. Failing *before* any device work gives a
    message that names the limit to raise, instead of a POOL_EXHAUSTED
    error bit halfway through the rebuild.
    """
    shard = ids % n_to
    key = shard.astype(np.int64) * cfg.n_lists + lists
    per_list = np.bincount(key, minlength=n_to * cfg.n_lists
                           ).reshape(n_to, cfg.n_lists)
    chains = -(-per_list // cfg.capacity)                     # ceil div
    slabs_needed = chains.sum(axis=1)
    if (bad := np.flatnonzero(slabs_needed > cfg.n_slabs)).size:
        s = int(bad[0])
        raise ValueError(
            f"reshard to {n_to} shards needs {int(slabs_needed[s])} slabs "
            f"on shard {s} but cfg.n_slabs={cfg.n_slabs}; raise n_slabs or "
            f"keep more shards")
    if (bad := np.argwhere(chains > cfg.max_chain)).size:
        s, li = (int(x) for x in bad[0])
        raise ValueError(
            f"reshard to {n_to} shards needs a {int(chains[s, li])}-slab "
            f"chain for list {li} on shard {s} but cfg.max_chain="
            f"{cfg.max_chain}; raise max_chain or keep more shards")


def _build_shard(cfg: SIVFConfig, centroids: np.ndarray, cb: np.ndarray,
                 vecs: np.ndarray, ids: np.ndarray, lists: np.ndarray,
                 codes: np.ndarray | None,
                 attrs: np.ndarray | None = None) -> SlabPoolState:
    """One target shard: fresh ``init_state`` + a single pre-routed insert.

    The batch pads to a power-of-two bucket (floor 64) so a sweep over
    shard counts compiles a bounded number of insert executables, same as
    the session handle's bucketing. With PQ, the *stored* codes ride
    along and are scattered as-is, so code planes survive byte-for-byte
    by construction — and the same holds for the int32 attribute stamps.
    """
    pq_cb = None if cfg.pq is None else jnp.asarray(cb)
    st = init_state(cfg, jnp.asarray(centroids), pq_cb)
    n = len(ids)
    if n == 0:
        return st
    b = max(64, 1 << (n - 1).bit_length())
    vp = np.zeros((b, cfg.dim), np.float32)
    vp[:n] = vecs
    ip = np.full((b,), -1, np.int32)
    ip[:n] = ids
    lp = np.zeros((b,), np.int32)
    lp[:n] = lists
    cp = None
    if codes is not None:
        cp = np.zeros((b, cfg.code_m), np.uint8)
        cp[:n] = codes
        cp = jnp.asarray(cp)
    ap = None
    if attrs is not None and cfg.n_attrs:
        ap = np.zeros((b, cfg.n_attrs), np.int32)
        ap[:n] = attrs
        ap = jnp.asarray(ap)
    st = ix.insert(cfg, st, jnp.asarray(vp), jnp.asarray(ip),
                   jnp.asarray(lp), cp, ap)
    if int(st.error):
        raise ValueError(
            f"reshard rebuild failed with error bits {int(st.error)} "
            f"(n={n} rows; pool n_slabs={cfg.n_slabs} max_chain="
            f"{cfg.max_chain})")                 # pragma: no cover - guarded
    return st


def reshard_state(cfg: SIVFConfig, state: SlabPoolState, n_from: int,
                  n_to: int, stack: bool | None = None) -> SlabPoolState:
    """Remap an S-shard index state onto S' shards. Pure; host-driven.

    ``state`` is a single-device state (``n_from == 1``) or the stacked
    per-shard state; leaves may live on device or host. The result is a
    plain single-device state when ``n_to == 1``, else a stacked state on
    the default device — :func:`place_sharded` places it onto a mesh.
    ``stack=True`` forces the stacked form even for ``n_to == 1`` (a
    one-shard *mesh* target still wants the leading shard axis).

    Semantics (the resharding contract, docs/checkpoint-format.md):
      * rows re-route by ``id % n_to`` — the same rule ``sharded_insert``
        applies, so inserts after the reshard land on the owning shard;
      * PQ codebooks and coarse centroids replicate to every target shard;
      * the rebuilt index is search-identical: same live ids, same
        distances — stored payloads AND stored PQ codes carry over
        byte-for-byte by construction (the codes are re-scattered as-is,
        never round-tripped through decode/encode);
      * slab layout is NOT preserved — each target shard re-packs its rows
        densely (a reshard is also a compaction), so only logical state
        (the :func:`flatten_live_rows` table) round-trips.

    Raises ``ValueError`` when the rows cannot fit ``n_to`` shards under
    the static per-shard pool geometry (see :func:`_check_reshard_fit`).
    """
    if n_to < 1:
        raise ValueError(f"n_to must be >= 1, got {n_to}")
    from repro import obs
    tel = obs.default()
    actual = _leading_shards(state)
    if n_from != actual:
        raise ValueError(
            f"state has {actual} shard(s) but n_from={n_from}")
    with tel.span("reshard.flatten"):
        rows = flatten_live_rows(cfg, state)
    ids, lists = rows["ids"], rows["lists"]
    _check_reshard_fit(cfg, ids, lists, n_to)
    codes = rows["codes"] if cfg.pq is not None else None
    if cfg.pq is not None and not cfg.pq.store_raw:
        # codes are the only payload; the rebuild scatters them verbatim.
        # Decoded codewords stand in for the raw vectors only where the
        # insert needs *some* fp rows (the zero-width data plane ignores
        # them; the cached norms they produce are unused by ADC scoring).
        vecs = np.asarray(pqmod.decode(jnp.asarray(rows["pq_codebooks"]),
                                       jnp.asarray(rows["codes"])))
    else:
        vecs = np.asarray(rows["data"], np.float32)
    if tel.enabled:
        # the bytes that cross the host on this flatten-and-rebuild path
        # (ROADMAP's device-side all-to-all would make this counter ~0)
        moved = sum(rows[k].nbytes for k in ("ids", "lists", "data",
                                             "codes", "attrs"))
        tel.counter("sivf_transfer_bytes_total",
                    "explicit host<->device transfer bytes by direction "
                    "and stage", ("direction", "stage")
                    ).inc(moved, direction="d2h", stage="reshard")
        tel.counter("sivf_reshard_rows_total",
                    "live rows re-routed by reshard_state"
                    ).inc(int(ids.shape[0]))
    shard = ids % n_to
    shards = []
    for t in range(n_to):
        sel = shard == t
        with tel.span("reshard.build_shard", shard=t):
            shards.append(_build_shard(cfg, rows["centroids"],
                                       rows["pq_codebooks"], vecs[sel],
                                       ids[sel], lists[sel],
                                       None if codes is None else codes[sel],
                                       rows["attrs"][sel] if cfg.n_attrs
                                       else None))
    if n_to == 1 and not stack:
        return shards[0]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *shards)


def search_stacked(cfg: SIVFConfig, state: SlabPoolState, queries, k: int,
                   nprobe: int, impl: str = "xla", block_q: int = 8
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Search a stacked per-shard state *without* a mesh (host-side merge).

    Runs the ordinary single-device search on each shard's slice and
    merges with the same rule ``sharded_search`` applies on device
    (concatenate per-shard [Q, k] partials in shard order, stable-sort by
    distance, keep k) — so results match a real mesh search exactly, ties
    included. Intended for inspecting host-restored or freshly-resharded
    stacked states; tests and ``reshard_sweep`` assert parity through it.
    """
    q = jnp.asarray(queries)
    host = jax.tree.map(np.asarray, state)       # ONE device->host snapshot
    if host.ids.ndim == 2:                       # plain single state
        d, lab = ix.search(cfg, jax.tree.map(jnp.asarray, host), q, k,
                         nprobe, impl=impl, block_q=block_q)
        return np.asarray(d), np.asarray(lab)
    ds, ls = [], []
    for s in range(_leading_shards(host)):
        sub = jax.tree.map(lambda x: jnp.asarray(x[s]), host)
        d, lab = ix.search(cfg, sub, q, k, nprobe, impl=impl, block_q=block_q)
        ds.append(np.asarray(d))
        ls.append(np.asarray(lab))
    dg, lg = np.concatenate(ds, axis=1), np.concatenate(ls, axis=1)
    order = np.argsort(dg, axis=1, kind="stable")[:, :k]
    return np.take_along_axis(dg, order, 1), np.take_along_axis(lg, order, 1)


def place_sharded(state: SlabPoolState, mesh: Mesh, axis: str = "data"
                  ) -> SlabPoolState:
    """Place a stacked per-shard state onto a mesh (leading axis sharded).

    Shard ``s`` of the stack lands on device ``s`` of the mesh axis, which
    is the same order ``jax.lax.axis_index`` sees inside the shard-mapped
    ops — so the ``id % n_shards`` ownership encoded in the stack matches
    the routing the ops will apply.
    """
    n = mesh.shape[axis]
    if _leading_shards(state) != n:
        raise ValueError(
            f"state has {_leading_shards(state)} shards but mesh axis "
            f"{axis!r} has {n}")
    sharding = NamedSharding(mesh, P(axis))
    return jax.tree.map(lambda x: jax.device_put(jnp.asarray(x), sharding),
                        state)


# ---------------------------------------------------------------------------
# Legacy free-function surface (thin delegation; prefer sivf.Index)
# ---------------------------------------------------------------------------

def dist_insert(cfg: SIVFConfig, mesh: Mesh, state: SlabPoolState,
                vecs: jax.Array, ext_ids: jax.Array, axis: str = "data"
                ) -> SlabPoolState:
    """Broadcast batch; each shard ingests the ids it owns."""
    return sharded_insert(cfg, mesh, axis)(state, vecs, ext_ids)


def dist_delete(cfg: SIVFConfig, mesh: Mesh, state: SlabPoolState,
                ext_ids: jax.Array, axis: str = "data") -> SlabPoolState:
    """Broadcast deletes; non-owners see ATT misses and no-op."""
    return sharded_delete(cfg, mesh, axis)(state, ext_ids)


def dist_search(cfg: SIVFConfig, mesh: Mesh, state: SlabPoolState,
                queries: jax.Array, k: int, nprobe: int, axis: str = "data",
                impl: str = "xla", block_q: int = 8
                ) -> tuple[jax.Array, jax.Array]:
    """Scatter-gather search across the mesh (see ``sharded_search``)."""
    return sharded_search(cfg, mesh, axis, impl, block_q)(
        state, queries, k, nprobe)


def total_live(state: SlabPoolState) -> int:
    """Aggregate live count across shards."""
    return int(jnp.sum(state.n_live))
