"""Distributed SIVF: shared-nothing data sharding + scatter-gather (paper §4.2).

The paper's 12-GPU MPI architecture maps 1:1 onto ``jax.shard_map`` over a
mesh axis:

  * **Data sharding** — each shard owns a disjoint id range via deterministic
    ``id % n_shards`` routing (the paper's round-robin/hash routing). Every
    shard keeps its *own* SlabPoolState; the global state is the stack of
    per-shard states along a leading axis sharded on ``axis_name``.
  * **Ingestion** — the batch is broadcast; each shard masks to its owned
    ids and ingests locally (no cross-shard sync, hence the paper's linear
    ingestion scaling).
  * **Search (scatter-gather)** — queries are broadcast; each shard searches
    its local shard; partial top-k are all-gathered and merged (the paper's
    MPI_Gather / tree reduction).
  * **Deletion** — broadcast; ids live on exactly one shard, others no-op
    (paper: "the target ID exists on at most one worker").
  * **Per-shard atomicity** — each shard runs the all-or-nothing insert of
    ``core.index``: a shard that hits POOL_EXHAUSTED / CHAIN_OVERFLOW
    keeps its previously-live ids (old payloads included) and raises only
    its own ``error`` bits, while sibling shards commit normally. The
    stacked ``state.error`` vector is therefore the per-shard truth that
    ``sivf.Index`` surfaces as ``MutationReport.shard_errors`` — eagerly
    or deferred, the accounting never has to guess which rows survived.

The ``sharded_*`` builders return the raw shard-mapped callables; they are
the single code path behind both the legacy ``dist_*`` free functions and
the ``sivf.Index`` mesh backend (``core/api.py``), which wraps them in jit
with buffer donation, shape-bucketed batches, and (in deferred mode)
device-resident report aux that only syncs at ``Index.flush()``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import index as ix
from repro.core.state import SIVFConfig, SlabPoolState, init_state
from repro.utils import shard_map_compat


def shard_of(ids: jax.Array, n_shards: int) -> jax.Array:
    """Deterministic owner shard for each external id."""
    return jnp.where(ids >= 0, ids % n_shards, -1)


def init_sharded_state(cfg: SIVFConfig, centroids: jax.Array, mesh: Mesh,
                       axis: str = "data",
                       pq_codebooks: jax.Array | None = None
                       ) -> SlabPoolState:
    """Per-shard empty states stacked on a leading sharded axis.

    ``pq_codebooks`` (when ``cfg.pq`` is set) replicates to every shard,
    like the coarse centroids — shards encode and ADC-score locally.
    """
    n = mesh.shape[axis]
    one = init_state(cfg, centroids, pq_codebooks)
    stacked = jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), one)
    sharding = NamedSharding(mesh, P(axis))
    return jax.tree.map(lambda x: jax.device_put(x, sharding), stacked)


def _spec_tree(state: SlabPoolState, axis: str):
    return jax.tree.map(lambda _: P(axis), state)


# ---------------------------------------------------------------------------
# Shard-mapped op builders (one code path for dist_* and sivf.Index)
# ---------------------------------------------------------------------------

def sharded_insert(cfg: SIVFConfig, mesh: Mesh, axis: str = "data"):
    """Broadcast-ingest op: each shard ingests the ids it owns.

    Returns ``run(state, vecs, ext_ids) -> state``. Building the shard_map
    wrapper happens at trace time, so callers that jit ``run`` pay it once
    per shape bucket. Failure is per-shard atomic: an exhausted shard's
    slice of the stacked output equals its input (plus error bits), so a
    partially-failing batch never drops payloads anywhere.
    """
    n = mesh.shape[axis]

    def run(state: SlabPoolState, vecs: jax.Array, ext_ids: jax.Array
            ) -> SlabPoolState:
        def local(st, v, i):
            st = jax.tree.map(lambda x: x[0], st)
            me = jax.lax.axis_index(axis)
            mine = shard_of(i, n) == me
            from repro.core.quantizer import assign
            lists = assign(st.centroids, v.astype(cfg.dtype), cfg.metric)
            st = ix._insert_impl(cfg, st, v, jnp.where(mine, i, -1), lists)
            return jax.tree.map(lambda x: x[None], st)

        f = shard_map_compat(
            local, mesh=mesh, check_vma=False,
            in_specs=(_spec_tree(state, axis), P(), P()),
            out_specs=_spec_tree(state, axis))
        return f(state, vecs, ext_ids)

    return run


def sharded_delete(cfg: SIVFConfig, mesh: Mesh, axis: str = "data"):
    """Broadcast-delete op: non-owners see ATT misses and no-op.

    Returns ``run(state, ext_ids) -> state``.
    """

    def run(state: SlabPoolState, ext_ids: jax.Array) -> SlabPoolState:
        def local(st, i):
            st = jax.tree.map(lambda x: x[0], st)
            st = ix._delete_impl(cfg, st, i)
            return jax.tree.map(lambda x: x[None], st)

        f = shard_map_compat(
            local, mesh=mesh, check_vma=False,
            in_specs=(_spec_tree(state, axis), P()),
            out_specs=_spec_tree(state, axis))
        return f(state, ext_ids)

    return run


def sharded_search(cfg: SIVFConfig, mesh: Mesh, axis: str = "data",
                   impl: str = "xla", block_q: int = 8,
                   use_tables: bool | None = None):
    """Scatter-gather search op: fused local top-k, all-gather, global merge.

    Returns ``run(state, queries, k, nprobe) -> (dists, labels)`` where
    ``k``/``nprobe`` must be trace-time constants. Each shard runs the same
    unified scan->top-k dispatch as ``core.search`` (``impl`` selects
    xla / pallas / pallas_interpret), so only the fused [Q, k] partials ever
    cross the interconnect — never per-slab candidates.
    """

    def run(state: SlabPoolState, queries: jax.Array, k: int, nprobe: int
            ) -> tuple[jax.Array, jax.Array]:
        def local(st, q):
            st = jax.tree.map(lambda x: x[0], st)
            d, l = ix._search_impl(cfg, st, q, k, nprobe, use_tables, impl,
                                   block_q)
            # gather fused [Q, k] partials from all shards (paper MPI_Gather)
            dg = jax.lax.all_gather(d, axis)                   # [S, Q, k]
            lg = jax.lax.all_gather(l, axis)
            s, qn, _ = dg.shape
            dg = jnp.moveaxis(dg, 0, 1).reshape(qn, s * k)
            lg = jnp.moveaxis(lg, 0, 1).reshape(qn, s * k)
            nd, idx = jax.lax.top_k(-dg, k)                    # global merge
            return -nd, jnp.take_along_axis(lg, idx, axis=1)

        f = shard_map_compat(
            local, mesh=mesh, check_vma=False,
            in_specs=(_spec_tree(state, axis), P()),
            out_specs=(P(), P()))
        return f(state, queries)

    return run


# ---------------------------------------------------------------------------
# Legacy free-function surface (thin delegation; prefer sivf.Index)
# ---------------------------------------------------------------------------

def dist_insert(cfg: SIVFConfig, mesh: Mesh, state: SlabPoolState,
                vecs: jax.Array, ext_ids: jax.Array, axis: str = "data"
                ) -> SlabPoolState:
    """Broadcast batch; each shard ingests the ids it owns."""
    return sharded_insert(cfg, mesh, axis)(state, vecs, ext_ids)


def dist_delete(cfg: SIVFConfig, mesh: Mesh, state: SlabPoolState,
                ext_ids: jax.Array, axis: str = "data") -> SlabPoolState:
    """Broadcast deletes; non-owners see ATT misses and no-op."""
    return sharded_delete(cfg, mesh, axis)(state, ext_ids)


def dist_search(cfg: SIVFConfig, mesh: Mesh, state: SlabPoolState,
                queries: jax.Array, k: int, nprobe: int, axis: str = "data",
                impl: str = "xla", block_q: int = 8
                ) -> tuple[jax.Array, jax.Array]:
    """Scatter-gather search across the mesh (see ``sharded_search``)."""
    return sharded_search(cfg, mesh, axis, impl, block_q)(
        state, queries, k, nprobe)


def total_live(state: SlabPoolState) -> int:
    """Aggregate live count across shards."""
    return int(jnp.sum(state.n_live))
