import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first init). Everything below is ordinary code.
if os.environ.get("REPRO_DRYRUN_DEVICES"):      # test override (smaller mesh)
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent on the
production mesh (sharding propagates, memory fits, collectives lower) and
extracts the §Roofline inputs: cost_analysis FLOPs/bytes, memory_analysis,
and the collective schedule parsed from post-SPMD HLO.

Results are cached incrementally in a JSON file so the sweep is resumable.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, cell_runnable
from repro.launch import roofline as R
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh, mesh_axes_dict
from repro.models import model as M
from repro.sharding import axes as AX
from repro.sharding.rules import make_plan
from repro.train.train_step import (TrainConfig, init_train_state,
                                    make_train_step, state_specs)
from repro.utils import set_mesh_compat


def _to_dtype(tree, dtype):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating)
            else s.dtype),
        tree)


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = ARCHS[arch_name]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    maxes = mesh_axes_dict(mesh)
    plan = make_plan(cfg, maxes, shape_kind=shape.kind,
                     global_batch=shape.global_batch)
    rules = plan.rules_dict
    chips = mesh.devices.size

    max_seq = shape.seq_len
    params_annot = SP.abstract_params(cfg, plan, max_seq=max_seq)
    params_sh = SP.param_shardings(params_annot, mesh, rules)
    params_abs = AX.strip(params_annot)
    batch_abs = SP.input_specs(cfg, shape)
    batch_sh = SP.input_shardings(cfg, shape, plan, mesh)

    t0 = time.time()
    with set_mesh_compat(mesh), AX.use_rules(rules):
        if shape.kind == "train":
            tcfg = TrainConfig()
            step_fn = make_train_step(cfg, plan, tcfg)
            state_abs = jax.eval_shape(init_train_state, params_abs)
            state_sh = state_specs(
                params_sh, params_abs=params_abs,
                batch_axes=plan.batch_axes, mesh_axes=maxes,
                zero1=os.environ.get("REPRO_ZERO1", "1") == "1")
            state_sh["opt"]["step"] = NamedSharding(mesh, P())
            fn = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))
            lowered = fn.lower(state_abs, batch_abs)
        elif shape.kind == "prefill":
            params_serve = _to_dtype(params_abs, jnp.dtype(cfg.dtype))

            def prefill(params, batch):
                logits, _, _ = M.forward(params, cfg, plan, batch)
                return logits

            fn = jax.jit(prefill, in_shardings=(params_sh, batch_sh))
            lowered = fn.lower(params_serve, batch_abs)
        else:  # decode
            params_serve = _to_dtype(params_abs, jnp.dtype(cfg.dtype))
            cache_abs = SP.abstract_decode_cache(
                cfg, plan, shape.global_batch, max_seq)
            cache_sh = SP.cache_shardings(cfg, plan, cache_abs, mesh)

            def serve_step(params, tokens, caches, pos):
                logits, new_caches = M.decode_step(
                    params, cfg, plan, tokens, caches, pos)
                return logits, new_caches

            fn = jax.jit(
                serve_step,
                in_shardings=(params_sh, batch_sh["tokens"], cache_sh,
                              NamedSharding(mesh, P())),
                donate_argnums=(2,))
            lowered = fn.lower(params_serve, batch_abs["tokens"], cache_abs,
                               jax.ShapeDtypeStruct((), jnp.int32))
        lower_s = time.time() - t0

        t1 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t1

    # -- extract roofline inputs --------------------------------------------
    # XLA cost_analysis counts while bodies ONCE (verified; see the HLO
    # analyzer docstring) — kept only as a cross-check column. The
    # trip-count-aware analyzer provides the real per-device numbers.
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):     # old jaxlib: list of per-program dicts
        cost = cost[0] if cost else {}
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
        }
    except Exception:
        mem_info = {}
    from repro.launch.hlo_analyzer import analyze
    hlo = analyze(compiled.as_text())
    # analyzer numbers are per-device (post-SPMD shapes): totals x chips
    flops = hlo["flops"] * chips
    bytes_acc = hlo["memory_bytes"] * chips
    wire = hlo["collective_wire_bytes"] * chips
    terms = R.roofline_terms(flops, bytes_acc, wire, chips)
    mflops = R.model_flops(cfg, shape)

    return {
        "status": "ok",
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "chips": chips,
        "plan": {
            "n_heads_padded": plan.n_heads_padded,
            "n_kv_heads_padded": plan.n_kv_heads_padded,
            "kv_sharded": plan.kv_sharded,
            "vocab_padded": plan.vocab_padded,
            "n_experts_padded": plan.n_experts_padded,
        },
        "lower_s": round(lower_s, 2),
        "compile_s": round(compile_s, 2),
        "hlo_flops": flops,
        "hlo_bytes": bytes_acc,
        "xla_cost_analysis_flops": xla_flops,   # cross-check (body-once)
        "xla_cost_analysis_bytes": xla_bytes,
        "model_flops": mflops,
        "useful_flops_frac": mflops / flops if flops else None,
        "memory": mem_info,
        "collectives": {
            "per_device": hlo["collectives"],
            "wire_bytes_total": wire,
        },
        "roofline": terms,
    }


def cell_key(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}|{shape}|{'multi' if multi_pod else 'single'}"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun_results.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    for arch in archs:
        for shape in shapes:
            runnable, reason = cell_runnable(ARCHS[arch], SHAPES[shape])
            for mp in meshes:
                key = cell_key(arch, shape, mp)
                if key in results and results[key].get("status") == "ok" \
                        and not args.force:
                    print(f"[skip-cached] {key}")
                    continue
                if not runnable:
                    results[key] = {"status": "skipped", "arch": arch,
                                    "shape": shape, "reason": reason}
                    print(f"[skip] {key}: {reason}")
                else:
                    print(f"[lower+compile] {key} ...", flush=True)
                    try:
                        results[key] = lower_cell(arch, shape, mp)
                        r = results[key]
                        print(f"  ok: compile={r['compile_s']}s "
                              f"flops={r['hlo_flops']:.3e} "
                              f"dominant={r['roofline']['dominant']}",
                              flush=True)
                    except Exception as e:
                        results[key] = {
                            "status": "error", "arch": arch, "shape": shape,
                            "error": f"{type(e).__name__}: {e}",
                            "traceback": traceback.format_exc()[-2000:],
                        }
                        print(f"  ERROR: {e}", flush=True)
                out_path.write_text(json.dumps(results, indent=1))

    n_ok = sum(1 for v in results.values() if v.get("status") == "ok")
    n_skip = sum(1 for v in results.values() if v.get("status") == "skipped")
    n_err = sum(1 for v in results.values() if v.get("status") == "error")
    print(f"\ndone: {n_ok} ok, {n_skip} skipped (documented), {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
