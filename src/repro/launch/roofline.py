"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS.md
§Roofline):

  compute    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
  memory     = HLO_bytes / (chips x 819e9 B/s HBM)
  collective = collective_bytes / (chips x 50e9 B/s per ICI link)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``. Collective bytes
are NOT in cost_analysis: we parse the post-SPMD HLO text and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, weighting each by its ring-traffic factor
(all-reduce 2x, others ~1x of operand bytes on the wire per device).
"""
from __future__ import annotations

import re

# TPU v5e-class hardware constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# ring-traffic factor: bytes on the wire per device / operand bytes
_TRAFFIC = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
            "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind from post-SPMD HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(r"=\s*\S+\s+(" + "|".join(_COLLECTIVES)
                      + r")(?:-start|-done)?\(", line)
        if not m:
            continue
        kind = m.group(1)
        if "-done(" in line:
            continue  # avoid double counting start/done pairs
        # operands: everything inside the call parens
        call = line[m.end():]
        depth, i = 1, 0
        while i < len(call) and depth:
            if call[i] == "(":
                depth += 1
            elif call[i] == ")":
                depth -= 1
            i += 1
        operands = call[: i - 1]
        out[kind]["count"] += 1
        out[kind]["bytes"] += _shape_bytes(operands)
    total = sum(v["bytes"] for v in out.values())
    wire = sum(_TRAFFIC[k] * v["bytes"] for k, v in out.items())
    out["total_bytes"] = total
    out["wire_bytes"] = int(wire)
    return out


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_wire_bytes: float, chips: int) -> dict:
    compute = flops / (chips * PEAK_FLOPS)
    memory = bytes_accessed / (chips * HBM_BW)
    collective = collective_wire_bytes / (chips * ICI_BW)
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    terms["dominant"] = dom
    terms["roofline_bound_s"] = bound
    # fraction of the bound the compute term fills = achievable MFU ceiling
    terms["compute_fraction_of_bound"] = compute / bound if bound else 0.0
    return terms


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS from the *unpadded* spec.

    train: 6*N*D (fwd+bwd); prefill: 2*N*D; decode: 2*N*B per step
    (MoE archs use active params). Attention O(S^2) term added for
    full-attention archs where it is material.
    """
    n_active = cfg.param_count_active()
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        base = 6.0 * n_active * b * s
    elif shape.kind == "prefill":
        base = 2.0 * n_active * b * s
    else:
        base = 2.0 * n_active * b          # one token per sequence
    # attention score/value FLOPs (causal ~ S^2/2), per attn layer
    attn_layers = sum(cfg.is_attn_layer(i) for i in range(cfg.n_layers))
    dh = cfg.qk_head_dim
    if shape.kind in ("train", "prefill"):
        mult = 3 if shape.kind == "train" else 1  # bwd ~ 2x fwd
        base += mult * attn_layers * b * 2.0 * cfg.n_heads * dh * s * s
    else:
        base += attn_layers * b * 4.0 * cfg.n_heads * dh * s
    return base
