"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips.

    REPRO_DRYRUN_MESH="d,m" overrides the single-pod extents (test-only;
    the production dry-run never sets it)."""
    import os
    override = os.environ.get("REPRO_DRYRUN_MESH")
    if override:
        d, m = (int(x) for x in override.split(","))
    else:
        d, m = 16, 16
    shape = (2, d, m) if multi_pod else (d, m)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes_dict(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
