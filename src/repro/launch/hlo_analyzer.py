"""Static analyzer for post-SPMD HLO text: trip-count-aware FLOP, memory
and collective accounting.

Why: ``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless
of trip count, so scan-over-layers models under-report FLOPs by the layer
count (verified experimentally; see EXPERIMENTS.md §Roofline methodology).
This analyzer parses the HLO module, builds the computation call graph
(whiles, fusions, calls, conditionals), extracts each while's trip count
from its condition's ROOT compare constant (the standard lax.scan
lowering), and accumulates costs weighted by execution multiplicity:

  * flops            — dot/convolution ops: 2 x |output| x |contraction|
  * memory bytes     — operand + result bytes of top-level ops in
                       non-fusion computations (fusion bodies stay in
                       registers/VMEM; the fusion op itself is counted at
                       its call site)
  * collective bytes — per kind, operand bytes x multiplicity

All sizes are per-partition (post-SPMD shapes), i.e. per-device costs.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1, "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?)\s*"
                     r"([\w\-]+)\(")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)="
    r"(?:%?([\w.\-]+)|\{([^}]*)\})")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes_of(text: str) -> int:
    """Total bytes of all array shapes mentioned in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_bytes(text: str) -> int:
    m = _SHAPE_RE.search(text)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _shape_dims(text: str) -> list[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_text: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    is_fusion_body: bool
    ops: list
    shapes: dict            # value name -> result text (shape)
    calls: list             # (callee, kind) kind in {while_body, call, ...}
    while_ops: list         # (body, cond)
    root_line: str = ""


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        # tuple types embed /*index=N*/ comments whose '=' breaks parsing
        if "/*" in s:
            s = re.sub(r"/\*.*?\*/", "", s)
        # computation header: `%name (p: f32[..]) -> f32[..] {` or `ENTRY ..`
        m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.*)\s*{",
                     s)
        if m and not s.startswith("%") or (m and "=" not in s.split("(")[0]):
            if m:
                name = m.group(2)
                cur = Computation(
                    name=name,
                    is_fusion_body="fused" in name,
                    ops=[], shapes={}, calls=[], while_ops=[])
                comps[name] = cur
                if m.group(1):
                    entry_name = name
                # parameters: record shapes
                for pm in re.finditer(r"%?([\w.\-]+):\s*([^,)]+)",
                                      m.group(3)):
                    cur.shapes[pm.group(1)] = pm.group(2)
                continue
        if cur is None:
            continue
        if s == "}":
            cur = None
            continue
        dm = _DEF_RE.match(s)
        if not dm:
            continue
        name, result_text, kind = dm.groups()
        cur.shapes[name] = result_text
        op = Op(name=name, kind=kind, result_text=result_text, line=s)
        cur.ops.append(op)
        if s.startswith("ROOT"):
            cur.root_line = s
        if kind == "while":
            body = cond = None
            for cm in _CALL_ATTR_RE.finditer(s):
                pass
            bm = re.search(r"body=%?([\w.\-]+)", s)
            cm2 = re.search(r"condition=%?([\w.\-]+)", s)
            if bm and cm2:
                cur.while_ops.append((bm.group(1), cm2.group(1), op))
        else:
            for cm in _CALL_ATTR_RE.finditer(s):
                single, many = cm.groups()
                if single:
                    cur.calls.append((single, kind))
                elif many:
                    for nm in _OPERAND_RE.findall(many):
                        cur.calls.append((nm, kind))
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(comps: dict, cond_name: str) -> int:
    """Trip count from the condition's ROOT compare against a constant
    (standard lax.scan/fori lowering); 1 if unrecognized."""
    cond = comps.get(cond_name)
    if cond is None or not cond.root_line:
        return 1
    if "compare" not in cond.root_line:
        return 1
    consts = {}
    for op in cond.ops:
        mm = re.search(r"constant\((-?\d+)\)", op.line)
        if mm:
            consts[op.name] = int(mm.group(1))
    operands = _OPERAND_RE.findall(
        cond.root_line.split("compare(", 1)[-1].split(")")[0])
    direction = re.search(r"direction=(\w+)", cond.root_line)
    for o in operands:
        if o in consts:
            n = consts[o]
            if direction and direction.group(1) in ("LT", "GT"):
                return max(n, 1)
            return max(n, 1)
    return 1


def _first_call_arg(line: str, kind: str) -> str:
    """First top-level operand of ``kind(...)`` — comma-split is wrong when
    operands carry inline shapes (``dot(f32[32,64]{1,0} %a, ...)``, older
    jaxlib), so track bracket depth instead."""
    args = line.split(kind + "(", 1)[1]
    depth = 0
    for i, ch in enumerate(args):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            if ch == ")" and depth == 0:
                return args[:i]
            depth -= 1
        elif ch == "," and depth == 0:
            return args[:i]
    return args


def _dot_flops(op: Op, shapes: dict) -> float:
    out_dims = _shape_dims(op.result_text)
    out_n = 1
    for d in out_dims:
        out_n *= d
    # contraction size from lhs shape + lhs_contracting_dims
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    # lhs shape: first operand — inline shape or symbol lookup
    first_arg = _first_call_arg(op.line, op.kind)
    dims = _shape_dims(first_arg)
    if not dims:
        nm = _OPERAND_RE.search(first_arg)
        if nm and nm.group(1) in shapes:
            dims = _shape_dims(shapes[nm.group(1)])
    csize = 1
    if cdims and dims:
        for ci in cdims.group(1).split(","):
            if ci != "" and int(ci) < len(dims):
                csize *= dims[int(ci)]
    return 2.0 * out_n * csize


def _op_memory_bytes(op: Op, shapes: dict) -> int:
    """result bytes + operand bytes (inline shapes or symbol lookup)."""
    total = _shape_bytes_of(op.result_text)
    call_args = op.line.split(op.kind + "(", 1)
    if len(call_args) < 2:
        return total
    # cut at closing paren of the call
    args, depth, i = call_args[1], 1, 0
    while i < len(args) and depth:
        if args[i] == "(":
            depth += 1
        elif args[i] == ")":
            depth -= 1
        i += 1
    args = args[: i - 1]
    inline = _shape_bytes_of(args)
    if inline:
        total += inline
    else:
        for nm in _OPERAND_RE.findall(args):
            if nm in shapes:
                total += _shape_bytes_of(shapes[nm])
    return total


_SKIP_MEM = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id", "iota",
             "while", "conditional", "call"}


_PURE_CONVERT = {"parameter", "convert", "bitcast", "constant"}


def _fusion_aware_bytes(op: Op, comp: Computation, comps: dict
                        ) -> tuple[int, str]:
    """(bytes, category) for one op.

    * in-place dynamic-update-slice fusions are charged 2x the updated
      slice, not the whole aliased buffer (XLA aliases input/output);
    * pure dtype-convert fusions are categorized "convert": the CPU
      backend materializes f32 copies of bf16 dot operands, which the TPU
      MXU consumes natively — the roofline memory term reports both raw
      and TPU-adjusted numbers (EXPERIMENTS.md methodology).
    """
    if op.kind == "fusion":
        cm = re.search(r"calls=%?([\w.\-]+)", op.line)
        callee = comps.get(cm.group(1)) if cm else None
        if callee is not None:
            kinds = {o.kind for o in callee.ops}
            if kinds <= _PURE_CONVERT and "convert" in kinds:
                return _op_memory_bytes(op, comp.shapes), "convert"
            dus = [o for o in callee.ops
                   if o.kind == "dynamic-update-slice"]
            if dus:
                args = dus[-1].line.split("dynamic-update-slice(", 1)[1]
                names = _OPERAND_RE.findall(args.split(")")[0])
                if len(names) >= 2 and names[1] in callee.shapes:
                    upd = _shape_bytes_of(callee.shapes[names[1]])
                    return 2 * upd, "mem"
    return _op_memory_bytes(op, comp.shapes), "mem"


def analyze(text: str) -> dict:
    """Full-module analysis. Returns per-device totals."""
    comps = parse_module(text)
    entry = comps.get("__entry__")
    if entry is None:
        return {"flops": 0.0, "memory_bytes": 0.0,
                "collectives": {}, "note": "no entry computation"}

    flops = 0.0
    mem = 0.0
    convert_mem = 0.0
    coll = defaultdict(lambda: {"count": 0, "bytes": 0.0})
    visited_mult: dict[str, float] = defaultdict(float)

    def visit(comp: Computation, mult: float, depth: int = 0):
        nonlocal flops, mem, convert_mem
        if depth > 64 or mult <= 0:
            return
        visited_mult[comp.name] += mult
        for op in comp.ops:
            if op.kind in ("dot", "convolution"):
                flops += mult * _dot_flops(op, comp.shapes)
            if op.kind.rstrip("-startdone") in _COLLECTIVES or \
                    any(op.kind == c or op.kind == c + "-start"
                        for c in _COLLECTIVES):
                base = op.kind.replace("-start", "").replace("-done", "")
                if base in _COLLECTIVES and not op.kind.endswith("-done"):
                    b = _op_memory_bytes(op, comp.shapes) \
                        - _shape_bytes_of(op.result_text)
                    if b <= 0:
                        b = _shape_bytes_of(op.result_text)
                    coll[base]["count"] += mult
                    coll[base]["bytes"] += mult * b
            if not comp.is_fusion_body and op.kind not in _SKIP_MEM:
                by, cat = _fusion_aware_bytes(op, comp, comps)
                if cat == "convert":
                    convert_mem += mult * by
                else:
                    mem += mult * by
        for body, cond, _op in comp.while_ops:
            trips = _trip_count(comps, cond)
            if body in comps:
                visit(comps[body], mult * trips, depth + 1)
            if cond in comps:
                visit(comps[cond], mult * trips, depth + 1)
        for callee, kind in comp.calls:
            if callee in comps:
                visit(comps[callee], mult, depth + 1)

    visit(entry, 1.0)

    # ring-traffic wire bytes
    traffic = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}
    wire = sum(traffic[k] * v["bytes"] for k, v in coll.items())
    return {
        "flops": flops,
        # TPU-adjusted (pure dtype-convert fusions excluded); raw includes
        # the CPU backend's f32 dot-operand materialization
        "memory_bytes": mem,
        "memory_bytes_raw": mem + convert_mem,
        "convert_bytes": convert_mem,
        "collectives": {k: {"count": v["count"], "bytes": v["bytes"]}
                        for k, v in coll.items()},
        "collective_wire_bytes": wire,
        "n_computations": len(comps) - 1,
    }
