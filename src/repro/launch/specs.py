"""ShapeDtypeStruct input specs for every (arch x shape) dry-run cell.

Same pattern as shannon/kernels: weak-type-correct, shardable stand-ins;
no device allocation. ``input_specs`` covers model inputs (tokens, labels,
modality-stub embeddings); cache specs cover decode-mode state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ShapeConfig
from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.sharding.axes import spec_for
from repro.sharding.rules import ShardPlan


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Model-input ShapeDtypeStructs for one cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        batch = {"tokens": _sds((b, 1), jnp.int32)}
    else:
        batch = {"tokens": _sds((b, s), jnp.int32)}
        if shape.kind == "train":
            batch["labels"] = _sds((b, s), jnp.int32)
    if cfg.frontend == "vision_stub" and shape.kind != "decode":
        batch["prefix_embeds"] = _sds(
            (b, cfg.n_prefix_embeds, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.enc_dec and shape.kind != "decode":
        batch["enc_frames"] = _sds(
            (b, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


def input_shardings(cfg: ModelConfig, shape: ShapeConfig, plan: ShardPlan,
                    mesh) -> dict:
    rules = plan.rules_dict
    bspec = P(rules["batch"], None) if rules else P()
    out = {"tokens": NamedSharding(mesh, bspec)}
    if shape.kind == "train":
        out["labels"] = NamedSharding(mesh, bspec)
    if cfg.frontend == "vision_stub" and shape.kind != "decode":
        out["prefix_embeds"] = NamedSharding(
            mesh, P(rules["batch"], None, None) if rules else P())
    if cfg.enc_dec and shape.kind != "decode":
        out["enc_frames"] = NamedSharding(
            mesh, P(rules["batch"], None, None) if rules else P())
    return out


def abstract_params(cfg: ModelConfig, plan: ShardPlan, max_seq: int):
    """Annotated abstract param tree (ShapeDtypeStruct leaves)."""
    return jax.eval_shape(
        lambda k: M.init_params(cfg, plan, k, max_seq=max_seq),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def abstract_decode_cache(cfg: ModelConfig, plan: ShardPlan, batch: int,
                          max_seq: int):
    return jax.eval_shape(
        lambda: M.init_decode_cache(cfg, plan, batch, max_seq,
                                    jnp.dtype(cfg.dtype)))


def cache_shardings(cfg: ModelConfig, plan: ShardPlan, cache_abs, mesh):
    """Spec tree for the decode cache, mirroring init_decode_cache's
    per-position structure. Attention KV: (None, batch, kv_seq, heads, None)
    — exactly one of kv_seq / heads maps to the model axis (rules.py);
    recurrent states shard batch + their channel dim."""
    rules = plan.rules_dict or {}

    def ns(*ax):
        return NamedSharding(mesh, spec_for(ax, rules))

    out = []
    for pos in range(cfg.layer_period):
        entry = cache_abs[pos]
        if cfg.attention == "mla" and cfg.is_attn_layer(pos):
            # absorbed latent cache [n_per, B, S, lat/rope]; latent dim
            # shards over the model axis (DUS-friendly, scores psum)
            lat = ns(None, "batch", None, "mlp")
            out.append(tuple(lat for _ in entry))
        elif cfg.is_attn_layer(pos) or cfg.enc_dec:
            kv = ns(None, "batch", "kv_seq", "kv_heads", "kv_dh")
            out.append(tuple(kv for _ in entry))     # self (+ cross) K,V
        elif cfg.block == "rwkv":
            out.append((
                ns(None, "batch", None, None),               # x_prev (tm)
                ns(None, "batch", "heads", None, None),      # wkv state
                ns(None, "batch", None, None),               # x_prev (cm)
            ))
        elif cfg.block == "hybrid":
            out.append((
                ns(None, "batch", None, "mlp"),              # conv state
                ns(None, "batch", "mlp", None),              # ssm state
            ))
        else:
            out.append((ns(None, None),))
    return out


def param_shardings(params_annot, mesh, rules):
    from repro.sharding.axes import Annot

    def one(a: Annot):
        return NamedSharding(mesh, spec_for(a.ax, rules))

    return jax.tree.map(one, params_annot,
                        is_leaf=lambda x: isinstance(x, Annot))
