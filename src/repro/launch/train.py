"""Training launcher: end-to-end driver with fault tolerance.

Features (design scales to 1000+ nodes; CPU runs use reduced configs):
  * elastic restart — restores the latest checkpoint onto whatever mesh the
    current invocation has (checkpoints are topology-independent);
  * preemption safety — SIGTERM/SIGINT trigger a final checkpoint before
    exit;
  * deterministic data skip-ahead — the pipeline is counter-based, so a
    restarted job consumes exactly the batches it would have;
  * straggler telemetry — per-step wall time is tracked; steps slower than
    ``straggler_factor`` x the trailing median are logged (at scale this
    feeds the re-mesh decision);
  * multi-host — ``--multihost`` calls jax.distributed.initialize() (no-op
    on a single host).

Example (CPU):
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import signal
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCHS
from repro.data.pipeline import DataConfig, TokenStream
from repro.models import model as M
from repro.sharding.axes import strip
from repro.sharding.rules import unpadded_plan
from repro.train.optimizer import OptConfig
from repro.train.train_step import (TrainConfig, init_train_state,
                                    make_train_step)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--multihost", action="store_true")
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--stop-after", type=int, default=0,
                    help="stop (checkpoint+exit) after N steps — simulated preemption")
    args = ap.parse_args(argv)

    if args.multihost:
        jax.distributed.initialize()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    plan = unpadded_plan(cfg)   # CPU path; the dry-run covers the big mesh

    key = jax.random.key(args.seed)
    params = strip(M.init_params(cfg, plan, key, max_seq=args.seq))
    state = init_train_state(params)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    tcfg = TrainConfig(
        opt=OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps),
        microbatches=args.microbatches)
    step_fn = jax.jit(make_train_step(cfg, plan, tcfg), donate_argnums=(0,))

    data = TokenStream(DataConfig(
        seed=args.seed, vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, n_hosts=jax.process_count(),
        host_id=jax.process_index()))

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if mgr is not None:
        latest = mgr.latest_step()
        if latest is not None:
            state = mgr.restore(latest, state)
            start_step = latest
            print(f"[elastic-restart] resumed from step {latest}")

    stop = {"flag": False}

    def handler(signum, frame):
        print(f"[preempt] signal {signum}: checkpoint + exit")
        stop["flag"] = True

    old = [signal.signal(s, handler) for s in (signal.SIGTERM, signal.SIGINT)]

    losses, times = [], []
    step = start_step
    try:
        for step in range(start_step, args.steps):
            batch = jax.tree.map(jnp.asarray, data.batch(step))
            if cfg.frontend == "vision_stub":
                batch["prefix_embeds"] = jnp.zeros(
                    (args.batch, cfg.n_prefix_embeds, cfg.d_model),
                    jnp.dtype(cfg.dtype))
                batch["labels"] = batch["labels"].at[
                    :, :cfg.n_prefix_embeds].set(-1)
            if cfg.enc_dec:
                batch["enc_frames"] = jnp.zeros(
                    (args.batch, cfg.enc_seq, cfg.d_model),
                    jnp.dtype(cfg.dtype))
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            losses.append(loss)
            times.append(dt)
            if len(times) > 8:
                med = statistics.median(times[-32:])
                if dt > args.straggler_factor * med:
                    print(f"[straggler] step {step}: {dt:.2f}s "
                          f"(median {med:.2f}s)")
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt:.2f}s", flush=True)
            if mgr is not None and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, state, blocking=False)
            if args.stop_after and step - start_step + 1 >= args.stop_after:
                print(f"[preempt-sim] stopping after {args.stop_after} steps")
                break
            if stop["flag"]:
                break
    finally:
        for s, h in zip((signal.SIGTERM, signal.SIGINT), old):
            signal.signal(s, h)
    if mgr is not None:
        mgr.save(step + 1, state, blocking=True)
    result = {"first_loss": losses[0] if losses else None,
              "last_loss": losses[-1] if losses else None,
              "steps_run": len(losses), "final_step": step + 1}
    print(f"done: loss {result['first_loss']:.4f} -> "
          f"{result['last_loss']:.4f} over {result['steps_run']} steps")
    return result


if __name__ == "__main__":
    main()
