"""Small shared utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def shard_map_compat(f, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` across jax versions.

    jax >= 0.6 exposes ``jax.shard_map(..., check_vma=)``; older releases
    only have ``jax.experimental.shard_map.shard_map(..., check_rep=)``
    (same flag, earlier name).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check_vma)


def set_mesh_compat(mesh):
    """Context manager entering ``mesh``: ``jax.set_mesh`` on new jax,
    the Mesh's own context-manager protocol on older releases."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def ceil_div(a, b):
    """Ceiling division for ints or int arrays."""
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    """Round ``a`` up to the next multiple of ``b``."""
    return int(ceil_div(a, b) * b)


def exclusive_cumsum(x: jax.Array, axis: int = 0) -> jax.Array:
    """Exclusive prefix sum along ``axis``."""
    inc = jnp.cumsum(x, axis=axis)
    return inc - x


def tree_num_params(tree) -> int:
    """Total number of array elements in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_num_bytes(tree) -> int:
    """Total number of bytes in a pytree."""
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def l2_sq(a: jax.Array, b: jax.Array) -> jax.Array:
    """Pairwise squared L2 distance, ``a [N,D]`` x ``b [M,D]`` -> ``[N,M]``.

    Uses the ||a||^2 - 2 a.b + ||b||^2 expansion so the inner term hits the
    MXU as a single matmul.
    """
    aa = jnp.sum(a * a, axis=-1, keepdims=True)       # [N,1]
    bb = jnp.sum(b * b, axis=-1, keepdims=True).T     # [1,M]
    ab = a @ b.T                                       # [N,M]
    return aa - 2.0 * ab + bb
