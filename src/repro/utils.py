"""Small shared utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def ceil_div(a, b):
    """Ceiling division for ints or int arrays."""
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    """Round ``a`` up to the next multiple of ``b``."""
    return int(ceil_div(a, b) * b)


def exclusive_cumsum(x: jax.Array, axis: int = 0) -> jax.Array:
    """Exclusive prefix sum along ``axis``."""
    inc = jnp.cumsum(x, axis=axis)
    return inc - x


def tree_num_params(tree) -> int:
    """Total number of array elements in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_num_bytes(tree) -> int:
    """Total number of bytes in a pytree."""
    return sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def l2_sq(a: jax.Array, b: jax.Array) -> jax.Array:
    """Pairwise squared L2 distance, ``a [N,D]`` x ``b [M,D]`` -> ``[N,M]``.

    Uses the ||a||^2 - 2 a.b + ||b||^2 expansion so the inner term hits the
    MXU as a single matmul.
    """
    aa = jnp.sum(a * a, axis=-1, keepdims=True)       # [N,1]
    bb = jnp.sum(b * b, axis=-1, keepdims=True).T     # [1,M]
    ab = a @ b.T                                       # [N,M]
    return aa - 2.0 * ab + bb
