"""Per-(arch, mesh, shape) sharding plans.

The production mesh is fixed at (data=16, model=16) (+pod=2 multi-pod), but
the assigned architectures have head/vocab/expert counts that do not all
divide 16. A ``ShardPlan`` resolves this with a *padding policy*
(DESIGN.md §6):

  * q-heads padded to a model-axis multiple. Two candidates are costed and
    the cheaper taken: (A) preserve the GQA group ratio g = Hq/Hkv by
    padding KV heads too, or (B) pad q heads only to a multiple of the
    axis that is divisible by Hkv (the group ratio grows; padded heads are
    inert via zero out-projection columns).
  * KV heads sharded when divisible, else replicated (GQA KV is small).
  * vocab padded to a multiple of model_axis*128 (Megatron-standard);
    padded logits masked to -inf.
  * MoE experts padded to a model-axis multiple; router logits for padded
    experts are -inf.

The padding waste is *measured*, not hidden: MODEL_FLOPS in the roofline
table uses the unpadded spec while HLO_FLOPS includes the pad (see
EXPERIMENTS.md §Roofline), and §Perf attacks the gap.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.utils import round_up


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    model_size: int                  # model-axis extent (1 = unsharded)
    n_heads_padded: int
    n_kv_heads_padded: int
    kv_sharded: bool
    vocab_padded: int
    n_experts_padded: int
    rules: tuple | None              # logical->mesh rules as sorted tuple
    batch_axes: tuple = ("data",)

    @property
    def rules_dict(self) -> dict | None:
        return dict(self.rules) if self.rules is not None else None

    @property
    def group_size(self) -> int:
        return self.n_heads_padded // self.n_kv_heads_padded


def _plan_heads(hq: int, hkv: int, m: int) -> tuple[int, int]:
    """Padded (q_heads, kv_heads) for model-axis extent m."""
    if hq % m == 0 and hq % hkv == 0:
        return hq, hkv
    g = max(hq // hkv, 1)
    # candidate A: preserve the group ratio, pad kv
    kv_a = hkv
    while (g * kv_a) % m != 0:
        kv_a += 1
    q_a = g * kv_a
    # candidate B: pad q only; group ratio grows
    q_b = round_up(hq, m)
    while q_b % hkv != 0:
        q_b += m
    if q_a <= q_b:
        return q_a, kv_a
    return q_b, hkv


def make_plan(cfg: ModelConfig, mesh_axes: dict[str, int] | None,
              shape_kind: str = "train",
              global_batch: int | None = None) -> ShardPlan:
    """Build the plan. ``mesh_axes`` e.g. {"data":16, "model":16} or
    {"pod":2, "data":16, "model":16}; None = single-device (tests).
    ``global_batch`` lets small-batch shapes (long_500k: batch=1) trade
    batch sharding for KV-sequence sharding over the data axes."""
    if mesh_axes is None or mesh_axes.get("model", 1) == 1:
        return ShardPlan(
            model_size=1,
            n_heads_padded=cfg.n_heads,
            n_kv_heads_padded=cfg.n_kv_heads,
            kv_sharded=False,
            vocab_padded=cfg.vocab_size,
            n_experts_padded=cfg.n_experts,
            rules=None,
        )
    m = mesh_axes["model"]
    hq_p, hkv_p = _plan_heads(cfg.n_heads, cfg.n_kv_heads, m)
    kv_sharded = hkv_p % m == 0
    vocab_p = round_up(cfg.vocab_size, m * 128)
    ne_p = round_up(cfg.n_experts, m) if cfg.moe else 0

    dp = ("pod", "data") if "pod" in mesh_axes else ("data",)
    batch_total = 1
    for a in dp:
        batch_total *= mesh_axes[a]
    batch_shardable = global_batch is None or global_batch % batch_total == 0

    rules = {
        "batch": (dp if len(dp) > 1 else dp[0]) if batch_shardable else None,
        "seq": None,
        # residual-stream sequence parallelism (Megatron-SP): stored
        # activations shard their seq dim over the model axis
        "seq_sp": "model" if shape_kind in ("train", "prefill") else None,
        # decode: the KV cache shards over the model axis on its *head* dim
        # when kv-heads divide (or MLA, whose padded heads always divide);
        # otherwise on its head_dim ("kv_dh") — always a multiple of the
        # axis. Sequence-dim sharding was tried and refuted: GSPMD lowers
        # the per-token dynamic_update_slice on a sharded dim as a
        # whole-buffer select, rewriting the full local cache every step
        # (EXPERIMENTS.md §Perf iteration 3).
        "kv_seq": None,
        "kv_dh": (
            "model" if shape_kind == "decode"
            and not (kv_sharded or cfg.attention == "mla") else None),
        "heads": "model",
        "kv_heads": "model" if kv_sharded else None,
        "embed": None,
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "dispatch": dp if len(dp) > 1 else dp[0],
        "kv_lora": None,
        "q_lora": None,
    }
    return ShardPlan(
        model_size=m,
        n_heads_padded=hq_p,
        n_kv_heads_padded=hkv_p,
        kv_sharded=kv_sharded,
        vocab_padded=vocab_p,
        n_experts_padded=ne_p,
        rules=tuple(sorted(rules.items())),
        batch_axes=dp,
    )


def unpadded_plan(cfg: ModelConfig) -> ShardPlan:
    return make_plan(cfg, None)
