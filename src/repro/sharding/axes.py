"""Logical-axis sharding (MaxText-style rules tables).

Model code annotates params and activations with *logical* axis names
("batch", "seq", "heads", "mlp", "vocab", "expert", ...). A rules table —
chosen per (arch, mesh) by ``repro.sharding.rules`` — maps logical names to
mesh axes. Outside a mesh context the constraints are no-ops, so the same
model code runs single-device tests and 512-chip dry-runs unchanged.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


@dataclasses.dataclass
class Annot:
    """A parameter annotated with logical axes (one name or None per dim).

    Registered as a pytree node (value is the child, axes are aux data) so
    annotated trees flow through jax.eval_shape — the dry-run builds
    abstract param trees without materializing 50B params.
    """
    v: Any
    ax: tuple


jax.tree_util.register_pytree_node(
    Annot,
    lambda a: ((a.v,), a.ax),
    lambda ax, ch: Annot(ch[0], ax),
)


def annot(v, *ax) -> Annot:
    assert v.ndim == len(ax), (v.shape, ax)
    return Annot(v, tuple(ax))


def _is_annot(x) -> bool:
    return isinstance(x, Annot)


def strip(tree):
    """Annotated param tree -> plain array tree."""
    return jax.tree.map(lambda a: a.v, tree, is_leaf=_is_annot)


def logical_axes(tree):
    """Annotated param tree -> logical-axes tree (same structure)."""
    return jax.tree.map(lambda a: a.ax, tree, is_leaf=_is_annot)


def current_rules() -> dict | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: dict):
    """Activate a logical->mesh rules table for constraints below."""
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def spec_for(ax: tuple, rules: dict | None = None) -> P:
    """Resolve logical axes -> PartitionSpec under the given rules."""
    rules = current_rules() if rules is None else rules
    if rules is None:
        return P()
    return P(*(rules.get(a) if a is not None else None for a in ax))


def constrain(x, *ax):
    """with_sharding_constraint by logical axes; no-op without rules."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec_for(ax, rules))


def specs_tree(annot_tree, rules: dict | None = None):
    """Annotated param tree -> PartitionSpec tree (for jit in_shardings)."""
    return jax.tree.map(lambda a: spec_for(a.ax, rules), annot_tree,
                        is_leaf=_is_annot)
