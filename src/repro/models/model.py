"""Model assembly: decoder LMs (dense/MoE/SSM/hybrid/VLM) and the whisper
encoder-decoder, all driven by ModelConfig + ShardPlan.

Layers are stacked and scanned per *period* (the smallest repeating layer
pattern: 1 for uniform archs, 8 for jamba's mamba/attn interleave) — this
keeps the HLO size O(period) instead of O(n_layers) for 512-device
compiles, and gives remat a natural boundary (one residual checkpoint per
period when cfg.remat).

Decode-mode caches are pytrees stacked over periods and scanned alongside
the layer params; attention caches carry the "kv_seq" sharded axis
(DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import rwkv as rwkv_mod
from repro.models.common import (
    apply_norm, embed_init, embed_lookup, lm_head, norm_init,
    sinusoid_positions,
)
from repro.sharding.axes import annot, constrain
from repro.sharding.rules import ShardPlan


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, plan: ShardPlan, pos: int) -> dict:
    """One layer's params for period-position ``pos``."""
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": norm_init(ks[0], cfg.d_model, cfg.norm)}
    if cfg.is_attn_layer(pos):
        if cfg.attention == "mla":
            p["attn"] = attn.init_mla(ks[1], cfg, plan)
        else:
            p["attn"] = attn.init_gqa(ks[1], cfg, plan)
    elif cfg.block == "rwkv":
        p["tm"] = rwkv_mod.init_time_mix(ks[1], cfg, plan)
    elif cfg.block == "hybrid":
        p["mamba"] = mamba_mod.init_mamba(ks[1], cfg, plan)
    p["ln2"] = norm_init(ks[2], cfg.d_model, cfg.norm)
    if cfg.is_moe_layer(pos):
        p["moe"] = mlp_mod.init_moe(ks[3], cfg, plan)
    elif cfg.block == "rwkv":
        p["cm"] = rwkv_mod.init_channel_mix(ks[3], cfg)
    else:
        p["mlp"] = mlp_mod.init_mlp(ks[3], cfg.d_model, cfg.d_ff,
                                    cfg.mlp_act)
    return p


def _init_enc_layer(key, cfg: ModelConfig, plan: ShardPlan) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "ln1": norm_init(ks[0], cfg.d_model, cfg.norm),
        "attn": attn.init_gqa(ks[1], cfg, plan),
        "ln2": norm_init(ks[2], cfg.d_model, cfg.norm),
        "mlp": mlp_mod.init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp_act),
    }


def _init_dec_layer(key, cfg: ModelConfig, plan: ShardPlan,
                    pos: int = 0) -> dict:
    """Whisper decoder layer: self-attn + cross-attn + mlp."""
    ks = jax.random.split(key, 6)
    return {
        "ln1": norm_init(ks[0], cfg.d_model, cfg.norm),
        "attn": attn.init_gqa(ks[1], cfg, plan),
        "ln_x": norm_init(ks[2], cfg.d_model, cfg.norm),
        "xattn": attn.init_gqa(ks[3], cfg, plan),
        "ln2": norm_init(ks[4], cfg.d_model, cfg.norm),
        "mlp": mlp_mod.init_mlp(ks[5], cfg.d_model, cfg.d_ff, cfg.mlp_act),
    }


def init_params(cfg: ModelConfig, plan: ShardPlan, key,
                max_seq: int = 4096) -> dict:
    """Annotated param tree. ``axes.strip`` for runtime values."""
    period = cfg.layer_period
    n_periods = cfg.n_layers // period
    assert cfg.n_layers % period == 0
    keys = jax.random.split(key, 8)

    params: dict = {
        "embed": embed_init(keys[0], plan.vocab_padded, cfg.d_model),
        "final_norm": norm_init(keys[1], cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(keys[6], plan.vocab_padded, cfg.d_model)
    mk_layer = _init_dec_layer if cfg.enc_dec else _init_layer
    layers = []
    for pos in range(period):
        per_period = [
            mk_layer(jax.random.fold_in(keys[2], p * period + pos),
                     cfg, plan, pos)
            for p in range(n_periods)
        ]
        stacked = jax.tree.map(
            lambda *xs: _stack_annot(xs), *per_period,
            is_leaf=_is_annot)
        layers.append(stacked)
    params["layers"] = layers

    if cfg.enc_dec:
        enc_layers = [
            _init_enc_layer(jax.random.fold_in(keys[3], i), cfg, plan)
            for i in range(cfg.n_enc_layers)
        ]
        params["encoder"] = {
            "layers": jax.tree.map(lambda *xs: _stack_annot(xs),
                                   *enc_layers, is_leaf=_is_annot),
            "ln_post": norm_init(keys[4], cfg.d_model, cfg.norm),
        }
        params["dec_pos"] = {"table": annot(
            jax.random.normal(keys[5], (max_seq, cfg.d_model),
                              jnp.float32) * 0.01, None, "embed")}
    return params


def _is_annot(x):
    from repro.sharding.axes import Annot
    return isinstance(x, Annot)


def _stack_annot(xs):
    from repro.sharding.axes import Annot
    return Annot(jnp.stack([x.v for x in xs]), (None,) + xs[0].ax)


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _apply_layer_full(lp, cfg: ModelConfig, plan: ShardPlan, pos: int,
                      x, positions, impl: str, collect_cache: bool,
                      init_state=None):
    """One sub-layer (period position). Returns (x, aux, cache_out)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    h = apply_norm(lp["ln1"], x)
    if cfg.is_attn_layer(pos):
        f = attn.mla_full if cfg.attention == "mla" else attn.gqa_full
        o, kv = f(lp["attn"], cfg, plan, h, positions, causal=True,
                  impl=impl)
        x = x + o
        if collect_cache:
            cache = kv
    elif cfg.block == "rwkv":
        b = x.shape[0]
        st = init_state if init_state is not None else (
            jnp.zeros((b, 1, cfg.d_model), x.dtype),
            jnp.zeros((b, plan.n_heads_padded, cfg.rwkv_head_size,
                       cfg.rwkv_head_size), jnp.float32))
        o, st_new = rwkv_mod.time_mix(lp["tm"], cfg, plan, h, st, impl=impl)
        x = x + o
        if collect_cache:
            cache = st_new
    elif cfg.block == "hybrid":
        b = x.shape[0]
        st = init_state if init_state is not None else \
            mamba_mod.init_mamba_state(cfg, b, x.dtype)
        o, st_new = mamba_mod.mamba_block(lp["mamba"], cfg, plan, h, st,
                                          impl=impl)
        x = x + o
        if collect_cache:
            cache = st_new

    h = apply_norm(lp["ln2"], x)
    if cfg.is_moe_layer(pos):
        o, aux = mlp_mod.moe(lp["moe"], cfg, plan, h)
        x = x + o
    elif cfg.block == "rwkv":
        b = x.shape[0]
        st = jnp.zeros((b, 1, cfg.d_model), x.dtype)
        o, cm_state = rwkv_mod.channel_mix(lp["cm"], cfg, h, st)
        x = x + o
        if collect_cache:
            cache = cache + (cm_state,) if cache is not None else (cm_state,)
    else:
        x = x + mlp_mod.apply_mlp(lp["mlp"], h, cfg.mlp_act)
    return x, aux, cache


def forward(params, cfg: ModelConfig, plan: ShardPlan, batch: dict,
            impl: str = "xla", collect_cache: bool = False):
    """Full-sequence forward.

    batch: tokens [B,S] (+ prefix_embeds for vlm, enc_frames for audio).
    Returns (logits [B,S,V], aux_loss, caches | None).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    x = embed_lookup(params["embed"], tokens, dtype)

    if cfg.frontend == "vision_stub" and "prefix_embeds" in batch:
        n_img = batch["prefix_embeds"].shape[1]
        x = jnp.concatenate(
            [batch["prefix_embeds"].astype(dtype), x[:, n_img:]], axis=1)

    enc_kv_all = None
    if cfg.enc_dec:
        enc_out = _encode(params, cfg, plan, batch["enc_frames"], impl)
        x = x + params["dec_pos"]["table"].astype(dtype)[None, :s]
        enc_kv_all = enc_out

    positions = jnp.arange(s)
    x = constrain(x, "batch", "seq_sp", None)
    period = cfg.layer_period

    def period_body(carry, lp_stack):
        x, aux = carry
        caches = []
        for pos in range(period):
            if cfg.enc_dec:
                x, a, c = _apply_dec_layer_full(
                    lp_stack[pos], cfg, plan, x, positions, enc_kv_all,
                    impl, collect_cache)
            else:
                x, a, c = _apply_layer_full(
                    lp_stack[pos], cfg, plan, pos, x, positions, impl,
                    collect_cache)
            aux = aux + a
            caches.append(c)
        return (x, aux), tuple(caches)

    body = jax.checkpoint(period_body) if cfg.remat else period_body
    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), tuple(params["layers"]))

    x = apply_norm(params["final_norm"], x)
    logits = lm_head(params.get("head", params["embed"]), x, cfg.vocab_size)
    return logits, aux, (caches if collect_cache else None)


def _apply_dec_layer_full(lp, cfg, plan, x, positions, enc_out, impl,
                          collect_cache):
    """Whisper decoder layer (self + cross + mlp)."""
    cache = None
    h = apply_norm(lp["ln1"], x)
    o, kv = attn.gqa_full(lp["attn"], cfg, plan, h, positions, causal=True,
                          impl=impl)
    x = x + o
    if collect_cache:
        cache = kv
    h = apply_norm(lp["ln_x"], x)
    ekv = attn.cross_kv(lp["xattn"], cfg, plan, enc_out)
    x = x + attn.cross_full(lp["xattn"], cfg, plan, h, ekv)
    h = apply_norm(lp["ln2"], x)
    x = x + mlp_mod.apply_mlp(lp["mlp"], h, cfg.mlp_act)
    return x, jnp.zeros((), jnp.float32), cache


def _encode(params, cfg: ModelConfig, plan: ShardPlan, frames, impl):
    """Whisper encoder over stub frame embeddings [B, Senc, d]."""
    dtype = jnp.dtype(cfg.dtype)
    b, s, _ = frames.shape
    x = frames.astype(dtype) + sinusoid_positions(
        s, cfg.d_model).astype(dtype)[None]
    positions = jnp.arange(s)

    def body(x, lp):
        h = apply_norm(lp["ln1"], x)
        o, _ = attn.gqa_full(lp["attn"], cfg, plan, h, positions,
                             causal=False, impl=impl)
        x = x + o
        h = apply_norm(lp["ln2"], x)
        x = x + mlp_mod.apply_mlp(lp["mlp"], h, cfg.mlp_act)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return apply_norm(params["encoder"]["ln_post"], x)


# ---------------------------------------------------------------------------
# decode (one token, stateful caches)
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, plan: ShardPlan, batch: int,
                      max_seq: int, dtype) -> list:
    """Stacked-over-periods cache pytree per period position."""
    period = cfg.layer_period
    n_per = cfg.n_layers // period
    caches = []
    for pos in range(period):
        if cfg.attention == "mla" and cfg.is_attn_layer(pos):
            # absorbed-form latent cache (§Perf iteration 5): 26.6x fewer
            # bytes than expanded per-head K/V
            caches.append((
                jnp.zeros((n_per, batch, max_seq, cfg.kv_lora_rank), dtype),
                jnp.zeros((n_per, batch, max_seq, cfg.qk_rope_dim), dtype),
            ))
        elif cfg.is_attn_layer(pos) or cfg.enc_dec:
            hkv = plan.n_kv_heads_padded
            dh = cfg.head_dim
            dv = cfg.head_dim
            entry = (
                jnp.zeros((n_per, batch, max_seq, hkv, dh), dtype),
                jnp.zeros((n_per, batch, max_seq, hkv, dv), dtype),
            )
            if cfg.enc_dec:   # + cross-attention K,V (filled at prefill)
                entry = entry + (
                    jnp.zeros((n_per, batch, cfg.enc_seq, hkv, dh), dtype),
                    jnp.zeros((n_per, batch, cfg.enc_seq, hkv, dv), dtype),
                )
            caches.append(entry)
        elif cfg.block == "rwkv":
            caches.append((
                jnp.zeros((n_per, batch, 1, cfg.d_model), dtype),
                jnp.zeros((n_per, batch, plan.n_heads_padded,
                           cfg.rwkv_head_size, cfg.rwkv_head_size),
                          jnp.float32),
                jnp.zeros((n_per, batch, 1, cfg.d_model), dtype),
            ))
        elif cfg.block == "hybrid":
            caches.append((
                jnp.zeros((n_per, batch, cfg.mamba_d_conv - 1,
                           cfg.mamba_d_inner), dtype),
                jnp.zeros((n_per, batch, cfg.mamba_d_inner,
                           cfg.mamba_d_state), jnp.float32),
            ))
        else:
            caches.append((jnp.zeros((n_per, 1), dtype),))
    return caches


def decode_step(params, cfg: ModelConfig, plan: ShardPlan, tokens,
                caches, pos, enc_out=None, impl: str = "xla",
                embeds=None):
    """One decode step. tokens [B,1]; pos: scalar int32 absolute position.
    ``embeds`` [B,1,d] overrides token embedding (VLM image prefix).

    Attention caches are *carried* through the layer scan as full stacks
    and updated one token slot at (layer, pos) — returning per-layer
    caches as scan outputs would rewrite a whole layer slice per step
    (§Perf iteration 3b). Recurrent states (rwkv/mamba) are small and
    stay scan-stacked. Returns (logits [B,1,V], new caches)."""
    dtype = jnp.dtype(cfg.dtype)
    b = tokens.shape[0]
    if embeds is not None:
        x = embeds.astype(dtype)
    else:
        x = embed_lookup(params["embed"], tokens, dtype)
    if cfg.enc_dec:
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"]["table"].astype(dtype), pos, 1, 0)[None]
    x = constrain(x, "batch", None, None)
    period = cfg.layer_period
    head_ax = "heads" if cfg.attention == "mla" else "kv_heads"
    new_caches = []

    for pp in range(period):
        lp_stack = params["layers"][pp]
        entry = caches[pp]
        n_per = jax.tree.leaves(lp_stack)[0].shape[0]

        if cfg.is_attn_layer(pp) or cfg.enc_dec:
            sk, sv = entry[0], entry[1]
            cross = tuple(entry[2:])          # whisper cross KV (read-only)

            def body(carry, xs, pp=pp, cross=cross):
                x, sk, sv = carry
                lp, li = xs
                h = apply_norm(lp["ln1"], x)
                if cfg.attention == "mla":
                    o, sk, sv = attn.mla_decode_absorbed_stacked(
                        lp["attn"], cfg, plan, h, sk, sv, li, pos)
                else:
                    o, sk, sv = attn.decode_attn_stacked(
                        lp["attn"], cfg, plan, h, sk, sv, li, pos,
                        head_ax=head_ax, mla=False)
                x = x + o
                if cfg.enc_dec:
                    hx = apply_norm(lp["ln_x"], x)
                    ek = jax.lax.dynamic_slice(
                        cross[0], (li, 0, 0, 0, 0),
                        (1,) + cross[0].shape[1:])[0]
                    ev = jax.lax.dynamic_slice(
                        cross[1], (li, 0, 0, 0, 0),
                        (1,) + cross[1].shape[1:])[0]
                    x = x + attn.cross_full(lp["xattn"], cfg, plan, hx,
                                            (ek, ev))
                h = apply_norm(lp["ln2"], x)
                if cfg.is_moe_layer(pp):
                    o, _ = mlp_mod.moe(lp["moe"], cfg, plan, h)
                    x = x + o
                else:
                    x = x + mlp_mod.apply_mlp(lp["mlp"], h, cfg.mlp_act)
                return (x, sk, sv), None

            (x, sk, sv), _ = jax.lax.scan(
                body, (x, sk, sv),
                (lp_stack, jnp.arange(n_per, dtype=jnp.int32)))
            new_caches.append((sk, sv) + cross)
            continue

        def body(x, xs, pp=pp):
            lp, ch = xs
            h = apply_norm(lp["ln1"], x)
            if cfg.block == "rwkv":
                o, st = rwkv_mod.time_mix(lp["tm"], cfg, plan, h,
                                          (ch[0], ch[1]), impl="xla")
                x = x + o
                ch_new = st
            elif cfg.block == "hybrid":
                o, st = mamba_mod.mamba_block(lp["mamba"], cfg, plan, h,
                                              (ch[0], ch[1]), impl="xla",
                                              chunk=1)
                x = x + o
                ch_new = st
            else:
                ch_new = ch
            h = apply_norm(lp["ln2"], x)
            if cfg.is_moe_layer(pp):
                o, _ = mlp_mod.moe(lp["moe"], cfg, plan, h)
                x = x + o
            elif cfg.block == "rwkv":
                o, cm_state = rwkv_mod.channel_mix(lp["cm"], cfg, h, ch[2])
                x = x + o
                ch_new = ch_new + (cm_state,)
            else:
                x = x + mlp_mod.apply_mlp(lp["mlp"], h, cfg.mlp_act)
            return x, ch_new

        x, nc = jax.lax.scan(body, x, (lp_stack, entry))
        new_caches.append(nc)

    x = apply_norm(params["final_norm"], x)
    logits = lm_head(params.get("head", params["embed"]), x, cfg.vocab_size)
    return logits, new_caches


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def lm_loss(logits, labels, aux=0.0, aux_coef: float = 0.01):
    """Cross-entropy with -1-masked labels + MoE aux loss."""
    v = logits.shape[-1]
    mask = labels >= 0
    lab = jnp.clip(labels, 0)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, lab[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss + aux_coef * aux
