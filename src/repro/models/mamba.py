"""Mamba (S6) block for the Jamba hybrid architecture.

XLA path: scan-of-checkpointed-scans over time — stores only chunk-boundary
[B, d_inner, n] states for the backward pass (the JAX analogue of the CUDA
kernel's recompute-in-backward; DESIGN.md §2). Pallas fast path:
kernels/mamba_scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense, dense_init
from repro.sharding.axes import annot, constrain
from repro.sharding.rules import ShardPlan


def init_mamba(key, cfg: ModelConfig, plan: ShardPlan) -> dict:
    d = cfg.d_model
    di = cfg.mamba_d_inner
    n = cfg.mamba_d_state
    dr = cfg.dt_rank
    kc = cfg.mamba_d_conv
    ks = jax.random.split(key, 8)
    # S4D-real initialization for A
    a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
    return {
        "w_in": dense_init(ks[0], d, 2 * di, "embed", "mlp"),
        "conv_w": annot(
            jax.random.normal(ks[1], (kc, di), jnp.float32) * (1 / kc) ** 0.5,
            None, "mlp"),
        "conv_b": annot(jnp.zeros((di,), jnp.float32), "mlp"),
        "w_x": dense_init(ks[2], di, dr + 2 * n, "mlp", None),
        "w_dt": dense_init(ks[3], dr, di, None, "mlp"),
        "dt_bias": annot(
            jnp.log(jnp.exp(jax.random.uniform(
                ks[4], (di,), jnp.float32, 1e-3, 1e-1)) - 1.0), "mlp"),
        "a_log": annot(jnp.log(a), "mlp", None),
        "d": annot(jnp.ones((di,), jnp.float32), "mlp"),
        "w_out": dense_init(ks[5], di, d, "mlp", "embed"),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv1d. x [B,S,di]; w [K,di]; returns (y, new_state
    [B,K-1,di])."""
    k = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)             # [B,S+K-1,di]
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    return y + b[None, None, :], xp[:, -(k - 1):]


def _ssm_sequential(u, delta, a, b, c, d, h0, chunk: int):
    """Selective scan, memory-bounded. u,delta [B,T,di]; b,c [B,T,n];
    h0 [B,di,n]. Returns (y [B,T,di], h_final)."""
    bsz, t, di = u.shape

    def inner(h, xs):
        u_t, dt_t, b_t, c_t = xs                              # [B,di],[B,n]
        da = jnp.exp(dt_t[..., None] * a[None])               # [B,di,n]
        h = da * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    n_chunks = max(t // chunk, 1)
    chunk = t // n_chunks

    @jax.checkpoint
    def chunk_fn(h, xs):
        uc, dtc, bc, cc = xs                                  # [B,c,...]
        h, y = jax.lax.scan(inner, h, (uc.transpose(1, 0, 2),
                                       dtc.transpose(1, 0, 2),
                                       bc.transpose(1, 0, 2),
                                       cc.transpose(1, 0, 2)))
        return h, y.transpose(1, 0, 2)

    def rs(x):
        return x.reshape(bsz, n_chunks, chunk, x.shape[-1]).transpose(
            1, 0, 2, 3)

    h, ys = jax.lax.scan(chunk_fn, h0, (rs(u), rs(delta), rs(b), rs(c)))
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, t, di)
    return y + d[None, None, :] * u, h


def mamba_block(p, cfg: ModelConfig, plan: ShardPlan, x, state,
                impl: str = "xla", chunk: int = 64):
    """x [B,S,d]; state = (conv_state [B,K-1,di], h [B,di,n]).
    Returns (out [B,S,d], new_state)."""
    b, s, _ = x.shape
    di = cfg.mamba_d_inner
    n = cfg.mamba_d_state
    dr = cfg.dt_rank
    conv_state, h0 = state

    xz = dense(p["w_in"], x)                                  # [B,S,2di]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = constrain(xin, "batch", "seq", "mlp")
    xc, conv_state = _causal_conv(xin, p["conv_w"].astype(x.dtype),
                                  p["conv_b"].astype(x.dtype), conv_state)
    xc = jax.nn.silu(xc)

    xdbc = dense(p["w_x"], xc)                                # [B,S,dr+2n]
    dt_r, b_in, c_in = jnp.split(xdbc, [dr, dr + n], axis=-1)
    delta = jax.nn.softplus(
        dense(p["w_dt"], dt_r).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"])                                  # [di,n] (<0)

    if impl.startswith("pallas"):
        from repro.kernels.mamba_scan.ops import mamba_scan
        y = mamba_scan(xc, delta, a, b_in.astype(jnp.float32),
                       c_in.astype(jnp.float32), p["d"],
                       interpret=(impl == "pallas_interpret"),
                       block_d=min(128, di))
        h_new = h0  # kernel path starts from zero state (prefill)
    else:
        y, h_new = _ssm_sequential(
            xc.astype(jnp.float32), delta, a, b_in.astype(jnp.float32),
            c_in.astype(jnp.float32), p["d"].astype(jnp.float32),
            h0.astype(jnp.float32), chunk)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = constrain(y, "batch", "seq", "mlp")
    out = dense(p["w_out"], y)
    return constrain(out, "batch", "seq_sp", None), (conv_state, h_new)


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> tuple:
    return (
        jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.mamba_d_inner), dtype),
        jnp.zeros((batch, cfg.mamba_d_inner, cfg.mamba_d_state),
                  jnp.float32),
    )
