"""RWKV6 (Finch) block: time-mix with data-dependent decay + channel-mix.

XLA path: the WKV recurrence runs as a scan-of-checkpointed-scans (outer
scan over chunks, rematerialized inner scan over tokens), which bounds
activation memory to chunk-boundary states — the TPU-training analogue of
the CUDA kernel's recompute-in-backward. The Pallas kernel
(kernels/wkv6) is the deployment fast path; both share the ref oracle.

Heads are padded per ShardPlan exactly like attention heads (DESIGN.md §6):
time-mix projections produce the padded head space and padded heads are
masked before the output projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense, dense_init
from repro.sharding.axes import annot, constrain
from repro.sharding.rules import ShardPlan

_LORA_RANK = 64


def init_time_mix(key, cfg: ModelConfig, plan: ShardPlan) -> dict:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    hp = plan.n_heads_padded
    da = hp * hs                                  # padded attention dim
    ks = jax.random.split(key, 12)
    p = {
        # ddlerp token-shift mixing coefficients for r,k,v,w,g
        "mu": annot(jax.random.uniform(ks[0], (5, d), jnp.float32), None,
                    "embed"),
        "w_r": dense_init(ks[1], d, da, "embed", "heads"),
        "w_k": dense_init(ks[2], d, da, "embed", "heads"),
        "w_v": dense_init(ks[3], d, da, "embed", "heads"),
        "w_g": dense_init(ks[4], d, da, "embed", "heads"),
        # data-dependent decay: w = w0 + tanh(x_w A) B  (LoRA, §RWKV6)
        "w0": annot(jnp.full((da,), -0.6, jnp.float32), "heads"),
        "w_lora_a": dense_init(ks[5], d, _LORA_RANK, "embed", None),
        "w_lora_b": dense_init(ks[6], _LORA_RANK, da, None, "heads"),
        "u": annot(jax.random.normal(ks[7], (hp, hs), jnp.float32) * 0.1,
                   "heads", None),
        "ln_scale": annot(jnp.ones((da,), jnp.float32), "heads"),
        "ln_bias": annot(jnp.zeros((da,), jnp.float32), "heads"),
        "w_o": dense_init(ks[8], da, d, "heads", "embed"),
    }
    return p


def _token_shift(x, x_prev):
    """[B,S,d] -> previous-token stream; x_prev [B,1,d] carries across."""
    return jnp.concatenate([x_prev, x[:, :-1]], axis=1)


def _wkv_sequential(r, k, v, w, u, s0, chunk: int):
    """Scan-of-checkpointed-scans WKV. r,k,w [B,T,H,dk]; v [B,T,H,dv];
    u [H,dk]; s0 [B,H,dk,dv]. Returns (y [B,T,H,dv], s_final)."""
    b, t, h, dk = r.shape
    dv = v.shape[-1]
    n = max(t // chunk, 1)
    chunk = t // n

    def inner(s, xs):
        r_t, k_t, v_t, w_t = xs                   # [B,H,dk]/[B,H,dv]
        kv = k_t[..., :, None] * v_t[..., None, :]            # [B,H,dk,dv]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, y

    @jax.checkpoint
    def chunk_fn(s, xs):
        rc, kc, vc, wc = xs                       # [B,c,H,*]
        s, y = jax.lax.scan(inner, s,
                            (rc.transpose(1, 0, 2, 3),
                             kc.transpose(1, 0, 2, 3),
                             vc.transpose(1, 0, 2, 3),
                             wc.transpose(1, 0, 2, 3)))
        return s, y.transpose(1, 0, 2, 3)         # [B,c,H,dv]

    def reshape(x):
        return x.reshape(b, n, chunk, *x.shape[2:]).transpose(1, 0, 2, 3, 4)

    s, ys = jax.lax.scan(chunk_fn, s0,
                         (reshape(r), reshape(k), reshape(v), reshape(w)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, h, dv)
    return y, s


def _group_norm(y, scale, bias, h, hs, eps: float = 1e-5):
    """Per-head LayerNorm (RWKV 'ln_x'). y [B,S,H*hs]."""
    shp = y.shape
    yf = y.astype(jnp.float32).reshape(*shp[:-1], h, hs)
    mu = jnp.mean(yf, -1, keepdims=True)
    var = jnp.var(yf, -1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + eps)
    yf = yf.reshape(shp) * scale + bias
    return yf


def time_mix(p, cfg: ModelConfig, plan: ShardPlan, x, state,
             impl: str = "xla", chunk: int = 16):
    """RWKV6 time mixing. x [B,S,d]; state = (x_prev [B,1,d],
    s [B,H,dk,dv]). Returns (out, new_state)."""
    b, s_len, d = x.shape
    hs = cfg.rwkv_head_size
    hp = plan.n_heads_padded
    x_prev, wkv_state = state
    xs = _token_shift(x, x_prev)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + (xs - x) * mu[i] for i in range(5))

    r = dense(p["w_r"], xr).reshape(b, s_len, hp, hs)
    k = dense(p["w_k"], xk).reshape(b, s_len, hp, hs)
    v = dense(p["w_v"], xv).reshape(b, s_len, hp, hs)
    g = dense(p["w_g"], xg)
    lora = jnp.tanh(dense(p["w_lora_a"], xw))
    w_raw = p["w0"].astype(jnp.float32) \
        + dense(p["w_lora_b"], lora, dtype=jnp.float32)
    w = jnp.exp(-jnp.exp(w_raw.astype(jnp.float32)))          # decay in (0,1)
    w = w.reshape(b, s_len, hp, hs)
    r = constrain(r, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "heads", None)
    v = constrain(v, "batch", "seq", "heads", None)

    if impl.startswith("pallas"):
        from repro.kernels.wkv6.ops import wkv6
        y = wkv6(r, k, v, w, p["u"],
                 interpret=(impl == "pallas_interpret")).astype(x.dtype)
        # kernel starts from zero state (prefill); sequential path for
        # stateful continuation
        s_new = wkv_state
    else:
        y32, s_new = _wkv_sequential(
            r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), w, p["u"].astype(jnp.float32),
            wkv_state.astype(jnp.float32), chunk)
        y = y32.astype(x.dtype)

    y = y.reshape(b, s_len, hp * hs)
    y = _group_norm(y, p["ln_scale"], p["ln_bias"], hp, hs).astype(x.dtype)
    y = y * jax.nn.silu(g)
    mask = (jnp.arange(hp) < cfg.n_rwkv_heads).astype(y.dtype)
    y = y * jnp.repeat(mask, hs)[None, None, :]
    out = dense(p["w_o"], y)
    new_state = (x[:, -1:], s_new)
    return constrain(out, "batch", "seq_sp", None), new_state


def init_channel_mix(key, cfg: ModelConfig) -> dict:
    d, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": annot(jax.random.uniform(ks[0], (2, d), jnp.float32), None,
                    "embed"),
        "w_k": dense_init(ks[1], d, dff, "embed", "mlp"),
        "w_v": dense_init(ks[2], dff, d, "mlp", "embed"),
        "w_r": dense_init(jax.random.fold_in(key, 3), d, d, "embed", None),
    }


def channel_mix(p, cfg: ModelConfig, x, state):
    """RWKV channel mixing. state = x_prev [B,1,d]."""
    xs = _token_shift(x, state)
    mu = p["mu"].astype(x.dtype)
    xk = x + (xs - x) * mu[0]
    xr = x + (xs - x) * mu[1]
    k = jnp.square(jax.nn.relu(dense(p["w_k"], xk)))
    k = constrain(k, "batch", "seq", "mlp")
    out = jax.nn.sigmoid(dense(p["w_r"], xr)) * dense(p["w_v"], k)
    return constrain(out, "batch", "seq_sp", None), x[:, -1:]
