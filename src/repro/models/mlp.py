"""MLPs: SwiGLU / GELU dense blocks and sort-based Mixture-of-Experts.

The MoE dispatch is the TPU-idiomatic sort+capacity plan (no [T,E,cap]
one-hot tensors): tokens are sorted by expert, ranked within expert by the
same segmented-prefix-sum machinery the SIVF core uses for slab slot
assignment (repro.core.index), gathered into an [E, cap, d] buffer, run
through batched expert einsums, and scattered back weighted. Experts shard
over the model axis (expert parallelism); the capacity dim shards over the
data axes so dispatch collectives stay in the all-to-all family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense, dense_init
from repro.sharding.axes import constrain
from repro.sharding.rules import ShardPlan
from repro.utils import round_up, shard_map_compat


# -- dense MLP ---------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, act: str) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], d, d_ff, "embed", "mlp"),
        "w_down": dense_init(ks[1], d_ff, d, "mlp", "embed"),
    }
    if act == "swiglu":
        p["w_gate"] = dense_init(ks[2], d, d_ff, "embed", "mlp")
    return p


def apply_mlp(p, x, act: str):
    if act == "swiglu":
        h = jax.nn.silu(dense(p["w_gate"], x)) * dense(p["w_up"], x)
    else:
        h = jax.nn.gelu(dense(p["w_up"], x))
    h = constrain(h, "batch", "seq", "mlp")
    return constrain(dense(p["w_down"], h), "batch", "seq_sp", None)


# -- Mixture of Experts --------------------------------------------------------

def init_moe(key, cfg: ModelConfig, plan: ShardPlan) -> dict:
    d, h = cfg.d_model, cfg.moe_d_ff
    e = plan.n_experts_padded or cfg.n_experts
    ks = jax.random.split(key, 5)
    scale = (1.0 / d) ** 0.5

    def ew(k, shape, ax):
        from repro.sharding.axes import annot
        return annot(jax.random.normal(k, shape, jnp.float32) * scale, *ax)

    # expert weights shard on the expert dim only (expert parallelism over
    # the model axis); the per-expert ffn dim stays local to its shard
    p = {
        "router": dense_init(ks[0], d, e, "embed", "expert"),
        "w_gate": ew(ks[1], (e, d, h), ("expert", None, None)),
        "w_up": ew(ks[2], (e, d, h), ("expert", None, None)),
        "w_down": ew(ks[3], (e, h, d), ("expert", None, None)),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d,
                               cfg.n_shared_experts * cfg.moe_d_ff, "swiglu")
    return p


def apply_moe_shardmap(p, cfg: ModelConfig, plan: ShardPlan, x):
    """Expert-parallel MoE with explicit all-to-all (beyond-paper §Perf).

    The GSPMD-auto dispatch (apply_moe) partitions the global
    token->expert scatter by replicate-then-partition, which costs TBs of
    all-reduce per step at 256 chips (EXPERIMENTS.md §Perf iteration 1).
    This variant runs dispatch *manually* per device inside shard_map:
    local top-k -> local capacity buffers -> one all-to-all over the model
    axis to the expert owners -> expert einsums -> reverse all-to-all ->
    local weighted combine. The only cross-device traffic is the routed
    token payload itself, twice.
    """
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.sharding.axes import spec_for
    b, s, d = x.shape
    e_pad = plan.n_experts_padded or cfg.n_experts
    e_real = cfg.n_experts
    k = cfg.moe_top_k
    rules = plan.rules_dict
    m = plan.model_size
    if rules is None or rules.get("seq_sp") != "model" or s % m != 0:
        return apply_moe(p, cfg, plan, x)   # decode / test fallback
    mesh = jax.sharding.get_abstract_mesh()
    dp_total = 1
    for a in plan.batch_axes:
        dp_total *= mesh.shape[a]
    if b % dp_total != 0:
        return apply_moe(p, cfg, plan, x)
    e_loc = e_pad // m
    n_loc = (b // dp_total) * (s // m)
    cap = int(round_up(max(int(n_loc * k * cfg.capacity_factor) // e_real,
                           1), 8))

    def local(xl, router, wg, wu, wd):
        # xl [B_loc, S_loc, d]; router [d, E]; wg/wu [E_loc, d, h]; wd [E_loc, h, d]
        nl = xl.shape[0] * xl.shape[1]
        xf = xl.reshape(nl, d)
        logits = (xf @ router.astype(xl.dtype)).astype(jnp.float32)
        if e_pad != e_real:
            logits = jnp.where(jnp.arange(e_pad) < e_real, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        topw, tope = jax.lax.top_k(probs, k)
        topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)

        load = jnp.zeros((e_pad,), jnp.float32).at[tope.reshape(-1)].add(1.0)
        load = load / (nl * k)
        aux = e_real * jnp.sum(load * jnp.mean(probs, axis=0))
        aux = jax.lax.pmean(aux, "model")

        ek = tope.reshape(nl * k)
        wk_ = topw.reshape(nl * k)
        tok = jnp.arange(nl * k) // k
        order = jnp.argsort(ek, stable=True)
        se, stok, sw = ek[order], tok[order], wk_[order]
        rank = jnp.arange(nl * k) - jnp.searchsorted(se, se, side="left")
        ok = rank < cap
        buf = jnp.zeros((e_pad, cap, d), xl.dtype)
        buf = buf.at[jnp.where(ok, se, e_pad), rank].set(xf[stok],
                                                         mode="drop")
        # ship expert blocks to their owner shard:
        #   [E_pad, cap, d] -> [E_loc, m*cap, d] (sources along dim 1)
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)
        hh = jax.nn.silu(jnp.einsum("ecd,edh->ech", buf, wg.astype(xl.dtype))
                         ) * jnp.einsum("ecd,edh->ech", buf,
                                        wu.astype(xl.dtype))
        outb = jnp.einsum("ech,ehd->ecd", hh, wd.astype(xl.dtype))
        # send results home: [E_loc, m*cap, d] -> [E_pad, cap, d]
        outb = jax.lax.all_to_all(outb, "model", split_axis=1,
                                  concat_axis=0, tiled=True)
        vals = outb[jnp.clip(se, 0, e_pad - 1), jnp.clip(rank, 0, cap - 1)]
        y = jnp.zeros((nl, d), xl.dtype)
        y = y.at[jnp.where(ok, stok, nl)].add(
            vals * sw[:, None].astype(xl.dtype), mode="drop")
        return y.reshape(xl.shape), aux[None]

    x_spec = spec_for(("batch", "seq_sp", None), rules)
    router_spec = P()   # router weight replicated inside the region
    w_spec = spec_for(("expert", None, None), rules)
    y, aux = shard_map_compat(
        local, mesh=mesh, check_vma=False,
        in_specs=(x_spec, router_spec, w_spec, w_spec, w_spec),
        out_specs=(x_spec, P(plan.batch_axes + ("model",))),
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    out = y
    if "shared" in p:
        out = out + apply_mlp(p["shared"], x, "swiglu")
    return constrain(out, "batch", "seq_sp", None), jnp.mean(aux)


MOE_IMPL = "shardmap"   # "shardmap" (beyond-paper §Perf) | "gspmd" (baseline)


def moe(p, cfg: ModelConfig, plan: ShardPlan, x):
    """MoE dispatcher; EXPERIMENTS.md §Perf compares the two paths."""
    if MOE_IMPL == "shardmap":
        return apply_moe_shardmap(p, cfg, plan, x)
    return apply_moe(p, cfg, plan, x)


def apply_moe(p, cfg: ModelConfig, plan: ShardPlan, x):
    """x [B,S,d] -> (out [B,S,d], aux_loss scalar)."""
    b, s, d = x.shape
    n = b * s
    e_pad = plan.n_experts_padded or cfg.n_experts
    e_real = cfg.n_experts
    k = cfg.moe_top_k
    xf = x.reshape(n, d)

    logits = dense(p["router"], xf).astype(jnp.float32)       # [N, E]
    if e_pad != e_real:
        logits = jnp.where(jnp.arange(e_pad) < e_real, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, k)                      # [N, K]
    topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style), over real experts
    load = jnp.zeros((e_pad,), jnp.float32).at[tope.reshape(-1)].add(1.0)
    load = load / (n * k)
    imp = jnp.mean(probs, axis=0)
    aux = e_real * jnp.sum(load * imp)

    # sort-based dispatch (same plan machinery as SIVF slab assignment)
    cap = int(round_up(max(int(n * k * cfg.capacity_factor) // e_real, 1), 8))
    ek = tope.reshape(n * k)
    wk = topw.reshape(n * k)
    tok = jnp.arange(n * k) // k
    order = jnp.argsort(ek, stable=True)
    se, stok, sw = ek[order], tok[order], wk[order]
    first = jnp.searchsorted(se, se, side="left")
    rank = jnp.arange(n * k) - first
    ok = rank < cap                                            # capacity drop
    buf = jnp.zeros((e_pad, cap, d), x.dtype)
    buf = buf.at[jnp.where(ok, se, e_pad), rank].set(xf[stok], mode="drop")
    buf = constrain(buf, "expert", "dispatch", None)

    hsh = jnp.einsum("ecd,edh->ech", buf, p["w_gate"].astype(x.dtype))
    hup = jnp.einsum("ecd,edh->ech", buf, p["w_up"].astype(x.dtype))
    hh = jax.nn.silu(hsh) * hup   # [E, cap, moe_d_ff]: E already on model
    hh = constrain(hh, "expert", "dispatch", None)
    out_buf = jnp.einsum("ech,ehd->ecd", hh, p["w_down"].astype(x.dtype))
    out_buf = constrain(out_buf, "expert", "dispatch", None)

    vals = out_buf[jnp.clip(se, 0, e_pad - 1), jnp.clip(rank, 0, cap - 1)]
    y = jnp.zeros((n, d), x.dtype)
    y = y.at[jnp.where(ok, stok, n)].add(
        vals * sw[:, None].astype(x.dtype), mode="drop")

    out = y.reshape(b, s, d)
    if "shared" in p:
        out = out + apply_mlp(p["shared"], x, "swiglu")
    return constrain(out, "batch", "seq_sp", None), aux
