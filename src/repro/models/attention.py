"""Attention: GQA (llama3/qwen3/phi3/llava/moonshot/jamba/whisper) and MLA
(minicpm3), in full-sequence (train/prefill) and decode (KV-cache) modes.

Head padding (ShardPlan): projections are built at the *padded* head count
so the head axis shards over the model mesh axis; padded heads are masked
to zero before the output projection, which keeps them exactly inert (zero
forward contribution and zero gradient) — see DESIGN.md §6.

Two compute paths: ``xla`` (pure jnp; dry-run + training) and ``pallas``
(kernels/flash_attention, interpret=True on CPU) — DESIGN.md §7.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import apply_rope, dense, dense_init, rms_norm_1d
from repro.sharding.axes import annot, constrain
from repro.sharding.rules import ShardPlan
from repro.utils import shard_map_compat


def _head_mask(plan: ShardPlan, n_real: int) -> jax.Array:
    """[H_pad] 1.0 for real heads, 0.0 for padding heads."""
    return (jnp.arange(plan.n_heads_padded) < n_real).astype(jnp.float32)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ModelConfig, plan: ShardPlan) -> dict:
    d, dh = cfg.d_model, cfg.head_dim
    hq, hkv = plan.n_heads_padded, plan.n_kv_heads_padded
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, hq * dh, "embed", "heads"),
        "wk": dense_init(ks[1], d, hkv * dh, "embed", "kv_heads"),
        "wv": dense_init(ks[2], d, hkv * dh, "embed", "kv_heads"),
        "wo": dense_init(ks[3], hq * dh, d, "heads", "embed"),
    }
    if cfg.qk_norm:
        p["q_norm"] = annot(jnp.ones((dh,), jnp.float32), None)
        p["k_norm"] = annot(jnp.ones((dh,), jnp.float32), None)
    return p


def _gqa_qkv(p, cfg: ModelConfig, plan: ShardPlan, x, positions,
             rope: bool = True):
    b, s, _ = x.shape
    dh = cfg.head_dim
    hq, hkv = plan.n_heads_padded, plan.n_kv_heads_padded
    q = dense(p["wq"], x).reshape(b, s, hq, dh)
    k = dense(p["wk"], x).reshape(b, s, hkv, dh)
    v = dense(p["wv"], x).reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = rms_norm_1d(q, p["q_norm"])
        k = rms_norm_1d(k, p["k_norm"])
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    v = constrain(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def _sdpa_grouped(q, k, v, qpos, kpos, causal: bool, scale: float):
    """Grouped-query attention without materializing repeated KV.

    q [B,S,Hq,dh]; k,v [B,T,Hkv,dh]; qpos [S], kpos [T] absolute positions.
    """
    b, s, hq, dh = q.shape
    hkv, t = k.shape[2], k.shape[1]
    g = hq // hkv
    q5 = q.reshape(b, s, hkv, g, dh)
    # bf16 operands + f32 accumulation: no materialized f32 copy of the
    # (potentially huge) KV cache (§Perf iteration 3)
    sc = jnp.einsum("bskgd,btkd->bkgst", q5, k,
                    preferred_element_type=jnp.float32) * scale
    if causal:
        mask = qpos[:, None] >= kpos[None, :]                # [S, T]
        sc = jnp.where(mask[None, None, None], sc, -jnp.inf)
    m = jnp.max(sc, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    pr = jnp.exp(sc - m)
    pr = pr / jnp.maximum(jnp.sum(pr, axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgst,btkd->bskgd", pr.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, s, hq, v.shape[-1]).astype(q.dtype)


_CHUNK_THRESHOLD = 2048
_Q_CHUNK = 1024
_KV_CHUNK = 1024


def _sdpa_chunked(q, k, v, qpos, kpos, causal: bool, scale: float,
                  q_chunk: int = _Q_CHUNK, kv_chunk: int = _KV_CHUNK):
    """Memory-efficient attention (Rabe-Staats double-scan; the XLA
    analogue of the flash kernel): never materializes S x T scores —
    the working set is one (q_chunk x kv_chunk) tile per step.

    The q-chunk scan body is checkpointed so backward recomputes tiles
    instead of storing them (mirrors flash backward)."""
    b, s, hq, dh = q.shape
    hkv, t = k.shape[2], k.shape[1]
    dv = v.shape[-1]
    g = hq // hkv
    qc = min(q_chunk, s)
    kc = min(kv_chunk, t)
    nq, nk = s // qc, t // kc
    qf = q.reshape(b, nq, qc, hkv, g, dh)
    kf = k.reshape(b, nk, kc, hkv, dh)
    vf = v.reshape(b, nk, kc, hkv, dv)
    qpos_c = qpos.reshape(nq, qc)
    kpos_c = kpos.reshape(nk, kc)

    @jax.checkpoint
    def per_q(_, xs):
        qi, qp = xs                                    # [b,qc,hkv,g,dh], [qc]

        def inner(carry, ys):
            m, denom, acc = carry
            ki, vi, kp = ys                            # [b,kc,hkv,dh], [kc]
            sc = jnp.einsum("bqkgd,btkd->bkgqt", qi, ki,
                            preferred_element_type=jnp.float32) * scale
            if causal:
                mask = qp[:, None] >= kp[None, :]      # [qc, kc]
                sc = jnp.where(mask[None, None, None], sc, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1, keepdims=True))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(sc - m_safe)
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            denom = alpha * denom + jnp.sum(p, axis=-1, keepdims=True)
            acc = alpha * acc + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(vi.dtype), vi,
                preferred_element_type=jnp.float32)
            return (m_new, denom, acc), None

        init = (jnp.full((b, hkv, g, qc, 1), -jnp.inf, jnp.float32),
                jnp.zeros((b, hkv, g, qc, 1), jnp.float32),
                jnp.zeros((b, hkv, g, qc, dv), jnp.float32))
        (m, denom, acc), _ = jax.lax.scan(
            inner, init,
            (jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0), kpos_c))
        o = acc / jnp.maximum(denom, 1e-30)                # [b,hkv,g,qc,dv]
        return None, jnp.moveaxis(o, 3, 1)             # [b,qc,hkv,g,dv]

    _, out = jax.lax.scan(per_q, None, (jnp.moveaxis(qf, 1, 0), qpos_c))
    out = jnp.moveaxis(out, 0, 1)                      # [nq->dim1]
    return out.reshape(b, s, hq, dv).astype(q.dtype)


def _sdpa(q, k, v, qpos, kpos, causal: bool, scale: float):
    """Dispatch: direct for short sequences, chunked beyond the threshold."""
    s, t = q.shape[1], k.shape[1]
    if max(s, t) > _CHUNK_THRESHOLD and s > 1 \
            and s % min(_Q_CHUNK, s) == 0 and t % min(_KV_CHUNK, t) == 0:
        return _sdpa_chunked(q, k, v, qpos, kpos, causal, scale)
    return _sdpa_grouped(q, k, v, qpos, kpos, causal, scale)


def _maybe_repeat_kv(k, v, plan: ShardPlan, g: int):
    """Beyond-paper §Perf (iteration 2): when KV heads are replicated
    (non-divisible count), the grouped einsum's kv-head dim blocks full
    head sharding and GSPMD partially replicates attention compute.
    Repeating KV to the padded q-head count (divisible by the model axis)
    restores full sharding; the repeated KV is itself head-sharded, so
    per-device bytes don't grow.

    Only applied when the kv-head count neither divides nor is divided by
    the model axis (phi3's 12 vs 16): measured on qwen3/llama3 (kv=8,
    16 % 8 == 0) GSPMD already shards the grouped form, and the repeat
    only adds HBM traffic (§Perf iteration 2, refuted sub-hypothesis)."""
    hkv = k.shape[2]
    m = plan.model_size
    if (m == 1 or plan.kv_sharded or g == 1
            or m % hkv == 0 or hkv % m == 0):
        return k, v
    k = constrain(jnp.repeat(k, g, axis=2), "batch", "seq", "heads", None)
    v = constrain(jnp.repeat(v, g, axis=2), "batch", "seq", "heads", None)
    return k, v


def gqa_full(p, cfg: ModelConfig, plan: ShardPlan, x, positions,
             causal: bool = True, impl: str = "xla"):
    """Full-sequence attention. Returns (out [B,S,d], (k, v) for caching)."""
    b, s, _ = x.shape
    dh = cfg.head_dim
    q, k, v = _gqa_qkv(p, cfg, plan, x, positions)
    scale = dh ** -0.5
    if impl == "xla":
        g = plan.n_heads_padded // plan.n_kv_heads_padded
        ka, va = _maybe_repeat_kv(k, v, plan, g)
        o = _sdpa(q, ka, va, positions, positions, causal, scale)
    else:
        from repro.kernels.flash_attention.ops import flash_attention
        o = flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal,
            interpret=(impl == "pallas_interpret"),
            block_q=min(128, s), block_k=min(128, s),
        ).transpose(0, 2, 1, 3)
    o = o * _head_mask(plan, cfg.n_heads)[None, None, :, None].astype(o.dtype)
    o = o.reshape(b, s, plan.n_heads_padded * dh)
    out = dense(p["wo"], o)
    return constrain(out, "batch", "seq_sp", None), (k, v)


def gqa_decode(p, cfg: ModelConfig, plan: ShardPlan, x, cache_k, cache_v,
               pos):
    """One-token decode. x [B,1,d]; cache_k/v [B,Smax,Hkv,dh]; pos scalar.

    The KV cache's sequence dim carries the "kv_seq" logical axis: on the
    production mesh it shards over the model axis (flash-decode style
    partial attention; GSPMD inserts the LSE-merge collectives) — the
    paper's scatter-gather pattern applied to attention (DESIGN.md §3).
    """
    b = x.shape[0]
    dh = cfg.head_dim
    positions = jnp.full((1,), pos, jnp.int32)
    q, k_new, v_new = _gqa_qkv(p, cfg, plan, x, positions)
    seq_axes = _seqshard_axes(plan)
    if seq_axes is not None:
        cache_k, cache_v, o = _decode_attn_seqshard(
            plan, q, cache_k, cache_v, k_new, v_new, pos, dh ** -0.5,
            seq_axes)
    else:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k_new.astype(cache_k.dtype), (0, pos, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v_new.astype(cache_v.dtype), (0, pos, 0, 0))
        cache_k = constrain(cache_k, "batch", "kv_seq", "kv_heads", None)
        cache_v = constrain(cache_v, "batch", "kv_seq", "kv_heads", None)
        t = cache_k.shape[1]
        kpos = jnp.arange(t)
        # causal = "key position <= current": mask via qpos >= kpos
        o = _sdpa(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype),
                  positions, kpos, True, dh ** -0.5)
    o = o * _head_mask(plan, cfg.n_heads)[None, None, :, None].astype(o.dtype)
    out = dense(p["wo"], o.reshape(b, 1, -1))
    return constrain(out, "batch", None, None), cache_k, cache_v


def decode_attn_stacked(p_attn, cfg, plan: ShardPlan, x, sk, sv, layer_i,
                        pos, head_ax: str, mla: bool = False):
    """Decode attention against a *stacked* cache [n_per, B, S, H, dh],
    updating exactly one token slot at (layer_i, :, pos) in place
    (§Perf iteration 3b): carrying the stack through the layer scan avoids
    the ys write-back that rewrote a full layer slice per step.
    Returns (out [B,1,d], sk, sv)."""
    b = x.shape[0]
    positions = jnp.full((1,), pos, jnp.int32)
    if mla:
        q, k_new, v_new = _mla_qkv(p_attn, cfg, plan, x, positions)
        scale = cfg.qk_head_dim ** -0.5
    else:
        q, k_new, v_new = _gqa_qkv(p_attn, cfg, plan, x, positions)
        scale = cfg.head_dim ** -0.5
    seq_axes = _seqshard_axes(plan)
    upd_k = k_new.astype(sk.dtype).reshape(1, b, 1, *k_new.shape[2:])
    upd_v = v_new.astype(sv.dtype).reshape(1, b, 1, *v_new.shape[2:])

    if seq_axes is None:
        sk = jax.lax.dynamic_update_slice(sk, upd_k,
                                          (layer_i, 0, pos, 0, 0))
        sv = jax.lax.dynamic_update_slice(sv, upd_v,
                                          (layer_i, 0, pos, 0, 0))
        sk = constrain(sk, None, "batch", "kv_seq", head_ax, "kv_dh")
        sv = constrain(sv, None, "batch", "kv_seq", head_ax, "kv_dh")
        ck = jax.lax.dynamic_slice(sk, (layer_i, 0, 0, 0, 0),
                                   (1,) + sk.shape[1:])[0]
        cv = jax.lax.dynamic_slice(sv, (layer_i, 0, 0, 0, 0),
                                   (1,) + sv.shape[1:])[0]
        t = ck.shape[1]
        o = _sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), positions,
                  jnp.arange(t), True, scale)
    else:
        # sequence-sharded stack: one-slot update + flash-decode LSE merge
        # inside shard_map (the paper's scatter-gather, DESIGN.md §3).
        # GSPMD would lower a DUS on the sharded dim as a whole-buffer
        # select (measured 550+ GB/step/device); the manual region writes
        # one slot and merges partial attention across shards.
        from repro.sharding.axes import spec_for
        from jax.sharding import PartitionSpec as P
        mesh = jax.sharding.get_abstract_mesh()
        rules = plan.rules_dict

        def local(q, sk, sv, kn, vn, li, pos):
            s_loc = sk.shape[2]
            idx = jax.lax.axis_index(seq_axes)
            own = (pos >= idx * s_loc) & (pos < (idx + 1) * s_loc)
            lpos = jnp.clip(pos - idx * s_loc, 0, s_loc - 1)
            cur_k = jax.lax.dynamic_slice(
                sk, (li, 0, lpos, 0, 0),
                (1, kn.shape[1], 1) + kn.shape[3:])
            cur_v = jax.lax.dynamic_slice(
                sv, (li, 0, lpos, 0, 0),
                (1, vn.shape[1], 1) + vn.shape[3:])
            sk = jax.lax.dynamic_update_slice(
                sk, jnp.where(own, kn, cur_k), (li, 0, lpos, 0, 0))
            sv = jax.lax.dynamic_update_slice(
                sv, jnp.where(own, vn, cur_v), (li, 0, lpos, 0, 0))
            ck = jax.lax.dynamic_slice(sk, (li, 0, 0, 0, 0),
                                       (1,) + sk.shape[1:])[0]
            cv = jax.lax.dynamic_slice(sv, (li, 0, 0, 0, 0),
                                       (1,) + sv.shape[1:])[0]
            bb, _, hq, dh_ = q.shape
            hkv = ck.shape[2]
            g = hq // hkv
            q5 = q.reshape(bb, 1, hkv, g, dh_)
            sc = jnp.einsum("bskgd,btkd->bkgst", q5, ck.astype(q.dtype),
                            preferred_element_type=jnp.float32) * scale
            slot = idx * s_loc + jnp.arange(s_loc)
            sc = jnp.where(slot[None, None, None, None, :] <= pos, sc,
                           -jnp.inf)
            m_g = jax.lax.pmax(jnp.max(sc, axis=-1, keepdims=True),
                               seq_axes)
            m_safe = jnp.where(jnp.isfinite(m_g), m_g, 0.0)
            pr = jnp.exp(sc - m_safe)
            l_g = jax.lax.psum(jnp.sum(pr, axis=-1, keepdims=True),
                               seq_axes)
            o = jnp.einsum("bkgst,btkd->bskgd", pr.astype(q.dtype),
                           cv.astype(q.dtype),
                           preferred_element_type=jnp.float32)
            o = jax.lax.psum(o, seq_axes)                # [b,1,k,g,dv]
            o = o / jnp.maximum(l_g.transpose(0, 3, 1, 2, 4), 1e-30)
            return sk, sv, o.reshape(bb, 1, hq, -1).astype(q.dtype)

        q_spec = spec_for(("batch", None, None, None), rules)
        c_spec = spec_for((None, "batch", "kv_seq", head_ax, None), rules)
        u_spec = spec_for((None, "batch", None, None, None), rules)
        sk, sv, o = shard_map_compat(
            local, mesh=mesh, check_vma=False,
            in_specs=(q_spec, c_spec, c_spec, u_spec, u_spec, P(), P()),
            out_specs=(c_spec, c_spec, q_spec),
        )(q, sk, sv, upd_k, upd_v, layer_i, pos)

    o = o * _head_mask(plan, cfg.n_heads)[None, None, :, None].astype(
        o.dtype)
    out = dense(p_attn["wo"], o.reshape(b, 1, -1))
    return constrain(out, "batch", None, None), sk, sv


def _seqshard_axes(plan: ShardPlan):
    """Mesh axes the decode cache's sequence dim shards over (or None)."""
    rules = plan.rules_dict
    if not rules:
        return None
    r = rules.get("kv_seq")
    if r is None:
        return None
    axes = r if isinstance(r, tuple) else (r,)
    return axes if "model" in axes else None


def _decode_attn_seqshard(plan: ShardPlan, q, cache_k, cache_v, k_new,
                          v_new, pos, scale: float, seq_axes: tuple):
    """Sequence-sharded decode attention via shard_map (§Perf iteration 3).

    GSPMD lowers a dynamic_update_slice on a sharded dim as a whole-buffer
    select — every layer rewrote its entire local cache each step
    (measured 550+ GB/step/device on llama3 decode_32k). Inside shard_map
    we express what the compiler cannot prove: the owning shard writes
    exactly one slot; every shard computes partial attention over its
    local sequence chunk; partials merge with the flash-decode
    log-sum-exp reduction — the paper's scatter-gather search (§4.2)
    applied to attention.
    """
    from repro.sharding.axes import spec_for
    from jax.sharding import PartitionSpec as P
    mesh = jax.sharding.get_abstract_mesh()
    rules = plan.rules_dict

    def local(q, ck, cv, kn, vn, pos):
        s_loc = ck.shape[1]
        idx = jax.lax.axis_index(seq_axes)
        own = (pos >= idx * s_loc) & (pos < (idx + 1) * s_loc)
        lpos = jnp.clip(pos - idx * s_loc, 0, s_loc - 1)
        cur_k = jax.lax.dynamic_slice_in_dim(ck, lpos, 1, 1)
        cur_v = jax.lax.dynamic_slice_in_dim(cv, lpos, 1, 1)
        ck = jax.lax.dynamic_update_slice_in_dim(
            ck, jnp.where(own, kn.astype(ck.dtype), cur_k), lpos, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cv, jnp.where(own, vn.astype(cv.dtype), cur_v), lpos, 1)
        # partial attention over the local chunk
        b, _, hq, dh_ = q.shape
        hkv = ck.shape[2]
        g = hq // hkv
        q5 = q.reshape(b, 1, hkv, g, dh_)
        sc = jnp.einsum("bskgd,btkd->bkgst", q5, ck,
                        preferred_element_type=jnp.float32) * scale
        slot = idx * s_loc + jnp.arange(s_loc)
        sc = jnp.where(slot[None, None, None, None, :] <= pos, sc, -jnp.inf)
        m_loc = jnp.max(sc, axis=-1, keepdims=True)      # [b,k,g,1,1]
        m_g = jax.lax.pmax(m_loc, seq_axes)
        m_safe = jnp.where(jnp.isfinite(m_g), m_g, 0.0)
        pr = jnp.exp(sc - m_safe)
        l_g = jax.lax.psum(jnp.sum(pr, axis=-1, keepdims=True), seq_axes)
        o = jnp.einsum("bkgst,btkd->bskgd", pr.astype(cv.dtype), cv,
                       preferred_element_type=jnp.float32)
        o = jax.lax.psum(o, seq_axes)                    # [b,1,k,g,dv]
        o = o / jnp.maximum(l_g.transpose(0, 3, 1, 2, 4), 1e-30)
        return ck, cv, o.reshape(b, 1, hq, -1).astype(q.dtype)

    q_spec = spec_for(("batch", None, None, None), rules)
    c_spec = spec_for(("batch", "kv_seq",
                       "kv_heads" if plan.kv_sharded else None, None), rules)
    ck, cv, o = shard_map_compat(
        local, mesh=mesh, check_vma=False,
        in_specs=(q_spec, c_spec, c_spec, q_spec, q_spec, P()),
        out_specs=(c_spec, c_spec, q_spec),
    )(q, cache_k, cache_v, k_new, v_new, pos)
    return ck, cv, o


def gqa_decode_paged(p, cfg: ModelConfig, plan: ShardPlan, x, k_pages,
                     v_pages, tables, lengths, starts, positions,
                     impl: str = "ref"):
    """One-token decode over the slab-paged KV cache (DESIGN.md §3).

    x [B,1,d]; k_pages/v_pages [n_pages, page, Hkv, dh]; tables [B, maxp]
    (the per-sequence ATT); lengths/starts [B] cache-coordinate window;
    positions [B] absolute positions for RoPE. Returns
    (out, k_pages, v_pages) — pages updated in place (donation-friendly).
    """
    b = x.shape[0]
    dh = cfg.head_dim
    page = k_pages.shape[1]
    q, k_new, v_new = _gqa_qkv(p, cfg, plan, x, positions[:, None])
    # write the new token into its slab slot (paper Alg. 2 reserve+publish;
    # slot = ATT[seq] -> (page, offset))
    pslot = lengths // page
    pidx = tables[jnp.arange(b), jnp.clip(pslot, 0, tables.shape[1] - 1)]
    ok = (pidx >= 0) & (lengths >= starts)
    tgt = jnp.where(ok, pidx, k_pages.shape[0])
    k_pages = k_pages.at[tgt, lengths % page].set(
        k_new[:, 0].astype(k_pages.dtype), mode="drop")
    v_pages = v_pages.at[tgt, lengths % page].set(
        v_new[:, 0].astype(v_pages.dtype), mode="drop")
    from repro.kernels.paged_attention.ops import paged_attention
    o = paged_attention(q[:, 0], k_pages, v_pages, tables, lengths + 1,
                        starts=starts,
                        impl="ref" if impl == "ref" else "pallas",
                        interpret=(impl == "pallas_interpret"))
    o = o * _head_mask(plan, cfg.n_heads)[None, :, None].astype(o.dtype)
    out = dense(p["wo"], o.reshape(b, 1, -1))
    return constrain(out, "batch", None, None), k_pages, v_pages


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_full(p, cfg: ModelConfig, plan: ShardPlan, x, enc_kv):
    """q from decoder x; k,v precomputed from encoder output."""
    b, s, _ = x.shape
    dh = cfg.head_dim
    hq = plan.n_heads_padded
    q = dense(p["wq"], x).reshape(b, s, hq, dh)
    q = constrain(q, "batch", "seq", "heads", None)
    k, v = enc_kv
    t = k.shape[1]
    o = _sdpa(q, k.astype(q.dtype), v.astype(q.dtype),
                      jnp.arange(s), jnp.arange(t), False, dh ** -0.5)
    o = o * _head_mask(plan, cfg.n_heads)[None, None, :, None].astype(o.dtype)
    out = dense(p["wo"], o.reshape(b, s, -1))
    return constrain(out, "batch", "seq_sp", None)


def cross_kv(p, cfg: ModelConfig, plan: ShardPlan, enc_out):
    """Precompute the encoder-side K,V once per sequence (prefill)."""
    b, t, _ = enc_out.shape
    dh = cfg.head_dim
    hkv = plan.n_kv_heads_padded
    k = dense(p["wk"], enc_out).reshape(b, t, hkv, dh)
    v = dense(p["wv"], enc_out).reshape(b, t, hkv, dh)
    return k, v


# ---------------------------------------------------------------------------
# MLA (minicpm3): multi-head latent attention
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, plan: ShardPlan) -> dict:
    d = cfg.d_model
    hq = plan.n_heads_padded
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 6)
    return {
        "w_dq": dense_init(ks[0], d, cfg.q_lora_rank, "embed", "q_lora"),
        "w_uq": dense_init(ks[1], cfg.q_lora_rank, hq * qk, "q_lora", "heads"),
        "w_dkv": dense_init(ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_dim,
                            "embed", "kv_lora"),
        "w_ukv": dense_init(ks[3], cfg.kv_lora_rank,
                            hq * (cfg.qk_nope_dim + cfg.v_head_dim),
                            "kv_lora", "heads"),
        "wo": dense_init(ks[4], hq * cfg.v_head_dim, d, "heads", "embed"),
        "q_ln": annot(jnp.ones((cfg.q_lora_rank,), jnp.float32), None),
        "kv_ln": annot(jnp.ones((cfg.kv_lora_rank,), jnp.float32), None),
    }


def _mla_qkv(p, cfg: ModelConfig, plan: ShardPlan, x, positions):
    b, s, _ = x.shape
    hq = plan.n_heads_padded
    nope, rp = cfg.qk_nope_dim, cfg.qk_rope_dim
    vh = cfg.v_head_dim
    cq = rms_norm_1d(dense(p["w_dq"], x), p["q_ln"])
    q = dense(p["w_uq"], cq).reshape(b, s, hq, nope + rp)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = dense(p["w_dkv"], x)                               # [B,S,rank+rp]
    c_lat = rms_norm_1d(ckv[..., :cfg.kv_lora_rank], p["kv_ln"])
    k_rope = ckv[..., cfg.kv_lora_rank:].reshape(b, s, 1, rp)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)

    kv = dense(p["w_ukv"], c_lat).reshape(b, s, hq, nope + vh)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, hq, rp))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "heads", None)
    v = constrain(v, "batch", "seq", "heads", None)
    return q, k, v


def mla_full(p, cfg: ModelConfig, plan: ShardPlan, x, positions,
             causal: bool = True, impl: str = "xla"):
    """Full-seq MLA. Cache output is the absorbed form (latent, rope_key)
    so prefill feeds the latent decode cache directly."""
    b, s, _ = x.shape
    q, k, v = _mla_qkv(p, cfg, plan, x, positions)
    scale = cfg.qk_head_dim ** -0.5
    o = _sdpa(q, k, v, positions, positions, causal, scale)
    o = o * _head_mask(plan, cfg.n_heads)[None, None, :, None].astype(o.dtype)
    out = dense(p["wo"], o.reshape(b, s, -1))
    lat = cfg.kv_lora_rank
    ckv = dense(p["w_dkv"], x)
    lat_cache = rms_norm_1d(ckv[..., :lat], p["kv_ln"])
    rope_cache = apply_rope(
        ckv[..., lat:].reshape(b, s, 1, cfg.qk_rope_dim), positions,
        cfg.rope_theta)[:, :, 0]
    return constrain(out, "batch", "seq_sp", None), (lat_cache, rope_cache)


def mla_absorbed_parts(p, cfg: ModelConfig, plan: ShardPlan, x, positions):
    """Absorbed-form MLA decode inputs (§Perf iteration 5, DeepSeek-style).

    Instead of caching expanded per-head K/V (48 heads x 160 dims per
    token), cache the shared compressed latent (256) + rope key (32):
    26.6x fewer cache bytes. Scores/outputs are mathematically exact:
      q_nope[h]·k_nope[h] = q_nope[h]·(c·W_k[h]) = (q_nope[h]·W_k[h]^T)·c
      out[h] = sum_t p_t v_t[h] = (sum_t p_t c_t)·W_v[h]
    Returns (q_comb [B,S,H,lat+rope], lat_new [B,S,lat],
    rope_new [B,S,rope]).
    """
    b, s, _ = x.shape
    hq = plan.n_heads_padded
    nope, rp = cfg.qk_nope_dim, cfg.qk_rope_dim
    lat = cfg.kv_lora_rank
    cq = rms_norm_1d(dense(p["w_dq"], x), p["q_ln"])
    q = dense(p["w_uq"], cq).reshape(b, s, hq, nope + rp)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    # absorb W_uk into q
    w_ukv = p["w_ukv"].reshape(lat, hq, nope + cfg.v_head_dim)
    w_k = w_ukv[..., :nope]                                  # [lat, H, nope]
    q_abs = jnp.einsum("bshd,lhd->bshl", q_nope,
                       w_k.astype(q_nope.dtype))             # [B,S,H,lat]
    q_comb = jnp.concatenate([q_abs, q_rope], axis=-1)
    # the new token's latent + rope key
    ckv = dense(p["w_dkv"], x)
    lat_new = rms_norm_1d(ckv[..., :lat], p["kv_ln"])        # [B,S,lat]
    rope_new = apply_rope(ckv[..., lat:].reshape(b, s, 1, rp), positions,
                          cfg.rope_theta)[:, :, 0]           # [B,S,rope]
    return q_comb, lat_new, rope_new


def mla_absorbed_out(p, cfg: ModelConfig, ctx):
    """ctx [B,S,H,lat] (attention-weighted latents) -> [B,S,H,v_head]."""
    b, s, hq, lat = ctx.shape
    nope = cfg.qk_nope_dim
    w_ukv = p["w_ukv"].reshape(lat, hq, nope + cfg.v_head_dim)
    w_v = w_ukv[..., nope:]                                  # [lat, H, vh]
    return jnp.einsum("bshl,lhv->bshv", ctx, w_v.astype(ctx.dtype))


def mla_decode_absorbed_stacked(p_attn, cfg: ModelConfig, plan: ShardPlan,
                                x, s_lat, s_rope, layer_i, pos):
    """Stacked latent-cache MLA decode: s_lat [n_per,B,S,lat],
    s_rope [n_per,B,S,rope]; one-slot update at (layer_i, :, pos)."""
    b = x.shape[0]
    positions = jnp.full((1,), pos, jnp.int32)
    q_comb, lat_new, rope_new = mla_absorbed_parts(p_attn, cfg, plan, x,
                                                   positions)
    s_lat = jax.lax.dynamic_update_slice(
        s_lat, lat_new.astype(s_lat.dtype)[None], (layer_i, 0, pos, 0))
    s_rope = jax.lax.dynamic_update_slice(
        s_rope, rope_new.astype(s_rope.dtype)[None], (layer_i, 0, pos, 0))
    # latent dim shards over the model axis ("mlp" rule): DUS stays local
    # (pos dim unsharded); scores pay one small psum per layer
    s_lat = constrain(s_lat, None, "batch", None, "mlp")
    s_rope = constrain(s_rope, None, "batch", None, "mlp")
    lat_i = jax.lax.dynamic_slice(s_lat, (layer_i, 0, 0, 0),
                                  (1,) + s_lat.shape[1:])[0]
    rope_i = jax.lax.dynamic_slice(s_rope, (layer_i, 0, 0, 0),
                                   (1,) + s_rope.shape[1:])[0]
    keys = jnp.concatenate([lat_i, rope_i], axis=-1)[:, :, None, :]
    vals = lat_i[:, :, None, :]              # [B,S,1,lat] shared "kv head"
    t = keys.shape[1]
    o = _sdpa(q_comb, keys.astype(q_comb.dtype), vals.astype(q_comb.dtype),
              positions, jnp.arange(t), True, cfg.qk_head_dim ** -0.5)
    o = mla_absorbed_out(p_attn, cfg, o)                     # [B,1,H,vh]
    o = o * _head_mask(plan, cfg.n_heads)[None, None, :, None].astype(
        o.dtype)
    out = dense(p_attn["wo"], o.reshape(b, 1, -1))
    return constrain(out, "batch", None, None), s_lat, s_rope


def mla_decode(p, cfg: ModelConfig, plan: ShardPlan, x, cache_k, cache_v,
               pos):
    """Decode with expanded-KV cache (latent-absorbed form is a §Perf
    follow-up; DESIGN.md §2 beyond-paper list)."""
    b = x.shape[0]
    positions = jnp.full((1,), pos, jnp.int32)
    q, k_new, v_new = _mla_qkv(p, cfg, plan, x, positions)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k_new.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v_new.astype(cache_v.dtype), (0, pos, 0, 0))
    cache_k = constrain(cache_k, "batch", "kv_seq", "heads", None)
    cache_v = constrain(cache_v, "batch", "kv_seq", "heads", None)
    t = cache_k.shape[1]
    o = _sdpa(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype),
                      positions, jnp.arange(t), True,
                      cfg.qk_head_dim ** -0.5)
    o = o * _head_mask(plan, cfg.n_heads)[None, None, :, None].astype(o.dtype)
    out = dense(p["wo"], o.reshape(b, 1, -1))
    return constrain(out, "batch", None, None), cache_k, cache_v
