"""Shared model building blocks: norms, linears, RoPE, embeddings.

Parameters are plain nested dicts of ``axes.Annot`` (array + logical axes);
``axes.strip`` yields the runtime pytree and ``axes.specs_tree`` the
PartitionSpecs for pjit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.axes import Annot, annot, constrain


def dense_init(key, d_in: int, d_out: int, ax_in: str, ax_out: str,
               scale: float | None = None) -> Annot:
    scale = (1.0 / d_in) ** 0.5 if scale is None else scale
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    return annot(w, ax_in, ax_out)


def dense(params: jax.Array, x: jax.Array, dtype=None) -> jax.Array:
    dtype = x.dtype if dtype is None else dtype
    return jnp.einsum("...i,io->...o", x, params.astype(dtype))


def norm_init(key, d: int, kind: str, ax: str = "embed") -> dict:
    del key
    p = {"scale": annot(jnp.ones((d,), jnp.float32), ax)}
    if kind == "layernorm":
        p["bias"] = annot(jnp.zeros((d,), jnp.float32), ax)
    return p


def apply_norm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in params:                       # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) \
            + params["bias"].astype(jnp.float32)
    else:                                      # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_1d(x: jax.Array, scale: jax.Array, eps: float = 1e-6):
    """Per-head qk-norm (qwen3): normalizes the trailing head_dim."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


# -- RoPE --------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, H, dh] (dh even); positions [..., S]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                          # [dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, dh/2]
    cos = jnp.cos(ang)[..., None, :]                        # [..., S, 1, dh/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(seq: int, d: int) -> jax.Array:
    """Whisper-style sinusoidal positional table [seq, d]."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-jnp.arange(0, d, 2, jnp.float32) / d * jnp.log(10000.0))
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# -- embeddings --------------------------------------------------------------

def embed_init(key, vocab_padded: int, d: int) -> dict:
    tbl = jax.random.normal(key, (vocab_padded, d), jnp.float32) * 0.02
    return {"table": annot(tbl, "vocab", "embed")}


def embed_lookup(params: dict, tokens: jax.Array, dtype) -> jax.Array:
    x = params["table"].astype(dtype)[tokens]
    return constrain(x, "batch", "seq", None)


def lm_head(params: dict, x: jax.Array, vocab_size: int) -> jax.Array:
    """Project to logits; padded vocab rows masked to -inf."""
    logits = jnp.einsum("...d,vd->...v", x, params["table"].astype(x.dtype))
    logits = constrain(logits, "batch", "seq", "vocab")
    vp = params["table"].shape[0]
    if vp != vocab_size:
        pad_mask = jnp.arange(vp) >= vocab_size
        logits = jnp.where(pad_mask, -1e30, logits.astype(jnp.float32)
                           ).astype(logits.dtype)
    return logits
