"""Jitted public wrapper for the top-k kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.topk.ref import topk_ref
from repro.kernels.topk.topk import topk_pallas


@partial(jax.jit, static_argnames=("k", "interpret", "impl", "block_q"))
def topk(dists, labels, k: int, interpret: bool = False,
         impl: str = "pallas", block_q: int = 8):
    if impl == "ref":
        return topk_ref(dists, labels, k)
    return topk_pallas(dists, labels, k, block_q=block_q, interpret=interpret)
