"""Pure-jnp oracle for the top-k selection kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_ref(dists: jax.Array, labels: jax.Array, k: int
             ) -> tuple[jax.Array, jax.Array]:
    """Smallest-k by distance. dists/labels [Q, L] -> [Q, k] each."""
    nd, idx = jax.lax.top_k(-dists, k)
    return -nd, jnp.take_along_axis(labels, idx, axis=1)
