"""Iterative top-k selection — Pallas TPU kernel (paper Alg. 3 merge phase).

The paper's warp merge (32 per-lane register lists -> one top-k) becomes a
VMEM-resident iterative selection: each grid step owns a [bq, L] tile of
candidate distances and extracts the k smallest by k rounds of
(min, argmin-via-one-hot, mask-to-inf). k is small (<= a few hundred) so
k passes over a VMEM tile beat a full sort.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


_NEG = -(2 ** 31) + 1  # python literal; jnp scalars would be captured consts


def _kernel(dist_ref, lab_ref, outd_ref, outl_ref, *, k: int):
    bq, nl = dist_ref.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, nl), 1)

    def body(j, cur):
        m = jnp.min(cur, axis=1, keepdims=True)                  # [bq, 1]
        # first index achieving the min (match lax.top_k tie-breaking)
        ix = jnp.min(jnp.where(cur == m, col, nl), axis=1, keepdims=True)
        oh = col == ix                                           # [bq, L]
        lab = jnp.max(jnp.where(oh, lab_ref[...], _NEG), axis=1)
        pl.store(outd_ref, (slice(None), pl.dslice(j, 1)), m)
        pl.store(outl_ref, (slice(None), pl.dslice(j, 1)), lab[:, None])
        return jnp.where(oh, jnp.inf, cur)

    jax.lax.fori_loop(0, k, body, dist_ref[...])


def topk_pallas(dists: jax.Array, labels: jax.Array, k: int,
                block_q: int = 8, interpret: bool = False
                ) -> tuple[jax.Array, jax.Array]:
    """dists/labels [Q, L] -> smallest-k (dists [Q,k], labels [Q,k])."""
    qn, nl = dists.shape
    if qn % block_q != 0:
        block_q = 1
    grid = (qn // block_q,)
    return pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, nl), lambda i: (i, 0)),
            pl.BlockSpec((block_q, nl), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda i: (i, 0)),
            pl.BlockSpec((block_q, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, k), dists.dtype),
            jax.ShapeDtypeStruct((qn, k), labels.dtype),
        ],
        interpret=interpret,
    )(dists, labels)
