"""Fused slab-scan -> top-k search — Pallas TPU kernel (paper Alg. 3, whole).

The unfused pipeline (``sivf_scan`` kernel -> ``topk`` kernel) materializes
the full ``[Q, T*C]`` candidate distance/label matrices in HBM between the
two kernels, which caps the query batch size and spends HBM bandwidth on
intermediates the paper's Alg. 3 never writes: the CUDA design keeps a
per-lane *register* top-k while scanning slabs and only ever emits ``[Q, k]``.

This kernel is the TPU analogue of that register top-k:

  * the slab-id table (one row per query, ``T = nprobe * max_chain``
    entries) is scalar-prefetched to SMEM and drives the ``BlockSpec``
    index_map, so each non-contiguous slab tile is DMA'd into VMEM as if it
    were a contiguous operand (§3.3 "coalesced search on non-contiguous
    memory");
  * queries are blocked into ``[bq, D]`` tiles; the grid walks
    ``(q_tile, q_within_tile, slab)`` with the slab axis innermost, and the
    ``[bq, k]`` output block is *revisited* across the inner two axes — it
    lives in VMEM for the whole scan of a query tile and is flushed to HBM
    exactly once per tile;
  * each grid step scores one ``(query, slab)`` pair on the MXU, masks dead
    slots via the validity bitmap, and folds the ``[1, C]`` candidates into
    the running ``[1, k]`` row by k rounds of min-extraction (k is small, so
    k passes over a VMEM-resident ``[1, k+C]`` row beat a sort).

Peak memory is ``O(Q*k + bq*D + C*D)`` instead of the unfused
``O(Q*T*C)`` — the ``T*C`` candidate matrix is never built.

Tie-breaking matches the XLA reference ``core.index.scan_slabs_topk``
exactly: the running buffer occupies the low indices of the merge row and
``lax.top_k`` (reference) / first-index-argmin (here) both prefer lower
indices, so distances AND labels agree bit-for-bit with the streaming
reference on every slab order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

WORD_BITS = 32
_NEG = -(2 ** 31) + 1  # python literal; jnp scalars would be captured consts


def _unpack_bitmap(words: jax.Array, capacity: int) -> jax.Array:
    """[1, W] u32 validity words -> [1, C] bool, slot-ordered."""
    w = capacity // WORD_BITS
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, capacity), 1)
    word_ix = slot // WORD_BITS
    bit_ix = (slot % WORD_BITS).astype(jnp.uint32)
    # gather word per slot via broadcast-compare (W is tiny)
    wsel = jnp.zeros((1, capacity), jnp.uint32)
    for wi in range(w):
        wsel = jnp.where(word_ix == wi, words[0, wi], wsel)
    return (jnp.right_shift(wsel, bit_ix) & jnp.uint32(1)) != 0


def fold_topk(outd_ref, outl_ref, qj, d, lab, *, capacity: int, k: int
              ) -> None:
    """Fold a ``[1, C]`` candidate row into the running ``[1, k]`` top-k.

    Merge row layout = [running k | C candidates]; identical to the
    reference's concatenate order, so first-index tie-breaking matches.
    Shared by the raw fused kernel (here) and the PQ ADC kernel
    (``pq_fused.py``) — candidates that score bit-identically therefore
    select bit-identically.
    """
    run_d = outd_ref[pl.ds(qj, 1), :]                   # [1, k]
    run_l = outl_ref[pl.ds(qj, 1), :]
    cd = jnp.concatenate([run_d, d], axis=1)            # [1, k+C]
    cl = jnp.concatenate([run_l, lab], axis=1)
    m = k + capacity
    col = jax.lax.broadcasted_iota(jnp.int32, (1, m), 1)

    def body(j, cur):
        lo = jnp.min(cur, axis=1, keepdims=True)        # [1, 1]
        ix = jnp.min(jnp.where(cur == lo, col, m), axis=1, keepdims=True)
        oh = col == ix
        lj = jnp.max(jnp.where(oh, cl, _NEG), axis=1, keepdims=True)
        # masking an extracted slot to +inf makes it re-selectable once the
        # true min is +inf; every genuinely-inf slot carries label -1
        # (dead / pad / init), so force -1 there instead of the stale label
        lj = jnp.where(jnp.isinf(lo), -1, lj)
        pl.store(outd_ref, (pl.dslice(qj, 1), pl.dslice(j, 1)), lo)
        pl.store(outl_ref, (pl.dslice(qj, 1), pl.dslice(j, 1)), lj)
        return jnp.where(oh, jnp.inf, cur)

    jax.lax.fori_loop(0, k, body, cd)


def predicate_mask(attrs_ref, consts_ref, fstruct: tuple) -> jax.Array:
    """Evaluate a compiled filter over one slab's attribute tile.

    ``attrs_ref`` holds the slab's attributes *pre-transposed* to
    ``[1, A, C]`` so each attribute row is a native lane-major ``[1, C]``
    vector (no in-kernel relayout); the filter constants live in SMEM via
    the second scalar-prefetch operand. Same ``filters.eval_structure``
    recursion as the XLA references and the host oracle -> identical masks.
    """
    from repro.core.filters import eval_structure
    at = attrs_ref[0]                                   # [A, C] int32
    return eval_structure(
        fstruct,
        lambda j: at[j:j + 1, :],                       # [1, C]
        lambda i: consts_ref[i])


def _kernel(table_ref, *refs, capacity: int, k: int, metric: str,
            fstruct: tuple | None = None):
    if fstruct is None:
        (q_ref, data_ref, ids_ref, norms_ref, bitmap_ref,
         outd_ref, outl_ref) = refs
        consts_ref = attrs_ref = None
    else:
        (consts_ref, q_ref, data_ref, ids_ref, norms_ref, attrs_ref,
         bitmap_ref, outd_ref, outl_ref) = refs
    qj = pl.program_id(1)                               # query within tile
    ti = pl.program_id(2)                               # slab within chain
    bq = pl.num_programs(1)
    t = pl.num_programs(2)
    qi = pl.program_id(0) * bq + qj                     # global query row
    slab = table_ref[qi * t + ti]                       # scalar, may be -1

    # first touch of this output block: reset the running top-k
    @pl.when((qj == 0) & (ti == 0))
    def _init():
        outd_ref[...] = jnp.full((bq, k), jnp.inf, jnp.float32)
        outl_ref[...] = jnp.full((bq, k), -1, jnp.int32)

    # -- score one (query, slab) pair on the MXU ---------------------------
    q = q_ref[pl.ds(qj, 1), :]                          # [1, D]
    x = data_ref[0]                                     # [C, D]
    dot = jax.lax.dot_general(
        q.astype(jnp.float32), x.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)             # [1, C]
    if metric == "l2":
        qq = jnp.sum(q.astype(jnp.float32) ** 2)
        d = qq - 2.0 * dot + norms_ref[...]
    else:
        d = -dot

    valid = _unpack_bitmap(bitmap_ref[...], capacity) & (slab >= 0)
    if fstruct is not None:
        # filtered-out slots fail exactly like deleted slots (+inf / -1):
        # they can never displace a passing candidate from the top-k
        valid &= predicate_mask(attrs_ref, consts_ref, fstruct)
    d = jnp.where(valid, d, jnp.inf)
    lab = jnp.where(valid, ids_ref[...], -1)

    # -- fold candidates into the running [1, k] row -----------------------
    fold_topk(outd_ref, outl_ref, qj, d, lab, capacity=capacity, k=k)


def sivf_fused_search_pallas(queries: jax.Array, table: jax.Array,
                             data: jax.Array, ids: jax.Array,
                             norms: jax.Array, bitmap: jax.Array, k: int,
                             metric: str = "l2", block_q: int = 8,
                             interpret: bool = False,
                             attrs: jax.Array | None = None,
                             fstruct: tuple | None = None,
                             fconsts: jax.Array | None = None
                             ) -> tuple[jax.Array, jax.Array]:
    """queries [Q,D], table [Q,T] -> (dists [Q,k], labels [Q,k]).

    Never materializes the [Q, T*C] candidate matrix; ragged Q is handled
    by padding to a block_q multiple with -1 slab rows (masked to +inf).

    With ``fstruct`` set (a compiled predicate structure from
    ``core.filters``), ``attrs`` ``[n_slabs, C, A]`` rides as one more
    slab-indexed operand (transposed here to ``[n_slabs, A, C]`` so the
    kernel reads lane-major attribute rows) and ``fconsts`` becomes a
    *second* scalar-prefetch operand — filter constants are data in SMEM,
    so every predicate of the same structure shares this one kernel.
    """
    qn, d_dim = queries.shape
    t = table.shape[1]
    _, c, _ = data.shape
    w = bitmap.shape[1]
    filtered = fstruct is not None

    bq = max(1, min(block_q, qn))
    pad = (-qn) % bq
    if pad:
        queries = jnp.concatenate(
            [queries, jnp.zeros((pad, d_dim), queries.dtype)])
        table = jnp.concatenate(
            [table, jnp.full((pad, t), -1, table.dtype)])
    qp = qn + pad

    grid = (qp // bq, bq, t)

    def slab_ix(qt, qj, ti, tab, *_):
        return (jnp.maximum(tab[(qt * bq + qj) * t + ti], 0), 0, 0)

    def slab_ix2(qt, qj, ti, tab, *_):
        return (jnp.maximum(tab[(qt * bq + qj) * t + ti], 0), 0)

    def q_ix(qt, qj, ti, *_):
        return (qt, 0)

    in_specs = [
        pl.BlockSpec((bq, d_dim), q_ix),                             # q
        pl.BlockSpec((1, c, d_dim), slab_ix),                        # data
        pl.BlockSpec((1, c), slab_ix2),                              # ids
        pl.BlockSpec((1, c), slab_ix2),                              # norms
    ]
    operands = [queries, data, ids, norms]
    if filtered:
        a = attrs.shape[-1]
        in_specs.append(pl.BlockSpec((1, a, c), slab_ix))            # attrs
        operands.append(attrs.swapaxes(1, 2))         # [n_slabs, A, C]
    in_specs.append(pl.BlockSpec((1, w), slab_ix2))                  # bitmap
    operands.append(bitmap)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2 if filtered else 1,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bq, k), q_ix),
            pl.BlockSpec((bq, k), q_ix),
        ],
    )
    kernel = functools.partial(_kernel, capacity=c, k=k, metric=metric,
                               fstruct=fstruct)
    prefetch = [table.reshape(-1)]
    if filtered:
        prefetch.append(fconsts.astype(jnp.int32))
    dists, labels = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qp, k), jnp.float32),
            jax.ShapeDtypeStruct((qp, k), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(*prefetch, *operands)
    return dists[:qn], labels[:qn]
