"""Pure-jnp oracle for the SIVF slab-scan kernel (paper Alg. 3 inner loop)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitmap as bm


def sivf_scan_ref(queries, table, data, ids, norms, bitmap, metric="l2"):
    """Validity-masked distances over gathered slabs.

    queries [Q, D] f32; table [Q, T] int32 slab ids (-1 pad);
    data [n_slabs, C, D]; ids [n_slabs, C] i32; norms [n_slabs, C] f32;
    bitmap [n_slabs, W] u32.
    Returns (dists [Q, T*C] f32 — +inf for dead/pad slots, labels [Q, T*C]).
    """
    qn, t = table.shape
    c = data.shape[1]
    sc = jnp.clip(table, 0)                                   # [Q, T]
    x = data[sc].astype(jnp.float32)                          # [Q, T, C, D]
    vb = bm.unpack_batch(bitmap[sc], c)                       # [Q, T, C]
    ok = vb & (table >= 0)[..., None]
    qf = queries.astype(jnp.float32)
    dot = jnp.einsum("qd,qtcd->qtc", qf, x)
    if metric == "l2":
        qq = jnp.sum(qf * qf, axis=-1)[:, None, None]
        d = qq - 2.0 * dot + norms[sc]
    else:
        d = -dot
    d = jnp.where(ok, d, jnp.inf)
    lab = jnp.where(ok, ids[sc], -1)
    return d.reshape(qn, t * c), lab.reshape(qn, t * c)
