"""Fused ADC scan -> top-k over PQ-compressed slabs — Pallas TPU kernel.

The raw fused kernel (``fused.py``) is bandwidth-bound on slab payload DMA:
every (query, slab) step moves a ``[C, D]`` fp32 tile from HBM. With
product quantization (``core/pq.py``) the same step only moves the
``[C, m]`` uint8 code tile — an ``4*D/m``-fold cut in scanned bytes (~32x
at D=64, m=8) — and scores candidates by *asymmetric distance*: per-query
lookup tables ``adc[s, j] = d(q_s, codebook[s, j])`` are staged once per
query tile in VMEM and a candidate's distance is the sum of its ``m``
table entries.

Same shape as ``fused.py`` otherwise:

  * the slab-id table is scalar-prefetched to SMEM and drives the code /
    id / bitmap ``BlockSpec`` index maps, so non-contiguous compressed
    slabs DMA as if contiguous;
  * the grid walks ``(q_tile, q_within_tile, slab)``, the ``[bq, k]``
    output block is revisited across the inner two axes and flushed once
    per tile;
  * deleted slots mask through the validity bitmap, empty chains (-1 slab
    ids) score +inf / label -1.

TPU has no fast VMEM gather, so each subspace's lookup is a one-hot
matmul: ``sel[C, ksub] @ adc_s[ksub]`` on the MXU. Exactly one product per
row is the (finite) table entry and the rest are 0.0, so each term equals
the gathered entry *bit-for-bit*; terms accumulate in ascending-subspace
order, matching ``core.index.scan_slabs_topk_pq``'s left-to-right adds.
The shared ``fold_topk`` then keeps selection/tie-breaking identical, so
the whole kernel is bit-exact against the XLA ADC reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sivf_scan.fused import (
    _unpack_bitmap,
    fold_topk,
    predicate_mask,
)


def _pq_kernel(table_ref, *refs, capacity: int, k: int, m: int,
               ksub: int, fstruct: tuple | None = None):
    if fstruct is None:
        (adc_ref, codes_ref, ids_ref, bitmap_ref,
         outd_ref, outl_ref) = refs
        consts_ref = attrs_ref = None
    else:
        (consts_ref, adc_ref, codes_ref, ids_ref, attrs_ref, bitmap_ref,
         outd_ref, outl_ref) = refs
    qj = pl.program_id(1)                               # query within tile
    ti = pl.program_id(2)                               # slab within chain
    bq = pl.num_programs(1)
    t = pl.num_programs(2)
    qi = pl.program_id(0) * bq + qj                     # global query row
    slab = table_ref[qi * t + ti]                       # scalar, may be -1

    @pl.when((qj == 0) & (ti == 0))
    def _init():
        outd_ref[...] = jnp.full((bq, k), jnp.inf, jnp.float32)
        outl_ref[...] = jnp.full((bq, k), -1, jnp.int32)

    # -- ADC-score one (query, slab) pair ----------------------------------
    codes = codes_ref[0].astype(jnp.int32)              # [C, m]
    kcol = jax.lax.broadcasted_iota(jnp.int32, (capacity, ksub), 1)
    d = None
    for s in range(m):                                  # ascending subspaces
        sel = (kcol == codes[:, s][:, None]).astype(jnp.float32)  # [C, K]
        adc_s = adc_ref[pl.ds(qj, 1), pl.ds(s * ksub, ksub)]      # [1, K]
        # HIGHEST precision: the default MXU pass truncates f32 operands
        # to bf16, which would round the looked-up table entry and break
        # bit-exactness on real TPUs (interpret mode hides this)
        term = jax.lax.dot_general(
            adc_s, sel, (((1,), (1,)), ((), ())),
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=jnp.float32)         # [1, C]
        d = term if d is None else d + term

    valid = _unpack_bitmap(bitmap_ref[...], capacity) & (slab >= 0)
    if fstruct is not None:
        # filtered-out slots fail exactly like deleted slots (+inf / -1)
        valid &= predicate_mask(attrs_ref, consts_ref, fstruct)
    d = jnp.where(valid, d, jnp.inf)
    lab = jnp.where(valid, ids_ref[...], -1)

    fold_topk(outd_ref, outl_ref, qj, d, lab, capacity=capacity, k=k)


def sivf_pq_fused_search_pallas(adc: jax.Array, table: jax.Array,
                                codes: jax.Array, ids: jax.Array,
                                bitmap: jax.Array, k: int, block_q: int = 8,
                                interpret: bool = False,
                                attrs: jax.Array | None = None,
                                fstruct: tuple | None = None,
                                fconsts: jax.Array | None = None
                                ) -> tuple[jax.Array, jax.Array]:
    """adc [Q, m, ksub], table [Q, T] -> (dists [Q, k], labels [Q, k]).

    ``adc`` comes from ``core.pq.adc_tables`` (already metric-shaped, so
    the kernel itself is metric-agnostic); ragged Q pads to a ``block_q``
    multiple with -1 slab rows (masked to +inf) and zero ADC rows.

    ``attrs``/``fstruct``/``fconsts`` add the compiled-predicate mask
    exactly as in ``fused.sivf_fused_search_pallas``: attributes become a
    slab-indexed ``[1, A, C]`` operand, constants a second scalar-prefetch
    SMEM vector, and filtered-out slots mask before the top-k fold.
    """
    qn, m, ksub = adc.shape
    t = table.shape[1]
    _, c, _ = codes.shape
    w = bitmap.shape[1]
    filtered = fstruct is not None
    adc = adc.reshape(qn, m * ksub)                     # row-major [s, j]

    bq = max(1, min(block_q, qn))
    pad = (-qn) % bq
    if pad:
        adc = jnp.concatenate(
            [adc, jnp.zeros((pad, m * ksub), adc.dtype)])
        table = jnp.concatenate(
            [table, jnp.full((pad, t), -1, table.dtype)])
    qp = qn + pad

    grid = (qp // bq, bq, t)

    def slab_ix(qt, qj, ti, tab, *_):
        return (jnp.maximum(tab[(qt * bq + qj) * t + ti], 0), 0, 0)

    def slab_ix2(qt, qj, ti, tab, *_):
        return (jnp.maximum(tab[(qt * bq + qj) * t + ti], 0), 0)

    def q_ix(qt, qj, ti, *_):
        return (qt, 0)

    in_specs = [
        pl.BlockSpec((bq, m * ksub), q_ix),
        pl.BlockSpec((1, c, m), slab_ix),                        # codes
        pl.BlockSpec((1, c), slab_ix2),                          # ids
    ]
    operands = [adc, codes, ids]
    if filtered:
        a = attrs.shape[-1]
        in_specs.append(pl.BlockSpec((1, a, c), slab_ix))        # attrs
        operands.append(attrs.swapaxes(1, 2))     # [n_slabs, A, C]
    in_specs.append(pl.BlockSpec((1, w), slab_ix2))              # bitmap
    operands.append(bitmap)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2 if filtered else 1,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bq, k), q_ix),
            pl.BlockSpec((bq, k), q_ix),
        ],
    )
    kernel = functools.partial(_pq_kernel, capacity=c, k=k, m=m, ksub=ksub,
                               fstruct=fstruct)
    prefetch = [table.reshape(-1)]
    if filtered:
        prefetch.append(fconsts.astype(jnp.int32))
    dists, labels = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qp, k), jnp.float32),
            jax.ShapeDtypeStruct((qp, k), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(*prefetch, *operands)
    return dists[:qn], labels[:qn]
