"""Lane-cooperative slab scan — Pallas TPU kernel (paper Alg. 3, adapted).

The paper assigns one *warp* per query and matches slab capacity C to the
warp width (32) so lane j evaluates slot j. The TPU analogue (DESIGN.md §2):
slab capacity C = 128 matches the lane width; each grid step evaluates one
(query, slab) pair as a `[1, D] x [D, C]` MXU matmul, with the validity
bitmap unpacked in-register to mask dead slots to +inf.

Slab indirection ("coalesced search on non-contiguous memory", §3.3) is
expressed with a scalar-prefetched block table: the slab-id table is
prefetched to SMEM and drives the BlockSpec index_map, so each slab tile is
DMA'd into VMEM exactly like a contiguous operand — the TPU equivalent of
the paper's coalesced slab loads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

WORD_BITS = 32


def _kernel(table_ref, q_ref, data_ref, ids_ref, norms_ref, bitmap_ref,
            dist_ref, lab_ref, *, capacity: int, metric: str):
    qi = pl.program_id(0)
    ti = pl.program_id(1)
    t = pl.num_programs(1)
    slab = table_ref[qi * t + ti]                       # scalar, may be -1

    q = q_ref[...]                                      # [1, D]
    x = data_ref[0]                                     # [C, D]
    dot = jax.lax.dot_general(
        q.astype(jnp.float32), x.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)             # [1, C]
    if metric == "l2":
        qq = jnp.sum(q.astype(jnp.float32) ** 2)
        d = qq - 2.0 * dot + norms_ref[...]             # [1, C]
    else:
        d = -dot

    # unpack validity bitmap: [1, W] u32 -> [1, C] bool
    w = capacity // WORD_BITS
    words = bitmap_ref[...]                             # [1, W]
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, capacity), 1)
    word_ix = slot // WORD_BITS
    bit_ix = (slot % WORD_BITS).astype(jnp.uint32)
    # gather word per slot via broadcast-compare (W is tiny)
    wsel = jnp.zeros((1, capacity), jnp.uint32)
    for wi in range(w):
        wsel = jnp.where(word_ix == wi, words[0, wi], wsel)
    bits = (jnp.right_shift(wsel, bit_ix) & jnp.uint32(1)) != 0
    valid = bits & (slab >= 0)

    dist_ref[...] = jnp.where(valid, d, jnp.inf)
    lab_ref[...] = jnp.where(valid, ids_ref[...], -1)


def sivf_scan_pallas(queries: jax.Array, table: jax.Array, data: jax.Array,
                     ids: jax.Array, norms: jax.Array, bitmap: jax.Array,
                     metric: str = "l2", interpret: bool = False
                     ) -> tuple[jax.Array, jax.Array]:
    """queries [Q,D], table [Q,T] -> (dists [Q,T*C], labels [Q,T*C])."""
    qn, d_dim = queries.shape
    t = table.shape[1]
    n_slabs, c, _ = data.shape
    w = bitmap.shape[1]

    grid = (qn, t)

    def slab_ix(qi, ti, tab):
        return (jnp.maximum(tab[qi * t + ti], 0), 0, 0)

    def slab_ix2(qi, ti, tab):
        return (jnp.maximum(tab[qi * t + ti], 0), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d_dim), lambda qi, ti, tab: (qi, 0)),      # q
            pl.BlockSpec((1, c, d_dim), slab_ix),                        # data
            pl.BlockSpec((1, c), slab_ix2),                              # ids
            pl.BlockSpec((1, c), slab_ix2),                              # norms
            pl.BlockSpec((1, w), slab_ix2),                              # bitmap
        ],
        out_specs=[
            pl.BlockSpec((1, c), lambda qi, ti, tab: (qi, ti)),
            pl.BlockSpec((1, c), lambda qi, ti, tab: (qi, ti)),
        ],
    )
    kernel = functools.partial(_kernel, capacity=c, metric=metric)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qn, t * c), jnp.float32),
            jax.ShapeDtypeStruct((qn, t * c), jnp.int32),
        ],
        interpret=interpret,
    )(table.reshape(-1), queries, data, ids, norms, bitmap)
