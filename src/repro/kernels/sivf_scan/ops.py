"""Jitted public wrappers for the SIVF slab-scan kernels.

``sivf_scan`` is the legacy *unfused* scan: it materializes the full
``[Q, T*C]`` candidate matrix and leaves k-selection to the separate topk
kernel. ``sivf_fused_search`` is the fused scan->top-k pipeline that emits
``[Q, k]`` directly (kernels/sivf_scan/fused.py); new code should use it —
the unfused pair is kept as the memory-heavy baseline for benchmarks and
kernel-level tests.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.sivf_scan.fused import sivf_fused_search_pallas
from repro.kernels.sivf_scan.ref import sivf_scan_ref
from repro.kernels.sivf_scan.sivf_scan import sivf_scan_pallas


@partial(jax.jit, static_argnames=("metric", "interpret", "impl"))
def sivf_scan(queries, table, data, ids, norms, bitmap, metric: str = "l2",
              interpret: bool = False, impl: str = "pallas"):
    """Validity-masked slab distance scan (unfused; returns [Q, T*C]).

    impl="pallas": the TPU kernel (interpret=True to emulate on CPU);
    impl="ref": the pure-jnp oracle (memory-heavy; test sizes only).
    """
    if impl == "ref":
        return sivf_scan_ref(queries, table, data, ids, norms, bitmap, metric)
    return sivf_scan_pallas(queries, table, data, ids, norms, bitmap,
                            metric=metric, interpret=interpret)


@partial(jax.jit,
         static_argnames=("k", "metric", "block_q", "interpret", "impl"))
def sivf_fused_search(queries, table, data, ids, norms, bitmap, k: int,
                      metric: str = "l2", block_q: int = 8,
                      interpret: bool = False, impl: str = "pallas"):
    """Fused scan->top-k: queries [Q,D], table [Q,T] -> ([Q,k], [Q,k]).

    impl="pallas": the fused TPU kernel (interpret=True to emulate on CPU);
    impl="ref": unfused oracle composition (sivf_scan_ref + lax.top_k) —
    memory-heavy, test sizes only.
    """
    if impl == "ref":
        import jax.numpy as jnp
        d, lab = sivf_scan_ref(queries, table, data, ids, norms, bitmap,
                               metric)
        nd, idx = jax.lax.top_k(-d, k)
        return -nd, jnp.take_along_axis(lab, idx, axis=1)
    return sivf_fused_search_pallas(queries, table, data, ids, norms, bitmap,
                                    k, metric=metric, block_q=block_q,
                                    interpret=interpret)


def executable_counts() -> dict[str, int]:
    """Observed jit-cache sizes of the ops-level kernel entry points.

    The telemetry layer's kernel-granularity twin of
    ``Index.compile_stats()``: these module-level jits are shared by every
    caller in the process, so a growing count here during steady-state
    serving is a compile storm at the kernel boundary (a shape or static
    argument is churning). -1 when the private cache-size API is
    unavailable.
    """
    def size(f):
        try:
            return int(f._cache_size())
        except Exception:               # pragma: no cover - private API
            return -1
    return {"sivf_scan": size(sivf_scan),
            "sivf_fused_search": size(sivf_fused_search)}


def translate_table(table, frame_of):
    """Rewrite a pool-slab-id table into cache-frame coordinates.

    ``table`` [Q, T] int32 pool slab ids (-1 pad), ``frame_of`` [n_slabs]
    int32 residency map (slab id -> cache frame, core/tiered.py). Returns
    the same-shape table with every live entry replaced by its cache
    frame, -1 pads preserved. This is the *only* adaptation the tiered
    slab cache needs at the kernel boundary: the fused / PQ / filtered
    scan kernels consume whatever slab table the scalar-prefetch operand
    carries, so feeding them a frame-translated table plus the cache
    planes leaves their math untouched — searches stay bit-exact against
    the all-resident pool. Every entry the caller passes must be resident
    (``frame_of[entry] >= 0``); prefetch guarantees that, and stale
    entries for *evicted* slabs are never read because a slab re-enters a
    table only through a prefetch that re-uploads it first.
    """
    import jax.numpy as jnp
    return jnp.where(table >= 0, frame_of[jnp.clip(table, 0)], -1)


# The PQ ADC kernel has no queries+codebooks wrapper here on purpose: the
# ADC table must be built ONCE per query batch and shared with whatever it
# is compared against (compiler fusion makes independent builds differ at
# the ULP level). Go through ``core.search`` / ``core._scan_dispatch``, or
# call ``pq_fused.sivf_pq_fused_search_pallas`` with an explicit table from
# ``core.pq.adc_tables``.
