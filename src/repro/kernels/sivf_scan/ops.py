"""Jitted public wrapper for the SIVF slab-scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.sivf_scan.ref import sivf_scan_ref
from repro.kernels.sivf_scan.sivf_scan import sivf_scan_pallas


@partial(jax.jit, static_argnames=("metric", "interpret", "impl"))
def sivf_scan(queries, table, data, ids, norms, bitmap, metric: str = "l2",
              interpret: bool = False, impl: str = "pallas"):
    """Validity-masked slab distance scan.

    impl="pallas": the TPU kernel (interpret=True to emulate on CPU);
    impl="ref": the pure-jnp oracle (memory-heavy; test sizes only).
    """
    if impl == "ref":
        return sivf_scan_ref(queries, table, data, ids, norms, bitmap, metric)
    return sivf_scan_pallas(queries, table, data, ids, norms, bitmap,
                            metric=metric, interpret=interpret)
