"""Jitted public wrapper for flash attention."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import mha_ref


@partial(jax.jit, static_argnames=("causal", "scale", "interpret", "impl",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, scale: float | None = None,
                    interpret: bool = False, impl: str = "pallas",
                    block_q: int = 128, block_k: int = 128):
    if impl == "ref":
        return mha_ref(q, k, v, causal=causal, scale=scale)
    return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)
