"""Flash attention — Pallas TPU kernel (online-softmax tiling, GQA).

Grid (B*Hq, Sq/bq, Sk/bk); the kv dimension is innermost so the VMEM
scratch (acc, m, l) carries the online softmax across kv tiles, and the
output tile is written once on the final kv step. BlockSpecs keep one
(q-tile, kv-tile) working set in VMEM; MXU dims are the (bq, dh) x (dh, bk)
score matmul and the (bq, bk) x (bk, dh) value matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = float("-inf")


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale: float, causal: bool, bq: int, bk: int,
            sq: int, sk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: skip kv tiles strictly above the diagonal band
    qpos_hi = qi * bq + bq - 1 + (sk - sq)
    run = (not causal) or (ki * bk <= qpos_hi)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)                     # [bq, dh]
        k = k_ref[0].astype(jnp.float32)                     # [bk, dh]
        v = v_ref[0].astype(jnp.float32)                     # [bk, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
                + qi * bq + (sk - sq)
            kpos = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1) + ki * bk
            s = jnp.where(qpos >= kpos, s, _NEG_INF)
        m_prev = m_ref[...]                                  # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                               # [bq, bk]
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _write():
        denom = l_ref[...]
        o_ref[0] = jnp.where(denom > 0, acc_ref[...] / jnp.maximum(denom, 1e-30),
                             0.0).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, causal: bool = True,
                           scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q [B,Hq,Sq,dh]; k,v [B,Hkv,Sk,dh] -> [B,Hq,Sq,dh]."""
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = dh ** -0.5 if scale is None else scale
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0

    grid = (b * hq, sq // bq, sk // bk)

    def q_ix(bh, qi, ki):
        return (bh, qi, 0)

    def kv_ix(bh, qi, ki):
        return ((bh // hq) * hkv + (bh % hq) // g, ki, 0)

    qr = q.reshape(b * hq, sq, dh)
    kr = k.reshape(b * hkv, sk, dh)
    vr = v.reshape(b * hkv, sk, dh)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq, bk=bk,
                          sq=sq, sk=sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), q_ix),
            pl.BlockSpec((1, bk, dh), kv_ix),
            pl.BlockSpec((1, bk, dh), kv_ix),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), q_ix),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, dh)
