"""Pure-jnp oracle for flash attention (GQA, optional causal)."""
from __future__ import annotations

import jax.numpy as jnp


def mha_ref(q, k, v, causal: bool = True, scale: float | None = None):
    """q [B,Hq,Sq,dh]; k,v [B,Hkv,Sk,dh] -> [B,Hq,Sq,dh].

    GQA: q head h attends to kv head h // (Hq // Hkv). Causal masking uses
    the ends-aligned convention (q position i maps to absolute position
    i + Sk - Sq), which covers both prefill (Sq == Sk) and chunked decode.
    """
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = dh ** -0.5 if scale is None else scale
    kq = jnp.repeat(k, g, axis=1)
    vq = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kq.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(sq)[:, None] + (sk - sq)
        kpos = jnp.arange(sk)[None, :]
        s = jnp.where(qpos >= kpos, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vq.astype(jnp.float32)).astype(q.dtype)
