"""Paged decode attention — Pallas TPU kernel.

This is the serving-side counterpart of the paper's "coalesced search on
non-contiguous memory" (§3.3): KV pages are SIVF slabs, the per-sequence
block table is the address-translation table, and the kernel streams pages
through VMEM with a scalar-prefetched index map — identical machinery to
kernels/sivf_scan, applied to attention instead of distance scan.

Grid (B, Hq, max_pages), online softmax accumulated in VMEM scratch across
the page dimension (innermost), output written on the last page step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = float("-inf")


def _kernel(tables_ref, lengths_ref, starts_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float, page: int, maxp: int):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    page_id = tables_ref[b * maxp + pi]
    length = lengths_ref[b]
    start = starts_ref[b]
    run = (page_id >= 0) & (pi * page < length) & ((pi + 1) * page > start)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)                     # [1, dh]
        k = k_ref[0, :, 0].astype(jnp.float32)               # [page, dh]
        v = v_ref[0, :, 0].astype(jnp.float32)               # [page, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        slot = jax.lax.broadcasted_iota(jnp.int32, (1, page), 1) + pi * page
        s = jnp.where((slot < length) & (slot >= start), s, _NEG_INF)
        m_prev = m_ref[...]                                  # [1, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                               # [1, page]
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(pi == maxp - 1)
    def _write():
        denom = l_ref[...]
        o_ref[0] = jnp.where(denom > 0, acc_ref[...] / jnp.maximum(denom, 1e-30),
                            0.0).astype(o_ref.dtype)


def paged_attention_pallas(q, k_pages, v_pages, block_tables, lengths,
                           starts=None, scale: float | None = None,
                           interpret: bool = False):
    """q [B,Hq,dk]; k/v pages [P,page,Hkv,dk|dv] -> [B,Hq,dv]."""
    b, hq, dk = q.shape
    n_pages, page, hkv, _ = k_pages.shape
    dv = v_pages.shape[-1]
    g = hq // hkv
    maxp = block_tables.shape[1]
    scale = dk ** -0.5 if scale is None else scale

    if starts is None:
        import jax.numpy as _jnp
        starts = _jnp.zeros_like(lengths)
    grid = (b, hq, maxp)

    def q_ix(bi, hi, pi, tab, lens, sts):
        return (bi, hi, 0)

    def kv_ix(bi, hi, pi, tab, lens, sts):
        return (jnp.maximum(tab[bi * maxp + pi], 0), 0, hi // g, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, dk), q_ix),
            pl.BlockSpec((1, page, 1, dk), kv_ix),
            pl.BlockSpec((1, page, 1, dv), kv_ix),
        ],
        out_specs=pl.BlockSpec((1, 1, dv), q_ix),
        scratch_shapes=[
            pltpu.VMEM((1, dv), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, page=page, maxp=maxp),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, dv), q.dtype),
        interpret=interpret,
    )(block_tables.reshape(-1), lengths, starts, q, k_pages, v_pages)
