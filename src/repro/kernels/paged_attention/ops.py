"""Jitted public wrapper for paged decode attention."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.paged_attention.paged_attention import paged_attention_pallas
from repro.kernels.paged_attention.ref import paged_attention_ref


@partial(jax.jit, static_argnames=("scale", "interpret", "impl"))
def paged_attention(q, k_pages, v_pages, block_tables, lengths,
                    starts=None, scale: float | None = None,
                    interpret: bool = False, impl: str = "pallas"):
    if impl == "ref":
        return paged_attention_ref(q, k_pages, v_pages, block_tables,
                                   lengths, starts=starts, scale=scale)
    return paged_attention_pallas(q, k_pages, v_pages, block_tables, lengths,
                                  starts=starts, scale=scale,
                                  interpret=interpret)
