"""Pure-jnp oracle for paged decode attention over SIVF-style slab pages."""
from __future__ import annotations

import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                        starts=None, scale: float | None = None):
    """Decode attention over a non-contiguous paged KV cache.

    q [B, Hq, dh] (one new token per sequence);
    k_pages / v_pages [n_pages, page, Hkv, dh] — the slab pool;
    block_tables [B, max_pages] int32 page ids (-1 pad) — the per-sequence
    ATT (paper §3.4); lengths [B] — live tokens per sequence.
    Returns [B, Hq, dh].
    """
    b, hq, dk = q.shape
    n_pages, page, hkv, _ = k_pages.shape
    dv = v_pages.shape[-1]
    g = hq // hkv
    scale = dk ** -0.5 if scale is None else scale
    maxp = block_tables.shape[1]

    tab = jnp.clip(block_tables, 0)
    k = k_pages[tab].reshape(b, maxp * page, hkv, dk)        # [B, S, Hkv, dk]
    v = v_pages[tab].reshape(b, maxp * page, hkv, dv)
    pos = jnp.arange(maxp * page)[None, :]
    ok = (pos < lengths[:, None]) & jnp.repeat(
        block_tables >= 0, page, axis=1)
    if starts is not None:      # sliding-window lower bound (cache coords)
        ok = ok & (pos >= starts[:, None])
    kq = jnp.repeat(k, g, axis=2)                            # [B, S, Hq, dh]
    vq = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   kq.astype(jnp.float32)) * scale
    s = jnp.where(ok[:, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)   # fully-masked rows -> output 0
    p = jnp.exp(s - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhs,bshd->bhd", p,
                      vq.astype(jnp.float32)).astype(q.dtype)
