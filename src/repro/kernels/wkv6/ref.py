"""Pure-jnp oracle for the RWKV6 (Finch) WKV recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u):
    """RWKV6 data-dependent-decay recurrence (arXiv:2404.05892 eq. WKV).

    r, k, w [B, T, H, dk]; v [B, T, H, dv]; u [H, dk] bonus.
      y_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t
      S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Returns y [B, T, H, dv] (f32).
    """
    b, t, h, dk = r.shape
    dv = v.shape[-1]

    def head(r_h, k_h, v_h, w_h, u_h):      # [T, dk] ... u_h [dk]
        def step(s, x):
            r_t, k_t, v_t, w_t = x
            kv = k_t[:, None] * v_t[None, :]               # [dk, dv]
            y = (s + u_h[:, None] * kv).T @ r_t            # [dv]
            s = w_t[:, None] * s + kv
            return s, y

        s0 = jnp.zeros((dk, dv), jnp.float32)
        _, y = jax.lax.scan(step, s0, (r_h, k_h, v_h, w_h))
        return y                                            # [T, dv]

    f = jax.vmap(jax.vmap(head, in_axes=(1, 1, 1, 1, 0), out_axes=1),
                 in_axes=(0, 0, 0, 0, None), out_axes=0)
    return f(r.astype(jnp.float32), k.astype(jnp.float32),
             v.astype(jnp.float32), w.astype(jnp.float32),
             u.astype(jnp.float32))
