"""RWKV6 WKV recurrence — Pallas TPU kernel.

Grid (B, H): each step owns one (batch, head) pair; the [dk, dv] recurrent
state lives in VMEM scratch and the T-loop runs inside the kernel (the
recurrence is inherently sequential in T; parallelism comes from the B*H
grid, which is how the official CUDA kernel is launched too).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_ref):
    t_len = r_ref.shape[1]
    s_ref[...] = jnp.zeros_like(s_ref)
    u = u_ref[...]                                           # [1, dk]

    # bare-int indices are rejected by older pallas releases; use size-1
    # dynamic slices and flatten instead
    def body(t, _):
        row = (pl.dslice(0, 1), pl.dslice(t, 1), pl.dslice(0, 1), slice(None))
        r_t = pl.load(r_ref, row).reshape(1, -1)
        k_t = pl.load(k_ref, row).reshape(1, -1)
        v_t = pl.load(v_ref, row).reshape(1, -1)
        w_t = pl.load(w_ref, row).reshape(1, -1)
        kv = k_t.reshape(-1, 1) * v_t                        # [dk, dv]
        s = s_ref[...]
        y = jax.lax.dot_general(                              # [1, dv]
            r_t, s + u.reshape(-1, 1) * kv,
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        pl.store(y_ref,
                 (pl.dslice(0, 1), pl.dslice(t, 1), pl.dslice(0, 1),
                  slice(None)),
                 y.reshape(1, 1, 1, -1))
        s_ref[...] = w_t.reshape(-1, 1) * s + kv
        return 0

    jax.lax.fori_loop(0, t_len, body, 0)


def wkv6_pallas(r, k, v, w, u, interpret: bool = False):
    """r,k,w [B,T,H,dk]; v [B,T,H,dv]; u [H,dk] -> y [B,T,H,dv] f32."""
    b, t, h, dk = r.shape
    dv = v.shape[-1]
    grid = (b, h)

    def x_ix(bi, hi):
        return (bi, 0, hi, 0)

    def u_ix(bi, hi):
        return (hi, 0)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, 1, dk), x_ix),
            pl.BlockSpec((1, t, 1, dk), x_ix),
            pl.BlockSpec((1, t, 1, dv), x_ix),
            pl.BlockSpec((1, t, 1, dk), x_ix),
            pl.BlockSpec((1, dk), u_ix),
        ],
        out_specs=pl.BlockSpec((1, t, 1, dv), x_ix),
        out_shape=jax.ShapeDtypeStruct((b, t, h, dv), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
      w.astype(jnp.float32), u.astype(jnp.float32))
