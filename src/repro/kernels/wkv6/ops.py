"""Jitted public wrapper for the RWKV6 WKV kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.wkv6.ref import wkv6_ref
from repro.kernels.wkv6.wkv6 import wkv6_pallas


@partial(jax.jit, static_argnames=("interpret", "impl"))
def wkv6(r, k, v, w, u, interpret: bool = False, impl: str = "pallas"):
    if impl == "ref":
        return wkv6_ref(r, k, v, w, u)
    return wkv6_pallas(r, k, v, w, u, interpret=interpret)
