"""Pure-jnp oracle for the Mamba (S6) selective state-space scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(u, delta, a, b, c, d):
    """Selective scan (Mamba, arXiv:2312.00752 Alg. 2).

    u, delta [B, T, di]; a [di, n]; b, c [B, T, n]; d [di].
      h_t = exp(delta_t * a) ⊙ h_{t-1} + (delta_t * u_t) b_t^T
      y_t = h_t c_t + d ⊙ u_t
    Returns y [B, T, di] (f32).
    """
    uf = u.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    da = jnp.exp(jnp.einsum("btd,dn->btdn", df, a.astype(jnp.float32)))
    dbu = jnp.einsum("btd,btn->btdn", df * uf, b.astype(jnp.float32))

    def step(h, x):
        da_t, dbu_t, c_t = x
        h = da_t * h + dbu_t                      # [di, n]
        y = h @ c_t                               # [di]
        return h, y

    def seq(da_s, dbu_s, c_s):
        h0 = jnp.zeros(da_s.shape[1:], jnp.float32)
        _, y = jax.lax.scan(step, h0, (da_s, dbu_s, c_s.astype(jnp.float32)))
        return y

    y = jax.vmap(seq)(da, dbu, c)
    return y + d.astype(jnp.float32)[None, None, :] * uf
