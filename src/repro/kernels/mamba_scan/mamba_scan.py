"""Mamba selective scan — Pallas TPU kernel.

Grid (B, di/bd): each step owns a [bd] channel tile of one sequence; the
[bd, n] SSM state sits in VMEM scratch and the T-loop runs in-kernel. The
channel tile is the TPU parallelism axis (the CUDA kernel parallelizes the
same way over threadblocks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_ref):
    t_len = u_ref.shape[1]
    h_ref[...] = jnp.zeros_like(h_ref)
    a = a_ref[...]                                             # [bd, n]
    d = d_ref[...]                                             # [1, bd]

    # bare-int indices are rejected by older pallas releases; use size-1
    # dynamic slices and flatten instead
    def body(t, _):
        row = (pl.dslice(0, 1), pl.dslice(t, 1), slice(None))
        u_t = pl.load(u_ref, row).reshape(-1)                  # [bd]
        dt_t = pl.load(dt_ref, row).reshape(-1)
        b_t = pl.load(b_ref, row).reshape(-1)                  # [n]
        c_t = pl.load(c_ref, row).reshape(-1)
        da = jnp.exp(dt_t.reshape(-1, 1) * a)                  # [bd, n]
        h = da * h_ref[...] + (dt_t * u_t).reshape(-1, 1) * b_t.reshape(1, -1)
        h_ref[...] = h
        y = jax.lax.dot_general(h, c_t.reshape(-1, 1),
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [bd, 1]
        y = y.reshape(1, -1) + d * u_t.reshape(1, -1)
        pl.store(y_ref, (pl.dslice(0, 1), pl.dslice(t, 1), slice(None)),
                 y.reshape(1, 1, -1))
        return 0

    jax.lax.fori_loop(0, t_len, body, 0)


def mamba_scan_pallas(u, delta, a, b, c, d, block_d: int = 128,
                      interpret: bool = False):
    """u, delta [B,T,di]; a [di,n]; b,c [B,T,n]; d [di] -> y [B,T,di] f32."""
    bsz, t, di = u.shape
    n = a.shape[1]
    bd = min(block_d, di)
    assert di % bd == 0
    grid = (bsz, di // bd)

    def x_ix(bi, ci):
        return (bi, 0, ci)

    def bc_ix(bi, ci):
        return (bi, 0, 0)

    def a_ix(bi, ci):
        return (ci, 0)

    def d_ix(bi, ci):
        return (0, ci)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, bd), x_ix),       # u
            pl.BlockSpec((1, t, bd), x_ix),       # delta
            pl.BlockSpec((bd, n), a_ix),          # a
            pl.BlockSpec((1, t, n), bc_ix),       # b
            pl.BlockSpec((1, t, n), bc_ix),       # c
            pl.BlockSpec((1, bd), d_ix),          # d
        ],
        out_specs=pl.BlockSpec((1, t, bd), x_ix),
        out_shape=jax.ShapeDtypeStruct((bsz, t, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(u.astype(jnp.float32), delta.astype(jnp.float32),
      a.astype(jnp.float32), b.astype(jnp.float32), c.astype(jnp.float32),
      d.astype(jnp.float32).reshape(1, -1))
