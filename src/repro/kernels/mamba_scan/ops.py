"""Jitted public wrapper for the Mamba selective scan."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.mamba_scan.mamba_scan import mamba_scan_pallas
from repro.kernels.mamba_scan.ref import mamba_scan_ref


@partial(jax.jit, static_argnames=("interpret", "impl", "block_d"))
def mamba_scan(u, delta, a, b, c, d, interpret: bool = False,
               impl: str = "pallas", block_d: int = 128):
    if impl == "ref":
        return mamba_scan_ref(u, delta, a, b, c, d)
    return mamba_scan_pallas(u, delta, a, b, c, d, block_d=block_d,
                             interpret=interpret)
