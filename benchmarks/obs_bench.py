"""``obs_overhead``: telemetry-on vs telemetry-off serve latency (ISSUE 9).

The observability layer's acceptance claim is *always-on-cheap*: running
with full telemetry (spans on every tile, per-tenant counters, stage
histograms) must cost at most 5% of serve p99 versus the disabled
fast path. This bench measures that directly on ONE warmed engine by
toggling ``Telemetry.enabled`` between interleaved blocks:

  * **Interleaved blocks, alternating order.** Each cycle runs one
    small OFF block and one small ON block — even cycles off->on, odd
    cycles on->off — so slow drift (thermal, allocator, runner warmup)
    and rare hiccups land on both sides equally in expectation instead
    of systematically penalising whichever side runs second.
  * **Pooled percentiles.** All OFF samples form one distribution, all
    ON samples another; the reported overhead is pooled
    ``p99_on / p99_off``. Per-cycle p50 ratios (median over cycles) are
    recorded alongside as the low-noise per-request check.
  * **Serve-side latency.** Each sample is ``queue_s + service_s`` from
    the engine's own provenance — the exact latency composition
    ``serve_churn`` gates — which excludes the waiter-thread wakeup
    handoff, a pure OS-scheduler noise source that telemetry cannot
    influence. Closed loop (one request in flight), so queueing
    amplification cannot multiply scheduler noise into the tail.
  * **In-bench gate.** The bench asserts pooled p99 ratio <= 1.05; a
    violation raises, which ``benchmarks/run.py --strict`` turns into a
    non-zero CI exit. ``scripts/check_bench.py`` additionally bands the
    recorded ratio against the committed baseline so the gate itself
    cannot be silently loosened.

Writes ``BENCH_obs.json`` via ``benchmarks/run.py obs_overhead``.
"""
from __future__ import annotations

import time

import jax
import numpy as np

import sivf
from benchmarks.common import Row
from repro.obs import Telemetry, latency_summary_ms, percentiles
from sivf import ServeEngine, TenantQuota

DIM = 32
N_LISTS = 32
WINDOW = 4096
K, NPROBE = 10, 8
BATCH = 8                       # fixed query-batch shape: one executable
BLOCK = 5                       # requests per on/off block: blocks must be
                                # much shorter than system noise bursts
                                # (~1-2 s here), or a burst lands on one
                                # side of a pair and swamps the comparison
CYCLES = 120                    # off+on block pairs (order alternates)
WARMUP = 150
OVERHEAD_BOUND = 1.05           # pooled p99_on/p99_off acceptance bound


def _build_engine(rng, tel):
    n_slabs = int(2.5 * WINDOW / 64) + N_LISTS
    cfg = sivf.SIVFConfig(dim=DIM, n_lists=N_LISTS, n_slabs=n_slabs,
                          capacity=64, n_max=1 << 18)
    train = rng.normal(size=(2048, DIM)).astype(np.float32)
    cents = sivf.train_kmeans(jax.random.key(0), train, N_LISTS)
    idx = sivf.Index(cfg, cents, deferred=True, min_bucket=64,
                     telemetry=tel)
    eng = ServeEngine(idx, default_k=K, default_nprobe=NPROBE,
                      max_queue=1024, max_coalesce=64, flush_every=8,
                      quotas={"app": TenantQuota(
                          max_inflight_searches=1024)})
    return idx, eng


def _prefill(eng, rng) -> None:
    writer = eng.session("ingest")
    futs = []
    for base in range(0, WINDOW, 64):
        vecs = rng.normal(size=(64, DIM)).astype(np.float32)
        ids = np.arange(base, base + 64, dtype=np.int32)
        futs.append(writer.add(vecs, ids))
    assert all(f.result(600).ok for f in futs)


def _block(sess, pool, n: int) -> list[float]:
    """Closed-loop: ``n`` sequential BATCH-row searches; per-request
    serve-side seconds (queue wait + tile service, engine-stamped)."""
    lats = []
    for i in range(n):
        res = sess.search(pool[i % len(pool)]).result(600)
        assert res.labels.shape == (BATCH, K)
        lats.append(res.queue_s + res.service_s)
    return lats


def obs_overhead_summary():
    """(rows, summary) for ``BENCH_obs.json`` — see module docstring."""
    rng = np.random.default_rng(7)
    tel = Telemetry(enabled=True)
    idx, eng = _build_engine(rng, tel)
    rows = []
    samples = {"off": [], "on": []}
    p50_ratios = []
    try:
        _prefill(eng, rng)
        sess = eng.session("app")
        pool = [rng.normal(size=(BATCH, DIM)).astype(np.float32)
                for _ in range(32)]
        _block(sess, pool, WARMUP)      # warm executables + both branches
        for i in range(CYCLES):
            order = ("off", "on") if i % 2 == 0 else ("on", "off")
            cycle = {}
            for mode in order:
                tel.enabled = mode == "on"
                cycle[mode] = _block(sess, pool, BLOCK)
                samples[mode] += cycle[mode]
            p50_ratios.append(
                percentiles(cycle["on"], (50.0,))[50.0]
                / max(percentiles(cycle["off"], (50.0,))[50.0], 1e-9))
        tel.enabled = True
        observed, bound = eng.assert_bounded_compiles()
    finally:
        eng.close()
    off = latency_summary_ms(samples["off"])
    on = latency_summary_ms(samples["on"])
    p99_ratio = on["p99_ms"] / max(off["p99_ms"], 1e-9)
    p50_ratio_median = float(np.median(p50_ratios))
    rows.append(Row(
        "obs_overhead.off", off["p50_ms"] / 1e3,
        f"p99={off['p99_ms']}ms over {len(samples['off'])} requests"))
    rows.append(Row(
        "obs_overhead.on", on["p50_ms"] / 1e3,
        f"p99={on['p99_ms']}ms over {len(samples['on'])} requests"))
    rows.append(Row(
        "obs_overhead.verdict", 0.0,
        f"pooled_p99_ratio={p99_ratio:.3f} "
        f"median_p50_ratio={p50_ratio_median:.3f} "
        f"(bound {OVERHEAD_BOUND}x over {CYCLES} interleaved cycles)"))
    assert p99_ratio <= OVERHEAD_BOUND, (
        f"telemetry overhead {p99_ratio:.3f}x exceeds the "
        f"{OVERHEAD_BOUND}x pooled-p99 bound (off={off}, on={on})")
    summary = {
        "dim": DIM, "window": WINDOW, "k": K, "nprobe": NPROBE,
        "batch": BATCH, "block": BLOCK, "cycles": CYCLES,
        "off": off, "on": on,
        "overhead": {
            "p99_ratio_pooled": round(p99_ratio, 4),
            "p50_ratio_median": round(p50_ratio_median, 4),
            "bound": OVERHEAD_BOUND,
        },
        "jit": {"search_executables": observed, "search_bound": bound},
    }
    return rows, summary
