"""``drift_sweep``: recall under distribution drift, maintained vs frozen.

The ISSUE 10 acceptance benchmark. A sliding-window stream draws vectors
from Gaussian clusters whose means random-walk every step, so the
coarse quantizer trained at t=0 goes progressively stale. Because insert
and query routing share the quantizer, staleness does not show up as a
routing error — it shows up as *pileup*: drifted clusters collide onto
the few frozen centroids nearest their new positions, the hot lists hit
the ``max_chain`` bound, and (batch admission being atomic) whole
batches start bouncing. Dropped rows are exactly the rows the client
expects to be searchable, so recall vs the brute-force oracle over the
intended window decays. Two twin indexes consume the *identical*
mutation stream:

  * **maintained** — runs ``Index.maintain`` (the occupancy-driven
    split / merge / recluster policy) after every step, and answers an
    aborted batch with a maintenance pass + retry (the serving recovery
    loop: split the hot list, re-admit);
  * **frozen** — never maintains; its centroids are the t=0 snapshot
    and an aborted batch is simply lost.

Per step we record recall@10 against the exact brute-force top-k over
the live window (the rows the *stream* says are live, not the rows the
index managed to keep). The claim under test: the maintained index
holds recall at the end of the schedule (>= 0.95, asserted in-bench so
``--strict`` CI fails on regression) while the frozen baseline visibly
decays below it — drift is the signal, maintenance is the fix.

Also recorded: search executable counts for both twins (maintenance
must not mint per-epoch executables) and per-step maintenance op
outcomes. Writes ``BENCH_drift.json`` via
``PYTHONPATH=src python -m benchmarks.run drift_sweep``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import sivf
from benchmarks.common import Row

DIM = 16
N_LISTS = 16
N_CLUSTERS = 12
WINDOW = 3072                  # live rows (sliding)
BATCH = 768                    # rows inserted (and evicted) per step
STEPS = 12
Q = 64
K = 10
NPROBE = 4                     # << N_LISTS
SIGMA = 2.0                    # per-step cluster-mean random-walk scale
SPREAD = 0.35                  # intra-cluster noise
MAX_CHAIN = 14                 # 448 rows/list: pileup hits this bound
MAINT_OPS = 6                  # policy budget per step
RETRIES = 4                    # maintain+retry attempts per aborted batch
RECALL_FLOOR = 0.95            # ISSUE acceptance bar (end of schedule)
DECAY_MARGIN = 0.05            # frozen must fall at least this far behind


def _draw(rng, means, n):
    which = rng.integers(0, len(means), size=n)
    return (means[which] + SPREAD * rng.normal(size=(n, DIM))
            ).astype(np.float32)


def _admit(idx, vecs, ids):
    """Add with the serving recovery loop: on an atomic abort, split the
    hottest list into the coldest and retry the identical batch."""
    for _ in range(RETRIES):
        if idx.add(vecs, ids).ok:
            return True
        occ = np.asarray(idx.stats()["list_occupancy"])
        idx.maintain(ops=[sivf.split(int(occ.argmax()), int(occ.argmin()))])
    return bool(idx.add(vecs, ids).ok)


def _recall(idx, qs, live_ids, live_vecs):
    d = ((qs[:, None] - live_vecs[None]) ** 2).sum(-1)
    order = np.argsort(d, axis=1, kind="stable")[:, :K]
    true = live_ids[order]                       # [Q, K] external ids
    pred = np.asarray(idx.search(qs, K, NPROBE).labels)
    hits = sum(len(set(pred[i].tolist()) & set(true[i].tolist()))
               for i in range(len(qs)))
    return hits / (len(qs) * K)


def drift_sweep_summary():
    """-> (rows, summary dict) for ``BENCH_drift.json``."""
    rng = np.random.default_rng(0)
    means = rng.normal(size=(N_CLUSTERS, DIM)).astype(np.float32) * 4.0

    cfg = sivf.SIVFConfig(dim=DIM, n_lists=N_LISTS, n_slabs=256,
                          capacity=32, n_max=1 << 14, max_chain=MAX_CHAIN)
    seed_vecs = _draw(rng, means, WINDOW)
    cents = np.asarray(sivf.train_kmeans(
        jax.random.key(0), jnp.asarray(seed_vecs), N_LISTS))

    # Bootstrap one index to a healthy layout (the seed k-means may glue
    # clusters past the chain bound; _admit splits its way out), then
    # clone the settled state into BOTH twins. The frozen baseline is a
    # *well-built* static index — it lacks only online maintenance.
    boot = sivf.Index(cfg, cents, min_bucket=Q)
    ids = np.arange(WINDOW, dtype=np.int32)
    half = WINDOW // 2
    assert _admit(boot, seed_vecs[:half], ids[:half])
    assert _admit(boot, seed_vecs[half:], ids[half:])
    boot.maintain(max_ops=MAINT_OPS)
    snap = jax.tree.map(np.asarray, boot.state)
    cents0 = np.asarray(snap.centroids)
    maintained = sivf.Index(cfg, cents0, min_bucket=Q,
                            _state=jax.tree.map(jnp.asarray, snap))
    frozen = sivf.Index(cfg, cents0, min_bucket=Q,
                        _state=jax.tree.map(jnp.asarray, snap))

    live: dict[int, np.ndarray] = {}
    live.update(zip(ids.tolist(), seed_vecs))
    next_id = WINDOW

    rows, steps, ops_log = [], [], []
    frozen_lost = 0
    for step in range(1, STEPS + 1):
        means = means + SIGMA * rng.normal(size=means.shape).astype(
            np.float32)
        vecs = _draw(rng, means, BATCH)
        ids = np.arange(next_id, next_id + BATCH, dtype=np.int32)
        next_id += BATCH
        evict = np.asarray(sorted(live)[:BATCH], np.int32)
        for idx in (maintained, frozen):
            idx.remove(evict)
        for i in evict.tolist():
            live.pop(i)
        live.update(zip(ids.tolist(), vecs))

        # frozen: an aborted batch is simply lost (nothing to retry with)
        if not frozen.add(vecs, ids).ok:
            frozen_lost += BATCH
        # maintained: abort -> split the hot list -> retry the identical
        # batch (_admit); plus one policy-planned tracking pass per step
        if not _admit(maintained, vecs, ids):
            raise AssertionError(
                f"maintained index failed admission at step {step} even "
                f"after {RETRIES} split+retry rounds")
        reps = maintained.maintain(max_ops=MAINT_OPS, strict=False)
        ops_log.append([(r.kind, r.committed, r.rows) for r in reps])

        # queries follow the *window* distribution (sampled live rows +
        # noise), not just the newest batch — rows a frozen index dropped
        # stay query targets for as long as the stream says they're live
        live_ids = np.fromiter(live.keys(), np.int32)
        live_vecs = np.stack([live[int(i)] for i in live_ids])
        pick = rng.integers(0, len(live_ids), Q)
        qs = (live_vecs[pick] +
              SPREAD * rng.normal(size=(Q, DIM))).astype(np.float32)
        rm = _recall(maintained, qs, live_ids, live_vecs)
        rf = _recall(frozen, qs, live_ids, live_vecs)
        m_occ = maintained.stats()["list_occupancy"]
        f_occ = frozen.stats()["list_occupancy"]
        steps.append({"step": step, "maintained_recall_at_10": round(rm, 4),
                      "frozen_recall_at_10": round(rf, 4),
                      "maintenance_ops": len(reps),
                      "committed_ops": sum(1 for r in reps if r.committed),
                      "frozen_rows_lost": frozen_lost,
                      "maintained_n_live": int(maintained.stats()["n_live"]),
                      "frozen_n_live": int(frozen.stats()["n_live"]),
                      "maintained_max_occ": int(max(m_occ)),
                      "frozen_max_occ": int(max(f_occ))})
        print(f"# drift step {step}: maintained={rm:.3f} frozen={rf:.3f} "
              f"max_occ m={max(m_occ)} f={max(f_occ)} lost={frozen_lost}",
              flush=True)

    final_m = steps[-1]["maintained_recall_at_10"]
    final_f = steps[-1]["frozen_recall_at_10"]
    decayed = 1.0 if final_f <= final_m - DECAY_MARGIN else 0.0
    rows.append(Row(
        "drift_sweep.final", 0.0,
        f"maintained={final_m:.3f} frozen={final_f:.3f} "
        f"steps={STEPS} nprobe={NPROBE}/{N_LISTS} "
        f"decayed={'YES' if decayed else 'NO'}"))

    # --strict CI: regression in either direction is a hard failure
    if final_m < RECALL_FLOOR:
        raise AssertionError(
            f"maintained recall@10 {final_m:.3f} < {RECALL_FLOOR} at end "
            f"of drift schedule — maintenance stopped tracking drift")
    if not decayed:
        raise AssertionError(
            f"frozen baseline did not decay (frozen={final_f:.3f} vs "
            f"maintained={final_m:.3f}) — the drift schedule lost its "
            f"witness and the benchmark proves nothing")

    summary = {
        "dim": DIM, "n_lists": N_LISTS, "n_clusters": N_CLUSTERS,
        "window": WINDOW, "batch": BATCH, "steps_total": STEPS,
        "k": K, "nprobe": NPROBE, "sigma": SIGMA,
        "maint_ops_per_step": MAINT_OPS,
        "steps": steps,
        "final": {
            "maintained_recall_at_10": final_m,
            "frozen_recall_at_10": final_f,
            "recall_gap": round(final_m - final_f, 4),
            "decayed": decayed,
            "frozen_rows_lost": frozen_lost,
        },
        # counters are shared across the twins (identical cfg) — one
        # number bounds both: maintenance must not mint executables
        "jit": {
            "search_executables": maintained.compile_stats()["search"],
        },
        "maintenance": {
            "total_ops": sum(len(o) for o in ops_log),
            "committed_ops": sum(c for s in steps
                                 for c in [s["committed_ops"]]),
        },
    }
    return rows, summary
