"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes
experiments/bench_results.json. Run: PYTHONPATH=src python -m benchmarks.run
[names ...] [--only fig1a,...] [--skip-dist] [--deferred] [--strict]

``streaming_churn --deferred`` runs the eager AND deferred churn variants
back-to-back and records p50/p99 latencies + jit compile counts to
``BENCH_streaming_churn.json``; ``pq_sweep`` always records its summary
(QPS, recall@10, measured slab temp bytes at Q=16/64/256) to
``BENCH_pq.json``; ``reshard_sweep`` records elastic-reshard wall-clock +
bytes moved for 1->2->4 shards at 100k vectors (PQ on/off, search-parity
asserted) to ``BENCH_reshard.json``; ``filtered_sweep`` records filtered-search QPS +
recall@10 at ~1%/10%/50% predicate selectivity vs the post-filter-then-
widen baseline (plus the jit executable count across filter structures)
to ``BENCH_filter.json``; ``serve_churn`` records the
open-loop mixed-workload SLO sweep (p50/p99/p999 search latency idle vs
under ingest at 3 arrival rates + sustained mutation throughput) to
``BENCH_serve.json`` (plus ``TELEMETRY_serve.json``, the engine's
end-of-run telemetry snapshot, uploaded as a CI artifact);
``tiered_sweep`` records the host-tier/device-
cache sweep (hit rate + QPS at working sets of 0.25x-2x the device slab
budget, bit-parity asserted against the all-resident pool) to
``BENCH_tiered.json``; ``obs_overhead`` records the telemetry-on vs
telemetry-off serve p99 comparison (median paired ratio gated at 1.05x
in-bench) to ``BENCH_obs.json``; ``drift_sweep`` records recall@10 under
a 12-step cluster-drift schedule for a maintained index (online
split/merge/recluster each step) vs a frozen-centroid twin on the
identical stream (maintained >= 0.95 and frozen decay both asserted
in-bench) to ``BENCH_drift.json`` (the slow CI job's perf data points —
``scripts/check_bench.py`` gates them against committed baselines).

Exceptions inside one benchmark print a ``<name>.ERROR`` row and the run
continues, so a multi-artifact sweep survives a single failure;
``--strict`` additionally exits non-zero at the end if *any* artifact
errored (CI uses it so a typo'd registry name or a swallowed exception
can't pass silently).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

from benchmarks import paper, serve_bench

ARTIFACTS = [
    ("fig1a", paper.fig1a_physical_deletion_overhead),
    ("fig1b", paper.fig1b_tombstone_compaction_trap),
    ("fig2", paper.fig2_ingestion_micro),
    ("fig3", paper.fig3_deletion_micro),
    ("fig4_5", paper.fig4_5_parameter_sensitivity),
    ("fig6_7_8", paper.fig6_7_8_real_datasets),
    ("fig9", paper.fig9_recall_pareto),
    ("fused", paper.fused_search_sweep),
    # pq_sweep is dispatched by name in main() (its summary-writing variant
    # records BENCH_pq.json), not through this table
    ("streaming_churn", paper.streaming_churn),
    ("streaming_churn_deferred", paper.streaming_churn_deferred),
    ("fig10", paper.fig10_zipfian_skew),
    ("fig11", paper.fig11_sliding_window),
    ("tab1", paper.tab1_tail_latency),
    ("tab2", paper.tab2_mixed_workload),
    ("tab3", paper.tab3_time_breakdown),
    ("tab4", paper.tab4_non_ivf_indexes),
]


def run_summary_artifact(name: str, fn, bench_path: str, results: dict
                         ) -> None:
    """Run a (rows, summary) benchmark, print rows, record results, and
    write the summary JSON next to the repo root (the slow CI job uploads
    it). Errors are swallowed like the generic loop — CI must check for
    the ``<name>.ERROR`` row / a fresh artifact, not the exit code."""
    t0 = time.time()
    try:
        rows, summary = fn()
        for r in rows:
            print(r.csv(), flush=True)
        results[name] = [
            {"name": r.name, "us": r.us, "derived": r.derived}
            for r in rows]
        bench_out = Path(bench_path)
        bench_out.write_text(json.dumps(summary, indent=1))
        print(f"# wrote {bench_out}")
    except Exception as e:  # keep the harness going
        print(f"{name}.ERROR,0,{type(e).__name__}: {e}", flush=True)
        results[name] = {"error": traceback.format_exc()[-1500:]}
    results.setdefault("_timing", {})[name] = round(time.time() - t0, 1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("names", nargs="*", default=[],
                    help="artifact names to run (same as --only)")
    ap.add_argument("--only", default="")
    ap.add_argument("--skip-dist", action="store_true")
    ap.add_argument("--deferred", action="store_true",
                    help="run streaming_churn in eager+deferred comparison "
                         "mode and write BENCH_streaming_churn.json")
    ap.add_argument("--strict", action="store_true",
                    help="still record every row, but exit non-zero if any "
                         "artifact errored (CI regression safety)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set()
    only |= set(args.names)
    only = only or None

    print("name,us_per_call,derived")
    results = {}
    artifacts = list(ARTIFACTS)
    if args.deferred and (only is None or "streaming_churn" in only):
        artifacts = [(n, f) for n, f in artifacts
                     if n not in ("streaming_churn",
                                  "streaming_churn_deferred")]
        run_summary_artifact("streaming_churn", paper.streaming_churn_compare,
                             "BENCH_streaming_churn.json", results)
    if only is None or "pq_sweep" in only:
        # pq_sweep always runs through its summary variant so the slab-DMA /
        # recall data point lands in BENCH_pq.json next to the churn artifact
        run_summary_artifact("pq_sweep", paper.pq_sweep_summary,
                             "BENCH_pq.json", results)
    if only is None or "reshard_sweep" in only:
        run_summary_artifact("reshard_sweep", paper.reshard_sweep_summary,
                             "BENCH_reshard.json", results)
    if only is None or "filtered_sweep" in only:
        run_summary_artifact("filtered_sweep", paper.filtered_sweep_summary,
                             "BENCH_filter.json", results)
    if only is None or "serve_churn" in only:
        run_summary_artifact("serve_churn", serve_bench.serve_churn_summary,
                             "BENCH_serve.json", results)
    if only is None or "tiered_sweep" in only:
        from benchmarks import tiered_bench
        run_summary_artifact("tiered_sweep",
                             tiered_bench.tiered_sweep_summary,
                             "BENCH_tiered.json", results)
    if only is None or "obs_overhead" in only:
        from benchmarks import obs_bench
        run_summary_artifact("obs_overhead",
                             obs_bench.obs_overhead_summary,
                             "BENCH_obs.json", results)
    if only is None or "drift_sweep" in only:
        from benchmarks import drift_bench
        run_summary_artifact("drift_sweep",
                             drift_bench.drift_sweep_summary,
                             "BENCH_drift.json", results)
    for name, fn in artifacts:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = fn()
            for r in rows:
                print(r.csv(), flush=True)
            results[name] = [
                {"name": r.name, "us": r.us, "derived": r.derived}
                for r in rows]
        except Exception as e:  # keep the harness going
            print(f"{name}.ERROR,0,{type(e).__name__}: {e}", flush=True)
            results[name] = {"error": traceback.format_exc()[-1500:]}
        results.setdefault("_timing", {})[name] = round(time.time() - t0, 1)

    if not args.skip_dist and (only is None or "fig13" in only):
        try:
            from benchmarks import distributed_bench
            scale = distributed_bench.run(dim=64)
            base = scale[0]
            for row in scale:
                s = row["shards"]
                print(f"fig13.scaling@shards={s},0,"
                      f"ingest={row['ingest_vps']:.0f}vps "
                      f"search={row['search_qps']:.0f}qps "
                      f"delete={row['delete_vps']:.0f}vps "
                      f"ingest_speedup={row['ingest_vps'] / base['ingest_vps']:.2f}x",
                      flush=True)
            results["fig13"] = scale
            # fig14: higher-dim (DINO-like) distributed comparison
            scale14 = distributed_bench.run(dim=256)
            for row in scale14:
                print(f"fig14.dino_like@shards={row['shards']},0,"
                      f"ingest={row['ingest_vps']:.0f}vps "
                      f"delete={row['delete_vps']:.0f}vps", flush=True)
            results["fig14"] = scale14
        except Exception as e:
            print(f"fig13.ERROR,0,{type(e).__name__}: {e}", flush=True)
            results["fig13"] = {"error": traceback.format_exc()[-1500:]}

    out = Path("experiments/bench_results.json")
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(results, indent=1))
    print(f"# wrote {out}")
    errored = sorted(name for name, v in results.items()
                     if isinstance(v, dict) and "error" in v)
    if errored:
        print(f"# errored artifacts: {','.join(errored)}")
        if args.strict:
            sys.exit(1)


if __name__ == "__main__":
    main()
