"""Benchmark utilities: timing, scaled datasets, index builders.

Scaling note (DESIGN.md §8): this container is a single CPU core, so the
paper's 1M-10B vector datasets are reproduced at 10^4-10^5 scale with the
same methodology; we report absolute numbers for this platform plus the
RATIOS vs baselines, which are the paper's claims (O(1) vs O(N), constant
vs linear scaling, recall parity).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.data.pipeline import VectorStream, VectorStreamConfig


def timeit(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall seconds over ``iters`` runs (jit warm)."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), r


def dataset(dim: int, n: int, seed: int = 0, zipf: float = 0.0,
            n_clusters: int = 64):
    """SIFT/GIST-like Gaussian-mixture vectors."""
    vs = VectorStream(VectorStreamConfig(seed=seed, dim=dim,
                                         n_clusters=n_clusters, zipf_a=zipf))
    return vs.batch(0, n)


def build_sivf(dim: int, n_lists: int, n_max: int, capacity: int = 64,
               slab_factor: float = 1.5, max_chain: int | None = None,
               metric: str = "l2", train_vecs=None, seed: int = 0):
    n_slabs = int(slab_factor * n_max / capacity) + n_lists
    if max_chain is None:
        max_chain = n_slabs            # bounded only by the pool itself
    cfg = core.SIVFConfig(dim=dim, n_lists=n_lists, n_slabs=n_slabs,
                          capacity=capacity, n_max=max(n_max * 2, 1024),
                          metric=metric, max_chain=max_chain)
    if train_vecs is None:
        train_vecs = dataset(dim, max(16 * n_lists, 2048), seed=seed + 7)
    cents = core.train_kmeans(jax.random.key(seed), jnp.asarray(train_vecs),
                              n_lists)
    return cfg, core.init_state(cfg, cents), np.asarray(cents)


def recall_at_k(pred_labels: np.ndarray, true_labels: np.ndarray) -> float:
    k = true_labels.shape[1]
    hits = [len(set(pred_labels[i].tolist())
                & set(true_labels[i].tolist()))
            for i in range(len(pred_labels))]
    return float(np.mean(hits) / k)


def exact_topk(vecs: np.ndarray, qs: np.ndarray, k: int) -> np.ndarray:
    from repro.utils import l2_sq
    d = np.asarray(l2_sq(jnp.asarray(qs), jnp.asarray(vecs)))
    return np.argsort(d, axis=1, kind="stable")[:, :k]


class Row:
    """One CSV row: name, us_per_call, derived metric string."""

    def __init__(self, name: str, seconds: float, derived: str = ""):
        self.name = name
        self.us = seconds * 1e6
        self.derived = derived

    def csv(self) -> str:
        return f"{self.name},{self.us:.1f},{self.derived}"
