"""``serve_churn``: open-loop mixed workload through the serve engine.

The repo's headline number (ISSUE 6): sustained search QPS *during*
ingest at a p99 SLO. Methodology follows the scale-point/SLO-percentile
scheme of the parquet-aggregator benchmark plan (SNIPPETS.md §2):

  * **Open loop.** Search arrivals are scheduled on a fixed-rate clock
    that never waits for completions, so queueing delay is *measured*
    rather than hidden (no coordinated omission). Per-request latency =
    (submit lag behind schedule) + queue wait + service time.
  * **Scale points.** Each arrival rate runs twice — ``idle`` (no
    mutations) then ``active`` (a second tenant streams paced add/remove
    batches through the deferred pipeline) — and records p50/p99/p999
    search latency plus the sustained mutation row throughput.
  * **SLO gate.** The bench itself asserts p99(active) <= 5x p99(idle)
    at every scale point (the paper's search-during-ingest claim) and
    that jit executable counts stay within the engine's coalescing
    bound. A violation raises, which ``benchmarks/run.py --strict``
    turns into a non-zero exit for CI.

Writes ``BENCH_serve.json`` via ``benchmarks/run.py serve_churn``, plus
``TELEMETRY_serve.json`` — the engine's end-of-run JSON telemetry
snapshot (per-stage histograms, per-tenant counters, slow-query log) —
which the slow CI job uploads as an artifact.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import jax
import numpy as np

import sivf
from benchmarks.common import Row
from repro.obs import Telemetry, latency_summary_ms
from sivf import Backpressure, ServeEngine, TenantQuota

DIM = 32
N_LISTS = 32
WINDOW = 16_384
K, NPROBE = 10, 8
MUT_BATCH = 64                  # rows per add (and per remove) batch
MUT_ROWS_PER_S = 1_500          # paced ingest pressure in the active phase
RATES = (50, 100, 200)          # open-loop search arrival rates (QPS)
PHASE_SECONDS = 4.0
SLO_RATIO = 5.0                 # p99 active/idle acceptance bound


def _build_engine(rng):
    n_slabs = int(2.5 * WINDOW / 64) + N_LISTS
    cfg = sivf.SIVFConfig(dim=DIM, n_lists=N_LISTS, n_slabs=n_slabs,
                          capacity=64, n_max=1 << 20)
    train = rng.normal(size=(4096, DIM)).astype(np.float32)
    cents = sivf.train_kmeans(jax.random.key(0), train, N_LISTS)
    # Telemetry stays ON for the whole measured run: the snapshot written
    # at the end (TELEMETRY_serve.json) is a CI artifact, and the bench
    # thereby exercises the <=5% overhead claim under the real SLO gate.
    tel = Telemetry(enabled=True, slow_threshold_s=0.050)
    idx = sivf.Index(cfg, cents, deferred=True, min_bucket=64,
                     telemetry=tel)
    eng = ServeEngine(
        idx, default_k=K, default_nprobe=NPROBE, max_queue=4096,
        max_coalesce=128, flush_every=8,
        quotas={"app": TenantQuota(max_inflight_searches=1024),
                "ingest": TenantQuota()})
    return idx, eng


def _prefill(eng, rng) -> int:
    """Fill the index to its steady-state window; returns next free id."""
    writer = eng.session("ingest")
    futs = []
    for base in range(0, WINDOW, MUT_BATCH):
        vecs = rng.normal(size=(MUT_BATCH, DIM)).astype(np.float32)
        ids = np.arange(base, base + MUT_BATCH, dtype=np.int32)
        futs.append(writer.add(vecs, ids))
    assert all(f.result(600).ok for f in futs)
    return WINDOW


def _warm_executables(eng, rng) -> None:
    """Compile every pow2 search tile (1..max_coalesce) and the mutation
    buckets before measurement, so scale points compare steady-state
    latency, not compile storms."""
    reader = eng.session("warmup")
    sizes = []
    b = 1
    while b <= 128:
        sizes.append(b)
        b *= 2
    futs = [reader.search(
        rng.normal(size=(s, DIM)).astype(np.float32), k=K, nprobe=NPROBE)
        for s in sizes]
    for f in futs:
        f.result(600)


class _IngestLoad:
    """Paced add/remove streamer: ``MUT_ROWS_PER_S`` rows/s in
    ``MUT_BATCH``-row batches, evicting behind a sliding window."""

    def __init__(self, eng, rng, next_id: int):
        self._sess = eng.session("ingest")
        self._rng = rng
        self.next_id = next_id
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._futs: list = []
        self.elapsed = 0.0

    def start(self) -> None:
        self._stop.clear()
        self._futs = []
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        interval = 2 * MUT_BATCH / MUT_ROWS_PER_S   # add+remove per cycle
        t0 = time.perf_counter()
        cycle = 0
        while not self._stop.is_set():
            sched = t0 + cycle * interval
            now = time.perf_counter()
            if now < sched:
                time.sleep(sched - now)
            vecs = self._rng.normal(size=(MUT_BATCH, DIM)
                                    ).astype(np.float32)
            ids = np.arange(self.next_id, self.next_id + MUT_BATCH,
                            dtype=np.int32)
            evict = ids - WINDOW
            try:
                self._futs.append(self._sess.add(vecs, ids))
                self._futs.append(self._sess.remove(evict))
            except Backpressure:               # shed, keep pacing
                pass
            else:
                self.next_id += MUT_BATCH
            cycle += 1
        self.elapsed = time.perf_counter() - t0

    def stop(self) -> dict:
        self._stop.set()
        self._thread.join()
        add_rows = rm_rows = 0
        for f in self._futs:
            res = f.result(600)
            assert res.ok, res.report
            if res.report.op == "add":
                add_rows += res.report.accepted + res.report.overwritten
            else:
                rm_rows += res.report.accepted
        dt = max(self.elapsed, 1e-9)
        return {"add_rows_per_s": round(add_rows / dt, 1),
                "remove_rows_per_s": round(rm_rows / dt, 1),
                "batches": len(self._futs)}


def _open_loop_searches(eng, rng, rate: float, seconds: float) -> dict:
    """Fixed-rate open-loop search phase; latency includes schedule lag +
    queue wait + service, per request."""
    reader = eng.session("app")
    n = int(rate * seconds)
    pool = [rng.normal(size=(int(rng.integers(1, 5)), DIM)
                       ).astype(np.float32) for _ in range(64)]
    records: list = []
    rejected = 0
    t0 = time.perf_counter()
    for i in range(n):
        sched = t0 + i / rate
        now = time.perf_counter()
        if now < sched:
            time.sleep(sched - now)
            now = sched
        try:
            fut = reader.search(pool[i % len(pool)])
        except Backpressure:
            rejected += 1
            continue
        records.append((now - sched, fut))
    lats = []
    for lag, fut in records:
        res = fut.result(600)
        lats.append(lag + res.queue_s + res.service_s)
    wall = time.perf_counter() - t0
    out = {"requests": n, "rejected": rejected,
           "achieved_qps": round(len(lats) / wall, 1)}
    out.update(latency_summary_ms(lats))        # shared obs percentile math
    return out


def serve_churn_summary():
    """(rows, summary) for ``BENCH_serve.json`` — see module docstring."""
    rng = np.random.default_rng(11)
    idx, eng = _build_engine(rng)
    rows, scale_points = [], []
    try:
        next_id = _prefill(eng, rng)
        _warm_executables(eng, rng)
        for rate in RATES:
            idle = _open_loop_searches(eng, rng, rate, PHASE_SECONDS)
            load = _IngestLoad(eng, rng, next_id)
            load.start()
            active = _open_loop_searches(eng, rng, rate, PHASE_SECONDS)
            active.update(load.stop())
            next_id = load.next_id
            ratio = round(active["p99_ms"] / max(idle["p99_ms"], 1e-9), 2)
            scale_points.append({"rate_qps": rate, "idle": idle,
                                 "active": active,
                                 "p99_active_over_idle": ratio})
            for phase, d in (("idle", idle), ("active", active)):
                rows.append(Row(
                    f"serve_churn.{phase}@{rate}qps", d["p50_ms"] / 1e3,
                    f"p99={d['p99_ms']}ms p999={d['p999_ms']}ms "
                    f"qps={d['achieved_qps']}"))
            rows.append(Row(
                f"serve_churn.slo@{rate}qps", 0.0,
                f"p99_active/idle={ratio}x "
                f"add={active['add_rows_per_s']}rows/s "
                f"remove={active['remove_rows_per_s']}rows/s"))
        observed, bound = eng.assert_bounded_compiles()
        worst = max(sp["p99_active_over_idle"] for sp in scale_points)
        assert worst <= SLO_RATIO, (
            f"p99 under ingest {worst}x idle exceeds the {SLO_RATIO}x SLO "
            f"bound: {scale_points}")
        stats = eng.stats()
        snap = eng.telemetry()            # full JSON snapshot, CI artifact
    finally:
        eng.close()
    Path("TELEMETRY_serve.json").write_text(json.dumps(snap, indent=1))
    print("# wrote TELEMETRY_serve.json")
    comp = idx.compile_stats()
    rows.append(Row(
        "serve_churn.jit_executables", 0.0,
        f"search={observed} (bound {bound}) add={comp['add']} "
        f"remove={comp['remove']} coalesce_mean={stats['coalesce_mean']}"))
    summary = {
        "dim": DIM, "window": WINDOW, "k": K, "nprobe": NPROBE,
        "phase_seconds": PHASE_SECONDS,
        "mutation_rows_per_s_offered": MUT_ROWS_PER_S,
        "scale_points": scale_points,
        "max_p99_active_over_idle": worst,
        "slo_ratio_bound": SLO_RATIO,
        "coalesce_mean": stats["coalesce_mean"],
        "coalesce_max": stats["coalesce_max"],
        "jit": {"search_executables": observed, "search_bound": bound,
                "add": comp["add"], "remove": comp["remove"]},
    }
    return rows, summary
