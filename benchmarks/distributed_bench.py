"""Figs 13-14: multi-shard scaling (scatter-gather over fake devices).

Runs in a SUBPROCESS because the device count must be fixed before jax
initializes (the main benchmark process keeps 1 device).
"""
from __future__ import annotations

import json
import subprocess
import sys

_SCRIPT = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
sys.path.insert(0, "src")
from repro import core
from repro.core import distributed as dist
from repro.data.pipeline import VectorStream, VectorStreamConfig

D, NL, N, B = int(sys.argv[1]), 16, 6000, 1000
out = []
vs = VectorStream(VectorStreamConfig(seed=0, dim=D, n_clusters=NL))
train = vs.batch(0, 1024)
for shards in (1, 2, 4, 8):
    cfg = core.SIVFConfig(dim=D, n_lists=NL, n_slabs=2 * N // 32 + NL,
                          capacity=32, n_max=4 * N, max_chain=64)
    cents = core.train_kmeans(jax.random.key(0), jnp.asarray(train), NL)
    mesh = jax.make_mesh((shards,), ("data",),
                         devices=np.array(jax.devices()[:shards]))
    state = dist.init_sharded_state(cfg, cents, mesh)
    vecs = vs.batch(1, N)
    ids = np.arange(N, dtype=np.int32)
    # warm + ingest
    state = dist.dist_insert(cfg, mesh, state, jnp.asarray(vecs[:B]),
                             jnp.asarray(ids[:B]))
    t0 = time.perf_counter()
    for lo in range(B, N, B):
        state = dist.dist_insert(cfg, mesh, state,
                                 jnp.asarray(vecs[lo:lo + B]),
                                 jnp.asarray(ids[lo:lo + B]))
    jax.block_until_ready(state.n_live)
    t_ins = time.perf_counter() - t0

    qs = jnp.asarray(vs.batch(2, 64))
    d, l = dist.dist_search(cfg, mesh, state, qs, 10, 8)   # warm
    t0 = time.perf_counter()
    for _ in range(3):
        d, l = dist.dist_search(cfg, mesh, state, qs, 10, 8)
    jax.block_until_ready(d)
    t_q = (time.perf_counter() - t0) / 3

    t0 = time.perf_counter()
    state = dist.dist_delete(cfg, mesh, state, jnp.asarray(ids[:B]))
    jax.block_until_ready(state.n_live)
    t_del = time.perf_counter() - t0
    out.append({"shards": shards, "ingest_vps": (N - B) / t_ins,
                "search_qps": 64 / t_q, "delete_vps": B / t_del})
print(json.dumps(out))
"""


def run(dim: int = 64) -> list[dict]:
    r = subprocess.run([sys.executable, "-c", _SCRIPT, str(dim)],
                       capture_output=True, text=True, timeout=560,
                       cwd="/root/repo")
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    return json.loads(r.stdout.strip().splitlines()[-1])
