"""One benchmark per paper table/figure (§5). Each function returns Rows.

Scaled to this CPU container (see common.py docstring); the paper's claims
are reproduced as RATIOS and scaling SHAPES, with absolute numbers for
this platform recorded alongside.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import sivf
from benchmarks.common import (Row, build_sivf, dataset, exact_topk,
                               recall_at_k, timeit)
from repro import core
from repro.baselines import ContiguousIVF, FlatIndex, HNSWLite, LSHIndex
from repro.obs import percentiles

D, NL, N = 64, 32, 20_000
BATCH = 1_000


def _sivf_loaded(dim=D, n=N, n_lists=NL, **kw):
    cfg, state, cents = build_sivf(dim, n_lists, n, **kw)
    vecs = dataset(dim, n)
    ids = np.arange(n, dtype=np.int32)
    for lo in range(0, n, 4096):
        state = core.insert(cfg, state, jnp.asarray(vecs[lo:lo + 4096]),
                            jnp.asarray(ids[lo:lo + 4096]))
    assert int(state.error) == 0
    return cfg, state, cents, vecs, ids


def fig1a_physical_deletion_overhead():
    """Fig 1(a): insert vs delete latency asymmetry across index types."""
    rows = []
    vecs = dataset(D, N)
    ids = np.arange(N, dtype=np.int32)
    newv = dataset(D, BATCH, seed=9)
    new_ids = np.arange(N, N + BATCH).astype(np.int32)

    cfg, state, cents, _, _ = _sivf_loaded()
    # warm the jitted mutation kernels outside the timed region
    wid = np.arange(N + BATCH, N + 2 * BATCH).astype(np.int32)
    state = core.insert(cfg, state, jnp.asarray(dataset(D, BATCH, seed=8)),
                        jnp.asarray(wid))
    state = core.delete(cfg, state, jnp.asarray(wid))
    t_ins, state = timeit(core.insert, cfg, state, jnp.asarray(newv),
                          jnp.asarray(new_ids), warmup=0, iters=1)
    t_del, state = timeit(core.delete, cfg, state,
                          jnp.asarray(ids[:BATCH]), warmup=0, iters=1)
    rows.append(Row("fig1a.sivf.insert_1k", t_ins,
                    f"{BATCH / t_ins:.0f} vec/s"))
    rows.append(Row("fig1a.sivf.delete_1k", t_del,
                    f"{BATCH / t_del:.0f} vec/s"))

    civf = ContiguousIVF(cents, list_cap=2 * N // NL)
    civf.insert(vecs, ids)
    t_ins, _ = timeit(lambda: civf.insert(newv, new_ids), warmup=0, iters=1)
    t_del, _ = timeit(lambda: civf.delete(ids[:BATCH]), warmup=0, iters=1)
    rows.append(Row("fig1a.contiguous_ivf.insert_1k", t_ins,
                    f"{BATCH / t_ins:.0f} vec/s"))
    rows.append(Row("fig1a.contiguous_ivf.delete_1k", t_del,
                    f"{BATCH / t_del:.0f} vec/s"))

    flat = FlatIndex(D, 2 * N)
    flat.insert(vecs, ids)
    flat.delete(ids[2 * BATCH:3 * BATCH])          # warm
    t_del, _ = timeit(lambda: flat.delete(ids[BATCH:2 * BATCH]),
                      warmup=0, iters=1)
    rows.append(Row("fig1a.flat.delete_1k", t_del,
                    f"{BATCH / t_del:.0f} vec/s"))
    return rows


def fig1b_tombstone_compaction_trap():
    """Fig 1(b): compaction pause scales O(N); SIVF eviction is O(1)."""
    rows = []
    for n in (5_000, 10_000, 20_000, 40_000):
        vecs = dataset(D, n)
        ids = np.arange(n, dtype=np.int32)
        cfg, state, cents = build_sivf(D, NL, n)
        for lo in range(0, n, 4096):
            state = core.insert(cfg, state, jnp.asarray(vecs[lo:lo + 4096]),
                                jnp.asarray(ids[lo:lo + 4096]))
        state = core.delete(cfg, state,
                            jnp.asarray(ids[BATCH:2 * BATCH]))    # warm
        t_del, _ = timeit(core.delete, cfg, state,
                          jnp.asarray(ids[:BATCH]), warmup=0, iters=1)
        # compaction analogue: contiguous re-layout of the whole index
        civf = ContiguousIVF(cents, list_cap=2 * n // NL)
        civf.insert(vecs, ids)
        t_cmp, _ = timeit(lambda: civf.delete(ids[:BATCH]), warmup=0,
                          iters=1)
        rows.append(Row(f"fig1b.sivf.delete@N={n}", t_del, "O(1) expected"))
        rows.append(Row(f"fig1b.compaction@N={n}", t_cmp, "O(N) expected"))
    return rows


def fig2_ingestion_micro():
    """Fig 2: ingestion throughput vs database size and n_list."""
    rows = []
    for n in (10_000, 20_000, 40_000):                    # fig 2a
        cfg, state, cents = build_sivf(D, NL, n + BATCH)
        vecs = dataset(D, n)
        for lo in range(0, n, 4096):
            chunk = vecs[lo:lo + 4096]
            state = core.insert(cfg, state, jnp.asarray(chunk),
                                jnp.asarray(np.arange(lo, lo + len(chunk)),
                                            jnp.int32))
        # warm the BATCH-shaped insert before timing
        state = core.insert(cfg, state, jnp.asarray(dataset(D, BATCH,
                                                            seed=2)),
                            jnp.asarray(np.arange(BATCH), jnp.int32))
        newv = jnp.asarray(dataset(D, BATCH, seed=3))
        nid = jnp.asarray(np.arange(n, n + BATCH), jnp.int32)
        t, state = timeit(core.insert, cfg, state, newv, nid, warmup=0,
                          iters=1)
        rows.append(Row(f"fig2a.sivf.ingest@N={n}", t,
                        f"{BATCH / t:.0f} vec/s"))
    for nl in (8, 32, 128):                               # fig 2b
        cfg, state, cents = build_sivf(D, nl, N)
        state = core.insert(cfg, state, jnp.asarray(dataset(D, BATCH,
                                                            seed=44)),
                            jnp.asarray(np.arange(BATCH, 2 * BATCH),
                                        jnp.int32))                 # warm
        newv = jnp.asarray(dataset(D, BATCH, seed=4))
        nid = jnp.asarray(np.arange(BATCH), jnp.int32)
        t, state = timeit(core.insert, cfg, state, newv, nid,
                          warmup=0, iters=1)
        rows.append(Row(f"fig2b.sivf.ingest@nlist={nl}", t,
                        f"{BATCH / t:.0f} vec/s"))
        # paper scenario: the contiguous baseline must GROW (2x re-layout)
        cents2 = np.asarray(core.train_kmeans(
            jax.random.key(0), jnp.asarray(dataset(D, 2048)), nl))
        civf = ContiguousIVF(cents2, list_cap=max(BATCH // (2 * nl), 4))
        civf.insert(np.asarray(newv), np.asarray(nid))        # warm jits
        civf2 = ContiguousIVF(cents2, list_cap=max(BATCH // (2 * nl), 4))
        t2, _ = timeit(lambda: civf2.insert(np.asarray(newv),
                                            np.asarray(nid)),
                       warmup=0, iters=1)
        rows.append(Row(f"fig2c.contiguous.ingest@nlist={nl}", t2,
                        f"sivf_speedup={t2 / t:.2f}x "
                        f"(relayouts={civf2.n_relayouts})"))
    return rows


def fig3_deletion_micro():
    """Fig 3: delete a batch from a populated index, vs baseline."""
    cfg, state, cents, vecs, ids = _sivf_loaded()
    state = core.delete(cfg, state, jnp.asarray(
        np.arange(N - BATCH, N).astype(np.int32)))   # warm
    t, state2 = timeit(core.delete, cfg, state, jnp.asarray(ids[:BATCH]),
                       warmup=0, iters=1)
    civf = ContiguousIVF(cents, list_cap=2 * N // NL)
    civf.insert(vecs, ids)
    t2, _ = timeit(lambda: civf.delete(ids[:BATCH]), warmup=0, iters=1)
    return [
        Row("fig3.sivf.delete_batch", t, f"{BATCH / t:.0f} vec/s"),
        Row("fig3.contiguous.delete_batch", t2,
            f"speedup={t2 / t:.1f}x (paper: 298x at 1M scale)"),
    ]


def fig4_5_parameter_sensitivity():
    """Figs 4-5: sweep pool headroom (slab_factor ~ mv/sl) and batch."""
    rows = []
    for sl in (1.1, 1.5, 2.0):
        cfg, state, cents = build_sivf(D, NL, N, slab_factor=sl)
        state = core.insert(cfg, state, jnp.asarray(dataset(D, BATCH,
                                                            seed=55)),
                            jnp.asarray(np.arange(BATCH, 2 * BATCH),
                                        jnp.int32))                 # warm
        newv = jnp.asarray(dataset(D, BATCH, seed=5))
        nid = jnp.asarray(np.arange(BATCH), jnp.int32)
        t, state = timeit(core.insert, cfg, state, newv, nid, warmup=0,
                          iters=1)
        rows.append(Row(f"fig4.insert@slab_factor={sl}", t,
                        f"{BATCH / t:.0f} vec/s"))
    for b in (250, 1000, 4000):
        cfg, state, cents, _, ids = _sivf_loaded()
        state = core.delete(cfg, state, jnp.asarray(ids[N - b:]))   # warm
        t, _ = timeit(core.delete, cfg, state, jnp.asarray(ids[:b]),
                      warmup=0, iters=1)
        rows.append(Row(f"fig5.delete@batch={b}", t, f"{b / t:.0f} vec/s"))
    return rows


def fig6_7_8_real_datasets():
    """Figs 6-8: per-dataset ingest/delete/search (deep/sift/t2i/gist-like
    dims)."""
    rows = []
    for name, dim, metric in [("deep", 96, "l2"), ("sift", 128, "l2"),
                              ("t2i", 200, "ip"), ("gist", 960, "l2")]:
        n = 8_000
        cfg, state, cents = build_sivf(dim, NL, n, metric=metric)
        vecs = dataset(dim, n, seed=11)
        ids = np.arange(n, dtype=np.int32)
        t_ing = 0.0
        for lo in range(0, n, 2000):
            t, state = timeit(core.insert, cfg, state,
                              jnp.asarray(vecs[lo:lo + 2000]),
                              jnp.asarray(ids[lo:lo + 2000]),
                              warmup=0, iters=1)
            t_ing += t
        rows.append(Row(f"fig6.{name}.ingest", t_ing,
                        f"{n / t_ing:.0f} vec/s"))
        t_del, state = timeit(core.delete, cfg, state,
                              jnp.asarray(ids[:BATCH]), warmup=0, iters=1)
        rows.append(Row(f"fig7.{name}.delete_1k", t_del,
                        f"{BATCH / t_del:.0f} vec/s"))
        qs = dataset(dim, 64, seed=12)
        t_q, _ = timeit(core.search, cfg, state, jnp.asarray(qs), 10, 8,
                        warmup=1, iters=3)
        rows.append(Row(f"fig8.{name}.search_qps", t_q,
                        f"{64 / t_q:.0f} qps"))
    return rows


def fig9_recall_pareto():
    """Fig 9: QPS vs Recall@10 sweeping nprobe; recall parity at full
    probe."""
    rows = []
    cfg, state, cents, vecs, ids = _sivf_loaded(n=10_000)
    live = np.ones(10_000, bool)
    qs = dataset(D, 64, seed=13)
    true = exact_topk(vecs, qs, 10)
    for nprobe in (1, 4, 8, 16, NL):
        t, (d, lab) = timeit(core.search, cfg, state, jnp.asarray(qs), 10,
                           nprobe, warmup=1, iters=3)
        rec = recall_at_k(np.asarray(lab), true)
        rows.append(Row(f"fig9.sivf@nprobe={nprobe}", t,
                        f"recall@10={rec:.3f} qps={64 / t:.0f}"))
    assert "recall@10=1.000" in rows[-1].derived, "full-probe parity"
    return rows


def fig10_zipfian_skew():
    """Fig 10: ingestion under Zipf-skewed cluster popularity."""
    rows = []
    for name, zipf in [("uniform", 0.0), ("zipf1.2", 1.2)]:
        cfg, state, cents = build_sivf(D, NL, N, slab_factor=2.0)
        vecs = dataset(D, N, seed=17, zipf=zipf)
        ids = np.arange(N, dtype=np.int32)
        t_tot, n_timed = 0.0, 0
        for lo in range(0, N, 4096):
            t, state = timeit(core.insert, cfg, state,
                              jnp.asarray(vecs[lo:lo + 4096]),
                              jnp.asarray(ids[lo:lo + 4096]), warmup=0,
                              iters=1)
            if lo > 0:                      # first chunk pays jit compile
                t_tot += t
                n_timed += min(4096, N - lo)
        assert int(state.error) == 0
        rows.append(Row(f"fig10.sivf.ingest.{name}", t_tot,
                        f"{n_timed / t_tot:.0f} vec/s"))
    return rows


def fig11_sliding_window():
    """Fig 11: end-to-end sliding window — per-step latency, SIVF in-place
    vs contiguous rebuild."""
    w, b = 8_000, 1_000
    cfg, state, cents = build_sivf(D, NL, w + 2 * b)
    vecs = dataset(D, w)
    state = core.insert(cfg, state, jnp.asarray(vecs[:4096]),
                        jnp.asarray(np.arange(4096), jnp.int32))
    state = core.insert(cfg, state, jnp.asarray(vecs[4096:]),
                        jnp.asarray(np.arange(4096, w), jnp.int32))
    next_id = w
    ts = []
    for step in range(6):
        newv = jnp.asarray(dataset(D, b, seed=100 + step))
        nid = jnp.asarray(np.arange(next_id, next_id + b), jnp.int32)
        evict = jnp.asarray(np.arange(next_id - w, next_id - w + b),
                            jnp.int32)
        t0 = time.perf_counter()
        state = core.insert(cfg, state, newv, nid)
        state = core.delete(cfg, state, evict)
        jax.block_until_ready(state.n_live)
        ts.append(time.perf_counter() - t0)
        next_id += b
    sivf_step = float(np.median(ts[2:]))   # exclude compile steps

    civf = ContiguousIVF(cents, list_cap=2 * w // NL)
    civf.insert(vecs, np.arange(w, dtype=np.int32))
    t0 = time.perf_counter()
    civf.insert(np.asarray(dataset(D, b, seed=200)),
                np.arange(next_id, next_id + b).astype(np.int32))
    civf.delete(np.arange(b, dtype=np.int32))
    jax.block_until_ready(civf.counts)
    base_step = time.perf_counter() - t0
    return [
        Row("fig11.sivf.window_step", sivf_step,
            f"{(w := base_step / sivf_step):.1f}x faster than rebuild"),
        Row("fig11.contiguous.window_step", base_step, ""),
    ]


def fused_search_sweep():
    """Beyond-paper sweep: fused scan->top-k pipeline vs the unfused
    two-stage pipeline (materialize [Q, T*C] candidates, then select).

    Columns: QPS (median wall) and peak temp bytes from XLA's
    ``memory_analysis`` — the unfused path's temp grows with Q*T*C while
    the fused path only ever holds the [Q, k] running result, which is the
    paper's Alg. 3 register-top-k claim in memory terms.
    """
    from repro.kernels.sivf_scan import ops as scan_ops

    rows = []
    k, nprobe = 10, 8
    cfg, state, cents, vecs, ids = _sivf_loaded(n=8_000, max_chain=64)
    t_cols = nprobe * cfg.max_chain

    def unfused(qs, table):
        # the ops-level unfused baseline: full [Q, T*C] scan, then select
        return scan_ops.sivf_fused_search(
            qs, table, state.data, state.ids, state.norms, state.bitmap, k,
            metric=cfg.metric, impl="ref")

    def fused(qs, table):
        return core.scan_slabs_topk(cfg, state, qs, table, k)

    peaks = {}
    for qn in (16, 64, 256):
        qs = jnp.asarray(dataset(D, qn, seed=77))
        lists = core.probe(state.centroids, qs, nprobe)
        table = core.gather_tables(cfg, state, lists)
        cand_mb = qn * t_cols * cfg.capacity * 8 / 2 ** 20   # f32 + i32
        for name, fn in (("unfused", unfused), ("fused", fused)):
            # AOT-compile once: the executable serves both the timing loop
            # and the peak-memory column
            compiled = jax.jit(fn).lower(qs, table).compile()
            t, _ = timeit(compiled, qs, table, warmup=1, iters=3)
            mem = compiled.memory_analysis()
            peak = int(getattr(mem, "temp_size_in_bytes", 0) or 0)
            peaks[(name, qn)] = peak
            rows.append(Row(f"fused_sweep.{name}@Q={qn}", t,
                            f"qps={qn / t:.0f} temp_mb={peak / 2 ** 20:.2f} "
                            f"candidate_matrix_mb={cand_mb:.2f}"))
    for qn in (64, 256):
        if peaks[("unfused", qn)] == 0:
            rows.append(Row(f"fused_sweep.memcheck@Q={qn}", 0.0,
                            "memory_analysis unavailable; peak check skipped"))
            continue
        assert peaks[("fused", qn)] < peaks[("unfused", qn)], \
            f"fused path must allocate less temp than unfused at Q={qn}"

    # the actual fused Pallas kernel, interpreter-emulated (parity witness;
    # wall time reflects the interpreter, not TPU performance)
    qn, np_small = 8, 2
    qs = jnp.asarray(dataset(D, qn, seed=78))
    lists = core.probe(state.centroids, qs, np_small)
    table = core.gather_tables(cfg, state, lists)
    t, (dp, lp) = timeit(core.search, cfg, state, qs, k, np_small,
                         impl="pallas_interpret", warmup=0, iters=1)
    dr, lr = core.search(cfg, state, qs, k, np_small, impl="xla")
    assert np.allclose(np.asarray(dp), np.asarray(dr), rtol=1e-5,
                       atol=1e-5), "fused kernel parity"
    assert (np.asarray(lp) == np.asarray(lr)).all(), "fused label parity"
    rows.append(Row(f"fused_sweep.pallas_interpret@Q={qn}", t,
                    "parity=ok (interpreter wall time; not TPU perf)"))
    return rows


def pq_sweep_summary():
    """PQ-compressed search vs uncompressed: (rows, summary) for run.py's
    ``BENCH_pq.json`` artifact.

    For Q in {16, 64, 256}: QPS and the peak temp bytes XLA's
    ``memory_analysis`` reports for the scan — the uncompressed scan
    gathers a ``[Q, C, D]`` fp32 slab tile per table column while the ADC
    scan gathers ``[Q, C, m]`` uint8 codes against a loop-invariant
    ``[Q, m, ksub]`` table, which is where the >=4x slab-DMA cut comes
    from. Also records recall@10 of ADC vs exact fp32 search on the same
    clustered data, and an interpreter-mode parity witness for the fused
    PQ Pallas kernel. Run via ``benchmarks/run.py pq_sweep``.
    """
    return _pq_sweep_impl()


def _pq_sweep_impl():
    import sivf
    from repro.core import pq as pqmod
    from repro.kernels.sivf_scan.pq_fused import sivf_pq_fused_search_pallas

    rows = []
    dim, k, nprobe = 128, 10, 8
    m, nbits = 8, 6          # 8 B/vector; nbits=6 keeps the ADC table small
    # planted neighbor groups (recall@10 is well-defined: each query's true
    # top-10 is its group) — same construction as tests/test_pq.py's oracle
    grng = np.random.default_rng(31)
    gcent = grng.normal(size=(800, dim)).astype(np.float32) * 2.0
    vecs = (np.repeat(gcent, 10, axis=0)
            + 0.4 * grng.normal(size=(8_000, dim))).astype(np.float32)
    n = len(vecs)
    ids = np.arange(n, dtype=np.int32)
    qvecs = (gcent[grng.integers(0, 800, size=64)]
             + 0.4 * grng.normal(size=(64, dim))).astype(np.float32)

    def build(pq_cfg):
        import dataclasses
        cfg, state, cents = build_sivf(dim, NL, n, capacity=64,
                                       max_chain=128, train_vecs=vecs[:4096])
        if pq_cfg is not None:
            cfg = dataclasses.replace(cfg, pq=pq_cfg)
            cb = pqmod.train_pq(jax.random.key(5), jnp.asarray(vecs[:4096]),
                                m, nbits)
            state = core.init_state(cfg, jnp.asarray(cents), cb)
        for lo in range(0, n, 4096):
            state = core.insert(cfg, state, jnp.asarray(vecs[lo:lo + 4096]),
                                jnp.asarray(ids[lo:lo + 4096]))
        assert int(state.error) == 0
        return cfg, state

    cfg_raw, st_raw = build(None)
    cfg_pq, st_pq = build(sivf.PQConfig(m=m, nbits=nbits))

    def raw_scan(qs, table):
        return core.scan_slabs_topk(cfg_raw, st_raw, qs, table, k)

    def pq_scan(qs, table):
        return core.scan_slabs_topk_pq(cfg_pq, st_pq, qs, table, k)

    summary = {"dim": dim, "n": n, "m": m, "nbits": nbits,
               "bytes_per_vector": {"raw": dim * 4, "pq": m},
               "temp_bytes": {}, "reduction": {}, "qps": {}}
    for qn in (16, 64, 256):
        qs = jnp.asarray(np.random.default_rng(77)
                         .normal(size=(qn, dim)).astype(np.float32))
        peaks = {}
        for name, cfg_, st_, fn in (("raw", cfg_raw, st_raw, raw_scan),
                                    ("pq", cfg_pq, st_pq, pq_scan)):
            lists = core.probe(st_.centroids, qs, nprobe)
            table = core.gather_tables(cfg_, st_, lists)
            compiled = jax.jit(fn).lower(qs, table).compile()
            t, _ = timeit(compiled, qs, table, warmup=1, iters=3)
            mem = compiled.memory_analysis()
            peak = int(getattr(mem, "temp_size_in_bytes", 0) or 0)
            peaks[name] = peak
            summary["temp_bytes"].setdefault(name, {})[str(qn)] = peak
            summary["qps"].setdefault(name, {})[str(qn)] = round(qn / t, 1)
            rows.append(Row(f"pq_sweep.{name}@Q={qn}", t,
                            f"qps={qn / t:.0f} temp_mb={peak / 2 ** 20:.2f}"))
        if peaks["raw"] == 0:
            rows.append(Row(f"pq_sweep.memcheck@Q={qn}", 0.0,
                            "memory_analysis unavailable; check skipped"))
            continue
        red = peaks["raw"] / max(peaks["pq"], 1)
        summary["reduction"][str(qn)] = round(red, 2)
        assert red >= 4.0, \
            f"PQ slab temp reduction {red:.1f}x < 4x at Q={qn}"
        rows.append(Row(f"pq_sweep.reduction@Q={qn}", 0.0,
                        f"temp_bytes_reduction={red:.1f}x"))

    # recall@10 of ADC vs exact fp32 (full probe isolates the PQ loss)
    d, labels = core.search(cfg_pq, st_pq, jnp.asarray(qvecs), k, NL)
    true = exact_topk(vecs, qvecs, k)
    rec = recall_at_k(np.asarray(labels), true)
    summary["recall_at_10"] = round(rec, 4)
    assert rec >= 0.8, f"PQ recall@10 {rec:.3f} < 0.8"
    rows.append(Row("pq_sweep.recall", 0.0, f"recall@10={rec:.3f}"))

    # fused PQ Pallas kernel, interpreter-emulated: bit-exact parity witness
    qn = 8
    qs = jnp.asarray(qvecs[:qn])
    lists = core.probe(st_pq.centroids, qs, 2)
    table = core.gather_tables(cfg_pq, st_pq, lists)
    adc = pqmod.adc_tables(st_pq.pq_codebooks, qs, cfg_pq.metric)
    t, (dp, lp) = timeit(sivf_pq_fused_search_pallas, adc, table, st_pq.codes,
                         st_pq.ids, st_pq.bitmap, k, interpret=True,
                         warmup=0, iters=1)
    dr, lr = core.scan_slabs_topk_pq(cfg_pq, st_pq, qs, table, k, adc=adc)
    assert (np.asarray(dp) == np.asarray(dr)).all(), "pq kernel parity"
    assert (np.asarray(lp) == np.asarray(lr)).all(), "pq label parity"
    summary["pallas_interpret_parity"] = "bit-exact"
    rows.append(Row(f"pq_sweep.pallas_interpret@Q={qn}", t,
                    "parity=bit-exact (interpreter wall; not TPU perf)"))
    return rows, summary


def filtered_sweep_summary():
    """Filtered search vs post-filter-then-widen: (rows, summary) for
    run.py's ``BENCH_filter.json`` artifact.

    Three predicate selectivities (~1% / ~10% / ~50% of N=20k rows) over
    ``attributes=("tenant", "ts")``. For each: QPS and recall@10 of the
    fused in-scan predicate mask (``Index.search(..., filter=...)``,
    full probe) against the brute-force-within-predicate oracle, next to
    the classical *post-filter* baseline — search unfiltered with a
    widened k', drop non-matching rows on the host, keep k. Post-filter
    recall collapses as selectivity tightens (the widened window still
    fills with non-matching near neighbors); the fused mask stays at 1.0
    because filtered-out slots can never displace passing candidates.
    Also records the jit search-executable count: three different filter
    *structures* (Eq / In / Range) at one query bucket must stay bounded
    by structures + unfiltered, never by filter constants.
    """
    import dataclasses

    from repro.core import filters as flt

    rows = []
    dim, k, qn = 32, 10, 64
    n = N
    rng = np.random.default_rng(17)
    vecs = dataset(dim, n)
    ids = np.arange(n, dtype=np.int32)
    tenant = rng.integers(0, 100, n).astype(np.int32)
    ts = rng.integers(0, 1000, n).astype(np.int32)
    attr_mat = np.stack([tenant, ts], axis=1)
    qs = dataset(dim, qn, seed=3)

    cfg, _, cents = build_sivf(dim, NL, n)
    cfg = dataclasses.replace(cfg, attributes=("tenant", "ts"))
    index = sivf.Index(cfg, jnp.asarray(cents), min_bucket=64)
    for lo in range(0, n, 4096):
        index.add(vecs[lo:lo + 4096], ids[lo:lo + 4096],
                  attrs=attr_mat[lo:lo + 4096])
    assert index.n_live == n

    # exact squared-L2 once; every per-predicate oracle masks this matrix
    from repro.utils import l2_sq
    dmat = np.asarray(l2_sq(jnp.asarray(qs), jnp.asarray(vecs)))

    preds = {
        "sel1pct": sivf.Eq("tenant", 7),
        "sel10pct": sivf.In("tenant", tuple(range(10))),
        "sel50pct": sivf.Range("ts", 0, 500),
    }
    summary = {"n": n, "dim": dim, "k": k, "queries": qn,
               "selectivities": {}}
    for name, pred in preds.items():
        mask = flt.host_matches(pred, cfg.attributes, attr_mat)
        sel = float(mask.mean())
        dm = np.where(mask[None, :], dmat, np.inf)
        oracle = np.argsort(dm, axis=1, kind="stable")[:, :k]

        t_f, res = timeit(index.search, qs, k, filter=pred)
        rec_f = recall_at_k(np.asarray(res.labels), oracle)
        rows.append(Row(f"filtered.{name}.fused", t_f,
                        f"sel={sel:.3f} qps={qn / t_f:.0f} "
                        f"recall@10={rec_f:.3f}"))

        # post-filter-then-widen baseline: the window a post-filter needs
        # to match in-scan recall is ~k/sel; cap it at 512 (already 51x k)
        # to keep the baseline "practical" — that cap is exactly why its
        # recall collapses at 1% selectivity
        widen = int(min(max(np.ceil(k / max(sel, 1e-6)), k), 512))
        t_p, wres = timeit(index.search, qs, widen)
        wl = np.asarray(wres.labels)
        keep = np.where((wl >= 0) & mask[np.clip(wl, 0, n - 1)], wl, -1)
        post = np.full((qn, k), -1, np.int32)
        for i in range(qn):
            got = keep[i][keep[i] >= 0][:k]
            post[i, :len(got)] = got
        rec_p = recall_at_k(post, oracle)
        rows.append(Row(f"filtered.{name}.postfilter", t_p,
                        f"widen_k={widen} qps={qn / t_p:.0f} "
                        f"recall@10={rec_p:.3f}"))

        summary["selectivities"][name] = {
            "selectivity": round(sel, 4),
            "fused": {"qps": round(qn / t_f, 1),
                      "recall_at_10": round(rec_f, 4)},
            "postfilter": {"widen_k": widen, "qps": round(qn / t_p, 1),
                           "recall_at_10": round(rec_p, 4)},
        }
        assert rec_f >= rec_p - 1e-9, \
            f"fused recall {rec_f} < post-filter {rec_p} at {name}"

    # full probe + in-scan mask == brute force within the predicate
    for name, s in summary["selectivities"].items():
        assert s["fused"]["recall_at_10"] == 1.0, \
            f"fused filtered recall != 1.0 at {name}: {s['fused']}"
    summary["search_executables"] = index.compile_stats()["search"]
    rows.append(Row("filtered.search_executables", 0.0,
                    f"count={summary['search_executables']} "
                    f"(3 filter structures + 3 unfiltered widen ks)"))
    return rows, summary


def tab1_tail_latency():
    """Table 1: deletion latency avg/p99/max over many streaming steps."""
    rows = []
    for name, dim in [("sift", 128), ("gist", 960)]:
        n = 6_000
        cfg, state, cents = build_sivf(dim, NL, 2 * n, slab_factor=2.0)
        vecs = dataset(dim, n, seed=21)
        state = core.insert(cfg, state, jnp.asarray(vecs),
                            jnp.asarray(np.arange(n), jnp.int32))
        b = 100
        next_id = n
        lats = []
        for step in range(40):
            newv = jnp.asarray(dataset(dim, b, seed=300 + step))
            state = core.insert(cfg, state, newv, jnp.asarray(
                np.arange(next_id, next_id + b) % cfg.n_max, jnp.int32))
            evict = jnp.asarray(np.arange(next_id - n, next_id - n + b)
                                % cfg.n_max, jnp.int32)
            t0 = time.perf_counter()
            state = core.delete(cfg, state, evict)
            jax.block_until_ready(state.n_live)
            lats.append(time.perf_counter() - t0)
            next_id += b
        lats = np.array(lats[5:])
        p99 = percentiles(lats, (99.0,))[99.0]  # shared obs quantile math
        rows.append(Row(f"tab1.{name}.delete_avg", float(lats.mean()),
                        f"p99={p99 * 1e3:.2f}ms "
                        f"max={lats.max() * 1e3:.2f}ms"))
    return rows


def tab2_mixed_workload():
    """Table 2: search latency stability under insert->search->delete."""
    rows = []
    cfg, state, cents, vecs, ids = _sivf_loaded(n=10_000)
    qs = jnp.asarray(dataset(D, 16, seed=23))
    next_id = 10_000
    lats = []
    for step in range(25):
        newv = jnp.asarray(dataset(D, 200, seed=400 + step))
        state = core.insert(cfg, state, newv, jnp.asarray(
            np.arange(next_id, next_id + 200) % cfg.n_max, jnp.int32))
        t0 = time.perf_counter()
        d, lab = core.search(cfg, state, qs, 10, 8)
        jax.block_until_ready(d)
        lats.append(time.perf_counter() - t0)
        state = core.delete(cfg, state, jnp.asarray(
            np.arange(next_id - 10_000, next_id - 9_800) % cfg.n_max,
            jnp.int32))
        next_id += 200
    lats = np.array(lats[3:])
    p99 = percentiles(lats, (99.0,))[99.0]      # shared obs quantile math
    rows.append(Row("tab2.search_avg_under_churn", float(lats.mean()),
                    f"p99={p99 * 1e3:.2f}ms"))
    return rows


def tab3_time_breakdown():
    """Table 3 analogue: where update time goes (this platform has no
    PCIe roundtrip by construction — the paper's 53% transfer + 39% malloc
    categories are architecturally eliminated; we attribute the remaining
    in-place update time)."""
    cfg, state, cents, vecs, ids = _sivf_loaded()
    newv = jnp.asarray(dataset(D, BATCH, seed=31))
    nid = jnp.asarray(np.arange(N, N + BATCH), jnp.int32)
    wid = np.arange(N + BATCH, N + 2 * BATCH).astype(np.int32)  # warm
    state = core.insert(cfg, state,
                        jnp.asarray(dataset(D, BATCH, seed=32)),
                        jnp.asarray(wid))
    state = core.delete(cfg, state, jnp.asarray(wid))
    t_assign, lists = timeit(
        lambda: core.assign(state.centroids, newv), warmup=1, iters=3)
    t_insert, state = timeit(core.insert, cfg, state, newv, nid,
                             warmup=0, iters=1)
    t_delete, state = timeit(core.delete, cfg, state,
                             jnp.asarray(ids[:BATCH]), warmup=0, iters=1)
    tot = t_insert + t_delete
    return [
        Row("tab3.quantize_frac", t_assign,
            f"{100 * t_assign / tot:.1f}% of update cycle"),
        Row("tab3.insert_kernel", t_insert,
            f"{100 * t_insert / tot:.1f}%"),
        Row("tab3.delete_kernel", t_delete,
            f"{100 * t_delete / tot:.1f}%"),
        Row("tab3.host_transfer", 0.0,
            "0% (GPU/TPU-resident by construction; paper baseline: 53.2%)"),
    ]


def tab4_non_ivf_indexes():
    """Table 4: add throughput + delete latency across index families.

    Every engine — SIVF included — is driven through the one
    ``IndexProtocol`` surface (``add``/``remove``), so the comparison
    measures the index, not per-engine call conventions.
    """
    rows = []
    n, b = 5_000, 500
    vecs = dataset(D, n, seed=41)
    ids = np.arange(n, dtype=np.int32)

    cfg, _, cents = build_sivf(D, NL, n + b)
    sivf_idx = sivf.Index(cfg, cents)
    sivf_idx.add(vecs, ids)                       # warm compile
    sivf_idx.remove(ids[n - b:])                  # warm shape-b remove
    sivf_idx.remove(ids)                          # drain
    engines = [
        ("sivf", sivf_idx, n, b, ""),
        ("flat", FlatIndex(D, 2 * n), n, b, ""),
        ("lsh", LSHIndex(jax.random.key(2), D, bucket_cap=n), n, b, ""),
        # graph insert is O(N log N) python: smaller workload
        ("hnsw", HNSWLite(D, m=8, ef=24), 800, 100, " (full rebuild)"),
    ]
    for name, eng, na, nd, note in engines:
        t_add, _ = timeit(lambda e=eng, m=na: e.add(vecs[:m], ids[:m]),
                          warmup=0, iters=1)
        t_del, _ = timeit(lambda e=eng, m=nd: e.remove(ids[:m]),
                          warmup=0, iters=1)
        rows.append(Row(f"tab4.{name}.add", t_add, f"{na / t_add:.0f} vec/s"))
        rows.append(Row(f"tab4.{name}.delete", t_del,
                        f"{t_del * 1e3:.2f} ms{note}"))
    return rows


def _streaming_churn_impl(deferred: bool, flush_every: int = 8):
    """Shared body for the eager / deferred streaming-churn variants.

    Returns ``(rows, summary)`` where ``summary`` is the JSON-friendly
    record (p50/p99 per op + compile counts) that ``benchmarks/run.py
    streaming_churn --deferred`` persists to ``BENCH_streaming_churn.json``.
    """
    from repro.data.pipeline import VectorStream, VectorStreamConfig
    rng = np.random.default_rng(7)
    stream = VectorStream(VectorStreamConfig(dim=D, n_clusters=NL))
    cfg, _, cents = build_sivf(D, NL, 40_000, capacity=64, max_chain=48,
                               train_vecs=stream.batch(0, 4096))
    idx = sivf.Index(cfg, cents, min_bucket=64, deferred=deferred)
    window, max_b = 8_192, 1_024
    tag = "streaming_churn.deferred" if deferred else "streaming_churn"

    next_id = 0
    step = 0
    while next_id <= window + max_b:              # fill to steady state
        s = int(rng.integers(200, max_b))
        idx.add(stream.batch(1 + step, s),
                np.arange(next_id, next_id + s, dtype=np.int32))
        next_id += s
        step += 1
    idx.flush()

    lat = {"add": [], "remove": [], "search": [], "flush": []}
    sizes_seen = set()
    for step in range(60):
        s = int(rng.integers(1, max_b))
        sizes_seen.add(s)
        vecs_b = stream.batch(100 + step, s)
        ids_b = np.arange(next_id, next_id + s, dtype=np.int32)
        t0 = time.perf_counter()
        rep = idx.add(vecs_b, ids_b)
        lat["add"].append(time.perf_counter() - t0)
        if not deferred:
            assert rep.ok, rep                    # deferred: checked at flush
        next_id += s
        evict = np.arange(next_id - window - s, next_id - window,
                          dtype=np.int32)
        t0 = time.perf_counter()
        idx.remove(evict)
        lat["remove"].append(time.perf_counter() - t0)
        q = int(rng.integers(1, 64))
        qs = rng.normal(size=(q, D)).astype(np.float32)
        t0 = time.perf_counter()
        res = idx.search(qs, 10, 8)
        jax.block_until_ready(res.distances)
        lat["search"].append(time.perf_counter() - t0)
        if deferred and step % flush_every == flush_every - 1:
            t0 = time.perf_counter()
            reports = idx.flush()                 # one sync, flush_every*2 reports
            lat["flush"].append(time.perf_counter() - t0)
            assert all(r.ok for r in reports), reports
    if deferred:
        for r in idx.flush():
            assert r.ok, r

    rows = []
    summary = {"mode": "deferred" if deferred else "eager",
               "n_ragged_sizes": len(sizes_seen), "p50_us": {}, "p99_us": {}}
    if deferred:
        summary["flush_every"] = flush_every
    ops = ("add", "remove", "search") + (("flush",) if deferred else ())
    for op in ops:
        a = np.asarray(lat[op])
        p = percentiles(a, (50.0, 99.0))        # shared obs quantile math
        p50, p99 = p[50.0], p[99.0]
        summary["p50_us"][op] = round(p50 * 1e6, 1)
        summary["p99_us"][op] = round(p99 * 1e6, 1)
        rows.append(Row(f"{tag}.{op}.p50", p50, f"p99={p99 * 1e6:.0f}us"))
    comp = idx.compile_stats()
    n_buckets = len(idx.bucket_shapes(max_b))
    summary["jit_compiles"] = comp
    summary["bucket_bound"] = n_buckets
    rows.append(Row(
        f"{tag}.jit_compiles", 0.0,
        f"add={comp['add']} remove={comp['remove']} "
        f"search={comp['search']} over {len(sizes_seen)} ragged sizes "
        f"(bucket bound {n_buckets})"))
    return rows, summary


def streaming_churn():
    """Streaming-session benchmark through the `sivf.Index` handle (ISSUE 2).

    A sliding-window churn with *ragged* batch sizes: per-op p50/p99 wall
    latency for add / remove / search, plus the observed jit-executable
    counts — the handle's power-of-two bucketing must keep them bounded by
    the number of bucket shapes, not the number of distinct batch sizes.
    """
    rows, _ = _streaming_churn_impl(deferred=False)
    return rows


def streaming_churn_deferred():
    """Deferred-report variant (ISSUE 3): add/remove submit without a host
    sync and MutationReports resolve in batches at ``Index.flush()`` — the
    per-op numbers show the per-batch sync tax deferral removes, ``flush``
    shows where it went (amortized over ``flush_every`` steps)."""
    rows, _ = _streaming_churn_impl(deferred=True)
    return rows


def streaming_churn_compare():
    """Eager + deferred back-to-back on shared executables, for
    ``benchmarks/run.py streaming_churn --deferred``. The deferred run must
    add zero jit executables (same cfg -> same op set)."""
    eager_rows, eager = _streaming_churn_impl(deferred=False)
    deferred_rows, deferred = _streaming_churn_impl(deferred=True)
    assert deferred["jit_compiles"] == eager["jit_compiles"], (
        "deferred mode compiled new executables", eager, deferred)
    return eager_rows + deferred_rows, {"eager": eager, "deferred": deferred}


def reshard_sweep_summary():
    """Elastic resharding sweep (ISSUE 5): (rows, summary) for run.py's
    ``BENCH_reshard.json`` artifact.

    Builds a 100k-vector index (dim=64) and walks the shard chain
    1 -> 2 -> 4 via the pure ``core.distributed.reshard_state``, PQ off
    and on, recording per step: wall seconds, live rows, and the bytes
    the canonical live-row table moves (payload/codes + id + list per
    row — the quantity a real device-side reshard would put on the
    interconnect). Search parity vs the pre-reshard index is asserted at
    the end of each chain (ids AND distances bit-identical), so the slow
    CI smoke is a correctness witness, not just a timer.
    """
    import dataclasses
    from repro.core import distributed as dist
    from repro.core import pq as pqmod

    n, dim, n_lists = 100_000, 64, 32
    m, nbits = 8, 8
    rng = np.random.default_rng(13)
    vecs = dataset(dim, n)
    ids = np.arange(n, dtype=np.int32)
    qs = jnp.asarray(rng.normal(size=(16, dim)).astype(np.float32))
    rows: list[Row] = []
    summary = {"n": n, "dim": dim, "chain": [1, 2, 4], "variants": {}}

    for tag in ("raw", "pq"):
        cfg, state, cents = build_sivf(dim, n_lists, n, capacity=64,
                                       max_chain=256,
                                       train_vecs=vecs[:4096])
        if tag == "pq":
            cfg = dataclasses.replace(cfg, pq=sivf.PQConfig(m=m, nbits=nbits))
            cb = pqmod.train_pq(jax.random.key(5), jnp.asarray(vecs[:4096]),
                                m, nbits)
            state = core.init_state(cfg, jnp.asarray(cents), cb)
        t0 = time.perf_counter()
        for lo in range(0, n, 4096):
            state = core.insert(cfg, state, jnp.asarray(vecs[lo:lo + 4096]),
                                jnp.asarray(ids[lo:lo + 4096]))
        jax.block_until_ready(state.n_live)
        assert int(state.error) == 0
        build_s = time.perf_counter() - t0
        d0, l0 = core.search(cfg, state, qs, 10, 8)
        d0, l0 = np.asarray(d0), np.asarray(l0)

        # bytes one live row moves through the canonical table
        row_bytes = (cfg.payload_dim * jnp.dtype(cfg.dtype).itemsize
                     + cfg.code_m + 4 + 4)             # + id + list
        steps, n_from = [], 1
        for n_to in (2, 4):
            t0 = time.perf_counter()
            state = dist.reshard_state(cfg, state, n_from, n_to)
            jax.block_until_ready(state.n_live)
            secs = time.perf_counter() - t0
            live = int(np.asarray(state.n_live).sum())
            moved = live * row_bytes
            steps.append({"from": n_from, "to": n_to, "seconds":
                          round(secs, 3), "rows": live,
                          "bytes_moved": moved,
                          "mb_per_s": round(moved / 2**20 / secs, 1)})
            rows.append(Row(f"reshard_sweep.{tag}@{n_from}->{n_to}", secs,
                            f"rows={live} moved_mb={moved / 2**20:.1f} "
                            f"mbps={moved / 2**20 / secs:.0f}"))
            n_from = n_to
        d1, l1 = dist.search_stacked(cfg, state, qs, 10, 8)
        assert np.array_equal(d0, d1) and np.array_equal(l0, l1), \
            f"reshard changed search results ({tag})"
        rows.append(Row(f"reshard_sweep.{tag}.parity", 0.0,
                        "search=bit-identical after 1->2->4"))
        summary["variants"][tag] = {
            "build_seconds": round(build_s, 2),
            "row_bytes": int(row_bytes),
            "steps": steps,
            "search_parity": "bit-identical",
        }
    return rows, summary
