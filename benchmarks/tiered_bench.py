"""``tiered_sweep``: host-resident cold store vs device hot cache (ISSUE 8).

Sweeps the *working set* (live slabs) across 0.25x / 0.5x / 1x / 2x of a
fixed device cache budget and measures, per ratio:

  * steady-state **hit rate** of the probe-driven prefetch (counter
    deltas over the timed region only, after a full warmup rotation);
  * search **QPS** through the tiered path, next to the all-resident
    twin's QPS on the identical query schedule;
  * **parity** — every timed batch is compared bit-for-bit (ids AND
    distances) against the all-resident twin; the recorded value is 1.0
    only if every batch matched, so the gate turns any residency bug
    into a hard CI failure.

Queries model temporal locality (the regime a tiered cache serves):
each batch targets one cluster "window", and successive batches rotate
through the windows. At <=1x the whole index becomes resident and the
timed region runs at hit rate ~1.0 with zero uploads; at 2x the rotation
forces LRU eviction and the hit rate measures how much of the working
set survives a full cycle.

Writes ``BENCH_tiered.json`` via ``benchmarks/run.py tiered_sweep``.
"""
from __future__ import annotations

import time

import jax
import numpy as np

import sivf
from benchmarks.common import Row
from repro.obs import latency_summary_ms

DIM = 32
N_LISTS = 8
CAPACITY = 64
DEVICE_SLABS = 64               # fixed hot-cache budget (slabs)
K, NPROBE = 10, 2
Q = 64                          # bucket-aligned batch (no pad rows probe)
RATIOS = {"r025": 0.25, "r05": 0.5, "r10": 1.0, "r20": 2.0}
TIMED_ROTATIONS = 6             # full window cycles in the timed region


def _build_pair(rng, n: int):
    """(tiered, all-resident) twins over the same ``n`` vectors."""
    n_slabs = 2 * int(2.0 * DEVICE_SLABS) + N_LISTS    # fits the 2x point
    cents = rng.normal(size=(N_LISTS, DIM)).astype(np.float32) * 4.0
    kw = dict(dim=DIM, n_lists=N_LISTS, n_slabs=n_slabs, capacity=CAPACITY,
              n_max=1 << 18)
    it = sivf.Index(sivf.SIVFConfig(device_slabs=DEVICE_SLABS, **kw), cents)
    if_ = sivf.Index(sivf.SIVFConfig(**kw), cents)
    # draw vectors tightly around their centroid so list occupancy is
    # uniform and a window's probes stay inside the window's chains
    owner = np.arange(n) % N_LISTS
    vecs = (cents[owner] + 0.1 * rng.normal(size=(n, DIM))).astype(
        np.float32)
    ids = np.arange(n, dtype=np.int32)
    for idx in (it, if_):
        r = idx.add(vecs, ids)
        assert r.ok, r
    return it, if_, cents


def _query_schedule(rng, cents) -> list[np.ndarray]:
    """One bucket-aligned batch per cluster window, cycling all lists."""
    return [(cents[w] + 0.1 * rng.normal(size=(Q, DIM))).astype(np.float32)
            for w in range(N_LISTS)]


def _run_point(rng, ratio_key: str, ratio: float):
    n = int(ratio * DEVICE_SLABS * CAPACITY)
    it, if_, cents = _build_pair(rng, n)
    batches = _query_schedule(rng, cents)

    def sweep(idx, lats=None):
        out = []
        for qs in batches:
            t = time.perf_counter()
            res = idx.search(qs, k=K, nprobe=NPROBE)
            out.append((np.asarray(res.labels), np.asarray(res.distances)))
            if lats is not None:        # np.asarray above forced the sync
                lats.append(time.perf_counter() - t)
        return out

    sweep(it), sweep(if_)                       # warmup: jit + cache fill
    s0 = it.stats()
    batch_lats: list[float] = []
    t0 = time.perf_counter()
    for _ in range(TIMED_ROTATIONS):
        got = sweep(it, batch_lats)
    t_tiered = time.perf_counter() - t0
    s1 = it.stats()
    t0 = time.perf_counter()
    for _ in range(TIMED_ROTATIONS):
        ref = sweep(if_)
    t_full = time.perf_counter() - t0

    parity = all(np.array_equal(g[0], r[0]) and np.array_equal(g[1], r[1])
                 for g, r in zip(got, ref))
    dh = s1["cache_hits"] - s0["cache_hits"]
    dm = s1["cache_misses"] - s0["cache_misses"]
    nq = TIMED_ROTATIONS * len(batches) * Q
    point = {
        "n_vectors": n,
        "slabs_used": int(it.stats()["slabs_used"]),
        "working_set_ratio": round(ratio, 4),
        "hit_rate": round(dh / max(dh + dm, 1), 4),
        "uploads_per_rotation": round(
            (s1["cache_uploads"] - s0["cache_uploads"]) / TIMED_ROTATIONS,
            2),
        "qps": round(nq / t_tiered, 1),
        "all_resident_qps": round(nq / t_full, 1),
        "parity": 1.0 if parity else 0.0,
    }
    point.update(latency_summary_ms(batch_lats))    # per-batch, shared math
    row = Row(
        f"tiered_sweep.{ratio_key}", t_tiered / nq,
        f"ws={ratio:g}x hit_rate={point['hit_rate']:.3f} "
        f"qps={point['qps']:.0f} full={point['all_resident_qps']:.0f}qps "
        f"batch_p99={point['p99_ms']}ms "
        f"parity={'OK' if parity else 'FAIL'}")
    return row, point


def tiered_sweep_summary():
    """-> (rows, summary dict) for ``BENCH_tiered.json``."""
    rng = np.random.default_rng(0)
    rows, ratios = [], {}
    for key, ratio in RATIOS.items():
        row, point = _run_point(rng, key, ratio)
        rows.append(row)
        ratios[key] = point
    bad = [k for k, p in ratios.items() if p["parity"] != 1.0]
    if bad:        # --strict turns this into a non-zero CI exit
        raise AssertionError(
            f"tiered search diverged from the all-resident pool at "
            f"{','.join(bad)} — residency bug")
    mem = sivf.memory_report(sivf.SIVFConfig(
        dim=DIM, n_lists=N_LISTS, n_slabs=2 * int(2.0 * DEVICE_SLABS)
        + N_LISTS, capacity=CAPACITY, n_max=1 << 18,
        device_slabs=DEVICE_SLABS))
    summary = {
        "dim": DIM, "n_lists": N_LISTS, "capacity": CAPACITY,
        "device_slabs": DEVICE_SLABS, "k": K, "nprobe": NPROBE,
        "queries_per_batch": Q,
        "host_bytes": mem["host_bytes"],
        "device_cache_bytes": mem["device_cache_bytes"],
        "ratios": ratios,
        "backend": jax.default_backend(),
    }
    return rows, summary
